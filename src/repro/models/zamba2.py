"""Zamba2 hybrid: a Mamba2 backbone with one *shared* full-attention block
invoked every (hybrid_ratio+1)-th position.  The shared block's weights live
once in HBM (the Zamba2 memory trick); each invocation applies its own
low-rank (LoRA) delta, and the block input fuses the current hidden state
with the original token embedding (concat + projection).
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as T
from repro.models.layers import _normal, dense_init, dense, rmsnorm_init, rmsnorm
from repro.models.mamba2 import (mamba2_init, mamba2_apply, mamba2_decode,
                                 make_mamba_cache)

LORA_TARGETS = ("wq", "wk", "wv", "wo", "gate", "up", "down")


def derive_pattern(cfg) -> Tuple[Tuple[int, Tuple[str, ...]], ...]:
    n = cfg.n_layers
    r = cfg.hybrid_ratio
    if not (r and cfg.shared_attn):
        return ((n, ("m",)),)
    P = r + 1
    full, rem = divmod(n, P)
    groups = []
    if full:
        groups.append((full, ("m",) * r + ("A",)))
    if rem:
        groups.append((1, ("m",) * rem))
    return tuple(groups)


def n_attn_invocations(cfg) -> int:
    return sum(count * pattern.count("A")
               for count, pattern in derive_pattern(cfg))


# ---------------------------------------------------------------------------
# Shared attention block (+ LoRA deltas)
# ---------------------------------------------------------------------------

def shared_block_init(key, cfg, dtype):
    ks = jax.random.split(key, 4)
    return {
        "in_fuse": dense_init(ks[0], 2 * cfg.d_model, cfg.d_model, dtype),
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "attn": L.attn_init(ks[1], cfg, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
        "ffn": L.mlp_init(ks[2], cfg.d_model, cfg.d_ff, dtype),
    }


def _lora_shapes(cfg):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    qkv_out = {"wq": cfg.n_heads * hd, "wk": cfg.n_kv_heads * hd,
               "wv": cfg.n_kv_heads * hd}
    shapes = {}
    for t in LORA_TARGETS:
        if t in qkv_out:
            shapes[t] = (d, qkv_out[t])
        elif t == "wo":
            shapes[t] = (cfg.n_heads * hd, d)
        elif t in ("gate", "up"):
            shapes[t] = (d, cfg.d_ff)
        else:  # down
            shapes[t] = (cfg.d_ff, d)
    return shapes


def lora_init(key, cfg, dtype):
    r = cfg.shared_attn_lora_rank
    shapes = _lora_shapes(cfg)
    ks = jax.random.split(key, len(shapes))
    p = {}
    for (t, (din, dout)), k in zip(shapes.items(), ks):
        p[t] = {"a": _normal(k, (din, r), dtype, 1.0 / math.sqrt(din)),
                "b": jnp.zeros((r, dout), dtype)}
    return p


def _lora_merge(shared, lora):
    """Materialise effective block params = shared + a@b deltas."""
    eff = jax.tree_util.tree_map(lambda x: x, shared)  # shallow-ish copy
    for t in LORA_TARGETS:
        delta = (lora[t]["a"] @ lora[t]["b"])
        if t in ("wq", "wk", "wv", "wo"):
            eff["attn"][t] = dict(eff["attn"][t])
            eff["attn"][t]["w"] = eff["attn"][t]["w"] + delta
        else:
            eff["ffn"][t] = dict(eff["ffn"][t])
            eff["ffn"][t]["w"] = eff["ffn"][t]["w"] + delta
    return eff


def shared_block_apply(shared, lora, cfg, x, x0, positions, *,
                       collect_cache=False, cache_cap=0):
    eff = _lora_merge(shared, lora)
    fused = dense(eff["in_fuse"], jnp.concatenate([x, x0], axis=-1))
    h = rmsnorm(eff["ln1"], fused, cfg.norm_eps)
    attn_out, kv = L.attn_apply(eff["attn"], cfg, h, positions, window=0)
    x = x + attn_out
    h2 = rmsnorm(eff["ln2"], x, cfg.norm_eps)
    x = x + L.mlp_apply(eff["ffn"], h2)
    if collect_cache:
        desc = T.LayerDesc(0, cfg.rope_theta, False)
        return x, T._pack_cache(kv, desc, cache_cap)
    return x, None


def shared_block_decode(shared, lora, cfg, x, x0, pos, k_cache, v_cache):
    eff = _lora_merge(shared, lora)
    fused = dense(eff["in_fuse"], jnp.concatenate([x, x0], axis=-1))
    h = rmsnorm(eff["ln1"], fused, cfg.norm_eps)
    attn_out, k_cache, v_cache = L.attn_decode(eff["attn"], cfg, h, pos,
                                               k_cache, v_cache, window=0)
    x = x + attn_out
    h2 = rmsnorm(eff["ln2"], x, cfg.norm_eps)
    x = x + L.mlp_apply(eff["ffn"], h2)
    return x, k_cache, v_cache


# ---------------------------------------------------------------------------
# Full hybrid LM
# ---------------------------------------------------------------------------

def _mamba_block_init(key, cfg, dtype):
    return {"ln": rmsnorm_init(cfg.d_model, dtype),
            "mamba": mamba2_init(key, cfg, dtype)}


def init_lm(cfg, key):
    dt = jnp.dtype(cfg.param_dtype)
    groups = derive_pattern(cfg)
    keys = jax.random.split(key, len(groups) + 3)
    params = {"embed": L.embed_init(keys[0], cfg.vocab_size, cfg.d_model, dt),
              "final_norm": rmsnorm_init(cfg.d_model, dt),
              "shared": shared_block_init(keys[-2], cfg, dt)}
    gp = []
    for gi, (count, pattern) in enumerate(groups):
        pkeys = jax.random.split(keys[gi + 1], len(pattern))
        stacked = []
        for j, kind in enumerate(pattern):
            bkeys = jax.random.split(pkeys[j], count)
            if kind == "m":
                stacked.append(jax.vmap(
                    lambda k: _mamba_block_init(k, cfg, dt))(bkeys))
            else:
                stacked.append(jax.vmap(lambda k: lora_init(k, cfg, dt))(bkeys))
        gp.append(stacked)
    params["groups"] = gp
    if not cfg.tie_embeddings:
        params["head"] = dense_init(keys[-1], cfg.d_model, cfg.vocab_size, dt)
    return params


def _forward(params, cfg, x, positions, ctx, *, remat=False, collect=False,
             cache_cap=0):
    groups = derive_pattern(cfg)
    x0 = x  # original embeddings feed every shared-attn invocation
    caches = [] if collect else None
    for gi, (count, pattern) in enumerate(groups):
        stacked = params["groups"][gi]

        def body(xc, xs, pattern=pattern):
            outs = []
            for j, kind in enumerate(pattern):
                if kind == "m":
                    h = rmsnorm(xs[j]["ln"], xc, cfg.norm_eps)
                    if collect:
                        y, c = mamba2_apply(xs[j]["mamba"], cfg, h,
                                            return_state=True)
                        outs.append(c)
                    else:
                        y = mamba2_apply(xs[j]["mamba"], cfg, h)
                    xc = xc + y
                else:
                    xc, c = shared_block_apply(
                        params["shared"], xs[j], cfg, xc, x0, positions,
                        collect_cache=collect, cache_cap=cache_cap)
                    if collect:
                        outs.append(c)
            if ctx is not None:
                xc = ctx.constrain_batch(xc)
            return xc, (outs if collect else None)

        if remat:
            body = jax.checkpoint(body)
        x, ys = jax.lax.scan(body, x, stacked)
        if collect:
            caches.append(ys)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, caches


def train_loss(params, cfg, batch, ctx=None, *, remat: bool = True):
    tokens, targets = batch["tokens"], batch["targets"]
    B, S = tokens.shape
    x = L.embed(params["embed"], tokens, jnp.dtype(cfg.compute_dtype))
    if ctx is not None:
        x = ctx.constrain_batch(x)
    positions = L.make_positions(B, S)
    hidden, _ = _forward(params, cfg, x, positions, ctx, remat=remat)
    ce = T.chunked_ce(params, cfg, hidden, targets, batch.get("loss_mask"))
    return ce, {"ce": ce}


def prefill(params, cfg, batch, ctx=None, *, max_len=None):
    tokens = batch["tokens"]
    B, S = tokens.shape
    max_len = max_len or S
    x = L.embed(params["embed"], tokens, jnp.dtype(cfg.compute_dtype))
    if ctx is not None:
        x = ctx.constrain_batch(x)
    positions = L.make_positions(B, S)
    hidden, caches = _forward(params, cfg, x, positions, ctx, collect=True,
                              cache_cap=max_len)
    logits = T.logits_fn(params, cfg, hidden[:, -1:, :])[:, 0]
    # decode path needs x0 at decode time: recomputed from the new token
    return logits, {"groups": caches, "pos": jnp.int32(S)}


def decode_step(params, cfg, cache, token, ctx=None):
    B = token.shape[0]
    x = L.embed(params["embed"], token[:, None], jnp.dtype(cfg.compute_dtype))
    x0 = x
    pos = cache["pos"].astype(jnp.int32)
    groups = derive_pattern(cfg)
    new_groups = []
    for gi, (count, pattern) in enumerate(groups):
        stacked = params["groups"][gi]
        cache_g = cache["groups"][gi]

        def body(xc, xs, pattern=pattern):
            ps, cs = xs
            outs = []
            for j, kind in enumerate(pattern):
                if kind == "m":
                    h = rmsnorm(ps[j]["ln"], xc, cfg.norm_eps)
                    y, c_new = mamba2_decode(ps[j]["mamba"], cfg, h, cs[j])
                    xc = xc + y
                else:
                    xc, ck, cv = shared_block_decode(
                        params["shared"], ps[j], cfg, xc, x0, pos,
                        cs[j]["k"], cs[j]["v"])
                    c_new = {"k": ck, "v": cv}
                outs.append(c_new)
            return xc, outs

        x, ng = jax.lax.scan(body, x, (stacked, cache_g))
        new_groups.append(ng)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = T.logits_fn(params, cfg, x)[:, 0]
    return logits, {"groups": new_groups, "pos": pos + 1}


def make_decode_cache(cfg, batch_size: int, max_len: int, dtype=None):
    dt = dtype or jnp.dtype(cfg.param_dtype)
    KV, D = cfg.n_kv_heads, cfg.resolved_head_dim

    def stack_cache(c, count):
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (count,) + a.shape), c)

    groups = []
    for count, pattern in derive_pattern(cfg):
        gs = []
        for kind in pattern:
            if kind == "m":
                gs.append(stack_cache(make_mamba_cache(cfg, batch_size, dt),
                                      count))
            else:
                gs.append({"k": jnp.zeros((count, batch_size, max_len, KV, D),
                                          dt),
                           "v": jnp.zeros((count, batch_size, max_len, KV, D),
                                          dt)})
        groups.append(gs)
    return {"groups": groups, "pos": jnp.int32(0)}
