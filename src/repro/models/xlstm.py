"""xLSTM (arXiv:2405.04517): mLSTM (matrix-memory, chunkwise-parallel) and
sLSTM (scalar-memory, inherently sequential) blocks in the paper's
xLSTM[m:1] mix.

mLSTM chunkwise recurrence (per head, stabilised exponential gating)
--------------------------------------------------------------------
state: C (Dk,Dv) = Σ decay · i_j · k_j v_jᵀ,  n (Dk),  m (stabiliser).
Within a chunk with carry (C0, n0, m0):
    b_i   = Σ_{s≤i} log f_s              (inclusive cumsum)
    s_ij  = b_i − b_j + ĩ_j   (j ≤ i)    intra-chunk log weights
    a_i   = b_i + m0                      carry-in log weight
    m_i   = max(max_j s_ij, a_i)
    h_i   = Σ_j e^{s_ij−m_i}(q_i·k_j)v_j + e^{a_i−m_i}(q_iᵀC0)
    l_i   = Σ_j e^{s_ij−m_i}(q_i·k_j)   + e^{a_i−m_i}(q_i·n0)
    y_i   = h_i / max(|l_i|, e^{−m_i})
The sLSTM keeps recurrent weights on the hidden state and is computed with a
lax.scan over time — per the paper, it is not parallelisable; that is the
architectural trade the 7:1 mix makes.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as T
from repro.models.layers import _normal, dense_init, dense, rmsnorm_init, rmsnorm

MLSTM_CHUNK = 256


# ---------------------------------------------------------------------------
# mLSTM cell
# ---------------------------------------------------------------------------

def _segsum(x):
    Q = x.shape[-1]
    c = jnp.cumsum(x, axis=-1)
    diff = c[..., :, None] - c[..., None, :]
    i = jnp.arange(Q)
    return jnp.where(i[:, None] >= i[None, :], diff, -jnp.inf)


def mlstm_chunked(q, k, v, igate, fgate, chunk: int = MLSTM_CHUNK,
                  init_state=None, return_state: bool = False):
    """q/k/v (B,S,H,D); igate/fgate (B,S,H) log-space gates.
    Returns y (B,S,H,D) [, state dict]."""
    B, S, H, D = q.shape
    scale = 1.0 / math.sqrt(D)
    Q = min(chunk, S)
    nc = -(-S // Q)
    pad = nc * Q - S
    if pad:
        z4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        z3 = ((0, 0), (0, pad), (0, 0))
        q, k, v = (jnp.pad(a, z4) for a in (q, k, v))
        igate = jnp.pad(igate, z3, constant_values=-1e9)  # i=0 at pads
        fgate = jnp.pad(fgate, z3)                        # logf=0: no decay

    qc = q.reshape(B, nc, Q, H, D).transpose(1, 0, 3, 2, 4).astype(jnp.float32) * scale
    kc = k.reshape(B, nc, Q, H, D).transpose(1, 0, 3, 2, 4).astype(jnp.float32)
    vc = v.reshape(B, nc, Q, H, D).transpose(1, 0, 3, 2, 4).astype(jnp.float32)
    gi = igate.reshape(B, nc, Q, H).transpose(1, 0, 3, 2).astype(jnp.float32)
    gf = fgate.reshape(B, nc, Q, H).transpose(1, 0, 3, 2).astype(jnp.float32)
    # all chunked tensors: (nc, B, H, Q, ...)

    if init_state is None:
        C0 = jnp.zeros((B, H, D, D), jnp.float32)
        n0 = jnp.zeros((B, H, D), jnp.float32)
        m0 = jnp.full((B, H), -1e9, jnp.float32)
    else:
        C0, n0, m0 = init_state["C"], init_state["n"], init_state["m"]

    def body(carry, xs):
        C, n, m = carry
        qi, ki, vi, g, f = xs        # (B,H,Q,D) / (B,H,Q)
        b = jnp.cumsum(f, axis=-1)   # (B,H,Q) inclusive
        s = _segsum(f) + g[..., None, :]
        # s_ij = (b_i - b_j) + g_j  -> shape (B,H,Q,Q)
        a = b + m[..., None]         # (B,H,Q)
        m_i = jnp.maximum(jnp.max(s, axis=-1), a)
        m_i = jnp.maximum(m_i, -1e30)
        Dm = jnp.exp(s - m_i[..., None])            # (B,H,Q,Q)
        am = jnp.exp(a - m_i)                        # (B,H,Q)
        qk = jnp.einsum("bhqd,bhkd->bhqk", qi, ki)   # (B,H,Q,Q)
        wij = Dm * qk
        h = jnp.einsum("bhqk,bhkd->bhqd", wij, vi) + \
            am[..., None] * jnp.einsum("bhqd,bhdv->bhqv", qi, C)
        l = jnp.sum(wij, axis=-1) + am * jnp.einsum("bhqd,bhd->bhq", qi, n)
        y = h / jnp.maximum(jnp.abs(l), jnp.exp(-m_i))[..., None]

        # chunk-boundary state update
        bQ = b[..., -1]                                  # (B,H)
        w_j = bQ[..., None] - b + g                      # (B,H,Q)
        m_new = jnp.maximum(bQ + m, jnp.max(w_j, axis=-1))
        old_scale = jnp.exp(bQ + m - m_new)              # (B,H)
        wj = jnp.exp(w_j - m_new[..., None])             # (B,H,Q)
        C_new = old_scale[..., None, None] * C + \
            jnp.einsum("bhq,bhqd,bhqv->bhdv", wj, ki, vi)
        n_new = old_scale[..., None] * n + \
            jnp.einsum("bhq,bhqd->bhd", wj, ki)
        return (C_new, n_new, m_new), y

    (Cf, nf, mf), ys = jax.lax.scan(body, (C0, n0, m0), (qc, kc, vc, gi, gf))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, nc * Q, H, D)[:, :S]
    if return_state:
        return y.astype(q.dtype), {"C": Cf, "n": nf, "m": mf}
    return y.astype(q.dtype)


def mlstm_decode(q, k, v, igate, fgate, state):
    """One step: q/k/v (B,H,D); gates (B,H) log-space."""
    C, n, m = state["C"], state["n"], state["m"]
    scale = 1.0 / math.sqrt(q.shape[-1])
    q = q.astype(jnp.float32) * scale
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    m_new = jnp.maximum(fgate + m, igate)
    fs = jnp.exp(fgate + m - m_new)
    is_ = jnp.exp(igate - m_new)
    C = fs[..., None, None] * C + is_[..., None, None] * \
        jnp.einsum("bhd,bhv->bhdv", k, v)
    n = fs[..., None] * n + is_[..., None] * k
    h = jnp.einsum("bhd,bhdv->bhv", q, C)
    l = jnp.einsum("bhd,bhd->bh", q, n)
    y = h / jnp.maximum(jnp.abs(l), jnp.exp(-m_new))[..., None]
    return y, {"C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------

def mlstm_block_init(key, cfg, dtype):
    d = cfg.d_model
    d_inner = cfg.ssm_expand * d
    H = cfg.n_heads
    ks = jax.random.split(key, 7)
    return {
        "ln": rmsnorm_init(d, dtype),
        "up": dense_init(ks[0], d, 2 * d_inner, dtype),
        "conv_w": _normal(ks[1], (cfg.ssm_conv, d_inner), dtype,
                          1.0 / math.sqrt(cfg.ssm_conv)),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "wq": dense_init(ks[2], d_inner, d_inner, dtype),
        "wk": dense_init(ks[3], d_inner, d_inner, dtype),
        "gates": dense_init(ks[4], d_inner, 2 * H, dtype, bias=True),
        "mh_norm": rmsnorm_init(d_inner, dtype),
        "skip": jnp.zeros((d_inner,), dtype),
        "down": dense_init(ks[5], d_inner, d, dtype,
                           scale=1.0 / math.sqrt(d_inner)),
    }


def _mlstm_qkvg(p, cfg, xm_conv, xm):
    B, S, d_inner = xm.shape
    H = cfg.n_heads
    D = d_inner // H
    q = dense(p["wq"], xm_conv).reshape(B, S, H, D)
    k = dense(p["wk"], xm_conv).reshape(B, S, H, D)
    v = xm.reshape(B, S, H, D)
    g = dense(p["gates"], xm_conv).astype(jnp.float32)
    ig, fg = jnp.split(g, 2, axis=-1)                 # (B,S,H)
    fg = jax.nn.log_sigmoid(fg + 3.0)                 # bias toward remember
    return q, k, v, ig, fg


def mlstm_block_apply(p, cfg, x, *, return_state=False, cache=None):
    from repro.models.mamba2 import _causal_conv
    B, S, d = x.shape
    h = rmsnorm(p["ln"], x, cfg.norm_eps)
    up = dense(p["up"], h)
    xm, z = jnp.split(up, 2, axis=-1)
    if cache is not None:
        ext = jnp.concatenate([cache["conv"].astype(xm.dtype), xm], axis=1)
        conv = _causal_conv(ext, p["conv_w"], p["conv_b"])[:, cache["conv"].shape[1]:]
    else:
        conv = _causal_conv(xm, p["conv_w"], p["conv_b"])
    conv = jax.nn.silu(conv.astype(jnp.float32)).astype(x.dtype)
    q, k, v, ig, fg = _mlstm_qkvg(p, cfg, conv, xm)
    init_state = cache["state"] if cache is not None else None
    if return_state:
        y, state = mlstm_chunked(q, k, v, ig, fg, init_state=init_state,
                                 return_state=True)
    else:
        y = mlstm_chunked(q, k, v, ig, fg, init_state=init_state)
    d_inner = xm.shape[-1]
    y = y.reshape(B, S, d_inner)
    y = rmsnorm(p["mh_norm"], y, cfg.norm_eps)
    y = y + p["skip"].astype(y.dtype) * conv
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    out = x + dense(p["down"], y)
    if return_state:
        K = p["conv_w"].shape[0]
        tail = xm if cache is None else jnp.concatenate(
            [cache["conv"].astype(xm.dtype), xm], axis=1)
        conv_cache = tail[:, -(K - 1):, :]
        if conv_cache.shape[1] < K - 1:
            conv_cache = jnp.pad(conv_cache,
                                 ((0, 0), (K - 1 - conv_cache.shape[1], 0),
                                  (0, 0)))
        return out, {"state": state, "conv": conv_cache}
    return out


def mlstm_block_decode(p, cfg, x, cache):
    """x (B,1,d)."""
    B, _, d = x.shape
    h = rmsnorm(p["ln"], x, cfg.norm_eps)
    up = dense(p["up"], h)[:, 0]
    xm, z = jnp.split(up, 2, axis=-1)
    conv_in = jnp.concatenate(
        [cache["conv"], xm[:, None, :].astype(cache["conv"].dtype)], axis=1)
    conv = jnp.einsum("bkc,kc->bc", conv_in.astype(jnp.float32),
                      p["conv_w"].astype(jnp.float32)) + \
        p["conv_b"].astype(jnp.float32)
    conv = jax.nn.silu(conv).astype(x.dtype)
    q, k, v, ig, fg = _mlstm_qkvg(p, cfg, conv[:, None, :], xm[:, None, :])
    y, state = mlstm_decode(q[:, 0], k[:, 0], v[:, 0], ig[:, 0], fg[:, 0],
                            cache["state"])
    d_inner = xm.shape[-1]
    y = y.reshape(B, d_inner).astype(x.dtype)
    y = rmsnorm(p["mh_norm"], y, cfg.norm_eps)
    y = y + p["skip"].astype(y.dtype) * conv
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    out = x + dense(p["down"], y)[:, None, :]
    return out, {"state": state, "conv": conv_in[:, 1:, :]}


# ---------------------------------------------------------------------------
# sLSTM block
# ---------------------------------------------------------------------------

def slstm_block_init(key, cfg, dtype):
    d = cfg.d_model
    H = cfg.n_heads
    Dh = d // H
    ff = int(math.ceil(4 * d / 3 / 64) * 64)
    ks = jax.random.split(key, 4)
    return {
        "ln": rmsnorm_init(d, dtype),
        "conv_w": _normal(ks[0], (cfg.ssm_conv, d), dtype,
                          1.0 / math.sqrt(cfg.ssm_conv)),
        "conv_b": jnp.zeros((d,), dtype),
        "w": dense_init(ks[1], d, 4 * d, dtype, bias=True),
        "r": _normal(ks[2], (H, Dh, 4 * Dh), dtype, 1.0 / math.sqrt(Dh)),
        "gn": rmsnorm_init(d, dtype),
        "ffn": L.mlp_init(ks[3], d, ff, dtype),
        "ffn_ln": rmsnorm_init(d, dtype),
    }


def _slstm_cell(carry, wx, r, H, Dh):
    """carry: (c, n, m, h) each (B,H,Dh); wx (B,4d) pre-activations."""
    c, n, m, h = carry
    B = wx.shape[0]
    rh = jnp.einsum("bhd,hdk->bhk", h, r.astype(h.dtype))  # (B,H,4Dh)
    pre = wx.reshape(B, H, 4 * Dh) + rh
    zt, it, ft, ot = jnp.split(pre.astype(jnp.float32), 4, axis=-1)
    zt = jnp.tanh(zt)
    ot = jax.nn.sigmoid(ot)
    lf = jax.nn.log_sigmoid(ft)                  # log f
    m_new = jnp.maximum(lf + m, it)
    i_ = jnp.exp(it - m_new)
    f_ = jnp.exp(lf + m - m_new)
    c = f_ * c + i_ * zt
    n = f_ * n + i_
    h_new = ot * c / jnp.maximum(n, 1e-6)
    return (c, n, m_new, h_new), h_new


def slstm_scan(p, cfg, conv_out, init=None):
    """conv_out (B,S,d) -> (h (B,S,d), final carry)."""
    B, S, d = conv_out.shape
    H = cfg.n_heads
    Dh = d // H
    wx = dense(p["w"], conv_out)                    # (B,S,4d)
    if init is None:
        z = jnp.zeros((B, H, Dh), jnp.float32)
        init = (z, z, jnp.full((B, H, Dh), -1e9, jnp.float32), z)

    def body(carry, wxt):
        return _slstm_cell(carry, wxt, p["r"], H, Dh)

    carry, hs = jax.lax.scan(body, init, wx.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, d).astype(conv_out.dtype)
    return h, carry


def slstm_block_apply(p, cfg, x, *, return_state=False, cache=None):
    from repro.models.mamba2 import _causal_conv
    B, S, d = x.shape
    h0 = rmsnorm(p["ln"], x, cfg.norm_eps)
    if cache is not None:
        ext = jnp.concatenate([cache["conv"].astype(h0.dtype), h0], axis=1)
        conv = _causal_conv(ext, p["conv_w"], p["conv_b"])[:, cache["conv"].shape[1]:]
    else:
        conv = _causal_conv(h0, p["conv_w"], p["conv_b"])
    conv = jax.nn.silu(conv.astype(jnp.float32)).astype(x.dtype)
    init = cache["state"] if cache is not None else None
    hs, carry = slstm_scan(p, cfg, conv, init)
    hs = rmsnorm(p["gn"], hs, cfg.norm_eps)
    x = x + hs
    x = x + L.mlp_apply(p["ffn"], rmsnorm(p["ffn_ln"], x, cfg.norm_eps))
    if return_state:
        K = p["conv_w"].shape[0]
        tail = h0 if cache is None else jnp.concatenate(
            [cache["conv"].astype(h0.dtype), h0], axis=1)
        cc = tail[:, -(K - 1):, :]
        if cc.shape[1] < K - 1:
            cc = jnp.pad(cc, ((0, 0), (K - 1 - cc.shape[1], 0), (0, 0)))
        return x, {"state": carry, "conv": cc}
    return x


def slstm_block_decode(p, cfg, x, cache):
    B, _, d = x.shape
    h0 = rmsnorm(p["ln"], x, cfg.norm_eps)
    conv_in = jnp.concatenate(
        [cache["conv"], h0[:, 0][:, None, :].astype(cache["conv"].dtype)],
        axis=1)
    conv = jnp.einsum("bkc,kc->bc", conv_in.astype(jnp.float32),
                      p["conv_w"].astype(jnp.float32)) + \
        p["conv_b"].astype(jnp.float32)
    conv = jax.nn.silu(conv).astype(x.dtype)
    hs, carry = slstm_scan(p, cfg, conv[:, None, :], cache["state"])
    hs = rmsnorm(p["gn"], hs, cfg.norm_eps)
    x = x + hs
    x = x + L.mlp_apply(p["ffn"], rmsnorm(p["ffn_ln"], x, cfg.norm_eps))
    return x, {"state": carry, "conv": conv_in[:, 1:, :]}


# ---------------------------------------------------------------------------
# Full xLSTM LM
# ---------------------------------------------------------------------------

def derive_pattern(cfg) -> Tuple[Tuple[int, Tuple[str, ...]], ...]:
    """Groups of (count, pattern) with 'm'/'s' block kinds, xLSTM[m:1]."""
    n = cfg.n_layers
    r = cfg.mlstm_ratio
    if not r:
        return ((n, ("m",)),)
    P = r + 1
    full, rem = divmod(n, P)
    pattern = ("m",) * r + ("s",)
    groups = []
    if full:
        groups.append((full, pattern))
    if rem:
        groups.append((1, ("m",) * rem))
    return tuple(groups)


def init_lm(cfg, key):
    dt = jnp.dtype(cfg.param_dtype)
    groups = derive_pattern(cfg)
    keys = jax.random.split(key, len(groups) + 2)
    params = {"embed": L.embed_init(keys[0], cfg.vocab_size, cfg.d_model, dt),
              "final_norm": rmsnorm_init(cfg.d_model, dt)}
    gp = []
    for gi, (count, pattern) in enumerate(groups):
        pkeys = jax.random.split(keys[gi + 1], len(pattern))
        stacked = []
        for j, kind in enumerate(pattern):
            bkeys = jax.random.split(pkeys[j], count)
            init_fn = mlstm_block_init if kind == "m" else slstm_block_init
            stacked.append(jax.vmap(lambda k: init_fn(k, cfg, dt))(bkeys))
        gp.append(stacked)
    params["groups"] = gp
    if not cfg.tie_embeddings:
        params["head"] = dense_init(keys[-1], cfg.d_model, cfg.vocab_size, dt)
    return params


def _forward(params, cfg, x, ctx, *, remat=False, collect=False):
    groups = derive_pattern(cfg)
    caches = [] if collect else None
    for gi, (count, pattern) in enumerate(groups):
        stacked = params["groups"][gi]

        def body(xc, xs, pattern=pattern):
            outs = []
            for j, kind in enumerate(pattern):
                fn = mlstm_block_apply if kind == "m" else slstm_block_apply
                if collect:
                    xc, cache = fn(xs[j], cfg, xc, return_state=True)
                    outs.append(cache)
                else:
                    xc = fn(xs[j], cfg, xc)
            if ctx is not None:
                xc = ctx.constrain_batch(xc)
            return xc, (outs if collect else None)

        if remat:
            body = jax.checkpoint(body)
        x, ys = jax.lax.scan(body, x, stacked)
        if collect:
            caches.append(ys)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, caches


def train_loss(params, cfg, batch, ctx=None, *, remat: bool = True):
    tokens, targets = batch["tokens"], batch["targets"]
    x = L.embed(params["embed"], tokens, jnp.dtype(cfg.compute_dtype))
    if ctx is not None:
        x = ctx.constrain_batch(x)
    hidden, _ = _forward(params, cfg, x, ctx, remat=remat)
    ce = T.chunked_ce(params, cfg, hidden, targets, batch.get("loss_mask"))
    return ce, {"ce": ce}


def prefill(params, cfg, batch, ctx=None, *, max_len=None):
    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens, jnp.dtype(cfg.compute_dtype))
    if ctx is not None:
        x = ctx.constrain_batch(x)
    hidden, caches = _forward(params, cfg, x, ctx, collect=True)
    logits = T.logits_fn(params, cfg, hidden[:, -1:, :])[:, 0]
    return logits, {"groups": caches, "pos": jnp.int32(tokens.shape[1])}


def decode_step(params, cfg, cache, token, ctx=None):
    B = token.shape[0]
    x = L.embed(params["embed"], token[:, None], jnp.dtype(cfg.compute_dtype))
    groups = derive_pattern(cfg)
    new_groups = []
    for gi, (count, pattern) in enumerate(groups):
        stacked = params["groups"][gi]
        cache_g = cache["groups"][gi]

        def body(xc, xs, pattern=pattern):
            ps, cs = xs
            outs = []
            for j, kind in enumerate(pattern):
                fn = mlstm_block_decode if kind == "m" else slstm_block_decode
                xc, c_new = fn(ps[j], cfg, xc, cs[j])
                outs.append(c_new)
            return xc, outs

        x, ng = jax.lax.scan(body, x, (stacked, cache_g))
        new_groups.append(ng)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = T.logits_fn(params, cfg, x)[:, 0]
    return logits, {"groups": new_groups, "pos": cache["pos"] + 1}


def make_decode_cache(cfg, batch_size: int, max_len: int, dtype=None):
    dt = dtype or jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    d_inner = cfg.ssm_expand * d
    H = cfg.n_heads
    K = cfg.ssm_conv
    B = batch_size

    def mcache(count):
        D = d_inner // H
        return {"state": {"C": jnp.zeros((count, B, H, D, D), jnp.float32),
                          "n": jnp.zeros((count, B, H, D), jnp.float32),
                          "m": jnp.full((count, B, H), -1e9, jnp.float32)},
                "conv": jnp.zeros((count, B, K - 1, d_inner), dt)}

    def scache(count):
        Dh = d // H
        z = jnp.zeros((count, B, H, Dh), jnp.float32)
        return {"state": (z, z, jnp.full((count, B, H, Dh), -1e9,
                                         jnp.float32), z),
                "conv": jnp.zeros((count, B, K - 1, d), dt)}

    groups = []
    for count, pattern in derive_pattern(cfg):
        groups.append([mcache(count) if kind == "m" else scache(count)
                       for kind in pattern])
    return {"groups": groups, "pos": jnp.int32(0)}
