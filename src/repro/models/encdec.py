"""Encoder-decoder transformer (seamless-m4t backbone).

The modality frontend is a STUB per the task spec: ``batch['src_embeds']``
carries precomputed frame embeddings (B, S_src, frontend_dim) which are
projected into the model width.  Encoder layers are bidirectional; decoder
layers are causal self-attention + cross-attention to the encoder memory.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as T
from repro.models.layers import dense_init, dense, rmsnorm_init, rmsnorm


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def enc_block_init(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {"ln1": rmsnorm_init(cfg.d_model, dtype),
            "attn": L.attn_init(ks[0], cfg, dtype),
            "ln2": rmsnorm_init(cfg.d_model, dtype),
            "ffn": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype,
                              bias=cfg.use_bias)}


def dec_block_init(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    return {"ln1": rmsnorm_init(cfg.d_model, dtype),
            "attn": L.attn_init(ks[0], cfg, dtype),
            "ln_x": rmsnorm_init(cfg.d_model, dtype),
            "xattn": L.attn_init(ks[1], cfg, dtype),
            "ln2": rmsnorm_init(cfg.d_model, dtype),
            "ffn": L.mlp_init(ks[2], cfg.d_model, cfg.d_ff, dtype,
                              bias=cfg.use_bias)}


def enc_block_apply(p, cfg, x, positions):
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    attn_out, _ = L.attn_apply(p["attn"], cfg, h, positions, window=0,
                               causal=False)
    x = x + attn_out
    x = x + L.mlp_apply(p["ffn"], rmsnorm(p["ln2"], x, cfg.norm_eps))
    return x


def _cross_kv(p, cfg, memory):
    """Precompute cross-attention K/V from encoder memory (no rope)."""
    B, Ss, _ = memory.shape
    hd = cfg.resolved_head_dim
    k = dense(p["xattn"]["wk"], memory).reshape(B, Ss, cfg.n_kv_heads, hd)
    v = dense(p["xattn"]["wv"], memory).reshape(B, Ss, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        k = rmsnorm(p["xattn"]["k_norm"], k, cfg.norm_eps)
    return k, v


def _cross_attend(p, cfg, x, mem_k, mem_v):
    """Cross attention: queries from x (no rope), keys from memory."""
    B, St, _ = x.shape
    hd = cfg.resolved_head_dim
    q = dense(p["xattn"]["wq"], x).reshape(B, St, cfg.n_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["xattn"]["q_norm"], q, cfg.norm_eps)
    qpos = L.make_positions(B, St)
    kpos = L.make_positions(B, mem_k.shape[1])
    o = L.attention(q, mem_k, mem_v, qpos, kpos, window=0, causal=False,
                    attn_softcap=cfg.attn_softcap)
    return dense(p["xattn"]["wo"], o.reshape(B, St, -1))


def dec_block_apply(p, cfg, x, positions, mem_k, mem_v):
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    attn_out, kv = L.attn_apply(p["attn"], cfg, h, positions, window=0)
    x = x + attn_out
    x = x + _cross_attend(p, cfg, rmsnorm(p["ln_x"], x, cfg.norm_eps),
                          mem_k, mem_v)
    x = x + L.mlp_apply(p["ffn"], rmsnorm(p["ln2"], x, cfg.norm_eps))
    return x, kv


def dec_block_decode(p, cfg, x, pos, k_cache, v_cache, mem_k, mem_v):
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    attn_out, k_cache, v_cache = L.attn_decode(p["attn"], cfg, h, pos,
                                               k_cache, v_cache, window=0)
    x = x + attn_out
    x = x + _cross_attend(p, cfg, rmsnorm(p["ln_x"], x, cfg.norm_eps),
                          mem_k, mem_v)
    x = x + L.mlp_apply(p["ffn"], rmsnorm(p["ln2"], x, cfg.norm_eps))
    return x, k_cache, v_cache


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------

def init_lm(cfg, key):
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    enc_keys = jax.random.split(ks[0], cfg.n_enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    params = {
        "src_proj": dense_init(ks[2], cfg.frontend_dim, cfg.d_model, dt,
                               bias=True),
        "enc_blocks": jax.vmap(lambda k: enc_block_init(k, cfg, dt))(enc_keys),
        "enc_norm": rmsnorm_init(cfg.d_model, dt),
        "embed": L.embed_init(ks[3], cfg.vocab_size, cfg.d_model, dt),
        "dec_blocks": jax.vmap(lambda k: dec_block_init(k, cfg, dt))(dec_keys),
        "final_norm": rmsnorm_init(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(ks[4], cfg.d_model, cfg.vocab_size, dt)
    return params


def encode(params, cfg, src_embeds, ctx=None, *, remat=False):
    x = dense(params["src_proj"],
              src_embeds.astype(jnp.dtype(cfg.compute_dtype)))
    if ctx is not None:
        x = ctx.constrain_batch(x)
    B, Ss, _ = x.shape
    positions = L.make_positions(B, Ss)

    def body(xc, p):
        xc = enc_block_apply(p, cfg, xc, positions)
        if ctx is not None:
            xc = ctx.constrain_batch(xc)
        return xc, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def train_loss(params, cfg, batch, ctx=None, *, remat: bool = True):
    """batch: src_embeds (B,Ss,fd), tokens (B,St), targets (B,St)."""
    memory = encode(params, cfg, batch["src_embeds"], ctx, remat=remat)
    tokens, targets = batch["tokens"], batch["targets"]
    B, St = tokens.shape
    x = L.embed(params["embed"], tokens, jnp.dtype(cfg.compute_dtype))
    if ctx is not None:
        x = ctx.constrain_batch(x)
    positions = L.make_positions(B, St)

    def body(xc, p):
        xc, _ = dec_block_apply(p, cfg, xc, positions,
                                *_cross_kv(p, cfg, memory))
        if ctx is not None:
            xc = ctx.constrain_batch(xc)
        return xc, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    ce = T.chunked_ce(params, cfg, x, targets, batch.get("loss_mask"))
    return ce, {"ce": ce}


def prefill(params, cfg, batch, ctx=None, *, max_len=None):
    """Encode source; build cross-KV cache and an empty self-KV cache.
    Returns (BOS logits, cache)."""
    memory = encode(params, cfg, batch["src_embeds"], ctx)
    B = memory.shape[0]
    max_len = max_len or memory.shape[1]

    def kv_body(_, p):
        return None, _cross_kv(p, cfg, memory)

    _, (mem_k, mem_v) = jax.lax.scan(kv_body, None, params["dec_blocks"])
    KV, D = cfg.n_kv_heads, cfg.resolved_head_dim
    Ld = cfg.n_layers
    cache = {
        "mem_k": mem_k, "mem_v": mem_v,  # (L, B, Ss, KV, D)
        "k": jnp.zeros((Ld, B, max_len, KV, D), mem_k.dtype),
        "v": jnp.zeros((Ld, B, max_len, KV, D), mem_v.dtype),
        "pos": jnp.int32(0),
    }
    # BOS step: decode token 0 logits from a zero-state decoder input
    bos = jnp.zeros((B,), jnp.int32)
    logits, cache = decode_step(params, cfg, cache, bos, ctx)
    return logits, cache


def decode_step(params, cfg, cache, token, ctx=None):
    B = token.shape[0]
    x = L.embed(params["embed"], token[:, None], jnp.dtype(cfg.compute_dtype))
    pos = cache["pos"].astype(jnp.int32)

    def body(xc, xs):
        p, ck, cv, mk, mv = xs
        xc, ck, cv = dec_block_decode(p, cfg, xc, pos, ck, cv, mk, mv)
        return xc, (ck, cv)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["k"], cache["v"],
                  cache["mem_k"], cache["mem_v"]))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = T.logits_fn(params, cfg, x)[:, 0]
    new_cache = dict(cache)
    new_cache.update({"k": k_new, "v": v_new, "pos": pos + 1})
    return logits, new_cache


def make_decode_cache(cfg, batch_size: int, max_len: int, dtype=None,
                      src_len: int = 0):
    dt = dtype or jnp.dtype(cfg.param_dtype)
    KV, D = cfg.n_kv_heads, cfg.resolved_head_dim
    Ld = cfg.n_layers
    B = batch_size
    Ss = src_len or max_len
    return {
        "mem_k": jnp.zeros((Ld, B, Ss, KV, D), dt),
        "mem_v": jnp.zeros((Ld, B, Ss, KV, D), dt),
        "k": jnp.zeros((Ld, B, max_len, KV, D), dt),
        "v": jnp.zeros((Ld, B, max_len, KV, D), dt),
        "pos": jnp.int32(0),
    }
