"""Mixture-of-Experts FFN: dropless top-k routing with sort + ragged_dot.

Two execution paths sharing one parameterisation:

* **local** (no mesh / tests): plain ragged_dot over the full expert stack.
* **distributed** (`ctx.enabled`): a ``shard_map`` over ``(data, model)`` with
  an *explicit* collective schedule — the per-layer FSDP all-gather of the
  expert weights over ``data``, local routing/sort/grouped-matmul, and one
  ``psum`` over ``model`` for the ff-sharded down projection.  Tokens never
  cross data shards (routing is per-shard dropless), which keeps the a2a
  traffic at zero for the baseline; an a2a EP variant is a §Perf experiment.

Weight layout (logical):
    gate/up : (E, d_model, moe_ff)   stored P(None, 'data', 'model')
    down    : (E, moe_ff, d_model)   stored P(None, 'model', 'data')
    router  : (d_model, E)           replicated, fp32 math
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import _normal, dense_init, dense, mlp_init, mlp_apply


def moe_init(key, cfg, dtype):
    E, d, ff = cfg.n_experts, cfg.d_model, (cfg.moe_d_ff or cfg.d_ff)
    ks = jax.random.split(key, 5)
    p = {
        "router": {"w": _normal(ks[0], (d, E), jnp.float32, 1.0 / math.sqrt(d))},
        "gate": _normal(ks[1], (E, d, ff), dtype, 1.0 / math.sqrt(d)),
        "up": _normal(ks[2], (E, d, ff), dtype, 1.0 / math.sqrt(d)),
        "down": _normal(ks[3], (E, ff, d), dtype, 1.0 / math.sqrt(ff)),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[4], d, ff * cfg.n_shared_experts, dtype)
    return p


def _route(x32, w_router, top_k: int):
    """x32 (T, d) fp32 -> (weights (T,k) fp32, ids (T,k) int32, probs (T,E))."""
    logits = x32 @ w_router
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, top_k)
    weights = weights / jnp.maximum(
        jnp.sum(weights, axis=-1, keepdims=True), 1e-9)
    return weights, ids.astype(jnp.int32), probs


CAPACITY_FACTOR = 1.25  # GShard-style slack over the perfectly-balanced load


def _capacity(T: int, k: int, E: int, cf: float = CAPACITY_FACTOR) -> int:
    """Static per-expert token capacity, rounded up to a multiple of 8."""
    c = int(math.ceil(T * k * cf / E))
    return max(8, -(-c // 8) * 8)


def _moe_local_math(x, p, cfg, *, n_local: int = 0, owner_start=None):
    """Routing + capacity-based grouped FFN.  Returns (y (T,d), aux dict).

    Dispatch is sort + scatter into a static (E_local, C, d) buffer — the
    classic GShard/Switch formulation.  Tokens beyond an expert's capacity C
    are dropped (their routing weight contributes nothing); C has 25% slack
    over the balanced load and the load-balance loss keeps routing
    near-balanced.  (``jax.lax.ragged_dot`` was measured to lower to a DENSE
    over-all-experts einsum — E/k times the useful FLOPs — so the capacity
    formulation is the honest baseline; see EXPERIMENTS.md §Perf.)

    Expert parallelism: when ``n_local`` is set, ``p`` holds only the
    ``n_local`` experts starting at (traced) global id ``owner_start``; rows
    routed elsewhere are masked out and the caller psums partial outputs
    over the expert-parallel axis.
    """
    T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    weights, ids, probs = _route(x.astype(jnp.float32), p["router"]["w"], k)

    flat_ids = ids.reshape(-1)                       # (T*k,)
    perm = jnp.argsort(flat_ids)                     # stable
    sorted_ids = flat_ids[perm]
    token_idx = perm // k                            # source token per row
    xs = x[token_idx]                                # (T*k, d) sorted by expert

    # rank of each routed row within its (global) expert group
    group_sizes = jnp.bincount(flat_ids, length=E)
    starts = jnp.cumsum(group_sizes) - group_sizes
    rank = jnp.arange(T * k, dtype=jnp.int32) - starts[sorted_ids]

    C = _capacity(T, k, E, getattr(cfg, 'moe_capacity', CAPACITY_FACTOR))
    keep = rank < C
    if n_local:
        local_ids = sorted_ids - owner_start
        keep &= (local_ids >= 0) & (local_ids < n_local)
        e_rows = n_local
    else:
        local_ids = sorted_ids
        e_rows = E
    dest = jnp.where(keep, local_ids * C + rank, e_rows * C)  # overflow row

    buf = jnp.zeros((e_rows * C + 1, d), x.dtype).at[dest].set(
        xs * keep[:, None].astype(x.dtype))
    h = buf[: e_rows * C].reshape(e_rows, C, d)

    gate_w = p["gate"].astype(x.dtype)
    up_w = p["up"].astype(x.dtype)
    down_w = p["down"].astype(x.dtype)
    g = jnp.einsum("ecd,edf->ecf", h, gate_w)
    u = jnp.einsum("ecd,edf->ecf", h, up_w)
    hh = (jax.nn.silu(g.astype(jnp.float32)) *
          u.astype(jnp.float32)).astype(x.dtype)
    y_ec = jnp.einsum("ecf,efd->ecd", hh, down_w).reshape(e_rows * C, d)

    # gather back (dropped/foreign rows contribute zero), unsort, combine.
    # Combine in the compute dtype with fp32 accumulation — materialising
    # an fp32 (T, k, d) copy was ~12% of kimi's HBM traffic (§Perf B3).
    ys_sorted = y_ec[jnp.minimum(dest, e_rows * C - 1)] * keep[:, None]
    inv = jnp.argsort(perm)
    ys = ys_sorted[inv].reshape(T, k, d)
    y = jnp.einsum("tkd,tk->td", ys, weights.astype(ys.dtype),
                   preferred_element_type=jnp.float32).astype(x.dtype)

    # GShard-style load-balance aux loss terms (local; caller aggregates).
    frac = jnp.mean(jax.nn.one_hot(flat_ids, E, dtype=jnp.float32), axis=0)
    prob = jnp.mean(probs, axis=0)
    lb = E * jnp.sum(frac * prob)
    return y, {"lb_loss": lb}


def moe_apply(p, cfg, x, ctx):
    """x (B, S, d) -> (y (B, S, d), aux dict).  ``ctx`` is a DistContext."""
    B, S, d = x.shape
    xt = x.reshape(B * S, d)

    if ctx is None or not ctx.enabled:
        y, aux = _moe_local_math(xt, p, cfg)
    else:
        y, aux = _moe_shard_map(p, cfg, xt, ctx)

    if cfg.n_shared_experts:
        y = y + mlp_apply(p["shared"], xt)
    return y.reshape(B, S, d), aux


def use_ep(cfg, ctx) -> bool:
    """Expert parallelism applies when the expert count divides the model
    axis (kimi: 384 % 16 == 0; grok's 8 experts < 16 shards fall back to
    the TP/capacity path)."""
    return (cfg.moe_impl in ("ep_a2a", "ep_token_a2a") and ctx is not None
            and ctx.enabled and cfg.n_experts % ctx.tp_size == 0)


def _moe_token_a2a_body(x_loc, p, cfg, maxis, n_local: int):
    """True token-routed expert parallelism (§Perf B4, DeepSeek-style).

    Tokens are sharded over (data x model); each routed (token, expert)
    pair is SENT to the model rank owning the expert via all_to_all,
    computed there, and sent back.  Versus the mask+psum EP baseline this
    removes (a) the 16x-replicated dispatch bookkeeping (every rank used to
    sort/scatter ALL the data-shard's tokens) and (b) the full-activation
    psum over 'model' — the two dominant HBM/collective terms of the kimi
    baseline.  Two capacity stages (send-side per destination rank,
    recv-side per local expert) keep every buffer static.
    """
    t, d = x_loc.shape
    E, k = cfg.n_experts, cfg.top_k
    tp = E // n_local
    cf = getattr(cfg, "moe_capacity", CAPACITY_FACTOR)
    weights, ids, probs = _route(x_loc.astype(jnp.float32),
                                 p["router"]["w"], k)

    # ---- stage 1: group routed rows by destination rank ------------------
    flat_ids = ids.reshape(-1)                        # (t*k,)
    owner = flat_ids // n_local                       # dst model rank
    perm = jnp.argsort(owner)
    sorted_owner = owner[perm]
    gs = jnp.bincount(owner, length=tp)
    starts = jnp.cumsum(gs) - gs
    rank1 = jnp.arange(t * k, dtype=jnp.int32) - starts[sorted_owner]
    C_send = _capacity(t, k, tp, cf)
    keep1 = rank1 < C_send
    dest1 = jnp.where(keep1, sorted_owner * C_send + rank1, tp * C_send)

    xs = x_loc[perm // k]                             # (t*k, d)
    send = jnp.zeros((tp * C_send + 1, d), x_loc.dtype).at[dest1].set(
        xs * keep1[:, None].astype(x_loc.dtype))[: tp * C_send]
    local_eid = (flat_ids - owner * n_local)[perm] + 1   # 1-based; 0 = empty
    send_eid = jnp.zeros((tp * C_send + 1,), jnp.int32).at[dest1].set(
        jnp.where(keep1, local_eid, 0))[: tp * C_send]

    # ---- exchange: rows travel to their expert's rank --------------------
    recv = jax.lax.all_to_all(send.reshape(tp, C_send, d), maxis,
                              split_axis=0, concat_axis=0, tiled=True)
    recv_eid = jax.lax.all_to_all(send_eid.reshape(tp, C_send), maxis,
                                  split_axis=0, concat_axis=0, tiled=True)
    recv = recv.reshape(tp * C_send, d)
    recv_eid = recv_eid.reshape(tp * C_send)

    # ---- stage 2: dispatch received rows into local experts --------------
    valid = recv_eid > 0
    eid = jnp.where(valid, recv_eid - 1, n_local)
    perm2 = jnp.argsort(eid)
    sorted_eid = eid[perm2]
    gs2 = jnp.bincount(eid, length=n_local + 1)
    starts2 = (jnp.cumsum(gs2) - gs2)[:n_local + 1]
    rank2 = jnp.arange(tp * C_send, dtype=jnp.int32) - starts2[sorted_eid]
    C_loc = _capacity(tp * C_send, 1, n_local, cf)
    keep2 = (rank2 < C_loc) & (sorted_eid < n_local)
    dest2 = jnp.where(keep2, sorted_eid * C_loc + rank2, n_local * C_loc)

    rows = recv[perm2]
    buf = jnp.zeros((n_local * C_loc + 1, d), x_loc.dtype).at[dest2].set(
        rows * keep2[:, None].astype(x_loc.dtype))[: n_local * C_loc]
    h = buf.reshape(n_local, C_loc, d)

    gate_w = p["gate"].astype(x_loc.dtype)
    up_w = p["up"].astype(x_loc.dtype)
    down_w = p["down"].astype(x_loc.dtype)
    g = jnp.einsum("ecd,edf->ecf", h, gate_w)
    u = jnp.einsum("ecd,edf->ecf", h, up_w)
    hh = (jax.nn.silu(g.astype(jnp.float32)) *
          u.astype(jnp.float32)).astype(x_loc.dtype)
    y_e = jnp.einsum("ecf,efd->ecd", hh, down_w).reshape(n_local * C_loc, d)

    # ---- inverse stage 2: back to recv-slot layout -----------------------
    y_sorted2 = y_e[jnp.minimum(dest2, n_local * C_loc - 1)] * keep2[:, None]
    y_recv = y_sorted2[jnp.argsort(perm2)]            # (tp*C_send, d)

    # ---- exchange back: rows return to their source rank -----------------
    y_back = jax.lax.all_to_all(y_recv.reshape(tp, C_send, d), maxis,
                                split_axis=0, concat_axis=0, tiled=True)
    y_rows = y_back.reshape(tp * C_send, d)

    # ---- inverse stage 1: combine on the source rank ---------------------
    ys_sorted = y_rows[jnp.minimum(dest1, tp * C_send - 1)] * keep1[:, None]
    ys = ys_sorted[jnp.argsort(perm)].reshape(t, k, d)
    y = jnp.einsum("tkd,tk->td", ys, weights.astype(ys.dtype),
                   preferred_element_type=jnp.float32).astype(x_loc.dtype)

    frac = jnp.mean(jax.nn.one_hot(flat_ids, E, dtype=jnp.float32), axis=0)
    prob = jnp.mean(probs, axis=0)
    lb = E * jnp.sum(frac * prob)
    return y, {"lb_loss": lb}


def _moe_shard_map(p, cfg, xt, ctx):
    """Distributed MoE via shard_map over (batch_axes..., model).

    Two schedules:
    * **TP/capacity** (default): every rank holds all experts (ff sharded
      over 'model'); ZeRO-3 re-gathers expert shards over 'data' per layer.
    * **EP** (``moe_impl='ep_a2a'``): experts sharded over 'model' (E/tp per
      rank), d sharded over 'data' for storage; per layer each rank gathers
      only ITS experts over 'data', computes its owned tokens, and partial
      outputs psum over 'model'.  This is the only recipe that fits 1T
      params on 16 GB/chip (kimi); see DESIGN.md §7.
    """
    from jax.sharding import PartitionSpec as P

    mesh = ctx.mesh
    baxes = ctx.batch_axes          # e.g. ('data',) or ('pod', 'data')
    maxis = ctx.model_axis
    fsdp = ctx.fsdp
    ep = use_ep(cfg, ctx)

    token_a2a = ep and cfg.moe_impl == "ep_token_a2a"

    if ep:
        gate_spec = P(maxis, baxes, None) if fsdp else P(maxis, None, None)
        down_spec = P(maxis, None, baxes) if fsdp else P(maxis, None, None)
        n_local = cfg.n_experts // ctx.tp_size
    else:
        gate_spec = P(None, baxes, maxis) if fsdp else P(None, None, maxis)
        down_spec = P(None, maxis, baxes) if fsdp else P(None, maxis, None)
        n_local = 0

    # token layout: mask+psum EP and TP replicate tokens over 'model';
    # token-a2a shards them over (data..., model) — 1/tp the bookkeeping.
    x_spec = P(baxes + (maxis,), None) if token_a2a else P(baxes, None)

    def body(x_loc, router_w, gate_w, up_w, down_w):
        if fsdp:
            # ZeRO-3 gather of this layer's expert shards over the data axes.
            for ax in baxes:
                gate_w = jax.lax.all_gather(gate_w, ax, axis=1, tiled=True)
                up_w = jax.lax.all_gather(up_w, ax, axis=1, tiled=True)
                down_w = jax.lax.all_gather(down_w, ax, axis=2, tiled=True)
        sub = {"router": {"w": router_w}, "gate": gate_w, "up": up_w,
               "down": down_w}
        if token_a2a:
            return _moe_token_a2a_body(x_loc, sub, cfg, maxis, n_local)
        if ep:
            owner_start = jax.lax.axis_index(maxis) * n_local
            y, aux = _moe_local_math(x_loc, sub, cfg, n_local=n_local,
                                     owner_start=owner_start)
        else:
            y, aux = _moe_local_math(x_loc, sub, cfg)
        # EP: partial outputs from owned experts; TP: partial over ff shards.
        y = jax.lax.psum(y, maxis)
        return y, aux

    def wrapped(*args):
        y, aux = body(*args)
        aux = {k: jax.lax.pmean(v, baxes + (maxis,)) for k, v in aux.items()}
        return y, aux

    return jax.shard_map(
        wrapped,
        mesh=mesh,
        in_specs=(x_spec, P(), gate_spec, gate_spec, down_spec),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(xt, p["router"]["w"], p["gate"], p["up"], p["down"])


def moe_param_specs(cfg, ctx):
    """PartitionSpec pytree matching moe_init output."""
    from jax.sharding import PartitionSpec as P
    baxes = ctx.batch_axes
    maxis = ctx.model_axis
    fsdp = ctx.fsdp
    specs = {
        "router": {"w": P()},
        "gate": P(None, baxes, maxis) if fsdp else P(None, None, maxis),
        "up": P(None, baxes, maxis) if fsdp else P(None, None, maxis),
        "down": P(None, maxis, baxes) if fsdp else P(None, maxis, None),
    }
    if cfg.n_shared_experts:
        mspec = {"gate": {"w": P(None, maxis)}, "up": {"w": P(None, maxis)},
                 "down": {"w": P(maxis, None)}}
        specs["shared"] = mspec
    return specs
