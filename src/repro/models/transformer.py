"""Decoder-only transformer family: dense (command-r, h2o-danube, gemma3),
MoE (grok-1, kimi-k2) and the VLM backbone (qwen2-vl).

Layer-pattern machinery
-----------------------
Architectures repeat a short *pattern* of heterogeneous layers (gemma3:
5 sliding-window + 1 global; kimi: 1 dense + 60 MoE).  We scan over
*super-blocks*: params are stacked ``(count, ...)`` per pattern position and
the pattern is unrolled (statically) inside the scanned body.  This keeps the
HLO at O(pattern) layers while supporting per-position static windows, RoPE
thetas and FFN kinds — no traced control flow.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as M

LOSS_CHUNK = 2048  # sequence chunking for the CE loss (memory knob)


class LayerDesc(NamedTuple):
    window: int      # 0 = full attention
    theta: float     # rope theta for this layer
    moe: bool        # MoE FFN instead of dense MLP


def derive_groups(cfg) -> Tuple[Tuple[int, Tuple[LayerDesc, ...]], ...]:
    """(count, pattern) groups covering cfg.n_layers in order."""
    n = cfg.n_layers
    if cfg.n_experts:
        fd = cfg.first_dense_layers
        dense_d = LayerDesc(cfg.sliding_window, cfg.rope_theta, False)
        moe_d = LayerDesc(cfg.sliding_window, cfg.rope_theta, True)
        groups = []
        if fd:
            groups.append((fd, (dense_d,)))
        groups.append((n - fd, (moe_d,)))
        return tuple(groups)
    if cfg.local_global_ratio:
        r = cfg.local_global_ratio
        local = LayerDesc(cfg.local_window, 10_000.0, False)
        glob = LayerDesc(0, cfg.rope_theta, False)
        pattern = (local,) * r + (glob,)
        full, rem = divmod(n, r + 1)
        groups = []
        if full:
            groups.append((full, pattern))
        if rem:
            groups.append((1, (local,) * rem))
        return tuple(groups)
    d = LayerDesc(cfg.sliding_window, cfg.rope_theta, False)
    return ((n, (d,)),)


# ---------------------------------------------------------------------------
# Block init / apply
# ---------------------------------------------------------------------------

def _dtype(cfg):
    return jnp.dtype(cfg.param_dtype)


def init_block(key, cfg, desc: LayerDesc):
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    p = {
        "ln1": L.rmsnorm_init(cfg.d_model, dt),
        "attn": L.attn_init(ks[0], cfg, dt),
    }
    if desc.moe:
        p["ffn"] = M.moe_init(ks[1], cfg, dt)
    else:
        p["ffn"] = L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, dt,
                              bias=cfg.use_bias)
    if not cfg.parallel_block:
        p["ln2"] = L.rmsnorm_init(cfg.d_model, dt)
    if cfg.sandwich_norm:
        p["ln1_post"] = L.rmsnorm_init(cfg.d_model, dt)
        p["ln2_post"] = L.rmsnorm_init(cfg.d_model, dt)
    return p


def _ffn_apply(p, cfg, desc, h, ctx):
    if desc.moe:
        return M.moe_apply(p["ffn"], cfg, h, ctx)
    return L.mlp_apply(p["ffn"], h), {}


def block_apply(p, cfg, desc: LayerDesc, x, positions, ctx):
    """Full-sequence block. Returns (x, (k, v), lb_aux)."""
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    attn_out, kv = L.attn_apply(p["attn"], cfg, h, positions,
                                window=desc.window, theta=desc.theta)
    if cfg.sandwich_norm:
        attn_out = L.rmsnorm(p["ln1_post"], attn_out, cfg.norm_eps)
    if cfg.parallel_block:
        ffn_out, aux = _ffn_apply(p, cfg, desc, h, ctx)
        x = x + attn_out + ffn_out
    else:
        x = x + attn_out
        h2 = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        ffn_out, aux = _ffn_apply(p, cfg, desc, h2, ctx)
        if cfg.sandwich_norm:
            ffn_out = L.rmsnorm(p["ln2_post"], ffn_out, cfg.norm_eps)
        x = x + ffn_out
    if ctx is not None:
        x = ctx.constrain_batch(x)
    return x, kv, aux.get("lb_loss", jnp.float32(0.0))


def block_decode(p, cfg, desc: LayerDesc, x, pos, k_cache, v_cache, ctx):
    """Single-token decode block. Returns (x, k_cache', v_cache')."""
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    attn_out, k_cache, v_cache = L.attn_decode(
        p["attn"], cfg, h, pos, k_cache, v_cache,
        window=desc.window, theta=desc.theta)
    if cfg.sandwich_norm:
        attn_out = L.rmsnorm(p["ln1_post"], attn_out, cfg.norm_eps)
    if cfg.parallel_block:
        ffn_out, _ = _ffn_apply(p, cfg, desc, h, ctx)
        x = x + attn_out + ffn_out
    else:
        x = x + attn_out
        h2 = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        ffn_out, _ = _ffn_apply(p, cfg, desc, h2, ctx)
        if cfg.sandwich_norm:
            ffn_out = L.rmsnorm(p["ln2_post"], ffn_out, cfg.norm_eps)
        x = x + ffn_out
    return x, k_cache, v_cache


def block_chunk(p, cfg, desc: LayerDesc, x, qpos, ck, cv, ctx_kpos, ctx):
    """Chunked-prefill block: a C-token span attends to an external KV
    context plus itself (paged serving).  Returns (x, k, v) where k/v are
    the chunk's new cache rows."""
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    attn_out, k, v = L.attn_prefill_chunk(
        p["attn"], cfg, h, qpos, ck, cv, ctx_kpos,
        window=desc.window, theta=desc.theta)
    if cfg.sandwich_norm:
        attn_out = L.rmsnorm(p["ln1_post"], attn_out, cfg.norm_eps)
    if cfg.parallel_block:
        ffn_out, _ = _ffn_apply(p, cfg, desc, h, ctx)
        x = x + attn_out + ffn_out
    else:
        x = x + attn_out
        h2 = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        ffn_out, _ = _ffn_apply(p, cfg, desc, h2, ctx)
        if cfg.sandwich_norm:
            ffn_out = L.rmsnorm(p["ln2_post"], ffn_out, cfg.norm_eps)
        x = x + ffn_out
    return x, k, v


# ---------------------------------------------------------------------------
# LM init
# ---------------------------------------------------------------------------

def init_lm(cfg, key):
    dt = _dtype(cfg)
    groups = derive_groups(cfg)
    keys = jax.random.split(key, len(groups) + 3)
    params = {"embed": L.embed_init(keys[0], cfg.vocab_size, cfg.d_model, dt),
              "final_norm": L.rmsnorm_init(cfg.d_model, dt)}
    gp = []
    for gi, (count, pattern) in enumerate(groups):
        pkeys = jax.random.split(keys[gi + 1], len(pattern))
        stacked = []
        for j, desc in enumerate(pattern):
            bkeys = jax.random.split(pkeys[j], count)
            stacked.append(jax.vmap(lambda k: init_block(k, cfg, desc))(bkeys))
        gp.append(stacked)
    params["groups"] = gp
    if not cfg.tie_embeddings:
        params["head"] = L.dense_init(keys[-1], cfg.d_model, cfg.vocab_size,
                                      dt)
    if cfg.patch_dim:
        params["patch_proj"] = L.dense_init(keys[-2], cfg.patch_dim,
                                            cfg.d_model, dt, bias=True)
    return params


def embed_scale(cfg) -> float:
    # gemma-style sqrt(d) embedding scaling rides the sandwich_norm flag.
    return math.sqrt(cfg.d_model) if cfg.sandwich_norm else 1.0


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def forward(params, cfg, x, positions, ctx, *, remat: bool = False,
            collect_cache: bool = False, cache_sizes=None):
    """Scan super-blocks.  Returns (hidden, lb_loss_sum, caches|None).

    ``cache_sizes``: per-layer cache capacity resolver — called as
    ``cache_sizes(desc)`` to produce the ring/linear cache capacity when
    ``collect_cache`` (prefill) is set.
    """
    groups = derive_groups(cfg)
    lb_total = jnp.float32(0.0)
    caches = [] if collect_cache else None

    for gi, (count, pattern) in enumerate(groups):
        stacked = params["groups"][gi]

        def body(carry, xs, pattern=pattern):
            xc, lb = carry
            outs = []
            for j, desc in enumerate(pattern):
                xc, kv, lbj = block_apply(xs[j], cfg, desc, xc, positions, ctx)
                lb = lb + lbj
                if collect_cache:
                    cap = cache_sizes(desc)
                    outs.append(_pack_cache(kv, desc, cap))
            return (xc, lb), (outs if collect_cache else None)

        if remat:
            body = jax.checkpoint(body)
        (x, lb_total), ys = jax.lax.scan(body, (x, lb_total), stacked)
        if collect_cache:
            caches.append(ys)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, lb_total, caches


def _pack_cache(kv, desc: LayerDesc, capacity: int):
    """Arrange full-sequence (k, v) into a decode cache of ``capacity``."""
    k, v = kv
    B, S, KV, D = k.shape
    if desc.window and capacity <= desc.window and S >= capacity:
        # ring buffer: keep the last `capacity` tokens at slot p % capacity
        idx = jnp.mod(jnp.arange(S - capacity, S), capacity)
        ring_k = jnp.zeros((B, capacity, KV, D), k.dtype).at[:, idx].set(
            k[:, S - capacity:])
        ring_v = jnp.zeros((B, capacity, KV, D), v.dtype).at[:, idx].set(
            v[:, S - capacity:])
        return {"k": ring_k, "v": ring_v}
    if S < capacity:
        pad = capacity - S
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return {"k": k, "v": v}
    return {"k": k[:, :capacity], "v": v[:, :capacity]}


def decode_forward(params, cfg, x, pos, cache, ctx):
    """One-token scan over super-blocks with cache threading."""
    groups = derive_groups(cfg)
    new_groups = []
    for gi, (count, pattern) in enumerate(groups):
        stacked = params["groups"][gi]
        cache_g = cache["groups"][gi]

        def body(xc, xs, pattern=pattern):
            ps, cs = xs
            new_cs = []
            for j, desc in enumerate(pattern):
                xc, ck, cv = block_decode(ps[j], cfg, desc, xc, pos,
                                          cs[j]["k"], cs[j]["v"], ctx)
                new_cs.append({"k": ck, "v": cv})
            return xc, new_cs

        x, new_cache_g = jax.lax.scan(body, x, (stacked, cache_g))
        new_groups.append(new_cache_g)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, {"groups": new_groups, "pos": pos + 1}


# ---------------------------------------------------------------------------
# Heads and losses
# ---------------------------------------------------------------------------

def _head_weight(params, cfg):
    if cfg.tie_embeddings:
        return params["embed"]["table"].T  # (d, V)
    return params["head"]["w"]


def logits_fn(params, cfg, hidden):
    w = _head_weight(params, cfg)
    logits = jnp.einsum("bsd,dv->bsv", hidden.astype(jnp.float32),
                        w.astype(jnp.float32))
    return L.softcap(logits, cfg.logit_softcap)


def chunked_ce(params, cfg, hidden, targets, mask=None, chunk=LOSS_CHUNK):
    """Cross-entropy without materialising (B, S, V) for the full sequence:
    scan over S-chunks; inside the chunk the label log-prob is extracted with
    an iota-compare-reduce (fuses under SPMD vocab sharding — no gather)."""
    B, S, d = hidden.shape
    V = cfg.vocab_size
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    chunk = min(chunk, S)
    n = -(-S // chunk)
    pad = n * chunk - S
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    hc = hidden.reshape(B, n, chunk, d).transpose(1, 0, 2, 3)
    tc = targets.reshape(B, n, chunk).transpose(1, 0, 2)
    mc = mask.reshape(B, n, chunk).transpose(1, 0, 2)

    w = _head_weight(params, cfg)

    def chunk_fn(carry, xs):
        tot, cnt = carry
        h, t, m = xs
        logits = jnp.einsum("bsd,dv->bsv", h.astype(jnp.float32),
                            w.astype(jnp.float32))
        logits = L.softcap(logits, cfg.logit_softcap)
        logz = jax.nn.logsumexp(logits, axis=-1)
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        ll = jnp.sum(jnp.where(iota == t[..., None], logits, 0.0), axis=-1)
        nll = (logz - ll) * m
        return (tot + jnp.sum(nll), cnt + jnp.sum(m)), None

    (tot, cnt), _ = jax.lax.scan(
        jax.checkpoint(chunk_fn), (jnp.float32(0.0), jnp.float32(0.0)),
        (hc, tc, mc))
    return tot / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------------------
# Entry points (family API)
# ---------------------------------------------------------------------------

LB_COEF = 0.01  # MoE load-balance loss coefficient


def _embed_inputs(params, cfg, batch, ctx):
    """Token (+ optional patch) embedding. Returns (x, positions, loss_mask)."""
    tokens = batch["tokens"]
    B = tokens.shape[0]
    x = L.embed(params["embed"], tokens, jnp.dtype(cfg.compute_dtype))
    x = x * embed_scale(cfg)
    mask = batch.get("loss_mask")
    if cfg.patch_dim and "patch_embeds" in batch:
        patches = L.dense(params["patch_proj"],
                          batch["patch_embeds"].astype(x.dtype))
        x = jnp.concatenate([patches, x], axis=1)
        Np = patches.shape[1]
        pm = jnp.concatenate(
            [jnp.zeros((B, Np), jnp.float32),
             jnp.ones((B, tokens.shape[1]), jnp.float32)], axis=1)
        mask = pm if mask is None else jnp.concatenate(
            [jnp.zeros((B, Np), jnp.float32), mask], axis=1)
    S = x.shape[1]
    if cfg.m_rope:
        positions = batch.get("positions")
        if positions is None:
            p1 = L.make_positions(B, S)
            positions = jnp.stack([p1, p1, p1], axis=-1)
    else:
        positions = batch.get("positions", L.make_positions(B, S))
    if ctx is not None:
        x = ctx.constrain_batch(x)
    return x, positions, mask


def train_loss(params, cfg, batch, ctx=None, *, remat: bool = True):
    """batch: tokens (B,S), targets (B,S) [, loss_mask, patch_embeds,
    positions].  Returns (loss, metrics)."""
    x, positions, mask = _embed_inputs(params, cfg, batch, ctx)
    targets = batch["targets"]
    if cfg.patch_dim and "patch_embeds" in batch:
        # targets align with the text tail; pad front with ignored labels
        Np = x.shape[1] - targets.shape[1]
        targets = jnp.pad(targets, ((0, 0), (Np, 0)))
    hidden, lb, _ = forward(params, cfg, x, positions, ctx, remat=remat)
    ce = chunked_ce(params, cfg, hidden, targets, mask)
    loss = ce + (LB_COEF * lb / max(cfg.n_layers, 1) if cfg.n_experts else 0.0)
    return loss, {"ce": ce, "lb": lb}


def prefill(params, cfg, batch, ctx=None, *, max_len: Optional[int] = None):
    """Build a decode cache from a full prompt. Returns (last_logits, cache)."""
    x, positions, _ = _embed_inputs(params, cfg, batch, ctx)
    S = x.shape[1]
    max_len = max_len or S

    def cache_sizes(desc: LayerDesc) -> int:
        return min(desc.window, max_len) if desc.window else max_len

    hidden, _, caches = forward(params, cfg, x, positions, ctx,
                                collect_cache=True, cache_sizes=cache_sizes)
    last = hidden[:, -1:, :]
    logits = logits_fn(params, cfg, last)[:, 0]
    cache = {"groups": caches, "pos": jnp.int32(S)}
    return logits, cache


def prefill_chunk(params, cfg, batch, ctx_cache, ctx_kpos, pos0, valid,
                  ctx=None):
    """Prefill one fixed-size chunk of a prompt against an external KV
    context (paged serving, DESIGN.md §6).

    batch["tokens"] (B,C): the chunk (right-padded past ``valid``);
    ctx_cache: decode-cache-layout groups with leaves (count,B,T,KV,D)
    holding the already-prefilled context; ctx_kpos (B,T): absolute key
    positions of those rows (<0 = unwritten, masked out of attention);
    pos0: traced int32 absolute position of the chunk's first token;
    valid: traced int32 count of real tokens in the chunk.

    Returns (logits (B,V) at chunk position valid-1, new_kv) where new_kv
    has leaves (count,B,C,KV,D) — the chunk's cache rows for the caller
    to scatter into its pool.  Padded positions produce garbage rows the
    caller must discard; their keys sit at positions >= the last valid
    query, so the causal mask keeps them out of the valid logits.

    Linear (non-windowed) caches and 1-D rope only — the callers gate on
    that (windowed/m-rope configs keep monolithic prefill).
    """
    tokens = batch["tokens"]
    B, C = tokens.shape
    x = L.embed(params["embed"], tokens,
                jnp.dtype(cfg.compute_dtype)) * embed_scale(cfg)
    qpos = (pos0 + jnp.arange(C, dtype=jnp.int32))[None, :]
    qpos = jnp.broadcast_to(qpos, (B, C)).astype(jnp.int32)
    new_groups = []
    for gi, (count, pattern) in enumerate(derive_groups(cfg)):
        stacked = params["groups"][gi]
        cache_g = ctx_cache["groups"][gi]

        def body(xc, xs, pattern=pattern):
            ps, cs = xs
            new_cs = []
            for j, desc in enumerate(pattern):
                xc, k, v = block_chunk(ps[j], cfg, desc, xc, qpos,
                                       cs[j]["k"], cs[j]["v"], ctx_kpos, ctx)
                new_cs.append({"k": k, "v": v})
            return xc, new_cs

        x, new_g = jax.lax.scan(body, x, (stacked, cache_g))
        new_groups.append(new_g)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    last = jax.lax.dynamic_slice_in_dim(x, jnp.maximum(valid - 1, 0), 1,
                                        axis=1)
    logits = logits_fn(params, cfg, last)[:, 0]
    return logits, {"groups": new_groups}


def decode_step(params, cfg, cache, token, ctx=None):
    """One serving step: token (B,) int32 -> (logits (B,V), cache')."""
    B = token.shape[0]
    x = L.embed(params["embed"], token[:, None],
                jnp.dtype(cfg.compute_dtype)) * embed_scale(cfg)
    pos = cache["pos"].astype(jnp.int32)
    hidden, cache = decode_forward(params, cfg, x, pos, cache, ctx)
    logits = logits_fn(params, cfg, hidden)[:, 0]
    return logits, cache


def make_decode_cache(cfg, batch_size: int, max_len: int, dtype=None):
    """Zero-initialised cache sized for a decode cell (dry-run input spec)."""
    dt = dtype or jnp.dtype(cfg.param_dtype)
    KV, D = cfg.n_kv_heads, cfg.resolved_head_dim
    groups = []
    for count, pattern in derive_groups(cfg):
        gs = []
        for desc in pattern:
            cap = min(desc.window, max_len) if desc.window else max_len
            gs.append({
                "k": jnp.zeros((count, batch_size, cap, KV, D), dt),
                "v": jnp.zeros((count, batch_size, cap, KV, D), dt),
            })
        groups.append(gs)
    return {"groups": groups, "pos": jnp.int32(0)}
