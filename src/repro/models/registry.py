"""Model registry: one uniform API per architecture family.

    model = get_model(cfg.model)
    params = model.init(cfg.model, key)
    loss, metrics = model.train_loss(params, cfg.model, batch, ctx)
    logits, cache = model.prefill(params, cfg.model, batch, ctx)
    logits, cache = model.decode_step(params, cfg.model, cache, token, ctx)
    cache = model.make_decode_cache(cfg.model, B, max_len)
"""

from __future__ import annotations

from types import SimpleNamespace

from repro.models import encdec, transformer, xlstm, zamba2


def get_model(model_cfg) -> SimpleNamespace:
    fam = model_cfg.family
    if fam in ("dense", "moe", "vlm"):
        mod = transformer
        return SimpleNamespace(
            init=transformer.init_lm,
            train_loss=transformer.train_loss,
            prefill=transformer.prefill,
            prefill_chunk=transformer.prefill_chunk,
            decode_step=transformer.decode_step,
            make_decode_cache=transformer.make_decode_cache,
            module=mod,
        )
    if fam == "ssm":
        return SimpleNamespace(
            init=xlstm.init_lm,
            train_loss=xlstm.train_loss,
            prefill=xlstm.prefill,
            decode_step=xlstm.decode_step,
            make_decode_cache=xlstm.make_decode_cache,
            module=xlstm,
        )
    if fam == "hybrid":
        return SimpleNamespace(
            init=zamba2.init_lm,
            train_loss=zamba2.train_loss,
            prefill=zamba2.prefill,
            decode_step=zamba2.decode_step,
            make_decode_cache=zamba2.make_decode_cache,
            module=zamba2,
        )
    if fam == "encdec":
        return SimpleNamespace(
            init=encdec.init_lm,
            train_loss=encdec.train_loss,
            prefill=encdec.prefill,
            decode_step=encdec.decode_step,
            make_decode_cache=encdec.make_decode_cache,
            module=encdec,
        )
    raise ValueError(f"unknown family: {fam}")
