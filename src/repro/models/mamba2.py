"""Mamba2 (SSD — state-space duality) blocks, chunked-parallel training form
plus exact recurrent decode.  Used directly by zamba2 and as the SSM half of
hybrid stacks.

Shapes (single group, n_groups=1):
    d_inner = ssm_expand * d_model
    H = cfg.ssm_heads, P = d_inner // H (head dim), N = cfg.ssm_state
    x (B,S,H,P), dt (B,S,H), A (H,) < 0, Bm/Cm (B,S,N)

Chunked SSD (chunk Q):
    y = SSD(x*dt, dt*A, B, C)
      = intra-chunk quadratic term + inter-chunk recurrent state passing.
The inter-chunk state scan is a plain lax.scan (nc steps) — cheap relative to
the intra-chunk matmuls and keeps HLO small.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import _normal, dense_init, dense, rmsnorm_init, rmsnorm

SSD_CHUNK = 256


def mamba2_init(key, cfg, dtype):
    d = cfg.d_model
    d_inner = cfg.ssm_expand * d
    H = cfg.ssm_heads or max(1, d_inner // 64)
    N = cfg.ssm_state
    conv_ch = d_inner + 2 * N
    ks = jax.random.split(key, 5)
    return {
        # in_proj -> [z (d_inner), x (d_inner), B (N), C (N), dt (H)]
        "in_proj": dense_init(ks[0], d, 2 * d_inner + 2 * N + H, dtype),
        "conv_w": _normal(ks[1], (cfg.ssm_conv, conv_ch), dtype,
                          1.0 / math.sqrt(cfg.ssm_conv)),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),       # A = -exp(A_log) ∈ (-1, 0]
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": rmsnorm_init(d_inner, dtype),
        "out_proj": dense_init(ks[2], d_inner, d, dtype,
                               scale=1.0 / math.sqrt(d_inner)),
    }


def _split_proj(cfg, proj):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = cfg.ssm_heads or max(1, d_inner // 64)
    N = cfg.ssm_state
    z, xbc_dt = jnp.split(proj, [d_inner], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [d_inner + 2 * N], axis=-1)
    return z, xbc, dt, (d_inner, H, N)


def _causal_conv(xbc, w, b):
    """Depthwise causal conv over time.  xbc (B,S,C); w (K,C)."""
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    acc = 0.0
    for i in range(K):
        acc = acc + pad[:, i:i + xbc.shape[1], :].astype(jnp.float32) * \
            w[i][None, None, :].astype(jnp.float32)
    return (acc + b.astype(jnp.float32)).astype(xbc.dtype)


def _segsum(x):
    """x (..., Q) -> (..., Q, Q) cumulative sums: out[i, j] = sum_{j<s<=i} x[s]
    for j <= i, -inf above the diagonal."""
    Q = x.shape[-1]
    c = jnp.cumsum(x, axis=-1)
    diff = c[..., :, None] - c[..., None, :]
    i = jnp.arange(Q)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int = SSD_CHUNK,
                init_state=None, return_state: bool = False):
    """Chunked SSD scan.

    x (B,S,H,P), dt (B,S,H) (post-softplus), A (H,) negative,
    Bm/Cm (B,S,N) shared across heads (single group).
    Returns y (B,S,H,P) [, final_state (B,H,P,N)].
    """
    Bb, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    nc = -(-S // Q)
    pad = nc * Q - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))

    xc = x.reshape(Bb, nc, Q, H, P)
    dtc = dt.reshape(Bb, nc, Q, H).astype(jnp.float32)
    Bc = Bm.reshape(Bb, nc, Q, N).astype(jnp.float32)
    Cc = Cm.reshape(Bb, nc, Q, N).astype(jnp.float32)

    dA = dtc * A[None, None, None, :]              # (B,nc,Q,H) log-decay
    seg = jnp.cumsum(dA, axis=2)                   # (B,nc,Q,H)

    # ---- intra-chunk (quadratic within Q) --------------------------------
    Lmat = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))  # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)     # (B,nc,Q,Q)
    M = scores[:, :, None, :, :] * Lmat                # (B,nc,H,Q,Q)
    Mdt = M * dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", Mdt,
                         xc.astype(jnp.float32))

    # ---- chunk boundary states ------------------------------------------
    decay_to_end = jnp.exp(seg[:, :, -1:, :] - seg)    # (B,nc,Q,H)
    sx = xc.astype(jnp.float32) * (dtc * decay_to_end)[..., None]
    chunk_states = jnp.einsum("bcqhp,bcqn->bchpn", sx, Bc)  # (B,nc,H,P,N)
    chunk_decay = jnp.exp(seg[:, :, -1, :])            # (B,nc,H) total decay

    # ---- inter-chunk recurrence ------------------------------------------
    s0 = (init_state if init_state is not None
          else jnp.zeros((Bb, H, P, N), jnp.float32))

    def step(s, inp):
        st, dec = inp  # (B,H,P,N), (B,H)
        s_out = s      # state entering this chunk
        s = s * dec[..., None, None] + st
        return s, s_out

    final_state, entry_states = jax.lax.scan(
        step, s0, (chunk_states.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(1, 0, 2)))
    entry_states = entry_states.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N)

    # entry-state contribution at position q: exp(seg_q) * C_q . S_entry
    y_inter = jnp.einsum("bcqn,bchpn->bcqhp", Cc, entry_states) * \
        jnp.exp(seg)[..., None]
    y = y_intra + y_inter
    y = y.reshape(Bb, nc * Q, H, P)[:, :S]
    if return_state:
        return y.astype(x.dtype), final_state
    return y.astype(x.dtype)


def mamba2_apply(p, cfg, x_in, *, return_state: bool = False,
                 init_state=None, conv_init=None):
    """Full-sequence mamba2 block: x_in (B,S,d) -> (y (B,S,d) [, cache]).

    cache = {'ssm': (B,H,P,N) fp32, 'conv': (B,K-1,C)} for decode handoff.
    """
    Bb, S, d = x_in.shape
    proj = dense(p["in_proj"], x_in)
    z, xbc, dt_raw, (d_inner, H, N) = _split_proj(cfg, proj)
    if conv_init is not None:
        ext = jnp.concatenate([conv_init.astype(xbc.dtype), xbc], axis=1)
        conv_out = _causal_conv(ext, p["conv_w"], p["conv_b"])[:, conv_init.shape[1]:]
    else:
        conv_out = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x_in.dtype)
    xs, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)
    P = d_inner // H
    xh = xs.reshape(Bb, S, H, P)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])

    if return_state:
        y, state = ssd_chunked(xh, dt, A, Bm, Cm, init_state=init_state,
                               return_state=True)
    else:
        y = ssd_chunked(xh, dt, A, Bm, Cm, init_state=init_state)

    y = y + xh.astype(y.dtype) * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(Bb, S, d_inner)
    y = rmsnorm(p["norm"], y, cfg.norm_eps) * jax.nn.silu(
        z.astype(jnp.float32)).astype(y.dtype)
    out = dense(p["out_proj"], y)
    if return_state:
        K = p["conv_w"].shape[0]
        tail = jnp.concatenate([conv_init, xbc], axis=1) if conv_init is not None else xbc
        conv_cache = tail[:, -(K - 1):, :]
        if conv_cache.shape[1] < K - 1:
            conv_cache = jnp.pad(
                conv_cache, ((0, 0), (K - 1 - conv_cache.shape[1], 0), (0, 0)))
        return out, {"ssm": state, "conv": conv_cache}
    return out


def mamba2_decode(p, cfg, x_in, cache):
    """Single-token recurrent step: x_in (B,1,d), cache {'ssm','conv'}."""
    Bb = x_in.shape[0]
    proj = dense(p["in_proj"], x_in[:, 0, :])
    z, xbc, dt_raw, (d_inner, H, N) = _split_proj(cfg, proj)

    # conv ring: cache['conv'] (B, K-1, C) holds the previous K-1 inputs
    K = p["conv_w"].shape[0]
    conv_in = jnp.concatenate([cache["conv"],
                               xbc[:, None, :].astype(cache["conv"].dtype)],
                              axis=1)  # (B, K, C)
    conv_out = jnp.einsum("bkc,kc->bc", conv_in.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) + \
        p["conv_b"].astype(jnp.float32)
    conv_out = jax.nn.silu(conv_out).astype(x_in.dtype)
    xs, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)
    P = d_inner // H
    xh = xs.reshape(Bb, H, P).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"][None, :])
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A[None, :])                       # (B,H)
    state = cache["ssm"] * dA[..., None, None] + \
        jnp.einsum("bhp,bn->bhpn", xh * dt[..., None], Bm.astype(jnp.float32))
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), state)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(Bb, d_inner).astype(x_in.dtype)
    y = rmsnorm(p["norm"], y, cfg.norm_eps) * jax.nn.silu(
        z.astype(jnp.float32)).astype(y.dtype)
    out = dense(p["out_proj"], y)[:, None, :]
    new_cache = {"ssm": state,
                 "conv": conv_in[:, 1:, :]}
    return out, new_cache


def make_mamba_cache(cfg, batch_size: int, dtype=jnp.float32):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = cfg.ssm_heads or max(1, d_inner // 64)
    N = cfg.ssm_state
    K = cfg.ssm_conv
    C = d_inner + 2 * N
    return {"ssm": jnp.zeros((batch_size, H, d_inner // H, N), jnp.float32),
            "conv": jnp.zeros((batch_size, K - 1, C), dtype)}
