"""Shared model building blocks: norms, RoPE/M-RoPE, GQA attention (direct +
flash-chunked), SwiGLU MLP, embeddings.

Conventions
-----------
* Pure functions over param pytrees (nested dicts of jnp arrays).
* ``init_*`` takes a PRNG key and returns the param dict; the matching apply
  function takes ``(params, ...)``.
* Activations flow in ``compute_dtype``; params live in ``param_dtype``;
  softmax/normalisation accumulate in fp32.
* Attention layouts:  q ``(B, S, H, Dh)``,  k/v ``(B, S, KV, Dh)``.
* ``window == 0`` means full (causal) attention; ``window > 0`` restricts
  attention to keys with ``q_pos - k_pos < window``.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

# Tunable chunking for the flash-style attention path (see EXPERIMENTS.md
# §Perf — these are hillclimb knobs).
Q_CHUNK = 1024
KV_CHUNK = 1024
FLASH_THRESHOLD = 4096  # use direct attention at/below this many keys

_NEG_INF = -2.0**30  # large-negative that is safe in bf16 accumulation


# ---------------------------------------------------------------------------
# Initialisers
# ---------------------------------------------------------------------------

def _normal(key, shape, dtype, scale):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def dense_init(key, d_in: int, d_out: int, dtype, *, bias: bool = False,
               scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": _normal(key, (d_in, d_out), dtype, scale)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_init(dim: int, dtype):
    return {"scale": jnp.zeros((dim,), dtype)}  # (1 + scale) parameterisation


def rmsnorm(p, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + p["scale"].astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def _rope_angles(positions, head_dim: int, theta: float):
    """positions (..., S) -> cos/sin (..., S, head_dim//2), fp32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, theta: float):
    """x (B, S, H, Dh); positions (B, S) absolute positions."""
    dt = x.dtype
    cos, sin = _rope_angles(positions, x.shape[-1], theta)
    cos = cos[:, :, None, :]  # (B, S, 1, half)
    sin = sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           axis=-1).astype(dt)


# M-RoPE (Qwen2-VL): head_dim/2 frequency slots split into (t, h, w)
# sections; each section rotates with its own position stream.
MROPE_SECTIONS = (2, 3, 3)  # ratios; scaled to head_dim//2 at apply time


def apply_mrope(x, positions3, theta: float, sections=MROPE_SECTIONS):
    """x (B, S, H, Dh); positions3 (B, S, 3) = (t, h, w) positions."""
    dt = x.dtype
    half = x.shape[-1] // 2
    total = sum(sections)
    sizes = [half * s // total for s in sections]
    sizes[-1] = half - sum(sizes[:-1])
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    # Build a per-slot position stream by selecting t/h/w per frequency slot.
    sec_id = jnp.concatenate([
        jnp.full((sz,), i, dtype=jnp.int32) for i, sz in enumerate(sizes)
    ])  # (half,)
    idx = jnp.broadcast_to(sec_id[None, None, :],
                           positions3.shape[:2] + (half,))
    pos = jnp.take_along_axis(positions3.astype(jnp.float32), idx, axis=-1)
    # (B, S, half)
    ang = pos * freqs
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           axis=-1).astype(dt)


# ---------------------------------------------------------------------------
# Soft capping (gemma / grok)
# ---------------------------------------------------------------------------

def softcap(x, cap: float):
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# Attention cores
# ---------------------------------------------------------------------------

def _gqa_scores(q, k, scale):
    """q (B,Sq,KV,G,D), k (B,Sk,KV,D) -> scores (B,KV,G,Sq,Sk) fp32."""
    return jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                      preferred_element_type=jnp.float32) * scale


def _mask(qpos, kpos, window: int, causal: bool):
    """qpos (B,Sq), kpos (B,Sk) -> bool (B,1,1,Sq,Sk). True = attend."""
    q = qpos[:, None, None, :, None]
    kk = kpos[:, None, None, None, :]
    m = kk >= 0  # invalid (unwritten ring-buffer) slots carry kpos < 0
    if causal:
        m &= q >= kk
    if window:
        m &= (q - kk) < window
    return m


def attention_direct(q, k, v, qpos, kpos, *, window: int = 0,
                     causal: bool = True, attn_softcap: float = 0.0):
    """Reference/direct attention. q (B,Sq,H,D), k/v (B,Sk,KV,D)."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, Sq, KV, G, D)
    s = _gqa_scores(qg, k, scale)
    s = softcap(s, attn_softcap)
    m = _mask(qpos, kpos, window, causal)
    s = jnp.where(m, s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)
    return o.reshape(B, Sq, H, D)


def attention_flash(q, k, v, qpos, kpos, *, window: int = 0,
                    causal: bool = True, attn_softcap: float = 0.0,
                    q_chunk: int = 0, kv_chunk: int = 0):
    """Flash-style chunked attention: O(Sq*kv_chunk) live memory via an
    online-softmax scan over KV chunks nested in a scan over Q chunks.

    Pure-jnp formulation (no Pallas) so the SPMD partitioner can shard the
    head and batch dims freely; this is the memory-safe path for 32k+ seqs.
    """
    q_chunk = q_chunk or Q_CHUNK
    kv_chunk = kv_chunk or KV_CHUNK
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(D)

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    nq = -(-Sq // q_chunk)
    nk = -(-Sk // kv_chunk)
    # Pad to multiples (padding masked out via kpos = -inf sentinel).
    pad_q = nq * q_chunk - Sq
    pad_k = nk * kv_chunk - Sk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        qpos = jnp.pad(qpos, ((0, 0), (0, pad_q)), constant_values=0)
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, ((0, 0), (0, pad_k)), constant_values=-1)

    qg = q.reshape(B, nq, q_chunk, KV, G, D).transpose(1, 0, 3, 4, 2, 5)
    # (nq, B, KV, G, cq, D)
    qp = qpos.reshape(B, nq, q_chunk).transpose(1, 0, 2)  # (nq, B, cq)
    kc = k.reshape(B, nk, kv_chunk, KV, D).transpose(1, 0, 3, 2, 4)
    # (nk, B, KV, ck, D)
    vc = v.reshape(B, nk, kv_chunk, KV, D).transpose(1, 0, 3, 2, 4)
    kp = kpos.reshape(B, nk, kv_chunk).transpose(1, 0, 2)  # (nk, B, ck)

    def q_step(_, q_in):
        qi, qpi = q_in  # (B,KV,G,cq,D), (B,cq)

        def kv_step(carry, kv_in):
            m_prev, l_prev, acc = carry
            ki, vi, kpi = kv_in  # (B,KV,ck,D), ..., (B,ck)
            s = jnp.einsum("bkgqd,bksd->bkgqs", qi, ki,
                           preferred_element_type=jnp.float32) * scale
            s = softcap(s, attn_softcap)
            msk = _mask_chunk(qpi, kpi, window, causal)
            s = jnp.where(msk, s, _NEG_INF)
            m_cur = jnp.max(s, axis=-1)
            m_new = jnp.maximum(m_prev, m_cur)
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bksd->bkgqd", p, vi.astype(jnp.float32))
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, KV, G, qi.shape[3]), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, qi.shape[3]), jnp.float32)
        a0 = jnp.zeros((B, KV, G, qi.shape[3], D), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kc, vc, kp))
        o = acc / jnp.maximum(l_f, 1e-37)[..., None]
        return None, o  # (B,KV,G,cq,D)

    _, o = jax.lax.scan(q_step, None, (qg, qp))
    # o: (nq, B, KV, G, cq, D) -> (B, Sq, H, D)
    o = o.transpose(1, 0, 4, 2, 3, 5).reshape(B, nq * q_chunk, H, D)
    return o[:, :Sq].astype(q.dtype)


def _mask_chunk(qpos, kpos, window: int, causal: bool):
    """qpos (B,cq), kpos (B,ck) -> (B,1,1,cq,ck)."""
    q = qpos[:, None, None, :, None]
    kk = kpos[:, None, None, None, :]
    m = kk >= 0
    if causal:
        m &= q >= kk
    if window:
        m &= (q - kk) < window
    return m


def attention(q, k, v, qpos, kpos, *, window: int = 0, causal: bool = True,
              attn_softcap: float = 0.0):
    """Dispatch: direct attention for short contexts, flash for long."""
    if k.shape[1] <= FLASH_THRESHOLD or q.shape[1] == 1:
        return attention_direct(q, k, v, qpos, kpos, window=window,
                                causal=causal, attn_softcap=attn_softcap)
    return attention_flash(q, k, v, qpos, kpos, window=window, causal=causal,
                           attn_softcap=attn_softcap)


# ---------------------------------------------------------------------------
# GQA attention layer (projections + rope + cache plumbing)
# ---------------------------------------------------------------------------

def attn_init(key, cfg, dtype):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], d, cfg.n_heads * hd, dtype, bias=cfg.use_bias),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * hd, dtype, bias=cfg.use_bias),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * hd, dtype, bias=cfg.use_bias),
        "wo": dense_init(ks[3], cfg.n_heads * hd, d, dtype,
                         scale=1.0 / math.sqrt(cfg.n_heads * hd)),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dtype)
        p["k_norm"] = rmsnorm_init(hd, dtype)
    return p


def attn_qkv(p, cfg, x, positions, *, theta: float = 0.0):
    """Project to q/k/v and apply rope.  positions: (B,S) or (B,S,3) m-rope.
    ``theta`` overrides cfg.rope_theta (per-layer theta, gemma3-style)."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    theta = theta or cfg.rope_theta
    q = dense(p["wq"], x).reshape(B, S, cfg.n_heads, hd)
    k = dense(p["wk"], x).reshape(B, S, cfg.n_kv_heads, hd)
    v = dense(p["wv"], x).reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if cfg.m_rope:
        q = apply_mrope(q, positions, theta)
        k = apply_mrope(k, positions, theta)
    else:
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    return q, k, v


def attn_apply(p, cfg, x, positions, *, window: int = 0, causal: bool = True,
               theta: float = 0.0):
    """Full-sequence attention (train / prefill). Returns (y, (k, v))."""
    q, k, v = attn_qkv(p, cfg, x, positions, theta=theta)
    pos1 = positions[..., 0] if cfg.m_rope else positions
    o = attention(q, k, v, pos1, pos1, window=window, causal=causal,
                  attn_softcap=cfg.attn_softcap)
    y = dense(p["wo"], o.reshape(x.shape[0], x.shape[1], -1))
    return y, (k, v)


def cache_kpos(pos, capacity: int, ring: bool):
    """Absolute key positions held by a cache of ``capacity`` slots when the
    current token sits at absolute position ``pos`` (traced scalar).

    Ring caches (windowed layers) store position p at slot ``p % capacity``;
    linear caches store p at slot p.  Unwritten slots get a negative kpos,
    which the attention mask treats as invalid.
    """
    j = jnp.arange(capacity, dtype=jnp.int32)
    if ring:
        return pos - jnp.mod(pos - j, capacity)
    return jnp.where(j <= pos, j, -1)


def attn_decode(p, cfg, x, pos, k_cache, v_cache, *, window: int = 0,
                theta: float = 0.0):
    """Single-token decode with in-place cache update.

    x (B,1,d); pos scalar int32 (absolute position of the new token);
    k_cache/v_cache (B,C,KV,Dh).  Windowed layers use ring caches
    (C == window); full layers use linear caches (C == max seq).
    Returns (y (B,1,d), k_cache', v_cache').
    """
    B = x.shape[0]
    positions = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
    if cfg.m_rope:
        positions = jnp.broadcast_to(pos[None, None, None], (B, 1, 3)).astype(jnp.int32)
    q, k, v = attn_qkv(p, cfg, x, positions, theta=theta)
    C = k_cache.shape[1]
    ring = window > 0 and C <= window
    slot = jnp.mod(pos, C) if ring else jnp.minimum(pos, C - 1)
    k_cache = jax.lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype),
                                           (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype),
                                           (0, slot, 0, 0))
    kpos = jnp.broadcast_to(cache_kpos(pos, C, ring)[None, :], (B, C))
    pos1 = positions[..., 0] if cfg.m_rope else positions
    o = attention_direct(q, k_cache.astype(q.dtype), v_cache.astype(q.dtype),
                         pos1, kpos, window=window, causal=True,
                         attn_softcap=cfg.attn_softcap)
    return dense(p["wo"], o.reshape(B, 1, -1)), k_cache, v_cache


def attn_prefill_chunk(p, cfg, x, qpos, k_ctx, v_ctx, ctx_kpos, *,
                       window: int = 0, theta: float = 0.0):
    """Chunked-prefill attention: a span of new tokens attends to an
    external KV context plus itself, causally.

    x (B,C,d); qpos (B,C) absolute positions of the chunk tokens;
    k_ctx/v_ctx (B,T,KV,Dh) already-cached context; ctx_kpos (B,T)
    absolute key positions of the context rows (<0 = unwritten, masked).
    Linear caches only (windowed/ring layers keep monolithic prefill).
    Returns (y (B,C,d), k, v) where k/v (B,C,KV,Dh) are the chunk's new
    cache rows for the caller to store.
    """
    B, C = x.shape[:2]
    q, k, v = attn_qkv(p, cfg, x, qpos, theta=theta)
    k_all = jnp.concatenate([k_ctx.astype(q.dtype), k.astype(q.dtype)], axis=1)
    v_all = jnp.concatenate([v_ctx.astype(q.dtype), v.astype(q.dtype)], axis=1)
    kpos_all = jnp.concatenate([ctx_kpos, qpos], axis=1)
    o = attention_direct(q, k_all, v_all, qpos, kpos_all, window=window,
                         causal=True, attn_softcap=cfg.attn_softcap)
    return dense(p["wo"], o.reshape(B, C, -1)), k, v


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, dtype, *, bias: bool = False):
    ks = jax.random.split(key, 3)
    return {
        "gate": dense_init(ks[0], d_model, d_ff, dtype, bias=bias),
        "up": dense_init(ks[1], d_model, d_ff, dtype, bias=bias),
        "down": dense_init(ks[2], d_ff, d_model, dtype, bias=bias,
                           scale=1.0 / math.sqrt(d_ff)),
    }


def mlp_apply(p, x):
    return dense(p["down"], jax.nn.silu(dense(p["gate"], x)) * dense(p["up"], x))


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_init(key, vocab: int, d_model: int, dtype, scale: float = 0.02):
    return {"table": _normal(key, (vocab, d_model), dtype, scale)}


def embed(p, tokens, compute_dtype):
    return p["table"][tokens].astype(compute_dtype)


def unembed(p_embed, x, *, w_head=None, logit_softcap_v: float = 0.0):
    """Project to vocab logits (fp32). Tied by default."""
    w = w_head if w_head is not None else p_embed["table"].T
    logits = jnp.einsum("bsd,dv->bsv", x.astype(jnp.float32),
                        w.astype(jnp.float32))
    return softcap(logits, logit_softcap_v)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def cross_entropy(logits, labels, mask=None):
    """logits (B,S,V) fp32, labels (B,S) int32. Returns mean NLL (fp32)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def make_positions(B: int, S: int):
    return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
