"""LR schedules as pure functions of a (traced) step scalar.

The schedule position is one of the IterPro induction variables: it is kept
as *independent* state (ICP) rather than re-derived from ``step``, so a
corrupted schedule position is recoverable from any partner IV via Eq. (1).
"""

from __future__ import annotations

import jax.numpy as jnp


def induction_specs(start_step: int = 0):
    """Affine induction spec for the state the schedule owns: the schedule
    position advances +1 per outer step from ``start_step``.  Consumed by
    ``core/icp.promote`` when it assembles the Recovery-Table IV registry
    (the leaf lives at ``iv/sched_pos`` in the train state)."""
    return {"sched_pos": (int(start_step), 1)}


def warmup_cosine(peak_lr: float, warmup_steps: int,
                  total_steps: int = 100_000, floor: float = 0.1):
    def lr(step):
        s = jnp.asarray(step, jnp.float32)
        warm = peak_lr * s / max(warmup_steps, 1)
        t = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1),
                     0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 *
                         (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(s < warmup_steps, warm, cos)

    return lr


def constant(peak_lr: float, warmup_steps: int = 0):
    def lr(step):
        s = jnp.asarray(step, jnp.float32)
        if warmup_steps:
            return peak_lr * jnp.minimum(1.0, s / warmup_steps)
        return jnp.full_like(s, peak_lr)

    return lr
