from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    adafactor,
    adamw,
    global_norm,
    make_optimizer,
)
from repro.optim.schedules import warmup_cosine  # noqa: F401
