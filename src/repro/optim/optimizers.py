"""Pure-JAX optimizers: AdamW (fp32 / bf16 / int8-quantised moments) and
Adafactor (factored second moment — the only recipe that fits 1T params on a
16 GB/chip pod).

Interface (optax-flavoured, dependency-free):

    opt = make_optimizer(train_plan, total_steps)
    state = opt.init(params)
    new_params, new_state, stats = opt.update(grads, state, params, step)

Optimizer state is an ordinary pytree sharded like the params (ZeRO), so it
participates in the IterPro recovery ladder like any other train-state leaf.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.optim.schedules import warmup_cosine

QBLOCK = 256  # int8 moment quantisation block


# ---------------------------------------------------------------------------
# int8 moment quantisation (block-wise absmax)
# ---------------------------------------------------------------------------

def _q8(x32):
    flat = x32.reshape(-1)
    pad = (-flat.shape[0]) % QBLOCK
    fp = jnp.pad(flat, (0, pad)).reshape(-1, QBLOCK)
    scale = jnp.max(jnp.abs(fp), axis=1, keepdims=True) / 127.0
    q = jnp.round(fp / jnp.maximum(scale, 1e-20)).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32)}


def _dq8(qs, shape):
    fp = qs["q"].astype(jnp.float32) * qs["scale"]
    n = 1
    for s in shape:
        n *= s
    return fp.reshape(-1)[:n].reshape(shape)


def _encode_moment(x32, dtype: str):
    if dtype == "int8":
        return _q8(x32)
    return x32.astype(jnp.dtype(dtype))


def _decode_moment(m, dtype: str, shape=None):
    if dtype == "int8":
        return _dq8(m, shape)
    return m.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Optimizer container
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Optimizer:
    """Optimizer + the induction specs for the state it owns.

    ``affine_ivs``/``derived_ivs`` export the optimizer-state counters to the
    Recovery Table (``core/icp.py`` mounts them under ``opt/``): ``affine_ivs``
    maps leaf name -> (init, step) for counters on an affine family (the step
    counter ``t``), ``derived_ivs`` maps leaf name -> fn(n) recomputing a
    value that is a pure function of the consensus iteration (bias-correction
    factors, Adafactor's decay).  The fns MUST reproduce bit-exactly the
    expression ``update`` writes at state version n — Eq. (1) repair of
    optimizer state is certified against the digest table afterwards.
    """
    init: Callable
    update: Callable  # (grads, state, params, step) -> (params, state, stats)
    name: str = "opt"
    affine_ivs: Dict[str, Tuple[int, int]] = field(default_factory=dict)
    derived_ivs: Dict[str, Callable] = field(default_factory=dict)


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(lr_fn, *, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          grad_clip=1.0, moment_dtype="float32"):
    def init(params):
        def zeros_like_m(p):
            z = jnp.zeros(p.shape, jnp.float32)
            return _encode_moment(z, moment_dtype)
        return {"m": jax.tree_util.tree_map(zeros_like_m, params),
                "v": jax.tree_util.tree_map(zeros_like_m, params),
                # optimizer-owned induction state (ICP): t is an affine IV
                # (+1 per update), bc1/bc2 are derived from it.  At version
                # n=0 both corrections are 1 - beta^0 = 0.
                "t": jnp.zeros((), jnp.int32),
                "bc1": jnp.zeros((), jnp.float32),
                "bc2": jnp.zeros((), jnp.float32)}

    def update(grads, state, params, step):
        if grad_clip:
            grads, gn = clip_by_global_norm(grads, grad_clip)
        else:
            gn = global_norm(grads)
        lr = lr_fn(step)
        # bias corrections advance from the optimizer's OWN counter — kept
        # independent of the loop's sched_pos so Eq. (1) has partners
        new_t = state["t"] + 1
        t = new_t.astype(jnp.float32)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        is_q = moment_dtype == "int8"

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m32 = _decode_moment(m, moment_dtype, p.shape)
            v32 = _decode_moment(v, moment_dtype, p.shape)
            m32 = b1 * m32 + (1 - b1) * g32
            v32 = b2 * v32 + (1 - b2) * jnp.square(g32)
            mhat = m32 / bc1
            vhat = v32 / bc2
            upd32 = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                upd32 = upd32 + weight_decay * p.astype(jnp.float32)
            newp = (p.astype(jnp.float32) - lr * upd32).astype(p.dtype)
            return newp, _encode_moment(m32, moment_dtype), \
                _encode_moment(v32, moment_dtype)

        # tree_map over (grads, m, v, params) triples
        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_p = jax.tree_util.tree_leaves(params)
        if is_q:
            # quantised moments have dict structure; walk the outer treedef
            flat_m = tdef.flatten_up_to(state["m"])
            flat_v = tdef.flatten_up_to(state["v"])
        else:
            flat_m = jax.tree_util.tree_leaves(state["m"])
            flat_v = jax.tree_util.tree_leaves(state["v"])
        outs = [upd(g, m, v, p)
                for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = tdef.unflatten([o[0] for o in outs])
        new_m = tdef.unflatten([o[1] for o in outs])
        new_v = tdef.unflatten([o[2] for o in outs])
        new_state = {"m": new_m, "v": new_v,
                     "t": new_t, "bc1": bc1, "bc2": bc2}
        return new_p, new_state, {"grad_norm": gn, "lr": lr}

    def _bc(beta):
        def fn(n):
            # the exact expression `update` writes at version n (f32 pow)
            return jnp.asarray(
                1.0 - beta ** jnp.asarray(n, jnp.float32), jnp.float32)
        return fn

    return Optimizer(init=init, update=update, name="adamw",
                     affine_ivs={"t": (0, 1)},
                     derived_ivs={"bc1": _bc(b1), "bc2": _bc(b2)})


# ---------------------------------------------------------------------------
# Adafactor (factored second moments, optional first moment off)
# ---------------------------------------------------------------------------

def adafactor(lr_fn, *, decay=0.8, eps=1e-30, clip_threshold=1.0,
              weight_decay=0.0, grad_clip=1.0, moment_dtype="bfloat16"):
    """Adafactor without momentum.  Matrices (ndim>=2) get factored row/col
    second-moment stats; vectors fall back to full stats.  Stat dtype is
    configurable (bf16 halves an already-tiny footprint)."""

    stat_dt = jnp.dtype(moment_dtype if moment_dtype != "int8" else "bfloat16")

    def init(params):
        def stats(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], stat_dt),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], stat_dt)}
            return {"v": jnp.zeros(p.shape, stat_dt)}
        return {"stats": jax.tree_util.tree_map(stats, params),
                # optimizer-owned induction state (ICP); beta2 at n=0 is a
                # placeholder (never read before the first update)
                "t": jnp.zeros((), jnp.int32),
                "beta2": jnp.zeros((), jnp.float32)}

    def update(grads, state, params, step):
        if grad_clip:
            grads, gn = clip_by_global_norm(grads, grad_clip)
        else:
            gn = global_norm(grads)
        lr = lr_fn(step)
        new_t = state["t"] + 1
        t = new_t.astype(jnp.float32)
        beta2 = 1.0 - t ** (-decay)

        def upd(g, s, p):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if g.ndim >= 2:
                vr = beta2 * s["vr"].astype(jnp.float32) + \
                    (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * s["vc"].astype(jnp.float32) + \
                    (1 - beta2) * jnp.mean(g2, axis=-2)
                denom = jnp.sqrt(
                    vr[..., None] * vc[..., None, :] /
                    jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True),
                                eps)[..., None])
                u = g32 / jnp.maximum(denom, eps)
                new_s = {"vr": vr.astype(stat_dt), "vc": vc.astype(stat_dt)}
            else:
                v = beta2 * s["v"].astype(jnp.float32) + (1 - beta2) * g2
                u = g32 / jnp.maximum(jnp.sqrt(v), eps)
                new_s = {"v": v.astype(stat_dt)}
            # update clipping (RMS)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), new_s

        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_p = jax.tree_util.tree_leaves(params)
        flat_s = tdef.flatten_up_to(state["stats"])
        outs = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_p = tdef.unflatten([o[0] for o in outs])
        new_s = tdef.unflatten([o[1] for o in outs])
        new_state = {"stats": new_s, "t": new_t, "beta2": beta2}
        return new_p, new_state, {"grad_norm": gn, "lr": lr}

    def _beta2(n):
        if n == 0:
            return jnp.zeros((), jnp.float32)  # the init placeholder
        return jnp.asarray(
            1.0 - jnp.asarray(n, jnp.float32) ** (-decay), jnp.float32)

    return Optimizer(init=init, update=update, name="adafactor",
                     affine_ivs={"t": (0, 1)},
                     derived_ivs={"beta2": _beta2})


def make_optimizer(train_plan, total_steps: int = 100_000) -> Optimizer:
    lr_fn = warmup_cosine(train_plan.learning_rate, train_plan.warmup_steps,
                          total_steps)
    if train_plan.optimizer == "adafactor":
        return adafactor(lr_fn, weight_decay=0.0,
                         grad_clip=train_plan.grad_clip,
                         moment_dtype=train_plan.moment_dtype)
    return adamw(lr_fn, weight_decay=train_plan.weight_decay,
                 grad_clip=train_plan.grad_clip,
                 moment_dtype=train_plan.moment_dtype)
