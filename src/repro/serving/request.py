"""Request/queue front end of the continuous-batching serving engine.

A ``Request`` carries everything the engine needs to (re)build its decode
state from scratch: the prompt and the accepted-token log.  The log IS the
serving RSI — prefix replay (prefill + forced decode over the log) rebuilds
a bit-identical cache, so a request survives the eviction of its slot with
no state beyond a few hundred int32s.

The ``RequestQueue`` is FIFO over arrival order with one extra operation,
``requeue_front``: a fault-evicted request re-enters at the FRONT of the
queue so its replay starts as soon as a slot frees (its arrival time has
long passed; making it wait behind fresh arrivals would double-charge it
for the fault).
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional

import numpy as np


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (P,) int32 prompt tokens
    max_new_tokens: int
    arrival_s: float = 0.0              # open-loop arrival (engine clock)
    #: extra per-request prefill features (B=1 leading axis), e.g.
    #: ``src_tokens`` / ``patch_embeds`` for encoder-decoder / VLM families
    features: dict = field(default_factory=dict)

    #: token log — log[0] is the prefill's argmax token (the first decode
    #: INPUT), log[1:] are accepted decode outputs.  Replay re-feeds
    #: log[:-1] and forces each step's output to the next log entry.
    log: List[int] = field(default_factory=list)
    #: outputs still to be forced during an in-progress prefix replay
    #: (drained by the engine; empty once the request is caught up)
    forced: Deque[int] = field(default_factory=deque)

    state: str = "queued"               # queued | active | done | dropped
    slot: Optional[int] = None
    replays: int = 0                    # fault-evictions survived
    retracted: int = 0                  # suspect tokens rescinded (total)

    # engine-clock timestamps (seconds since run start; -1 = not yet)
    t_admit_s: float = -1.0
    t_first_s: float = -1.0             # first generated token
    t_done_s: float = -1.0
    #: set at fault eviction; cleared (and accounted) at re-admission
    t_evicted_s: float = -1.0

    @property
    def n_out(self) -> int:
        """Accepted generated tokens (prefill token excluded)."""
        return max(0, len(self.log) - 1)

    @property
    def done(self) -> bool:
        return self.n_out >= self.max_new_tokens

    def retract(self, n: int) -> int:
        """Rescind the last ``n`` accepted outputs (suspect window after a
        fault; never touches log[0], the prefill token).  Returns how many
        were actually removed."""
        n = min(n, self.n_out)
        if n:
            del self.log[-n:]
            self.retracted += n
        return n


class RequestQueue:
    """Arrival-ordered FIFO with front-requeue for fault-evicted requests."""

    def __init__(self, requests=()):
        self._q: Deque[Request] = deque(
            sorted(requests, key=lambda r: (r.arrival_s, r.rid)))

    def __len__(self) -> int:
        return len(self._q)

    def push(self, rq: Request) -> None:
        self._q.append(rq)

    def requeue_front(self, rq: Request) -> None:
        rq.state = "queued"
        rq.slot = None
        self._q.appendleft(rq)

    def pop_ready(self, now_s: float) -> Optional[Request]:
        """Next request whose arrival time has passed (None if the head is
        still in the future or the queue is empty)."""
        if self._q and self._q[0].arrival_s <= now_s:
            return self._q.popleft()
        return None

    def next_arrival(self) -> Optional[float]:
        return self._q[0].arrival_s if self._q else None


class VirtualClock:
    """Deterministic engine clock for benchmarks and tests.

    ``clock()`` reads the current virtual time; ``clock.sleep(dt)``
    advances it.  ``ServingEngine.run`` waits for the next arrival via
    the clock's own ``sleep`` when it has one, so an idle engine on a
    virtual clock jumps straight to the next arrival instead of
    busy-spinning wall time that the virtual clock never sees."""

    def __init__(self, t0: float = 0.0):
        self.t = float(t0)

    def __call__(self) -> float:
        return self.t

    def sleep(self, dt: float) -> None:
        self.t += max(0.0, float(dt))
