"""Continuous-batching serving with slot-isolated recovery.

Public surface:

* :class:`~repro.serving.request.Request` / ``RequestQueue`` — the queue
  front end; a request's accepted-token log is its replay RSI.
* :class:`~repro.serving.engine.ServingEngine` / ``ServingReport`` — the
  iteration-level scheduler over slot-major decode state with a per-slot
  canary slice (1 fused launch + 1 scalar fault sync per engine step).
"""

from repro.serving.request import Request, RequestQueue
from repro.serving.engine import ServingEngine, ServingReport

__all__ = ["Request", "RequestQueue", "ServingEngine", "ServingReport"]
