"""Continuous-batching serving with slot-isolated recovery.

Public surface:

* :class:`~repro.serving.request.Request` / ``RequestQueue`` — the queue
  front end; a request's accepted-token log is its replay RSI.
  ``VirtualClock`` is the injectable engine clock (deterministic idle
  waits for benchmarks/tests).
* :class:`~repro.serving.engine.ServingEngine` / ``ServingReport`` — the
  iteration-level scheduler over paged (or dense slot-major) decode state
  with a block-granular canary (1 fused launch + 1 scalar fault sync per
  engine step).
* :mod:`~repro.serving.paged` — the shared KV block pool:
  ``BlockAllocator`` plus the typed admission errors (``AdmissionError``
  is permanent over-capacity, ``PoolSaturated`` a transient block
  shortage).
"""

from repro.serving.request import Request, RequestQueue, VirtualClock
from repro.serving.engine import ServingEngine, ServingReport
from repro.serving.paged import AdmissionError, BlockAllocator, PoolSaturated

__all__ = ["Request", "RequestQueue", "VirtualClock", "ServingEngine",
           "ServingReport", "AdmissionError", "BlockAllocator",
           "PoolSaturated"]
