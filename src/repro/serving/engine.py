"""Continuous-batching serving engine with slot-isolated recovery.

The training loop's resilience story (rotating checksum canary, one fused
launch + one scalar sync per step, exact replay from a tiny log) transfers
to serving as follows (DESIGN.md §6):

* **Slot-major decode state.**  The engine owns S batch *slots*.  Every
  decode-cache leaf is laid out ``[slot, ...]`` over per-slot B=1 caches
  (including the per-slot position counter, so requests at different
  depths coexist), and one vmapped decode executable advances all S lanes
  per engine step.  Admission and eviction are ``dynamic_update_slice``
  writes into the slot axis through ONE compiled function with a traced
  slot index — never a retrace, never a reshape of live state.

* **Per-slot canary slices.**  The rotating checksum canary is built over
  the *slot view* (``core.detect.slot_view``): digest units are (leaf,
  slot) pairs, so a checksum fault names its injured slot(s) directly.
  The check of the input view's slice ``s % K`` and the arm of the output
  view's slice ``(s+1) % K`` ride the decode's own launch (the
  ``check_arm_subcomputation`` core embedded in the engine's jitted step,
  exactly as core/fused_step.py does for training), donated or not.

* **Hot-path contract** (hard-asserted by benchmarks/serving_slo.py):
  one logical launch per engine step (vmapped decode + forced-token
  select + in-step canary + per-slot finite trap, one executable per
  rotation) + one scalar fault sync (``kernels.digest.fetch`` of the
  any-mismatch flag).  The accepted tokens come back in the same
  launch's payload — the serving data plane, not a detection cost.

* **Slot-isolated recovery.**  On a fault the policy
  (``core.recover.plan_serving_recovery``) evicts ONLY the injured slots:
  each victim's last ``K-1`` accepted tokens are rescinded (the provable
  suspect window under a K-slice canary), the request re-enters the queue
  front, and its slot's canary rows are re-certified against the lane's
  current bytes so no unit double-fires.  Healthy slots keep decoding the
  very next engine step — they even keep the fault step's own tokens,
  which are valid because lanes are computationally independent.
  Re-admission is prefix replay, the serving RSI: B=1 prefill + forced
  decode over the token log rebuilds a bit-identical lane (pinned by
  tests/test_serving.py).

* **Admission keeps the canary sound** with a partial ``refresh`` of the
  admitted slot's rows (patched in BOTH generations, generation counter
  untouched — the core/detect.py partial-refresh contract), so units of
  other slots armed before the admission still verify.

* **Paged KV pool** (default where supported; ``serving/paged.py``).
  Instead of one dense ``[max_len]`` cache per slot, every cache leaf is
  a shared block pool ``[n_blocks, block_size, ...]`` plus per-slot block
  tables: a request owns ``ceil((P + 1 + max_new) / block_size)`` blocks,
  admission is a block-budget decision, and freed blocks return to the
  pool on completion/eviction.  The hot path stays ONE launch: a Pallas
  block-gather kernel (``kernels/paged_kv.py``) materialises each slot's
  owned blocks, the *unmodified* vmapped decode runs on the gathered view
  (bit-exact vs the dense engine by construction), and the written row
  scatters back — all inside the same jitted step as the canary.  Canary
  units become (leaf, block) + per-slot ``pos``; block → owning slot is a
  host allocator lookup, so a flip on a FREE block evicts nobody.  All
  data movement is fixed-shape (scratch block 0 absorbs masked lanes), so
  block alloc/free churn causes 0 retraces.

* **Chunked prefill** (``prefill_chunk=C`` > 0, paged mode): long prompts
  prefill in C-token chunks interleaved one per engine-run iteration with
  decode steps, so a long prompt no longer stalls the S decode lanes —
  bounding short-request p99 under mixed traffic (measured by
  ``benchmarks/serving_slo.py``).  Chunk outputs are token-equivalent to
  monolithic prefill (same values, different fp reduction order;
  deterministic per platform, pinned by tests/test_serving.py).

Mesh mode (``ctx=DistContext``): params shard per ``launch/specs``; the
slot-major cache (or block pool) is replicated and the canary goes
shard-local over the replicated view (PR-5 machinery), keeping the
1-launch/1-sync contract with an all-reduced fault flag.  Slot-sharded
caches are a ROADMAP item.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.detect import (ChecksumCanary, FaultReport, block_leaf_prefix,
                               block_of_leaf, slot_leaf_prefix, slot_view)
from repro.core.faults import flip_bit
from repro.core.fused_step import _args_signature, _sds
from repro.core.recover import plan_serving_recovery
from repro.kernels import digest as kdigest
from repro.kernels import paged_kv as pkv
from repro.kernels.ops import leaf_key
from repro.models.registry import get_model
from repro.serving import paged as pgd
from repro.serving.paged import AdmissionError, BlockAllocator, PoolSaturated
from repro.serving.request import Request, RequestQueue

#: global fused-engine-step executable cache — keyed by (plan, K, donate,
#: S, model cfg, rotation, arg signature) so every engine over the same
#: smoke/serve configuration (one per test, one per benchmark run) shares
#: the K rotation-specialised executables and never recompiles.
_EXEC_CACHE: Dict[Tuple, Tuple] = {}

#: module-level prefill / admit executables, keyed by (model cfg, max_len,
#: [slots,] replication sharding) — engines over the same serving shape
#: (baseline vs storm run of a benchmark, one engine per test) share them,
#: so only the first engine's first admission pays compilation.
_PREFILL_CACHE: Dict[Tuple, object] = {}
_ADMIT_CACHE: Dict[Tuple, object] = {}

#: paged-mode admission-path executables (zero-on-alloc, span scatter,
#: chunk prefill, lane activate/deactivate) — keyed by pool geometry so
#: every engine over the same serving shape shares them.
_PAGED_FN_CACHE: Dict[Tuple, Dict] = {}


def evict_mesh(mesh) -> int:
    """Drop every serving-side executable keyed on ``mesh`` (cache keys
    carry the replication NamedSharding and/or a sharded digest plan) —
    the elastic remesh path's stale-executable guard."""
    from repro.kernels import digest as kdigest
    mk = kdigest._mesh_key(mesh)
    n = 0
    for cache in (_EXEC_CACHE, _PREFILL_CACHE, _ADMIT_CACHE,
                  _PAGED_FN_CACHE):
        stale = [k for k in cache if kdigest.key_on_mesh(k, mk)]
        for k in stale:
            del cache[k]
        n += len(stale)
    return n

_BIT_WIDTH = {"float32": 32, "int32": 32, "uint32": 32,
              "bfloat16": 16, "float16": 16, "int16": 16,
              "int8": 8, "uint8": 8}


def _pcts(xs: Sequence[float]) -> Dict[str, float]:
    if not xs:
        return {"mean": 0.0, "p50": 0.0, "p99": 0.0}
    a = np.asarray(xs, np.float64)
    return {"mean": float(a.mean()),
            "p50": float(np.percentile(a, 50)),
            "p99": float(np.percentile(a, 99))}


@dataclass
class ServingReport:
    """Engine telemetry — the data behind the serving SLO benchmark."""
    n_slots: int = 0
    requests: int = 0
    completed: int = 0
    dropped: int = 0
    tokens_out: int = 0
    engine_steps: int = 0
    admissions: int = 0
    admission_rejected: int = 0     # over-budget requests (typed error)
    faults_injected: int = 0
    faults_detected: int = 0
    faults_recovered: int = 0
    faults_on_free_slots: int = 0   # occupant already gone: SDC-risk count
    replay_tokens: int = 0
    retracted_tokens: int = 0
    decode_ms: List[float] = field(default_factory=list)
    #: per-fault recovery wall time: eviction -> victim re-admitted
    recovery_ms: List[float] = field(default_factory=list)
    injured_rids: Set[int] = field(default_factory=set)
    per_request: Dict[int, Dict] = field(default_factory=dict)

    def summary(self) -> Dict:
        d, r = _pcts(self.decode_ms), _pcts(self.recovery_ms)
        return {
            "requests": self.requests,
            "completed": self.completed,
            "dropped": self.dropped,
            "tokens_out": self.tokens_out,
            "engine_steps": self.engine_steps,
            "admissions": self.admissions,
            "admission_rejected": self.admission_rejected,
            "slots": self.n_slots,
            "faults": {"injected": self.faults_injected,
                       "detected": self.faults_detected,
                       "recovered": self.faults_recovered,
                       "on_free_slots": self.faults_on_free_slots},
            "mean_decode_ms": d["mean"],
            "p50_decode_ms": d["p50"],
            "p99_decode_ms": d["p99"],
            "mean_recovery_ms": r["mean"],
            "p50_recovery_ms": r["p50"],
            "p99_recovery_ms": r["p99"],
            "replay_tokens": self.replay_tokens,
            "retracted_tokens": self.retracted_tokens,
        }


class ServingEngine:
    """Iteration-level scheduler + slot-major decoder + slot canary.

    Parameters
    ----------
    cfg           : full config (``cfg.model`` drives the model family)
    n_slots       : batch slots S (concurrent requests per engine step)
    max_len       : decode-cache capacity (prompt + generation budget)
    canary_slices : rotating canary K over the S×L (leaf, slot) units;
                    0 disables the canary (free traps only)
    donate        : donate the slot-major cache into the engine step —
                    the production in-place KV-update setting
    ctx           : DistContext for mesh serving (params sharded, cache
                    replicated, shard-local canary) or None
    seed          : params init seed
    max_replays   : fault-evictions a request survives before it is
                    dropped (bounds livelock under a persistent-fault
                    adversary)
    paged         : None = auto (paged KV pool where the family supports
                    it — linear caches, 1-D rope); False forces the dense
                    per-slot cache; True errors if unsupported
    block_size    : KV-pool block size in token positions (paged mode;
                    ``max_len`` rounds up to a multiple)
    prefill_chunk : 0 = monolithic prefill; C > 0 prefills prompts in
                    C-token chunks interleaved with decode steps (paged
                    mode only)
    pool_blocks   : total pool blocks incl. the scratch block (0 = full
                    capacity: every slot can hold a max-size request)
    """

    def __init__(self, cfg, *, n_slots: int = 4, max_len: int = 64,
                 canary_slices: int = 4, donate: bool = True,
                 ctx=None, seed: int = 0, max_replays: int = 8,
                 verbose: bool = False, paged: Optional[bool] = None,
                 block_size: int = 8, prefill_chunk: int = 0,
                 pool_blocks: int = 0, parity: bool = False):
        self.cfg = cfg
        self.m = cfg.model
        self.model = get_model(self.m)
        self.S = int(n_slots)
        self.max_len = int(max_len)
        self.K = int(canary_slices)
        self.donate = bool(donate)
        self.ctx = ctx if (ctx is not None and ctx.enabled) else None
        self.max_replays = int(max_replays)
        self.verbose = verbose
        self.block_size = int(block_size)
        self.prefill_chunk = int(prefill_chunk)

        params = self.model.init(self.m, jax.random.PRNGKey(seed))
        self._repl = None
        if self.ctx is not None:
            from jax.sharding import NamedSharding, PartitionSpec
            from repro.launch.specs import param_shardings
            psh, _ = param_shardings(self.ctx, cfg, params)
            params = jax.device_put(params, psh)
            self._repl = NamedSharding(self.ctx.mesh, PartitionSpec())
        self.params = params

        # at-rest parity over the STATIC params (core/parity.py): serving
        # never mutates them, so one build at load time + healthy digests
        # recorded here let `scrub_params` detect and repair silent
        # at-rest corruption in O(bytes/D) with no weight reload
        self.parity_store = None
        self._param_refs: Optional[Dict[str, np.ndarray]] = None
        if parity:
            from repro.core.parity import ParityStore
            self.parity_store = ParityStore(params, ctx=self.ctx)
            self.parity_store.build(params)
            plan = self.parity_store.plan
            on_mesh = plan.mesh is not None
            self._param_refs = {
                k: (np.asarray(kdigest.host_shard_checksums(leaf))
                    if on_mesh
                    else np.asarray(kdigest.host_checksum(np.asarray(leaf))))
                for k, leaf in zip(plan.keys, plan.leaves(params))}

        # paged-mode resolution: auto-detect unless forced off
        self.paged = False
        if paged is not False:
            ml = -(-self.max_len // self.block_size) * self.block_size
            probe = self.model.make_decode_cache(self.m, 1, ml)
            supported = pgd.paged_supported(self.model, self.m, probe, ml)
            if paged and not supported:
                raise ValueError(
                    "paged=True: this family/config has no paged-KV "
                    "support (needs linear non-windowed caches, 1-D rope "
                    "and a prefill_chunk entry point)")
            self.paged = supported
            if self.paged:
                self.max_len = ml

        tok = jnp.zeros((self.S,), jnp.int32)
        if self.paged:
            # shared block pool + per-slot block tables; block 0 scratch
            self.max_blocks = self.max_len // self.block_size
            self.n_blocks = int(pool_blocks) or (1 + self.S * self.max_blocks)
            if self.n_blocks < 2:
                raise ValueError("pool_blocks must be >= 2")
            per_slot = self.model.make_decode_cache(self.m, 1, self.max_len)
            pool = pgd.make_block_pool(per_slot, self.n_blocks,
                                       self.block_size)
            bt = jnp.zeros((self.S, self.max_blocks), jnp.int32)
            pos = jnp.zeros((self.S,), jnp.int32)
            amask = jnp.zeros((self.S,), bool)
            if self._repl is not None:
                pool = jax.device_put(
                    pool, jax.tree_util.tree_map(lambda _: self._repl, pool))
                bt, pos, amask, tok = (jax.device_put(x, self._repl)
                                       for x in (bt, pos, amask, tok))
            self.pool, self.bt, self.pos, self.amask = pool, bt, pos, amask
            self.cache = None
            self._bt_np = np.zeros((self.S, self.max_blocks), np.int32)
            self.alloc = BlockAllocator(self.n_blocks)
        else:
            # slot-major decode state: per-slot B=1 caches stacked on a
            # leading [slot] axis (positions become a (S,) vector —
            # per-slot depths for free); tok holds each lane's next input
            per_slot = self.model.make_decode_cache(self.m, 1, self.max_len)
            cache = jax.tree_util.tree_map(
                lambda l: jnp.stack([l] * self.S), per_slot)
            if self._repl is not None:
                cache = jax.device_put(
                    cache,
                    jax.tree_util.tree_map(lambda _: self._repl, cache))
                tok = jax.device_put(tok, self._repl)
            self.cache = cache
        self.tok = tok

        self.canary: Optional[ChecksumCanary] = None
        self.plan = None
        self._slot_keys: List[Tuple[str, ...]] = []
        self._block_keys: List[Tuple[str, ...]] = []
        self._pos_keys: List[str] = []
        if self.K:
            view = (self._view() if self.paged
                    else slot_view(self.cache, self.S))
            self.canary = ChecksumCanary(view, n_slices=self.K, ctx=self.ctx)
            self.plan = self.canary.plan
            if self.paged:
                self._block_keys = [
                    tuple(k for k in self.plan.keys
                          if k.startswith(block_leaf_prefix(b) + "/"))
                    for b in range(self.n_blocks)]
                self._pos_keys = [f"{slot_leaf_prefix(u)}/pos"
                                  for u in range(self.S)]
            else:
                self._slot_keys = [
                    tuple(k for k in self.plan.keys
                          if k.startswith(slot_leaf_prefix(u) + "/"))
                    for u in range(self.S)]

        model, m, repl, max_len = self.model, self.m, self._repl, self.max_len
        pkey = (m, max_len, repl)
        self._prefill = _PREFILL_CACHE.get(pkey)
        if self._prefill is None:
            self._prefill = jax.jit(
                lambda p, b: model.prefill(p, m, b, None, max_len=max_len))
            _PREFILL_CACHE[pkey] = self._prefill

        akey = (m, max_len, self.S, repl)
        self._admit_exec = None if self.paged else _ADMIT_CACHE.get(akey)
        if self._admit_exec is None and not self.paged:
            def admit_fn(cache, tok, sub, t0, u):
                # slice write with a TRACED slot index: one executable
                # serves every slot — admission/eviction never retraces
                def put(big, small):
                    return jax.lax.dynamic_update_slice(
                        big, small[None].astype(big.dtype),
                        (u,) + (0,) * (big.ndim - 1))
                ncache = jax.tree_util.tree_map(put, cache, sub)
                if repl is not None:
                    ncache = jax.tree_util.tree_map(
                        lambda x: jax.lax.with_sharding_constraint(x, repl),
                        ncache)
                ntok = jax.lax.dynamic_update_slice(tok, t0[None], (u,))
                return ncache, ntok
            self._admit_exec = jax.jit(admit_fn, donate_argnums=(0, 1))
            _ADMIT_CACHE[akey] = self._admit_exec

        # no-forcing device constants (steady state never pays an extra
        # host->device transfer for the forced-token mask)
        fm0 = jnp.zeros((self.S,), bool)
        ft0 = jnp.zeros((self.S,), jnp.int32)
        if self._repl is not None:
            fm0 = jax.device_put(fm0, self._repl)
            ft0 = jax.device_put(ft0, self._repl)
        self._fmask0, self._ftok0 = fm0, ft0

        # host-side slot table
        self.slot_rid: List[Optional[int]] = [None] * self.S
        self._by_slot: Dict[int, Request] = {}
        self._prefilling: Dict[int, Dict] = {}   # paged: slot -> {rq, off}
        self._slot_history: List[Optional[int]] = [None] * self.S
        self.step_count = 0
        self.report = ServingReport(n_slots=self.S)
        self._execs: Dict[int, Tuple] = {}
        self._sig = None
        self._fns = self._paged_fns() if self.paged else None

    # -- paged-mode plumbing ----------------------------------------------

    def _view(self):
        """Canary view of the paged state: (leaf, block) + per-slot pos."""
        return pgd.paged_canary_view(self.pool, self.pos, self.n_blocks,
                                     self.S)

    def _dev(self, x):
        return x if self._repl is None else jax.device_put(x, self._repl)

    def _paged_fns(self) -> Dict:
        """Admission-path executables (module-cached per pool geometry):
        fixed-shape pool writes with traced indices — block churn never
        retraces."""
        key = (self.m, self.S, self.max_blocks, self.block_size,
               self.n_blocks, self._repl)
        fns = _PAGED_FN_CACHE.get(key)
        if fns is not None:
            return fns
        model, m, bs, repl = self.model, self.m, self.block_size, self._repl
        cap = self.max_len

        def pin(tree):
            if repl is None:
                return tree
            return jax.tree_util.tree_map(
                lambda x: jax.lax.with_sharding_constraint(x, repl), tree)

        def zero_fn(pool, bids):
            return pin(pgd.zero_blocks(pool, bids))

        def span_fn(pool, new_kv, bt_row, start, valid):
            return pin(pgd.scatter_span(pool, new_kv, bt_row, start, valid,
                                        bs))

        def chunk_fn(params, pool, bt_row, tokens, pos0, valid):
            ctx_cache = pgd.ctx_from_pool(pool, bt_row, bs, pos0)
            kpos = pgd.ctx_kpos(pos0, cap)
            logits, new_kv = model.prefill_chunk(
                params, m, {"tokens": tokens}, ctx_cache, kpos, pos0, valid,
                None)
            npool = pgd.scatter_span(pool, new_kv["groups"], bt_row, pos0,
                                     valid, bs)
            return pin(npool), logits

        def act_fn(pos, tok, amask, p0, t0, u):
            npos = jax.lax.dynamic_update_slice(pos, p0[None], (u,))
            ntok = jax.lax.dynamic_update_slice(tok, t0[None], (u,))
            nam = jax.lax.dynamic_update_slice(
                amask, jnp.ones((1,), bool), (u,))
            return pin(npos), pin(ntok), pin(nam)

        def deact_fn(amask, u):
            return pin(jax.lax.dynamic_update_slice(
                amask, jnp.zeros((1,), bool), (u,)))

        fns = {
            "zero": jax.jit(zero_fn, donate_argnums=(0,)),
            "span": jax.jit(span_fn, donate_argnums=(0,)),
            "chunk": jax.jit(chunk_fn, donate_argnums=(1,)),
            "activate": jax.jit(act_fn, donate_argnums=(0, 1, 2)),
            "deact": jax.jit(deact_fn, donate_argnums=(0,)),
        }
        _PAGED_FN_CACHE[key] = fns
        return fns

    def _refresh_blocks(self, blocks) -> None:
        """Re-certify the given pool blocks' canary rows after an
        out-of-step pool write (both generations, no generation bump).
        One refresh per block keeps the digest-subset key set bounded —
        every subset is pre-warmed by ``warm()``, so churn never
        retraces."""
        if self.canary is None or not blocks:
            return
        view = self._view()
        for b in sorted(blocks):
            self.canary.refresh(view, keys=self._block_keys[b])

    # -- compiled engine step ---------------------------------------------

    def _build_exec(self, r: int):
        """AOT-compile rotation ``r``'s fused engine step."""
        model, m, S, repl = self.model, self.m, self.S, self._repl
        plan, canary = self.plan, self.canary

        def vdecode(params, cache, tok):
            # per-slot B=1 decode vmapped over the slot axis: every lane
            # advances at ITS OWN position; lanes are computationally
            # independent (the slot-isolation guarantee)
            def one(c, t):
                lg, nc = model.decode_step(params, m, c, t[None], None)
                return lg[0], nc
            return jax.vmap(one)(cache, tok)

        def pin(tree):
            if repl is None:
                return tree
            return jax.tree_util.tree_map(
                lambda x: jax.lax.with_sharding_constraint(x, repl), tree)

        chk = canary._slice_indices(r) if canary else []
        arm = canary._slice_indices(r + 1) if canary else []
        if not (chk or arm):
            # no canary (or degenerate rotation): plain fused step
            def fused(cache, tok, fmask, ftok, params):
                logits, ncache = vdecode(params, cache, tok)
                ncache = pin(ncache)
                nxt = jnp.where(fmask, ftok,
                                jnp.argmax(logits, -1).astype(jnp.int32))
                finite = jnp.isfinite(logits).all(axis=-1)
                payload = jnp.stack([nxt, finite.astype(jnp.int32)], axis=1)
                return ncache, nxt, payload
            jfn = jax.jit(fused,
                          donate_argnums=(0, 1) if self.donate else ())
            lowered = jfn.lower(_sds(self.cache), _sds(self.tok),
                                _sds(self._fmask0), _sds(self._ftok0),
                                _sds(self.params))
            return lowered.compile(), (), ()

        core, union = kdigest.check_arm_subcomputation(plan, chk, arm)

        def fused(cache, tok, fmask, ftok, buf, ref_read, ref_write, params):
            # ONE launch: slot-view slices are free static gathers; the
            # check slice reads the INPUT lanes (scheduled before the
            # donated in-place writes), the arm slice reads the output
            in_leaves = plan.leaves(slot_view(cache, S))
            logits, ncache = vdecode(params, cache, tok)
            ncache = pin(ncache)
            out_leaves = plan.leaves(slot_view(ncache, S))
            nxt = jnp.where(fmask, ftok,
                            jnp.argmax(logits, -1).astype(jnp.int32))
            finite = jnp.isfinite(logits).all(axis=-1)   # per-slot free trap
            buf, flag, bad, new_write = core(
                buf,
                [in_leaves[i] for i in chk] + [out_leaves[i] for i in arm],
                ref_read, ref_write)
            payload = jnp.stack([nxt, finite.astype(jnp.int32)], axis=1)
            return ncache, nxt, payload, flag, bad, buf, new_write

        donate_argnums = (4, 6) + ((0, 1) if self.donate else ())
        jfn = jax.jit(fused, donate_argnums=donate_argnums)
        table_sds = _sds(canary.reference)
        buf_sds = _sds(plan.take_buffer(union))
        lowered = jfn.lower(_sds(self.cache), _sds(self.tok),
                            _sds(self._fmask0), _sds(self._ftok0),
                            buf_sds, table_sds, table_sds, _sds(self.params))
        return lowered.compile(), union, tuple(chk)

    def _build_exec_paged(self, r: int):
        """AOT-compile rotation ``r``'s fused PAGED engine step: Pallas
        block gather -> unmodified vmapped dense decode on the gathered
        view -> fixed-shape token scatter-back, with the canary's
        check/arm riding the same launch over the (leaf, block) + pos
        view.  Bit-exact vs the dense engine by construction (the decode
        computation is literally identical)."""
        model, m, S, repl = self.model, self.m, self.S, self._repl
        plan, canary = self.plan, self.canary
        NB, bs = self.n_blocks, self.block_size
        interp = pkv._interpret()

        def vdecode(params, gcache, tok):
            def one(c, t):
                lg, nc = model.decode_step(params, m, c, t[None], None)
                return lg[0], nc
            return jax.vmap(one)(gcache, tok)

        def pin(tree):
            if repl is None:
                return tree
            return jax.tree_util.tree_map(
                lambda x: jax.lax.with_sharding_constraint(x, repl), tree)

        def step_core(params, pool, bt, pos, amask, tok, fmask, ftok):
            gcache = pgd.gathered_cache(pool, bt, pos, interpret=interp)
            logits, ngc = vdecode(params, gcache, tok)
            npool = pgd.scatter_token(pool, ngc["groups"], bt, pos, amask,
                                      bs)
            npos = jnp.where(amask, pos + 1, pos)
            nxt = jnp.where(fmask, ftok,
                            jnp.argmax(logits, -1).astype(jnp.int32))
            finite = jnp.isfinite(logits).all(axis=-1)
            return npool, npos, nxt, finite

        chk = canary._slice_indices(r) if canary else []
        arm = canary._slice_indices(r + 1) if canary else []
        if not (chk or arm):
            def fused(pool, bt, pos, amask, tok, fmask, ftok, params):
                npool, npos, nxt, finite = step_core(
                    params, pool, bt, pos, amask, tok, fmask, ftok)
                npool, npos = pin(npool), pin(npos)
                payload = jnp.stack([nxt, finite.astype(jnp.int32)], axis=1)
                return npool, npos, nxt, payload
            jfn = jax.jit(fused,
                          donate_argnums=(0, 2, 4) if self.donate else ())
            lowered = jfn.lower(_sds(self.pool), _sds(self.bt),
                                _sds(self.pos), _sds(self.amask),
                                _sds(self.tok), _sds(self._fmask0),
                                _sds(self._ftok0), _sds(self.params))
            return lowered.compile(), (), ()

        core, union = kdigest.check_arm_subcomputation(plan, chk, arm)

        def fused(pool, bt, pos, amask, tok, fmask, ftok, buf, ref_read,
                  ref_write, params):
            in_leaves = plan.leaves(
                pgd.paged_canary_view(pool, pos, NB, S))
            npool, npos, nxt, finite = step_core(
                params, pool, bt, pos, amask, tok, fmask, ftok)
            npool, npos = pin(npool), pin(npos)
            out_leaves = plan.leaves(
                pgd.paged_canary_view(npool, npos, NB, S))
            buf, flag, bad, new_write = core(
                buf,
                [in_leaves[i] for i in chk] + [out_leaves[i] for i in arm],
                ref_read, ref_write)
            payload = jnp.stack([nxt, finite.astype(jnp.int32)], axis=1)
            return npool, npos, nxt, payload, flag, bad, buf, new_write

        donate_argnums = (7, 9) + ((0, 2, 4) if self.donate else ())
        jfn = jax.jit(fused, donate_argnums=donate_argnums)
        table_sds = _sds(canary.reference)
        buf_sds = _sds(plan.take_buffer(union))
        lowered = jfn.lower(_sds(self.pool), _sds(self.bt), _sds(self.pos),
                            _sds(self.amask), _sds(self.tok),
                            _sds(self._fmask0), _sds(self._ftok0),
                            buf_sds, table_sds, table_sds, _sds(self.params))
        return lowered.compile(), union, tuple(chk)

    def _exec(self, r: int):
        ent = self._execs.get(r)
        if ent is None:
            if self._sig is None:
                arrs = ((self.pool, self.bt, self.pos, self.amask, self.tok,
                         self.params) if self.paged
                        else (self.cache, self.tok, self.params))
                self._sig = ("paged" if self.paged else "dense",
                             _args_signature(arrs))
            key = (self.plan, self.K, self.donate, self.S, self.m, r,
                   self._sig)
            ent = _EXEC_CACHE.get(key)
            if ent is None:
                ent = (self._build_exec_paged(r) if self.paged
                       else self._build_exec(r))
                _EXEC_CACHE[key] = ent
            self._execs[r] = ent
        return ent

    def warm(self) -> float:
        """AOT-compile every rotation executable (idempotent; returns wall
        seconds).  First use per configuration pays; the global cache
        makes later engines free.  Paged engines also pre-warm every
        per-block / per-slot digest-refresh subset, so block alloc/free
        churn at steady state never traces a new digest function."""
        t0 = time.perf_counter()
        for r in range(max(1, self.K)):
            self._exec(r)
        if self.paged and self.canary is not None:
            view = self._view()
            for b in range(self.n_blocks):
                self.canary.refresh(view, keys=self._block_keys[b])
            for u in range(self.S):
                self.canary.refresh(view, keys=[self._pos_keys[u]])
        return time.perf_counter() - t0

    # -- hot path ----------------------------------------------------------

    def _forced_arrays(self):
        forced = [(u, rq.forced[0]) for u, rq in self._by_slot.items()
                  if rq.forced]
        if not forced:
            return self._fmask0, self._ftok0
        fm = np.zeros((self.S,), bool)
        ft = np.zeros((self.S,), np.int32)
        for u, t in forced:
            fm[u] = True
            ft[u] = t
        if self._repl is not None:
            return (jax.device_put(fm, self._repl),
                    jax.device_put(ft, self._repl))
        return jnp.asarray(fm), jnp.asarray(ft)

    def engine_step(self) -> Tuple[np.ndarray, np.ndarray,
                                   Optional[FaultReport]]:
        """Advance every lane one token: ONE logical launch + ONE scalar
        fault sync (+ the token payload transfer — the data plane).

        Returns ``(tokens (S,), finite (S,) bool, report|None)``.  On a
        report the injured lanes' output is corrupt-derived; healthy
        lanes' tokens are valid (lane independence) and are kept.
        """
        s = self.step_count
        fmask, ftok = self._forced_arrays()
        r = s % self.K if self.K else 0
        compiled, union, chk = self._exec(r)
        kdigest.STATS.launches += 1
        report = None
        if self.paged:
            if union:
                can = self.canary
                ref_read, ref_write = can.begin_update()
                (npool, npos, ntok, payload, flag, bad, buf,
                 new_write) = compiled(
                    self.pool, self.bt, self.pos, self.amask, self.tok,
                    fmask, ftok, self.plan.take_buffer(union), ref_read,
                    ref_write, self.params)
                self.plan.put_buffer(union, buf)
                can.commit_update(new_write)
                if bool(kdigest.fetch(flag)):  # the step's ONE fault sync
                    report = FaultReport(
                        s, "checksum", detail="paged block canary",
                        resolver=self._paged_resolver(chk, bad))
            else:
                npool, npos, ntok, payload = compiled(
                    self.pool, self.bt, self.pos, self.amask, self.tok,
                    fmask, ftok, self.params)
            self.pool, self.pos, self.tok = npool, npos, ntok
        elif union:
            can = self.canary
            ref_read, ref_write = can.begin_update()
            (ncache, ntok, payload, flag, bad, buf, new_write) = compiled(
                self.cache, self.tok, fmask, ftok,
                self.plan.take_buffer(union), ref_read, ref_write,
                self.params)
            self.plan.put_buffer(union, buf)
            can.commit_update(new_write)
            if bool(kdigest.fetch(flag)):     # the step's ONE fault sync
                report = FaultReport(
                    s, "checksum", detail="slot canary",
                    resolver=lambda: can._attribute(chk, bad))
            self.cache, self.tok = ncache, ntok
        else:
            ncache, ntok, payload = compiled(
                self.cache, self.tok, fmask, ftok, self.params)
            self.cache, self.tok = ncache, ntok
        self.step_count += 1
        pl = np.asarray(payload)              # data plane: the tokens
        return pl[:, 0], pl[:, 1].astype(bool), report

    def _paged_resolver(self, chk, bad):
        """Attribution closure for a paged-canary fault: translate the
        plan's (leaf, block) keys into ``slotNNN/...`` keys for blocks a
        request owned AT DETECTION TIME (the owner map is snapshotted
        here, before recovery frees anything), so
        ``FaultReport.injured_slots()`` works unchanged.  Flips on
        unowned blocks keep their ``blockNNNN/`` keys — nobody to evict.
        """
        can = self.canary
        owner = dict(self.alloc.owner)

        def resolve():
            leaves, shards = can._attribute(chk, bad)
            def xlat(k):
                b = block_of_leaf(k)
                o = owner.get(b) if b is not None else None
                return k if o is None else f"{slot_leaf_prefix(o)}/{k}"
            return (sorted(xlat(k) for k in leaves),
                    {xlat(k): v for k, v in shards.items()})
        return resolve

    # -- scheduler: admission / acceptance / eviction ----------------------

    def free_slots(self) -> List[int]:
        return [u for u in range(self.S) if self.slot_rid[u] is None]

    def check_admissible(self, rq: Request) -> None:
        """Reject a request whose worst-case KV footprint can NEVER fit
        (typed ``AdmissionError``) — the admission capacity guard.  Under
        paging this is the block-budget check; dense it is the ``max_len``
        check the engine used to silently overflow past."""
        need = len(rq.prompt) + 1 + rq.max_new_tokens
        if self.paged:
            nb = pgd.blocks_needed(len(rq.prompt), rq.max_new_tokens,
                                   self.block_size)
            if nb > self.max_blocks:
                raise AdmissionError(
                    f"rid={rq.rid}: needs {nb} blocks "
                    f"({need} positions), per-slot budget is "
                    f"{self.max_blocks} blocks ({self.max_len} positions)")
            if nb > self.alloc.capacity:
                raise AdmissionError(
                    f"rid={rq.rid}: needs {nb} blocks, whole pool holds "
                    f"{self.alloc.capacity}")
        elif need > self.max_len:
            raise AdmissionError(
                f"rid={rq.rid}: needs {need} positions "
                f"(prompt {len(rq.prompt)} + 1 + max_new "
                f"{rq.max_new_tokens}), slot capacity is {self.max_len}")

    def admit(self, rq: Request, slot: int, now_s: float = 0.0, *,
              interleave: bool = False) -> None:
        """Prefill + write the request into ``slot``; re-certify the
        touched canary units (partial refresh, both generations).

        Paged mode reserves the request's whole block budget up front
        (may raise ``PoolSaturated``) and, with ``interleave=True`` and a
        configured ``prefill_chunk``, only runs admission bookkeeping —
        the prompt is then prefilled chunk-at-a-time by ``_prefill_step``
        calls interleaved with decode engine steps."""
        self.check_admissible(rq)
        if self.paged:
            self._admit_paged(rq, slot, now_s, interleave=interleave)
            return
        batch = {"tokens": jnp.asarray(
            np.asarray(rq.prompt, np.int32)[None])}
        for k, v in rq.features.items():
            batch[k] = jnp.asarray(v)
        logits, sub = self._prefill(self.params, batch)
        if self._repl is not None:
            sub = jax.device_put(
                sub, jax.tree_util.tree_map(lambda _: self._repl, sub))
        replaying = bool(rq.log)
        if replaying:
            # prefix replay: the log IS the RSI — force the lane back
            # through its accepted tokens (bit-identical rebuild)
            t0 = rq.log[0]
            rq.forced = deque(rq.log[1:])
            self.report.replay_tokens += len(rq.log) - 1
        else:
            t0 = int(np.argmax(np.asarray(logits[0])))
            rq.log = [t0]
        self.cache, self.tok = self._admit_exec(
            self.cache, self.tok, sub, jnp.int32(t0), jnp.int32(slot))
        if self.canary is not None:
            # partial refresh: patch ONLY this slot's rows (in both
            # generations, no generation bump) so units of other slots
            # armed before this admission still verify
            self.canary.refresh(slot_view(self.cache, self.S),
                                keys=self._slot_keys[slot])
        self.slot_rid[slot] = rq.rid
        self._by_slot[slot] = rq
        rq.slot = slot
        rq.state = "active"
        if rq.t_admit_s < 0:
            rq.t_admit_s = now_s
        self.report.admissions += 1
        if self.verbose:
            kind = "replay" if replaying else "admit"
            print(f"[engine] {kind} rid={rq.rid} -> slot {slot} "
                  f"(log={len(rq.log)})")

    def _admit_paged(self, rq: Request, slot: int, now_s: float, *,
                     interleave: bool) -> None:
        """Paged admission: reserve the full block budget, zero the blocks
        (bit-exactness: freed blocks may hold non-finite bytes), wire the
        block table, and start the prefill.  All pool writes here are
        out-of-step, so the touched blocks' digests are refreshed before
        the next engine step can check them."""
        nb = pgd.blocks_needed(len(rq.prompt), rq.max_new_tokens,
                               self.block_size)
        bids = self.alloc.allocate(slot, nb)   # may raise PoolSaturated
        pad = np.zeros((self.max_blocks,), np.int32)
        pad[:nb] = bids
        self.pool = self._fns["zero"](self.pool,
                                      self._dev(jnp.asarray(pad)))
        self._bt_np[slot] = 0
        self._bt_np[slot, :nb] = bids
        self.bt = self._dev(jnp.asarray(self._bt_np))
        self.slot_rid[slot] = rq.rid
        rq.slot = slot
        rq.state = "active"
        if rq.t_admit_s < 0:
            rq.t_admit_s = now_s
        self.report.admissions += 1
        self._prefilling[slot] = {"rq": rq, "off": 0}
        # zero-on-alloc scattered through the padded index vector, which
        # repeats scratch block 0 — refresh it along with the real blocks
        self._refresh_blocks(set(bids) | {0})
        if self.verbose:
            kind = "replay" if rq.log else "admit"
            print(f"[engine] {kind} rid={rq.rid} -> slot {slot} "
                  f"({nb} blocks {bids})")
        if not interleave:
            while slot in self._prefilling:
                self._prefill_step(slot)

    def _prefill_step(self, slot: int) -> None:
        """Advance one slot's in-progress prefill by one unit: the whole
        prompt (monolithic) or one ``prefill_chunk``-sized chunk.  The
        produced KV rows are span-scattered into the slot's blocks and
        those blocks' digests refreshed; the final unit activates the
        lane."""
        st = self._prefilling[slot]
        rq = st["rq"]
        off = st["off"]
        P = len(rq.prompt)
        bs = self.block_size
        bt_row = self.bt[slot]
        owned = self.alloc.owned(slot)
        if self.prefill_chunk <= 0:
            # monolithic: reuse the dense prefill executable, then span-
            # scatter its (padded-to-max_len) cache — paged-vs-dense
            # bit-exact prefill by construction
            batch = {"tokens": jnp.asarray(
                np.asarray(rq.prompt, np.int32)[None])}
            for k, v in rq.features.items():
                batch[k] = jnp.asarray(v)
            logits, sub = self._prefill(self.params, batch)
            if self._repl is not None:
                sub = jax.device_put(
                    sub, jax.tree_util.tree_map(lambda _: self._repl, sub))
            self.pool = self._fns["span"](self.pool, sub["groups"], bt_row,
                                          jnp.int32(0), jnp.int32(P))
            touched = set(owned[: -(-P // bs)])
            st["off"] = P
        else:
            C = self.prefill_chunk
            valid = min(C, P - off)
            tokens = np.zeros((1, C), np.int32)
            tokens[0, :valid] = np.asarray(rq.prompt, np.int32)[
                off:off + valid]
            self.pool, logits = self._fns["chunk"](
                self.params, self.pool, bt_row, jnp.asarray(tokens),
                jnp.int32(off), jnp.int32(valid))
            touched = set(owned[off // bs: -(-(off + valid) // bs)])
            st["off"] = off + valid
        # padded scatter lanes redirect to scratch block 0
        self._refresh_blocks(touched | {0})
        if st["off"] >= P:
            del self._prefilling[slot]
            self._activate(rq, slot, P, logits)

    def _activate(self, rq: Request, slot: int, P: int, logits) -> None:
        """Prefill finished: install the first decode input and flip the
        lane active (fixed-shape dynamic-slice writes — no retrace)."""
        if rq.log:
            # prefix replay: the log IS the RSI
            t0 = rq.log[0]
            rq.forced = deque(rq.log[1:])
            self.report.replay_tokens += len(rq.log) - 1
        else:
            t0 = int(np.argmax(np.asarray(logits)[0]))
            rq.log = [t0]
        self.pos, self.tok, self.amask = self._fns["activate"](
            self.pos, self.tok, self.amask, jnp.int32(P), jnp.int32(t0),
            jnp.int32(slot))
        if self.canary is not None:
            self.canary.refresh(self._view(), keys=[self._pos_keys[slot]])
        self._by_slot[slot] = rq

    def _free(self, slot: int) -> None:
        self._slot_history[slot] = self.slot_rid[slot]
        self.slot_rid[slot] = None
        self._by_slot.pop(slot, None)
        if self.paged:
            self._prefilling.pop(slot, None)
            self.alloc.free(slot)
            self._bt_np[slot] = 0
            self.bt = self._dev(jnp.asarray(self._bt_np))
            self.amask = self._fns["deact"](self.amask, jnp.int32(slot))

    def _finish(self, rq: Request, now_s: float, dropped: bool = False
                ) -> None:
        rq.state = "dropped" if dropped else "done"
        rq.t_done_s = now_s
        self.report.per_request[rq.rid] = {
            "arrival_s": rq.arrival_s,
            "t_admit_s": rq.t_admit_s,
            "t_first_s": rq.t_first_s,
            "t_done_s": now_s,
            "e2e_s": now_s - rq.arrival_s,
            "n_out": rq.n_out,
            "replays": rq.replays,
            "retracted": rq.retracted,
            "dropped": dropped,
            "tokens": list(rq.log[1:]),
        }
        if dropped:
            self.report.dropped += 1
        else:
            self.report.completed += 1

    def _accept(self, tokens: np.ndarray, now_s: float) -> None:
        """Fold one step's payload into the active requests."""
        for u in sorted(self._by_slot):
            rq = self._by_slot[u]
            if rq.forced:
                # forced replay output — already in the log (accounted
                # before the fault); the lane just rebuilt one token
                rq.forced.popleft()
                continue
            rq.log.append(int(tokens[u]))
            self.report.tokens_out += 1
            if rq.t_first_s < 0:
                rq.t_first_s = now_s
            if rq.done:
                self._finish(rq, now_s)
                self._free(u)

    def handle_fault(self, report: Optional[FaultReport],
                     finite: np.ndarray, now_s: float,
                     queue: RequestQueue) -> List[int]:
        """Slot-isolated recovery: evict injured slots to prefix replay.
        Returns the evicted slot ids."""
        rep = self.report
        rep.faults_detected += 1
        nf = [u for u in self._by_slot if not finite[u]]
        plan = plan_serving_recovery(report, n_slices=self.K,
                                     nonfinite_slots=nf)
        occupied = (sorted(set(self._by_slot) | set(self._prefilling))
                    if self.paged else sorted(self._by_slot))
        victims = occupied if plan.scope == "engine" else plan.slots
        refresh_blocks: set = set()
        if self.paged:
            # snapshot BEFORE the frees below return blocks to the pool:
            # the injured (and victim-owned) blocks keep their corrupt
            # bytes until the next zero-on-alloc, and their units must
            # not double-fire meanwhile
            if report is not None:
                refresh_blocks |= set(report.injured_blocks())
            for u in victims:
                refresh_blocks |= set(self.alloc.owned(u))
        any_dropped = False
        for u in victims:
            rq = self._by_slot.get(u)
            if rq is None and self.paged and u in self._prefilling:
                rq = self._prefilling[u]["rq"]
            if rq is None:
                # occupant already completed/evicted — the fault window
                # may have overlapped its live tokens: SDC-risk telemetry
                rep.faults_on_free_slots += 1
                continue
            n = plan.retract if plan.retract is not None else rq.n_out
            removed = rq.retract(n)
            rep.retracted_tokens += removed
            rep.tokens_out -= removed
            rq.replays += 1
            rq.t_evicted_s = now_s
            rep.injured_rids.add(rq.rid)
            self._free(u)
            if rq.replays > self.max_replays:
                self._finish(rq, now_s, dropped=True)
                any_dropped = True
            else:
                queue.requeue_front(rq)
            if self.verbose:
                print(f"[engine] FAULT step {self.step_count} slot {u} "
                      f"rid={rq.rid} ({plan.reason}) — retract {removed}, "
                      f"replaying {len(rq.log) - 1} tokens")
        if self.paged:
            if (plan.scope == "slots" and not victims
                    and report is not None):
                # attribution landed only on unowned pool blocks — a
                # free-block flip evicts nobody (SDC-risk telemetry only)
                rep.faults_on_free_slots += 1
            if self.canary is not None:
                self._refresh_blocks(refresh_blocks)
                for u in victims:
                    self.canary.refresh(self._view(),
                                        keys=[self._pos_keys[u]])
        elif self.canary is not None and victims:
            # re-certify every evicted lane against its CURRENT (corrupt-
            # lineage) bytes: the lane keeps decoding garbage until the
            # next admission overwrites it, and its units must not
            # double-fire meanwhile (fault path only — one digest launch)
            keys = [k for u in victims for k in self._slot_keys[u]]
            self.canary.refresh(slot_view(self.cache, self.S), keys=keys)
        if not any_dropped:
            rep.faults_recovered += 1
        return victims

    # -- fault injection (evaluation adversary) ----------------------------

    def corrupt_slot(self, rng, slot: Optional[int] = None,
                     key: Optional[str] = None, bit: Optional[int] = None,
                     armed_only: bool = False) -> Tuple[int, str, int]:
        """Flip one bit of one element inside one slot's lane (the paper's
        single-bit-flip model scoped to the slot axis).  Prefers active
        slots.  Returns (slot, leaf key, bit).

        ``armed_only=True`` restricts the target to a (leaf, slot) unit
        inside the canary's currently **protected at-rest window** — the
        units armed from the previous step's output and checked by the
        NEXT engine step.  A rotating K-slice canary is a sampling
        detector (a random at-rest flip is caught with probability ~1/K
        per step, exactly as in training); armed-window targeting models
        the covered case deterministically, which is what the SLO storm
        and the slot-isolation tests need.  Random mode measures raw
        coverage instead.
        """
        if self.paged:
            return self._corrupt_paged(rng, slot, key, bit, armed_only)
        active = [u for u in range(self.S) if self.slot_rid[u] is not None]
        if armed_only and self.canary is not None and key is None:
            cls = self.step_count % self.K
            def cands(pool):
                out = []
                for u_ in pool:
                    if slot is not None and u_ != slot:
                        continue
                    for k_ in self._slot_keys[u_]:
                        if self.plan.index_of(k_) % self.K == cls:
                            out.append((u_, k_.split("/", 1)[1]))
                return out
            pool = cands(active) or cands(range(self.S))
            if pool:
                u, key = pool[rng.randrange(len(pool))]
                slot = u
        u = slot if slot is not None else rng.choice(active or
                                                     list(range(self.S)))
        flat, treedef = jax.tree_util.tree_flatten_with_path(self.cache)
        catalog = [(i, leaf_key(p), x) for i, (p, x) in enumerate(flat)]
        if key is not None:
            picks = [c for c in catalog if c[1] == key]
            if not picks:
                raise KeyError(key)
            i, k, leaf = picks[0]
        else:
            sizes = [max(1, int(np.prod(x.shape[1:], dtype=np.int64)))
                     for _, _, x in catalog]
            total = sum(sizes)
            pick = rng.randrange(total)
            acc = 0
            for (i, k, leaf), sz in zip(catalog, sizes):
                acc += sz
                if pick < acc:
                    break
        per = max(1, int(np.prod(leaf.shape[1:], dtype=np.int64)))
        e = rng.randrange(per)
        width = _BIT_WIDTH.get(str(leaf.dtype), 32)
        b = bit if bit is not None else rng.randrange(width)
        leaves = [x for _, x in flat]
        leaves[i] = flip_bit(leaf, u * per + e, b)
        self.cache = jax.tree_util.tree_unflatten(treedef, leaves)
        self.report.faults_injected += 1
        rid = self.slot_rid[u]
        if rid is not None:
            self.report.injured_rids.add(rid)
        return u, k, b

    def corrupt_param(self, rng, key: Optional[str] = None,
                      bit: Optional[int] = None) -> Tuple[str, int]:
        """Flip one bit of one element of a parity-covered PARAM leaf —
        the at-rest weight-rot adversary `scrub_params` exists for.
        Preserves the leaf's device layout.  Returns (leaf key, bit)."""
        if self.parity_store is None:
            raise ValueError("corrupt_param requires parity=True")
        plan = self.parity_store.plan
        if key is None:
            key = plan.keys[rng.randrange(len(plan.keys))]
        leaves = dict(zip(plan.keys, plan.leaves(self.params)))
        leaf = leaves[key]
        size = max(1, int(np.prod(leaf.shape, dtype=np.int64)))
        e = rng.randrange(size)
        width = _BIT_WIDTH.get(str(leaf.dtype), 32)
        b = bit if bit is not None else rng.randrange(width)
        flipped = flip_bit(leaf, e, b)
        sharding = getattr(leaf, "sharding", None)
        if sharding is not None:
            flipped = jax.device_put(flipped, sharding)
        self.params = jax.tree_util.tree_map_with_path(
            lambda p, x: flipped if leaf_key(p) == key else x, self.params)
        self.report.faults_injected += 1
        return key, b

    def scrub_params(self) -> Dict:
        """At-rest integrity sweep over the params: verify every covered
        leaf against the load-time digests and XOR-reconstruct any
        injured shard from parity + survivors (no reload, no re-shard,
        O(bytes/D) moved).  Returns the scrub stats; repaired params are
        installed in place so subsequent decode steps use healthy
        weights."""
        if self.parity_store is None:
            raise ValueError("scrub_params requires parity=True")
        new_params, stats = self.parity_store.scrub(
            self.params, self._param_refs)
        if stats["repaired"]:
            self.params = new_params
            self.report.faults_detected += stats["repaired"]
            self.report.faults_recovered += stats["repaired"]
        stats["memory_bytes"] = self.parity_store.memory_bytes
        return stats

    def _owned_unit_keys(self, u: int) -> List[str]:
        """All canary plan keys a slot currently owns: its blocks' units
        plus its ``pos`` unit."""
        keys = [k for b in self.alloc.owned(u) for k in self._block_keys[b]]
        keys.append(self._pos_keys[u])
        return keys

    def _corrupt_paged(self, rng, slot, key, bit, armed_only
                       ) -> Tuple[int, str, int]:
        """Paged fault injector: the flip model is the same single-bit
        flip, but a 'slot' target is now the set of pool blocks the slot
        currently owns (plus its pos unit) — which is exactly the canary's
        (leaf, block) attribution granularity.  ``key`` accepts full plan
        keys (``blockNNNN/...`` or ``slotNNN/pos``) so tests can flip a
        specific — even unowned — block.  Returns (owning slot | -1,
        plan key, bit)."""
        active = [u for u in range(self.S) if self.slot_rid[u] is not None]
        if key is None:
            if armed_only and self.canary is not None:
                cls = self.step_count % self.K
                def cands(lanes):
                    out = []
                    for u_ in lanes:
                        if slot is not None and u_ != slot:
                            continue
                        for k_ in self._owned_unit_keys(u_):
                            if self.plan.index_of(k_) % self.K == cls:
                                out.append(k_)
                    return out
                picks = cands(active) or cands(range(self.S))
            else:
                lanes = ([slot] if slot is not None
                         else (active or list(range(self.S))))
                picks = [k_ for u_ in lanes
                         for k_ in self._owned_unit_keys(u_)]
            if not picks:
                picks = list(self._pos_keys)
            key = picks[rng.randrange(len(picks))]
        if key in self._pos_keys:
            u = self._pos_keys.index(key)
            b = bit if bit is not None else rng.randrange(32)
            self.pos = flip_bit(self.pos, u, b)
        else:
            blk = block_of_leaf(key)
            if blk is None:
                raise KeyError(key)
            rest = key.split("/", 1)[1]
            flat, treedef = jax.tree_util.tree_flatten_with_path(self.pool)
            for i, (p, x) in enumerate(flat):
                if leaf_key(p) == rest:
                    break
            else:
                raise KeyError(key)
            per = max(1, int(np.prod(x.shape[1:], dtype=np.int64)))
            e = rng.randrange(per)
            width = _BIT_WIDTH.get(str(x.dtype), 32)
            b = bit if bit is not None else rng.randrange(width)
            leaves = [lx for _, lx in flat]
            leaves[i] = flip_bit(x, blk * per + e, b)
            self.pool = jax.tree_util.tree_unflatten(treedef, leaves)
            u = self.alloc.owner.get(blk, -1)
        self.report.faults_injected += 1
        rid = self.slot_rid[u] if 0 <= u < self.S else None
        if rid is not None:
            self.report.injured_rids.add(rid)
        return u, key, b

    # -- driver ------------------------------------------------------------

    def run(self, requests: Sequence[Request], *, inject_every: int = 0,
            inject_rng=None, inject_armed_only: bool = True,
            clock=None) -> ServingReport:
        """Drive the engine until every request completes (or drops).

        ``inject_every`` > 0 runs the fault-storm adversary: one bit flip
        into a (preferably active) slot every N ACCEPTED tokens — by
        default into the canary's protected window (``inject_armed_only``;
        see ``corrupt_slot``), so every storm fault is detected and the
        recovery path is what gets measured.  Pinning the cadence to
        accepted tokens (not engine steps) keeps the storm survivable by
        construction: every fault is separated by N tokens of real
        progress, however long its replay takes.  ``clock`` overrides the
        engine clock (seconds; default: wall time since this call) — the
        SLO benchmark uses it for open-loop arrivals.
        """
        queue = RequestQueue(requests)
        rep = self.report
        rep.requests += len(requests)
        t_start = time.perf_counter()
        clock = clock or (lambda: time.perf_counter() - t_start)
        next_inject = rep.tokens_out + inject_every
        interleave = self.paged and self.prefill_chunk > 0
        while True:
            # admissions: fill free slots from the queue (iteration-level
            # scheduling — new requests enter every engine step)
            while True:
                free = self.free_slots()
                if not free:
                    break
                rq = queue.pop_ready(clock())
                if rq is None:
                    break
                evicted_at = rq.t_evicted_s
                try:
                    self.admit(rq, free[0], now_s=clock(),
                               interleave=interleave)
                except AdmissionError as err:
                    # permanent capacity overflow: typed rejection, not a
                    # silent cache overrun (and not a drop of anyone else)
                    rep.admission_rejected += 1
                    if self.verbose:
                        print(f"[engine] REJECT {err}")
                    self._finish(rq, clock(), dropped=True)
                    continue
                except PoolSaturated:
                    # transient block shortage: head-of-line waits for a
                    # running request to return its blocks
                    queue.requeue_front(rq)
                    break
                if evicted_at >= 0:
                    rep.recovery_ms.append(1e3 * (clock() - evicted_at))
                    rq.t_evicted_s = -1.0
            if self.paged and self._prefilling:
                # chunked prefill: one chunk per in-progress admission per
                # engine iteration, interleaved with the decode step below
                # so long prompts never stall the running batch
                for u in sorted(self._prefilling):
                    self._prefill_step(u)
            if not self._by_slot:
                if self.paged and self._prefilling:
                    continue
                nxt = queue.next_arrival()
                if nxt is None:
                    break
                # wait through the ENGINE clock: an injected (virtual)
                # clock supplies its own sleep, so idle waits advance
                # virtual time instead of busy-spinning wall time
                wait = max(0.0, nxt - clock())
                sleeper = getattr(clock, "sleep", None)
                (sleeper or time.sleep)(wait)
                continue

            if inject_every and rep.tokens_out >= next_inject:
                self.corrupt_slot(inject_rng, armed_only=inject_armed_only)
                next_inject = rep.tokens_out + inject_every

            t0 = time.perf_counter()
            tokens, finite, report = self.engine_step()
            rep.decode_ms.append(1e3 * (time.perf_counter() - t0))
            rep.engine_steps += 1
            now = clock()
            if report is not None or any(not finite[u]
                                         for u in self._by_slot):
                self.handle_fault(report, finite, now, queue)
            # healthy lanes keep the fault step's own tokens: lanes are
            # computationally independent, so a fault in slot u cannot
            # taint slot v's output
            self._accept(tokens, now)
        return rep
