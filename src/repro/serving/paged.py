"""Paged KV pool for the serving engine (DESIGN.md §6).

The dense engine stacks one ``[max_len]``-capacity decode cache per slot,
so every admitted request pays worst-case HBM no matter how short it is.
Here every cache leaf becomes a shared **block pool** —
``(n_blocks, block_size, count, KV, D)`` — plus a per-slot **block
table** ``(S, max_blocks)``: a request owns exactly
``ceil((len(prompt) + 1 + max_new_tokens) / block_size)`` blocks, and
admission is a block-budget decision (`BlockAllocator`).

Layout invariants the engine's resilience contract leans on:

* **Block 0 is scratch.**  Unallocated block-table entries and all masked
  scatter lanes point at it, so every data-movement op has a fixed shape
  regardless of how many blocks a slot really owns (0 retraces across
  alloc/free churn).  Its bytes are junk by design; nothing reads them —
  attention masks unwritten positions via ``cache_kpos`` — but the canary
  still digests it, so every out-of-step write that can touch it (any
  admission scatter) must be followed by a block-0 digest refresh.
* **Blocks are zeroed on allocation** (`zero_blocks`): a freed block may
  hold non-finite bytes from an evicted/poisoned sequence, and a masked
  attention weight times Inf/NaN is NaN — zeroing keeps masked garbage
  exactly 0-weighted (the bit-exactness chain in DESIGN.md §6).
* **The hot-path gather is a pure copy** (`kernels/paged_kv.py`): the
  vmapped decode step runs *unmodified* on the gathered per-slot view,
  which is what makes paged-vs-dense bit-exactness hold by construction.

The canary view (`paged_canary_view`) digests the pool at (leaf, block)
granularity plus a per-slot ``pos`` unit; `block → owning slot` is a host
lookup in the allocator, so a fault injures *blocks* and only transitively
the slot that owns them — a flip on a free block evicts nobody.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.detect import block_view, slot_view
from repro.kernels.paged_kv import gather_blocks

tree_map = jax.tree_util.tree_map


class AdmissionError(ValueError):
    """Request can never be admitted: its worst-case KV footprint
    (``len(prompt) + 1 + max_new_tokens`` positions) exceeds the engine's
    per-slot budget (dense: ``max_len``; paged: ``max_blocks`` blocks) or
    the whole pool.  Permanent — retrying cannot help."""


class PoolSaturated(RuntimeError):
    """Transient block shortage: the request fits the per-slot budget but
    the pool's free list is currently too short.  Retry after a running
    request completes and returns its blocks."""


def blocks_needed(prompt_len: int, max_new_tokens: int,
                  block_size: int) -> int:
    """Worst-case block count for a request: every prompt position, every
    generated token, and the one-past-the-end write slot."""
    need = prompt_len + 1 + max_new_tokens
    return -(-need // block_size)


class BlockAllocator:
    """Host-side free-list allocator over the shared pool.

    Block 0 is reserved as scratch and never handed out.  Allocation and
    free order are deterministic (LIFO free list) so seeded runs admit
    identical block tables — the serving reproducibility tests depend on
    it.  ``owner`` maps physical block id → owning slot; the canary's
    fault path uses it to translate (leaf, block) attribution into the
    slot to evict (or into "free block, nobody to evict")."""

    def __init__(self, n_blocks: int):
        if n_blocks < 2:
            raise ValueError("pool needs >= 2 blocks (block 0 is scratch)")
        self.n_blocks = n_blocks
        self._free: List[int] = list(range(n_blocks - 1, 0, -1))
        self._owned: Dict[int, List[int]] = {}
        self.owner: Dict[int, int] = {}

    @property
    def capacity(self) -> int:
        return self.n_blocks - 1

    @property
    def free_count(self) -> int:
        return len(self._free)

    def allocate(self, slot: int, n: int) -> List[int]:
        if slot in self._owned:
            raise ValueError(f"slot {slot} already owns blocks")
        if n > len(self._free):
            raise PoolSaturated(
                f"need {n} blocks, {len(self._free)} free "
                f"(pool capacity {self.capacity})")
        blocks = [self._free.pop() for _ in range(n)]
        self._owned[slot] = blocks
        for b in blocks:
            self.owner[b] = slot
        return blocks

    def free(self, slot: int) -> List[int]:
        blocks = self._owned.pop(slot, [])
        for b in blocks:
            del self.owner[b]
        self._free.extend(reversed(blocks))
        return blocks

    def owned(self, slot: int) -> List[int]:
        return list(self._owned.get(slot, ()))


# ---------------------------------------------------------------------------
# Pool construction and data movement
#
# Shape conventions (B=1 per slot throughout):
#   per-slot cache leaf (dense layout) : (count, 1, cap, KV, D)
#   pool leaf                          : (n_blocks, block_size, count, KV, D)
#   gathered per-slot view             : (S, count, 1, cap, KV, D)
# with cap = max_blocks * block_size == max_len (rounded up by the engine).
# ---------------------------------------------------------------------------

def paged_supported(model, model_cfg, per_slot, max_len: int) -> bool:
    """Can this family's decode cache be paged?  Requires the chunk-prefill
    entry point, linear (non-ring) per-position caches of exactly
    ``max_len`` capacity, and 1-D rope (no m-rope / patch inputs)."""
    if getattr(model, "prefill_chunk", None) is None:
        return False
    if getattr(model_cfg, "m_rope", False) or getattr(model_cfg, "patch_dim", 0):
        return False
    if not (isinstance(per_slot, dict) and set(per_slot) == {"groups", "pos"}):
        return False
    leaves = jax.tree_util.tree_leaves(per_slot["groups"])
    return bool(leaves) and all(
        l.ndim == 5 and l.shape[1] == 1 and l.shape[2] == max_len
        for l in leaves)


def make_block_pool(per_slot, n_blocks: int, block_size: int):
    """Block-major pool from a per-slot dense cache template (B=1)."""
    def pool_leaf(l):
        count = l.shape[0]
        feat = l.shape[3:]
        return jnp.zeros((n_blocks, block_size, count) + feat, l.dtype)
    return {"groups": tree_map(pool_leaf, per_slot["groups"])}


def gathered_cache(pool, bt, pos, *, interpret=None):
    """Materialise the dense slot-major cache view the vmapped decode step
    expects, via the Pallas block gather (one DMA program per
    (slot, logical block)).

    Rows at positions >= ``pos[s]`` are zeroed: block-table padding points
    at scratch block 0, whose bytes can be non-finite (inactive lanes
    scatter junk there), and a masked attention weight of exactly 0.0
    times NaN is NaN.  The dense cache keeps those rows as exact zeros
    (prefill zero-padding), so zeroing here is what makes the gathered
    view bit-identical to the dense one."""
    def g(leaf):
        out = gather_blocks(leaf, bt, interpret=interpret)
        S, mb, bs, count = out.shape[:4]
        feat = out.shape[4:]
        out = out.reshape((S, mb * bs, count) + feat)
        valid = jnp.arange(mb * bs, dtype=jnp.int32)[None, :] < pos[:, None]
        out = jnp.where(
            valid.reshape((S, mb * bs) + (1,) * (len(feat) + 1)),
            out, jnp.zeros((), out.dtype))
        out = jnp.moveaxis(out, 1, 2)       # (S, count, cap, *feat)
        return out[:, :, None]              # (S, count, 1, cap, *feat)
    return {"groups": tree_map(g, pool["groups"]), "pos": pos}


def scatter_token(pool, ngroups, bt, pos, amask, block_size: int):
    """Write each active lane's newly decoded cache row back to the pool.

    ngroups: the post-decode gathered view's groups (leaves
    (S, count, 1, cap, *feat)) — the row at position ``pos[s]`` is the
    only one the decode step changed.  Inactive lanes redirect to scratch
    block 0 (fixed-shape scatter; no retrace as lanes come and go)."""
    bs = block_size
    mb = bt.shape[1]
    S = pos.shape[0]
    p = jnp.clip(pos, 0, mb * bs - 1)
    bl = jnp.clip(p // bs, 0, mb - 1)
    bids = jnp.where(amask, jnp.take_along_axis(bt, bl[:, None], axis=1)[:, 0],
                     0)
    offs = jnp.where(amask, p % bs, 0)

    def upd(pool_leaf, nl):
        x = nl[:, :, 0]                     # (S, count, cap, *feat)
        idx = p.reshape((S,) + (1,) * (x.ndim - 1))
        vals = jnp.take_along_axis(x, idx, axis=2)[:, :, 0]
        return pool_leaf.at[bids, offs].set(vals.astype(pool_leaf.dtype))

    return {"groups": tree_map(upd, pool["groups"], ngroups)}


def scatter_span(pool, new_kv_groups, bt_row, start, valid, block_size: int):
    """Scatter a prefilled span (positions ``start .. start+valid-1``) of
    one slot into the pool.  new_kv_groups leaves: (count, 1, C, *feat).
    Rows past ``valid`` redirect to scratch block 0."""
    bs = block_size
    mb = bt_row.shape[0]

    def upd(pool_leaf, nl):
        C = nl.shape[2]
        j = start + jnp.arange(C, dtype=jnp.int32)
        ok = jnp.arange(C, dtype=jnp.int32) < valid
        bl = jnp.clip(j // bs, 0, mb - 1)
        bids = jnp.where(ok, bt_row[bl], 0)
        offs = jnp.where(ok, j % bs, 0)
        x = jnp.moveaxis(nl[:, 0], 1, 0)    # (C, count, *feat)
        return pool_leaf.at[bids, offs].set(x.astype(pool_leaf.dtype))

    return {"groups": tree_map(upd, pool["groups"], new_kv_groups)}


def zero_blocks(pool, bids):
    """Zero the pool rows of the given physical blocks (padded index
    vectors repeat block 0 — harmless, it's scratch)."""
    def z(leaf):
        zeros = jnp.zeros((bids.shape[0],) + leaf.shape[1:], leaf.dtype)
        return leaf.at[bids].set(zeros)
    return {"groups": tree_map(z, pool["groups"])}


def ctx_from_pool(pool, bt_row, block_size: int, pos0=None):
    """One slot's context in dense cache layout (admission path — plain
    jnp gather, not the hot-path kernel).  Returns groups with leaves
    (count, 1, cap, *feat).  ``pos0`` (traced int32) zeroes rows at
    positions >= pos0 — same non-finite-scratch guard as
    ``gathered_cache``."""
    def g(leaf):
        t = jnp.take(leaf, bt_row, axis=0)  # (mb, bs, count, *feat)
        mb, bs, count = t.shape[:3]
        t = t.reshape((mb * bs, count) + t.shape[3:])
        if pos0 is not None:
            valid = jnp.arange(mb * bs, dtype=jnp.int32) < pos0
            t = jnp.where(valid.reshape((mb * bs,) + (1,) * (t.ndim - 1)),
                          t, jnp.zeros((), t.dtype))
        t = jnp.moveaxis(t, 0, 1)           # (count, cap, *feat)
        return t[:, None]                   # (count, 1, cap, *feat)
    return {"groups": tree_map(g, pool["groups"])}


def ctx_kpos(pos0, cap: int):
    """Absolute key positions of a linear context of ``cap`` rows of which
    the first ``pos0`` are written (<0 = unwritten, masked)."""
    j = jnp.arange(cap, dtype=jnp.int32)
    return jnp.where(j < pos0, j, -1)[None, :]


def paged_canary_view(pool, pos, n_blocks: int, n_slots: int):
    """Digest view: (leaf, block) units over the pool + a per-slot ``pos``
    unit.  Block tables / activity masks / last-token buffers stay
    uncovered control plane (host-rebuildable, like the dense engine's
    token buffer)."""
    view = block_view(pool, n_blocks)
    view.update(slot_view({"pos": pos}, n_slots))
    return view
