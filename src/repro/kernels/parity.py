"""Pallas TPU kernel: XOR parity fold / single-shard reconstruction.

The ICP analogue for *sharded* state (DESIGN.md §4.2): a parity shard is the
manufactured independent partner.  XOR is bit-exact — reconstruction returns
the lost shard's exact bits, so the exact-or-abort rule holds with no
floating-point caveats.  The fold walks the replica axis in VMEM-resident
(256, 128) int32 tiles.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
TILE_ROWS = 256


def _xor_fold_kernel(x_ref, out_ref):
    """x_ref: (R, 1, TILE_ROWS, LANES) — all R replicas of one tile."""
    x = x_ref[:, 0, :, :]
    R = x.shape[0]
    acc = x[0]
    for r in range(1, R):
        acc = acc ^ x[r]
    out_ref[0] = acc


def xor_fold_tiles(x, *, interpret: bool = True):
    """x: (R, nt, TILE_ROWS, LANES) int32 -> parity (nt, TILE_ROWS, LANES)."""
    R, nt = x.shape[0], x.shape[1]
    return pl.pallas_call(
        _xor_fold_kernel,
        grid=(nt,),
        in_specs=[pl.BlockSpec((R, 1, TILE_ROWS, LANES),
                               lambda i: (0, i, 0, 0))],
        out_specs=pl.BlockSpec((1, TILE_ROWS, LANES), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nt, TILE_ROWS, LANES), jnp.int32),
        interpret=interpret,
    )(x)


def _xor_update_kernel(x_ref, p_ref, out_ref):
    """x_ref: (D, 1, TILE_ROWS, LANES) deltas; p_ref: the parity tile."""
    acc = p_ref[0]
    for d in range(x_ref.shape[0]):
        acc = acc ^ x_ref[d, 0]
    out_ref[0] = acc


def xor_update_tiles(x, parity, *, interpret: bool = True):
    """Incremental parity update: ``parity ^ XOR_d x[d]``.

    ``x``: (D, nt, TILE_ROWS, LANES) int32 per-shard delta tiles
    (``old_shard XOR new_shard``), ``parity``: (nt, TILE_ROWS, LANES)
    int32 — the live parity rides the launch in place
    (``input_output_aliases``), so the steady-state update allocates
    nothing.  ``xor_update_tiles(x, zeros)`` is a rebuild-from-scratch
    fold, which is what makes incremental == rebuild testable bit-exactly
    (XOR is associative/commutative with identity 0).
    """
    D, nt = x.shape[0], x.shape[1]
    return pl.pallas_call(
        _xor_update_kernel,
        grid=(nt,),
        in_specs=[pl.BlockSpec((D, 1, TILE_ROWS, LANES),
                               lambda i: (0, i, 0, 0)),
                  pl.BlockSpec((1, TILE_ROWS, LANES), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, TILE_ROWS, LANES), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nt, TILE_ROWS, LANES), jnp.int32),
        input_output_aliases={1: 0},
        interpret=interpret,
    )(x, parity)
