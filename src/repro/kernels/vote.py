"""Pallas TPU kernel: bitwise triple-modular-redundancy majority vote.

Repairs a corrupted replicated leaf from three synchronously-updated copies
(the tensor-level "partner induction variables" of DESIGN.md §4.2): each
output bit is the majority of the three input bits, so any single-copy
corruption — of any width, on any element — is erased.  Pure VPU bit-ops at
HBM bandwidth; tiles mirror the checksum kernel's (256, 128) int32 layout.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
TILE_ROWS = 256


def _vote_kernel(a_ref, b_ref, c_ref, out_ref):
    a = a_ref[0]
    b = b_ref[0]
    c = c_ref[0]
    out_ref[0] = (a & b) | (a & c) | (b & c)


def vote3_tiles(a, b, c, *, interpret: bool = True):
    """a/b/c: (nt, TILE_ROWS, LANES) int32 -> majority (nt, TILE_ROWS, LANES)."""
    nt = a.shape[0]
    spec = pl.BlockSpec((1, TILE_ROWS, LANES), lambda i: (i, 0, 0))
    return pl.pallas_call(
        _vote_kernel,
        grid=(nt,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, jnp.int32),
        interpret=interpret,
    )(a, b, c)
