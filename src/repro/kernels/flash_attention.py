"""Pallas TPU kernel: flash attention (causal / sliding-window / GQA).

The perf-critical compute hot spot of every assigned LM architecture.  The
baseline materialises (B, H, Sq, Sk) fp32 scores (fine at 4k, impossible at
32k+); this kernel streams KV blocks through VMEM with online softmax so
live memory is O(block_q x block_k) per core.

TPU mapping
-----------
* grid = (B*H, Sq/bq, Sk/bk) — the innermost axis is ARBITRARY-ordered
  revisiting of the same output block: m/l/acc live in VMEM scratch and the
  output block is written once on the last KV block.
* BlockSpecs tile (1, bq, D) of q / (1, bk, D) of kv into VMEM; with
  bq = bk = 512 and D = 128 the working set is
  q 128 KiB + k/v 256 KiB + acc 256 KiB f32 « 16 MiB VMEM.
* matmul dims (bq, D)x(D, bk): D is a multiple of 128 for every assigned
  arch except gemma3-1b (256) and kimi (112->pad 128) — the ops wrapper
  pads D to 128 alignment so the MXU tiles cleanly.
* GQA: the kv block index is derived from the flattened (b*H + h) program
  id inside the index_map — no kv duplication in HBM.
* causal + window masks are computed from block-local iotas; fully-masked
  blocks still run (grid is static) but @pl.when skips their FLOPs.

Validated in interpret mode against ``ref.flash_attention_ref`` over a
shape/dtype sweep (tests/test_kernels.py).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512
_NEG_INF = -2.0**30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int, seq_k: int,
                  block_q: int, block_k: int, softcap: float):
    iq = pl.program_id(1)
    jk = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = jk * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    live = k_pos < seq_k                    # kv padding
    if causal:
        live &= q_pos >= k_pos
    if window:
        live &= (q_pos - k_pos) < window

    # skip fully-masked blocks (causal upper triangle / outside the window)
    block_live = True
    if causal:
        block_live = (jk * block_k) <= (iq * block_q + block_q - 1)
    if window:
        # newest key this q block can see is q_max; oldest is q_min-window+1
        block_live = block_live & (
            (jk * block_k + block_k - 1) > (iq * block_q - window))

    @pl.when(block_live)
    def _body():
        q = q_ref[0].astype(jnp.float32)     # (bq, D)
        k = k_ref[0].astype(jnp.float32)     # (bk, D)
        v = v_ref[0].astype(jnp.float32)     # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        s = jnp.where(live, s, _NEG_INF)

        m_prev = m_ref[...]                  # (bq,)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])      # (bq, bk)
        corr = jnp.exp(m_prev - m_new)       # (bq,)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(jk == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-37)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, window: int = 0,
                         softcap: float = 0.0, block_q: int = DEFAULT_BLOCK_Q,
                         block_k: int = DEFAULT_BLOCK_K,
                         interpret: bool = True):
    """q (BH, Sq, D), k/v (BKV, Sk, D) pre-padded to block/lane multiples;
    BH = B*H and BKV = B*KV flattened.  Returns o (BH, Sq, D)."""
    BH, Sq, D = q.shape
    BKV, Sk, _ = k.shape
    G = BH // BKV                      # q heads per kv head (within a batch)
    nq = Sq // block_q
    nk = Sk // block_k
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        seq_k=Sk, block_q=block_q, block_k=block_k, softcap=softcap)

    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b // G, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # m (running max)
            pltpu.VMEM((block_q,), jnp.float32),      # l (running denom)
            pltpu.VMEM((block_q, D), jnp.float32),    # acc (unnormalised o)
        ],
        interpret=interpret,
    )(q, k, v)
