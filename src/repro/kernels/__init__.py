"""Pallas TPU kernels for the IterPro detection/redundancy hot path.

checksum — blocked Fletcher digest (the ~free canary detector)
digest   — fused single-launch whole-state digesting (DigestPlan: one
           pallas_call + one host sync per canary check, DESIGN.md §4.2)
vote     — bitwise TMR majority across replicas (replica repair)
parity   — XOR parity fold / reconstruction (manufactured redundancy)

Each kernel: <name>.py (pl.pallas_call + explicit BlockSpec VMEM tiling),
with jit'd wrappers in ops.py and pure-jnp oracles in ref.py.  All
algorithms are bitwise/integer — tests assert bit-exact equality.
Kernels run compiled on TPU, interpret=True elsewhere.
"""

from repro.kernels import digest, ops, ref  # noqa: F401
from repro.kernels.digest import DigestPlan, plan_for  # noqa: F401
