"""Pallas TPU kernels for the IterPro detection/redundancy hot path.

checksum — blocked Fletcher digest (the ~free canary detector)
vote     — bitwise TMR majority across replicas (replica repair)
parity   — XOR parity fold / reconstruction (manufactured redundancy)

Each kernel: <name>.py (pl.pallas_call + explicit BlockSpec VMEM tiling),
with jit'd wrappers in ops.py and pure-jnp oracles in ref.py.  All
algorithms are bitwise/integer — tests assert bit-exact equality.
Kernels run compiled on TPU, interpret=True elsewhere.
"""

from repro.kernels import ops, ref  # noqa: F401
