"""Fused single-launch state digesting — the DigestPlan engine.

The paper's headline economics (~0% no-fault overhead) require detection to
cost one HBM-bandwidth streaming pass.  The seed implementation dispatched
one jit'd ``checksum`` per pytree leaf and forced a device→host sync per
leaf per step — O(leaves) kernel launches and blocking transfers on the
no-fault hot path.  This module replaces that with (DESIGN.md §4.2):

* **DigestPlan** — computed once per state *structure* (treedef + leaf
  shapes/dtypes) and cached: a flat int32 packing layout where every leaf
  occupies a private, row-aligned (128-element / 512 B) range of a single
  buffer — dense enough that a state with hundreds of small leaves packs
  to ~its own size, not 128 KiB per leaf — plus the row→leaf segment map
  and per-row offset table the combine needs.
* **one Pallas launch** per digest: all selected leaves are packed into
  one (nt, TILE_ROWS, LANES) buffer and digested by a single
  ``row_checksums`` pallas_call; per-leaf digests are exact segment sums
  of the per-row partials (int32 wraparound arithmetic, so the result is
  bit-identical to per-leaf ``ops.checksum``).
* **device-side comparison** — consumers keep an on-device reference
  digest table (n_leaves, 2) and compare tables on device, fetching one
  scalar "any mismatch?" flag per check.  Leaf attribution via the
  leaf-index→path map happens only on the slow (fault) path.
* **persistent packing buffer** — each (plan, leaf-subset) owns ONE
  packing buffer for the lifetime of the plan.  The pack step is a Pallas
  kernel with ``input_output_aliases`` (``checksum.pack_rows``) and every
  jitted digest donates the buffer back into itself, so a steady-state
  digest makes zero new device allocations: the same HBM range is
  rewritten in place every step (donation-safe hot path; DESIGN.md §4.2).
* **host digest path** — ``host_checksum``/``host_tree_checksums`` compute
  the same Fletcher digests in numpy uint32 wraparound arithmetic,
  bit-identical to the kernel, so micro-snapshot host DMA copies are
  certified without re-uploading a byte to the device.
* **digest as traceable subcomputation** — ``DigestPlan.digest_fn`` and
  ``check_arm_subcomputation`` return PURE functions whose only host-side
  work (plan lookup, row maps, offsets) happens at build/trace time: the
  traced path carries no dict lookups, so callers can embed a digest
  inside their own jitted program.  ``core/fused_step.py`` uses this to
  run the canary check+arm INSIDE the jitted (donated) training step.

Launch/sync/byte contract per detection mode, for state of ``B`` bytes,
canary period ``K`` and mesh size ``D`` (the DESIGN.md §4.2/§5 cost
table in code form; D=1 off-mesh):

  ===================  ========  =============  ==================
  mode                 launches  host syncs     bytes/step
  ===================  ========  =============  ==================
  per-leaf (seed)      O(L/K)    O(L/K)         ~2B/K
  fused check_and_arm  1         1 scalar       ~2B/K
  donated pair         2         1 scalar       ~2B/K
  in-step fused        0 extra*  1 scalar       ~2B/K
  sharded (any mode)   same      same 1 scalar  ~2B/K (÷D per dev)
  ===================  ========  =============  ==================

  *the in-step fused mode rides the step's own launch: the digest is a
  subcomputation of the jitted step (``core/fused_step.py``), so the
  no-fault hot path is 1 combined launch/step total — counted as one
  ``STATS.launches`` — at the cost of K rotation-specialised step
  executables.

Mesh sharding (``ShardedDigestPlan``/``sharded_plan_for``; DESIGN.md §5)
changes the *placement* of the work, not the contract: under shard_map
every device packs and digests only its addressable shard rows against
its own slice of the sharded (n_shards, L, 2) reference tables, and the
single scalar the host fetches is the all-reduced any(fault) flag — the
only cross-device communication on the no-fault path.  Shard digests are
bit-identical to the single-device ``host_checksum`` oracle applied to
each shard's bytes (``host_shard_checksums``).

Instrumentation: ``STATS`` counts launches (one per digest invocation —
each digest is one in-place pack + one ``row_checksums`` pallas_call,
counted as a single fused launch; the in-step fused mode counts its one
combined step+digest dispatch), host syncs (every device→host fetch in
this module and in the canary goes through ``fetch``), and traces
(incremented inside traced bodies, so a plan-cache hit provably does not
retrace).  The host digest path touches no device and counts nothing.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.ops import segment_sum
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.kernels import checksum as _ck
from repro.kernels import ref as _ref

LANES = _ck.LANES
TILE_ROWS = _ck.TILE_ROWS


# ---------------------------------------------------------------------------
# instrumentation
# ---------------------------------------------------------------------------

@dataclass
class DigestStats:
    """Hot-path accounting for the detection-cost model (DESIGN.md §4.2)."""
    launches: int = 0   # fused digest invocations (== pallas launches)
    syncs: int = 0      # device→host transfers
    traces: int = 0     # jit traces of digest functions (cache misses)

    def reset(self) -> None:
        self.launches = self.syncs = self.traces = 0

    def snapshot(self) -> Tuple[int, int, int]:
        return (self.launches, self.syncs, self.traces)


STATS = DigestStats()


def fetch(x) -> np.ndarray:
    """The ONLY device→host crossing in the digest subsystem — counted."""
    STATS.syncs += 1
    return np.asarray(x)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# plan
# ---------------------------------------------------------------------------

def leaf_key(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


@dataclass(frozen=True)
class LeafSpec:
    key: str
    index: int          # position in the plan's canonical (sorted-key) order
    size: int           # int32 elements (== element count; to_i32 is 1:1)
    n_rows: int         # row-aligned footprint: max(1, ceil(size/LANES))


class DigestPlan:
    """Packing layout + compiled digest functions for one state structure.

    The canonical leaf order is sorted-by-path (stable across runs and
    matching the rotating-canary slice assignment).  Every compiled
    function in a plan contains exactly one pallas_call.
    """

    def __init__(self, treedef, keys: Tuple[str, ...],
                 sizes: Tuple[int, ...]):
        self.treedef = treedef
        self.keys = keys                       # sorted
        self.specs = tuple(
            LeafSpec(key=k, index=i, size=s,
                     n_rows=max(1, -(-s // LANES)))
            for i, (k, s) in enumerate(zip(keys, sizes)))
        self.n_leaves = len(keys)
        self.n_rows = sum(sp.n_rows for sp in self.specs)
        self.n_tiles = -(-self.n_rows // TILE_ROWS)
        self.bytes_per_pass = self.n_tiles * TILE_ROWS * LANES * 4
        self._key_to_index = {k: i for i, k in enumerate(keys)}
        self._digest_fns: Dict[Tuple[int, ...], object] = {}
        # donation-safe steady state: one jitted (donating) digest and one
        # persistent packing buffer per leaf subset
        self._jitted_fns: Dict[Tuple[int, ...], object] = {}
        self._pack_bufs: Dict[Tuple[int, ...], jnp.ndarray] = {}
        # permutation from tree_flatten_with_path order -> sorted-key order
        self._order: Optional[List[int]] = None

    # -- leaf extraction ---------------------------------------------------

    def leaves(self, tree) -> List:
        """Tree leaves in the plan's canonical (sorted-key) order.

        Rejects trees whose structure differs from the plan's — a renamed
        or moved leaf must never be silently digested against another
        leaf's reference row."""
        flat, treedef = jax.tree_util.tree_flatten(tree)
        if treedef != self.treedef:
            raise ValueError(
                f"tree structure does not match DigestPlan: got {treedef}, "
                f"plan was built for {self.treedef}")
        if self._order is None:
            with_path = jax.tree_util.tree_flatten_with_path(tree)[0]
            paths = [leaf_key(p) for p, _ in with_path]
            self._order = sorted(range(len(paths)), key=lambda i: paths[i])
        return [flat[i] for i in self._order]

    def index_of(self, key: str) -> int:
        return self._key_to_index[key]

    # -- compiled digest over a leaf subset --------------------------------

    def digest_fn(self, indices: Optional[Sequence[int]] = None):
        """Traced digest core ``(pack_buf, leaves_subset) -> (pack_buf,
        (len(indices), 2) int32 table)``.

        ``indices`` selects plan leaves (canonical order); None = all.
        The returned function is pure/traceable: callers embed it in their
        own jit and donate the packing buffer at THEIR jit boundary (the
        canary does; ``digest_table`` below wraps it for direct use).
        Cached per subset, so the hot path never retraces.
        """
        idx = tuple(range(self.n_leaves)) if indices is None \
            else tuple(indices)
        fn = self._digest_fns.get(idx)
        if fn is None:
            fn = self._build_digest_fn(idx)
            self._digest_fns[idx] = fn
        return fn

    def _build_digest_fn(self, idx: Tuple[int, ...]):
        specs = [self.specs[i] for i in idx]
        n_rows = sum(sp.n_rows for sp in specs)
        padded_rows = -(-n_rows // TILE_ROWS) * TILE_ROWS
        nt = padded_rows // TILE_ROWS
        # row→leaf segment map; pad/fill rows stay all-zero for the life of
        # the persistent buffer so they contribute nothing to whichever
        # segment they land in (use 0)
        seg_ids = np.zeros(padded_rows, np.int32)
        offsets = np.zeros(padded_rows, np.int32)
        starts: List[int] = []     # element offset of each leaf in the buf
        r = 0
        for j, sp in enumerate(specs):
            seg_ids[r:r + sp.n_rows] = j
            # each row's element offset within its leaf, for the exact
            # Fletcher combine: Σ(off+j)·x = off·Σx + Σj·x (mod 2^32)
            offsets[r:r + sp.n_rows] = \
                np.arange(sp.n_rows, dtype=np.int32) * np.int32(LANES)
            starts.append(r * LANES)
            r += sp.n_rows
        n_seg = len(specs)

        def digest(buf, leaves):
            STATS.traces += 1          # trace-time only: counts cache misses
            # in-place row-aligned packing into the persistent buffer: only
            # the leaf ranges are written (fill/tail rows are permanently
            # zero), and input_output_aliases + caller donation make the
            # write allocation-free in steady state
            flats = [_ref.to_i32(leaf) for leaf in leaves]
            buf = _ck.pack_rows(buf, flats, starts, interpret=_interpret())
            d = _ck.row_checksums(buf.reshape(nt, TILE_ROWS, LANES),
                                  interpret=_interpret()) \
                .reshape(padded_rows, 2)
            seg = jnp.asarray(seg_ids)
            s1 = segment_sum(d[:, 0], seg, num_segments=n_seg)
            s2 = segment_sum(d[:, 1] + jnp.asarray(offsets) * d[:, 0],
                             seg, num_segments=n_seg)
            return buf, jnp.stack([s1, s2], axis=1)

        return digest

    # -- persistent packing buffers ----------------------------------------

    def take_buffer(self, indices: Optional[Sequence[int]] = None
                    ) -> jnp.ndarray:
        """The subset's packing buffer, to be donated into a digest call;
        pair with ``put_buffer`` on the returned alias.  A take/put pair
        REGISTERS the subset as hot-path-persistent (the canary's
        rotating slices); subsets digested via ``digest_table``/
        ``digest_subset`` without prior registration stay transient, so
        off-hot-path full-state digests do not pin packed-state HBM."""
        idx = tuple(range(self.n_leaves)) if indices is None \
            else tuple(indices)
        buf = self._pack_bufs.get(idx)
        if buf is None or buf.is_deleted():
            n_rows = sum(self.specs[i].n_rows for i in idx)
            padded = -(-n_rows // TILE_ROWS) * TILE_ROWS * LANES
            buf = jnp.zeros((padded,), jnp.int32)
            self._pack_bufs[idx] = buf
        return buf

    def put_buffer(self, indices: Optional[Sequence[int]],
                   buf: jnp.ndarray) -> None:
        """Store the donated-through buffer back as the subset's live one."""
        idx = tuple(range(self.n_leaves)) if indices is None \
            else tuple(indices)
        self._pack_bufs[idx] = buf

    def buffer_pointer(self, indices: Optional[Sequence[int]] = None):
        """Device address of the subset's packing buffer (None before first
        use) — the benchmark's steady-state buffer-reuse probe."""
        idx = tuple(range(self.n_leaves)) if indices is None \
            else tuple(indices)
        buf = self._pack_bufs.get(idx)
        return None if buf is None else buf.unsafe_buffer_pointer()

    def _jitted_digest(self, idx: Tuple[int, ...]):
        fn = self._jitted_fns.get(idx)
        if fn is None:
            fn = jax.jit(self.digest_fn(idx), donate_argnums=(0,))
            self._jitted_fns[idx] = fn
        return fn

    def _run(self, idx: Tuple[int, ...], leaves) -> jnp.ndarray:
        STATS.launches += 1
        # persist the packing buffer only for subsets the hot path has
        # registered via take/put (the canary's rotating slices): the
        # off-hot-path full-state digests (snapshot certification, canary
        # init/refresh) would otherwise pin ~1x packed-state HBM for the
        # plan's lifetime — eating the very saving donation buys
        persist = idx in self._pack_bufs
        buf, table = self._jitted_digest(idx)(self.take_buffer(idx), leaves)
        if persist:
            self.put_buffer(idx, buf)
        else:
            del self._pack_bufs[idx]
        return table

    # -- public digesting --------------------------------------------------

    def digest_table(self, tree) -> jnp.ndarray:
        """(n_leaves, 2) int32 digest table, on device.  ONE fused launch
        (in-place pack + row digest), zero host syncs — the replacement
        for per-leaf ``checksum``.  The packing buffer persists (and the
        call is allocation-free) only for hot-path-registered subsets;
        see ``take_buffer``."""
        idx = tuple(range(self.n_leaves))
        return self._run(idx, self.leaves(tree))

    def digest_subset(self, tree, indices: Sequence[int]) -> jnp.ndarray:
        """(len(indices), 2) digest table for the selected leaves — one
        launch covering only those leaves' tiles (the rotating-canary read
        slice)."""
        idx = tuple(indices)
        if not idx:
            return jnp.zeros((0, 2), jnp.int32)
        leaves = self.leaves(tree)
        return self._run(idx, [leaves[i] for i in idx])

    def digest_dict(self, tree) -> Dict[str, np.ndarray]:
        """Host-side per-leaf digests: one launch + ONE transfer (the seed
        paid one launch and one transfer per leaf)."""
        table = fetch(self.digest_table(tree))
        return {k: table[i] for i, k in enumerate(self.keys)}

    def verify(self, tree, reference: Dict[str, np.ndarray]) -> List[str]:
        """Leaf paths whose digest no longer matches ``reference`` — one
        launch + one transfer; used by snapshot/rung verification."""
        current = self.digest_dict(tree)
        bad = []
        for k, ref_digest in reference.items():
            cur = current.get(k)
            if cur is None or not np.array_equal(cur, ref_digest):
                bad.append(k)
        return sorted(bad)


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------

_PLAN_CACHE: Dict[object, DigestPlan] = {}


def _signature(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    sig = tuple(sorted(
        (leaf_key(p), jnp.shape(x), jnp.result_type(x).name)
        for p, x in flat))
    return treedef, sig


def plan_for(tree) -> DigestPlan:
    """The cached DigestPlan for ``tree``'s structure.  Keyed by treedef +
    per-leaf (path, shape, dtype), so every state with the same structure —
    every step of a training run — shares one plan and its compiled
    digest functions (no per-step retracing)."""
    treedef, sig = _signature(tree)
    key = (treedef, sig)
    plan = _PLAN_CACHE.get(key)
    if plan is None:
        keys = tuple(k for k, _, _ in sig)
        # to_i32 maps every supported dtype to exactly one int32 per
        # element, so the packed size is just the element count.
        sizes = tuple(int(np.prod(shape, dtype=np.int64))
                      for _, shape, _ in sig)
        plan = DigestPlan(treedef, keys, sizes)
        _PLAN_CACHE[key] = plan
    return plan


def clear_plan_cache() -> None:
    _PLAN_CACHE.clear()
    _SHARDED_PLAN_CACHE.clear()


# ---------------------------------------------------------------------------
# mesh-sharded digesting (DESIGN.md §5) — shard-local digests under shard_map
#
# On an N-device mesh the detection economics must not change: one combined
# launch and ONE scalar host sync per step, with every device streaming only
# its own addressable shard.  The unit of detection becomes the (leaf, shard)
# pair: each device packs and checksums the rows of its local block, the
# reference tables grow a leading shard dimension (n_shards, L, 2) and live
# SHARDED over the mesh (each device compares only its own rows, on device),
# and the only cross-device traffic on the no-fault path is the all-reduced
# any(fault) scalar (one pmax over the mesh axes).  Fault-path attribution
# resolves to (leaf, shard), which is what lets recovery restore only the
# injured shard's addressable state (core/recover.py shard_patch rung).
# ---------------------------------------------------------------------------

def mesh_device_order(mesh: Mesh) -> Tuple:
    """Canonical shard order: mesh devices flattened row-major over the
    mesh axes IN ORDER.  Shard id ``d`` everywhere in this subsystem (bad
    masks, reference-table rows, snapshot shard digests, FaultReport
    shards) means the device at this flat position."""
    return tuple(mesh.devices.flatten())


def _leaf_pspec(x) -> P:
    """The leaf's PartitionSpec padded to its rank (shard_map in_specs
    want explicit entries)."""
    spec = tuple(x.sharding.spec)
    return P(*(spec + (None,) * (jnp.ndim(x) - len(spec))))


class ShardedDigestPlan(DigestPlan):
    """Per-shard packing layout + shard_map'd digest functions for one
    (state structure, leaf shardings, mesh) triple.

    The inherited layout (``specs``/``n_rows``/row maps) is computed over
    the LOCAL shard sizes — every device owns an identical private layout
    because GSPMD shard shapes are uniform — so the whole single-device
    digest core (in-place pack kernel + one ``row_checksums`` pallas_call
    + exact segment-sum combine) runs unchanged INSIDE ``shard_map``, once
    per device, in the same single logical launch.  Global artifacts grow
    a leading shard dim, sharded over all mesh axes flattened:

      * packing buffer   (n_shards, local_padded)  — persistent + donated,
      * digest tables    (n_shards, n_leaves, 2)   — row [d, i] = Fletcher
        digest of leaf i's shard-d local block, bit-identical to
        ``host_checksum`` of that block's bytes (the single-device oracle).

    ``bytes_per_pass`` stays the GLOBAL accounting (sum over shards) so
    the §4.2/§5 cost model reads the same: ~2B/K streamed per step total,
    ~2B/(K·n_shards) per device.
    """

    def __init__(self, mesh: Mesh, treedef, keys: Tuple[str, ...],
                 local_sizes: Tuple[int, ...], pspecs: Tuple[P, ...],
                 local_shapes: Tuple[Tuple[int, ...], ...]):
        super().__init__(treedef, keys, local_sizes)
        self.mesh = mesh
        self.axis_names = tuple(mesh.axis_names)
        self.n_shards = int(mesh.size)
        self.pspecs = pspecs                    # per leaf, canonical order
        self.local_shapes = local_shapes        # per leaf, canonical order
        #: specs for the shard-stacked artifacts: dim 0 distributes over
        #: every mesh axis in order == ``mesh_device_order``
        self.buf_spec = P(self.axis_names, None)
        self.table_spec = P(self.axis_names, None, None)
        # global accounting: every device streams its local pass
        self.local_bytes_per_pass = self.bytes_per_pass
        self.bytes_per_pass = self.bytes_per_pass * self.n_shards
        self._local_digest_fns: Dict[Tuple[int, ...], object] = {}

    # -- local core --------------------------------------------------------

    def local_digest_fn(self, idx: Tuple[int, ...]):
        """The UNWRAPPED per-device digest core ``(local_buf, local_leaves)
        -> (local_buf, (len(idx), 2))`` over the local layout — the piece
        ``check_arm_subcomputation`` embeds inside one shard_map together
        with the on-device compare/arm and the fault-flag all-reduce."""
        fn = self._local_digest_fns.get(idx)
        if fn is None:
            fn = DigestPlan._build_digest_fn(self, idx)
            self._local_digest_fns[idx] = fn
        return fn

    def _local_block(self, i: int, leaf):
        """Reshape a shard_map-local leaf block to the leaf's local shape
        (shard_map hands blocks with size-1 sharded dims, not squeezed)."""
        return leaf.reshape(self.local_shapes[i])

    # -- shard_map wrapper -------------------------------------------------

    def _build_digest_fn(self, idx: Tuple[int, ...]):
        local = self.local_digest_fn(idx)

        def local_fn(buf, *leaves):
            blocks = [self._local_block(i, leaf)
                      for i, leaf in zip(idx, leaves)]
            b, t = local(buf[0], blocks)
            return b[None], t[None]

        fn = shard_map(
            local_fn, mesh=self.mesh,
            in_specs=(self.buf_spec,) + tuple(self.pspecs[i] for i in idx),
            out_specs=(self.buf_spec, self.table_spec),
            check_rep=False)

        def digest(buf, leaves):
            return fn(buf, *leaves)

        return digest

    # -- persistent packing buffers (sharded) ------------------------------

    def take_buffer(self, indices: Optional[Sequence[int]] = None
                    ) -> jnp.ndarray:
        idx = tuple(range(self.n_leaves)) if indices is None \
            else tuple(indices)
        buf = self._pack_bufs.get(idx)
        if buf is None or buf.is_deleted():
            n_rows = sum(self.specs[i].n_rows for i in idx)
            padded = -(-n_rows // TILE_ROWS) * TILE_ROWS * LANES
            buf = jax.device_put(
                jnp.zeros((self.n_shards, padded), jnp.int32),
                NamedSharding(self.mesh, self.buf_spec))
            self._pack_bufs[idx] = buf
        return buf

    def buffer_pointer(self, indices: Optional[Sequence[int]] = None):
        """Per-shard device addresses (tuple, mesh-flat order) — a sharded
        array has one buffer per device, all of which must be stable."""
        idx = tuple(range(self.n_leaves)) if indices is None \
            else tuple(indices)
        buf = self._pack_bufs.get(idx)
        if buf is None:
            return None
        by_dev = {sh.device: sh.data.unsafe_buffer_pointer()
                  for sh in buf.addressable_shards}
        return tuple(by_dev[d] for d in mesh_device_order(self.mesh))

    # -- public digesting --------------------------------------------------
    # digest_table / digest_subset are inherited and now return sharded
    # (n_shards, n, 2) tables; the per-leaf host views index the shard dim.

    def digest_dict(self, tree) -> Dict[str, np.ndarray]:
        """Host per-leaf PER-SHARD digests keyed by path: each value is
        (n_shards, 2).  One launch + one transfer, as unsharded."""
        table = fetch(self.digest_table(tree))        # (D, L, 2)
        return {k: table[:, i] for i, k in enumerate(self.keys)}

    def verify(self, tree, reference: Dict[str, np.ndarray]) -> List[str]:
        """Leaf paths with ANY shard digest mismatching ``reference``
        (values (n_shards, 2), as produced by ``digest_dict``)."""
        current = self.digest_dict(tree)
        bad = []
        for k, ref_digest in reference.items():
            cur = current.get(k)
            if cur is None or not np.array_equal(cur, ref_digest):
                bad.append(k)
        return sorted(bad)


_SHARDED_PLAN_CACHE: Dict[object, ShardedDigestPlan] = {}


def _mesh_key(mesh: Mesh):
    return (tuple(mesh.axis_names), tuple(mesh.shape.values()),
            tuple(d.id for d in mesh.devices.flatten()))


def key_on_mesh(cache_key, mesh_key) -> bool:
    """True when any element of a cache key (plan, NamedSharding, ...)
    carries a ``.mesh`` matching ``mesh_key`` — the shared predicate of
    every module's ``evict_mesh`` (elastic hard loss: executables and
    plans pinned to a dead mesh must be dropped, both to release their
    buffers and so a later drill in the same process cannot hit a
    stale-device executable)."""
    elems = cache_key if isinstance(cache_key, tuple) else (cache_key,)
    for el in elems:
        m = getattr(el, "mesh", None)
        if isinstance(m, Mesh) and _mesh_key(m) == mesh_key:
            return True
    return False


def evict_mesh_plans(mesh) -> int:
    """Drop cached ShardedDigestPlans keyed on ``mesh``."""
    mk = _mesh_key(mesh)
    stale = [k for k in _SHARDED_PLAN_CACHE if k[0] == mk]
    for k in stale:
        del _SHARDED_PLAN_CACHE[k]
    return len(stale)


def sharded_plan_for(tree, mesh: Mesh) -> ShardedDigestPlan:
    """The cached ShardedDigestPlan for ``tree``'s structure on ``mesh``.

    Every leaf must already carry a ``NamedSharding`` on ``mesh`` (i.e. the
    state has been ``device_put`` with its partition specs — see
    ``launch/specs.state_shardings``): the plan's per-shard layout is
    derived from those specs and cached by (mesh, structure, specs), so a
    training run digests through one compiled shard_map program per leaf
    subset with no per-step retracing."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    entries = []
    for path, x in flat:
        sharding = getattr(x, "sharding", None)
        if not isinstance(sharding, NamedSharding):
            raise ValueError(
                f"sharded_plan_for requires NamedSharding leaves; "
                f"{leaf_key(path)} has {type(sharding).__name__} — "
                f"device_put the state with its specs first")
        if _mesh_key(sharding.mesh) != _mesh_key(mesh):
            raise ValueError(
                f"leaf {leaf_key(path)} is sharded on a different mesh")
        local_shape = sharding.shard_shape(jnp.shape(x))
        entries.append((leaf_key(path), _leaf_pspec(x), local_shape,
                        jnp.result_type(x).name))
    entries.sort(key=lambda e: e[0])
    key = (_mesh_key(mesh), treedef,
           tuple((k, tuple(sp), ls, dt) for k, sp, ls, dt in entries))
    plan = _SHARDED_PLAN_CACHE.get(key)
    if plan is None:
        keys = tuple(k for k, _, _, _ in entries)
        local_sizes = tuple(int(np.prod(ls, dtype=np.int64))
                            for _, _, ls, _ in entries)
        pspecs = tuple(sp for _, sp, _, _ in entries)
        local_shapes = tuple(ls for _, _, ls, _ in entries)
        plan = ShardedDigestPlan(mesh, treedef, keys, local_sizes, pspecs,
                                 local_shapes)
        _SHARDED_PLAN_CACHE[key] = plan
    return plan


# ---------------------------------------------------------------------------
# check+arm as a traceable subcomputation — the shared core of every fused
# canary mode (DESIGN.md §4.2).  Building it resolves all host-side plan
# state (digest-fn lookup, row index arrays, segment maps) ONCE; the
# returned function is pure and jit-embeddable, so the same subcomputation
# serves both the standalone fused launches (core/detect.py) and the
# in-step fused mode that runs it inside the jitted, donated training step
# (core/fused_step.py).
# ---------------------------------------------------------------------------

def check_arm_subcomputation(plan: DigestPlan, chk: Sequence[int],
                             arm: Sequence[int]):
    """Build the fused check+arm digest core for one canary rotation.

    Returns ``(fn, union)`` where ``union = tuple(chk) + tuple(arm)`` names
    the packing-buffer subset (``plan.take_buffer(union)``) and

        fn(buf, leaves, ref_read, ref_write)
            -> (buf, any_mismatch, bad_mask, new_write)

    digests ``leaves`` (the chk-slice leaves followed by the arm-slice
    leaves, possibly drawn from two state versions) in ONE pallas launch,
    compares the first ``len(chk)`` digests against rows ``chk`` of
    ``ref_read`` on device, and scatter-arms the remaining digests into
    rows ``arm`` of ``ref_write`` (in place when the caller donates it).
    Pure/traceable: no host-side plan lookups survive into the traced
    path, so callers may embed ``fn`` inside their own jit — including a
    jitted step function that donates its state (core/fused_step.py).

    For a ``ShardedDigestPlan`` the same signature is served by a
    shard_map'd core (one logical launch; every device digests and
    compares only its own shard rows): ``ref_read``/``ref_write`` are the
    sharded (n_shards, L, 2) generation tables, ``bad_mask`` is the
    sharded (n_shards, len(chk)) per-(leaf, shard) mismatch matrix that
    stays on device until fault-path attribution, and ``any_mismatch`` is
    the all-reduced (pmax over every mesh axis) replicated scalar — the
    ONLY cross-device communication on the no-fault path.
    """
    if isinstance(plan, ShardedDigestPlan):
        return _sharded_check_arm_subcomputation(plan, chk, arm)
    chk = tuple(chk)
    arm = tuple(arm)
    union = chk + arm
    digest = plan.digest_fn(union)
    chk_rows = np.asarray(chk, np.int32)
    arm_rows = np.asarray(arm, np.int32)
    nc = len(chk)

    def fn(buf, leaves, ref_read, ref_write):
        buf, table = digest(buf, leaves)    # ONE fused launch
        bad = jnp.any(table[:nc] != ref_read[chk_rows], axis=1) \
            if nc else jnp.zeros((0,), bool)
        new_write = ref_write.at[arm_rows].set(table[nc:]) \
            if arm else ref_write
        return buf, jnp.any(bad), bad, new_write

    return fn, union


def _sharded_check_arm_subcomputation(plan: ShardedDigestPlan,
                                      chk: Sequence[int],
                                      arm: Sequence[int]):
    """Mesh variant of ``check_arm_subcomputation`` — one shard_map whose
    body runs the per-device digest core, the on-device compare of the
    device's own reference rows, the in-place arm scatter into the
    device's own write rows, and the any(fault) all-reduce."""
    chk = tuple(chk)
    arm = tuple(arm)
    union = chk + arm
    local_digest = plan.local_digest_fn(union)
    chk_rows = np.asarray(chk, np.int32)
    arm_rows = np.asarray(arm, np.int32)
    nc = len(chk)
    axes = plan.axis_names

    def local_fn(buf, ref_read, ref_write, *leaves):
        blocks = [plan._local_block(i, leaf)
                  for i, leaf in zip(union, leaves)]
        b, table = local_digest(buf[0], blocks)       # per-device local pass
        bad = jnp.any(table[:nc] != ref_read[0, chk_rows], axis=1) \
            if nc else jnp.zeros((0,), bool)
        # the fault flag is the only cross-device hop on the no-fault path
        flag = jax.lax.pmax(jnp.any(bad).astype(jnp.int32), axes) > 0
        new_write = ref_write.at[0, arm_rows].set(table[nc:]) \
            if arm else ref_write
        return b[None], flag, bad[None], new_write

    smapped = shard_map(
        local_fn, mesh=plan.mesh,
        in_specs=(plan.buf_spec, plan.table_spec, plan.table_spec)
        + tuple(plan.pspecs[i] for i in union),
        out_specs=(plan.buf_spec, P(), P(plan.axis_names, None),
                   plan.table_spec),
        check_rep=False)

    def fn(buf, leaves, ref_read, ref_write):
        return smapped(buf, ref_read, ref_write, *leaves)

    return fn, union


# ---------------------------------------------------------------------------
# host digest path — certify micro-snapshot host DMA copies without a
# device re-upload (DESIGN.md §4.2).  Bit-identical to the kernel: numpy
# uint32 arithmetic wraps mod 2^32 exactly like the int32 device math.
# ---------------------------------------------------------------------------

def _host_i32(x: np.ndarray) -> np.ndarray:
    """Host mirror of ``ref.to_i32``: flat int32 view of the raw bits."""
    a = np.ascontiguousarray(x)
    if a.dtype.itemsize == 4:          # float32 / int32 / uint32: bit view
        return a.reshape(-1).view(np.int32)
    if a.dtype.itemsize == 2:          # bf16 / f16 / i16 / u16: zero-extend
        return a.reshape(-1).view(np.uint16).astype(np.int32)
    if a.dtype.itemsize == 1:          # i8 / u8: zero-extend
        return a.reshape(-1).view(np.uint8).astype(np.int32)
    if a.dtype == np.int64:            # truncate, as jnp astype does
        return a.reshape(-1).astype(np.int32)
    return np.ascontiguousarray(
        a.astype(np.float32)).reshape(-1).view(np.int32)


def host_checksum(x) -> np.ndarray:
    """Fletcher digest int32[2] of a HOST array — bit-identical to
    ``ops.checksum``/``ref.checksum_ref`` of the same bytes, with zero
    device work (no upload, no launch, no sync)."""
    f = _host_i32(np.asarray(x)).view(np.uint32)
    idx = np.arange(1, f.shape[0] + 1, dtype=np.uint32)
    s1 = np.add.reduce(f, dtype=np.uint32)
    s2 = np.add.reduce(f * idx, dtype=np.uint32)
    return np.array([s1, s2], dtype=np.uint32).view(np.int32)


def host_tree_checksums(tree) -> Dict[str, np.ndarray]:
    """Per-leaf host digests keyed by path — the snapshot-certification
    twin of ``ops.tree_checksums``, computed on the host DMA copy."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {leaf_key(p): host_checksum(leaf) for p, leaf in flat}


def host_verify_tree(tree, reference: Dict[str, np.ndarray]) -> List[str]:
    """Leaf paths of a HOST tree whose digest no longer matches
    ``reference`` — snapshot verification on the fault path, device-free."""
    current = host_tree_checksums(tree)
    bad = []
    for k, ref_digest in reference.items():
        cur = current.get(k)
        if cur is None or not np.array_equal(cur, ref_digest):
            bad.append(k)
    return sorted(bad)


def shard_indices(x) -> List[Tuple]:
    """Per-shard global index tuples of a NamedSharding array, in
    mesh-flat shard order — the slice each shard id addresses.  This is
    the metadata micro-snapshots store so shard-local restore can carve a
    single shard's bytes out of a host copy."""
    m = x.sharding.devices_indices_map(jnp.shape(x))
    return [m[d] for d in mesh_device_order(x.sharding.mesh)]


def host_shard_checksums(x) -> np.ndarray:
    """(n_shards, 2) host digests of a sharded array, shard order matching
    the sharded digest tables — the single-device uint32 oracle the kernel
    path is asserted bit-identical against.  (Snapshot certification does
    NOT route through here: ``core/microcheckpoint.py`` hashes its stored
    host copy's slices directly via ``host_checksum``, so it never
    re-fetches the device.)"""
    host = np.asarray(x)
    return np.stack([host_checksum(host[idx]) for idx in shard_indices(x)])


# ---------------------------------------------------------------------------
# single-flip localisation — triage's certificate engine.  The Fletcher pair
# (s1, s2) over a leaf's packed words is an error-locating code for the
# single-bit-flip channel: one flipped bit b in word j shifts the digests by
#
#     delta1 = s1' - s1 = d            (mod 2^32),   d = +-2^b
#     delta2 = s2' - s2 = (j + 1) * d  (mod 2^32)
#
# so the (bit, word) coordinates of the flip are solvable from the reference
# digest the canary already holds — no second copy of the data needed.
# ---------------------------------------------------------------------------

def _inv_odd_u32(w: int) -> int:
    """Multiplicative inverse of odd ``w`` mod 2^32 (Newton iteration)."""
    inv = w & 0xFFFFFFFF
    for _ in range(5):
        inv = (inv * (2 - w * inv)) & 0xFFFFFFFF
    return inv


def locate_single_flip(ref_pair, cur_pair, n_words: int):
    """Solve the digest pair for a single flipped bit.

    Args: reference and current int32[2] digests of the same leaf (or
    shard slice) and the packed word count.  Returns ``(bit, delta,
    candidates)`` — the flipped bit index, the mod-2^32 word delta
    (``old_word = (cur_word - delta) & 0xFFFFFFFF``), and the candidate
    flat word indices j (several only when ``n_words > 2^(32-bit)``) — or
    ``None`` when the deltas are inconsistent with EVERY single-bit flip
    (multi-word or multi-bit damage: the caller must escalate).
    """
    ref = np.asarray(ref_pair).view(np.uint32).reshape(-1)
    cur = np.asarray(cur_pair).view(np.uint32).reshape(-1)
    d1 = int((int(cur[0]) - int(ref[0])) & 0xFFFFFFFF)
    d2 = int((int(cur[1]) - int(ref[1])) & 0xFFFFFFFF)
    if d1 == 0:
        return None  # a single flip always moves s1 by a non-zero +-2^b
    bit = (d1 & -d1).bit_length() - 1  # trailing zeros of d1
    w = d1 >> bit
    # d = +2^b gives w = 1; d = -2^b mod 2^32 gives w = 2^(32-b) - 1
    if w not in (1, (1 << (32 - bit)) - 1):
        return None
    q = (d2 * _inv_odd_u32(w)) & 0xFFFFFFFF
    if q & ((1 << bit) - 1):
        return None  # (j+1)*2^b must have b low zero bits
    m = q >> bit  # j + 1 mod 2^(32-bit)
    period = 1 << (32 - bit)
    first = m if m != 0 else period
    candidates = [j1 - 1 for j1 in range(first, n_words + 1, period)]
    if not candidates:
        return None
    return bit, d1, candidates
