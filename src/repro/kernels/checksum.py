"""Pallas TPU kernel: blocked Fletcher-style checksum.

This is the paper's "zero-overhead detection" made real on TPU: the canary
detector must stream the full train state at HBM bandwidth with no MXU use
and negligible VMEM residency, so it can overlap with step compute.

Layout: the flat int32 view is tiled (TILE_ROWS, LANES) = (256, 128) — one
VMEM-resident tile is 128 KiB, well under the ~16 MiB/core budget, and the
lane dim matches the VPU's native 128-lane registers.  Each grid step
produces a (2,)-digest for its tile; tile digests are combined *exactly*
into per-block digests by the ops wrapper (the weighted term needs a global
offset correction: Σ(i+g)·x = Σi·x + g·Σx, all mod 2^32).

Two entry points:

* ``checksum_tiles`` — per-tile digests with *local* weights; the caller
  applies the offset correction (legacy single-array path).
* ``row_checksums``  — the fused-digest variant (DESIGN.md §4.2): one
  launch digests every 128-lane ROW of a whole train state packed into a
  single buffer.  Row granularity lets the DigestPlan pack leaves
  back-to-back at 512 B alignment (tile alignment would inflate a state
  with many small leaves by up to 256×), and per-leaf digests fall out of
  a plain segment-sum over the row digests — no per-leaf launches, no
  per-leaf host syncs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
TILE_ROWS = 256
TILE = TILE_ROWS * LANES  # 32768 int32 = 128 KiB per VMEM tile


def _tile_sums(x):
    """(s1, s2_local) of one (TILE_ROWS, LANES) int32 tile."""
    rows, lanes = x.shape
    # local position weights 1..TILE (row-major within the tile)
    row = jax.lax.broadcasted_iota(jnp.int32, (rows, lanes), 0)
    lane = jax.lax.broadcasted_iota(jnp.int32, (rows, lanes), 1)
    idx = row * lanes + lane + 1
    s1 = jnp.sum(x, dtype=jnp.int32)
    s2 = jnp.sum(x * idx, dtype=jnp.int32)
    return s1, s2


def _checksum_kernel(x_ref, out_ref):
    """x_ref: (1, TILE_ROWS, LANES) int32 tile; out_ref: (1, 2) int32."""
    s1, s2 = _tile_sums(x_ref[0, :, :])
    out_ref[0, 0] = s1
    out_ref[0, 1] = s2


def _row_checksum_kernel(x_ref, out_ref):
    """x_ref (1, TILE_ROWS, LANES) -> out_ref (1, TILE_ROWS, 2): per-row
    Fletcher partials with lane-local weights 1..LANES.  Rows combine into
    leaf digests exactly: Σ(off+j)·x = off·Σx + Σj·x (mod 2^32)."""
    x = x_ref[0, :, :]
    rows, lanes = x.shape
    lane = jax.lax.broadcasted_iota(jnp.int32, (rows, lanes), 1) + 1
    out_ref[0, :, 0] = jnp.sum(x, axis=1, dtype=jnp.int32)
    out_ref[0, :, 1] = jnp.sum(x * lane, axis=1, dtype=jnp.int32)


def _row_checksum_batch_kernel(x_ref, out_ref):
    """All-tiles-in-one-block variant of ``_row_checksum_kernel``:
    x_ref (nt, TILE_ROWS, LANES), out_ref (nt, TILE_ROWS, 2).

    Used in interpret mode, where per-grid-step execution costs O(full
    buffer) per step (the interpreter re-slices the whole operand each
    iteration), making a tiled grid quadratic in state size.  One block +
    vectorized reductions keeps the interpret path a single linear pass.
    Compiled TPU keeps the tiled grid (a whole train state does not fit
    VMEM)."""
    x = x_ref[...]
    _, rows, lanes = x.shape
    lane = jax.lax.broadcasted_iota(jnp.int32, (rows, lanes), 1) + 1
    out_ref[..., 0] = jnp.sum(x, axis=2, dtype=jnp.int32)
    out_ref[..., 1] = jnp.sum(x * lane[None, :, :], axis=2, dtype=jnp.int32)


def pack_rows(buf: jnp.ndarray, flats, starts, *, interpret: bool = True):
    """In-place scatter of leaf bit-streams into the persistent packing
    buffer (DESIGN.md §4.2 buffer reuse).

    buf    : flat int32 packing buffer — ALIASED into the output
             (``input_output_aliases={0: 0}``), so when the caller's jit
             donates it the pack is a true in-place write: zero new device
             allocations per digest in steady state.
    flats  : flat int32 views of the leaves (``ref.to_i32`` output).
    starts : static element offset of each flat within ``buf`` (the plan's
             row-aligned layout).

    Only the leaf ranges are written; the inter-leaf fill and the tail pad
    are zero-initialised once at buffer creation and never touched again
    (leaf sizes are plan constants, so the zero regions are invariant).
    Compiled-TPU note: the un-gridded whole-buffer form below is the
    interpret/CPU path; a compiled TPU pack would keep ``buf`` in HBM
    (``memory_space=pltpu.HBM``) and DMA per leaf — see DESIGN.md
    "Follow-on work".
    """
    starts = tuple(int(s) for s in starts)

    def kernel(*refs):
        # refs = (buf_ref, *leaf_refs, out_ref); buf_ref is aliased to
        # out_ref, so untouched regions keep their (zero) contents.
        out_ref = refs[-1]
        for leaf_ref, start in zip(refs[1:-1], starts):
            out_ref[pl.ds(start, leaf_ref.shape[0])] = leaf_ref[...]

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(buf.shape, buf.dtype),
        input_output_aliases={0: 0},
        interpret=interpret,
    )(buf, *flats)


def checksum_tiles(x_i32_tiles: jnp.ndarray, *, interpret: bool = True):
    """x_i32_tiles: (nt, TILE_ROWS, LANES) int32 -> (nt, 2) int32 digests."""
    nt = x_i32_tiles.shape[0]
    return pl.pallas_call(
        _checksum_kernel,
        grid=(nt,),
        in_specs=[pl.BlockSpec((1, TILE_ROWS, LANES),
                               lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nt, 2), jnp.int32),
        interpret=interpret,
    )(x_i32_tiles)


def row_checksums(x_i32_tiles: jnp.ndarray, *, interpret: bool = True):
    """Single-launch whole-state digest pass at ROW granularity.

    x_i32_tiles : (nt, TILE_ROWS, LANES) int32 — every row of every leaf,
                  packed back to back at row (512 B) alignment
                  (see digest.DigestPlan).
    Returns (nt, TILE_ROWS, 2) int32 per-row partials; the caller combines
    rows into per-leaf digests with its static row→leaf segment map:
        leaf_s1 = Σ_r s1_r        leaf_s2 = Σ_r (s2_r + off_r·s1_r)
    where off_r is the row's element offset within its leaf (mod 2^32 —
    int32 wraparound makes the combine exact).

    Compiled (TPU): one grid launch, one 128 KiB VMEM tile per step.
    Interpret (CPU tests): the same digest as a single-block vectorized
    kernel — the interpreter's per-grid-step cost is O(full buffer), which
    would make the tiled grid quadratic in state size.
    """
    nt = x_i32_tiles.shape[0]
    if interpret:
        return pl.pallas_call(
            _row_checksum_batch_kernel,
            out_shape=jax.ShapeDtypeStruct((nt, TILE_ROWS, 2), jnp.int32),
            interpret=True,
        )(x_i32_tiles)
    return pl.pallas_call(
        _row_checksum_kernel,
        grid=(nt,),
        in_specs=[pl.BlockSpec((1, TILE_ROWS, LANES),
                               lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, TILE_ROWS, 2), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nt, TILE_ROWS, 2), jnp.int32),
        interpret=interpret,
    )(x_i32_tiles)
