"""Pallas TPU kernel: blocked Fletcher-style checksum.

This is the paper's "zero-overhead detection" made real on TPU: the canary
detector must stream the full train state at HBM bandwidth with no MXU use
and negligible VMEM residency, so it can overlap with step compute.

Layout: the flat int32 view is tiled (TILE_ROWS, LANES) = (256, 128) — one
VMEM-resident tile is 128 KiB, well under the ~16 MiB/core budget, and the
lane dim matches the VPU's native 128-lane registers.  Each grid step
produces a (2,)-digest for its tile; tile digests are combined *exactly*
into per-block digests by the ops wrapper (the weighted term needs a global
offset correction: Σ(i+g)·x = Σi·x + g·Σx, all mod 2^32).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
TILE_ROWS = 256
TILE = TILE_ROWS * LANES  # 32768 int32 = 128 KiB per VMEM tile


def _checksum_kernel(x_ref, out_ref):
    """x_ref: (1, TILE_ROWS, LANES) int32 tile; out_ref: (1, 2) int32."""
    x = x_ref[0, :, :]
    rows, lanes = x.shape
    # local position weights 1..TILE (row-major within the tile)
    row = jax.lax.broadcasted_iota(jnp.int32, (rows, lanes), 0)
    lane = jax.lax.broadcasted_iota(jnp.int32, (rows, lanes), 1)
    idx = row * lanes + lane + 1
    s1 = jnp.sum(x, dtype=jnp.int32)
    s2 = jnp.sum(x * idx, dtype=jnp.int32)
    out_ref[0, 0] = s1
    out_ref[0, 1] = s2


def checksum_tiles(x_i32_tiles: jnp.ndarray, *, interpret: bool = True):
    """x_i32_tiles: (nt, TILE_ROWS, LANES) int32 -> (nt, 2) int32 digests."""
    nt = x_i32_tiles.shape[0]
    return pl.pallas_call(
        _checksum_kernel,
        grid=(nt,),
        in_specs=[pl.BlockSpec((1, TILE_ROWS, LANES),
                               lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nt, 2), jnp.int32),
        interpret=interpret,
    )(x_i32_tiles)
