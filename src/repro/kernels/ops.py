"""jit'd public wrappers around the Pallas kernels.

Handles: arbitrary shapes/dtypes (bit-cast + pad to tile multiples), exact
digest recombination across tiles, interpret-mode selection (Pallas kernels
execute in interpret mode on CPU; compiled mode on TPU), and pytree-level
orchestration (leaf digests for whole train states).
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import checksum as _ck
from repro.kernels import digest as _dg
from repro.kernels import parity as _pk
from repro.kernels import ref as _ref
from repro.kernels import vote as _vk
from repro.kernels.digest import leaf_key, plan_for  # noqa: F401 (re-export)

TILE = _ck.TILE  # int32 elements per kernel tile


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _tiles(x) -> Tuple[jnp.ndarray, int]:
    """Flat int32 view padded and reshaped to (nt, TILE_ROWS, LANES)."""
    flat = _ref.to_i32(x)
    n = flat.shape[0]
    nt = max(1, -(-n // TILE))
    flat = jnp.pad(flat, (0, nt * TILE - n))
    return flat.reshape(nt, _ck.TILE_ROWS, _ck.LANES), n


@jax.jit
def checksum(x) -> jnp.ndarray:
    """Two-term Fletcher digest int32[2] of the raw bits of ``x``.

    Tile digests (s1_t, s2_t) combine exactly:
        s1 = Σ_t s1_t
        s2 = Σ_t (s2_t + offset_t · s1_t)      (mod 2^32)
    """
    tiles, _ = _tiles(x)
    d = _ck.checksum_tiles(tiles, interpret=_interpret())  # (nt, 2)
    nt = d.shape[0]
    offsets = jnp.arange(nt, dtype=jnp.int32) * jnp.int32(TILE)
    s1 = jnp.sum(d[:, 0], dtype=jnp.int32)
    s2 = jnp.sum(d[:, 1] + offsets * d[:, 0], dtype=jnp.int32)
    return jnp.stack([s1, s2])


@jax.jit
def blocked_checksum(x) -> jnp.ndarray:
    """Per-tile digests int32[nt, 2].  Localisation granularity is the
    kernel tile: TILE = TILE_ROWS·LANES = 32768 int32 elements = 128 KiB
    (coarser than the pure-jnp oracle's ``ref.CHECKSUM_BLOCK`` default —
    the oracle block size is a reference-semantics knob, not the kernel's
    tiling)."""
    tiles, _ = _tiles(x)
    return _ck.checksum_tiles(tiles, interpret=_interpret())


@jax.jit
def vote3(a, b, c):
    """Bitwise majority of three equal-shaped arrays, original dtype out."""
    ta, n = _tiles(a)
    tb, _ = _tiles(b)
    tc, _ = _tiles(c)
    out = _vk.vote3_tiles(ta, tb, tc, interpret=_interpret())
    return _ref.from_i32(out.reshape(-1)[:n], a)


@jax.jit
def xor_fold(arrays: Sequence[jnp.ndarray]):
    """Parity of N equal-shaped arrays (original dtype out)."""
    ts = []
    n = None
    for a in arrays:
        t, n = _tiles(a)
        ts.append(t)
    stacked = jnp.stack(ts)  # (R, nt, rows, lanes)
    out = _pk.xor_fold_tiles(stacked, interpret=_interpret())
    return _ref.from_i32(out.reshape(-1)[:n], arrays[0])


@jax.jit
def xor_reconstruct(parity, others: Sequence[jnp.ndarray]):
    """Recover the missing shard from parity + the surviving shards."""
    return xor_fold(list(others) + [parity])


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 0, block_k: int = 0):
    """Model-layout flash attention: q (B, Sq, H, D), k/v (B, Sk, KV, D).

    Handles GQA flattening, block-multiple padding of Sq/Sk and lane-multiple
    (128) padding of D, then calls the Pallas kernel (compiled on TPU,
    interpret elsewhere).  Returns (B, Sq, H, D) in q.dtype.
    """
    from repro.kernels import flash_attention as _fa

    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    bq = block_q or min(_fa.DEFAULT_BLOCK_Q, max(Sq, 16))
    bk = block_k or min(_fa.DEFAULT_BLOCK_K, max(Sk, 16))

    pad_q = (-Sq) % bq
    pad_k = (-Sk) % bk
    # lane alignment: round D up to a multiple of 128 (tiny test dims are
    # left alone — interpret mode has no lane constraint)
    pad_d = (-D) % 128 if D >= 128 else 0

    qt = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, pad_d)))
    kt = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, pad_d)))
    vt = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, pad_d)))

    # (B, S, H, D) -> (B*H, S, D); kv -> (B*KV, S, D).  The kernel's GQA
    # index map assumes q-head-major flattening per batch.
    qf = qt.transpose(0, 2, 1, 3).reshape(B * H, Sq + pad_q, D + pad_d)
    kf = kt.transpose(0, 2, 1, 3).reshape(B * KV, Sk + pad_k, D + pad_d)
    vf = vt.transpose(0, 2, 1, 3).reshape(B * KV, Sk + pad_k, D + pad_d)

    # scale by true D, not padded D: kernel scales by padded; correct it
    o = _fa.flash_attention_bhsd(
        qf * np.sqrt((D + pad_d) / D).astype(qf.dtype),
        kf, vf, causal=causal, window=window, softcap=softcap,
        block_q=bq, block_k=bk, interpret=_interpret())
    o = o.reshape(B, H, Sq + pad_q, D + pad_d).transpose(0, 2, 1, 3)
    return o[:, :Sq, :, :D]


# ---------------------------------------------------------------------------
# Pytree-level orchestration — thin wrappers over the fused DigestPlan
# (one pallas launch + one host transfer per call; the seed paid one
# launch and one blocking transfer per LEAF — see DESIGN.md §4.2).
# ---------------------------------------------------------------------------

def tree_checksums(tree) -> Dict[str, np.ndarray]:
    """Digest per leaf, keyed by path string — the Recovery Table's 'key'
    column (the paper keys on (file, line, column) debug tuples; ours is the
    state-leaf path, which plays the same role)."""
    return plan_for(tree).digest_dict(tree)


def subtree_checksums(tree, keys) -> Dict[str, np.ndarray]:
    """Digests for the named leaves only (the rotating-canary read slice —
    the paid 1/K of the detection cost; everything else is modeled as fused
    into the step's write stream).  One launch over the subset's tiles."""
    plan = plan_for(tree)
    kset = set(keys)
    want = [k for k in plan.keys if k in kset]
    idx = [plan.index_of(k) for k in want]
    table = _dg.fetch(plan.digest_subset(tree, idx)) if idx \
        else np.zeros((0, 2), np.int32)
    return {k: table[i] for i, k in enumerate(want)}


def verify_tree(tree, reference: Dict[str, np.ndarray]) -> List[str]:
    """Return leaf paths whose digest no longer matches ``reference``."""
    return plan_for(tree).verify(tree, reference)


def rotating_slice(step: int, n_slices: int, n_leaves: int) -> List[int]:
    """Indices of the leaves checked at ``step`` under the rotating-canary
    schedule (full coverage every n_slices steps at 1/n_slices the cost)."""
    return [i for i in range(n_leaves) if i % n_slices == step % n_slices]
