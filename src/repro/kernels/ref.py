"""Pure-jnp oracles for the Pallas kernels.

These define the *semantics*; the kernels must match them bit-exactly
(all the algorithms are integer/bitwise, so there is no tolerance — tests
assert equality, not allclose).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

CHECKSUM_BLOCK = 4096  # elements per digest block (int32 lanes)


def to_i32(x) -> jnp.ndarray:
    """Bit-cast any array to a flat int32 vector (zero-padded to 4-byte
    multiples).  The checksum domain is raw bits, so repairs can be verified
    bit-exactly regardless of dtype."""
    x = jnp.asarray(x)
    if x.dtype == jnp.int32:
        flat = x.reshape(-1)
    elif x.dtype in (jnp.float32, jnp.uint32):
        flat = jax.lax.bitcast_convert_type(x, jnp.int32).reshape(-1)
    elif x.dtype in (jnp.bfloat16, jnp.float16, jnp.int16, jnp.uint16):
        i16 = jax.lax.bitcast_convert_type(x.reshape(-1), jnp.int16)
        flat = i16.astype(jnp.uint16).astype(jnp.int32)
    elif x.dtype in (jnp.int8, jnp.uint8):
        flat = x.reshape(-1).astype(jnp.uint8).astype(jnp.int32)
    elif x.dtype == jnp.int64:
        flat = x.reshape(-1).astype(jnp.int32)
    else:
        flat = jax.lax.bitcast_convert_type(
            x.astype(jnp.float32), jnp.int32).reshape(-1)
    return flat


def checksum_ref(x) -> jnp.ndarray:
    """Fletcher-style two-term digest over the raw bits of ``x``.

    s1 = Σ x_i               (mod 2^32, int32 wraparound)
    s2 = Σ (i+1)·x_i         (mod 2^32)
    Returns int32[2].  Position weighting catches element swaps that a plain
    sum would miss.
    """
    flat = to_i32(x)
    n = flat.shape[0]
    idx = (jnp.arange(n, dtype=jnp.int32) + 1)
    s1 = jnp.sum(flat, dtype=jnp.int32)
    s2 = jnp.sum(flat * idx, dtype=jnp.int32)
    return jnp.stack([s1, s2])


def blocked_checksum_ref(x, block: int = CHECKSUM_BLOCK) -> jnp.ndarray:
    """Per-block digests int32[nb, 2] — the localisation variant: a corrupt
    element identifies its block, so repair touches one block, not the whole
    leaf."""
    flat = to_i32(x)
    n = flat.shape[0]
    nb = -(-n // block)
    flat = jnp.pad(flat, (0, nb * block - n))
    blocks = flat.reshape(nb, block)
    idx = (jnp.arange(block, dtype=jnp.int32) + 1)[None, :]
    s1 = jnp.sum(blocks, axis=1, dtype=jnp.int32)
    s2 = jnp.sum(blocks * idx, axis=1, dtype=jnp.int32)
    return jnp.stack([s1, s2], axis=1)


def vote3_ref(a, b, c):
    """Bitwise triple-modular-redundancy majority: out bit = majority bit."""
    ai, bi, ci = (to_i32(v) for v in (a, b, c))
    maj = (ai & bi) | (ai & ci) | (bi & ci)
    return from_i32(maj, a)


def xor_fold_ref(arrays):
    """XOR-fold of equal-shaped arrays (parity construction)."""
    acc = to_i32(arrays[0])
    for a in arrays[1:]:
        acc = acc ^ to_i32(a)
    return from_i32(acc, arrays[0])


def xor_reconstruct_ref(parity, others):
    """Reconstruct the missing shard: parity ^ xor(others)."""
    acc = to_i32(parity)
    for a in others:
        acc = acc ^ to_i32(a)
    return from_i32(acc, parity)


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        softcap: float = 0.0):
    """Dense-softmax oracle for the flash kernel.

    q (BH, Sq, D), k/v (BKV, Sk, D), BH a multiple of BKV (GQA flattening).
    fp32 softmax, same masking semantics as the kernel.
    """
    BH, Sq, D = q.shape
    BKV, Sk, _ = k.shape
    G = BH // BKV
    kr = jnp.repeat(k, G, axis=0)
    vr = jnp.repeat(v, G, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) / np.sqrt(D)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    live = jnp.ones((Sq, Sk), bool)
    if causal:
        live &= qp >= kp
    if window:
        live &= (qp - kp) < window
    s = jnp.where(live[None], s, -2.0**30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      vr.astype(jnp.float32)).astype(q.dtype)


def from_i32(flat_i32, like) -> jnp.ndarray:
    """Inverse of to_i32 for the dtypes used in state trees."""
    like = jnp.asarray(like)
    if like.dtype == jnp.int32:
        return flat_i32.reshape(like.shape)
    if like.dtype in (jnp.float32, jnp.uint32):
        return jax.lax.bitcast_convert_type(
            flat_i32.reshape(like.shape), like.dtype)
    if like.dtype in (jnp.bfloat16, jnp.float16, jnp.int16, jnp.uint16):
        i16 = flat_i32.astype(jnp.uint16).astype(jnp.int16)
        return jax.lax.bitcast_convert_type(
            i16.reshape(like.shape), like.dtype)
    if like.dtype in (jnp.int8, jnp.uint8):
        return flat_i32.astype(like.dtype).reshape(like.shape)
    raise TypeError(f"unsupported dtype {like.dtype}")
