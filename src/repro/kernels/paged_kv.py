"""Pallas TPU kernel: paged-KV block gather (serving engine, DESIGN.md §6).

The paged serving engine keeps every decode-cache leaf as a shared **block
pool** ``(n_blocks, block_size, ...)`` plus per-slot block tables
``(S, max_blocks)`` — a slot owns exactly the blocks its sequence needs, so
heterogeneous prompt/generation lengths stop paying ``max_len`` HBM per
slot.  The decode hot path then needs one data movement: materialise each
slot's owned blocks as a contiguous per-slot view for the vmapped decode
step.  That gather is this kernel.

Why a gather (and not a fused paged-attention kernel): the engine's
resilience contract demands the paged engine be **bit-exact** against the
dense slot-major engine (tests/test_serving.py), and a fused online-softmax
paged-attention kernel would change the floating-point reduction order.
Gathering the owned blocks and running the *unmodified* dense decode on the
gathered view keeps the computation literally identical — same ops over the
same values — so bit-exactness holds by construction, and the canary /
replay machinery needs no numeric caveats.

TPU mapping
-----------
* grid = (S, max_blocks): one program per (slot, logical block).
* The block table rides ``PrefetchScalarGridSpec`` **scalar prefetch**: the
  input BlockSpec's index_map reads ``bt[s, j]`` to pick which *physical*
  pool block is DMA'd into VMEM — the kernel body is a pure copy, so the
  whole gather is HBM->HBM DMA traffic steered by the table, touching only
  the blocks a slot owns (plus the scratch block for unallocated entries).
* Block shape (1, block_size, F) where F flattens the per-token feature
  dims; for compiled TPU lowering F should be a multiple of 128 lanes (the
  iterpro smoke config's F = count*KV*D = 128 is; CPU interpret mode has no
  constraint).

Validated against the jnp reference gather over shape/dtype sweeps in
tests/test_kernels.py, and load-bearing in the serving engine's fused step
(one combined launch: gather + vmapped decode + scatter-back + canary).
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def gather_blocks(pool_leaf, block_tables, *, interpret=None):
    """Gather each slot's owned blocks out of a shared block pool.

    pool_leaf    : (n_blocks, block_size, *feat) — one cache leaf's pool
    block_tables : (S, max_blocks) int32 — physical block id per (slot,
                   logical block); unallocated entries point at the scratch
                   block 0 (the caller masks those positions out of
                   attention, so their bytes are never consumed).

    Returns (S, max_blocks, block_size, *feat): slot-major, logical-block
    ordered — ``out[s].reshape(max_blocks * block_size, *feat)`` is slot
    ``s``'s linear cache view.
    """
    if interpret is None:
        interpret = _interpret()
    nb, bs = pool_leaf.shape[:2]
    feat = pool_leaf.shape[2:]
    F = int(np.prod(feat, dtype=np.int64)) if feat else 1
    S, mb = block_tables.shape
    pool3 = pool_leaf.reshape(nb, bs, F)

    def kernel(bt_ref, pool_ref, out_ref):
        del bt_ref  # consumed by the index_map, not the body
        out_ref[0, 0] = pool_ref[0]

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(S, mb),
            in_specs=[
                pl.BlockSpec((1, bs, F), lambda s, j, bt: (bt[s, j], 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, bs, F),
                                   lambda s, j, bt: (s, j, 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((S, mb, bs, F), pool_leaf.dtype),
        interpret=interpret,
    )(block_tables, pool3)
    return out.reshape((S, mb, bs) + feat)


def gather_blocks_ref(pool_leaf, block_tables):
    """jnp reference gather (oracle for the kernel; also the admission-path
    context gather, where one slot's blocks are fetched off the hot path)."""
    return jnp.take(pool_leaf, block_tables, axis=0)
