"""Pure-step replay — the RSI (Recoverable Sequence of Instructions) rung.

The paper replays a cloned address computation over *terminal values* that
are still intact in the process image.  The training-loop analogue observes
that the whole step function is pure:

    state_t = step(state_{t-1}, batch(t-1)),   batch(t) = f(seed, t)

so given any *verified* snapshot at step t0 <= t, the exact state at t is
recomputable by replaying (t - t0) deterministic steps — no I/O, no lost
work beyond the replayed window, bit-exact on the same topology.

The snapshot plays the paper's "terminal values" role: the micro-checkpointer
guarantees (by digest verification — our liveness analysis) that the replay
inputs are intact before we trust them.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import jax
import numpy as np


@dataclass
class ReplayResult:
    state: object
    steps_replayed: int
    from_step: int
    to_step: int


def device_put_like(host_state, like_state=None, shardings=None):
    """Move a host snapshot back to device buffers.

    ``like_state`` shards each leaf like the live reference; ``shardings``
    (a pytree of shardings) serves the donated-mesh case where no live
    reference exists — the snapshot upload itself is shard-local: every
    device receives only its addressable slice of each leaf, never a full
    replicated copy (DESIGN.md §5)."""
    if like_state is None and shardings is not None:
        return jax.device_put(host_state, shardings)
    if like_state is None:
        return jax.tree_util.tree_map(jax.numpy.asarray, host_state)

    def put(host_leaf, live_leaf):
        try:
            sharding = live_leaf.sharding
        except AttributeError:
            sharding = None
        if sharding is not None:
            return jax.device_put(host_leaf, sharding)
        return jax.numpy.asarray(host_leaf)

    return jax.tree_util.tree_map(put, host_state, like_state)


def replay(step_fn: Callable, batch_fn: Callable, snapshot_state,
           from_step: int, to_step: int, *, like_state=None,
           shardings=None, on_step: Optional[Callable] = None
           ) -> ReplayResult:
    """Replay ``step_fn`` from ``from_step`` (exclusive state snapshot taken
    *before* executing step ``from_step``) up to (but not including)
    ``to_step``.

    step_fn(state, batch) -> (state, metrics); batch_fn(step) -> batch.
    ``shardings`` places the snapshot on a mesh when no ``like_state``
    reference survives (donated loops).
    """
    assert to_step >= from_step, (from_step, to_step)
    state = device_put_like(snapshot_state, like_state, shardings)
    for s in range(from_step, to_step):
        state, _ = step_fn(state, batch_fn(s))
        if on_step is not None:
            on_step(s, state)
    return ReplayResult(state=state, steps_replayed=to_step - from_step,
                        from_step=from_step, to_step=to_step)
