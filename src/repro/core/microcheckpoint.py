"""Micro-checkpoints — the paper's Algorithm 2 at training-loop scale.

The paper spills induction-variable *initial values* to the stack so Eq. (1)
is evaluable at recovery time.  Our two-tier analogue:

* **IV micro-checkpoint** (every step, bytes): the iv block + its digests.
  This is literally the paper's mechanism — the loop-control initial/current
  values, kept where the recovery runtime can always reach them.
* **state snapshot** (every K steps, double-buffered, in-HBM/host-RAM):
  a full train-state copy + per-leaf digests, giving the replay rung a
  nearby anchor.  No disk I/O on the recovery path — that is the entire
  near-zero-downtime claim vs classic C/R.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import digest as kdigest


def host_copy(tree):
    """Materialised host copy of a device tree, safe under donation.

    Routed through a device-side temp: converting the LIVE array to
    numpy can cache a zero-copy host view on it (the bf16 path does),
    which pins the buffer and silently vetoes ``donate_argnums``
    in-place reuse for the array's lifetime.  The temp absorbs the
    view/cache and is dropped; the copy owns its bytes either way.
    Shared by the micro-checkpointer and ``checkpoint.store`` — every
    host copy of live state must go through here.
    """
    return jax.tree_util.tree_map(
        lambda x: np.asarray(jnp.array(x, copy=True)), tree)


_host_copy = host_copy


@dataclass
class Snapshot:
    step: int
    state: object
    digests: Dict[str, np.ndarray]
    nbytes: int = 0                  # cached at snapshot time
    wall: float = field(default_factory=time.time)
    #: mesh metadata (sharded loops; DESIGN.md §5): per leaf, the global
    #: index tuple each shard id addresses (mesh-flat device order) and
    #: the per-shard host digests of exactly those bytes.  This is what
    #: lets the shard_patch recovery rung carve a SINGLE injured shard's
    #: bytes out of the host copy, certify them, and restore only that
    #: shard's addressable state.
    shard_slices: Optional[Dict[str, List]] = None
    shard_digests: Optional[Dict[str, np.ndarray]] = None


class MicroCheckpointer:
    """Double-buffered in-memory snapshots + per-step IV micro-checkpoints.

    ``ctx`` (a ``DistContext`` with a live mesh) switches snapshots to
    shard-aware mode: alongside the per-leaf digests, every snapshot
    records each leaf's shard→index map and per-shard host digests
    (``Snapshot.shard_slices``/``shard_digests``), so recovery can verify
    and restore individual (leaf, shard) units instead of whole states.
    The host copy itself is unchanged (one DMA read of the live state);
    the shard digests are a second host-side hashing pass over the same
    bytes, off the hot path."""

    def __init__(self, interval: int = 8, keep: int = 2, ctx=None):
        self.interval = max(1, interval)
        self.keep = max(1, keep)
        self.ctx = ctx if (ctx is not None and ctx.enabled) else None
        self.snapshots: List[Snapshot] = []
        self.iv_log: Dict[int, Dict[str, int]] = {}

    # -- per-step (bytes) ----------------------------------------------------

    def record_iv(self, step: int, iv: Dict) -> None:
        self.iv_log[step] = {k: int(v) for k, v in iv.items()}
        # bounded memory: keep a window
        if len(self.iv_log) > 4 * self.interval:
            for s in sorted(self.iv_log)[:-2 * self.interval]:
                del self.iv_log[s]

    # -- every K steps (double-buffered) --------------------------------------

    def maybe_snapshot(self, step: int, state) -> bool:
        if step % self.interval != 0:
            return False
        self.snapshot(step, state)
        return True

    def snapshot(self, step: int, state) -> None:
        # ONE read of the live state: the host copy is the only
        # device→host movement; digests are computed FROM THAT COPY on the
        # host (numpy uint32 wraparound, bit-identical to the kernel) and
        # certify exactly the bytes stored.  No device re-upload: on TPU
        # the digest rides the host DMA path, and under ``donate_argnums``
        # loops the snapshot never competes with the step for the donated
        # buffers.
        host = _host_copy(state)
        shard_slices = shard_digests = None
        if self.ctx is not None:
            # shard-aware metadata: index maps from the LIVE shardings,
            # digests from the host copy's bytes (never re-read the
            # device) — per (leaf, shard), in mesh-flat shard order
            shard_slices, shard_digests = {}, {}
            flat_live = jax.tree_util.tree_flatten_with_path(state)[0]
            flat_host = jax.tree_util.tree_leaves(host)
            for (path, live), hleaf in zip(flat_live, flat_host):
                key = kdigest.leaf_key(path)
                idxs = kdigest.shard_indices(live)
                shard_slices[key] = idxs
                # hash each DISTINCT slice once: a replicated leaf maps
                # every shard to the same full-leaf index, and hashing it
                # D times would make snapshots O(replicated_bytes x D)
                seen: Dict[Tuple, np.ndarray] = {}
                rows = []
                for idx in idxs:
                    k = tuple((s.start, s.stop, s.step)
                              if isinstance(s, slice) else s for s in idx)
                    if k not in seen:
                        seen[k] = kdigest.host_checksum(hleaf[idx])
                    rows.append(seen[k])
                shard_digests[key] = np.stack(rows)
        snap = Snapshot(step=step, state=host,
                        digests=kdigest.host_tree_checksums(host),
                        nbytes=sum(leaf.nbytes for leaf in
                                   jax.tree_util.tree_leaves(host)),
                        shard_slices=shard_slices,
                        shard_digests=shard_digests)
        self.snapshots.append(snap)
        if len(self.snapshots) > self.keep:
            self.snapshots.pop(0)

    def latest(self, before: Optional[int] = None) -> Optional[Snapshot]:
        cands = [s for s in self.snapshots
                 if before is None or s.step <= before]
        return cands[-1] if cands else None

    def verify(self, snap: Snapshot) -> List[str]:
        """Digest-verify a snapshot before trusting it for replay
        (exact-or-abort: a rotted snapshot must not silently replay).
        Entirely host-side — the stored bytes are hashed where they live,
        with no device upload."""
        return kdigest.host_verify_tree(snap.state, snap.digests)

    def verify_shards(self, snap: Snapshot,
                      shards: Dict[str, List[int]]) -> List[str]:
        """Digest-verify only the named (leaf, shard) units of a snapshot
        — the shard_patch rung's exact-or-abort gate.  Hashes ONLY the
        bytes that would be restored; returns ``"leaf@shard"`` names that
        fail (empty = all certified).  Host-side, no device work."""
        if snap.shard_slices is None or snap.shard_digests is None:
            return sorted(f"{k}@{d}" for k, ds in shards.items() for d in ds)
        host = {kdigest.leaf_key(p): leaf for p, leaf in
                jax.tree_util.tree_flatten_with_path(snap.state)[0]}
        bad = []
        for key, ids in shards.items():
            idxs = snap.shard_slices.get(key)
            ref = snap.shard_digests.get(key)
            leaf = host.get(key)
            for d in ids:
                if idxs is None or ref is None or leaf is None \
                        or d >= len(idxs):
                    bad.append(f"{key}@{d}")
                    continue
                cur = kdigest.host_checksum(leaf[idxs[d]])
                if not np.array_equal(cur, ref[d]):
                    bad.append(f"{key}@{d}")
        return sorted(bad)

    @property
    def memory_bytes(self) -> int:
        """Resident snapshot footprint — cached per snapshot at capture
        time (the seed re-materialised every leaf with ``np.asarray`` on
        each property read)."""
        return sum(s.nbytes for s in self.snapshots)
