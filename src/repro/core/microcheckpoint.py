"""Micro-checkpoints — the paper's Algorithm 2 at training-loop scale.

The paper spills induction-variable *initial values* to the stack so Eq. (1)
is evaluable at recovery time.  Our two-tier analogue:

* **IV micro-checkpoint** (every step, bytes): the iv block + its digests.
  This is literally the paper's mechanism — the loop-control initial/current
  values, kept where the recovery runtime can always reach them.
* **state snapshot** (every K steps, double-buffered, in-HBM/host-RAM):
  a full train-state copy + per-leaf digests, giving the replay rung a
  nearby anchor.  No disk I/O on the recovery path — that is the entire
  near-zero-downtime claim vs classic C/R.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import digest as kdigest


def host_copy(tree):
    """Materialised host copy of a device tree, safe under donation.

    Routed through a device-side temp: converting the LIVE array to
    numpy can cache a zero-copy host view on it (the bf16 path does),
    which pins the buffer and silently vetoes ``donate_argnums``
    in-place reuse for the array's lifetime.  The temp absorbs the
    view/cache and is dropped; the copy owns its bytes either way.
    Shared by the micro-checkpointer and ``checkpoint.store`` — every
    host copy of live state must go through here.
    """
    return jax.tree_util.tree_map(
        lambda x: np.asarray(jnp.array(x, copy=True)), tree)


_host_copy = host_copy


@dataclass
class Snapshot:
    step: int
    state: object
    digests: Dict[str, np.ndarray]
    nbytes: int = 0                  # cached at snapshot time
    wall: float = field(default_factory=time.time)


class MicroCheckpointer:
    """Double-buffered in-memory snapshots + per-step IV micro-checkpoints."""

    def __init__(self, interval: int = 8, keep: int = 2):
        self.interval = max(1, interval)
        self.keep = max(1, keep)
        self.snapshots: List[Snapshot] = []
        self.iv_log: Dict[int, Dict[str, int]] = {}

    # -- per-step (bytes) ----------------------------------------------------

    def record_iv(self, step: int, iv: Dict) -> None:
        self.iv_log[step] = {k: int(v) for k, v in iv.items()}
        # bounded memory: keep a window
        if len(self.iv_log) > 4 * self.interval:
            for s in sorted(self.iv_log)[:-2 * self.interval]:
                del self.iv_log[s]

    # -- every K steps (double-buffered) --------------------------------------

    def maybe_snapshot(self, step: int, state) -> bool:
        if step % self.interval != 0:
            return False
        self.snapshot(step, state)
        return True

    def snapshot(self, step: int, state) -> None:
        # ONE read of the live state: the host copy is the only
        # device→host movement; digests are computed FROM THAT COPY on the
        # host (numpy uint32 wraparound, bit-identical to the kernel) and
        # certify exactly the bytes stored.  No device re-upload: on TPU
        # the digest rides the host DMA path, and under ``donate_argnums``
        # loops the snapshot never competes with the step for the donated
        # buffers.
        host = _host_copy(state)
        snap = Snapshot(step=step, state=host,
                        digests=kdigest.host_tree_checksums(host),
                        nbytes=sum(leaf.nbytes for leaf in
                                   jax.tree_util.tree_leaves(host)))
        self.snapshots.append(snap)
        if len(self.snapshots) > self.keep:
            self.snapshots.pop(0)

    def latest(self, before: Optional[int] = None) -> Optional[Snapshot]:
        cands = [s for s in self.snapshots
                 if before is None or s.step <= before]
        return cands[-1] if cands else None

    def verify(self, snap: Snapshot) -> List[str]:
        """Digest-verify a snapshot before trusting it for replay
        (exact-or-abort: a rotted snapshot must not silently replay).
        Entirely host-side — the stored bytes are hashed where they live,
        with no device upload."""
        return kdigest.host_verify_tree(snap.state, snap.digests)

    @property
    def memory_bytes(self) -> int:
        """Resident snapshot footprint — cached per snapshot at capture
        time (the seed re-materialised every leaf with ``np.asarray`` on
        each property read)."""
        return sum(s.nbytes for s in self.snapshots)
