"""IterPro's contribution, adapted to the training/serving loop (DESIGN §4).

Detection (detect) -> diagnosis (recovery_table) -> repair (recover, via
induction/icp, parity, microcheckpoint, replay) -> exact-or-abort verify.
"""

from repro.core.detect import ChecksumCanary, FaultReport, trap_loss_spike, trap_nonfinite  # noqa: F401
from repro.core.faults import InjectionPlan, flip_bit, inject, inject_shard_loss, sample_plan  # noqa: F401
from repro.core.fused_step import FusedStepFactory  # noqa: F401
from repro.core.icp import promote, recoverable_iv_count  # noqa: F401
from repro.core.induction import IVRegistry, IVSpec, RecoveryAbort  # noqa: F401
from repro.core.microcheckpoint import MicroCheckpointer, Snapshot  # noqa: F401
from repro.core.parity import ParityPlan, ParityStore, parity_plan_for  # noqa: F401
from repro.core.recover import RecoveryEvent, RecoveryFailed, RecoveryRuntime  # noqa: F401
from repro.core.recovery_table import RecoveryTable, TableEntry  # noqa: F401
from repro.core.replay import ReplayResult, replay  # noqa: F401
