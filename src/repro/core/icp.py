"""Independent Compute Promotion (ICP) — the paper's Algorithm 1, applied to
training-loop state.

The paper's compiler pass promotes *derived* induction values (``i + 1``
inside an unrolled body) into *independent* induction variables with their
own PHI/update, because only independent copies can recover each other.

The training-loop analogue: counters like ``tokens_seen`` or
``data_offset`` are naturally *derived* (``step * global_batch``) — a
corruption of ``step`` corrupts every derived value computed from it.  ICP
here rewrites a derived-counter specification into independent state that
advances by its own literal increment each iteration (see
``train/loop.py:advance_iv``), and registers the (init, step) pair with the
IVRegistry so Eq. (1) applies.

``promote`` is the framework's ICP entry point: given the loop description
(global batch, microbatch count), it returns the registry of independent
IVs — the moral equivalent of running Algorithm 1 over the loop body.
"""

from __future__ import annotations

from typing import Dict, Tuple

from repro.core.induction import IVRegistry


def derived_counters(global_batch: int, n_micro: int) -> Dict[str, Tuple[int, int]]:
    """The affine family each counter belongs to: name -> (init, step).

    Before ICP these would be *expressions* over ``step``; after ICP each is
    independent loop state with the same affine semantics.
    """
    return {
        "step": (0, 1),
        "data_offset": (0, global_batch),
        "rng_counter": (0, 1),
        "sched_pos": (0, 1),
        "micro_count": (0, max(n_micro, 1)),
    }


def promote(arch_cfg, global_batch: int) -> IVRegistry:
    """ICP: emit the independent-IV registry for this training loop."""
    n_micro = max(arch_cfg.train.microbatch, 1)
    return IVRegistry(derived_counters(global_batch, n_micro))


def recoverable_iv_count(arch_cfg, global_batch: int,
                         icp_enabled: bool = True) -> int:
    """How many IVs are recoverable — the Table-6 metric.

    Without ICP only ``step`` exists as true loop state (everything else is
    derived from it), so a corruption of the one counter has *no partner* to
    recover from: 0 recoverable.  With ICP every promoted counter has ≥1
    independent partner: all are recoverable.
    """
    n = len(derived_counters(global_batch,
                             max(arch_cfg.train.microbatch, 1)))
    return n if icp_enabled else 0
