"""Independent Compute Promotion (ICP) — the paper's Algorithm 1, applied to
training-loop state.

The paper's compiler pass promotes *derived* induction values (``i + 1``
inside an unrolled body) into *independent* induction variables with their
own PHI/update, because only independent copies can recover each other.

The training-loop analogue: counters like ``tokens_seen`` or
``data_offset`` are naturally *derived* (``step * global_batch``) — a
corruption of ``step`` corrupts every derived value computed from it.  ICP
here rewrites a derived-counter specification into independent state that
advances by its own literal increment each iteration (see
``train/loop.py:advance_iv``), and registers the (init, step) pair with the
IVRegistry so Eq. (1) applies.

``promote`` is the framework's ICP entry point: given the loop description
(global batch, microbatch count), it returns the registry of independent
IVs — the moral equivalent of running Algorithm 1 over the loop body.

Registry keys are FULL train-state leaf paths (``iv/step``, ``opt/t``, …)
so the recovery runtime can match a ``FaultReport``'s injured leaves against
the registry directly.  Two fragments are merged:

* the loop's own counters under ``iv/`` (``derived_counters`` +
  ``optim.schedules.induction_specs`` for the schedule position);
* the optimizer-owned induction state under ``opt/`` — the step counter
  ``t`` as an affine IV, and bias-correction / decay factors as *derived*
  entries recomputable from the consensus iteration (an ICP-exposed side
  effect: because the affine counters are independent, the consensus n is
  always available to recompute any pure function of it in place).
"""

from __future__ import annotations

from typing import Dict, Tuple

from repro.core.induction import IVRegistry
from repro.optim.optimizers import make_optimizer
from repro.optim.schedules import induction_specs as schedule_induction_specs


def derived_counters(global_batch: int, n_micro: int) -> Dict[str, Tuple[int, int]]:
    """The affine family each counter belongs to: name -> (init, step).

    Before ICP these would be *expressions* over ``step``; after ICP each is
    independent loop state with the same affine semantics.
    """
    counters = {
        "step": (0, 1),
        "data_offset": (0, global_batch),
        "rng_counter": (0, 1),
        "micro_count": (0, max(n_micro, 1)),
    }
    counters.update(schedule_induction_specs())
    return counters


def optimizer_iv_specs(arch_cfg):
    """(affine, derived) optimizer-state induction specs, keyed by full
    ``opt/…`` leaf path — exported by the optimizer that owns the state."""
    opt = make_optimizer(arch_cfg.train)
    affine = {f"opt/{name}": spec for name, spec in opt.affine_ivs.items()}
    derived = {f"opt/{name}": fn for name, fn in opt.derived_ivs.items()}
    return affine, derived


def promote(arch_cfg, global_batch: int) -> IVRegistry:
    """ICP: emit the independent-IV registry for this training loop,
    covering both the ``iv/`` counter block and the optimizer's own
    induction state (keys are full train-state leaf paths)."""
    n_micro = max(arch_cfg.train.microbatch, 1)
    specs = {f"iv/{name}": spec
             for name, spec in derived_counters(global_batch, n_micro).items()}
    opt_affine, opt_derived = optimizer_iv_specs(arch_cfg)
    specs.update(opt_affine)
    return IVRegistry(specs, derived=opt_derived)


def recoverable_iv_count(arch_cfg, global_batch: int,
                         icp_enabled: bool = True) -> int:
    """How many IVs are recoverable — the Table-6 metric.

    Without ICP only ``step`` exists as true loop state (everything else is
    derived from it), so a corruption of the one counter has *no partner* to
    recover from: 0 recoverable.  With ICP every promoted counter has ≥1
    independent partner, and every derived optimizer entry is recomputable
    from the consensus: all are recoverable.
    """
    reg = promote(arch_cfg, global_batch)
    return len(reg.specs) + len(reg.derived) if icp_enabled else 0
