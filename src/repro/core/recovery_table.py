"""The Recovery Table — the paper's §3.4 metadata, for train-state leaves.

Paper columns: (key, symbol, parameters) where *key* identifies the faulting
instruction, *symbol* names the recovery kernel and *parameters* name the
terminal values the kernel replays from.

Here: *key* is the state-leaf path, *symbol* is the ordered recovery ladder
(the escalation sequence of recovery kernels applicable to that leaf) and
*parameters* are the inputs each rung needs.  Built once per run
("compile time") and serialisable next to checkpoint metadata.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field, asdict
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.kernels.ops import leaf_key


RUNG_TRIAGE = "triage"           # rung 0: classify + tolerate (no repair)
RUNG_EQ1 = "eq1"                 # induction-variable partner recovery
RUNG_OPT_IV = "opt_iv"           # optimizer-state induction repair (Eq.(1))
RUNG_SHARD = "shard_patch"       # restore only the injured shard's bytes
RUNG_REPLICA = "replica_vote"    # TMR vote across DP replicas
RUNG_PARITY = "parity_xor"       # XOR parity reconstruction
RUNG_REPLAY = "replay"           # pure-step replay from snapshot
RUNG_REMESH = "remesh"           # hard loss: shrink the mesh, keep training
RUNG_CHECKPOINT = "checkpoint"   # classic restore (last resort)


@dataclass(frozen=True)
class TableEntry:
    key: str                      # leaf path
    ladder: Tuple[str, ...]       # ordered recovery kernels
    params: Tuple[str, ...]       # terminal values the first rung consumes
    dtype: str = ""
    shape: Tuple[int, ...] = ()


class RecoveryTable:
    def __init__(self, entries: Dict[str, TableEntry]):
        self.entries = entries

    @classmethod
    def build(cls, state, *, replicated: bool = False,
              parity: bool = False, sharded: bool = False,
              triage: bool = False, elastic: bool = False,
              opt_ivs: Tuple[str, ...] = ()) -> "RecoveryTable":
        """Construct the table for a train state.

        replicated: DP replica copies exist (pure-DP leaves) -> replica rung
        parity:     parity shards are maintained -> parity rung
        sharded:    the loop runs on a mesh with shard-aware snapshots ->
                    the shard_patch rung (restore only the injured shard's
                    addressable bytes) leads every non-IV ladder.  The
                    rung gates itself at recovery time (it aborts into
                    the rest of the ladder when the report carries no
                    (leaf, shard) attribution, when the state was donated
                    or when no version-matched snapshot exists), so
                    listing it here is safe for trap-detected faults too.
        triage:     a canary maintains digest references and the runtime
                    runs with ``triage=True`` -> rung 0 (classify +
                    tolerate) leads every non-induction ladder.  Like
                    shard_patch it self-gates at recovery time (aborts
                    into the rest of the ladder when no certificate
                    holds), so listing it is always safe.
        elastic:    an ElasticManager is attached (launch/elastic.py) ->
                    the remesh rung sits between replay and the classic
                    checkpoint restore in EVERY ladder: any escalation
                    that would otherwise abort to disk first tries to
                    shrink the mesh onto the survivors.  The rung
                    self-gates at recovery time (aborts unless the report
                    names lost rows), so listing it is always safe; a
                    hard-loss report short-circuits straight to it via
                    ``RecoveryRuntime._ladder``.
        opt_ivs:    full paths of optimizer-owned induction leaves
                    (``core.icp.promote`` registry keys under ``opt/``):
                    their ladder leads with the opt_iv branch of the
                    Eq. (1) consensus engine, partnered by the whole
                    induction registry, instead of paying replay.
        """
        entries: Dict[str, TableEntry] = {}
        iv_names = sorted(state.get("iv", {}))
        opt_iv_set = set(opt_ivs)

        tail = (RUNG_REPLAY, RUNG_REMESH, RUNG_CHECKPOINT) if elastic \
            else (RUNG_REPLAY, RUNG_CHECKPOINT)

        def visit(path, leaf):
            key = leaf_key(path)
            arr = np.asarray(leaf)
            if key.startswith("iv/"):
                partners = tuple(f"iv/{n}" for n in iv_names
                                 if f"iv/{n}" != key)
                ladder = (RUNG_EQ1,) + tail
                params = partners
            elif key in opt_iv_set:
                partners = tuple(f"iv/{n}" for n in iv_names) + tuple(
                    k for k in sorted(opt_iv_set) if k != key)
                ladder = (RUNG_OPT_IV,) + tail
                params = partners
            else:
                rungs: List[str] = []
                if triage:
                    rungs.append(RUNG_TRIAGE)
                if sharded:
                    rungs.append(RUNG_SHARD)
                if replicated:
                    rungs.append(RUNG_REPLICA)
                if parity:
                    rungs.append(RUNG_PARITY)
                rungs += list(tail)
                ladder = tuple(rungs)
                params = ("snapshot", "iv/step")
            entries[key] = TableEntry(key=key, ladder=ladder, params=params,
                                      dtype=str(arr.dtype),
                                      shape=tuple(arr.shape))
            return leaf

        jax.tree_util.tree_map_with_path(visit, state)
        return cls(entries)

    def lookup(self, key: str) -> Optional[TableEntry]:
        if key in self.entries:
            return self.entries[key]
        # prefix match (a report may name a subtree)
        for k, e in self.entries.items():
            if k.startswith(key) or key.startswith(k):
                return e
        return None

    def to_json(self) -> str:
        return json.dumps({k: asdict(e) for k, e in self.entries.items()},
                          indent=1, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "RecoveryTable":
        raw = json.loads(text)
        return cls({k: TableEntry(key=v["key"], ladder=tuple(v["ladder"]),
                                  params=tuple(v["params"]),
                                  dtype=v.get("dtype", ""),
                                  shape=tuple(v.get("shape", ())))
                    for k, v in raw.items()})

    def __len__(self):
        return len(self.entries)
