"""Fault injection harness — reproduces the paper's §5.1 methodology on this
framework's failure domain.

Paper: pick a dynamic instruction weighted by execution count, flip one bit
in its destination operand, observe the outcome (Benign / Crash / SDC /
Hang) and the manifestation latency.

Here: pick a train-state leaf weighted by element count (the execution-
weighted analogue — large tensors are touched proportionally more), flip one
bit of one element at a chosen step, and classify the outcome by running the
instrumented loop:
  * Benign  — detectors stay silent AND the final state matches fault-free
              (e.g. flip of a dead mantissa bit, or masked by the optimizer)
  * Crash   — a trap fires (non-finite loss / checksum mismatch): the
              TPU-domain analogue of SIGSEGV; recovery is attempted
  * SDC     — no trap, but the trajectory diverges from fault-free
  * Hang    — loss stops improving for a window (proxy; true hangs do not
              occur in a pure dataflow program)
"""

from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref as kref
from repro.kernels.ops import leaf_key


@dataclass(frozen=True)
class InjectionPlan:
    leaf: str          # leaf path key
    element: int       # flat element index
    bit: int           # bit position within the element's width
    step: int          # training step at which to inject
    target: str = "params"  # 'params' | 'opt' | 'iv' | 'activations'


def _leaf_catalog(tree) -> List[Tuple[str, int, str]]:
    """[(key, size, dtype_name)] for every array leaf."""
    out = []

    def visit(path, leaf):
        arr = np.asarray(leaf)
        out.append((leaf_key(path), int(arr.size), str(arr.dtype)))
        return leaf

    jax.tree_util.tree_map_with_path(visit, tree)
    return out


def sample_plan(rng: random.Random, state, max_step: int,
                target: str = "params") -> InjectionPlan:
    """Size-weighted leaf choice; uniform element/bit/step — the paper's
    execution-weighted single-bit-flip model."""
    tree = state[target] if target in ("params", "opt", "iv") else state
    catalog = _leaf_catalog(tree)
    sizes = [s for (_, s, _) in catalog]
    total = sum(sizes)
    pick = rng.randrange(total)
    acc = 0
    for key, size, dtype in catalog:
        acc += size
        if pick < acc:
            width = {"float32": 32, "int32": 32, "uint32": 32,
                     "bfloat16": 16, "float16": 16, "int16": 16,
                     "int8": 8, "uint8": 8}.get(dtype, 32)
            return InjectionPlan(
                leaf=key,
                element=rng.randrange(size),
                bit=rng.randrange(width),
                step=rng.randrange(max_step),
                target=target,
            )
    raise AssertionError("unreachable")


def _signed_mask(bit: int, width: int):
    """1<<bit as a signed value of ``width`` bits (wraps the sign bit)."""
    return int(np.uint64(1 << bit).astype({32: np.int32, 16: np.int16,
                                           8: np.int8}[width]))


def flip_bit(arr: jnp.ndarray, element: int, bit: int) -> jnp.ndarray:
    """Flip one bit of one element, preserving dtype/shape (pure)."""
    a = jnp.asarray(arr)
    shape, dtype = a.shape, a.dtype
    if dtype in (jnp.float32, jnp.uint32):
        i = jax.lax.bitcast_convert_type(a, jnp.int32).reshape(-1)
        i = i.at[element].set(i[element] ^ jnp.int32(_signed_mask(bit, 32)))
        return jax.lax.bitcast_convert_type(i.reshape(shape), dtype)
    if dtype == jnp.int32:
        f = a.reshape(-1)
        f = f.at[element].set(f[element] ^ jnp.int32(_signed_mask(bit, 32)))
        return f.reshape(shape)
    if dtype in (jnp.bfloat16, jnp.float16, jnp.int16):
        i = jax.lax.bitcast_convert_type(a.reshape(-1), jnp.int16)
        i = i.at[element].set(
            i[element] ^ jnp.int16(_signed_mask(min(bit, 15), 16)))
        return jax.lax.bitcast_convert_type(i, dtype).reshape(shape)
    if dtype in (jnp.int8, jnp.uint8):
        f = a.reshape(-1)
        f = f.at[element].set(
            f[element] ^ jnp.asarray(_signed_mask(min(bit, 7), 8), dtype))
        return f.reshape(shape)
    raise TypeError(f"unsupported dtype {dtype}")


def inject(state, plan: InjectionPlan):
    """Apply the plan to a train state (returns a new state)."""
    if plan.target in ("params", "opt", "iv"):
        tree = state[plan.target]
        out = dict(state)
        out[plan.target] = _inject_tree(tree, plan)
        return out
    return _inject_tree(state, plan)  # plan sampled over the whole tree


def _inject_tree(tree, plan: InjectionPlan):
    hit = {"done": False}

    def visit(path, leaf):
        if leaf_key(path) == plan.leaf and not hit["done"]:
            hit["done"] = True
            flipped = flip_bit(leaf, plan.element, plan.bit)
            # mesh state: the flip's bitcast/reshape chain must not change
            # the leaf's layout — an adversary corrupts bytes in place, it
            # does not reshard the victim
            sharding = getattr(leaf, "sharding", None)
            if isinstance(sharding, jax.sharding.NamedSharding):
                flipped = jax.device_put(flipped, sharding)
            return flipped
        return leaf

    out = jax.tree_util.tree_map_with_path(visit, tree)
    if not hit["done"]:
        raise KeyError(f"leaf not found: {plan.leaf}")
    return out


def inject_shard_loss(state, leaf_frac: float, rng: random.Random,
                      target: str = "params"):
    """Simulate a lost device: NaN-out a contiguous fraction of every leaf
    of the target tree (the shard that lived on the dead chip)."""
    def visit(path, leaf):
        arr = jnp.asarray(leaf)
        if not jnp.issubdtype(arr.dtype, jnp.floating):
            return leaf
        n = arr.size
        k = max(1, int(n * leaf_frac))
        start = rng.randrange(max(n - k, 1))
        flat = arr.reshape(-1)
        flat = flat.at[start:start + k].set(jnp.nan)
        return flat.reshape(arr.shape)

    out = dict(state)
    out[target] = jax.tree_util.tree_map_with_path(visit, state[target])
    return out
