"""RecoveryRuntime — the paper's §3.5 runtime, for the training loop.

The paper's runtime is a SIGSEGV handler: inactive on the hot path, invoked
only on a fault, it looks up the recovery kernel in the Recovery Table,
pulls the kernel's parameters out of the stalled process image and replays
the RSI; recovery is exact-or-abort.

This runtime wraps a training loop the same way: it does *nothing* until a
``FaultReport`` arrives (from a detector or from an external signal such as
a device loss), then walks the leaf's recovery ladder:

    rung 0  triage        FlipTracker-style classification BEFORE any
                          repair: localise the flip from the digest pair
                          and TOLERATE it (re-arm the digests, zero work)
                          when a certificate proves it harmless — dead
                          (never-read) bytes or a below-epsilon mantissa
                          perturbation in an EMA moment
    rung 1  eq1 / opt_iv  induction-state partner recovery (Eq. (1), ns):
                          the ``iv`` counter block AND the optimizer-owned
                          induction leaves (step counter ``t`` affine,
                          bias-correction/decay factors recomputed from
                          the consensus iteration)
    rung 2  shard_patch   restore ONLY the injured shard's addressable
                          bytes from a version-matched, digest-certified
                          micro-snapshot (mesh loops; DESIGN.md §5)
    rung 3  replica_vote  bitwise TMR vote across DP replicas
    rung 4  parity_xor    XOR parity shard reconstruction
    rung 5  replay        pure-step replay from a verified micro-snapshot
    rung 6  checkpoint    classic disk restore (the paper's strawman)

Every rung's repair is digest-verified before the loop resumes; a rung that
cannot certify an exact repair escalates (the abort-instead-of-SDC rule,
§5.3.1).  The runtime records per-recovery telemetry (rung used, wall time,
steps lost) — the data behind the Fig-7/8 benchmarks.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.detect import ChecksumCanary, FaultReport, block_of_leaf
from repro.core.induction import IVRegistry, RecoveryAbort
from repro.core.microcheckpoint import MicroCheckpointer
from repro.core.parity import ParityStore
from repro.core.recovery_table import (
    RUNG_CHECKPOINT,
    RUNG_EQ1,
    RUNG_OPT_IV,
    RUNG_PARITY,
    RUNG_REMESH,
    RUNG_REPLAY,
    RUNG_REPLICA,
    RUNG_SHARD,
    RUNG_TRIAGE,
    RecoveryTable,
)
from repro.core.replay import device_put_like, replay
from repro.kernels import digest as kdigest
from repro.kernels import ops as kops
from repro.optim.optimizers import QBLOCK

#: triage epsilon certificate: a mantissa perturbation of an EMA moment is
#: tolerable when |new - old| <= max(REL_EPS * max(|old|, |new|), ABS_FLOOR)
#: — the induced relative error in the update direction is of the same
#: order, far below the optimizer's own stochastic noise floor.
TRIAGE_REL_EPS = 1e-5
TRIAGE_ABS_FLOOR = 1e-12


@dataclass
class RecoveryEvent:
    """Telemetry for one recovery (one Fig-8 sample)."""
    step: int
    report: FaultReport
    rung: str = ""                 # rung that succeeded
    attempted: List[str] = field(default_factory=list)
    wall_seconds: float = 0.0
    steps_replayed: int = 0
    bytes_moved: int = 0           # host→device bytes (shard_patch rung)
    recovered: bool = False
    phase_seconds: Dict[str, float] = field(default_factory=dict)


class RecoveryFailed(RuntimeError):
    """Every rung exhausted — the job must fall back to cold restart."""


class RecoveryRuntime:
    """Off-hot-path recovery engine for a pure training loop.

    Parameters
    ----------
    step_fn     : jitted step(state, batch) -> (state, metrics)
    batch_fn    : pure batch_fn(step) -> batch  (index-addressable pipeline)
    iv_registry : IVRegistry from ``core.icp.promote`` (ICP output)
    micro       : MicroCheckpointer (per-step IV log + K-step snapshots)
    parity      : optional ParityStore (core/parity.py) over the
                  param/opt shards — the device-resident XOR parity the
                  canary maintains in-launch; enables the parity_xor rung
    replicas    : optional callable step -> list of ≥2 healthy replica state
                  trees (pure-DP deployments); used by the TMR rung
    checkpoint  : optional (load_fn() -> (state, step)) — disk restore
    canary      : optional ChecksumCanary over the same state — the parity
                  rung localises finite flips against its reference table
                  (per-shard digests on a mesh, trial reconstruction
                  off-mesh) and digest-certifies every reconstruction
                  before resume
    donated     : the loop runs its step with ``donate_argnums``: on a
                  trap the pre-step state buffers have been consumed by
                  the step and MUST NOT be touched — the ladder pivots
                  unconditionally to the in-HBM micro-snapshot + IV
                  replay rung (then classic checkpoint), and replay does
                  not consult the dead state for sharding
    shardings   : pytree of NamedShardings for the train state (mesh
                  loops) — places replayed snapshots back on the mesh
                  when donation left no live reference, each device
                  receiving only its addressable slice
    triage      : enable rung 0 — classify the injured (leaf, shard)
                  against the canary's reference digest pair BEFORE any
                  repair, and tolerate certified-harmless flips in place
                  (zero bytes moved, zero steps replayed).  Requires a
                  canary; only checksum reports with live buffers are
                  classifiable, everything else falls straight through
    """

    def __init__(self, *, step_fn, batch_fn, iv_registry: IVRegistry,
                 micro: MicroCheckpointer,
                 parity: Optional[ParityStore] = None,
                 replicas: Optional[Callable] = None,
                 checkpoint: Optional[Callable] = None,
                 table: Optional[RecoveryTable] = None,
                 donated: bool = False,
                 shardings=None,
                 canary: Optional[ChecksumCanary] = None,
                 triage: bool = False,
                 elastic: Optional[Callable] = None):
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.ivs = iv_registry
        self.micro = micro
        self.parity = parity
        self.replicas = replicas
        self.checkpoint = checkpoint
        self.table = table
        self.donated = donated
        self.shardings = shardings
        self.canary = canary
        self.triage = triage
        #: hard-loss handler ``(state, report, step) -> ElasticResume``
        #: (``launch/elastic.ElasticManager.hook`` — core/ stays
        #: layering-clean by taking a callable, not the manager)
        self.elastic = elastic
        #: the remesh rung's side channel: the full resume bundle (new
        #: ctx/step/bfn/canary/parity) for the loop to swap in after
        #: ``recover`` returns the reconstructed state
        self.pending_remesh = None
        self.events: List[RecoveryEvent] = []

    # ------------------------------------------------------------------
    # Rung implementations.  Each returns the repaired state or raises
    # RecoveryAbort; the ladder driver verifies and escalates.
    # ------------------------------------------------------------------

    def _induction_leaf(self, state, name: str):
        """The live leaf a full-path registry key names (``iv/…`` resolves
        into the counter block, anything else through the state tree)."""
        if name.startswith("iv/"):
            return state.get("iv", {}).get(name[3:])
        return _leaf_by_key(state, name)

    def _rung_eq1(self, state, report: FaultReport, step: int):
        """Repair corrupted induction state from healthy partners.

        Registered as BOTH the ``eq1`` and ``opt_iv`` rungs (the Recovery
        Table decides which name a leaf's ladder advertises): one Eq. (1)
        majority diagnosis runs over every affine counter the registry
        knows — the ``iv`` block AND the optimizer-owned step counter —
        then (a) affine outliers are rewritten to their family value at
        the consensus iteration n*, and (b) derived entries (bias
        corrections, Adafactor decay) whose stored bits disagree with the
        recomputation at n* are rewritten in place.  All of it is scalar
        arithmetic: zero snapshot bytes, zero replayed steps.
        """
        vals: Dict[str, int] = {}
        for name in self.ivs.specs:
            leaf = self._induction_leaf(state, name)
            if leaf is not None:
                vals[name] = int(leaf)
        if not vals:
            raise RecoveryAbort("no registered induction leaves in state")
        n_star, bad = self.ivs.diagnose(vals)
        if n_star is None:
            raise RecoveryAbort("no consensus among induction variables")
        derived_bad: List[str] = []
        for name in self.ivs.derived:
            leaf = self._induction_leaf(state, name)
            if leaf is None:
                continue
            have = np.asarray(leaf)
            want = np.asarray(self.ivs.derived_value(name, n_star),
                              have.dtype)
            if have.tobytes() != want.tobytes():   # bit compare, not value
                derived_bad.append(name)
        if not bad and not derived_bad:
            raise RecoveryAbort(
                "induction state consistent — fault is elsewhere")
        out = dict(state)
        new_iv = dict(state["iv"])
        swap: Dict[str, object] = {}
        for name in bad:
            v = self.ivs.specs[name].value_at(n_star)
            if name.startswith("iv/"):
                k = name[3:]
                new_iv[k] = jnp.asarray(v, jnp.asarray(state["iv"][k]).dtype)
            else:
                leaf = self._induction_leaf(state, name)
                swap[name] = jnp.asarray(v, jnp.asarray(leaf).dtype)
        for name in derived_bad:
            leaf = self._induction_leaf(state, name)
            swap[name] = jnp.asarray(self.ivs.derived_value(name, n_star),
                                     jnp.asarray(leaf).dtype)
        out["iv"] = new_iv
        if swap:
            out = jax.tree_util.tree_map_with_path(
                lambda path, leaf: swap.get(kops.leaf_key(path), leaf), out)
        repaired = sorted(bad) + sorted(derived_bad)
        return out, (f"repaired {repaired} via Eq.(1) consensus n={n_star}"
                     + (f" (derived recompute: {sorted(derived_bad)})"
                        if derived_bad else ""))

    # -- rung 0: triage -------------------------------------------------

    def _rung_triage(self, state, report: FaultReport, step: int):
        """Classify the injured (leaf, shard) BEFORE any repair and
        tolerate certified-harmless flips in place (FlipTracker, arXiv:
        1809.01362).  Single-event-upset fault model: the Fletcher digest
        pair the canary already holds is an error-locating code for one
        flipped bit, so triage can name the (bit, word) coordinates and
        the implied pre-flip bits with no second copy of the data.

        Certificates (EVERY injured leaf must certify, else abort):

          * dead region — the flip landed on bytes the update never reads
            (int8-quantised moment pad tail; the absmax scale of an
            all-pad block): bitwise harmless, and the next update rewrites
            them wholesale;
          * below-epsilon moment perturbation — a mantissa-tail flip in a
            float EMA moment whose old/new values differ by at most
            ``TRIAGE_REL_EPS`` relative: the induced update-direction
            error is of the same order and decays geometrically under the
            EMA, far below the optimizer's stochastic noise floor.

        Tolerate = re-arm the digest table rows to the tolerated bits
        (``canary.refresh(keys=…)`` patches BOTH generations without a
        bump) and resume with the state untouched — zero bytes moved,
        zero steps replayed.  Anything uncertifiable (multi-word damage,
        exponent-scale perturbations, non-moment leaves) escalates:
        exact-or-abort is preserved because tolerate never ALTERS state,
        it only re-certifies it.
        """
        if not self.triage:
            raise RecoveryAbort("triage disabled")
        if self.canary is None:
            raise RecoveryAbort("triage needs a canary digest reference")
        if report.detector != "checksum":
            raise RecoveryAbort(
                "only digest-attributed faults are classifiable")
        if getattr(report, "consumed", False):
            raise RecoveryAbort(
                "faulting buffers donated into the step — nothing to "
                "classify in place")
        injured = list(report.leaves or ())
        if not injured:
            raise RecoveryAbort("no leaf attribution to classify")
        notes = []
        for key in injured:
            leaf = _leaf_by_key(state, key)
            if leaf is None:
                raise RecoveryAbort(f"injured leaf {key} not in state")
            notes.append(f"{key}: "
                         f"{self._certify_tolerable(state, key, leaf)}")
        # tolerate MUST re-arm: the digest rows still describe the
        # pre-flip bits, so without this every later check would re-fire
        # on a value we have decided to live with (partial refresh — both
        # generations patched, no bump, unrelated rows untouched)
        self.canary.refresh(state, keys=injured)
        return state, "tolerated without repair — " + "; ".join(notes)

    def _certify_tolerable(self, state, key: str, leaf) -> str:
        """Certificate check for one injured leaf; returns the tolerance
        note or raises RecoveryAbort."""
        host = np.asarray(leaf)
        bit, cands = self._localise_flip(key, leaf, host)
        if all(self._dead_element(state, key, j) for j, _, _ in cands):
            return (f"dead-region flip (bit {bit}, "
                    f"{len(cands)} candidate word(s), never read)")
        if not self._moment_leaf(key):
            raise RecoveryAbort(
                f"{key} is not an EMA moment — no tolerance certificate")
        worst = 0.0
        for j, cur_w, old_w in cands:
            if self._dead_element(state, key, j):
                continue
            new_v = _word_value(host.dtype, cur_w)
            old_v = _word_value(host.dtype, old_w)
            if not (np.isfinite(new_v) and np.isfinite(old_v)):
                raise RecoveryAbort(
                    f"{key}: non-finite endpoint at word {j} — escalate")
            delta = abs(new_v - old_v)
            tol = max(TRIAGE_REL_EPS * max(abs(new_v), abs(old_v)),
                      TRIAGE_ABS_FLOOR)
            if delta > tol:
                raise RecoveryAbort(
                    f"{key}: |Δ|={delta:.3e} at word {j} exceeds the "
                    f"epsilon certificate ({tol:.3e}) — escalate")
            worst = max(worst, delta)
        return (f"sub-epsilon moment perturbation (bit {bit}, "
                f"|Δ|≤{worst:.3e})")

    def _localise_flip(self, key: str, leaf, host: np.ndarray):
        """(bit, [(flat_element, cur_word, old_word), …]) for the single
        flip the digest-pair evidence implies, or RecoveryAbort when the
        evidence is inconsistent with any single-bit flip.  ``to_i32``
        packs one word per element for every supported dtype, so word
        index == flat element index (shard-local indices are translated
        to leaf-flat coordinates on a mesh)."""
        ref = np.asarray(self.canary.fault_reference_digest(key))
        if ref.ndim == 2:                       # sharded canary rows
            cur_rows = kdigest.host_shard_checksums(leaf)
            idxs = kdigest.shard_indices(leaf)
            seen, mismatch = set(), []
            for d, idx in enumerate(idxs):
                sig = tuple((sl.start, sl.stop) for sl in idx)
                if sig in seen:                 # replicated slice
                    continue
                seen.add(sig)
                if not np.array_equal(cur_rows[d], ref[d]):
                    mismatch.append((d, idx))
            if not mismatch:
                raise RecoveryAbort(
                    f"{key}: shard digests match the reference — stale "
                    f"attribution")
            if len(mismatch) > 1:
                raise RecoveryAbort(
                    f"{key}: {len(mismatch)} shards mismatch — more than "
                    f"one event, escalate")
            d, idx = mismatch[0]
            sub = np.ascontiguousarray(host[idx])
            words = kdigest._host_i32(sub).view(np.uint32)
            sol = kdigest.locate_single_flip(ref[d], cur_rows[d],
                                             words.size)
            if sol is None:
                raise RecoveryAbort(
                    f"{key} shard {d}: digest deltas inconsistent with a "
                    f"single-bit flip — escalate")
            bit, delta, local = sol
            starts = [0 if sl.start is None else int(sl.start)
                      for sl in idx]
            out = []
            for j in local:
                multi = np.unravel_index(j, sub.shape) if sub.shape else ()
                g = tuple(int(a) + s for a, s in zip(multi, starts))
                gflat = int(np.ravel_multi_index(g, host.shape)) \
                    if host.shape else 0
                cur_w = int(words[j])
                out.append((gflat, cur_w, (cur_w - delta) & 0xFFFFFFFF))
            return bit, out
        words = kdigest._host_i32(host).view(np.uint32)
        cur = kdigest.host_checksum(host)
        if np.array_equal(cur, ref):
            raise RecoveryAbort(
                f"{key}: digest matches the reference — stale attribution")
        sol = kdigest.locate_single_flip(ref, cur, words.size)
        if sol is None:
            raise RecoveryAbort(
                f"{key}: digest deltas inconsistent with a single-bit "
                f"flip — escalate")
        bit, delta, cand = sol
        return bit, [(j, int(words[j]),
                      (int(words[j]) - delta) & 0xFFFFFFFF) for j in cand]

    @staticmethod
    def _moment_leaf(key: str) -> bool:
        """Float EMA-moment leaves — the only state the epsilon
        certificate applies to (params/IVs always escalate)."""
        return key.startswith(("opt/m/", "opt/v/", "opt/stats/")) \
            and not key.endswith("/q")

    def _dead_element(self, state, key: str, j: int) -> bool:
        """Is flat element ``j`` of ``key`` dead — bytes the optimizer
        update never reads and rewrites wholesale each step?  True for
        the int8-quantised moment pad tail (``_q8`` pads to QBLOCK;
        ``_dq8`` slices the logical size back out) and for the absmax
        scale of an all-pad block."""
        base = None
        for pre in ("opt/m/", "opt/v/"):
            if key.startswith(pre):
                base = key[len(pre):]
                break
        if base is None:
            return False
        if base.endswith("/q"):
            p = _leaf_by_key(state, "params/" + base[:-len("/q")])
            return p is not None and j >= int(np.prod(jnp.shape(p)))
        if base.endswith("/scale"):
            p = _leaf_by_key(state, "params/" + base[:-len("/scale")])
            return p is not None and \
                j * QBLOCK >= int(np.prod(jnp.shape(p)))
        return False

    def _rung_replica(self, state, report: FaultReport, step: int):
        """Bitwise TMR vote across DP replicas of the corrupted leaves."""
        if self.replicas is None:
            raise RecoveryAbort("no replicas maintained")
        reps = self.replicas(step)
        if reps is None or len(reps) < 2:
            raise RecoveryAbort("fewer than 2 healthy replicas")
        bad = set(report.leaves)

        def heal(path, leaf, *partner_leaves):
            key = kops.leaf_key(path)
            if bad and key not in bad:
                return leaf
            if len(partner_leaves) >= 2:
                return kops.vote3(leaf, partner_leaves[0], partner_leaves[1])
            return partner_leaves[0]  # 2-way: trust the healthy replica

        out = jax.tree_util.tree_map_with_path(heal, state, *reps[:2])
        return out, f"replica vote over {len(reps)} replicas"

    def _rung_parity(self, state, report: FaultReport, step: int):
        """Reconstruct the injured (leaf, shard) from XOR parity — the
        snapshot-free rung: 0 host-snapshot bytes read, 0 steps replayed,
        O(leaf_bytes/D) reconstructed.

        Covers the FULL state tree (params AND optimizer state — the seed
        repaired only ``state["params"]``, so an opt/EMA-leaf fault
        returned "success" with nothing repaired and burned a verify
        round).  Applicability gates (abort → escalate, never guess):

          * a parity store must be maintained and the faulting version's
            buffers must be LIVE — an in-step fused report under donation
            says ``consumed=True`` and aborts up front (the donated PAIR
            protocol checks before the step consumes, so its reports keep
            live survivors even under donation);
          * at least one injured leaf must be parity-covered (up-front
            RecoveryAbort otherwise — int64/float64 leaves and the IV
            block are not covered);
          * exactly ONE shard per injured leaf: single parity tolerates a
            single lost component per leaf (arXiv:1309.0212), two injured
            shards of one leaf escalate;
          * checksum/external reports are digest-certified against the
            canary's reference table before resume (``host_shard_checksums``
            per shard on a mesh, whole-leaf ``host_checksum`` off-mesh);
            an uncertifiable reconstruction aborts (exact-or-abort).
        """
        store = self.parity
        if store is None:
            raise RecoveryAbort("no parity maintained")
        if getattr(report, "consumed", False):
            raise RecoveryAbort(
                "faulting version donated into the detecting step — "
                "survivors are dead, replay instead")
        injured = list(report.shards or ()) or list(report.leaves or ())
        if not injured:
            # free traps carry no leaf attribution — name suspects via the
            # non-finite scan (the only evidence class a trap leaves)
            injured = _default_verify(state)
        covered = [k for k in injured if store.covers(k)]
        if not covered:
            raise RecoveryAbort("no injured leaf is parity-covered")
        # the table generation the fired check compared against — NOT the
        # current read table, which the fused protocols have already
        # advanced past by the time the fault path runs
        refs = self.canary.fault_reference_digests() \
            if self.canary is not None else None
        certifiable = report.detector in ("checksum", "external")
        on_mesh = store.plan.mesh is not None
        moved = [0, 0]                      # bytes reconstructed, shards
        repaired: Dict[str, object] = {}
        for key in covered:
            leaf = _leaf_by_key(state, key)
            if leaf is None:
                raise RecoveryAbort(f"injured leaf {key} not in state")
            shards = self._locate_shards(leaf, key, report, refs)
            if not shards:
                raise RecoveryAbort(
                    f"cannot localise the injured shard of {key}")
            if len(shards) > 1:
                raise RecoveryAbort(
                    f"{len(shards)} injured shards of {key} — a single "
                    f"parity shard reconstructs exactly one")
            d = shards[0]
            if on_mesh:
                # surviving devices keep their exact buffers; the
                # reconstructed block's bytes move to EVERY device holding
                # that logical block (all replicas — O(leaf_bytes/D) each)
                block = np.asarray(store.reconstruct_shard(leaf, key, d))
                sharding = leaf.sharding
                devs = kdigest.mesh_device_order(sharding.mesh)
                by_dev = {sh.device: sh.data
                          for sh in leaf.addressable_shards}
                holders = set(store.plan.block_devices(key, d))
                bufs = [jax.device_put(block, dev) if i in holders
                        else by_dev[dev] for i, dev in enumerate(devs)]
                new_leaf = jax.make_array_from_single_device_arrays(
                    leaf.shape, sharding, bufs)
                moved[0] += block.nbytes * len(holders)
            else:
                new_leaf = store.reconstruct_leaf(leaf, key, d)
                moved[0] += 4 * store.plan.block_sizes[key][d]
            moved[1] += 1
            if certifiable and refs is not None and key in refs:
                got = kdigest.host_shard_checksums(new_leaf) if on_mesh \
                    else kdigest.host_checksum(np.asarray(new_leaf))
                if not np.array_equal(np.asarray(got),
                                      np.asarray(refs[key])):
                    raise RecoveryAbort(
                        f"reconstruction of {key} shard {d} failed digest "
                        f"certification — escalating")
            repaired[key] = new_leaf

        def swap(path, leaf):
            return repaired.get(kops.leaf_key(path), leaf)

        out = jax.tree_util.tree_map_with_path(swap, state)
        self._last_patched_bytes = moved[0]
        return out, (f"parity reconstruction of {moved[1]} shard(s) of "
                     f"{len(covered)} leaf/leaves ({moved[0]} B, "
                     f"no snapshot, no replay)")

    def _locate_shards(self, leaf, key: str, report: FaultReport,
                       refs) -> List[int]:
        """Which unique logical block(s) of ``leaf`` are injured, in the
        parity plan's block coordinates.  Device-coordinate evidence (the
        sharded canary attributes per DEVICE) is translated through
        ``plan.device_block`` — replicas of one corrupted logical slice
        collapse to ONE injured block, which single parity CAN repair.

        In order of evidence quality:
          1. the report's own (leaf, shard) attribution (sharded canary);
          2. per-shard uint32 digests of the live leaf against the
             canary's reference rows (``host_shard_checksums`` — the
             finite-bitflip case the seed's non-finite-only scan aborted
             on);
          3. off-mesh, where the reference is one whole-leaf digest:
             trial reconstruction — reconstruct each candidate shard in
             turn and keep the one whose repaired leaf matches the
             reference (localisation, repair and certification in one
             O(D · leaf_bytes/D) sweep).  ALL candidates are tried and a
             unique match is required: a false candidate mirrors the XOR
             delta into its own block at the same block-local offset, and
             for a flip of bit b the two complementary word deltas sit
             exactly ``block_len`` apart — Fletcher's weighted term
             shifts by ``2^b * block_len``, which is ``0 mod 2^32``
             whenever ``b + log2(block_len) >= 32``, so high-bit flips
             can digest-collide.  Two matches are indistinguishable by
             parity too (both repairs are parity-consistent), so the
             only exact-or-abort answer is to escalate to replay;
          4. last resort (no canary): per-block non-finite scan.
        """
        store = self.parity
        dmap = store.plan.device_block[key]
        ids = (report.shards or {}).get(key)
        if ids:
            return sorted({dmap[int(i)] for i in ids})
        ref = refs.get(key) if refs else None
        if ref is not None and store.plan.mesh is not None \
                and np.ndim(ref) == 2:
            got = kdigest.host_shard_checksums(leaf)
            bad = np.nonzero(np.any(got != np.asarray(ref), axis=-1))[0]
            if len(bad):
                return sorted({dmap[int(i)] for i in bad})
        if ref is not None and store.plan.mesh is None:
            matches = [
                d for d in range(store.plan.n_blocks[key])
                if np.array_equal(
                    kdigest.host_checksum(np.asarray(
                        store.reconstruct_leaf(leaf, key, d))),
                    np.asarray(ref))]
            if len(matches) == 1:
                return matches
            if len(matches) > 1:
                raise RecoveryAbort(
                    f"{len(matches)} candidate shards of {key} digest-"
                    f"certify (Fletcher collision of the XOR-mirrored "
                    f"repair) — ambiguous, escalating")
        if jnp.issubdtype(jnp.result_type(leaf), jnp.floating):
            if store.plan.mesh is None:
                flat = jnp.asarray(leaf).reshape(-1)
                c = store.plan.block_len[key]
                flat = jnp.pad(flat, (0, store.n_shards * c - flat.shape[0]))
                bad = np.asarray(jnp.any(
                    ~jnp.isfinite(flat.reshape(store.n_shards, c)), axis=1))
            else:
                uniq, _ = store.plan.slices[key]
                bad = np.asarray([
                    bool(jnp.any(~jnp.isfinite(
                        leaf[tuple(slice(a, b) for a, b in idx)])))
                    for idx in uniq])
            idx = np.nonzero(bad)[0]
            if len(idx):
                return [int(i) for i in idx]
        return []

    def _rung_shard_patch(self, state, report: FaultReport, step: int):
        """Restore ONLY the injured shards' addressable bytes (mesh loops).

        Applicability gates (abort → escalate, never guess):
          * the report must carry (leaf, shard) attribution — only the
            sharded canary produces it;
          * the loop must not have donated the state (the live healthy
            shards are the other half of the patch);
          * the newest snapshot must be VERSION-MATCHED (``snap.step ==
            step``): the canary certifies the live buffer against the
            digests of the same state version, so only a same-version
            snapshot can supply bit-exact replacement bytes — an older
            one would silently mix state versions (the SDC the paper's
            exact-or-abort rule exists to prevent);
          * the injured (leaf, shard) units must digest-certify in the
            snapshot (``MicroCheckpointer.verify_shards``).

        The patch rebuilds each corrupt leaf with
        ``jax.make_array_from_single_device_arrays``: healthy devices
        keep their existing shard buffers (zero copies), only the injured
        shards' bytes cross host→device.  Byte movement is reported — the
        point of the rung is that it is ~state_bytes/n_shards, not
        state_bytes."""
        shards = dict(getattr(report, "shards", None) or {})
        if not shards:
            raise RecoveryAbort("no (leaf, shard) attribution")
        if self.donated:
            raise RecoveryAbort("donated buffers are dead — replay instead")
        if all(k.startswith("iv/") for k in shards):
            raise RecoveryAbort("IV block repairs via Eq.(1)")
        snap = self.micro.latest(before=step)
        if snap is None:
            raise RecoveryAbort("no snapshot available")
        if snap.step != step:
            raise RecoveryAbort(
                f"no version-matched snapshot (have step {snap.step}, "
                f"fault is against version {step})")
        rotten = self.micro.verify_shards(snap, shards)
        if rotten:
            raise RecoveryAbort(f"snapshot shards failed verification: "
                                f"{rotten[:3]}")
        host = {kops.leaf_key(p): leaf for p, leaf in
                jax.tree_util.tree_flatten_with_path(snap.state)[0]}
        moved = [0, 0]                      # bytes, shard units

        def heal(path, leaf):
            key = kops.leaf_key(path)
            ids = set(shards.get(key) or ())
            if not ids:
                return leaf
            sharding = leaf.sharding
            devs = kdigest.mesh_device_order(sharding.mesh)
            idxs = snap.shard_slices[key]
            by_dev = {sh.device: sh.data for sh in leaf.addressable_shards}
            bufs = []
            for d, dev in enumerate(devs):
                if d in ids:
                    piece = np.ascontiguousarray(host[key][idxs[d]])
                    bufs.append(jax.device_put(piece, dev))
                    moved[0] += piece.nbytes
                    moved[1] += 1
                else:
                    bufs.append(by_dev[dev])
            return jax.make_array_from_single_device_arrays(
                leaf.shape, sharding, bufs)

        out = jax.tree_util.tree_map_with_path(heal, state)
        self._last_patched_bytes = moved[0]
        return out, (f"patched {moved[1]} shard(s) of {len(shards)} "
                     f"leaf/leaves ({moved[0]} B moved) from snapshot "
                     f"@{snap.step}")

    def _rung_replay(self, state, report: FaultReport, step: int):
        """Replay from the newest digest-verified snapshot ≤ step."""
        snap = self.micro.latest(before=step)
        if snap is None:
            raise RecoveryAbort("no snapshot available")
        rotten = self.micro.verify(snap)
        if rotten:
            raise RecoveryAbort(f"snapshot failed verification: {rotten[:3]}")
        res = replay(self.step_fn, self.batch_fn, snap.state,
                     snap.step, step,
                     like_state=None if self.donated else state,
                     shardings=self.shardings)
        self._last_replayed = res.steps_replayed
        return res.state, f"replayed {res.steps_replayed} steps from {snap.step}"

    def _rung_checkpoint(self, state, report: FaultReport, step: int):
        """Classic restore — the baseline the paper seeks to avoid."""
        if self.checkpoint is None:
            raise RecoveryAbort("no checkpoint loader configured")
        ck_state, ck_step = self.checkpoint()
        res = replay(self.step_fn, self.batch_fn, ck_state, ck_step, step,
                     like_state=None if self.donated else state,
                     shardings=self.shardings)
        self._last_replayed = res.steps_replayed
        return res.state, f"restored step {ck_step} + replayed to {step}"

    def _rung_remesh(self, state, report: FaultReport, step: int):
        """HARD loss: devices are gone, not corrupt — shrink the mesh and
        keep training (DESIGN.md §7).  Delegates to the attached elastic
        handler (survivor-honest gather + certify, parity reconstruction
        of the dead rows' shards, old-mesh cache eviction, one re-lower
        on the degraded context) and swaps the runtime's own executables
        so any later rung/replay this event — and every subsequent one —
        runs against the new mesh.  The full resume bundle is left on
        ``pending_remesh`` for the training loop."""
        if self.elastic is None:
            raise RecoveryAbort("no elastic handler attached")
        rows = tuple(getattr(report, "lost_rows", ()) or ())
        if not rows:
            raise RecoveryAbort("report names no lost rows")
        resume = self.elastic(state, report, step)
        self.pending_remesh = resume
        self.step_fn = resume.step
        self.batch_fn = resume.bfn
        self.shardings = resume.shardings
        if resume.canary is not None:
            self.canary = resume.canary
        if resume.pstore is not None:
            self.parity = resume.pstore
        ev = resume.event
        self._last_patched_bytes = ev.bytes_reconstructed
        return resume.state, (
            f"remeshed dp {ev.old_dp}->{ev.new_dp} (rows {ev.lost_rows} "
            f"lost), {ev.blocks_reconstructed} blocks "
            f"({ev.bytes_reconstructed} B) parity-reconstructed, "
            f"{ev.certified_blocks} survivor blocks certified, "
            f"re-lowered once in {ev.relower_seconds:.2f}s")

    _RUNGS = {
        RUNG_TRIAGE: _rung_triage,
        RUNG_EQ1: _rung_eq1,
        RUNG_OPT_IV: _rung_eq1,     # same consensus engine, opt-IV ladder
        RUNG_SHARD: _rung_shard_patch,
        RUNG_REPLICA: _rung_replica,
        RUNG_PARITY: _rung_parity,
        RUNG_REPLAY: _rung_replay,
        RUNG_REMESH: _rung_remesh,
        RUNG_CHECKPOINT: _rung_checkpoint,
    }

    # ------------------------------------------------------------------
    # Ladder driver
    # ------------------------------------------------------------------

    def recover(self, state, report: FaultReport, step: int,
                verify: Optional[Callable] = None,
                ladder: Optional[Sequence[str]] = None):
        """Walk the ladder; return (repaired_state, RecoveryEvent).

        ``verify(state) -> List[str]`` names still-corrupt leaves (empty =
        verified).  Default: non-finite scan over float leaves.
        """
        # in-step fused detection defers leaf attribution: the hot path
        # fetched only the scalar mismatch flag, so the per-leaf bad-mask
        # vector is still on device — materialise it now (fault path; one
        # extra transfer) so the Recovery Table lookup and the targeted
        # rungs see the corrupted leaf paths exactly as with the pair
        # protocol.
        report.resolve()
        ladder = list(ladder) if ladder is not None else self._ladder(report)
        verify = verify or _default_verify
        ev = RecoveryEvent(step=step, report=report)
        t0 = time.perf_counter()
        for rung in ladder:
            fn = self._RUNGS.get(rung)
            if fn is None:
                continue
            ev.attempted.append(rung)
            self._last_replayed = 0
            self._last_patched_bytes = 0
            tr = time.perf_counter()
            try:
                cand, detail = fn(self, state, report, step)
            except RecoveryAbort as e:
                ev.phase_seconds[rung] = time.perf_counter() - tr
                ev.report.detail += f" | {rung}: {e}"
                continue
            bad = verify(cand)
            ev.phase_seconds[rung] = time.perf_counter() - tr
            if bad:
                # exact-or-abort: the repair did not certify — escalate
                ev.report.detail += f" | {rung}: post-verify failed {bad[:2]}"
                continue
            ev.rung = rung
            ev.recovered = True
            ev.steps_replayed = self._last_replayed
            ev.bytes_moved = self._last_patched_bytes
            ev.wall_seconds = time.perf_counter() - t0
            ev.report.detail += f" | {rung}: {detail}"
            self.events.append(ev)
            return cand, ev
        ev.wall_seconds = time.perf_counter() - t0
        self.events.append(ev)
        raise RecoveryFailed(str(report))

    def _ladder(self, report: FaultReport) -> List[str]:
        """Choose the ladder from the Recovery Table (or the default)."""
        if getattr(report, "lost_rows", None):
            # HARD loss: the devices themselves are gone — no in-place
            # rung applies (there is nothing to patch into), no replay
            # helps (snapshots are sharded onto the dead mesh).  Remesh
            # onto the survivors; only the classic checkpoint restore
            # sits below it.
            return [RUNG_REMESH, RUNG_CHECKPOINT]
        if self.donated:
            # the pre-step state was donated into the step — there are no
            # live buffers for the in-place rungs (Eq.(1), TMR, parity,
            # shard patch) to read or repair: pivot straight to snapshot +
            # IV replay.  ONE exception: the donated-PAIR protocol checks
            # the buffer BEFORE the step consumes it, so a checksum report
            # with ``consumed=False`` still has live survivors — the
            # parity rung can reconstruct the injured shard in place with
            # no snapshot and no replay (in-step fused reports under
            # donation say ``consumed=True`` and skip it).
            ladder = [RUNG_REPLAY, RUNG_CHECKPOINT]
            if (self.parity is not None
                    and report.detector in ("checksum", "external")
                    and not getattr(report, "consumed", False)):
                ladder.insert(0, RUNG_PARITY)
            if self._triage_applies(report):
                # the donated-PAIR protocol checks before the step
                # consumes, so its reports still have live bytes to
                # classify — triage rides ahead of parity/replay
                ladder.insert(0, RUNG_TRIAGE)
            return ladder
        if self.table is not None and report.leaves:
            entry = self.table.lookup(report.leaves[0])
            if entry is not None:
                return list(entry.ladder)
        if report.leaves and all(k.startswith("iv/") for k in report.leaves):
            return [RUNG_EQ1, RUNG_REPLAY, RUNG_CHECKPOINT]
        if report.leaves and all(
                k in self.ivs.specs or k in self.ivs.derived
                for k in report.leaves):
            # optimizer-owned induction leaves (opt/t, bias corrections):
            # the opt-IV branch of the same Eq. (1) consensus engine
            return [RUNG_OPT_IV, RUNG_REPLAY, RUNG_CHECKPOINT]
        ladder = [RUNG_EQ1, RUNG_REPLICA, RUNG_PARITY, RUNG_REPLAY,
                  RUNG_CHECKPOINT]
        if getattr(report, "shards", None):
            # mesh attribution: try the byte-minimal shard patch first —
            # its gates (version match, shard certification) abort cleanly
            # into the generic ladder when it does not apply
            ladder.insert(0, RUNG_SHARD)
        if self._triage_applies(report):
            ladder.insert(0, RUNG_TRIAGE)
        return ladder

    def _triage_applies(self, report: FaultReport) -> bool:
        """Rung 0 gate: enabled, a canary to certify against, digest
        attribution, and live (un-donated) buffers to classify."""
        return (self.triage and self.canary is not None
                and report.detector == "checksum"
                and not getattr(report, "consumed", False)
                and bool(report.leaves))

    # -- telemetry -------------------------------------------------------

    def summary(self) -> Dict:
        n = len(self.events)
        rec = [e for e in self.events if e.recovered]
        by_rung: Dict[str, int] = {}
        for e in rec:
            by_rung[e.rung] = by_rung.get(e.rung, 0) + 1
        return {
            "events": n,
            "recovered": len(rec),
            "recovery_rate": len(rec) / n if n else 1.0,
            "by_rung": by_rung,
            "mean_wall_ms": 1e3 * float(np.mean([e.wall_seconds
                                                 for e in rec])) if rec else 0.0,
            "mean_steps_replayed": float(np.mean([e.steps_replayed
                                                  for e in rec])) if rec else 0.0,
        }


# ---------------------------------------------------------------------------
# Serving recovery policy — slot-scoped eviction vs whole-state ladder
# ---------------------------------------------------------------------------
#
# The training runtime above walks a per-leaf ladder because every rung can
# repair state IN PLACE.  The serving engine has a cheaper primitive the
# trainer lacks: each batch slot's decode state is rebuildable from its
# request's token log (prefix replay — the serving RSI), and the slot-view
# canary attributes a fault to (leaf, slot).  The policy below decides, per
# FaultReport, between
#
#   * ``slots`` — evict ONLY the injured slots to prefix replay; healthy
#     slots keep decoding the very next engine step.  Requires slot
#     attribution (checksum units or per-slot non-finite flags) and bounds
#     the suspect-token window:
#
#       - checksum: the in-step fused canary checks each row against the
#         digest armed ONE step earlier (the generation tables alternate
#         every step), so a mismatch proves the corruption arose in the
#         single inter-step gap just crossed.  The only corrupt-derived
#         token is the detection step's own output, which the engine
#         discards for evicted slots — zero ACCEPTED tokens are suspect,
#         retract = 0.  (This is also what makes the storm livelock-free:
#         a fault costs eviction + replay, never accepted progress.)
#       - nonfinite: the free trap fires only when the poison reaches the
#         logits, which for recurrent/SSM-style caches can lag the flip by
#         several steps.  Retract the last K-1 accepted tokens — the
#         at-rest window the rotating canary leaves unchecked between a
#         unit's check and its next arm — as the conservative bound.
#
#     tests/test_serving.py pins the bit-exactness of both paths (replay
#     determinism regenerates retracted-but-clean tokens identically).
#   * ``engine`` — no slot attribution (e.g. an external signal): evict
#     every active slot — the serving analogue of the trainer's
#     whole-state replay rung.  Without a canary bound on detection
#     latency the retraction must be the full log (replay from prompt).


@dataclass
class ServingRecoveryPlan:
    """What the engine must do about one FaultReport."""
    scope: str                     # 'slots' | 'engine'
    slots: List[int]               # slots to evict (scope='slots')
    retract: Optional[int] = None  # suspect tokens to rescind; None = all
    reason: str = ""


def plan_serving_recovery(report: FaultReport, *, n_slices: int,
                          nonfinite_slots: Sequence[int] = ()
                          ) -> ServingRecoveryPlan:
    """Slot-scoped eviction vs whole-state eviction for a serving fault.

    ``n_slices``       : the canary's K (0 = no canary: free traps only).
    ``nonfinite_slots``: active slots whose logits went non-finite this
                         step (the engine's free trap — computed in-launch
                         and fetched with the token payload).
    """
    slots = set(report.injured_slots()) if report is not None else set()
    slots.update(nonfinite_slots)
    checksum = report is not None and report.detector == "checksum"
    if checksum:
        # one-step detection latency (checked row == row armed last step):
        # no accepted token predates the corruption — nothing to rescind
        retract = 0
    else:
        # nonfinite trap: poison may have sat in the unchecked at-rest
        # window for up to K-1 steps before reaching the logits
        retract = max(0, n_slices - 1) if n_slices else None
    if slots:
        return ServingRecoveryPlan(
            scope="slots", slots=sorted(slots), retract=retract,
            reason=f"slot attribution ({report.detector if report else 'nonfinite'})")
    if report is not None:
        leaves = report.resolve()
        if leaves and all(block_of_leaf(k) is not None for k in leaves):
            # Paged pool: every corrupted leaf is a pool block with no
            # owning slot — the flip landed on free (or scratch) bytes
            # that no live sequence reads.  Nothing to evict; the engine
            # just re-certifies the injured blocks' digests.
            return ServingRecoveryPlan(
                scope="slots", slots=[], retract=0,
                reason="checksum attribution to unowned pool blocks — "
                       "no live victim")
    return ServingRecoveryPlan(
        scope="engine", slots=[], retract=None,
        reason="no slot attribution — evict all active slots")


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _word_value(dtype, word: int) -> float:
    """Decode a packed ``to_i32`` word back to the float it encodes (the
    triage epsilon certificate compares old/new VALUES, not bits)."""
    dt = np.dtype(dtype)
    if dt.itemsize == 4:
        return float(np.array([word & 0xFFFFFFFF],
                              np.uint32).view(np.float32)[0])
    if dt.itemsize == 2:
        return float(np.array([word & 0xFFFF], np.uint16).view(dt)[0])
    raise RecoveryAbort(f"no value decoding for dtype {dt}")


def _leaf_by_key(tree, key: str):
    found = [None]

    def visit(path, leaf):
        if kops.leaf_key(path) == key:
            found[0] = leaf
        return leaf

    jax.tree_util.tree_map_with_path(visit, tree)
    return found[0]


_VERIFY_CACHE: Dict[object, Callable] = {}


def _default_verify(state) -> List[str]:
    """Non-finite scan over float leaves — names corrupt leaves.

    Fused like the digest engine (DESIGN.md §4.2): one jitted device pass
    producing a per-leaf flag vector and ONE host transfer, instead of a
    blocking ``isfinite().all()`` fetch per leaf.  The compiled scan is
    cached per state structure, so repeated rung verifications never
    retrace."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(state)
    keys = [kops.leaf_key(p) for p, _ in flat]
    float_idx = [i for i, (_, x) in enumerate(flat)
                 if jnp.issubdtype(jnp.result_type(x), jnp.floating)]
    if not float_idx:
        return []
    sig = (treedef, tuple((jnp.shape(x), jnp.result_type(x).name)
                          for _, x in flat))
    fn = _VERIFY_CACHE.get(sig)
    if fn is None:
        fn = jax.jit(lambda leaves: jnp.stack(
            [~jnp.isfinite(leaf).all() for leaf in leaves]))
        _VERIFY_CACHE[sig] = fn
    mask = kdigest.fetch(fn([flat[i][1] for i in float_idx]))
    return sorted(keys[i] for i, b in zip(float_idx, mask) if b)
