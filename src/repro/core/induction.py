"""Induction-variable registry and Eq. (1) partner recovery.

The paper (§3.2): for induction variables i, k updated as ``i += s_i``,
``k += s_k`` in the same loop, a corrupted i is recovered from k via

    i = (k - k0) / s_k * s_i + i0                                   Eq. (1)

Here the "loop" is the training loop and the IVs are the counters in
``TrainState['iv']`` (step, data_offset, rng_counter, sched_pos,
micro_count) — kept *independent* by ICP (see ``core/icp.py``) precisely so
this recovery is possible.

Beyond the paper's pairwise recovery we implement *majority diagnosis*: each
IV implies an iteration index n_x = (x - x0)/s_x; with ≥3 registered IVs the
modal n identifies every corrupted counter at once (the paper's exact-or-
abort rule falls out naturally: no modal majority -> abort to next rung).
"""

from __future__ import annotations

from collections import Counter
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class IVSpec:
    name: str
    init: int
    step: int  # per-iteration increment (loop-invariant, may be any int != 0)

    def value_at(self, n: int) -> int:
        return self.init + n * self.step

    def iteration_of(self, value: int) -> Optional[int]:
        """Implied iteration index, or None if value is inconsistent with
        this IV's affine family (non-divisible residue)."""
        delta = int(value) - self.init
        if self.step == 0:
            return None
        n, r = divmod(delta, self.step)
        return int(n) if r == 0 else None


class IVRegistry:
    """The Recovery-Table fragment for induction variables.

    Two entry classes:

    * **affine** (``specs``): counters following ``x(n) = init + n*step`` —
      the Eq. (1) family.  These vote in ``diagnose`` and repair each other.
    * **derived** (``derived``): values that are not affine in n but are a
      pure function of it (bias-correction factors ``1 - beta^n``,
      Adafactor's decay ``1 - n^-0.8``, …).  They carry no vote — a flip in
      one is repaired by recomputing ``derived[name](n*)`` from the affine
      consensus iteration.
    """

    def __init__(self, specs: Dict[str, Tuple[int, int]],
                 derived: Optional[Dict[str, Callable[[int], object]]] = None):
        """specs: name -> (init, step); derived: name -> fn(n) -> value."""
        self.specs: Dict[str, IVSpec] = {
            name: IVSpec(name, int(init), int(step))
            for name, (init, step) in specs.items()
        }
        self.derived: Dict[str, Callable[[int], object]] = dict(derived or {})
        if not self.specs:
            raise ValueError("empty IV registry")
        overlap = set(self.specs) & set(self.derived)
        if overlap:
            raise ValueError(f"IV names both affine and derived: {overlap}")

    # -- Eq. (1): pairwise recovery ----------------------------------------

    def eq1(self, target: str, partner: str, partner_value: int) -> int:
        """Recover ``target``'s value from a healthy ``partner`` value.

        Exact-or-abort: a partner whose value has a non-zero residue mod its
        step is NOT on its affine family — it is itself corrupted, and
        "repairing" from it would manufacture a silently wrong value.
        """
        ps = self.specs[partner]
        ts = self.specs[target]
        if ps.step == 0:
            raise RecoveryAbort(f"partner {partner} has zero step")
        n, r = divmod(int(partner_value) - ps.init, ps.step)
        if r != 0:
            raise RecoveryAbort(
                f"partner {partner}={int(partner_value)} is off its affine "
                f"family (residue {r} mod step {ps.step}) — refusing Eq.(1)")
        return ts.init + n * ts.step

    # -- derived entries -----------------------------------------------------

    def is_derived(self, name: str) -> bool:
        return name in self.derived

    def derived_value(self, name: str, n: int):
        """Recompute a derived entry at consensus iteration ``n`` — the
        exact expression the optimizer update writes at state version n."""
        return self.derived[name](int(n))

    # -- majority diagnosis --------------------------------------------------

    def implied_iterations(self, values: Dict[str, int]) -> Dict[str, Optional[int]]:
        return {name: self.specs[name].iteration_of(values[name])
                for name in self.specs if name in values}

    def diagnose(self, values: Dict[str, int]) -> Tuple[Optional[int], List[str]]:
        """Returns (consensus iteration n or None, corrupted IV names).

        Majority vote over implied iteration indices.  A strict majority of
        registered IVs must agree, else (None, all names) — the
        exact-or-abort escalation signal.
        """
        implied = self.implied_iterations(values)
        votes = Counter(n for n in implied.values() if n is not None)
        if not votes:
            return None, sorted(implied)
        n_star, count = votes.most_common(1)[0]
        if count * 2 <= len(implied):
            return None, sorted(implied)
        bad = [name for name, n in implied.items() if n != n_star]
        return n_star, sorted(bad)

    def recover(self, values: Dict[str, int]) -> Tuple[Dict[str, int], List[str]]:
        """Repair all corrupted IVs from the consensus iteration.

        Returns (repaired values, names repaired).  Raises RecoveryAbort if
        no consensus exists (the abort-instead-of-SDC rule).
        """
        n_star, bad = self.diagnose(values)
        if n_star is None:
            raise RecoveryAbort("no consensus among induction variables")
        fixed = dict(values)
        for name in bad:
            fixed[name] = self.specs[name].value_at(n_star)
        return fixed, bad


class RecoveryAbort(RuntimeError):
    """Raised when a recovery rung cannot certify an exact repair —
    the runtime escalates to the next rung instead of risking an SDC."""
