"""Detectors — the TPU-domain analogue of the paper's free SIGSEGV trap.

Ordered by cost:
  1. ``trap_nonfinite``   — free: inspects the already-computed loss/grad-norm
     scalars.  A transient fault that corrupts arithmetic state overwhelmingly
     surfaces as Inf/NaN within a step or two (the paper's observation that
     89.8% of crashes are SIGSEGV within ≤50 instructions transfers as:
     non-finite contamination within ≤2 steps).
  2. ``trap_loss_spike``  — free: order-of-magnitude loss jump.
  3. ``checksum_canary``  — one HBM pass over a rotating 1/K slice of the
     state (Pallas kernel): catches *dormant* corruption (e.g. a flipped
     optimizer-moment bit that hasn't contaminated the loss yet), giving
     full-state coverage every K steps at 1/K cost.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.kernels import ops as kops


@dataclass
class FaultReport:
    step: int
    detector: str               # 'nonfinite' | 'loss_spike' | 'checksum' | 'external'
    leaves: List[str] = field(default_factory=list)  # suspected leaf paths
    detail: str = ""

    def __str__(self):
        where = f" leaves={self.leaves[:3]}{'...' if len(self.leaves) > 3 else ''}" \
            if self.leaves else ""
        return f"FaultReport(step={self.step}, {self.detector}{where} {self.detail})"


def trap_nonfinite(step: int, metrics: Dict) -> Optional[FaultReport]:
    for name in ("loss", "grad_norm"):
        v = metrics.get(name)
        if v is None:
            continue
        fv = float(v)
        if not math.isfinite(fv):
            return FaultReport(step, "nonfinite",
                               detail=f"{name}={fv}")
    return None


def trap_loss_spike(step: int, metrics: Dict, history: Sequence[float],
                    factor: float = 10.0, window: int = 8) -> Optional[FaultReport]:
    if len(history) < window:
        return None
    v = metrics.get("loss")
    if v is None:
        return None
    fv = float(v)
    ref = float(np.median(list(history)[-window:]))
    if math.isfinite(fv) and fv > factor * max(ref, 1e-6):
        return FaultReport(step, "loss_spike",
                           detail=f"loss={fv:.3g} median={ref:.3g}")
    return None


class ChecksumCanary:
    """Rotating-slice checksum detector over a state subtree.

    reference digests are refreshed after every *verified* step for the
    slice just checked; a mismatch names the corrupted leaves exactly —
    the Recovery Table key the runtime needs.
    """

    def __init__(self, tree, n_slices: int = 4):
        self.n_slices = max(1, n_slices)
        self.reference: Dict[str, np.ndarray] = kops.tree_checksums(tree)
        self._keys = sorted(self.reference)

    def _slice_keys(self, step: int) -> List[str]:
        r = step % self.n_slices
        return [k for i, k in enumerate(self._keys) if i % self.n_slices == r]

    def refresh(self, tree, keys: Optional[Sequence[str]] = None):
        if keys is None:
            self.reference = kops.tree_checksums(tree)
            return
        cur = kops.subtree_checksums(tree, keys)   # digest only the slice
        self.reference.update(cur)

    def check(self, step: int, tree) -> Optional[FaultReport]:
        keys = self._slice_keys(step)
        cur = kops.subtree_checksums(tree, keys)
        bad = [k for k in keys
               if not np.array_equal(cur.get(k), self.reference.get(k))]
        if bad:
            return FaultReport(step, "checksum", leaves=sorted(bad))
        return None

    def check_full(self, step: int, tree) -> Optional[FaultReport]:
        bad = kops.verify_tree(tree, self.reference)
        if bad:
            return FaultReport(step, "checksum", leaves=bad)
        return None

    def arm(self, step: int, tree) -> None:
        """End-of-step: digest the slice that ``check(step+1, ...)`` will
        verify.  Together with ``check`` this is the 2/K-cost rotating
        canary: corruption landing in the armed slice between two steps is
        caught before the next step consumes it."""
        self.refresh(tree, self._slice_keys(step + 1))
