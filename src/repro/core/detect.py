"""Detectors — the TPU-domain analogue of the paper's free SIGSEGV trap.

Ordered by cost:
  1. ``trap_nonfinite``   — free: inspects the already-computed loss/grad-norm
     scalars.  A transient fault that corrupts arithmetic state overwhelmingly
     surfaces as Inf/NaN within a step or two (the paper's observation that
     89.8% of crashes are SIGSEGV within ≤50 instructions transfers as:
     non-finite contamination within ≤2 steps).
  2. ``trap_loss_spike``  — free: order-of-magnitude loss jump.
  3. ``checksum_canary``  — one HBM pass over a rotating 2/K slice of the
     state (a single fused Pallas launch; DESIGN.md §4.2): catches *dormant*
     corruption (e.g. a flipped optimizer-moment bit that hasn't
     contaminated the loss yet), giving full-state coverage every K steps.
     The hot path costs exactly one kernel launch and one scalar
     device→host sync per step, independent of the number of state leaves.

Canary launch/sync contract by mode (bytes are ~2/K of the state in every
mode; full table in DESIGN.md §4.2):

  * ``check_and_arm`` (non-donated loops) — 1 fused launch + 1 scalar
    sync per step;
  * ``arm_current``/``check`` pair (donated loops, detection outside the
    step) — 2 launches (only the check syncs, 1 scalar);
  * in-step fused (``fuse_into_step`` → ``core/fused_step.py``) — the
    check of the *input* slice and the arm of the *output* slice run
    INSIDE the jitted (optionally donated) step: 1 combined launch + 1
    scalar sync per step, at the cost of K rotation-specialised step
    executables.  Leaf attribution is deferred to the fault path via
    ``FaultReport.resolve``.

On a device mesh (``ChecksumCanary(..., ctx=DistContext)``; DESIGN.md §5)
every mode keeps its contract: digests become shard-local (each device
streams only its addressable rows), the reference tables are sharded with
the state, and the one fetched scalar is the all-reduced any(fault) flag
— the only cross-device hop on the no-fault path.  Attribution resolves
to (leaf, shard) pairs so recovery can restore a single injured shard.
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import digest as kdigest
from repro.kernels.ops import rotating_slice

#: default window for the loss-spike trap; callers keep a bounded
#: ``deque(maxlen=LOSS_WINDOW)`` history (unbounded lists grew without
#: limit over long runs).
LOSS_WINDOW = 8

# ---------------------------------------------------------------------------
# Slot-slice canary mapping (serving engine; DESIGN.md §6)
#
# The serving engine lays its decode state out slot-major — every cache
# leaf carries a leading ``[slot]`` axis — and protects it with an ordinary
# ChecksumCanary built over a *slot view*: a tree whose top-level keys are
# ``slot000``, ``slot001``, ... each holding that slot's slice of every
# leaf.  The canary needs no slot awareness at all: its digest units are
# simply (leaf, slot) pairs by construction, so the rotating checksum
# attributes a fault to a specific slot for free and recovery can evict
# exactly the injured requests.
# ---------------------------------------------------------------------------

_SLOT_RE = re.compile(r"^slot(\d+)/")

#: Paged serving (DESIGN.md §6): pool leaves live under ``blockNNNN/``
#: view keys; after ownership translation an owned block's path becomes
#: ``slotNNN/blockNNNN/<leaf>`` — matched mid-path, hence ``(?:^|/)``.
_BLOCK_RE = re.compile(r"(?:^|/)block(\d+)/")


def slot_leaf_prefix(slot: int) -> str:
    """Canonical view key for one slot (zero-padded so string-sorted plan
    keys group by slot)."""
    return f"slot{slot:03d}"


def slot_view(tree, n_slots: int) -> Dict:
    """Per-slot view of a slot-major tree (every leaf ``[slot, ...]``).

    Inside a jitted program the slices are free (fused static-index
    gathers); outside they alias device memory.  The view's digest-plan
    keys are ``slotNNN/<leaf path>`` — the (leaf, slot) canary units."""
    return {slot_leaf_prefix(u): jax.tree_util.tree_map(lambda l: l[u], tree)
            for u in range(n_slots)}


def slot_of_leaf(key: str) -> Optional[int]:
    """Slot id encoded in a slot-view leaf path (None for non-slot keys)."""
    m = _SLOT_RE.match(key)
    return int(m.group(1)) if m else None


def block_leaf_prefix(block: int) -> str:
    """Canonical view key for one pool block (paged serving engine)."""
    return f"block{block:04d}"


def block_view(pool, n_blocks: int) -> Dict:
    """Per-block view of a block-major KV pool (every leaf
    ``[block, block_size, ...]``).  The digest-plan keys become
    ``blockNNNN/<leaf path>`` — (leaf, block) canary units, so the
    rotating checksum attributes a fault to a specific *pool block*; the
    engine's allocator then maps block → owning slot (or to no owner, in
    which case the fault hit free bytes and nothing needs evicting)."""
    return {block_leaf_prefix(b): jax.tree_util.tree_map(lambda l: l[b], pool)
            for b in range(n_blocks)}


def block_of_leaf(key: str) -> Optional[int]:
    """Pool block id encoded in a block-view leaf path (None for
    non-block keys).  Matches both raw plan keys (``block0007/...``) and
    ownership-translated report keys (``slot001/block0007/...``)."""
    m = _BLOCK_RE.search(key)
    return int(m.group(1)) if m else None


@dataclass
class FaultReport:
    step: int
    detector: str               # 'nonfinite' | 'loss_spike' | 'checksum' | 'external'
    leaves: List[str] = field(default_factory=list)  # suspected leaf paths
    detail: str = ""
    #: mesh attribution (sharded canary): leaf path -> injured shard ids
    #: (mesh-flat device order).  Empty off-mesh or when only free traps
    #: fired; the shard_patch recovery rung consumes it to restore only
    #: the injured shards' addressable state.
    shards: Dict[str, List[int]] = field(default_factory=dict)
    #: deferred leaf attribution (in-step fused detection): the hot path
    #: fetches only the scalar mismatch flag; the per-(leaf[, shard])
    #: bad-mask stays on device until the fault path calls ``resolve``
    #: (one extra transfer, fault path only).
    resolver: Optional[Callable] = \
        field(default=None, repr=False, compare=False)
    #: True when the faulting state version was DONATED into the step that
    #: detected the fault (in-step fused detection under donation): the
    #: surviving shards' buffers are dead, so the in-place rungs — parity
    #: reconstruction included — must abort to snapshot+replay.  The
    #: donated PAIR protocol checks BEFORE the step consumes the buffer,
    #: so its reports stay ``consumed=False`` and parity can repair live
    #: survivors even under donation.
    consumed: bool = False
    #: HARD loss (non-transient): data-axis row indices whose devices are
    #: gone (host/board failure).  A non-empty tuple routes the ladder to
    #: the ``remesh`` rung — in-place repair is meaningless when the
    #: hardware itself is dead (launch/elastic.py; DESIGN.md §7).
    lost_rows: Tuple[int, ...] = ()

    def resolve(self) -> List[str]:
        """Materialise ``leaves`` (and ``shards``, on a mesh) from a
        deferred attribution (no-op when attribution already happened at
        detection time)."""
        if self.resolver is not None:
            res = self.resolver()
            if isinstance(res, tuple):
                self.leaves, self.shards = res
            else:
                self.leaves = res
            self.resolver = None
        return self.leaves

    def injured_slots(self) -> List[int]:
        """Slot ids named by a slot-view canary report (serving engine).

        Resolves deferred attribution, then parses the ``slotNNN/`` prefix
        of every corrupted leaf path.  Empty for non-slot canaries or when
        only free traps fired (the engine then falls back to its per-slot
        non-finite flags)."""
        return sorted({s for s in (slot_of_leaf(k) for k in self.resolve())
                       if s is not None})

    def injured_blocks(self) -> List[int]:
        """Pool block ids named by a block-view canary report (paged
        serving engine).  Empty for non-paged canaries."""
        return sorted({b for b in (block_of_leaf(k) for k in self.resolve())
                       if b is not None})

    def __str__(self):
        where = f" leaves={self.leaves[:3]}{'...' if len(self.leaves) > 3 else ''}" \
            if self.leaves else ""
        return f"FaultReport(step={self.step}, {self.detector}{where} {self.detail})"


def trap_nonfinite(step: int, metrics: Dict) -> Optional[FaultReport]:
    for name in ("loss", "grad_norm"):
        v = metrics.get(name)
        if v is None:
            continue
        fv = float(v)
        if not math.isfinite(fv):
            return FaultReport(step, "nonfinite",
                               detail=f"{name}={fv}")
    return None


def trap_loss_spike(step: int, metrics: Dict, history: Sequence[float],
                    factor: float = 10.0,
                    window: int = LOSS_WINDOW) -> Optional[FaultReport]:
    if len(history) < window:
        return None
    v = metrics.get("loss")
    if v is None:
        return None
    fv = float(v)
    ref = float(np.median(list(history)[-window:]))
    if math.isfinite(fv) and fv > factor * max(ref, 1e-6):
        return FaultReport(step, "loss_spike",
                           detail=f"loss={fv:.3g} median={ref:.3g}")
    return None


# per-plan cache of the fused canary step functions.  Plans are global
# singletons per state structure (kernels.digest._PLAN_CACHE), so every
# ChecksumCanary instance over the same structure — e.g. one per campaign
# trial — reuses the same compiled functions and never retraces.
_FUSED_CACHE: Dict[Tuple[object, int, str, int], object] = {}


def evict_mesh(mesh) -> int:
    """Drop fused canary executables whose plan (digest or parity) is
    keyed on ``mesh`` — the elastic remesh path calls this so a dead
    mesh's executables release their buffers and a later drill in the
    same process cannot hit a stale-device program."""
    mk = kdigest._mesh_key(mesh)
    stale = [k for k in _FUSED_CACHE if kdigest.key_on_mesh(k, mk)]
    for k in stale:
        del _FUSED_CACHE[k]
    return len(stale)


class ChecksumCanary:
    """Rotating-slice checksum detector over a state subtree.

    The reference digests live in a **double-buffered pair of on-device
    tables** (n_leaves, 2), alternating by *generation*: every
    ``check_and_arm`` verifies against the previous generation's table
    (rows armed one step ago) while scatter-arming the next generation's
    table **in place** — the write table is donated into the fused step
    function, so the hot path allocates nothing, and the read table
    survives untouched.  That survival is what makes the canary
    donation-safe: when the training step runs with ``donate_argnums`` the
    pre-step state buffer is consumed by the step, but its digests (armed
    last generation) are still on device for the trap path to report
    against.

    One ``check_and_arm`` is a single fused launch (in-place pack +
    digest) + exactly one scalar "any mismatch?" host sync.  Leaf
    attribution (the Recovery Table key the runtime needs) walks the
    leaf-index map only on the fault path.

    Donation protocol: a fused check+arm launch cannot span a donated
    step — the pre-step and post-step buffers are never simultaneously
    readable, and comparing digests across state *versions* would trap on
    every legitimate update.  A donated loop therefore splits the pair
    over the buffer's lifetime: ``arm_current(s, state)`` at the TOP of
    the loop body (digest slice ``s % K`` of the buffer the previous step
    just produced; one launch, no sync) and ``check(s, state)`` right
    before the step consumes it (one launch, ONE scalar sync).  Same
    2·(1/K) bytes per step as the fused call; the protected at-rest
    window is everything between the two dispatch points — on real
    hardware, the async-queue gap where the buffer sits in HBM.
    ``fuse_into_step`` collapses the pair back to ONE launch by running
    the check of the input slice and the arm of the output slice *inside*
    the jitted (donated) step — K rotation-specialised step executables,
    see core/fused_step.py.

    ``check``/``arm`` remain as standalone entry points for callers that
    hold only one state version at a time; each is itself a single fused
    launch (``arm`` syncs nothing).

    Mesh sharding (``ctx=DistContext`` with a live mesh; DESIGN.md §5):
    the canary becomes shard-local with NO change to the per-step
    contract.  The plan switches to a ``ShardedDigestPlan`` (every device
    digests only its addressable shard rows under shard_map), both
    generation tables grow a leading shard dim — (n_shards, L, 2),
    sharded over the mesh so each device compares and arms only its own
    rows — and the one fetched scalar becomes the all-reduced any(fault)
    flag, the only cross-device communication on the no-fault path.
    Every protocol above (fused ``check_and_arm``, donated pair, in-step
    fused) composes unchanged; fault-path attribution resolves to
    (leaf, shard) pairs (``FaultReport.shards``), which is what lets the
    recovery runtime restore only the injured shard's addressable state.
    The protected state must be ``device_put`` with its partition specs
    before the canary is built (``launch/specs.state_shardings``).
    """

    def __init__(self, tree, n_slices: int = 4, ctx=None):
        self.n_slices = max(1, n_slices)
        self.ctx = ctx if (ctx is not None and ctx.enabled) else None
        self.plan = kdigest.sharded_plan_for(tree, self.ctx.mesh) \
            if self.ctx else kdigest.plan_for(tree)
        self._keys: Tuple[str, ...] = self.plan.keys
        table = self.plan.digest_table(tree)
        #: generation-alternating reference tables; row i of either ==
        #: digest of leaf ``self._keys[i]`` as of the generation that
        #: last armed it.  ``_tables[_gen & 1]`` is the read (surviving)
        #: generation, the other slot is scatter-armed in place.
        self._tables = [table, table.copy()]
        self._gen = 0
        #: optional device-resident parity store (core/parity.ParityStore):
        #: when attached, parity maintenance rides the canary's own fused
        #: launches — incremental (old^new^parity) inside ``check_and_arm``,
        #: rebuild-of-the-armed-version inside ``arm``/``arm_current`` —
        #: so the launch/sync contract of every protocol is unchanged.
        self._parity = None
        #: the read table that served the most recent FIRED check.  The
        #: fused protocols commit the generation bump before the flag is
        #: fetched, so after a fault ``reference`` already points at the
        #: next generation (whose row for the faulted leaf is stale);
        #: recovery certification needs the rows the mismatch was actually
        #: compared against.  Set on the fault path only.
        self._fault_reference = None

    def attach_parity(self, store) -> None:
        """Ride the given ParityStore on every subsequent arm: the store's
        buffer is donated through the canary's fused programs and committed
        in lockstep with the generation tables.  The store's plan must be
        built over the same state structure as this canary's plan."""
        self._parity = store

    @property
    def parity_store(self):
        return self._parity

    @property
    def generation(self) -> int:
        """Monotonic table generation — bumped by every arm and by a full
        ``refresh`` (the post-restore correctness hinge; see ``refresh``)."""
        return self._gen

    @property
    def reference(self) -> jnp.ndarray:
        """The surviving (read-generation) on-device reference table."""
        return self._tables[self._gen & 1]

    # -- slice geometry ----------------------------------------------------

    def _slice_indices(self, step: int) -> List[int]:
        return rotating_slice(step, self.n_slices, len(self._keys))

    def _slice_keys(self, step: int) -> List[str]:
        return [self._keys[i] for i in self._slice_indices(step)]

    # -- fused step functions ---------------------------------------------

    def _fused_fn(self, kind: str, r: int):
        """jit'd fused step function for rotation ``r``.

        kind 'check_arm': ``(pack_buf, leaves, ref_read, ref_write) ->
        (pack_buf, flag, bad_mask, new_write)`` — check-slice leaves +
        arm-slice leaves (possibly from two state versions) packed into
        ONE digest launch; the packing buffer and the write-generation
        table are donated, so the arm scatter is in place.
        'check': ``(pack_buf, leaves, ref_read) -> (pack_buf, flag, bad)``
        (no table written); 'arm': ``(pack_buf, leaves, ref_write) ->
        (pack_buf, new_write)`` (no comparison).

        With a parity store attached, the arming kinds grow a donated
        parity-buffer argument plus the covered old/new leaves and return
        the updated parity as an extra output — the parity XOR rides the
        SAME launch (the steady-state contract is untouched; only the
        bytes streamed grow).  'check' never touches parity.
        """
        pplan = self._parity.plan \
            if (self._parity is not None and kind != "check") else None
        key = (self.plan, self.n_slices, kind, r, pplan)
        fn = _FUSED_CACHE.get(key)
        if fn is not None:
            return fn
        chk = self._slice_indices(r) if kind != "arm" else []
        arm = self._slice_indices(r + 1) if kind != "check" else []
        core, union = kdigest.check_arm_subcomputation(self.plan, chk, arm)

        if kind == "check":
            def check_fn(buf, leaves, ref_read):
                buf, flag, bad, _ = core(buf, leaves, ref_read, ref_read)
                return buf, flag, bad
            fn = jax.jit(check_fn, donate_argnums=(0,))
        elif kind == "arm":
            if pplan is None:
                def arm_fn(buf, leaves, ref_write):
                    buf, _, _, new_write = core(
                        buf, leaves, ref_write, ref_write)
                    return buf, new_write
                fn = jax.jit(arm_fn, donate_argnums=(0, 2))
            else:
                def arm_fn(buf, leaves, ref_write, parity, armed_leaves):
                    buf, _, _, new_write = core(
                        buf, leaves, ref_write, ref_write)
                    # donated-pair maintenance: only ONE state version is
                    # visible, so the per-step parity form is a rebuild of
                    # the armed (healthy-assumed) version, in this launch
                    new_parity = pplan.rebuild_leaves(armed_leaves)
                    return buf, new_write, new_parity
                fn = jax.jit(arm_fn, donate_argnums=(0, 2, 3))
        else:
            if pplan is None:
                fn = jax.jit(core, donate_argnums=(0, 3))
            else:
                def check_arm_fn(buf, leaves, ref_read, ref_write, parity,
                                 old_leaves, new_leaves):
                    buf, flag, bad, new_write = core(
                        buf, leaves, ref_read, ref_write)
                    # incremental old^new^parity, gated on THIS launch's
                    # fault flag: a detected fault zeroes the delta so the
                    # committed parity keeps describing the last healthy
                    # certified version (the one reconstruction restores)
                    new_parity = pplan.update_leaves(
                        parity, old_leaves, new_leaves, flag)
                    return buf, flag, bad, new_write, new_parity
                fn = jax.jit(check_arm_fn, donate_argnums=(0, 3, 4))
        _FUSED_CACHE[key] = (fn, union)
        return fn, union

    def _gather(self, tree, indices: Sequence[int]) -> List:
        leaves = self.plan.leaves(tree)
        return [leaves[i] for i in indices]

    def _attribute(self, chk: Sequence[int], bad_mask
                   ) -> Tuple[List[str], Dict[str, List[int]]]:
        """Fault path only: fetch the mismatch mask (the one extra
        transfer) and name the corrupted leaf paths.  Off-mesh the mask is
        (len(chk),) and the shard map is empty; on a mesh it is
        (n_shards, len(chk)) and every corrupted leaf also names its
        injured shard ids (mesh-flat device order)."""
        mask = np.atleast_1d(kdigest.fetch(bad_mask))
        if mask.ndim == 2:       # sharded: per-(shard, leaf) mismatch
            shards = {self._keys[i]: [int(d) for d in
                                      np.nonzero(mask[:, j])[0]]
                      for j, i in enumerate(chk) if mask[:, j].any()}
            return sorted(shards), shards
        return sorted(self._keys[i] for i, b in zip(chk, mask) if b), {}

    def _report(self, step: int, chk: Sequence[int], bad_mask) -> FaultReport:
        leaves, shards = self._attribute(chk, bad_mask)
        return FaultReport(step, "checksum", leaves=leaves, shards=shards)

    # -- generation-table plumbing ----------------------------------------
    #
    # The double-buffered reference pair is exposed through a begin/commit
    # protocol so that detection embedded in OTHER jitted programs (the
    # in-step fused mode, core/fused_step.py) can do the same in-place arm
    # the standalone fused launches do: ``begin_update`` hands out the
    # surviving read table and the donatable write table; the caller
    # donates the write table into its program and hands the aliased
    # result back to ``commit_update``, which installs it and bumps the
    # generation.  Every arm in this module goes through the same pair,
    # so the generation discipline (read table survives the donated step;
    # ``refresh`` bumps past both) holds whether the arm happened in a
    # standalone launch or inside the step.

    def begin_update(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(read_table, write_table) for one check+arm generation: verify
        against the first, donate the second into the arming program."""
        return self._tables[self._gen & 1], self._tables[(self._gen + 1) & 1]

    def commit_update(self, new_write: jnp.ndarray) -> None:
        """Install the donated-through write table and bump the generation
        (the armed rows become the next check's reference)."""
        self._tables[(self._gen + 1) & 1] = new_write
        self._gen += 1

    # -- hot path ----------------------------------------------------------

    def check_and_arm(self, step: int, tree, armed_tree=None
                      ) -> Optional[FaultReport]:
        """The fused per-step canary: verify slice ``step % K`` of ``tree``
        against the generation armed last step, and (re)digest slice
        ``(step+1) % K`` of ``armed_tree`` (default: ``tree``) into the
        next generation — one kernel launch, one scalar host sync, zero
        allocations (packing buffer and write table both donated).

        In a (non-donated) training loop call this after the step with
        ``(pre_step_state, post_step_state)``: the check slice of the
        pre-step state is the same buffer the previous step armed, and the
        arm slice snapshots the fresh output the next check will verify.
        Donated loops must NOT use this fused form across the step — use
        the ``arm_current``/``check`` pair (see class docstring): a
        donated step consumes the pre-step buffer, so a post-hoc check
        would have nothing to digest, and a pre-step fused call would
        compare digests across state versions.
        """
        if armed_tree is None:
            armed_tree = tree
        r = step % self.n_slices
        chk = self._slice_indices(step)
        leaves = self._gather(tree, chk) + \
            self._gather(armed_tree, self._slice_indices(step + 1))
        if not leaves:
            return None
        fn, union = self._fused_fn("check_arm", r)
        kdigest.STATS.launches += 1
        ref_read, ref_write = self.begin_update()
        if self._parity is not None:
            pp = self._parity.plan
            buf, flag, bad, new_write, new_parity = fn(
                self.plan.take_buffer(union), leaves, ref_read, ref_write,
                self._parity.parity, pp.leaves(tree), pp.leaves(armed_tree))
            # the updated parity tracks ``armed_tree`` — the post-step
            # state version, same stamp as the donated pair's arm half
            self._parity.commit(new_parity, step + 1)
        else:
            buf, flag, bad, new_write = fn(
                self.plan.take_buffer(union), leaves, ref_read, ref_write)
        self.plan.put_buffer(union, buf)
        self.commit_update(new_write)
        if bool(kdigest.fetch(flag)):       # the step's ONE host sync
            self._fault_reference = ref_read
            return self._report(step, chk, bad)
        return None

    # -- compat / slow-path entry points ----------------------------------

    def check(self, step: int, tree) -> Optional[FaultReport]:
        """Verify slice ``step % K`` only (single launch + scalar sync;
        tables untouched, generation unchanged)."""
        chk = self._slice_indices(step)
        if not chk:
            return None
        fn, union = self._fused_fn("check", step % self.n_slices)
        kdigest.STATS.launches += 1
        buf, flag, bad = fn(self.plan.take_buffer(union),
                            self._gather(tree, chk),
                            self._tables[self._gen & 1])
        self.plan.put_buffer(union, buf)
        if bool(kdigest.fetch(flag)):
            self._fault_reference = self._tables[self._gen & 1]
            return self._report(step, chk, bad)
        return None

    def check_full(self, step: int, tree) -> Optional[FaultReport]:
        """Verify every leaf against the read generation (one launch; only
        meaningful right after init/refresh, off the rotating schedule)."""
        table = self.plan.digest_table(tree)
        # last axis = the 2 Fletcher terms; a leading shard dim (sharded
        # canary) survives into the mask for (leaf, shard) attribution
        bad = jnp.any(table != self.reference, axis=-1)
        if bool(kdigest.fetch(jnp.any(bad))):
            self._fault_reference = self.reference
            return self._report(step, range(len(self._keys)), bad)
        return None

    def arm(self, step: int, tree) -> None:
        """End-of-step: digest the slice that ``check(step+1, ...)`` will
        verify into the next generation (single launch, no host sync).
        Together with ``check`` this is the rotating canary;
        ``check_and_arm`` fuses both into one launch."""
        arm = self._slice_indices(step + 1)
        if not arm:
            return
        fn, union = self._fused_fn("arm", step % self.n_slices)
        kdigest.STATS.launches += 1
        _, ref_write = self.begin_update()
        if self._parity is not None:
            buf, new_write, new_parity = fn(
                self.plan.take_buffer(union), self._gather(tree, arm),
                ref_write, self._parity.parity,
                self._parity.plan.leaves(tree))
            self._parity.commit(new_parity, step + 1)
        else:
            buf, new_write = fn(self.plan.take_buffer(union),
                                self._gather(tree, arm), ref_write)
        self.plan.put_buffer(union, buf)
        self.commit_update(new_write)

    def fuse_into_step(self, step_fn, *, donate: bool = False,
                       warm: str = "lazy"):
        """Wrap ``step_fn(state, *args) -> (new_state, aux)`` so the canary
        check of the *input* state's slice ``s % K`` and the arm of the
        *output* state's slice ``(s+1) % K`` run INSIDE the jitted step —
        true 1-launch/step detection, donated or not (DESIGN.md §4.2
        "in-step fused" column).

        ``state`` must match this canary's plan structure; extra ``*args``
        (batch, params, ...) pass through untouched.  ``donate=True``
        donates the state into the step (the production in-place-update
        setting) — XLA schedules the input-slice digest reads before the
        donated in-place writes, which is what lets one launch span both
        state versions.  ``warm`` is the K-executable compilation knob:
        ``'eager'`` compiles all K rotation-specialised executables at the
        first call, ``'lazy'`` compiles each rotation on first use.

        Returns a ``FusedStepFactory`` (core/fused_step.py); drive it with
        ``factory.step(s, state, *args) -> (new_state, aux, report)``.
        """
        from repro.core.fused_step import FusedStepFactory
        return FusedStepFactory(step_fn, self, donate=donate, warm=warm)

    def arm_current(self, step: int, tree) -> None:
        """Donated-loop arm: digest slice ``step % K`` of the live state
        into the next generation (single launch, no sync) and bump.

        Call at the TOP of the loop body, as close as possible to the step
        that produced the buffer; ``check(step, tree)`` just before the
        next step then verifies the same slice of the same buffer version.
        The pair protects the buffer's whole at-rest window and never
        needs to read it after the step donates it."""
        self.arm(step - 1, tree)

    def refresh(self, tree, keys: Optional[Sequence[str]] = None) -> None:
        """Re-digest the whole reference table (or the named leaves) —
        called after a verified repair or restore, off the hot path.

        A full refresh BUMPS the generation and installs the fresh table
        as the new read generation.  The bump is load-bearing under
        donation: without it the first post-restore ``check_and_arm``
        would verify the restored state against the stale pre-restore
        generation and fire a spurious checksum fault (regression-tested
        in tests/test_digest.py).

        A PARTIAL refresh (explicit ``keys=``) must do the opposite: the
        generation is NOT bumped.  A bump here would swap the read/write
        roles of the double-buffered pair mid-rotation, so every slice
        NOT in ``keys`` would next be verified against the table its rows
        were armed into two generations ago — a different state version —
        and fire a spurious fault under donation.  Instead the named
        leaves' rows are patched IN BOTH generations (the repair certifies
        regardless of which table serves the next check) and every
        unrelated row — and the generation counter — is left untouched
        (regression-tested in tests/test_digest.py)."""
        if keys is None:
            table = self.plan.digest_table(tree)
            self._gen += 1
            self._tables[self._gen & 1] = table
            self._fault_reference = None
            return
        idx = sorted(self.plan.index_of(k) for k in keys)
        if not idx:
            return
        rows = np.asarray(idx, np.int32)
        sub = self.plan.digest_subset(tree, idx)
        # targeted repair: patch the named rows in BOTH generations so the
        # repair certifies regardless of which table serves the next check.
        # (...) keeps the leading shard dim of a sharded canary's tables:
        # row i of every shard is the leaf's per-shard digest.
        for b in (0, 1):
            self._tables[b] = self._tables[b].at[..., rows, :].set(sub)

    def reference_digests(self) -> Dict[str, np.ndarray]:
        """Host copy of the surviving reference table (debug/telemetry;
        one sync).  Sharded canaries yield (n_shards, 2) per leaf."""
        table = kdigest.fetch(self.reference)
        return {k: table[..., i, :] for i, k in enumerate(self._keys)}

    def fault_reference_digests(self) -> Dict[str, np.ndarray]:
        """Host copy of the table generation that served the most recent
        FIRED check — the rows the mismatch was compared against, which is
        what a repair must be certified against.  ``check_and_arm`` and
        the in-step fused protocol commit the generation bump before the
        flag sync, so ``reference_digests()`` is already one generation
        ahead on the fault path; the pair protocol's ``check`` commits
        nothing and the two accessors agree.  Falls back to the current
        reference when no check has fired since the last refresh."""
        table = self._fault_reference
        if table is None:
            table = self.reference
        table = kdigest.fetch(table)
        return {k: table[..., i, :] for i, k in enumerate(self._keys)}

    def surviving_reference_digests(self, dead):
        """``fault_reference_digests`` under a HARD loss: the reference
        table is sharded row-per-device, so the dead devices' rows are
        genuinely gone — reading them in a single-process simulation
        would be cheating the drill.  Returns ``(digests, have)``:
        ``digests[k]`` is the (n_shards, 2) rows with dead rows zeroed,
        ``have[d]`` marks the rows read from surviving devices (the only
        rows a survivor shard may be certified against)."""
        if self.ctx is None:
            raise ValueError("surviving_reference_digests needs a "
                             "sharded canary")
        table = self._fault_reference
        if table is None:
            table = self.reference
        dead = set(dead)
        out = np.zeros(table.shape, np.int32)
        got = np.zeros(table.shape, bool)
        for sh in table.addressable_shards:
            if sh.device in dead:
                continue
            out[sh.index] = np.asarray(sh.data)
            got[sh.index] = True
        have = got.reshape(table.shape[0], -1).all(axis=1)
        dig = {k: out[..., i, :] for i, k in enumerate(self._keys)}
        return dig, have

    def fault_reference_digest(self, key: str) -> np.ndarray:
        """Single-leaf row of ``fault_reference_digests`` — the reference
        pair the triage rung solves ``kernels.digest.locate_single_flip``
        against (int32[2], or (n_shards, 2) on a sharded canary)."""
        table = self._fault_reference
        if table is None:
            table = self.reference
        table = kdigest.fetch(table)
        return table[..., self.plan.index_of(key), :]
