"""Detectors — the TPU-domain analogue of the paper's free SIGSEGV trap.

Ordered by cost:
  1. ``trap_nonfinite``   — free: inspects the already-computed loss/grad-norm
     scalars.  A transient fault that corrupts arithmetic state overwhelmingly
     surfaces as Inf/NaN within a step or two (the paper's observation that
     89.8% of crashes are SIGSEGV within ≤50 instructions transfers as:
     non-finite contamination within ≤2 steps).
  2. ``trap_loss_spike``  — free: order-of-magnitude loss jump.
  3. ``checksum_canary``  — one HBM pass over a rotating 2/K slice of the
     state (a single fused Pallas launch; DESIGN.md §4.2): catches *dormant*
     corruption (e.g. a flipped optimizer-moment bit that hasn't
     contaminated the loss yet), giving full-state coverage every K steps.
     The hot path costs exactly one kernel launch and one scalar
     device→host sync per step, independent of the number of state leaves.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import digest as kdigest
from repro.kernels.ops import rotating_slice

#: default window for the loss-spike trap; callers keep a bounded
#: ``deque(maxlen=LOSS_WINDOW)`` history (unbounded lists grew without
#: limit over long runs).
LOSS_WINDOW = 8


@dataclass
class FaultReport:
    step: int
    detector: str               # 'nonfinite' | 'loss_spike' | 'checksum' | 'external'
    leaves: List[str] = field(default_factory=list)  # suspected leaf paths
    detail: str = ""

    def __str__(self):
        where = f" leaves={self.leaves[:3]}{'...' if len(self.leaves) > 3 else ''}" \
            if self.leaves else ""
        return f"FaultReport(step={self.step}, {self.detector}{where} {self.detail})"


def trap_nonfinite(step: int, metrics: Dict) -> Optional[FaultReport]:
    for name in ("loss", "grad_norm"):
        v = metrics.get(name)
        if v is None:
            continue
        fv = float(v)
        if not math.isfinite(fv):
            return FaultReport(step, "nonfinite",
                               detail=f"{name}={fv}")
    return None


def trap_loss_spike(step: int, metrics: Dict, history: Sequence[float],
                    factor: float = 10.0,
                    window: int = LOSS_WINDOW) -> Optional[FaultReport]:
    if len(history) < window:
        return None
    v = metrics.get("loss")
    if v is None:
        return None
    fv = float(v)
    ref = float(np.median(list(history)[-window:]))
    if math.isfinite(fv) and fv > factor * max(ref, 1e-6):
        return FaultReport(step, "loss_spike",
                           detail=f"loss={fv:.3g} median={ref:.3g}")
    return None


# per-plan cache of the fused canary step functions.  Plans are global
# singletons per state structure (kernels.digest._PLAN_CACHE), so every
# ChecksumCanary instance over the same structure — e.g. one per campaign
# trial — reuses the same compiled functions and never retraces.
_FUSED_CACHE: Dict[Tuple[object, int, str, int], object] = {}


class ChecksumCanary:
    """Rotating-slice checksum detector over a state subtree.

    The reference digests live in an **on-device table** (n_leaves, 2);
    ``check_and_arm`` verifies the step's check slice and refreshes the
    next step's arm slice with a single fused Pallas launch, compares
    digest tables device-side, and fetches exactly one scalar
    "any mismatch?" flag.  Leaf attribution (the Recovery Table key the
    runtime needs) walks the leaf-index map only on the fault path.

    ``check``/``arm`` remain as standalone entry points for callers that
    hold only one state version at a time; each is itself a single fused
    launch (``arm`` syncs nothing).
    """

    def __init__(self, tree, n_slices: int = 4):
        self.n_slices = max(1, n_slices)
        self.plan = kdigest.plan_for(tree)
        self._keys: Tuple[str, ...] = self.plan.keys
        #: on-device reference digest table, row i == digest of leaf
        #: ``self._keys[i]``.
        self.reference: jnp.ndarray = self.plan.digest_table(tree)

    # -- slice geometry ----------------------------------------------------

    def _slice_indices(self, step: int) -> List[int]:
        return rotating_slice(step, self.n_slices, len(self._keys))

    def _slice_keys(self, step: int) -> List[str]:
        return [self._keys[i] for i in self._slice_indices(step)]

    # -- fused step functions ---------------------------------------------

    def _fused_fn(self, kind: str, r: int):
        """jit'd (leaves, reference) -> (flag, bad_mask, new_reference).

        kind 'check_arm': leaves = check-slice leaves + arm-slice leaves
        (possibly from two state versions) packed into ONE digest launch;
        'check': check slice only (reference unchanged); 'arm': arm slice
        only (no comparison).
        """
        key = (self.plan, self.n_slices, kind, r)
        fn = _FUSED_CACHE.get(key)
        if fn is not None:
            return fn
        chk = self._slice_indices(r) if kind != "arm" else []
        arm = self._slice_indices(r + 1) if kind != "check" else []
        union = tuple(chk) + tuple(arm)
        digest = self.plan.digest_fn(union)
        chk_rows = np.asarray(chk, np.int32)
        arm_rows = np.asarray(arm, np.int32)
        nc = len(chk)

        def step_fn(leaves, reference):
            table = digest(leaves)              # ONE pallas launch
            bad = jnp.any(table[:nc] != reference[chk_rows], axis=1) \
                if nc else jnp.zeros((0,), bool)
            new_ref = reference.at[arm_rows].set(table[nc:]) \
                if len(arm) else reference
            return jnp.any(bad), bad, new_ref

        fn = jax.jit(step_fn)
        _FUSED_CACHE[key] = fn
        return fn

    def _gather(self, tree, indices: Sequence[int]) -> List:
        leaves = self.plan.leaves(tree)
        return [leaves[i] for i in indices]

    def _report(self, step: int, chk: Sequence[int], bad_mask) -> FaultReport:
        # fault path only: fetch the per-leaf mismatch vector and attribute
        mask = kdigest.fetch(bad_mask)
        leaves = sorted(self._keys[i] for i, b in zip(chk, mask) if b)
        return FaultReport(step, "checksum", leaves=leaves)

    # -- hot path ----------------------------------------------------------

    def check_and_arm(self, step: int, tree, armed_tree=None
                      ) -> Optional[FaultReport]:
        """The fused per-step canary: verify slice ``step % K`` of ``tree``
        against the reference armed last step, and (re)digest slice
        ``(step+1) % K`` of ``armed_tree`` (default: ``tree``) — one kernel
        launch, one scalar host sync.

        In a training loop call this after the step with
        ``(pre_step_state, post_step_state)``: the check slice of the
        pre-step state is the same buffer the previous step armed, and the
        arm slice snapshots the fresh output the next check will verify.
        """
        if armed_tree is None:
            armed_tree = tree
        r = step % self.n_slices
        chk = self._slice_indices(step)
        leaves = self._gather(tree, chk) + \
            self._gather(armed_tree, self._slice_indices(step + 1))
        if not leaves:
            return None
        fn = self._fused_fn("check_arm", r)
        kdigest.STATS.launches += 1
        flag, bad, new_ref = fn(leaves, self.reference)
        self.reference = new_ref
        if bool(kdigest.fetch(flag)):       # the step's ONE host sync
            return self._report(step, chk, bad)
        return None

    # -- compat / slow-path entry points ----------------------------------

    def check(self, step: int, tree) -> Optional[FaultReport]:
        """Verify slice ``step % K`` only (single launch + scalar sync)."""
        chk = self._slice_indices(step)
        if not chk:
            return None
        fn = self._fused_fn("check", step % self.n_slices)
        kdigest.STATS.launches += 1
        flag, bad, _ = fn(self._gather(tree, chk), self.reference)
        if bool(kdigest.fetch(flag)):
            return self._report(step, chk, bad)
        return None

    def check_full(self, step: int, tree) -> Optional[FaultReport]:
        """Verify every leaf (one launch; used off the rotating schedule)."""
        table = self.plan.digest_table(tree)
        bad = jnp.any(table != self.reference, axis=1)
        if bool(kdigest.fetch(jnp.any(bad))):
            return self._report(step, range(len(self._keys)), bad)
        return None

    def arm(self, step: int, tree) -> None:
        """End-of-step: digest the slice that ``check(step+1, ...)`` will
        verify (single launch, no host sync).  Together with ``check`` this
        is the rotating canary; ``check_and_arm`` fuses both into one
        launch."""
        arm = self._slice_indices(step + 1)
        if not arm:
            return
        fn = self._fused_fn("arm", step % self.n_slices)
        kdigest.STATS.launches += 1
        _, _, self.reference = fn(self._gather(tree, arm), self.reference)

    def refresh(self, tree, keys: Optional[Sequence[str]] = None) -> None:
        """Re-digest the whole reference table (or the named leaves) —
        called after a verified repair, off the hot path."""
        if keys is None:
            self.reference = self.plan.digest_table(tree)
            return
        idx = sorted(self.plan.index_of(k) for k in keys)
        if not idx:
            return
        rows = np.asarray(idx, np.int32)
        self.reference = self.reference.at[rows].set(
            self.plan.digest_subset(tree, idx))

    def reference_digests(self) -> Dict[str, np.ndarray]:
        """Host copy of the reference table (debug/telemetry; one sync)."""
        table = kdigest.fetch(self.reference)
        return {k: table[i] for i, k in enumerate(self._keys)}
