"""In-step fused detection under donation — the step carries its own canary.

PR 3 made the rotating checksum canary donation-safe by splitting the
check/arm pair around the step (``arm_current`` after the step produces a
buffer, ``check`` just before the next step consumes it): 2 launches/step.
This module inverts the control flow — instead of the runtime calling the
digest around the step, the *step function itself* is wrapped so that

  * the digest of canary slice ``s % K`` of the INPUT state (the check),
  * the user step, and
  * the digest of slice ``(s+1) % K`` of the OUTPUT state (the arm)

are one jitted program per rotation ``r = s % K``.  XLA's dataflow
scheduling orders the input-slice digest reads before the donated in-place
writes, so the pre- and post-step state versions CAN meet in one launch —
the thing the host-side pair could never do across a donated dispatch.

Launch/sync/byte contract (DESIGN.md §4.2, "in-step fused" column):

  * 1 combined launch/step (the step's own dispatch; detection adds zero
    extra launches) — down from 2 (donated pair) or from 1 step + 1
    digest launch (non-donated ``check_and_arm``);
  * 1 scalar "any mismatch?" device→host sync/step; the per-leaf bad-mask
    vector stays on device until the fault path resolves attribution
    (``FaultReport.resolve``);
  * ~2/K of the state's bytes digested per step — unchanged;
  * 0 steady-state device allocations on the digest path: the persistent
    packing buffer and the write-generation reference table are donated
    through every call, exactly as in the standalone fused launches.

The price is K rotation-specialised compilations of the step: each
rotation digests a different leaf subset, so each is its own executable.
``FusedStepFactory`` AOT-compiles (``jit(...).lower(...).compile()``) and
caches the K executables globally — keyed by (plan, K, step_fn, donate,
rotation, arg shapes) so campaign-style callers that build one factory
per trial over the same structure never recompile — and warms them
eagerly or lazily per the ``warm`` knob.  After warmup the hot path never
retraces (``kernels.digest.STATS.traces`` stays flat).

Detection semantics are bit-identical to the non-donated
``check_and_arm`` protocol: slice ``s % K`` of the input state is
verified against the generation that armed it (step ``s-1``'s output
digest — the same buffer version), and slice ``(s+1) % K`` of the output
is armed for step ``s+1``'s check.  The trajectory itself is bit-exact to
the unfused step: the digest subcomputation only *reads* the state on
either side of the user step, it never feeds back into it.
"""

from __future__ import annotations

import time
import weakref
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.detect import ChecksumCanary, FaultReport
from repro.kernels import digest as kdigest

#: global executable cache — step_fn -> {(plan, K, donate, rotation,
#: args_sig): (compiled, union, chk)}.  The outer map is WEAKLY keyed on
#: the step-fn object: callers that build many factories over one
#: long-lived step function (one per campaign trial — the campaign holds
#: the function) share entries and never recompile, while callers that
#: mint a fresh step function per run (launch/train.py, launch/serve.py)
#: leak nothing — when the run's factory and step function are released,
#: their K executables evaporate with the weak key.
_EXEC_CACHE: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def clear_executable_cache() -> None:
    """Drop every cached fused-step executable immediately (the weak
    keying already reclaims entries whose step function has died)."""
    _EXEC_CACHE.clear()


def evict_mesh(mesh) -> int:
    """Drop cached fused-step executables keyed on ``mesh`` (via their
    digest/parity plans) across ALL live step functions — the elastic
    remesh path: a dead mesh's executables must release their buffers,
    and a second drill in-process must never hit one."""
    from repro.kernels import digest as kdigest
    mk = kdigest._mesh_key(mesh)
    n = 0
    for by_key in _EXEC_CACHE.values():
        stale = [k for k in by_key if kdigest.key_on_mesh(k, mk)]
        for k in stale:
            del by_key[k]
        n += len(stale)
    return n


def _sds(tree):
    """ShapeDtypeStructs of a pytree — compile without executing.

    NamedShardings ride along: a mesh-sharded state (DESIGN.md §5) must
    AOT-compile against its real layout, or the executable would insert
    reshards around the shard_map'd canary subcomputation."""
    from jax.sharding import NamedSharding

    def sds(x):
        sharding = getattr(x, "sharding", None)
        if isinstance(sharding, NamedSharding):
            return jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x),
                                        sharding=sharding)
        return jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x))

    return jax.tree_util.tree_map(sds, tree)


def _args_signature(args) -> Tuple:
    from jax.sharding import NamedSharding

    def sig(x):
        sh = getattr(x, "sharding", None)
        spec = str(sh.spec) if isinstance(sh, NamedSharding) else None
        return (jnp.shape(x), jnp.result_type(x).name, spec)

    flat, treedef = jax.tree_util.tree_flatten(args)
    return (treedef, tuple(sig(x) for x in flat))


class FusedStepFactory:
    """K rotation-specialised executables of (check ∘ step ∘ arm).

    Built by ``ChecksumCanary.fuse_into_step``.  Drive with::

        new_state, aux, report = factory.step(s, state, *args)

    ``step_fn(state, *args) -> (new_state, aux)`` must take and return the
    canary's plan structure as its first argument/result; ``aux`` (metrics,
    logits, ...) passes through.  ``report`` is ``None`` on the no-fault
    path (after the ONE scalar sync) or a ``FaultReport`` with deferred
    leaf attribution.  On a report the returned ``new_state`` was computed
    FROM the corrupted input and must be discarded by the caller; with
    ``donate=True`` the input state has also been consumed — recovery must
    pivot to snapshot + replay (``RecoveryRuntime(donated=True)``), just
    as with the arm/check pair.

    Compilation accounting: ``n_compiles``/``compile_seconds`` accumulate
    the K-executable warmup cost (the benchmarks report it); ``warm()``
    forces the full rotation eagerly and returns the wall time it took.
    """

    def __init__(self, step_fn, canary: ChecksumCanary, *,
                 donate: bool = False, warm: str = "lazy"):
        if warm not in ("lazy", "eager"):
            raise ValueError(f"warm must be 'lazy' or 'eager', got {warm!r}")
        self.step_fn = step_fn
        self.canary = canary
        self.plan = canary.plan
        self.n_slices = canary.n_slices
        self.donate = donate
        self.warm_mode = warm
        self.n_compiles = 0
        self.compile_seconds = 0.0
        self._warmed_sigs: set = set()
        #: the signature of the first-seen step args, memoised so the hot
        #: path never re-flattens the args pytree (a serve-mode factory
        #: would otherwise flatten the full params tree every token).
        #: The factory therefore assumes a STABLE arg structure across
        #: ``step`` calls — a shape change raises an aval mismatch from
        #: the compiled executable rather than silently recompiling.
        self._step_sig = None

    # -- compilation -------------------------------------------------------

    def _build(self, r: int, state_sds, args_sds):
        """Trace + AOT-compile rotation ``r``'s fused executable."""
        from jax.sharding import NamedSharding

        chk = self.canary._slice_indices(r)
        arm = self.canary._slice_indices(r + 1)
        core, union = kdigest.check_arm_subcomputation(self.plan, chk, arm) \
            if (chk or arm) else (None, ())
        plan, step_fn = self.plan, self.step_fn
        pstore = self.canary.parity_store
        pplan = pstore.plan if (pstore is not None and core is not None) \
            else None

        def pin_layout(new_state):
            # mesh loops: constrain the OUTPUT state to the input layout.
            # GSPMD would otherwise pick different shardings for some
            # leaves, which (a) breaks the steady state of an AOT
            # executable (step s+1's input no longer matches the compiled
            # sharding) and (b) defeats donation, which can only reuse a
            # donated buffer into an identically-laid-out output.
            def c(x, s):
                sh = getattr(s, "sharding", None)
                if isinstance(sh, NamedSharding):
                    return jax.lax.with_sharding_constraint(x, sh)
                return x
            return jax.tree_util.tree_map(c, new_state, state_sds)

        if core is None:
            # degenerate rotation (fewer leaves than slices): plain step
            def fused(state, *args):
                new_state, aux = step_fn(state, *args)
                return pin_layout(new_state), aux
            donate_argnums = (0,) if self.donate else ()
            jfn = jax.jit(fused, donate_argnums=donate_argnums)
            lowered = jfn.lower(state_sds, *args_sds)
        elif pplan is None:
            def fused(state, buf, ref_read, ref_write, *args):
                in_leaves = plan.leaves(state)
                new_state, aux = step_fn(state, *args)
                new_state = pin_layout(new_state)
                out_leaves = plan.leaves(new_state)
                # one digest launch spanning both state versions: the
                # check slice reads the INPUT buffers (scheduled before
                # the donated in-place writes), the arm slice reads the
                # step's output
                buf, flag, bad, new_write = core(
                    buf,
                    [in_leaves[i] for i in chk] +
                    [out_leaves[i] for i in arm],
                    ref_read, ref_write)
                return new_state, aux, buf, flag, bad, new_write
            donate_argnums = (1, 3) + ((0,) if self.donate else ())
            jfn = jax.jit(fused, donate_argnums=donate_argnums)
            table_sds = _sds(self.canary.reference)
            buf_sds = _sds(self.plan.take_buffer(union))
            lowered = jfn.lower(state_sds, buf_sds, table_sds, table_sds,
                                *args_sds)
        else:
            def fused(state, buf, ref_read, ref_write, parity, *args):
                in_leaves = plan.leaves(state)
                p_old = pplan.leaves(state)
                new_state, aux = step_fn(state, *args)
                new_state = pin_layout(new_state)
                out_leaves = plan.leaves(new_state)
                buf, flag, bad, new_write = core(
                    buf,
                    [in_leaves[i] for i in chk] +
                    [out_leaves[i] for i in arm],
                    ref_read, ref_write)
                # incremental parity (old ^ new ^ parity) riding the SAME
                # fused launch, gated on this step's own fault flag: XLA
                # schedules the old-shard reads with the check-slice
                # digest reads, before the donated in-place writes
                new_parity = pplan.update_leaves(
                    parity, p_old, pplan.leaves(new_state), flag)
                return new_state, aux, buf, flag, bad, new_write, new_parity
            donate_argnums = (1, 3, 4) + ((0,) if self.donate else ())
            jfn = jax.jit(fused, donate_argnums=donate_argnums)
            table_sds = _sds(self.canary.reference)
            buf_sds = _sds(self.plan.take_buffer(union))
            parity_sds = _sds(pstore.parity)
            lowered = jfn.lower(state_sds, buf_sds, table_sds, table_sds,
                                parity_sds, *args_sds)
        t0 = time.perf_counter()
        compiled = lowered.compile()
        self.compile_seconds += time.perf_counter() - t0
        self.n_compiles += 1
        return compiled, union, tuple(chk)

    def _executable(self, r: int, sig, state, args):
        per_fn = _EXEC_CACHE.get(self.step_fn)
        if per_fn is None:
            per_fn = _EXEC_CACHE[self.step_fn] = {}
        pstore = self.canary.parity_store
        key = (self.plan, self.n_slices, self.donate, r, sig,
               pstore.plan if pstore is not None else None)
        ent = per_fn.get(key)
        if ent is None:
            ent = self._build(r, _sds(state), _sds(args))
            per_fn[key] = ent
        return ent

    def warm(self, state, *args) -> float:
        """Compile all K rotation executables for these arg shapes (no
        step compute — AOT lower/compile only).  Returns wall seconds;
        idempotent per arg signature."""
        return self._warm(_args_signature(args), state, args)

    def _warm(self, sig, state, args) -> float:
        if sig in self._warmed_sigs:
            return 0.0
        t0 = time.perf_counter()
        for r in range(self.n_slices):
            self._executable(r, sig, state, args)
        self._warmed_sigs.add(sig)
        return time.perf_counter() - t0

    # -- hot path ----------------------------------------------------------

    def step(self, s: int, state, *args):
        """Run one fused step: returns ``(new_state, aux, report)``.

        ONE launch (the combined step+detection executable) and ONE scalar
        host sync; the write-generation table commit and generation bump
        ride the canary's begin/commit plumbing, so interleaving with
        ``refresh`` (post-recovery) behaves exactly like the pair path.
        """
        # the signature is the dispatch key — memoised on first use so
        # steady-state steps never re-flatten the args pytree
        sig = self._step_sig
        if sig is None:
            sig = self._step_sig = _args_signature(args)
        if self.warm_mode == "eager":
            self._warm(sig, state, args)
        can = self.canary
        r = s % self.n_slices
        compiled, union, chk = self._executable(r, sig, state, args)
        kdigest.STATS.launches += 1
        if not union:                       # degenerate rotation: no digest
            new_state, aux = compiled(state, *args)
            return new_state, aux, None
        ref_read, ref_write = can.begin_update()
        pstore = can.parity_store
        if pstore is not None:
            new_state, aux, buf, flag, bad, new_write, new_parity = compiled(
                state, self.plan.take_buffer(union), ref_read, ref_write,
                pstore.parity, *args)
            pstore.commit(new_parity, s + 1)
        else:
            new_state, aux, buf, flag, bad, new_write = compiled(
                state, self.plan.take_buffer(union), ref_read, ref_write,
                *args)
        self.plan.put_buffer(union, buf)
        can.commit_update(new_write)
        report = None
        if bool(kdigest.fetch(flag)):       # the step's ONE host sync
            # the commit above already bumped the generation; the rows
            # this check actually compared against live in ref_read —
            # recovery certifies reconstructions against THEM
            can._fault_reference = ref_read
            # under donation the faulting input version was consumed by
            # this very launch: the parity rung's survivors are dead, and
            # the report says so up front (consumed=True) instead of
            # letting the rung discover it post-hoc
            report = FaultReport(
                s, "checksum",
                detail="in-step fused check",
                resolver=lambda: can._attribute(chk, bad),
                consumed=self.donate)
        return new_state, aux, report
