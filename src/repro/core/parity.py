"""Parity manager — manufactured redundancy for sharded state (the ICP
analogue at tensor level, DESIGN.md §4.2).

For a state sharded N ways over the data axis, one XOR parity shard per leaf
(1/N memory overhead) makes any single lost/corrupt shard exactly
reconstructible.  On the simulator the 'shards' are explicit array slices;
on a real pod the fold is a reduce over the data axis (the kernels are
shard-local either way).
"""

from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.kernels.ops import leaf_key


def _split(leaf, n_shards: int):
    """Shard a leaf on its first divisible dim (fallback: flat split)."""
    arr = jnp.asarray(leaf)
    if arr.ndim and arr.shape[0] % n_shards == 0:
        return jnp.split(arr, n_shards, axis=0)
    flat = arr.reshape(-1)
    pad = (-flat.shape[0]) % n_shards
    flat = jnp.pad(flat, (0, pad))
    return jnp.split(flat, n_shards)


def _join(shards, like):
    arr = jnp.asarray(like)
    if arr.ndim and arr.shape[0] % len(shards) == 0:
        return jnp.concatenate(shards, axis=0)
    flat = jnp.concatenate(shards)
    return flat[: arr.size].reshape(arr.shape)


class ParityManager:
    """Maintains one parity 'shard' per leaf of a tree."""

    def __init__(self, n_shards: int):
        self.n_shards = n_shards
        self.parity: Dict[str, np.ndarray] = {}

    def build(self, tree) -> None:
        def visit(path, leaf):
            shards = _split(leaf, self.n_shards)
            self.parity[leaf_key(path)] = np.asarray(kops.xor_fold(shards))
            return leaf

        jax.tree_util.tree_map_with_path(visit, tree)

    def repair(self, tree, lost_shard: int, keys: Optional[List[str]] = None):
        """Repair the given shard index of every (or the named) leaves.
        Parity payloads have the dtype/shape of one shard, so reconstruction
        is a direct XOR fold with the survivors."""
        want = set(keys) if keys is not None else None

        def visit(path, leaf):
            k = leaf_key(path)
            if want is not None and k not in want:
                return leaf
            if k not in self.parity:
                return leaf
            shards = list(_split(leaf, self.n_shards))
            survivors = [s for i, s in enumerate(shards) if i != lost_shard]
            shards[lost_shard] = kops.xor_reconstruct(
                jnp.asarray(self.parity[k]), survivors)
            return _join(shards, leaf)

        return jax.tree_util.tree_map_with_path(visit, tree)

    @property
    def memory_bytes(self) -> int:
        return sum(p.nbytes for p in self.parity.values())
