"""Device-resident XOR parity — manufactured redundancy for sharded state
(the ICP analogue at tensor level; DESIGN.md §4.2 and the parity-rung
section).

One parity shard per covered state leaf (params AND optimizer state): for a
leaf split into D shards, ``parity = XOR_d shard_d`` (over the raw ``to_i32``
bits), so any single lost or corrupt shard is exactly reconstructible from
the surviving peers plus parity — ``shard_j = parity ^ XOR_{d != j} shard_d``
— with no host snapshot and no replay.  XOR is bit-exact, so the
exact-or-abort rule holds with no floating-point caveats.

Coordinate system (the satellite bugfix this module exists for): the shard
boundaries are derived from each leaf's actual ``NamedSharding`` slices
(``kernels.digest.shard_indices``, mesh-flat device order — the SAME map the
sharded canary's digest tables and ``host_shard_checksums`` use), so the
(leaf, shard) a ``FaultReport`` attributes and the parity block it indexes
are one coordinate system by construction.  The seed's ``_split``
(first-divisible-dim) could disagree with a TP-sharded layout; a slice-map
derivation cannot.  Off-mesh the "shards" are D equal row-aligned chunks of
the flat ``to_i32`` view — again used identically by build, update and
reconstruct.

Replication: a leaf that is only partially sharded (e.g. TP-sharded but
DP-replicated) maps several devices to the SAME logical slice.  XOR over
identical copies self-cancels (an even replica count contributes zero!),
so the stream is built over the leaf's UNIQUE logical blocks — the slice
map deduplicated in mesh-flat device order — with zero rows padding the
shard axis.  ``device_block[key]`` translates a device-coordinate shard id
(what the sharded canary attributes) into the unique-block coordinate this
module reconstructs in; a repair is placed back on EVERY device holding
the injured block, keeping replicas bit-consistent.

Layout: the per-leaf parity blocks are concatenated into ONE int32 buffer —

  * off-mesh: tile-shaped ``(nt, TILE_ROWS, LANES)`` so the hot-path update
    is a single Pallas launch (``kernels.parity.xor_update_tiles``, parity
    aliased in place);
  * on a mesh: ``(D, Crow)`` sharded ``P(axis_names, None)`` like the digest
    packing buffers — each device holds 1/D of the parity (total memory
    overhead = state_bytes/D).

Hard loss (``row_safe=True``; DESIGN.md §7): the default placement puts
parity row ``d`` on device ``d`` — a whole lost DATA ROW therefore takes
its parity down with its data, and a leaf sharded over both the data and
the model axis loses SEVERAL unique blocks at once (one per model
column), which a single flat XOR fold cannot reconstruct.  ``row_safe``
mode fixes both for the elastic remesh path:

  * **placement** — the buffer is sharded over the NON-batch axes only
    (``P(("model",), None)``; fully replicated on a pure-DP mesh), so
    every surviving data row holds a complete copy of the parity.  The
    per-device memory cost rises from stream/D to stream/tp.
  * **fold groups** — unique blocks are grouped by their slice projection
    onto the dims NOT sharded over batch axes; the XOR fold runs PER
    GROUP (the stream carries ``n_groups × block_len`` columns per leaf),
    so a lost data row erases at most ONE member of each group — exactly
    the single erasure XOR inverts.  Only data-sharded leaves are covered
    in this mode: replicated / model-only leaves keep a surviving replica
    on the remaining rows and are re-gathered instead (launch/elastic.py).

Host-side reconstruction (``host_parity_flat`` / ``host_surviving_blocks``
/ ``host_reconstruct_block`` / ``host_assemble_leaf``) reads ONLY shards
on surviving devices — the honesty contract of the simulated-loss drill:
dead devices still answer in a single-process simulation, so every read
on the remesh path filters ``addressable_shards`` explicitly.

The hot-path entry points (``update_leaves`` / ``rebuild_leaves``) are pure
and traceable: the canary embeds them INSIDE its fused check/arm programs
(core/detect.py) and the fused step factory inside the donated step itself
(core/fused_step.py), so parity maintenance adds ZERO launches and ZERO
syncs to the steady state.  Updates are gated on the in-launch fault flag —
a detected fault zeroes the delta, so the committed parity keeps describing
the last healthy certified state version (the version the canary's read
generation certifies, which is exactly what reconstruction must produce).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.kernels import digest as kdigest
from repro.kernels import parity as pk
from repro.kernels import ref as kref
from repro.kernels.ops import leaf_key

LANES = pk.LANES
TILE_ROWS = pk.TILE_ROWS
TILE = TILE_ROWS * LANES

#: dtypes whose ``to_i32`` view is invertible (``from_i32`` restores the
#: exact bits).  int64/float64 views are lossy (truncated), so leaves of
#: those dtypes are NOT parity-covered — a fault there escalates past the
#: parity rung instead of risking a silent wrong-bits repair.
_INVERTIBLE = tuple(map(jnp.dtype, (
    jnp.int32, jnp.float32, jnp.uint32,
    jnp.bfloat16, jnp.float16, jnp.int16, jnp.uint16,
    jnp.int8, jnp.uint8)))


def _covered(key: str, dtype, shape=None) -> bool:
    """Parity coverage: params + optimizer state (everything but induction
    state, which Eq.(1) repairs for free — the ``iv`` block and the 0-d
    optimizer counters ``opt/t``/bias corrections) in invertible dtypes."""
    if shape is not None and tuple(shape) == ():
        return False
    return not key.startswith("iv") and jnp.dtype(dtype) in _INVERTIBLE


def _norm_slices(idx, shape) -> Tuple[Tuple[int, int], ...]:
    """devices_indices_map entry -> ((start, stop), ...) per dim."""
    out = []
    for sl, dim in zip(idx, shape):
        start = 0 if sl.start is None else int(sl.start)
        stop = dim if sl.stop is None else int(sl.stop)
        out.append((start, stop))
    return tuple(out)


class ParityPlan:
    """Block layout + traceable parity math for one (structure, sharding)
    pair.  Cached globally (``parity_plan_for``) so every store over the
    same structure — e.g. one per campaign trial — shares the layout and
    the compiled functions that close over it (no per-trial retraces)."""

    def __init__(self, keys: Tuple[str, ...],
                 shapes: Dict[str, Tuple[int, ...]],
                 dtypes: Dict[str, str],
                 slices: Optional[Dict[str, Tuple]],
                 n_shards: int, mesh=None,
                 groups: Optional[Dict[str, Tuple[Tuple[int, ...], ...]]]
                 = None,
                 row_safe: bool = False,
                 parity_axes: Tuple[str, ...] = ()):
        self.keys = keys
        self.key_set = frozenset(keys)
        self.shapes = shapes
        self.dtypes = dtypes
        #: key -> UNIQUE ((start, stop), ...) slice tuples in first-seen
        #: mesh-flat device order — mesh mode only (replicas deduplicated)
        self.slices = slices
        self.n_shards = n_shards
        self.mesh = mesh
        self.axis_names = tuple(mesh.axis_names) if mesh is not None else ()
        #: row-loss-survivable mode: fold per group, shard the buffer over
        #: the non-batch axes only (``parity_axes``; () -> replicated)
        self.row_safe = row_safe
        self.parity_axes = tuple(parity_axes)

        #: per-key common block length (int32 elements; blocks are padded
        #: to it so every leaf contributes equal columns to the stream)
        self.block_len: Dict[str, int] = {}
        #: per-key per-block true (unpadded) sizes and shapes
        self.block_sizes: Dict[str, Tuple[int, ...]] = {}
        self.block_shapes: Dict[str, Tuple[Tuple[int, ...], ...]] = {}
        #: per-key count of unique logical blocks (<= n_shards)
        self.n_blocks: Dict[str, int] = {}
        #: per-key device-coordinate shard id -> unique block id (mesh:
        #: the sharded canary attributes faults per DEVICE; off-mesh the
        #: two coordinate systems coincide)
        self.device_block: Dict[str, Tuple[int, ...]] = {}
        #: per-key fold groups: tuple of member-block-id tuples.  Default
        #: (non-row_safe) is ONE group holding every block — the original
        #: flat fold, same stream layout, bit for bit.
        self.groups: Dict[str, Tuple[Tuple[int, ...], ...]] = {}
        #: per-key block id -> (group, member index within the group)
        self.block_group: Dict[str, Tuple[Tuple[int, int], ...]] = {}
        self.n_groups: Dict[str, int] = {}
        off = 0
        self.offsets: Dict[str, int] = {}
        for k in keys:
            shape = shapes[k]
            size = int(np.prod(shape, dtype=np.int64)) if shape else 1
            if slices is None:
                c = max(1, -(-size // n_shards))
                self.block_len[k] = c
                self.block_sizes[k] = tuple(
                    max(0, min(c, size - d * c)) for d in range(n_shards))
                self.block_shapes[k] = tuple(
                    (self.block_sizes[k][d],) for d in range(n_shards))
                self.n_blocks[k] = n_shards
                self.device_block[k] = tuple(range(n_shards))
            else:
                uniq, dev_to_blk = slices[k]
                bshapes = tuple(
                    tuple(stop - start for start, stop in idx)
                    for idx in uniq)
                bsizes = tuple(
                    int(np.prod(bs, dtype=np.int64)) if bs else 1
                    for bs in bshapes)
                self.block_shapes[k] = bshapes
                self.block_sizes[k] = bsizes
                self.block_len[k] = max(bsizes)
                self.n_blocks[k] = len(uniq)
                self.device_block[k] = dev_to_blk
            gk = (groups or {}).get(k)
            if gk is None:
                gk = (tuple(range(self.n_blocks[k])),)
            self.groups[k] = gk
            bg = [(0, 0)] * self.n_blocks[k]
            for g, members in enumerate(gk):
                for m, blk in enumerate(members):
                    bg[blk] = (g, m)
            self.block_group[k] = tuple(bg)
            self.n_groups[k] = len(gk)
            self.offsets[k] = off
            off += self.n_groups[k] * self.block_len[k]
        #: total parity stream length (int32 elements)
        self.stream_len = off
        if row_safe:
            self.fold_width = max(
                [1] + [max((len(g) for g in self.groups[k]), default=1)
                       for k in keys])
        else:
            self.fold_width = n_shards
        if mesh is None:
            self.n_tiles = max(1, -(-self.stream_len // TILE))
            self.buffer_shape = (self.n_tiles, TILE_ROWS, LANES)
            self.buffer_spec = None
        elif row_safe:
            rows = 1
            for a in self.parity_axes:
                rows *= mesh.shape[a]
            crow = max(LANES, -(-self.stream_len // rows))
            crow = -(-crow // LANES) * LANES
            self.buffer_shape = (rows, crow)
            self.buffer_spec = P(self.parity_axes if self.parity_axes
                                 else None, None)
        else:
            crow = max(LANES, -(-self.stream_len // n_shards))
            crow = -(-crow // LANES) * LANES
            self.buffer_shape = (n_shards, crow)
            self.buffer_spec = P(self.axis_names, None)
        self._recon_cache: Dict[Tuple[str, int], object] = {}

    # -- layout helpers ----------------------------------------------------

    @property
    def memory_bytes(self) -> int:
        return int(np.prod(self.buffer_shape, dtype=np.int64)) * 4

    def leaves(self, tree) -> List:
        """Covered leaves in plan-key order."""
        flat, _ = jax.tree_util.tree_flatten_with_path(tree)
        by_key = {leaf_key(p): x for p, x in flat}
        return [by_key[k] for k in self.keys]

    def block_devices(self, key: str, blk: int) -> Tuple[int, ...]:
        """Mesh-flat device indices holding unique block ``blk`` — where a
        reconstructed block must be placed back (all replicas)."""
        return tuple(i for i, b in enumerate(self.device_block[key])
                     if b == blk)

    def make_buffer(self):
        """Zero parity buffer with the plan's device layout."""
        z = jnp.zeros(self.buffer_shape, jnp.int32)
        if self.mesh is not None:
            z = jax.device_put(
                z, NamedSharding(self.mesh, self.buffer_spec))
        return z

    # -- traceable stream construction ------------------------------------

    def _block_rows(self, key: str, leaf) -> List[jnp.ndarray]:
        """Per-unique-block padded int32 rows (mesh mode)."""
        c = self.block_len[key]
        uniq, _ = self.slices[key]
        rep = NamedSharding(self.mesh, P(None)) \
            if self.row_safe and self.mesh is not None else None
        rows = []
        for idx in uniq:
            blk = leaf[tuple(slice(a, b) for a, b in idx)]
            row = kref.to_i32(blk)
            if rep is not None:
                # jax 0.4.x XLA:CPU SPMD miscompiles concatenate over
                # flattened slices of a middle-dim-sharded operand (wrong
                # VALUES, not layout); pinning each row replicated before
                # any stack/concat keeps the downstream fold local.  The
                # gather is semantically free: the group fold XORs blocks
                # living on different data rows, so cross-row movement of
                # the stream is inherent to parity maintenance.
                row = jax.lax.with_sharding_constraint(row, rep)
            if row.shape[0] < c:
                row = jnp.pad(row, (0, c - row.shape[0]))
            rows.append(row)
        return rows

    def _leaf_blocks(self, key: str, leaf) -> jnp.ndarray:
        """(fold_width, n_groups*block_len) int32 — the leaf's unique
        logical blocks laid out for the fold, derived from the SAME slice
        map the canary's shard digests use (a replicated slice contributes
        ONCE; duplicate copies would self-cancel under XOR).  Row m holds
        each group's m-th member side by side; rows past a group's size
        are zero padding, so folding the row axis XORs exactly the members
        of each group into that group's parity segment."""
        c = self.block_len[key]
        if self.slices is None:
            flat = kref.to_i32(leaf)
            flat = jnp.pad(flat, (0, self.n_shards * c - flat.shape[0]))
            return flat.reshape(self.n_shards, c)
        rows = self._block_rows(key, leaf)
        if not self.row_safe:
            if len(rows) < self.fold_width:
                rows.append(jnp.zeros(
                    (self.fold_width - len(rows), c), jnp.int32))
                return jnp.concatenate(
                    [jnp.stack(rows[:-1]), rows[-1]], axis=0)
            return jnp.stack(rows)
        zero = jnp.zeros((c,), jnp.int32)
        out = []
        for m in range(self.fold_width):
            segs = [rows[members[m]] if m < len(members) else zero
                    for members in self.groups[key]]
            out.append(segs[0] if len(segs) == 1
                       else jnp.concatenate(segs))
        return jnp.stack(out)

    def stream_mat(self, leaves: Sequence) -> jnp.ndarray:
        """(fold_width, stream_len) int32: the fold input columns."""
        mat = jnp.concatenate(
            [self._leaf_blocks(k, leaf)
             for k, leaf in zip(self.keys, leaves)], axis=1)
        if self.mesh is not None and not self.row_safe:
            mat = jax.lax.with_sharding_constraint(
                mat, NamedSharding(self.mesh, P(self.axis_names, None)))
        return mat

    def _to_tiles(self, mat: jnp.ndarray) -> jnp.ndarray:
        """(D, stream_len) -> (D, nt, TILE_ROWS, LANES) (off-mesh)."""
        pad = self.n_tiles * TILE - self.stream_len
        return jnp.pad(mat, ((0, 0), (0, pad))).reshape(
            self.n_shards, self.n_tiles, TILE_ROWS, LANES)

    def _fold_rows(self, mat: jnp.ndarray) -> jnp.ndarray:
        """XOR-reduce the shard axis and lay the fold out as the mesh
        parity buffer (D, Crow) sharded over the mesh."""
        # Unrolled elementwise XOR: XLA:CPU rejects a bitwise-xor
        # lax.reduce computation, and D is a small static constant anyway.
        fold = mat[0]
        for d in range(1, mat.shape[0]):
            fold = fold ^ mat[d]
        if self.row_safe:
            # pin the fold replicated BEFORE the buffer placement: the
            # partitioner otherwise propagates the buffer sharding back
            # through the fold and re-enters the miscompiled slice+concat
            # partitioning (see _block_rows) — the final constraint then
            # becomes a local slice-out of the replicated fold.
            fold = jax.lax.with_sharding_constraint(
                fold, NamedSharding(self.mesh, P(None)))
        pad = int(np.prod(self.buffer_shape, dtype=np.int64)) \
            - self.stream_len
        rows = jnp.pad(fold, (0, pad)).reshape(self.buffer_shape)
        return jax.lax.with_sharding_constraint(
            rows, NamedSharding(self.mesh, self.buffer_spec))

    # -- traceable hot-path entry points -----------------------------------

    def rebuild_leaves(self, leaves: Sequence) -> jnp.ndarray:
        """Parity from scratch — the donated-pair ``arm_current`` form
        (only one state version is ever visible under donation, so the
        per-step maintenance is a rebuild of the armed version)."""
        if not self.keys:
            # empty coverage (e.g. row_safe over a pure-DP state: every
            # leaf re-gathers from replicas instead) — keep a zero buffer
            z = jnp.zeros(self.buffer_shape, jnp.int32)
            if self.mesh is not None:
                z = jax.lax.with_sharding_constraint(
                    z, NamedSharding(self.mesh, self.buffer_spec))
            return z
        mat = self.stream_mat(leaves)
        if self.mesh is not None:
            return self._fold_rows(mat)
        return pk.xor_fold_tiles(self._to_tiles(mat),
                                 interpret=kdigest._interpret())

    def update_leaves(self, parity, old_leaves: Sequence,
                      new_leaves: Sequence, fault) -> jnp.ndarray:
        """Incremental update ``parity ^ XOR_d(old_d ^ new_d)``, gated:
        when ``fault`` (the launch's own mismatch flag) fires the delta is
        zeroed, so the committed parity keeps describing the last healthy
        version — the gate is applied to the DELTA, not the result, so the
        donated parity buffer is consumed exactly once (alias-safe)."""
        if not self.keys:
            return parity
        delta = self.stream_mat(old_leaves) ^ self.stream_mat(new_leaves)
        delta = jnp.where(fault, jnp.int32(0), delta)
        if self.mesh is not None:
            return parity ^ self._fold_rows(delta)
        return pk.xor_update_tiles(self._to_tiles(delta), parity,
                                   interpret=kdigest._interpret())

    # -- fault path: reconstruction ---------------------------------------

    def _parity_segment(self, parity, key: str,
                        group: int = 0) -> jnp.ndarray:
        off = self.offsets[key] + group * self.block_len[key]
        flat = parity.reshape(-1)
        return jax.lax.dynamic_slice(flat, (off,), (self.block_len[key],))

    def _survivor_fold(self, parity, leaf, key: str, shard: int):
        """group_parity_segment ^ XOR over the group's surviving members —
        the injured block's exact bits (padded to block_len).  ``shard``
        is a unique-block id; only its fold group participates (in the
        default single-group layout that is every block, the original
        flat fold)."""
        g, _ = self.block_group[key][shard]
        acc = self._parity_segment(parity, key, g)
        if self.slices is None:
            blocks = self._leaf_blocks(key, leaf)
            for d in range(self.n_blocks[key]):
                if d != shard:
                    acc = acc ^ blocks[d]
            return acc
        rows = self._block_rows(key, leaf)
        for blk in self.groups[key][g]:
            if blk != shard:
                acc = acc ^ rows[blk]
        return acc

    def reconstruct_shard(self, key: str, shard: int):
        """Compiled ``(parity, leaf) -> injured block`` (block shape, leaf
        dtype) for a mesh leaf — cached per (key, shard), fault path only."""
        ent = self._recon_cache.get((key, shard))
        if ent is None:
            bshape = self.block_shapes[key][shard]
            bsize = self.block_sizes[key][shard]
            dtype = self.dtypes[key]

            def recon(parity, leaf):
                acc = self._survivor_fold(parity, leaf, key, shard)
                return kref.from_i32(acc[:bsize], jnp.zeros(bshape, dtype))

            ent = jax.jit(recon)
            self._recon_cache[(key, shard)] = ent
        return ent

    def reconstruct_leaf(self, key: str, shard: int):
        """Compiled ``(parity, leaf) -> repaired whole leaf`` (off-mesh:
        the injured flat chunk is spliced back into the leaf's i32 view)."""
        ent = self._recon_cache.get((key, shard))
        if ent is None:
            c = self.block_len[key]
            bsize = self.block_sizes[key][shard]
            start = shard * c

            def recon(parity, leaf):
                acc = self._survivor_fold(parity, leaf, key, shard)
                flat = kref.to_i32(leaf)
                flat = jax.lax.dynamic_update_slice(
                    flat, acc[:bsize], (start,))
                return kref.from_i32(flat, leaf)

            ent = jax.jit(recon)
            self._recon_cache[(key, shard)] = ent
        return ent

    # -- hard-loss path: host-side, survivor-only reads --------------------
    #
    # The elastic remesh path (launch/elastic.py) runs on the HOST against
    # a mesh whose devices are partly "dead".  In the single-process
    # simulation dead devices still answer, so these helpers take the dead
    # device set explicitly and filter every ``addressable_shards`` read —
    # reading a dead shard would be cheating the drill.

    def _flat_device_index(self) -> Dict:
        devs = kdigest.mesh_device_order(self.mesh)
        return {dev: i for i, dev in enumerate(devs)}

    def host_parity_flat(self, parity, dead=frozenset()) -> np.ndarray:
        """The full flat parity stream assembled from SURVIVING devices
        only.  Raises if any parity region went down with the dead set —
        the row_safe placement exists precisely so it never does for a
        data-row loss."""
        if self.mesh is None:
            return np.asarray(parity).reshape(-1)[:self.stream_len]
        dead = set(dead)
        out = np.zeros(self.buffer_shape, np.int32)
        have = np.zeros(self.buffer_shape, bool)
        for sh in parity.addressable_shards:
            if sh.device in dead:
                continue
            out[sh.index] = np.asarray(sh.data)
            have[sh.index] = True
        if not bool(have.all()):
            raise RuntimeError(
                "parity rows lost along with the dead devices — a hard "
                "row loss needs the row_safe placement (ParityStore("
                "row_safe=True))")
        return out.reshape(-1)[:self.stream_len]

    def host_surviving_blocks(self, key: str, leaf,
                              dead=frozenset()) -> Dict[int, np.ndarray]:
        """block id -> padded int32 row, read only from surviving
        replicas (first surviving holder per unique block wins)."""
        c = self.block_len[key]
        fidx = self._flat_device_index()
        dmap = self.device_block[key]
        dead = set(dead)
        out: Dict[int, np.ndarray] = {}
        for sh in leaf.addressable_shards:
            if sh.device in dead:
                continue
            b = dmap[fidx[sh.device]]
            if b in out:
                continue
            row = np.asarray(kref.to_i32(sh.data))
            if row.shape[0] < c:
                row = np.pad(row, (0, c - row.shape[0]))
            out[b] = row
        return out

    def host_reconstruct_block(self, key: str, blk: int,
                               parity_flat: np.ndarray,
                               blocks: Dict[int, np.ndarray]) -> np.ndarray:
        """Lost block ``blk`` from its group's parity segment + surviving
        members — exact by XOR algebra.  Raises on a double erasure
        within the fold group (two dead members: not invertible)."""
        g, _ = self.block_group[key][blk]
        c = self.block_len[key]
        off = self.offsets[key] + g * c
        acc = parity_flat[off:off + c].astype(np.int32).copy()
        for other in self.groups[key][g]:
            if other == blk:
                continue
            row = blocks.get(other)
            if row is None:
                raise RuntimeError(
                    f"double erasure in the fold group of {key}: blocks "
                    f"{blk} and {other} are both lost — XOR parity "
                    f"inverts a single erasure per group")
            acc ^= row
        bsize = self.block_sizes[key][blk]
        bshape = self.block_shapes[key][blk]
        return np.asarray(kref.from_i32(
            jnp.asarray(acc[:bsize]),
            jnp.zeros(bshape, self.dtypes[key])))

    def host_assemble_leaf(self, key: str, leaf, dead=frozenset()):
        """(full host array, missing unique-block ids): surviving shards
        placed at their slice-map positions, blocks with no surviving
        replica listed for parity reconstruction."""
        fidx = self._flat_device_index()
        dmap = self.device_block[key]
        uniq, _ = self.slices[key]
        dead = set(dead)
        out = np.zeros(self.shapes[key], jnp.dtype(self.dtypes[key]))
        have = set()
        for sh in leaf.addressable_shards:
            if sh.device in dead:
                continue
            b = dmap[fidx[sh.device]]
            if b in have:
                continue
            out[tuple(slice(a, bnd) for a, bnd in uniq[b])] = \
                np.asarray(sh.data)
            have.add(b)
        missing = [b for b in range(self.n_blocks[key]) if b not in have]
        return out, missing


_PARITY_PLAN_CACHE: Dict[Tuple, ParityPlan] = {}


def evict_mesh_plans(mesh) -> int:
    """Drop cached ParityPlans keyed on ``mesh`` (elastic remesh: plans
    for the lost mesh must not pin dead-device layouts in memory)."""
    mk = kdigest._mesh_key(mesh)
    stale = [k for k in _PARITY_PLAN_CACHE if k[0] == mk]
    for k in stale:
        del _PARITY_PLAN_CACHE[k]
    return len(stale)


def _dim_axes(entry) -> Tuple[str, ...]:
    """PartitionSpec dim entry -> tuple of mesh axis names."""
    if entry is None:
        return ()
    if isinstance(entry, str):
        return (entry,)
    return tuple(entry)


def parity_plan_for(tree, *, mesh=None, n_shards: int = 4,
                    row_safe: bool = False,
                    batch_axes: Tuple[str, ...] = ()) -> ParityPlan:
    """The cached ParityPlan for ``tree``'s structure (and, on a mesh, its
    actual NamedSharding layout — the slice map IS the plan).

    ``row_safe`` (requires ``mesh`` + ``batch_axes``): row-loss-survivable
    coverage — only DATA-sharded leaves are covered (replicated /
    model-only leaves keep surviving replicas and are re-gathered on the
    elastic path instead), blocks fold per group (grouped by their slice
    projection onto the non-data dims, so a lost row erases at most one
    member per group), and the buffer shards over the non-batch axes
    only.  Leaves with a dim sharded JOINTLY over batch and non-batch
    axes are excluded: a row loss would doubly erase inside one group
    (real model specs from ``spec_for_param`` never joint-shard)."""
    if row_safe and mesh is None:
        raise ValueError("row_safe parity requires a mesh")
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    bset = set(batch_axes)
    entries = []
    groups: Dict[str, Tuple[Tuple[int, ...], ...]] = {}
    for path, x in flat:
        k = leaf_key(path)
        dt = jnp.result_type(x)
        if not _covered(k, dt, jnp.shape(x)):
            continue
        shape = tuple(jnp.shape(x))
        gk = None
        if mesh is not None:
            sharding = getattr(x, "sharding", None)
            if not isinstance(sharding, NamedSharding):
                raise ValueError(
                    f"parity on a mesh requires NamedSharding leaves; "
                    f"{k} has {type(sharding).__name__}")
            if row_safe:
                spec = tuple(sharding.spec)
                spec = spec + (None,) * (len(shape) - len(spec))
                per_dim = [set(_dim_axes(e)) for e in spec]
                data_dims = tuple(i for i, ax in enumerate(per_dim)
                                  if ax and ax <= bset)
                mixed = any(ax & bset and ax - bset for ax in per_dim)
                if not data_dims or mixed:
                    continue
            per_dev = tuple(_norm_slices(idx, shape)
                            for idx in kdigest.shard_indices(x))
            # dedupe replicas in mesh-flat device order: XOR over
            # identical copies self-cancels, so the stream carries each
            # logical slice once; the device->block map rides along for
            # fault-attribution translation
            uniq: List[Tuple] = []
            seen: Dict[Tuple, int] = {}
            dev_to_blk = []
            for idx in per_dev:
                b = seen.get(idx)
                if b is None:
                    b = seen[idx] = len(uniq)
                    uniq.append(idx)
                dev_to_blk.append(b)
            sl = (tuple(uniq), tuple(dev_to_blk))
            if row_safe:
                # fold groups: same non-data projection -> same group
                # (members differ only in data coordinates, so one lost
                # row kills at most one member per group)
                dset = set(data_dims)
                gmap: Dict[Tuple, int] = {}
                glist: List[List[int]] = []
                for b, idx in enumerate(uniq):
                    p = tuple(s for i, s in enumerate(idx)
                              if i not in dset)
                    gi = gmap.get(p)
                    if gi is None:
                        gi = gmap[p] = len(glist)
                        glist.append([])
                    glist[gi].append(b)
                gk = tuple(tuple(g) for g in glist)
        else:
            sl = None
        entries.append((k, shape, dt.name, sl, gk))
        if gk is not None:
            groups[k] = gk
    entries.sort(key=lambda e: e[0])
    d = mesh.size if mesh is not None else max(2, n_shards)
    key = (kdigest._mesh_key(mesh) if mesh is not None else ("host", d),
           treedef, tuple(entries), row_safe, tuple(batch_axes))
    plan = _PARITY_PLAN_CACHE.get(key)
    if plan is None:
        if row_safe:
            parity_axes = tuple(a for a in mesh.axis_names
                                if a not in bset)
        else:
            parity_axes = ()
        plan = ParityPlan(
            keys=tuple(e[0] for e in entries),
            shapes={e[0]: e[1] for e in entries},
            dtypes={e[0]: e[2] for e in entries},
            slices={e[0]: e[3] for e in entries}
            if mesh is not None else None,
            n_shards=d, mesh=mesh,
            groups=groups if row_safe else None,
            row_safe=row_safe, parity_axes=parity_axes)
        _PARITY_PLAN_CACHE[key] = plan
    return plan


class ParityStore:
    """The live parity shard: one device-resident buffer + a version.

    Hot-path maintenance does NOT go through this object — the canary /
    fused step embed ``plan.update_leaves`` / ``plan.rebuild_leaves`` in
    their own launches and hand the donated-through buffer back to
    ``commit``.  The store's own methods are the off-hot-path half:
    ``build``/``rebuild`` after init or recovery, ``reconstruct_*`` on the
    fault path.
    """

    def __init__(self, tree, *, ctx=None, n_shards: int = 4,
                 row_safe: bool = False):
        mesh = ctx.mesh if (ctx is not None
                            and getattr(ctx, "enabled", False)) else None
        if row_safe and mesh is None:
            row_safe = False  # off-mesh: no rows to lose
        self.plan = parity_plan_for(
            tree, mesh=mesh, n_shards=n_shards, row_safe=row_safe,
            batch_axes=tuple(ctx.batch_axes) if row_safe else ())
        self.parity = self.plan.make_buffer()
        self.version = -1

    # -- coverage ---------------------------------------------------------

    def covers(self, key: str) -> bool:
        return key in self.plan.key_set

    @property
    def n_shards(self) -> int:
        return self.plan.n_shards

    @property
    def memory_bytes(self) -> int:
        return self.plan.memory_bytes

    # -- off-hot-path maintenance -----------------------------------------

    def build(self, tree, step: int = 0) -> None:
        """(Re)build parity from scratch — init and post-recovery (a
        replayed/restored state is a new version; stale parity must not
        survive it).  One jitted call, off the hot path."""
        plan = self.plan
        fn = getattr(plan, "_rebuild_jit", None)
        if fn is None:
            fn = plan._rebuild_jit = jax.jit(plan.rebuild_leaves)
        self.parity = fn(plan.leaves(tree))
        self.version = step

    rebuild = build

    def commit(self, new_parity, step: int) -> None:
        """Install the buffer a hot-path launch donated through."""
        self.parity = new_parity
        self.version = step

    # -- fault path -------------------------------------------------------

    def reconstruct_shard(self, leaf, key: str, shard: int):
        """Injured mesh shard's exact bits (block shape, leaf dtype)."""
        return self.plan.reconstruct_shard(key, shard)(self.parity, leaf)

    def reconstruct_leaf(self, leaf, key: str, shard: int):
        """Off-mesh: the leaf with the injured chunk reconstructed."""
        return self.plan.reconstruct_leaf(key, shard)(self.parity, leaf)

    def scrub(self, tree, refs: Dict[str, np.ndarray]):
        """At-rest verify-and-repair sweep (the serving-side use: params
        never change while serving, so one parity build at load time plus
        this sweep detects AND repairs silent at-rest corruption with no
        reload and no model re-shard).

        ``refs`` holds the healthy digests recorded at build time —
        per-shard rows (``host_shard_checksums``) on a mesh, one
        whole-leaf ``host_checksum`` pair off-mesh.  Returns
        ``(repaired_tree, stats)``; leaves whose reconstruction does not
        digest-certify are reported in ``stats['failed']`` and left
        untouched (exact-or-abort — the caller escalates to a reload).
        """
        plan = self.plan
        on_mesh = plan.mesh is not None
        stats = {"checked": 0, "repaired": 0, "bytes_moved": 0,
                 "failed": []}
        repaired: Dict[str, object] = {}
        for key, leaf in zip(plan.keys, plan.leaves(tree)):
            ref = refs.get(key)
            if ref is None:
                continue
            stats["checked"] += 1
            ref = np.asarray(ref)
            if on_mesh:
                got = kdigest.host_shard_checksums(leaf)
                bad = np.nonzero(np.any(got != ref, axis=-1))[0]
                if not len(bad):
                    continue
                blocks = sorted({plan.device_block[key][int(i)]
                                 for i in bad})
                if len(blocks) > 1:
                    stats["failed"].append(key)
                    continue
                blk = blocks[0]
                block = np.asarray(self.reconstruct_shard(leaf, key, blk))
                holders = set(plan.block_devices(key, blk))
                devs = kdigest.mesh_device_order(leaf.sharding.mesh)
                by_dev = {sh.device: sh.data
                          for sh in leaf.addressable_shards}
                bufs = [jax.device_put(block, dev) if i in holders
                        else by_dev[dev] for i, dev in enumerate(devs)]
                new_leaf = jax.make_array_from_single_device_arrays(
                    leaf.shape, leaf.sharding, bufs)
                if not np.array_equal(
                        np.asarray(kdigest.host_shard_checksums(new_leaf)),
                        ref):
                    stats["failed"].append(key)
                    continue
                stats["bytes_moved"] += block.nbytes * len(holders)
            else:
                if np.array_equal(
                        np.asarray(kdigest.host_checksum(np.asarray(leaf))),
                        ref):
                    continue
                new_leaf = None
                for d in range(plan.n_blocks[key]):
                    cand = self.reconstruct_leaf(leaf, key, d)
                    if np.array_equal(
                            np.asarray(
                                kdigest.host_checksum(np.asarray(cand))),
                            ref):
                        new_leaf = cand
                        stats["bytes_moved"] += 4 * plan.block_sizes[key][d]
                        break
                if new_leaf is None:
                    stats["failed"].append(key)
                    continue
            repaired[key] = new_leaf
            stats["repaired"] += 1
        if not repaired:
            return tree, stats
        out = jax.tree_util.tree_map_with_path(
            lambda p, x: repaired.get(leaf_key(p), x), tree)
        return out, stats
