"""Trip-count-aware cost analysis over optimized HLO text.

Why this exists: ``compiled.cost_analysis()`` on the XLA:CPU backend
(calibrated empirically, see EXPERIMENTS.md §Dry-run) reports PER-DEVICE
numbers and counts every ``while`` body ONCE — a 61-layer scanned model
under-reports FLOPs ~61x.  The roofline needs the real program, so we parse
``compiled.as_text()`` ourselves:

* build the computation call graph (entry -> while bodies / fusions / calls),
* recover each while loop's trip count from its condition computation
  (jax scans lower to ``compare(counter, constant(T)), direction=LT``),
* propagate execution multiplicities down the graph,
* count per-device FLOPs (dot/convolution, operand shapes resolved through
  the SSA def map), HBM traffic (operands + outputs of every top-level op
  outside fusion interiors — post-fusion, a fusion's boundary IS its HBM
  traffic on TPU), and collective bytes by kind.

All results are per-device; multiply by chip count for program totals.
Validated against analytic ground truth in tests/test_hlo_cost.py.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

# dtype -> bytes
_DT = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "opaque": 0, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
}

_SHAPE = re.compile(r"\b([a-z]\d*[a-z]*\d*)\[([\d,]*)\]")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")
_CALLS = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_BODY = re.compile(r"body=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_INT = re.compile(r"\bconstant\((\d+)\)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_KERNEL_WINDOW = re.compile(r"window=\{size=([\dx]+)")
_FEATURE_GROUPS = re.compile(r"feature_group_count=(\d+)")
_OPERAND_NAME = re.compile(r"%?([\w.\-]+)\s*$")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")
# ops that move no HBM data themselves
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "add-dependency", "partition-id", "replica-id"}


def _shapes_of(text: str) -> List[Tuple[int, Tuple[int, ...]]]:
    """[(nbytes, dims)] for each shape literal in ``text``."""
    out = []
    for dt, dims in _SHAPE.findall(text):
        if dt not in _DT:
            continue
        dd = tuple(int(d) for d in dims.split(",")) if dims else ()
        n = 1
        for d in dd:
            n *= d
        out.append((n * _DT[dt], dd))
    return out


def _paren_group(s: str) -> Tuple[str, int]:
    """Contents of the first balanced paren group and its end index."""
    depth = 0
    start = -1
    for i, ch in enumerate(s):
        if ch == "(":
            if depth == 0:
                start = i
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return s[start + 1:i], i
    return "", -1


def _split_top(s: str) -> List[str]:
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur).strip())
    return [t for t in out if t]


@dataclass
class Instr:
    name: str
    opcode: str
    text: str
    out_bytes: int
    out_dims: Tuple[int, ...]
    operands: List[str] = field(default_factory=list)
    calls: List[str] = field(default_factory=list)
    body: Optional[str] = None
    cond: Optional[str] = None


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    defs: Dict[str, Instr] = field(default_factory=dict)
    max_const: int = 0  # trip-count recovery when used as a while condition


def _parse_instr(line: str) -> Optional[Instr]:
    m = _INSTR.match(line)
    if m is None:
        return None
    name, rhs = m.group(1), m.group(2)

    # strip a tuple output shape to find the opcode token
    work = rhs
    if work.startswith("("):
        _, end = _paren_group(work)
        work = work[end + 1:].lstrip()
    om = re.match(r"^(?:\S+\s+)?([a-z][\w\-]*)\(", work)
    opcode = om.group(1) if om else ""

    # output shapes: text before the opcode's '('
    k = rhs.find(opcode + "(") if opcode else -1
    head = rhs[:k] if k >= 0 else rhs
    tail = rhs[k + len(opcode):] if k >= 0 else ""
    out_shapes = _shapes_of(head)
    out_bytes = sum(b for b, _ in out_shapes)
    out_dims = out_shapes[0][1] if out_shapes else ()

    operands: List[str] = []
    if tail.startswith("("):
        inner, _ = _paren_group(tail)
        for tok in _split_top(inner):
            nm = _OPERAND_NAME.search(tok)
            if nm:
                operands.append(nm.group(1))

    ins = Instr(name=name, opcode=opcode, text=rhs, out_bytes=out_bytes,
                out_dims=out_dims, operands=operands)
    cm = _CALLS.search(rhs)
    if cm:
        ins.calls.append(cm.group(1))
    bm = _BODY.search(rhs)
    if bm:
        ins.body = bm.group(1)
    dm = _COND.search(rhs)
    if dm:
        ins.cond = dm.group(1)
    brm = _BRANCHES.search(rhs)
    if brm:
        for b in brm.group(1).split(","):
            b = b.strip().lstrip("%")
            if b:
                ins.calls.append(b)
    return ins


def parse_module(hlo_text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in hlo_text.splitlines():
        s = raw.strip()
        if not s or s.startswith("//") or s.startswith("HloModule"):
            continue
        if s == "}":
            cur = None
            continue
        if s.endswith("{"):
            hm = _COMP_HDR.match(s)
            if hm:
                cur = Computation(name=hm.group(1))
                comps[cur.name] = cur
                if s.startswith("ENTRY"):
                    entry = cur.name
                continue
        if cur is None:
            continue
        ins = _parse_instr(s)
        if ins is None:
            continue
        cur.instrs.append(ins)
        cur.defs[ins.name] = ins
        for c in _CONST_INT.findall(s):
            cur.max_const = max(cur.max_const, int(c))
    return comps, entry


def _operand_bytes(comp: Computation, ins: Instr) -> int:
    total = 0
    for op in ins.operands:
        d = comp.defs.get(op)
        if d is not None:
            total += d.out_bytes
    return total


def _dot_flops(comp: Computation, ins: Instr) -> float:
    out_elems = 1
    for d in ins.out_dims:
        out_elems *= d
    if ins.opcode == "dot":
        cm = _CONTRACT.search(ins.text)
        k = 1
        lhs = comp.defs.get(ins.operands[0]) if ins.operands else None
        if cm and lhs is not None:
            for i in (int(x) for x in cm.group(1).split(",") if x):
                if i < len(lhs.out_dims):
                    k *= lhs.out_dims[i]
        return 2.0 * out_elems * k
    if ins.opcode == "convolution":
        wm = _KERNEL_WINDOW.search(ins.text)
        ksize = 1
        if wm:
            for d in wm.group(1).split("x"):
                ksize *= int(d)
        fg = _FEATURE_GROUPS.search(ins.text)
        groups = int(fg.group(1)) if fg else 1
        lhs = comp.defs.get(ins.operands[0]) if ins.operands else None
        cin = 1
        if groups == 1 and lhs is not None and lhs.out_dims:
            cin = lhs.out_dims[-1]
        return 2.0 * out_elems * ksize * max(cin, 1)
    return 0.0


@dataclass
class HloCost:
    """Per-device totals (multiply by chips for the program)."""
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes_by_kind: Dict[str, float] = field(default_factory=dict)
    coll_count_by_kind: Dict[str, int] = field(default_factory=dict)
    while_trips: Dict[str, int] = field(default_factory=dict)
    fusion_flops: float = 0.0   # flops inside fusion interiors (subset)

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll_bytes_by_kind.values())

    def to_dict(self) -> Dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "coll_bytes_per_device": self.coll_bytes,
            "coll_bytes_by_kind": dict(self.coll_bytes_by_kind),
            "coll_count_by_kind": dict(self.coll_count_by_kind),
            "while_trips": dict(self.while_trips),
        }


def analyze(hlo_text: str) -> HloCost:
    comps, entry = parse_module(hlo_text)
    cost = HloCost()
    if entry is None:
        return cost

    from collections import deque
    mult: Dict[Tuple[str, bool], float] = {}
    queue = deque([(entry, False, 1.0)])
    seen_budget = 100_000
    while queue and seen_budget:
        seen_budget -= 1
        name, in_fusion, m = queue.popleft()
        key = (name, in_fusion)
        mult[key] = mult.get(key, 0.0) + m
        comp = comps.get(name)
        if comp is None:
            continue
        for ins in comp.instrs:
            if ins.body is not None:
                trips = 1
                if ins.cond and ins.cond in comps:
                    trips = max(1, comps[ins.cond].max_const)
                cost.while_trips[ins.body] = trips
                queue.append((ins.body, in_fusion, m * trips))
            if ins.opcode == "fusion":
                for c in ins.calls:
                    queue.append((c, True, m))
            elif ins.opcode in ("call", "conditional", "custom-call"):
                for c in ins.calls:
                    queue.append((c, in_fusion, m))
            # reducers/sorters apply tiny lambdas — no dots inside; skip

    for (name, in_fusion), m in mult.items():
        comp = comps.get(name)
        if comp is None:
            continue
        for ins in comp.instrs:
            fl = _dot_flops(comp, ins)
            if fl:
                cost.flops += m * fl
                if in_fusion:
                    cost.fusion_flops += m * fl
            if in_fusion:
                continue  # fusion interiors: on-chip, no HBM traffic
            op = ins.opcode
            if op in _FREE_OPS or not op or op == "while":
                continue
            if op.endswith("-done"):
                continue
            is_coll = next((k for k in COLLECTIVE_OPS if op.startswith(k)),
                           None)
            if is_coll:
                b = float(max(ins.out_bytes, _operand_bytes(comp, ins)))
                cost.coll_bytes_by_kind[is_coll] = \
                    cost.coll_bytes_by_kind.get(is_coll, 0.0) + m * b
                cost.coll_count_by_kind[is_coll] = \
                    cost.coll_count_by_kind.get(is_coll, 0) + int(m)
            cost.hbm_bytes += m * (ins.out_bytes + _operand_bytes(comp, ins))
    return cost


# ---------------------------------------------------------------------------
# collective time model: ring algorithms
# ---------------------------------------------------------------------------

_ALGO_FACTOR = {
    "all-reduce": 2.0,          # reduce-scatter + all-gather
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def collective_seconds(coll_bytes_by_kind: Dict[str, float],
                       link_bw: float) -> float:
    """Per-device collective seconds under ring-algorithm cost factors.
    Input bytes are per-device (the partitioned module's shard sizes)."""
    t = 0.0
    for kind, b in coll_bytes_by_kind.items():
        t += _ALGO_FACTOR.get(kind, 1.0) * b / link_bw
    return t
