"""ShapeDtypeStruct stand-ins + sharding assembly for every lowered program.

``input_specs(arch_cfg, shape_spec)`` returns the exact kwargs the dry-run
lowers ``train_step`` / ``prefill_step`` / ``serve_step`` against: weak-type
correct, shardable, zero device allocation (everything is built with
``jax.eval_shape``).

Modality frontends are STUBS per the task spec: the audio/vlm cells receive
precomputed frame/patch embeddings as inputs (``src_embeds`` /
``patch_embeds``), not raw waveforms/pixels.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.distributed.context import DistContext
from repro.distributed.sharding import (
    batch_specs,
    cache_specs,
    opt_state_specs,
    param_specs,
)
from repro.models.registry import get_model
from repro.optim import make_optimizer
from repro.train.loop import init_iv, iv_step_sizes

# Modality-stub geometry (backbone-only cells)
SRC_FRAMES = 512       # seamless: pre-encoded audio frames per sample
N_PATCHES = 256        # qwen2-vl: vision patches per sample


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), jnp.dtype(dtype))


# ---------------------------------------------------------------------------
# batch / cache / state structs (no allocation)
# ---------------------------------------------------------------------------

def batch_struct(cfg: ArchConfig, B: int, S: int) -> Dict[str, Any]:
    m = cfg.model
    batch = {
        "tokens": _sds((B, S), jnp.int32),
        "targets": _sds((B, S), jnp.int32),
    }
    if m.n_enc_layers:
        batch["src_embeds"] = _sds((B, SRC_FRAMES, m.frontend_dim),
                                   jnp.float32)
    if m.patch_dim:
        batch["patch_embeds"] = _sds((B, N_PATCHES, m.patch_dim), jnp.float32)
        if m.m_rope:
            batch["positions"] = _sds((B, S + N_PATCHES, 3), jnp.int32)
    return batch


def state_struct(cfg: ArchConfig, global_batch: int):
    """TrainState as ShapeDtypeStructs via eval_shape (no init on device)."""
    model = get_model(cfg.model)
    opt = make_optimizer(cfg.train, 100_000)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)

    params = jax.eval_shape(lambda k: model.init(cfg.model, k), key)
    opt_state = jax.eval_shape(opt.init, params)
    iv = jax.eval_shape(lambda: init_iv(cfg, global_batch))
    return {"params": params, "opt": opt_state, "iv": iv}


def params_struct(cfg: ArchConfig):
    model = get_model(cfg.model)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(lambda k: model.init(cfg.model, k), key)


def cache_struct(cfg: ArchConfig, B: int, max_len: int):
    model = get_model(cfg.model)
    return jax.eval_shape(
        lambda: model.make_decode_cache(cfg.model, B, max_len))


# ---------------------------------------------------------------------------
# shardings
# ---------------------------------------------------------------------------

def _named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def state_shardings(ctx: DistContext, cfg: ArchConfig, state_st):
    pspecs = param_specs(ctx, state_st["params"], cfg.sharding, cfg.model)
    ospecs = opt_state_specs(ctx, state_st["params"], pspecs, cfg.train)
    ivspecs = jax.tree_util.tree_map(lambda _: P(), state_st["iv"])
    specs = {"params": pspecs, "opt": ospecs, "iv": ivspecs}
    return _named(ctx.mesh, specs), specs


def param_shardings(ctx: DistContext, cfg: ArchConfig, params_st):
    """NamedSharding tree for a bare param tree (serving-side twin of
    ``state_shardings``)."""
    pspecs = param_specs(ctx, params_st, cfg.sharding, cfg.model)
    return _named(ctx.mesh, pspecs), pspecs


def batch_shardings(ctx: DistContext, batch_st):
    specs = batch_specs(ctx, batch_st)
    return _named(ctx.mesh, specs), specs


def cache_shardings(ctx: DistContext, cache_st):
    specs = cache_specs(ctx, cache_st)
    return _named(ctx.mesh, specs), specs


# ---------------------------------------------------------------------------
# bind_state — the one mesh-binding recipe
# ---------------------------------------------------------------------------

class BoundState:
    """What ``bind_state`` hands back: the placed state, the layout-pinned
    (still unjitted) step, the device-placing batch fn, and the sharding
    trees.  Iterable as ``state, step, bfn, shardings = bound`` for the
    common call sites."""

    __slots__ = ("state", "step", "bfn", "shardings", "specs",
                 "batch_shardings")

    def __init__(self, state, step, bfn, shardings, specs, batch_sh):
        self.state = state
        self.step = step
        self.bfn = bfn
        self.shardings = shardings
        self.specs = specs
        self.batch_shardings = batch_sh

    def __iter__(self):
        return iter((self.state, self.step, self.bfn, self.shardings))

    def pin(self, fn):
        """Pin another step-shaped fn to the same state layout (identity
        off-mesh) — e.g. a donated variant of the bound step."""
        if self.shardings is None:
            return fn
        from repro.train.loop import pin_state_shardings
        return pin_state_shardings(fn, self.shardings)


def bind_state(ctx: Optional[DistContext], cfg: ArchConfig, state,
               raw_step: Callable, batch_fn: Callable, *,
               example_batch=None) -> BoundState:
    """THE mesh-binding recipe, in one place (previously copy-pasted
    through train/campaign/overhead/examples/tests — forgetting any line
    silently loses the layout pin and with it the zero-resharding
    guarantee):

      1. derive the state's NamedShardings (``state_shardings``),
      2. ``device_put`` the state onto them,
      3. pin the step to that layout (``pin_state_shardings`` — output
         shardings declared so recovery device_puts can't drift),
      4. wrap ``batch_fn`` to place each batch on its batch shardings.

    Off-mesh (``ctx`` None or local) everything passes through untouched.
    The elastic remesh path calls this against the degraded context — the
    SAME recipe re-lowers the survivor state, which is the point of
    having it be one function.  An already-pinned step is unwrapped
    first, so re-binding onto a new mesh never stacks a stale layout
    constraint under the fresh one."""
    if ctx is None or not getattr(ctx, "enabled", False):
        return BoundState(state, raw_step, batch_fn, None, None, None)
    from repro.train.loop import pin_state_shardings
    raw_step = getattr(raw_step, "unpinned_step", raw_step)
    shardings, specs = state_shardings(ctx, cfg, state)
    state = jax.device_put(state, shardings)
    pinned = pin_state_shardings(raw_step, shardings)
    ex = example_batch if example_batch is not None else batch_fn(0)
    bsh, _ = batch_shardings(ctx, ex)

    def bfn(s):
        return jax.device_put(batch_fn(s), bsh)

    return BoundState(state, pinned, bfn, shardings, specs, bsh)


# ---------------------------------------------------------------------------
# the public entry: one call per dry-run cell
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeSpec, ctx: DistContext):
    """(kwargs structs, in_shardings kwargs tree) for the cell's program.

    train   -> step(state, batch)
    prefill -> prefill(params, batch)
    decode  -> serve_step(params, cache, token)
    """
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        state_st = state_struct(cfg, B)
        bat_st = batch_struct(cfg, B, S)
        st_sh, _ = state_shardings(ctx, cfg, state_st)
        b_sh, _ = batch_shardings(ctx, bat_st)
        return {"state": state_st, "batch": bat_st}, \
               {"state": st_sh, "batch": b_sh}
    if shape.kind == "prefill":
        p_st = params_struct(cfg)
        bat_st = batch_struct(cfg, B, S)
        bat_st.pop("targets")
        pspecs = param_specs(ctx, p_st, cfg.sharding, cfg.model)
        p_sh = _named(ctx.mesh, pspecs)
        b_sh, _ = batch_shardings(ctx, bat_st)
        return {"params": p_st, "batch": bat_st}, \
               {"params": p_sh, "batch": b_sh}
    if shape.kind == "decode":
        p_st = params_struct(cfg)
        c_st = cache_struct(cfg, B, S)
        tok = _sds((B,), jnp.int32)
        pspecs = param_specs(ctx, p_st, cfg.sharding, cfg.model)
        p_sh = _named(ctx.mesh, pspecs)
        c_sh, _ = cache_shardings(ctx, c_st)
        t_sh = NamedSharding(ctx.mesh, P(None))
        return {"params": p_st, "cache": c_st, "token": tok}, \
               {"params": p_sh, "cache": c_sh, "token": t_sh}
    raise ValueError(shape.kind)
