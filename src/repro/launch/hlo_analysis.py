"""Post-SPMD HLO analysis: collective bytes + roofline terms.

``cost_analysis()`` reports FLOPs and HBM bytes but NOT collective traffic,
so we parse the optimized HLO text: every ``all-gather`` / ``all-reduce`` /
``reduce-scatter`` / ``all-to-all`` / ``collective-permute`` instruction
contributes its operand bytes (max of input/output — the larger side is
what actually crosses links for AG/RS).

Roofline model (TPU v5e constants, per task spec):
    compute    = HLO_FLOPs   / (chips * 197e12 FLOP/s)
    memory     = HLO_bytes   / (chips * 819e9  B/s)
    collective = coll_bytes  / (chips * 50e9   B/s/link)
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

# -- hardware constants (TPU v5e) -------------------------------------------
PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# one shape literal: bf16[256,4096,1024]{2,1,0:T(8,128)}
_SHAPE_RE = re.compile(r"\b([a-z]+\d*)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * b


def _line_shapes(text: str) -> List[int]:
    return [_shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(text)]


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum operand sizes of every collective op in optimized HLO text.

    HLO lines look like::

        %ag = bf16[512,8192]{...} all-gather(%x), replica_groups=...

    The output shape leads; input shapes appear in the operand list only as
    operand *names*, so per-line we conservatively take the line's largest
    shape literal (output for AG/AR, which equals max(in,out) for AG; for RS
    the larger *input* appears when the op is written with explicit operand
    shapes — fused ops do include them).
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        s = line.strip()
        if not s or s.startswith("//"):
            continue
        m = re.search(r"=\s*(?:\([^)]*\)\s*)?[a-z0-9\[\],{}:()\s.]*?\b(" +
                      "|".join(_COLLECTIVES) + r")\b", s)
        if m is None:
            # also catch "xxx = bf16[..] all-reduce(" simple form
            hit = None
            for kind in _COLLECTIVES:
                if f" {kind}(" in s or s.startswith(f"{kind}("):
                    hit = kind
                    break
            if hit is None:
                continue
            kind = hit
        else:
            kind = m.group(1)
        # `all-reduce-start`/`-done` pairs: count the start only
        if "-done" in s:
            continue
        sizes = _line_shapes(s.split("(", 1)[0])  # shapes before the operand list
        if not sizes:
            sizes = _line_shapes(s)
        if not sizes:
            continue
        nbytes = max(sizes) if kind != "all-to-all" else max(sizes)
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + nbytes
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


# ---------------------------------------------------------------------------
# roofline terms
# ---------------------------------------------------------------------------

@dataclass
class Roofline:
    flops: float               # total HLO FLOPs for the program (all chips)
    hbm_bytes: float            # total HLO bytes accessed (all chips)
    coll_bytes: float           # total collective bytes (all chips)
    chips: int
    model_flops: float = 0.0    # 6*N*D-style useful FLOPs
    coll_seconds: float = 0.0   # per-device collective seconds (algo-factored)

    @property
    def t_compute(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        if self.coll_seconds:
            return self.coll_seconds
        return self.coll_bytes / (self.chips * ICI_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Roofline step time (perfect overlap of the three engines)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_fraction(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / achievable step time — the score."""
        if self.t_bound <= 0:
            return 0.0
        t_useful = self.model_flops / (self.chips * PEAK_FLOPS)
        return t_useful / self.t_bound

    def to_dict(self) -> Dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes, "chips": self.chips,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_fraction": self.useful_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


def cost_to_roofline(cost: Dict, coll: CollectiveStats, chips: int,
                     model_flops: float) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    return Roofline(flops=flops, hbm_bytes=nbytes,
                    coll_bytes=float(coll.total_bytes), chips=chips,
                    model_flops=model_flops)


def hlo_cost_to_roofline(hc, chips: int, model_flops: float) -> Roofline:
    """Build the roofline from the trip-count-aware text analysis
    (``hlo_cost.analyze``).  ``hc`` carries per-device numbers."""
    from repro.launch.hlo_cost import collective_seconds
    return Roofline(
        flops=hc.flops * chips,
        hbm_bytes=hc.hbm_bytes * chips,
        coll_bytes=hc.coll_bytes * chips,
        chips=chips,
        model_flops=model_flops,
        coll_seconds=collective_seconds(hc.coll_bytes_by_kind, ICI_BW),
    )


# ---------------------------------------------------------------------------
# model FLOPs (6*N*D for dense; 6*N_active*D for MoE; attention term added)
# ---------------------------------------------------------------------------

def param_counts(cfg) -> Tuple[int, int]:
    """(total_params, active_params) — ``active`` is a COMPUTE proxy:
    weight-tied blocks (zamba2's shared attention) count once per
    *application*, and MoE counts top-k experts only."""
    total, active, enc = _param_components(cfg)
    return int(total), int(active + enc)


def _param_components(cfg) -> Tuple[float, float, float]:
    """(total_stored, decoder_active_per_token, encoder_params)."""
    m = cfg.model
    d, L, V = m.d_model, m.n_layers, m.vocab_size
    H, KV, Dh = m.n_heads, m.n_kv_heads, m.resolved_head_dim
    attn = d * H * Dh + 2 * d * KV * Dh + H * Dh * d          # q,k,v,o
    dense_mlp = 3 * d * m.d_ff                                  # gate,up,down
    total = active = V * d                                      # embed
    if not m.tie_embeddings:
        total += V * d
        active += V * d

    if m.family == "ssm":
        # xLSTM block: q/k/v/o projections + gates (approx 8 d^2 per block)
        per = 8 * d * d
        total += L * per
        active += L * per
    elif m.family == "hybrid" and m.shared_attn:
        # ONE shared attention block, applied L // (ratio+1) times
        n_attn = L // (m.hybrid_ratio + 1) if m.hybrid_ratio else 0
        shared = attn + dense_mlp + 2 * d * d                  # + in_fuse
        total += shared
        active += shared * n_attn                              # compute proxy
        dinner = m.ssm_expand * d
        mamba = 3 * d * dinner + 2 * dinner * m.ssm_state      # per block
        total += L * mamba
        active += L * mamba
    else:
        for layer in range(L):
            total += attn
            active += attn
            if m.n_experts and layer >= m.first_dense_layers:
                ff = m.moe_d_ff or m.d_ff
                expert = 3 * d * ff
                total += m.n_experts * expert + m.n_shared_experts * expert
                active += m.top_k * expert + m.n_shared_experts * expert
            elif m.d_ff:
                total += dense_mlp
                active += dense_mlp
            if m.ssm_state and m.family != "hybrid":
                dinner = m.ssm_expand * d
                total += 3 * d * dinner
                active += 3 * d * dinner

    enc = 0.0
    if m.n_enc_layers:
        enc = m.n_enc_layers * (attn + dense_mlp)
        total += enc
        # cross-attention projections in every decoder layer
        cross = L * (2 * d * KV * Dh)
        total += cross
        active += cross
    return total, active, enc


def _attn_context_lengths(cfg, S: int) -> list:
    """Effective context length per layer (window-aware)."""
    m = cfg.model
    out = []
    for _ in range(m.n_enc_layers or 0):
        out.append(S)  # encoder full self-attention
    if m.family in ("ssm",):
        return out  # no attention layers
    n = m.n_layers
    if m.family == "hybrid" and m.hybrid_ratio:
        n = max(1, n // (m.hybrid_ratio + 1))  # only the shared-attn layers
    for i in range(n):
        if m.local_global_ratio:
            r = m.local_global_ratio
            w = m.local_window if (i % (r + 1)) != r else 0
        else:
            w = m.sliding_window
        out.append(min(w, S) if w else S)
    return out


SRC_FRAMES = 512   # enc-dec modality-stub source length (launch/specs.py)


def model_flops_for_cell(cfg, shape) -> float:
    """Useful-FLOPs denominator for MFU: 6*N_active*D (train) or 2*N_active*D
    (inference) PLUS the attention quadratic term (PaLM-style accounting,
    causal-halved, window-aware).  decode cells process B tokens/step.

    enc-dec cells follow serving semantics: *prefill* encodes the SOURCE
    (SRC_FRAMES frames) and emits one BOS decode — it does NOT run S target
    tokens; *decode* runs the decoder only (self + cross attention)."""
    m = cfg.model
    _, dec_active, enc_params = _param_components(cfg)
    H, Dh = m.n_heads, m.resolved_head_dim
    S, B = shape.seq_len, shape.global_batch
    encdec = bool(m.n_enc_layers)

    dec_ctxs = [c for c in _attn_context_lengths(cfg, S)][m.n_enc_layers:]
    enc_self = 2.0 * B * H * Dh * SRC_FRAMES * SRC_FRAMES \
        * m.n_enc_layers if encdec else 0.0     # bidirectional (no halving)

    if shape.kind == "train":
        tokens = B * S
        attn_fwd = sum(2.0 * B * H * Dh * S * c for c in dec_ctxs)
        cross_fwd = 4.0 * B * H * Dh * S * SRC_FRAMES * m.n_layers \
            if encdec else 0.0                  # full (no causal halving)
        return (6.0 * dec_active * tokens + 3.0 * (attn_fwd + cross_fwd) +
                3.0 * (2.0 * enc_params * B * SRC_FRAMES + enc_self))

    if shape.kind == "prefill":
        if encdec:
            # encode source + build cross-KV + one BOS decode step
            return (2.0 * enc_params * B * SRC_FRAMES + enc_self +
                    2.0 * dec_active * B)
        tokens = B * S
        attn_fwd = sum(2.0 * B * H * Dh * S * c for c in dec_ctxs)
        return 2.0 * dec_active * tokens + attn_fwd

    # decode: one token against a C-token cache, no causal halving
    attn_step = sum(4.0 * B * H * Dh * c for c in dec_ctxs)
    if encdec:
        attn_step += 4.0 * B * H * Dh * SRC_FRAMES * m.n_layers  # cross
    return 2.0 * dec_active * B + attn_step
