"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — smoke tests and benches must keep seeing
1 CPU device; only the dry-run process forces 512 placeholder devices.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod = 16x16 = 256 chips (v5e pod, ("data","model")); two pods
    add a leading "pod" axis (DCN) => 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Arbitrary mesh (used by §Perf sharding experiments)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def parse_mesh(spec: Optional[str]):
    """``--mesh`` strings to (shape, axes): "4" -> data-parallel only,
    "4,2" -> ("data", "model"), "2,4,2" -> ("pod", "data", "model")."""
    if not spec:
        return None, None
    shape = tuple(int(s) for s in spec.replace("x", ",").split(",") if s)
    axes = {1: ("data",), 2: ("data", "model"),
            3: ("pod", "data", "model")}.get(len(shape))
    if axes is None:
        raise ValueError(f"--mesh takes 1-3 comma-separated sizes, got {spec!r}")
    return shape, axes


def make_context(mesh_spec: Optional[str]):
    """DistContext for a ``--mesh`` knob (None off-mesh) — the shared
    entry point of the train/serve drivers' mesh flags.  On CPU, force
    devices first: XLA_FLAGS=--xla_force_host_platform_device_count=N."""
    shape, axes = parse_mesh(mesh_spec)
    if shape is None:
        return None
    need = int(np.prod(shape))
    have = len(jax.devices())
    if have < need:
        raise ValueError(
            f"--mesh {mesh_spec} needs {need} devices, have {have} — on "
            f"CPU set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{need}")
    from repro.distributed.context import DistContext
    return DistContext.for_mesh(make_mesh(shape, axes))


def make_degraded_mesh(lost_data_slices: int = 1, *, multi_pod: bool = False,
                       base=None, dead=None):
    """Elastic re-mesh after losing rows of the data axis (a failed
    host/board takes out a whole model row).  The job continues at
    reduced data-parallel width on the surviving devices — no replacement
    hardware required.

    With ``base`` (a live Mesh), the degraded mesh is the SAME axis names
    over the base's device array with the dead data rows deleted —
    ``dead`` gives explicit row indices (default: the trailing
    ``lost_data_slices`` rows).  Without ``base``, the original
    production-shape path: a fresh (16-lost)x16 (or 31x16 multi-pod)
    mesh over the leading devices."""
    from jax.sharding import Mesh
    if base is not None:
        names = base.axis_names
        axis = "data" if "data" in names else names[0]
        ai = names.index(axis)
        n = base.devices.shape[ai]
        rows_dead = set(int(r) for r in dead) if dead is not None else \
            set(range(n - lost_data_slices, n))
        keep = [r for r in range(n) if r not in rows_dead]
        if not keep:
            raise ValueError("no data slices left")
        return Mesh(np.take(base.devices, keep, axis=ai), names)
    rows = (32 if multi_pod else 16) - lost_data_slices
    if rows < 1:
        raise ValueError("no data slices left")
    devices = np.asarray(jax.devices()[: rows * 16]).reshape(rows, 16)
    return Mesh(devices, ("data", "model"))


def mesh_chip_count(mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
