"""Resilient serving CLI — a thin driver over the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch iterpro-100m --smoke \
        --requests 8 --prompt-len 16 --gen 12 --inject 5

Everything serving-shaped lives in ``repro.serving``: the request queue,
the iteration-level scheduler over slot-major decode state, the per-slot
canary slice, and slot-isolated recovery (injured slots evict to prefix
replay; healthy slots keep decoding the very next engine step).  This
module only (a) turns CLI knobs into an engine + a request batch, (b)
seeds EVERY RNG in play — ``random``, numpy, and the JAX param key — from
one ``--seed`` so injection campaigns are reproducible run-to-run, and
(c) reports the engine's summary (now with p50/p99 percentiles next to
the means).

Composition knobs mirror the training path: ``--donate`` donates the
slot-major cache into the fused step (in-place KV update), detection is
ALWAYS in-step fused (1 launch + 1 scalar fault sync per engine step —
the ``--fused-detect`` flag of the old fixed-batch driver is accepted
for compatibility and is a no-op), and ``--mesh`` serves off a device
mesh with sharded params, a replicated slot-major cache, and a
shard-local canary.  KV memory is a paged block pool by default where
the family supports it (``--block-size`` sets the block granularity,
``--dense`` forces the old per-slot cache), and ``--prefill-chunk``
prefills long prompts chunk-at-a-time interleaved with decode steps.
"""

from __future__ import annotations

import argparse
import json
import random

import numpy as np

from repro.configs import get_config
from repro.serving import Request, ServingEngine
from repro.serving.engine import ServingReport   # noqa: F401 (re-export)

#: compat alias — the old fixed-batch driver exposed a ServeReport; the
#: engine's report (superset: percentiles, slot/SLO counters) replaces it
ServeReport = ServingReport


def make_requests(cfg, n_requests: int, prompt_len: int, gen_tokens: int,
                  nprng, arrivals=None):
    """Synthetic request batch: random prompts, optional open-loop
    arrival times (default: all at t=0, the closed-batch setting)."""
    vocab = cfg.model.vocab_size
    reqs = []
    for i in range(n_requests):
        reqs.append(Request(
            rid=i,
            prompt=nprng.integers(0, vocab, size=prompt_len).astype(np.int32),
            max_new_tokens=gen_tokens,
            arrival_s=float(arrivals[i]) if arrivals is not None else 0.0))
    return reqs


def serve(cfg, *, n_requests: int, prompt_len: int, gen_tokens: int,
          seed: int = 0, inject_every: int = 0, verbose: bool = True,
          canary_slices: int = 4, donate: bool = False,
          fused_detect: bool = False, mesh=None, n_slots: int = 0,
          paged=None, block_size: int = 8, prefill_chunk: int = 0,
          parity: bool = False):
    """Serve ``n_requests`` random prompts through the continuous-batching
    engine; returns the engine summary dict.

    ``inject_every`` > 0 flips one bit in a (preferably active) slot's
    decode state every N accepted tokens, targeted into the canary's
    protected window (see ``ServingEngine.corrupt_slot``) so the recovery
    path — slot eviction + prefix replay — is what gets exercised.
    ``fused_detect`` is accepted for CLI compatibility: the engine step is
    always in-step fused.

    ``parity=True`` adds at-rest protection for the STATIC params: one
    XOR parity build at load time (1/D memory), then an end-of-run
    ``scrub_params`` sweep that detects and repairs silent weight rot in
    O(bytes/D) without reloading the checkpoint.  With ``inject_every``
    set, one param bit is also flipped after the run so the smoke
    exercises the repair (reported under ``"parity"`` in the summary).
    """
    del fused_detect  # engine detection is always in-step fused
    # one seed, every RNG: stdlib `random` (injection storm), numpy
    # (prompts), and the JAX param key (engine init) — plus the global
    # singletons, so user code downstream of serve() is reproducible too
    random.seed(seed)
    np.random.seed(seed % 2**32)
    rng = random.Random(seed)
    nprng = np.random.default_rng(seed)

    ctx = None
    if mesh:
        from repro.launch.mesh import make_context
        ctx = make_context(mesh)

    slots = n_slots or min(4, max(1, n_requests))
    eng = ServingEngine(
        cfg, n_slots=slots, max_len=prompt_len + gen_tokens + 1,
        canary_slices=canary_slices, donate=donate, ctx=ctx, seed=seed,
        # serve() promises every request completes (prefix replay always
        # works) — the drop bound is an SLO-benchmark knob, not a CLI one
        max_replays=10**6, verbose=verbose, paged=paged,
        block_size=block_size, prefill_chunk=prefill_chunk, parity=parity)
    reqs = make_requests(cfg, n_requests, prompt_len, gen_tokens, nprng)
    eng.warm()
    rep = eng.run(reqs, inject_every=inject_every, inject_rng=rng)
    out = rep.summary()
    if parity:
        if inject_every:
            # at-rest weight-rot adversary: flip one param bit after the
            # run so the scrub below demonstrates detection + XOR repair
            eng.corrupt_param(rng)
        out["parity"] = eng.scrub_params()
    if verbose:
        print(json.dumps(out, indent=1))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="iterpro-100m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0,
                    help="seeds random, numpy AND the JAX param key")
    ap.add_argument("--slots", type=int, default=0,
                    help="batch slots (0: min(4, requests))")
    ap.add_argument("--canary-slices", type=int, default=4)
    ap.add_argument("--inject", type=int, default=0,
                    help="flip one bit in a slot's decode state every N "
                         "accepted tokens")
    ap.add_argument("--donate", action="store_true",
                    help="donate the slot-major cache into the fused step "
                         "(in-place KV update)")
    ap.add_argument("--fused-detect", action="store_true",
                    help="compat no-op: detection is always in-step fused")
    ap.add_argument("--block-size", type=int, default=8,
                    help="paged-KV block size in token positions")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="prefill long prompts in chunks of this many "
                         "tokens, interleaved with decode steps (0: "
                         "monolithic prefill)")
    ap.add_argument("--dense", action="store_true",
                    help="force the dense per-slot KV cache (paged pool "
                         "is the default where the family supports it)")
    ap.add_argument("--mesh", default=None,
                    help="serve off a device mesh, e.g. '4,2' (CPU repro: "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=8); params shard, the slot cache "
                         "replicates, the canary goes shard-local")
    ap.add_argument("--parity", action="store_true",
                    help="at-rest XOR parity over the static params (1/D "
                         "memory): an end-of-run scrub detects and "
                         "repairs silent weight rot in O(bytes/D) with "
                         "no checkpoint reload")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    serve(cfg, n_requests=args.requests, prompt_len=args.prompt_len,
          gen_tokens=args.gen, seed=args.seed, inject_every=args.inject,
          canary_slices=args.canary_slices, donate=args.donate,
          fused_detect=args.fused_detect, mesh=args.mesh,
          n_slots=args.slots, paged=False if args.dense else None,
          block_size=args.block_size, prefill_chunk=args.prefill_chunk,
          parity=args.parity)


if __name__ == "__main__":
    main()
