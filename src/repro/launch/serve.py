"""Recovery-wrapped batched serving driver.

    PYTHONPATH=src python -m repro.launch.serve --arch iterpro-100m --smoke \
        --requests 16 --prompt-len 32 --gen 32 --inject 20

Serving under IterPro: the decode loop state (params + KV/recurrent cache +
position counters) is the protected state.  A transient fault that corrupts
the cache or a position counter is detected by the free traps (non-finite
logits) or the rotating canary, and repaired by:
  * Eq. (1) — the decode position counters are affine IVs (pos, tokens_out);
  * **prefix replay** — the generated prefix is the serving analogue of the
    paper's RSI: re-running prefill + the accepted tokens rebuilds an exact
    cache from the (tiny) token log instead of dropping the request.
"""

from __future__ import annotations

import argparse
import json
import math
import random
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import FaultReport, flip_bit, sample_plan, inject
from repro.data.pipeline import TokenPipeline
from repro.launch.mesh import make_context
from repro.models.registry import get_model
from repro.train.loop import make_train_state


@dataclass
class ServeReport:
    requests: int = 0
    tokens_out: int = 0
    faults_injected: int = 0
    faults_detected: int = 0
    faults_recovered: int = 0
    replay_tokens: int = 0
    decode_ms: List[float] = field(default_factory=list)
    recovery_ms: List[float] = field(default_factory=list)

    def summary(self) -> Dict:
        return {
            "requests": self.requests,
            "tokens_out": self.tokens_out,
            "faults": {"injected": self.faults_injected,
                       "detected": self.faults_detected,
                       "recovered": self.faults_recovered},
            "mean_decode_ms": float(np.mean(self.decode_ms))
            if self.decode_ms else 0.0,
            "mean_recovery_ms": float(np.mean(self.recovery_ms))
            if self.recovery_ms else 0.0,
            "replay_tokens": self.replay_tokens,
        }


def serve(cfg, *, n_requests: int, prompt_len: int, gen_tokens: int,
          seed: int = 0, inject_every: int = 0, verbose: bool = True,
          canary_slices: int = 4, donate: bool = False,
          fused_detect: bool = False, mesh: Optional[str] = None) -> Dict:
    """Recovery-wrapped batched serving.  Detection: free trap (non-finite
    logits) + a rotating checksum canary over the decode cache —
    bit-flips in a KV cache rarely drive logits non-finite (RMSNorm masks
    magnitudes; see EXPERIMENTS.md), so the canary carries detection here
    exactly as in training.

    ``donate=True`` jits the decode step with ``donate_argnums`` on the
    cache — the production in-place KV-update setting.  The canary then
    runs just before the decode consumes the cache (its last readable
    moment); prefix replay never needs the donated buffer, so recovery is
    unchanged.

    ``fused_detect=True`` runs the canary INSIDE the jitted decode step
    (``ChecksumCanary.fuse_into_step``): the check of the input cache's
    slice ``t % K`` and the arm of the updated cache's next slice ride the
    decode's own launch — 1 combined launch + 1 scalar sync per token,
    donated or not, at the cost of K rotation-specialised decode
    compilations.

    ``mesh="dp,tp"`` serves off a device mesh (DESIGN.md §5): params and
    decode cache shard per ``distributed/sharding.py``, the cache canary
    goes shard-local (per-device digests, all-reduced fault flag), and
    prefix replay rebuilds the sharded cache in place."""
    from repro.core import ChecksumCanary

    m = cfg.model
    model = get_model(m)
    key = jax.random.PRNGKey(seed)
    params = model.init(m, key)
    pipe = TokenPipeline(m.vocab_size, prompt_len, n_requests, seed=seed)
    ctx = make_context(mesh)

    batch = pipe.batch_at(0)
    if m.n_enc_layers:
        batch = pipe.with_src_embeds(batch, 32, m.frontend_dim, 0)
    if m.patch_dim:
        batch = pipe.with_patches(batch, 8, m.patch_dim, 0)

    cache_sh = None
    if ctx is not None:
        from repro.launch.specs import batch_shardings, param_shardings
        psh, _ = param_shardings(ctx, cfg, params)
        params = jax.device_put(params, psh)
        bsh, _ = batch_shardings(ctx, batch)
        batch = jax.device_put(batch, bsh)

    max_len = prompt_len + gen_tokens + 8
    prefill = jax.jit(lambda p, b: model.prefill(p, m, b, None,
                                                 max_len=max_len))

    def raw_decode_fn(p, c, t):
        lg, nc = model.decode_step(p, m, c, t, None)
        if cache_sh is not None:
            # mesh: pin the updated cache to the canonical layout — the
            # per-token invariant the shard-local canary plans against
            nc = jax.tree_util.tree_map(
                jax.lax.with_sharding_constraint, nc, cache_sh)
        return lg, nc

    decode = jax.jit(raw_decode_fn, donate_argnums=(1,) if donate else ())

    rng = random.Random(seed + 3)
    rep = ServeReport(requests=n_requests)

    logits, cache = prefill(params, batch)
    if ctx is not None:
        from repro.launch.specs import cache_shardings
        cache_sh, _ = cache_shardings(ctx, cache)
        cache = jax.device_put(cache, cache_sh)
    token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    # The decode-INPUT log — the replay source.  inputs[0] is the prefill's
    # token; each accepted decode appends its output (the next input).
    # (An earlier version logged outputs only and replayed one token off —
    # the cache canary caught the bit-level divergence immediately.)
    inputs: List[np.ndarray] = [np.asarray(token)]
    canary = ChecksumCanary({"cache": cache}, n_slices=canary_slices,
                            ctx=ctx) \
        if canary_slices else None
    fused = None
    if fused_detect:
        if canary is None:
            raise ValueError("fused_detect requires canary_slices > 0")

        def raw_decode(ctree, p, tok):
            lg, nc = raw_decode_fn(p, ctree["cache"], tok)
            return {"cache": nc}, lg

        # the factory jits decode + canary together; the plain jitted
        # `decode` above still serves prefix replay on the fault path.
        # Warm all K rotation executables BEFORE the timed loop so the
        # first token's decode_ms doesn't absorb the compilations.
        fused = canary.fuse_into_step(raw_decode, donate=donate,
                                      warm="eager")
        fused.warm({"cache": cache}, params, token)

    t = 0
    last_inject = -1
    while t < gen_tokens:
        if donate and canary and fused is None:
            # donated decode, arm half: digest slice t%K of the cache the
            # previous decode just produced (one launch, no sync); the
            # check below verifies the same slice of the same version
            canary.arm_current(t, {"cache": cache})

        # adversary: corrupt the cache mid-decode (evaluation only; once
        # per position — a recovery retry must not be re-hit)
        if inject_every and t and t % inject_every == 0 and last_inject != t:
            plan = sample_plan(rng, {"cache": cache}, max_step=1,
                               target="cache")
            cache = inject({"cache": cache}, plan)["cache"]
            rep.faults_injected += 1
            last_inject = t

        report = None
        if donate and canary and fused is None:
            # donated decode, check half: the cache's last readable moment
            # is BEFORE the step consumes it — one launch + one scalar
            # sync verifies slice t%K against the arm at the loop top
            report = canary.check(t, {"cache": cache})

        if report is None:
            t0 = time.perf_counter()
            if fused is not None:
                # in-step fused canary: cache check + next-slice arm ride
                # the decode's own launch (1 launch + 1 scalar sync/token)
                ctree, logits, report = fused.step(
                    t, {"cache": cache}, params, token)
                new_cache = ctree["cache"]
            else:
                logits, new_cache = decode(params, cache, token)
            jax.block_until_ready(logits)
            rep.decode_ms.append(1e3 * (time.perf_counter() - t0))

            if canary and not donate and fused is None:
                # fused rotating canary — one launch + one scalar sync per
                # token: verify slice t%K of the cache the decode just
                # consumed, arm slice (t+1)%K of the fresh cache
                report = canary.check_and_arm(t, {"cache": cache},
                                              {"cache": new_cache})

        ok = report is None and bool(jnp.isfinite(logits).all())
        if ok:
            cache = new_cache
            token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            inputs.append(np.asarray(token))
            rep.tokens_out += n_requests
            t += 1
            continue

        # ---------------- recovery: prefix replay ------------------------
        rep.faults_detected += 1
        detector = report.detector if report is not None else "nonfinite"
        if verbose:
            print(f"[serve] FAULT at token {t} ({detector}) — replaying "
                  f"{len(inputs) - 1}-token prefix")
        t0 = time.perf_counter()
        logits, cache = prefill(params, batch)
        if cache_sh is not None:
            # rebuild on the mesh: the replayed cache must re-enter the
            # canonical sharded layout the canary plans against
            cache = jax.device_put(cache, cache_sh)
        for prev in inputs[:-1]:
            _, cache = decode(params, cache, jnp.asarray(prev))
        token = jnp.asarray(inputs[-1])
        if canary:
            canary.refresh({"cache": cache})   # rebuilt cache = new reference
        rep.replay_tokens += len(inputs) - 1
        rep.recovery_ms.append(1e3 * (time.perf_counter() - t0))
        rep.faults_recovered += 1

    return rep.summary()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="iterpro-100m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--inject", type=int, default=0,
                    help="corrupt the cache every N generated tokens")
    ap.add_argument("--donate", action="store_true",
                    help="donate the decode cache into the step (in-place "
                         "KV update); the canary checks pre-decode")
    ap.add_argument("--fused-detect", action="store_true",
                    help="run the cache canary INSIDE the jitted decode "
                         "(1 combined launch + 1 scalar sync per token)")
    ap.add_argument("--mesh", default=None,
                    help="serve off a device mesh, e.g. '4,2' (CPU repro: "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=8); params/cache shard, the cache canary "
                         "goes shard-local")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    out = serve(cfg, n_requests=args.requests, prompt_len=args.prompt_len,
                gen_tokens=args.gen, seed=args.seed,
                inject_every=args.inject, donate=args.donate,
                fused_detect=args.fused_detect, mesh=args.mesh)
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
