import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input-shape x mesh) cell:
    jit(program, in_shardings, out_shardings).lower(**input_specs).compile()
must succeed; we record ``memory_analysis()`` (fits per chip?),
``cost_analysis()`` (FLOPs / bytes) and the collective schedule parsed from
the optimized HLO — the inputs to EXPERIMENTS.md §Roofline.

Usage:
    python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all [--mesh both] [--out dryrun_results.json]
"""

import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax

from repro.configs import get_config, get_shape, list_archs
from repro.distributed.context import DistContext
from repro.launch import hlo_analysis as H
from repro.launch import hlo_cost as HC
from repro.launch.mesh import make_production_mesh, mesh_chip_count
from repro.launch.specs import input_specs
from repro.models.registry import get_model
from repro.train.loop import make_train_step


def build_program(cfg, shape, ctx):
    """The callable lowered for this cell."""
    model = get_model(cfg.model)
    mcfg = cfg.model
    if shape.kind == "train":
        step = make_train_step(cfg, ctx=ctx, global_batch=shape.global_batch)
        return lambda state, batch: step(state, batch)
    if shape.kind == "prefill":
        return lambda params, batch: model.prefill(params, mcfg, batch, ctx,
                                                   max_len=shape.seq_len)
    if shape.kind == "decode":
        return lambda params, cache, token: model.decode_step(
            params, mcfg, cache, token, ctx)
    raise ValueError(shape.kind)


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             *, keep_hlo: bool = False, variant: Optional[Dict] = None,
             tag: str = "") -> Dict:
    """One dry-run cell.  ``variant`` drives §Perf experiments:
        {"mesh_shape": (64, 4), "mesh_axes": ("data", "model"),
         "flash_threshold": 2048,
         "train": {"microbatch": 0}, "model": {"moe_impl": "..."}}
    """
    import dataclasses as _dc

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    rec: Dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                 "kind": shape.kind}
    if tag:
        rec["tag"] = tag
    if variant:
        rec["variant"] = {k: v for k, v in variant.items()}
    if shape_name in cfg.skipped_shapes():
        rec.update(status="skipped",
                   reason="full-attention arch: long_500k requires "
                          "sub-quadratic attention (DESIGN.md §8)")
        return rec

    if variant:
        if variant.get("train"):
            cfg = cfg.with_overrides(
                train=_dc.replace(cfg.train, **variant["train"]))
        if variant.get("model"):
            cfg = cfg.with_overrides(
                model=_dc.replace(cfg.model, **variant["model"]))
        if variant.get("flash_threshold") is not None:
            from repro.models import layers as _L
            _L.FLASH_THRESHOLD = variant["flash_threshold"]
        if variant.get("q_chunk"):
            from repro.models import layers as _L
            _L.Q_CHUNK = variant["q_chunk"]
        if variant.get("kv_chunk"):
            from repro.models import layers as _L
            _L.KV_CHUNK = variant["kv_chunk"]
        if variant.get("loss_chunk"):
            from repro.models import transformer as _T
            _T.LOSS_CHUNK = variant["loss_chunk"]

    if variant and variant.get("mesh_shape"):
        from repro.launch.mesh import make_mesh
        mesh = make_mesh(variant["mesh_shape"],
                         variant.get("mesh_axes", ("data", "model")))
    else:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh_chip_count(mesh)
    ctx = DistContext.for_mesh(mesh, fsdp=cfg.sharding.fsdp)

    t0 = time.perf_counter()
    try:
        structs, shardings = input_specs(cfg, shape, ctx)
        program = build_program(cfg, shape, ctx)
        jitted = jax.jit(
            program,
            in_shardings=tuple(shardings[k] for k in structs),
        )
        with mesh:
            lowered = jitted.lower(*structs.values())
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        hc = HC.analyze(hlo)          # trip-count-aware text analysis
        mflops = H.model_flops_for_cell(cfg, shape)
        roof = H.hlo_cost_to_roofline(hc, chips, mflops)

        rec.update(
            status="ok",
            chips=chips,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory=_mem_dict(mem),
            xla_cost={k: cost[k] for k in ("flops", "bytes accessed")
                      if cost and k in cost},   # per-device, scan-once (raw)
            hlo_cost=hc.to_dict(),
            roofline=roof.to_dict(),
            hlo_lines=len(hlo.splitlines()),
        )
        if keep_hlo:
            rec["hlo"] = hlo
    except Exception as e:  # a failing cell is a bug in our system
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    return rec


def _mem_dict(mem) -> Dict:
    if mem is None:
        return {}
    out = {}
    for name in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        v = getattr(mem, name, None)
        if v is not None:
            out[name] = int(v)
    # bytes per device: arguments+temp+output are per-device figures for SPMD
    out["per_device_total"] = sum(out.get(k, 0) for k in
                                  ("argument_size_in_bytes",
                                   "temp_size_in_bytes",
                                   "output_size_in_bytes"))
    return out


def iter_cells(archs, shapes, meshes):
    for arch in archs:
        cfg = get_config(arch)
        arch_shapes = shapes or [s.name for s in cfg.shapes()]
        for shape_name in arch_shapes:
            for mesh_kind in meshes:
                yield arch, shape_name, mesh_kind


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--append", action="store_true",
                    help="merge into --out instead of overwriting")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--variant", default=None,
                    help="JSON variant dict for §Perf experiments")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    variant = json.loads(args.variant) if args.variant else None

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = [args.shape] if args.shape else None

    results = []
    if args.append and args.out and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results}

    for arch, shape_name, mesh_kind in iter_cells(archs, shapes, meshes):
        if (arch, shape_name, mesh_kind) in done and not variant:
            continue
        rec = run_cell(arch, shape_name, mesh_kind, keep_hlo=args.keep_hlo,
                       variant=variant, tag=args.tag)
        results.append(rec)
        status = rec["status"]
        extra = ""
        if status == "ok":
            r = rec["roofline"]
            extra = (f" compile={rec['compile_s']}s"
                     f" bottleneck={r['bottleneck']}"
                     f" roofline={r['roofline_fraction']:.3f}")
        elif status == "error":
            extra = " " + rec["error"][:120]
        print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}: {status}{extra}",
              flush=True)
        if args.out:
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
