"""Fault-tolerant training driver — the paper's runtime as a first-class
feature of the training loop.

    PYTHONPATH=src python -m repro.launch.train --arch iterpro-100m --smoke \
        --steps 200 --batch 8 --seq 128 --inject 5

Hot path per step (in order, mirroring the paper's §3.5 design):
    1. step_fn (jitted; pure)                         — the work
    2. free traps on already-computed scalars         — SIGSEGV analogue
    3. rotating checksum canary over 1/K of the state — dormant corruption
    4. micro-checkpoint bookkeeping (bytes)           — Algorithm 2
Everything else (recovery ladder, snapshots restore, disk C/R) is OFF the
hot path and runs only on a FaultReport.

With ``--fused-detect`` steps 1 and 3 are ONE jitted program: the canary
check/arm runs inside the step (core/fused_step.py), so the no-fault hot
path is a single launch + a single scalar sync even under ``--donate``.
"""

from __future__ import annotations

import argparse
import json
import random
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core import (
    ChecksumCanary,
    FaultReport,
    MicroCheckpointer,
    ParityStore,
    RecoveryFailed,
    RecoveryRuntime,
    inject,
    promote,
    sample_plan,
    trap_loss_spike,
    trap_nonfinite,
)
from repro.core.detect import LOSS_WINDOW
from repro.data.pipeline import TokenPipeline
from repro.launch.mesh import make_context
from repro.launch.specs import bind_state
from repro.train.loop import (
    make_train_state,
    make_train_step,
)


@dataclass
class LoopReport:
    steps: int = 0
    faults_injected: int = 0
    faults_detected: int = 0
    faults_recovered: int = 0
    losses: List[float] = field(default_factory=list)
    recovery_ms: List[float] = field(default_factory=list)
    step_seconds: List[float] = field(default_factory=list)
    elastic_events: List[Dict] = field(default_factory=list)

    def summary(self) -> Dict:
        out = {
            "steps": self.steps,
            "final_loss": self.losses[-1] if self.losses else None,
            "faults_injected": self.faults_injected,
            "faults_detected": self.faults_detected,
            "faults_recovered": self.faults_recovered,
            "mean_recovery_ms": float(np.mean(self.recovery_ms))
            if self.recovery_ms else 0.0,
            "mean_step_ms": 1e3 * float(np.mean(self.step_seconds))
            if self.step_seconds else 0.0,
        }
        if self.elastic_events:
            out["elastic_events"] = list(self.elastic_events)
        return out


def batch_for(cfg, pipe, step):
    batch = pipe.batch_at(step)
    m = cfg.model
    if m.n_enc_layers:
        batch = pipe.with_src_embeds(batch, 64, m.frontend_dim, step)
    if m.patch_dim:
        batch = pipe.with_patches(batch, 16, m.patch_dim, step)
    return batch


def train(cfg, *, steps: int, global_batch: int, seq_len: int,
          seed: int = 0, snapshot_interval: int = 8,
          checkpoint_dir: Optional[str] = None, checkpoint_interval: int = 50,
          inject_every: int = 0, inject_target: str = "params",
          canary_slices: int = 4, detectors: bool = True,
          donate: bool = False, fused_detect: bool = False,
          fused_warm: str = "eager", mesh: Optional[str] = None,
          parity: bool = False, triage: bool = False,
          elastic: bool = False, kill_row_at: Optional[int] = None,
          verbose: bool = True) -> Dict:
    """Run the recovery-wrapped loop; returns the loop report dict.

    ``donate=True`` is the production compilation setting: the step is
    jitted with ``donate_argnums=(0,)`` so XLA updates the train state in
    place (half the state HBM).  The resilient path stays donation-safe:
    the canary runs at the pre-step buffer's last readable moment (just
    before the step consumes it) with its double-buffered reference table,
    and on ANY trap recovery pivots to the in-HBM micro-snapshot + IV
    replay rung — the trap path never touches a donated buffer.  With
    ``donate=False`` the loop is bit-identical to the pre-donation driver.

    ``fused_detect=True`` fuses the canary INTO the jitted step
    (``ChecksumCanary.fuse_into_step``; DESIGN.md §4.2 "in-step fused"):
    the input-slice check and the output-slice arm are subcomputations of
    the step itself, so each step is 1 combined launch + 1 scalar sync —
    under donation this halves the dispatch count of the arm/check pair —
    at the cost of ``canary_slices`` rotation-specialised compilations
    (``fused_warm``: ``'eager'`` compiles all K before the first step,
    ``'lazy'`` compiles each rotation on first use).  Detection semantics
    and digests are bit-identical to the unfused paths, which are left
    untouched when the flag is off.

    ``mesh="dp,tp"`` (e.g. ``"4,2"``) runs the WHOLE resilient loop on a
    device mesh (DESIGN.md §5): the state is sharded per
    ``launch/specs.state_shardings`` and pinned there every step, the
    canary goes shard-local (per-device digests + per-device generation
    tables; the one fetched scalar is the all-reduced fault flag),
    snapshots carry per-(leaf, shard) digests, and recovery gains the
    shard_patch rung (restore only the injured shard's addressable
    bytes).  Composes with ``donate``/``fused_detect`` unchanged.

    ``parity=True`` maintains a device-resident XOR parity shard over the
    full state tree (params AND optimizer moments; core/parity.py), kept
    current by the same launch that runs the canary check/arm — no extra
    dispatch, no host traffic.  On a (leaf, shard) fault the recovery
    ladder gains the ``parity_xor`` rung: the injured shard is rebuilt
    from surviving peers + parity in O(bytes/D), digest-certified, with
    zero host-snapshot bytes read and zero replay steps.  Memory cost is
    1/D of the covered state (each device holds 1/D of the parity under
    ``mesh``).  Requires ``detectors=True`` — parity maintenance rides
    the canary's launches and reconstruction certifies against its
    reference digests.

    ``triage=True`` enables recovery rung 0 (``core/recover.py``):
    checksum-attributed faults are classified against the canary's
    reference digest pair BEFORE any repair, and certified-harmless flips
    (dead int8-moment pad bytes, below-epsilon EMA-moment mantissa
    perturbations) are tolerated in place — the digest rows are re-armed
    to the tolerated bits and the loop resumes with zero bytes moved and
    zero replayed steps.  Strictly fault-path-only: the steady state
    keeps the same 1-launch/1-sync/0-retrace contract (asserted by
    ``benchmarks/overhead.py``).  Requires ``detectors=True``.

    ``elastic=True`` (requires ``mesh`` + ``parity`` + ``detectors``)
    arms the HARD-loss path (launch/elastic.py; DESIGN.md §7): the parity
    buffer moves to row-safe placement (sharded over the non-batch mesh
    axes only, so losing a data row never loses the parity that covers
    it), and a ``FaultReport`` carrying ``lost_rows`` routes recovery to
    the ``remesh`` rung — the dead rows' FSDP shards are rebuilt from
    surviving peers + parity, digest-certified against the canary's
    surviving reference rows, the step is re-lowered ONCE onto the
    shrunken mesh, and training resumes at reduced DP width with the
    SAME global batch.  ``kill_row_at=N`` is the chaos drill: before
    step N the loop synthesises an external hard-loss report for the
    highest surviving data row (no process actually dies — the "dead"
    devices are simply never read again).
    """
    key = jax.random.PRNGKey(seed)
    pipe = TokenPipeline(cfg.model.vocab_size, seq_len, global_batch,
                         seed=seed)
    ctx = make_context(mesh)
    state = make_train_state(cfg, key, global_batch=global_batch)
    raw_step = make_train_step(cfg, global_batch=global_batch)
    raw_bfn = lambda s: batch_for(cfg, pipe, s)
    # THE mesh-binding recipe (shardings + device_put + layout pin +
    # batch placement) lives in launch/specs.bind_state — the elastic
    # remesh path re-runs the SAME recipe against the degraded context
    state, raw_step, bfn, shardings = bind_state(
        ctx, cfg, state, raw_step, raw_bfn)
    step_fn = jax.jit(raw_step, donate_argnums=(0,) if donate else ())

    micro = MicroCheckpointer(interval=snapshot_interval, ctx=ctx)
    ckpt = CheckpointManager(checkpoint_dir,
                             interval=checkpoint_interval) \
        if checkpoint_dir else None
    canary = ChecksumCanary(state, n_slices=canary_slices, ctx=ctx) \
        if detectors else None
    pstore = None
    if parity:
        if canary is None:
            raise ValueError("parity requires detectors=True (parity "
                             "maintenance rides the canary's launches and "
                             "reconstruction certifies against its digests)")
        # elastic hard loss needs row-safe parity placement: the buffer
        # lives on the non-batch mesh axes so a dead data row never takes
        # the parity covering its own shards down with it
        pstore = ParityStore(state, ctx=ctx, row_safe=elastic)
        pstore.build(state)
        canary.attach_parity(pstore)
    if triage and canary is None:
        raise ValueError("triage requires detectors=True (rung 0 "
                         "classifies against the canary's digest pair)")
    emgr = None
    elastic_hook = None
    if elastic:
        if ctx is None:
            raise ValueError("elastic requires mesh='dp,tp' (a hard loss "
                             "shrinks the data axis of a device mesh)")
        if pstore is None:
            raise ValueError("elastic requires parity=True (dead rows' "
                             "shards are rebuilt from the XOR parity)")
        from repro.launch.elastic import ElasticManager
        emgr = ElasticManager(ctx, verbose=verbose)
        elastic_hook = emgr.hook(raw_step=raw_step, cfg=cfg,
                                 batch_fn=raw_bfn, canary=canary,
                                 pstore=pstore, donate=donate)
    if kill_row_at is not None and emgr is None:
        raise ValueError("kill_row_at requires elastic=True")
    runtime = RecoveryRuntime(
        step_fn=step_fn,
        batch_fn=bfn, iv_registry=promote(cfg, global_batch), micro=micro,
        parity=pstore, checkpoint=ckpt.loader(state) if ckpt else None,
        donated=donate, shardings=shardings, canary=canary, triage=triage,
        elastic=elastic_hook)
    fused = None
    if fused_detect:
        if canary is None:
            raise ValueError("fused_detect requires detectors=True "
                             "(the canary IS the in-step detector)")
        # the factory jits the RAW step together with the canary check/arm;
        # the separately jitted step_fn above still serves replay/recovery
        fused = canary.fuse_into_step(raw_step, donate=donate,
                                      warm=fused_warm)
        if fused_warm == "eager":
            # compile all K rotation executables BEFORE the loop so the
            # first step's wall time doesn't absorb them ('lazy' keeps
            # the documented pay-per-rotation behaviour)
            fused.warm(state, bfn(0))

    rng = random.Random(seed + 7)
    rep = LoopReport()
    # bounded: the spike trap reads only the last LOSS_WINDOW losses
    # (rep.losses keeps the full telemetry trace)
    history = deque(maxlen=LOSS_WINDOW)
    last_inject = -1

    s = 0
    while s < steps:
        if donate and canary is not None and fused is None:
            # donated hot path, arm half: digest slice s%K of the buffer
            # the previous step just produced (one launch, no sync);
            # check(s) below verifies the SAME slice of the SAME buffer
            # version right before the step consumes it
            canary.arm_current(s, state)

        micro.record_iv(s, state["iv"])
        micro.maybe_snapshot(s, state)
        if ckpt:
            ckpt.maybe_save(s, state)

        # -- adversary: single-bit flip before the step (evaluation only;
        #    once per step — a recovery retry must not be re-hit) --
        if inject_every and s and s % inject_every == 0 and last_inject != s:
            plan = sample_plan(rng, state, max_step=1, target=inject_target)
            state = inject(state, plan)
            rep.faults_injected += 1
            last_inject = s

        report = None
        if emgr is not None and kill_row_at is not None \
                and s == kill_row_at and not emgr.dead:
            # chaos drill: the highest surviving data row "dies" here —
            # an external hard-loss report routes straight to the remesh
            # rung; the dead devices are never read again
            target = emgr.kill_target()
            report = FaultReport(
                s, "external", lost_rows=(target,),
                detail=f"simulated hard loss of data row {target}")
        if report is None and donate and canary is not None \
                and fused is None:
            # donated hot path, check half: the step is about to CONSUME
            # the state buffers, so this is their last readable moment —
            # one launch + ONE scalar sync verifies slice s%K against the
            # generation armed at the top of this loop body
            report = canary.check(s, state)

        if report is None:
            t0 = time.perf_counter()
            if fused is not None:
                # in-step fused canary: the check of slice s%K of the
                # input state and the arm of slice (s+1)%K of the output
                # ride the step's own launch — 1 combined launch + 1
                # scalar sync, donated or not; on a report the new state
                # is corrupt-derived and discarded below
                new_state, metrics, report = fused.step(s, state, bfn(s))
            else:
                new_state, metrics = step_fn(state, bfn(s))
            jax.block_until_ready(metrics["loss"])
            rep.step_seconds.append(time.perf_counter() - t0)

            if detectors and report is None:
                report = trap_nonfinite(s, metrics) or \
                    trap_loss_spike(s, metrics, history)
                if report is None and not donate and canary is not None \
                        and fused is None:
                    # fused rotating canary — ONE launch + ONE scalar sync:
                    # verify the pre-step state's slice (armed at the end
                    # of an earlier step: was the state rotted while at
                    # rest / in use?) and digest the fresh output's
                    # next-check slice
                    report = canary.check_and_arm(s, state, new_state)

            if report is None:
                state = new_state
                loss = float(metrics["loss"])
                history.append(loss)
                rep.losses.append(loss)
                if verbose and s % max(1, steps // 10) == 0:
                    print(f"[train] step {s:5d} loss {loss:.4f}")
                s += 1
                rep.steps += 1
                continue

        # ---------------- recovery path (off hot path) -------------------
        rep.faults_detected += 1
        # in-step fused reports defer leaf attribution to the fault path —
        # materialise it here so the log names the corrupted leaves
        # exactly like the unfused paths (no-op for resolved reports)
        report.resolve()
        if verbose:
            print(f"[train] FAULT at step {s}: {report}")
        try:
            t0 = time.perf_counter()
            state, ev = runtime.recover(state, report, s)
            rep.faults_recovered += 1
            rep.recovery_ms.append(1e3 * (time.perf_counter() - t0))
            resume = getattr(runtime, "pending_remesh", None)
            if resume is not None:
                # hard loss: the remesh rung already rebuilt EVERYTHING
                # against the degraded mesh — swap the loop's working set
                # wholesale; canary/parity are freshly armed (no refresh/
                # rebuild: they'd re-digest what was just certified)
                runtime.pending_remesh = None
                ctx = resume.ctx
                state = resume.state
                step_fn = resume.step       # AOT-compiled: cannot retrace
                raw_step = resume.raw_step
                bfn = resume.bfn
                shardings = resume.shardings
                canary = resume.canary
                pstore = resume.pstore
                micro = MicroCheckpointer(interval=snapshot_interval,
                                          ctx=ctx)
                runtime.micro = micro
                # re-close the hook over the new artifacts so a SECOND
                # loss composes (emgr.ctx already advanced)
                runtime.elastic = emgr.hook(
                    raw_step=raw_step, cfg=cfg, batch_fn=raw_bfn,
                    canary=canary, pstore=pstore, donate=donate)
                if fused is not None:
                    # the old fused executables were evicted with the old
                    # mesh; rebuild against the fresh canary
                    fused = canary.fuse_into_step(raw_step, donate=donate,
                                                  warm=fused_warm)
                    if fused_warm == "eager":
                        fused.warm(state, bfn(s))
                rep.elastic_events.append(resume.event.to_dict())
            else:
                if canary is not None:
                    canary.refresh(state)
                if pstore is not None:
                    # recovery may have produced a whole new state version
                    # (replay/checkpoint rungs); re-anchor the parity to it
                    pstore.rebuild(state, s)
            if verbose:
                print(f"[train] recovered via {ev.rung} in "
                      f"{rep.recovery_ms[-1]:.1f} ms")
        except RecoveryFailed:
            if ckpt is None:
                raise
            state, ck_step = ckpt.restore(state)
            s = ck_step
            if canary is not None:
                # restored state == new reference; stale digests would
                # fire a spurious checksum fault on the next step
                canary.refresh(state)
            if pstore is not None:
                pstore.rebuild(state, ck_step)
            if verbose:
                print(f"[train] cold restore to step {ck_step}")

    if ckpt:
        ckpt.wait()
    out = rep.summary()
    out["recovery"] = runtime.summary()
    if ctx is not None:
        out["mesh"] = {"shape": dict(ctx.mesh.shape),
                       "devices": ctx.n_devices}
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="iterpro-100m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--inject", type=int, default=0,
                    help="inject a bit-flip every N steps")
    ap.add_argument("--inject-target", default="params",
                    choices=["params", "opt", "iv"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--snapshot-interval", type=int, default=8)
    ap.add_argument("--canary-slices", type=int, default=4,
                    help="canary rotation period K (1 = digest the whole "
                         "state every step: deterministic same-step "
                         "detection, K× the streaming bytes)")
    ap.add_argument("--donate", action="store_true",
                    help="jit the step with donate_argnums=(0,) — the "
                         "production in-place-update setting; recovery "
                         "pivots to snapshot+replay")
    ap.add_argument("--fused-detect", action="store_true",
                    help="fuse the canary check/arm INTO the jitted step "
                         "(1 combined launch + 1 scalar sync per step; "
                         "K rotation-specialised compilations)")
    ap.add_argument("--fused-warm", default="eager",
                    choices=["eager", "lazy"],
                    help="compile the K fused step executables up front "
                         "(eager) or on first use of each rotation (lazy)")
    ap.add_argument("--mesh", default=None,
                    help="run on a device mesh, e.g. '4,2' = 4-way data x "
                         "2-way model parallel (CPU repro: XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8); "
                         "detection goes shard-local, recovery gains the "
                         "shard_patch rung")
    ap.add_argument("--parity", action="store_true",
                    help="keep a device-resident XOR parity shard over the "
                         "full state (1/D memory), updated by the canary's "
                         "own launch; recovery gains the parity_xor rung "
                         "(snapshot-free O(bytes/D) shard reconstruction)")
    ap.add_argument("--triage", action="store_true",
                    help="enable recovery rung 0: classify checksum faults "
                         "against the canary's digest pair and tolerate "
                         "certified-harmless flips in place (dead bytes, "
                         "sub-epsilon moment perturbations) — zero bytes "
                         "moved, zero replay; uncertifiable faults "
                         "escalate unchanged")
    ap.add_argument("--elastic", action="store_true",
                    help="arm the hard-loss remesh path (requires --mesh "
                         "and --parity): row-safe parity placement, and a "
                         "lost_rows fault report shrinks the data axis, "
                         "rebuilds the dead rows' shards from parity, "
                         "re-lowers once and resumes at reduced DP width "
                         "with the same global batch")
    ap.add_argument("--kill-row-at", type=int, default=None, metavar="STEP",
                    help="chaos drill: simulate the hard loss of the "
                         "highest surviving data row just before STEP "
                         "(requires --elastic)")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    out = train(cfg, steps=args.steps, global_batch=args.batch,
                seq_len=args.seq, seed=args.seed,
                snapshot_interval=args.snapshot_interval,
                checkpoint_dir=args.ckpt_dir,
                inject_every=args.inject,
                inject_target=args.inject_target,
                canary_slices=args.canary_slices,
                donate=args.donate,
                fused_detect=args.fused_detect,
                fused_warm=args.fused_warm,
                mesh=args.mesh,
                parity=args.parity,
                triage=args.triage,
                elastic=args.elastic,
                kill_row_at=args.kill_row_at)
    print(json.dumps(out, indent=1) if args.json else out)


if __name__ == "__main__":
    main()
