import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Top-HBM-ops / top-collectives profile of one dry-run cell — the
'profiler' of the §Perf loop (there is no wall-clock trace on CPU; the
lowered artifact is the profile).

    PYTHONPATH=src python -m repro.launch.profile_cell --arch kimi-k2-1t-a32b \
        --shape train_4k --variant '{"train": {"microbatch": 0}}' --top 15
"""

import argparse
import json
from collections import deque

import jax

from repro.configs import get_config, get_shape
from repro.distributed.context import DistContext
from repro.launch import hlo_cost as HC
from repro.launch.dryrun import build_program, run_cell
from repro.launch.mesh import make_mesh, make_production_mesh
from repro.launch.specs import input_specs


def profile(arch: str, shape_name: str, variant=None, top: int = 15):
    import dataclasses as _dc
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if variant:
        if variant.get("train"):
            cfg = cfg.with_overrides(
                train=_dc.replace(cfg.train, **variant["train"]))
        if variant.get("model"):
            cfg = cfg.with_overrides(
                model=_dc.replace(cfg.model, **variant["model"]))
        if variant.get("flash_threshold") is not None:
            from repro.models import layers as _L
            _L.FLASH_THRESHOLD = variant["flash_threshold"]
        if variant.get("q_chunk"):
            from repro.models import layers as _L
            _L.Q_CHUNK = variant["q_chunk"]
        if variant.get("kv_chunk"):
            from repro.models import layers as _L
            _L.KV_CHUNK = variant["kv_chunk"]
    if variant and variant.get("mesh_shape"):
        mesh = make_mesh(variant["mesh_shape"],
                         variant.get("mesh_axes", ("data", "model")))
    else:
        mesh = make_production_mesh()
    ctx = DistContext.for_mesh(mesh, fsdp=cfg.sharding.fsdp)
    structs, shardings = input_specs(cfg, shape, ctx)
    prog = build_program(cfg, shape, ctx)
    with mesh:
        compiled = jax.jit(prog, in_shardings=tuple(
            shardings[k] for k in structs)).lower(*structs.values()).compile()
    comps, entry = HC.parse_module(compiled.as_text())

    q = deque([(entry, False, 1.0)])
    mult = {}
    while q:
        name, in_f, m = q.popleft()
        mult[(name, in_f)] = mult.get((name, in_f), 0.0) + m
        comp = comps.get(name)
        if comp is None:
            continue
        for ins in comp.instrs:
            if ins.body is not None:
                trips = max(1, comps[ins.cond].max_const) \
                    if ins.cond in comps else 1
                q.append((ins.body, in_f, m * trips))
            if ins.opcode == "fusion":
                for c in ins.calls:
                    q.append((c, True, m))
            elif ins.opcode in ("call", "conditional", "custom-call"):
                for c in ins.calls:
                    q.append((c, in_f, m))

    rows, colls = [], []
    for (name, in_f), m in mult.items():
        comp = comps.get(name)
        if comp is None or in_f:
            continue
        for ins in comp.instrs:
            if ins.opcode in HC._FREE_OPS or not ins.opcode \
                    or ins.opcode == "while" or ins.opcode.endswith("-done"):
                continue
            b = m * (ins.out_bytes + HC._operand_bytes(comp, ins))
            rows.append((b, ins.opcode, ins.name[:50], name[:40], m))
            if any(ins.opcode.startswith(k) for k in HC.COLLECTIVE_OPS):
                colls.append((m * max(ins.out_bytes,
                                      HC._operand_bytes(comp, ins)),
                              ins.opcode, ins.name[:50], m))
    rows.sort(reverse=True)
    colls.sort(reverse=True)
    total = sum(r[0] for r in rows)
    print(f"total HBM traffic: {total/1e12:.2f} TB/device")
    print(f"top {top} HBM ops:")
    for b, op, iname, cname, m in rows[:top]:
        print(f"  {b/1e9:9.1f} GB m={m:5.0f} {op:14s} {iname:50s} {cname}")
    print(f"top {min(top, len(colls))} collectives:")
    for b, op, iname, m in colls[:top]:
        print(f"  {b/1e9:9.1f} GB m={m:5.0f} {op:18s} {iname}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default=None)
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args()
    profile(args.arch, args.shape,
            json.loads(args.variant) if args.variant else None, args.top)


if __name__ == "__main__":
    main()
