"""Elastic hard-loss recovery — shrink the mesh, keep training (DESIGN §7).

At 1000+-node scale the dominant NON-transient failure is a lost
host/board: a whole row of the data axis disappears and no in-place rung
(core/recover.py) can help — the hardware holding those shards is gone.
Classic response: kill the job, re-provision, restore from the last disk
checkpoint.  The near-zero-downtime response, implemented here end to end:

1. **Deterministic data re-assignment** — every surviving host recomputes
   the same ``shard_assignment(step, dead)`` locally (no coordinator
   round): the dead rows' input slices are absorbed by survivors,
   rotating by step, and the concatenation of the surviving loads is the
   SAME global batch (``stolen_batch`` below is that identity, asserted
   by the chaos drill).
2. **Survivor-honest state reconstruction** — every leaf is reassembled
   on the host from SURVIVING device shards only (dead devices still
   answer in a single-process simulation, so every read filters the dead
   set explicitly).  Blocks with no surviving replica are reconstructed
   from the row-safe XOR parity (``core/parity.py``: parity sharded over
   the non-batch axes survives any data-row loss; per-group folds make a
   row loss a single erasure per group).  Surviving blocks are certified
   against the canary's surviving reference-table rows — the dead rows'
   digests died with their devices and are never read.
3. **Elastic re-mesh** — ``DistContext.degrade`` derives the shrunken
   context, every executable/plan cached against the dead mesh is
   evicted (``invalidate_mesh_caches`` — both to release buffers and so
   a second drill in-process can never hit a stale-device executable),
   and ``launch/specs.bind_state`` re-runs THE one binding recipe against
   the degraded context: device_put onto the new NamedShardings, re-pin,
   re-lower exactly once (AOT ``lower().compile()`` — the returned step
   can never retrace).  Fresh canary + parity artifacts are built on the
   shrunken context and training resumes at reduced DP width.

Total downtime = reconstruct (O(lost bytes)) + one re-lower — no disk
restore, no replay, no replacement hardware.
"""

from __future__ import annotations

import time
from dataclasses import asdict, dataclass, field
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import shard_assignment
from repro.distributed.context import DistContext
from repro.kernels import digest as kdigest
from repro.kernels.ops import leaf_key
from repro.launch.mesh import make_degraded_mesh, mesh_chip_count
from repro.launch.specs import input_specs


# ---------------------------------------------------------------------------
# events / resume bundle
# ---------------------------------------------------------------------------

@dataclass
class ElasticEvent:
    """Telemetry of one hard-loss remesh (benchmarks/elastic_drill.py
    reports these; the drill asserts ``disk_restores == 0``)."""
    step: int
    lost_rows: Tuple[int, ...] = ()       # row indices in the ctx at loss
    lost_slices: Tuple[int, ...] = ()     # original data-slice ids
    old_dp: int = 0
    new_dp: int = 0
    new_dp_width: int = 0                 # legacy alias of new_dp
    downtime_seconds: float = 0.0
    reconstruct_seconds: float = 0.0
    relower_seconds: float = 0.0
    bytes_reconstructed: int = 0
    bytes_regathered: int = 0
    blocks_reconstructed: int = 0
    leaves_regathered: int = 0
    certified_blocks: int = 0
    uncertified_blocks: int = 0
    evicted_executables: int = 0
    disk_restores: int = 0

    def to_dict(self) -> Dict:
        return asdict(self)


@dataclass
class ElasticResume:
    """Everything the training loop swaps in after a remesh."""
    ctx: DistContext
    state: object
    step: Callable          # AOT-compiled pinned step (cannot retrace)
    raw_step: Callable      # the pinned, unjitted step (replay / rebinds)
    bfn: Callable
    shardings: object
    specs: object
    canary: object = None
    pstore: object = None
    event: ElasticEvent = field(default_factory=lambda: ElasticEvent(0))


# ---------------------------------------------------------------------------
# survivor-honest host reads
# ---------------------------------------------------------------------------

def _host_regather(leaf, dead):
    """Full host copy of a leaf assembled from SURVIVING device shards
    only.  Returns None when some region has no surviving replica (the
    caller must then have parity coverage or fail loudly)."""
    out = np.zeros(leaf.shape, leaf.dtype)
    have = np.zeros(leaf.shape, bool)
    for sh in leaf.addressable_shards:
        if sh.device in dead:
            continue
        out[sh.index] = np.asarray(sh.data)
        have[sh.index] = True
    if not bool(have.all()):
        return None
    return out


def _certify_leaf(key, full, leaf, refs, have, dead, mesh):
    """Certify the surviving unique blocks of ``full`` (our host
    assembly) against the canary's SURVIVING reference rows: the digest
    of each block must equal the table row of a surviving device holding
    it (``host_checksum`` is bit-identical to the sharded table's rows by
    construction).  Returns (certified, mismatched) block counts —
    mismatches mean the row was armed for an older state version (K > 1
    rotation) or the survivor itself is corrupt."""
    from repro.core.parity import _norm_slices
    ref = refs.get(key)
    if ref is None:
        return 0, 0
    devs = kdigest.mesh_device_order(mesh)
    idxs = [_norm_slices(i, full.shape) for i in kdigest.shard_indices(leaf)]
    ok = bad = 0
    seen = set()
    for d, (dev, idx) in enumerate(zip(devs, idxs)):
        if dev in dead or not have[d] or idx in seen:
            continue
        seen.add(idx)
        block = full[tuple(slice(a, b) for a, b in idx)]
        got = np.asarray(kdigest.host_checksum(block))
        if np.array_equal(got, np.asarray(ref[d])):
            ok += 1
        else:
            bad += 1
    return ok, bad


def stolen_batch(pipe, step: int, n_slices: int,
                 dead: Tuple[int, ...]) -> Dict[str, jnp.ndarray]:
    """The global batch as the SURVIVORS assemble it: every surviving
    slice loads its own rows plus the dead slices' rows its
    ``shard_assignment`` hands it, and the pieces concatenate back in
    canonical slice order — bit-identical to ``pipe.batch_at(step)``
    (the chaos drill asserts this identity; it is what 'same global
    batch at reduced DP width' means)."""
    assign = shard_assignment(step, n_slices, tuple(dead))
    parts: Dict[int, Dict[str, jnp.ndarray]] = {}
    for owner, slices in assign.items():
        for sl in slices:
            parts[sl] = pipe.shard_at(step, sl, n_slices)
    return {k: jnp.concatenate([parts[i][k] for i in range(n_slices)],
                               axis=0)
            for k in parts[0]}


# ---------------------------------------------------------------------------
# mesh-keyed cache eviction (the stale-executable guard)
# ---------------------------------------------------------------------------

def invalidate_mesh_caches(mesh) -> Dict[str, int]:
    """Evict every global cache entry keyed on ``mesh``: fused-step and
    fused-canary executables, serving-engine executables, and the
    digest/parity plan caches.  Executables pin their device assignment
    at compile time — after a hard loss they reference dead devices, hold
    device buffers alive, and a second drill in the same process would
    silently hit them."""
    from repro.core import detect, fused_step
    from repro.core import parity as core_parity
    counts = {
        "fused_step": fused_step.evict_mesh(mesh),
        "fused_canary": detect.evict_mesh(mesh),
        "digest_plans": kdigest.evict_mesh_plans(mesh),
        "parity_plans": core_parity.evict_mesh_plans(mesh),
    }
    try:
        from repro.serving import engine as serving_engine
        counts["serving"] = serving_engine.evict_mesh(mesh)
    except ImportError:                        # pragma: no cover
        counts["serving"] = 0
    return counts


# ---------------------------------------------------------------------------
# the manager
# ---------------------------------------------------------------------------

class ElasticManager:
    """Tracks dead data slices and runs the hard-loss recovery path.

    Two construction modes:

    * ``ElasticManager(n_slices=8)`` — assignment-only (the original
      dry-run API): ``mark_dead`` + ``assignment`` + ``degraded_mesh``.
    * ``ElasticManager(ctx)`` — live mode over a meshed ``DistContext``:
      ``on_loss`` executes reconstruction + remesh + re-lower and returns
      an ``ElasticResume``.  The manager's ``ctx`` advances to the
      degraded context after each loss, so a second loss composes
      (``slice_ids`` keeps the surviving rows' ORIGINAL slice ids for
      ``shard_assignment``).
    """

    def __init__(self, ctx: Optional[DistContext] = None, *,
                 n_slices: Optional[int] = None, verbose: bool = False):
        if ctx is not None and not isinstance(ctx, DistContext):
            raise TypeError("pass a DistContext or n_slices=...")
        self.ctx = ctx if (ctx is not None and ctx.enabled) else None
        if n_slices is None:
            n_slices = self.ctx.mesh.shape[self.ctx.data_axis] \
                if self.ctx else 0
        self.n_slices = int(n_slices)
        self.verbose = verbose
        #: dead ORIGINAL data-slice ids — the coordinate system of
        #: ``shard_assignment`` (stable across successive remeshes)
        self.dead: set = set()
        #: current-ctx row index -> original slice id
        self.slice_ids = list(range(self.n_slices))
        self.events: list = []

    # -- assignment (original API) ----------------------------------------

    @property
    def dead_rows(self) -> set:
        return self.dead

    def mark_dead(self, *slices: int) -> None:
        self.dead.update(int(s) for s in slices)
        if len(self.dead) >= self.n_slices:
            raise RuntimeError("all data slices lost")
        self.slice_ids = [s for s in self.slice_ids if s not in self.dead]

    def assignment(self, step: int) -> Dict[int, Tuple[int, ...]]:
        """Which input slices each surviving slice loads this step."""
        return shard_assignment(step, self.n_slices, tuple(self.dead))

    def degraded_mesh(self, *, multi_pod: bool = False):
        if self.ctx is not None:
            return self.ctx.mesh
        return make_degraded_mesh(len(self.dead), multi_pod=multi_pod)

    def kill_target(self) -> int:
        """Highest surviving row index of the CURRENT mesh — what a
        simulated ``--kill-row-at`` takes out."""
        return len(self.slice_ids) - 1

    # -- the hard-loss path ------------------------------------------------

    def on_loss(self, *, step: int, dead_rows: Sequence[int], state,
                raw_step: Callable, cfg, batch_fn: Callable,
                canary=None, pstore=None, donate: bool = False,
                strict_certify: Optional[bool] = None) -> ElasticResume:
        """Execute the full degraded-mesh resume: survivor-honest gather
        + certify, parity reconstruction of the dead rows' shards,
        old-mesh cache eviction, re-bind + ONE re-lower on the degraded
        context, fresh canary/parity artifacts.  ``dead_rows`` are row
        indices of the CURRENT context's data axis."""
        if self.ctx is None:
            raise RuntimeError("on_loss needs a meshed DistContext")
        t0 = time.perf_counter()
        ctx = self.ctx
        dead_rows = tuple(sorted(int(r) for r in dead_rows))
        dead = set()
        for r in dead_rows:
            dead.update(ctx.row_devices(r))
        if strict_certify is None:
            strict_certify = canary is not None and canary.n_slices == 1

        plan = pstore.plan if pstore is not None else None
        if plan is not None and not plan.keys:
            plan = None  # empty coverage: pure re-gather path
        if plan is not None and not plan.row_safe:
            raise RuntimeError(
                "hard-loss recovery needs a row_safe ParityStore — the "
                "default parity placement dies with the row it covers")
        refs = have = None
        if canary is not None:
            refs, have = canary.surviving_reference_digests(dead)
        pflat = plan.host_parity_flat(pstore.parity, dead) \
            if plan is not None else None

        # ---- survivor-honest gather + certify + reconstruct ------------
        bytes_recon = bytes_regather = 0
        blocks_recon = leaves_regathered = 0
        certified = uncertified = 0
        flat, treedef = jax.tree_util.tree_flatten_with_path(state)
        host_leaves = []
        for path, leaf in flat:
            key = leaf_key(path)
            if plan is not None and key in plan.key_set:
                full, missing = plan.host_assemble_leaf(key, leaf, dead)
                if missing:
                    blocks = plan.host_surviving_blocks(key, leaf, dead)
                    uniq, _ = plan.slices[key]
                    for b in missing:
                        blk = plan.host_reconstruct_block(
                            key, b, pflat, blocks)
                        full[tuple(slice(a, bnd)
                                   for a, bnd in uniq[b])] = blk
                        bytes_recon += blk.nbytes
                        blocks_recon += 1
            else:
                full = _host_regather(leaf, dead)
                if full is None:
                    raise RuntimeError(
                        f"leaf {key}: some region has neither a "
                        f"surviving replica nor parity coverage — "
                        f"unrecoverable without a checkpoint")
                bytes_regather += full.nbytes
                leaves_regathered += 1
            if refs is not None:
                ok, bad = _certify_leaf(key, full, leaf, refs, have,
                                        dead, ctx.mesh)
                certified += ok
                uncertified += bad
            host_leaves.append(full)
        if strict_certify and uncertified:
            raise RuntimeError(
                f"{uncertified} surviving blocks failed digest "
                f"certification against the surviving reference rows")
        host_state = jax.tree_util.tree_unflatten(treedef, host_leaves)
        t_recon = time.perf_counter() - t0

        # ---- drop everything pinned to the dead mesh --------------------
        evicted = invalidate_mesh_caches(ctx.mesh)

        # ---- remesh + re-bind + ONE re-lower ----------------------------
        lost_slices = tuple(self.slice_ids[r] for r in dead_rows
                            if r < len(self.slice_ids))
        old_dp = ctx.dp_size
        new_ctx = ctx.degrade(dead_rows)
        from repro.launch.specs import bind_state
        t1 = time.perf_counter()
        bound = bind_state(new_ctx, cfg, host_state, raw_step, batch_fn)
        jfn = jax.jit(bound.step,
                      donate_argnums=(0,) if donate else ())
        compiled = jfn.lower(bound.state, bound.bfn(step)).compile()
        relower = time.perf_counter() - t1

        # ---- fresh detection/parity artifacts on the shrunken ctx -------
        new_canary = new_pstore = None
        if pstore is not None:
            from repro.core.parity import ParityStore
            new_pstore = ParityStore(bound.state, ctx=new_ctx,
                                     row_safe=True)
            new_pstore.build(bound.state, step)
        if canary is not None:
            from repro.core.detect import ChecksumCanary
            new_canary = ChecksumCanary(
                bound.state, n_slices=canary.n_slices, ctx=new_ctx)
            if new_pstore is not None and canary.parity_store is not None:
                new_canary.attach_parity(new_pstore)

        self.dead.update(lost_slices)
        self.slice_ids = [s for i, s in enumerate(self.slice_ids)
                          if i not in set(dead_rows)]
        self.ctx = new_ctx
        ev = ElasticEvent(
            step=step, lost_rows=dead_rows, lost_slices=lost_slices,
            old_dp=old_dp, new_dp=new_ctx.dp_size,
            new_dp_width=new_ctx.dp_size,
            downtime_seconds=time.perf_counter() - t0,
            reconstruct_seconds=t_recon, relower_seconds=relower,
            bytes_reconstructed=bytes_recon,
            bytes_regathered=bytes_regather,
            blocks_reconstructed=blocks_recon,
            leaves_regathered=leaves_regathered,
            certified_blocks=certified, uncertified_blocks=uncertified,
            evicted_executables=sum(evicted.values()),
            disk_restores=0)
        self.events.append(ev)
        if self.verbose:
            print(f"[elastic] step {step}: lost rows {dead_rows} "
                  f"(slices {lost_slices}), dp {old_dp}->{ev.new_dp}, "
                  f"reconstructed {blocks_recon} blocks "
                  f"({bytes_recon} B), re-lowered in {relower:.2f}s, "
                  f"downtime {ev.downtime_seconds:.2f}s")
        return ElasticResume(
            ctx=new_ctx, state=bound.state, step=compiled,
            raw_step=bound.step, bfn=bound.bfn,
            shardings=bound.shardings, specs=bound.specs,
            canary=new_canary, pstore=new_pstore, event=ev)

    def hook(self, *, raw_step, cfg, batch_fn, canary=None, pstore=None,
             donate: bool = False) -> Callable:
        """Adapter for ``RecoveryRuntime(elastic=...)``: a callable
        ``(state, report, step) -> ElasticResume`` closing over the bind
        ingredients (the runtime stays layering-clean: core/ never
        imports launch/)."""
        def run(state, report, step):
            return self.on_loss(
                step=step, dead_rows=tuple(report.lost_rows),
                state=state, raw_step=raw_step, cfg=cfg,
                batch_fn=batch_fn, canary=canary, pstore=pstore,
                donate=donate)
        return run


def relower_degraded(cfg, shape, *, lost_slices: int = 1,
                     multi_pod: bool = False):
    """Re-lower + compile the cell's program on the degraded mesh.

    Returns (compiled, mesh, seconds) — the elastic-scaling dry-run proof
    (the production-shape twin of the live ``on_loss`` path, runnable
    with 512 placeholder devices and no state)."""
    t0 = time.perf_counter()
    mesh = make_degraded_mesh(lost_slices, multi_pod=multi_pod)
    ctx = DistContext.for_mesh(mesh, fsdp=cfg.sharding.fsdp)
    structs, shardings = input_specs(cfg, shape, ctx)

    from repro.launch.dryrun import build_program
    program = build_program(cfg, shape, ctx)
    jitted = jax.jit(program, in_shardings=tuple(shardings[k]
                                                 for k in structs))
    with mesh:
        compiled = jitted.lower(*structs.values()).compile()
    return compiled, mesh, time.perf_counter() - t0
