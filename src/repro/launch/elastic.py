"""Elastic scaling + straggler/failure mitigation (DESIGN §7).

At 1000+-node scale the dominant non-transient failure is a lost host/board:
a 16-chip row of the data axis disappears.  Classic response: kill the job,
re-provision, restore from the last disk checkpoint.  IterPro-JAX's response
(the paper's near-zero-downtime philosophy applied at pod scale):

1. **Deterministic data re-assignment** — every surviving host recomputes the
   same ``shard_assignment(step, dead)`` locally (no coordinator round):
   the dead rows' input slices are absorbed by survivors, rotating by step.
2. **Elastic re-mesh** — ``make_degraded_mesh`` rebuilds a (rows-k, 16) mesh
   on the survivors; parameters re-shard via ``jax.device_put`` with the new
   NamedShardings (one all-gather-free reshard — FSDP shards move, replicated
   leaves stay).  The step function is re-lowered once; training resumes at
   reduced data-parallel width with the SAME global batch (survivors each
   carry proportionally more rows).
3. **State repair** — the lost rows' FSDP/parity shards are reconstructed by
   the recovery ladder (parity rung) or re-gathered from optimizer-replicated
   copies; see core/recover.py.

The dry-run proof: ``relower_degraded`` compiles the identical step function
against the degraded mesh — demonstrating the re-mesh path is executable
without code changes.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import jax

from repro.data.pipeline import shard_assignment
from repro.distributed.context import DistContext
from repro.launch.mesh import make_degraded_mesh, mesh_chip_count
from repro.launch.specs import input_specs


@dataclass
class ElasticEvent:
    step: int
    lost_slices: Tuple[int, ...]
    new_dp_width: int
    relower_seconds: float


class ElasticManager:
    """Tracks dead data slices and produces degraded meshes/assignments."""

    def __init__(self, n_slices: int):
        self.n_slices = n_slices
        self.dead: set = set()
        self.events: list = []

    def mark_dead(self, *slices: int) -> None:
        self.dead.update(slices)
        if len(self.dead) >= self.n_slices:
            raise RuntimeError("all data slices lost")

    def assignment(self, step: int) -> Dict[int, Tuple[int, ...]]:
        """Which input slices each surviving slice loads this step."""
        return shard_assignment(step, self.n_slices, tuple(self.dead))

    def degraded_mesh(self, *, multi_pod: bool = False):
        return make_degraded_mesh(len(self.dead), multi_pod=multi_pod)


def relower_degraded(cfg, shape, *, lost_slices: int = 1,
                     multi_pod: bool = False):
    """Re-lower + compile the cell's program on the degraded mesh.

    Returns (compiled, mesh, seconds) — the elastic-scaling dry-run proof.
    """
    t0 = time.perf_counter()
    mesh = make_degraded_mesh(lost_slices, multi_pod=multi_pod)
    ctx = DistContext.for_mesh(mesh, fsdp=cfg.sharding.fsdp)
    structs, shardings = input_specs(cfg, shape, ctx)

    from repro.launch.dryrun import build_program
    program = build_program(cfg, shape, ctx)
    jitted = jax.jit(program, in_shardings=tuple(shardings[k]
                                                 for k in structs))
    with mesh:
        compiled = jitted.lower(*structs.values()).compile()
    return compiled, mesh, time.perf_counter() - t0
