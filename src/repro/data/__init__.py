from repro.data.pipeline import TokenPipeline, shard_assignment  # noqa: F401
