"""Deterministic, index-addressable data pipeline.

The IterPro recovery story *requires* that any training step's inputs are a
pure function of the loop's induction variables: ``batch = f(seed, step)``.
That makes every step replayable (the RSI replay rung of the recovery
ladder) and makes the data-iterator offset an affine induction variable —
``offset = step * global_batch`` — i.e. a *partner* of the step counter in
the paper's Eq. (1) sense.

Synthetic LM data with learnable structure: an affine token recurrence with
key-derived noise, so that a ~100M model's loss visibly drops within a few
hundred steps (used by the end-to-end example and the fault-injection
benchmarks).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class TokenPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.05  # fraction of tokens replaced by uniform noise

    # -- pure index-addressable access --------------------------------------

    def batch_at(self, step) -> Dict[str, jnp.ndarray]:
        """Full global batch for ``step`` (traced-compatible: step may be a
        traced int32 scalar)."""
        return self._slice(step, 0, self.global_batch)

    def shard_at(self, step, shard: int, n_shards: int) -> Dict[str, jnp.ndarray]:
        """The ``shard``-th of ``n_shards`` slices of the step's batch —
        what one data-parallel host loads."""
        per = self.global_batch // n_shards
        return self._slice(step, shard * per, per)

    def _slice(self, step, row0: int, rows: int):
        """Rows [row0, row0+rows) of the step's batch."""
        base = jax.random.PRNGKey(self.seed)
        kstep = jax.random.fold_in(base, jnp.asarray(step, jnp.int32))

        # Sequence identity: absolute sample index = step*B + row. Each
        # sequence is generated independently of all others (addressable).
        sample_ids = jnp.asarray(step, jnp.int32) * self.global_batch + \
            row0 + jnp.arange(rows, dtype=jnp.int32)

        def gen_seq(sid):
            k = jax.random.fold_in(base, sid)
            k1, k2, k3 = jax.random.split(k, 3)
            a = 3 + 2 * jax.random.randint(k1, (), 0, 8)     # odd multiplier
            c = jax.random.randint(k2, (), 1, self.vocab_size)
            t0 = jax.random.randint(k3, (), 0, self.vocab_size)
            idx = jnp.arange(self.seq_len + 1, dtype=jnp.int32)
            toks = jnp.mod(t0 + idx * a + (idx * idx) * c, self.vocab_size)
            kn1, kn2 = jax.random.split(jax.random.fold_in(k, 7))
            flip = jax.random.uniform(kn1, (self.seq_len + 1,)) < self.noise
            rand = jax.random.randint(kn2, (self.seq_len + 1,), 0,
                                      self.vocab_size)
            toks = jnp.where(flip, rand, toks)
            return toks

        toks = jax.vmap(gen_seq)(sample_ids)
        del kstep
        return {"tokens": toks[:, :-1].astype(jnp.int32),
                "targets": toks[:, 1:].astype(jnp.int32)}

    # -- auxiliary modality stubs -------------------------------------------

    def with_patches(self, batch, n_patches: int, patch_dim: int, step):
        base = jax.random.PRNGKey(self.seed + 101)
        k = jax.random.fold_in(base, jnp.asarray(step, jnp.int32))
        B = batch["tokens"].shape[0]
        patches = jax.random.normal(k, (B, n_patches, patch_dim),
                                    jnp.float32)
        p1 = jnp.broadcast_to(
            jnp.arange(self.seq_len + n_patches, dtype=jnp.int32)[None],
            (B, self.seq_len + n_patches))
        batch = dict(batch)
        batch["patch_embeds"] = patches
        batch["positions"] = jnp.stack([p1, p1, p1], axis=-1)
        return batch

    def with_src_embeds(self, batch, src_len: int, frontend_dim: int, step):
        base = jax.random.PRNGKey(self.seed + 202)
        k = jax.random.fold_in(base, jnp.asarray(step, jnp.int32))
        B = batch["tokens"].shape[0]
        batch = dict(batch)
        batch["src_embeds"] = jax.random.normal(
            k, (B, src_len, frontend_dim), jnp.float32)
        return batch


def shard_assignment(step: int, n_shards: int,
                     dead: Sequence[int] = ()) -> Dict[int, Tuple[int, ...]]:
    """Deterministic work-stealing of data-shard slices.

    Healthy hosts deterministically absorb the slices of ``dead`` hosts,
    rotating by step so no single survivor is permanently overloaded
    (straggler/failure mitigation without a coordinator: every host computes
    the same assignment from (step, dead-set)).
    """
    healthy = [s for s in range(n_shards) if s not in set(dead)]
    if not healthy:
        raise RuntimeError("no healthy data shards remain")
    assign: Dict[int, list] = {h: [h] for h in healthy}
    for i, d in enumerate(sorted(set(dead))):
        owner = healthy[(step + i) % len(healthy)]
        assign[owner].append(d)
    return {h: tuple(v) for h, v in assign.items()}
