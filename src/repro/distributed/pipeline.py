"""GPipe-style pipeline parallelism over a mesh axis (DESIGN §7: optional
PP across the 'pod' axis at multi-pod scale).

`gpipe(stage_fn, n_stages, axis)` builds a shard_map-able SPMD program:
stage s holds slice s of the stacked stage params; microbatches flow
through the stages via `ppermute`, with the classic (M + S - 1)-step
schedule and masked bubbles.  The last stage's outputs are psum-merged so
every rank returns the full output (convenient for loss computation).

Use case at 1000+-node scale: when a model's layers do not fit a pod even
under FSDP, stages map onto pods and only (B_micro, d) activations cross
the DCN per schedule tick — orders of magnitude less inter-pod traffic
than FSDP gathers.  Correctness is validated against the sequential
composition in tests/test_pipeline.py.
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def gpipe(stage_fn: Callable, n_stages: int, axis: str):
    """Returns body(stage_params, xs) for use inside shard_map.

    stage_params: pytree with leaves (1, ...) — this rank's stage slice.
    xs: (M, B, d) microbatched input, replicated over the stage axis.
    Returns (M, B, d) outputs, replicated.
    """

    def body(stage_params, xs):
        params = jax.tree_util.tree_map(lambda p: p[0], stage_params)
        s = jax.lax.axis_index(axis)
        M = xs.shape[0]
        T = M + n_stages - 1

        def tick(t, state):
            carry_in, out = state
            mb = t - s                        # microbatch index at stage s
            active = (mb >= 0) & (mb < M)

            # stage 0 reads from the input queue; others from the wire
            x0 = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(t, 0, M - 1), axis=0, keepdims=False)
            x_in = jnp.where(s == 0, x0, carry_in)

            y = stage_fn(params, x_in)
            y = jnp.where(active, y, jnp.zeros_like(y))

            # last stage commits its finished microbatch
            write = active & (s == n_stages - 1)
            idx = jnp.clip(mb, 0, M - 1)
            slot = jax.lax.dynamic_index_in_dim(out, idx, axis=0,
                                                keepdims=False)
            out = jax.lax.dynamic_update_index_in_dim(
                out, jnp.where(write, y, slot), idx, axis=0)

            # advance the pipe: stage i -> i+1
            carry_next = jax.lax.ppermute(
                y, axis, [(i, i + 1) for i in range(n_stages - 1)])
            return (carry_next, out)

        carry0 = jnp.zeros_like(xs[0])
        out0 = jnp.zeros_like(xs)
        _, out = jax.lax.fori_loop(0, T, tick, (carry0, out0))
        # only the last stage wrote; merge so every rank holds the result
        return jax.lax.psum(out, axis)

    return body


def pipeline_apply(stage_fn: Callable, stacked_params, xs, mesh,
                   axis: str = "stage"):
    """Convenience wrapper: shard stage params over ``axis`` and run the
    pipeline.  stacked_params leaves: (S, ...); xs: (M, B, d) replicated."""
    n_stages = mesh.shape[axis]
    body = gpipe(stage_fn, n_stages, axis)
    pspec = jax.tree_util.tree_map(
        lambda p: P(axis, *([None] * (p.ndim - 1))), stacked_params)
    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(pspec, P()),
        out_specs=P(),
        check_vma=False,
    )(stacked_params, xs)
