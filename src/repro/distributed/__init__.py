from repro.distributed.context import DistContext  # noqa: F401
