"""DistContext — the one object threaded through model AND resilience code
that knows how this program maps onto the device mesh.

Model code never touches ``jax.sharding`` directly: it calls
``ctx.constrain(x, spec...)`` (a no-op when running locally, e.g. in CPU unit
tests) and family modules consult ``ctx.batch_axes`` / ``ctx.model_axis`` for
shard_map specs.  This keeps every model definition runnable on a laptop and
shardable on a 512-chip mesh with zero code changes.

The contract
------------

A ``DistContext`` is a frozen value with exactly two states:

* **local** (``mesh is None``, ``enabled == False``): every helper
  degrades to the identity / size-1 answer.  Code written against the
  context runs unchanged on one device — this is what keeps the entire
  test suite and the smoke configs on 1 CPU device.
* **meshed** (``enabled == True``): ``mesh`` is a live ``jax.sharding.Mesh``
  whose axis names partition into ``batch_axes`` (data/pod parallelism)
  and ``model_axis`` (tensor parallelism).  ``sharding(*spec)`` /
  ``constrain(x, *spec)`` build ``NamedSharding``s on that mesh;
  ``dp_size`` / ``tp_size`` report the axis products.

Consumers and what they rely on:

* **models** (``models/*``): ``constrain`` / ``constrain_batch`` for
  activation layout hints; must tolerate the local no-op.
* **partitioners** (``distributed/sharding.py``, ``launch/specs.py``):
  derive every train-state leaf's ``PartitionSpec`` from
  ``batch_axes``/``model_axis`` with divisibility guards, then
  ``launch/specs.state_shardings`` turns them into ``NamedSharding``s.
* **the resilience layer** (DESIGN.md §5): ``ChecksumCanary(...,
  ctx=ctx)``, ``MicroCheckpointer(..., ctx=ctx)`` and the recovery
  runtime key EVERYTHING on this object.  The canary derives its
  shard-local digest layout from the leaves' ``NamedSharding``s (so the
  state must be ``device_put`` with its specs BEFORE the canary is
  built), snapshots record per-(leaf, shard) digests in mesh-flat device
  order (``n_devices`` shards, ``device_order()``), and detection's only
  cross-device communication is the all-reduced fault flag.  Passing
  ``ctx=None`` (or a local context) reproduces the single-device
  behaviour bit for bit — the resilience stack treats the context
  exactly like model code does: one object, two states, no branches
  leaking past construction time.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class DistContext:
    mesh: Optional[Mesh] = None
    batch_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    fsdp: bool = False

    @property
    def enabled(self) -> bool:
        return self.mesh is not None

    @classmethod
    def local(cls) -> "DistContext":
        return cls(mesh=None)

    @classmethod
    def for_mesh(cls, mesh: Mesh, *, fsdp: bool = False) -> "DistContext":
        names = mesh.axis_names
        batch_axes = tuple(a for a in ("pod", "data") if a in names)
        return cls(mesh=mesh, batch_axes=batch_axes, model_axis="model",
                   fsdp=fsdp)

    # -- sharding helpers ----------------------------------------------------

    def sharding(self, *spec) -> Optional[NamedSharding]:
        if not self.enabled:
            return None
        return NamedSharding(self.mesh, P(*spec))

    def constrain(self, x, *spec):
        """with_sharding_constraint that degrades to identity off-mesh."""
        if not self.enabled:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec)))

    def constrain_batch(self, x):
        """Shard the leading (batch) dim over the batch axes."""
        if not self.enabled:
            return x
        spec = (self.batch_axes,) + (None,) * (x.ndim - 1)
        return self.constrain(x, *spec)

    @property
    def dp_size(self) -> int:
        if not self.enabled:
            return 1
        return int(
            __import__("numpy").prod(
                [self.mesh.shape[a] for a in self.batch_axes]))

    @property
    def tp_size(self) -> int:
        if not self.enabled:
            return 1
        # a mesh without the model axis (pure DP, e.g. "--mesh 4") has
        # tensor-parallel width 1
        return self.mesh.shape.get(self.model_axis, 1)

    # -- elastic views (DESIGN.md §7) ----------------------------------------

    @property
    def data_axis(self) -> str:
        """The innermost data-parallel axis — the axis whose rows a hard
        host/board loss removes."""
        return self.batch_axes[-1] if self.batch_axes else "data"

    def row_devices(self, row: int) -> Tuple:
        """Devices of data row ``row`` — what dies together when a host
        holding that row is lost."""
        if not self.enabled:
            return ()
        import numpy as np
        ai = self.mesh.axis_names.index(self.data_axis)
        return tuple(np.take(self.mesh.devices, row, axis=ai).flatten())

    def degrade(self, dead_rows) -> "DistContext":
        """The context after losing ``dead_rows`` of the data axis: the
        same axis names over the surviving device rows.  Every derived
        artifact (NamedShardings, digest/parity plans, shard ids) must be
        rebuilt against the returned context — nothing built on the old
        mesh is valid on the new one."""
        if not self.enabled:
            raise ValueError("cannot degrade a local context")
        import numpy as np
        axis = self.data_axis
        ai = self.mesh.axis_names.index(axis)
        dead = set(int(r) for r in dead_rows)
        n = self.mesh.devices.shape[ai]
        bad = dead - set(range(n))
        if bad:
            raise ValueError(f"dead rows {sorted(bad)} outside data axis "
                             f"of size {n}")
        keep = [r for r in range(n) if r not in dead]
        if not keep:
            raise RuntimeError("no surviving data rows to remesh onto")
        devices = np.take(self.mesh.devices, keep, axis=ai)
        return DistContext.for_mesh(Mesh(devices, self.mesh.axis_names),
                                    fsdp=self.fsdp)

    # -- resilience-layer views ---------------------------------------------

    @property
    def n_devices(self) -> int:
        """Total mesh size — the shard count of every sharded resilience
        artifact (digest tables, bad masks, snapshot shard digests)."""
        if not self.enabled:
            return 1
        return int(self.mesh.size)

    def device_order(self) -> Tuple:
        """Mesh devices in canonical (mesh-flat, row-major over axis
        order) sequence — shard id ``d`` throughout the resilience layer
        means this tuple's d-th device."""
        if not self.enabled:
            return tuple(jax.devices()[:1])
        return tuple(self.mesh.devices.flatten())
