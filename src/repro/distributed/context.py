"""DistContext — the one object threaded through model code that knows how
this program maps onto the device mesh.

Model code never touches ``jax.sharding`` directly: it calls
``ctx.constrain(x, spec...)`` (a no-op when running locally, e.g. in CPU unit
tests) and family modules consult ``ctx.batch_axes`` / ``ctx.model_axis`` for
shard_map specs.  This keeps every model definition runnable on a laptop and
shardable on a 512-chip mesh with zero code changes.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class DistContext:
    mesh: Optional[Mesh] = None
    batch_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    fsdp: bool = False

    @property
    def enabled(self) -> bool:
        return self.mesh is not None

    @classmethod
    def local(cls) -> "DistContext":
        return cls(mesh=None)

    @classmethod
    def for_mesh(cls, mesh: Mesh, *, fsdp: bool = False) -> "DistContext":
        names = mesh.axis_names
        batch_axes = tuple(a for a in ("pod", "data") if a in names)
        return cls(mesh=mesh, batch_axes=batch_axes, model_axis="model",
                   fsdp=fsdp)

    # -- sharding helpers ----------------------------------------------------

    def sharding(self, *spec) -> Optional[NamedSharding]:
        if not self.enabled:
            return None
        return NamedSharding(self.mesh, P(*spec))

    def constrain(self, x, *spec):
        """with_sharding_constraint that degrades to identity off-mesh."""
        if not self.enabled:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec)))

    def constrain_batch(self, x):
        """Shard the leading (batch) dim over the batch axes."""
        if not self.enabled:
            return x
        spec = (self.batch_axes,) + (None,) * (x.ndim - 1)
        return self.constrain(x, *spec)

    @property
    def dp_size(self) -> int:
        if not self.enabled:
            return 1
        return int(
            __import__("numpy").prod(
                [self.mesh.shape[a] for a in self.batch_axes]))

    @property
    def tp_size(self) -> int:
        if not self.enabled:
            return 1
        return self.mesh.shape[self.model_axis]
