"""Partition-spec generation for every train-state leaf — the single
source of layout truth consumed by compilation AND resilience.

Rules are name/shape driven over the flattened param tree.  Every rule goes
through a divisibility guard — a dim that does not divide its mesh axis is
silently replicated instead of crashing the partitioner (e.g. xLSTM's 4
heads on a 16-wide model axis).

Layout summary (the baseline recipe; §Perf iterates on this):
    embeddings   (V, d)      -> (model, fsdp)
    qkv/up/gate  (d, out)    -> (fsdp, model)
    wo/down      (in, d)     -> (model, fsdp)
    MoE experts  (E, d, ff)  -> (None, fsdp, model)   [gathered per layer]
    norms/scalars            -> replicated
    optimizer moments        -> same spec as their param
Stacked (scan) leaves get leading ``None``s for the stack dims.

Entry points: ``param_specs`` (params), ``opt_state_specs`` (optimizer
moments, derived from the param specs so ZeRO-style co-sharding holds),
``batch_specs`` (leading dim over the batch axes) and ``cache_specs``
(decode caches).  ``launch/specs.state_shardings`` assembles them into the
full train-state ``NamedSharding`` tree.

The resilience layer consumes these specs DOWNSTREAM of ``device_put``
rather than importing this module: ``kernels/digest.sharded_plan_for``
reads each live leaf's ``NamedSharding`` (produced from the specs built
here) to derive its shard-local digest layout, and micro-snapshots record
per-shard slice maps from the same shardings.  That makes this module's
guard behaviour load-bearing for detection too: whatever layout the specs
choose — sharded or guard-replicated — the canary digests exactly the
bytes each device actually owns, so spec changes here never need matching
changes in the detection/recovery stack (DESIGN.md §5).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distributed.context import DistContext

# weight names whose *output* (last) dim shards over the model axis
_OUT_MODEL = {"wq", "wk", "wv", "gate", "up", "in_proj", "w_up", "head",
              "src_proj", "patch_proj", "in_fuse"}
# weight names whose *input* (first logical) dim shards over the model axis
_IN_MODEL = {"wo", "down", "out_proj"}
# per-head vectors that shard over model when divisible
_HEAD_VECS = {"A_log", "D", "dt_bias"}


def _axis_size(ctx: DistContext, axes) -> int:
    if not ctx.enabled:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    # an axis the mesh doesn't have counts as size 1 (=> the guard
    # replicates): a pure data-parallel mesh ("--mesh 4") simply has no
    # "model" axis, and every TP rule must degrade to replication
    return int(np.prod([ctx.mesh.shape.get(a, 1) for a in axes]))


def _guard(ctx: DistContext, dim: int, axes) -> Optional[object]:
    """Return axes if dim divides the axes' total size, else None.
    Axes the mesh doesn't have are dropped first (pure-DP meshes carry
    no "model" axis), so a returned spec never names a missing axis."""
    if axes is None:
        return None
    if ctx.enabled:
        names = (axes,) if isinstance(axes, str) else tuple(axes)
        names = tuple(a for a in names if a in ctx.mesh.shape)
        if not names:
            return None
        axes = names[0] if isinstance(axes, str) else names
    size = _axis_size(ctx, axes)
    return axes if (size > 1 and dim % size == 0) else None


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "idx"):
            names.append(f"[{k.idx}]")
        else:
            names.append(str(k))
    return tuple(names)


def _logical_rank(names: Tuple[str, ...], shape) -> int:
    """How many trailing dims are the 'logical' weight dims (the rest are
    scan-stacking dims).  Heuristic: biases/norm scales are rank-1 vectors;
    matrices rank-2; conv weights (K, C) rank-2; MoE experts / lora / sLSTM-r
    rank-3."""
    leaf = names[-1]
    parent = names[-2] if len(names) >= 2 else ""
    gparent = names[-3] if len(names) >= 3 else ""
    if leaf in ("scale", "b", "conv_b", "skip", "A_log", "D", "dt_bias"):
        return 1
    if leaf in ("q", "m"):  # int8 moment payload (blocks, QBLOCK) / mlstm m
        return 2
    if leaf in ("gate", "up", "down") and parent == "ffn" and len(shape) >= 3:
        return 3  # raw MoE expert stacks (E, d, ff)
    if leaf == "r":
        return 3  # sLSTM recurrent (H, Dh, 4Dh)
    if leaf in ("a", "b") and parent in ("wq", "wk", "wv", "wo", "gate",
                                         "up", "down"):
        return 2  # lora factors
    if leaf in ("w", "table", "conv_w"):
        return 2
    return min(2, len(shape))


def spec_for_param(ctx: DistContext, path, leaf, sharding_plan,
                   model_cfg=None) -> P:
    names = _path_names(path)
    shape = leaf.shape
    fsdp_axes = ctx.batch_axes if (sharding_plan.fsdp and ctx.enabled) else None
    model = ctx.model_axis if ctx.enabled else None

    # Attention projections shard over *whole heads*: a model axis that does
    # not divide the head count must not slice head_dim (the contraction dim
    # of QK^T) — GSPMD would otherwise emit partial-sum all-reduces of the
    # full (B,H,Sq,Sk) score tensor.  Heads that don't divide => replicate.
    if model_cfg is not None and ctx.enabled and len(names) >= 2 \
            and names[-2] in ("wq", "wk", "wv", "wo") and "attn" in names:
        tp = ctx.tp_size
        heads = model_cfg.n_kv_heads if names[-2] in ("wk", "wv") \
            else model_cfg.n_heads
        if heads % tp != 0:
            model = None

    lr = _logical_rank(names, shape)
    lead = (None,) * (len(shape) - lr)
    logical = shape[len(shape) - lr:]
    leaf_name = names[-1]
    parent = names[-2] if len(names) >= 2 else ""
    gparent = names[-3] if len(names) >= 3 else ""

    def spec(*dims):
        return P(*(lead + dims))

    # ---- MoE expert stacks (E, d, ff) / (E, ff, d) -------------------------
    ep = (sharding_plan.expert_parallel and ctx.enabled
          and logical and logical[0] % _axis_size(ctx, model or ()) == 0
          if lr == 3 and parent == "ffn" else False)
    if lr == 3 and leaf_name in ("gate", "up") and parent == "ffn":
        if ep:  # experts over model, d over data (EP storage layout)
            return spec(_guard(ctx, logical[0], model),
                        _guard(ctx, logical[1], fsdp_axes), None)
        return spec(None, _guard(ctx, logical[1], fsdp_axes),
                    _guard(ctx, logical[2], model))
    if lr == 3 and leaf_name == "down" and parent == "ffn":
        if ep:
            return spec(_guard(ctx, logical[0], model), None,
                        _guard(ctx, logical[2], fsdp_axes))
        return spec(None, _guard(ctx, logical[1], model),
                    _guard(ctx, logical[2], fsdp_axes))
    if leaf_name == "r":
        return spec(_guard(ctx, logical[0], model), None, None)

    # ---- embeddings --------------------------------------------------------
    if leaf_name == "table":
        return spec(_guard(ctx, logical[0], model),
                    _guard(ctx, logical[1], fsdp_axes))

    # ---- router (keep replicated: fp32, tiny, read every step) -------------
    if parent == "router" or gparent == "router":
        return spec(*([None] * lr))

    # ---- lora factors -------------------------------------------------------
    if leaf_name == "a" and parent in _OUT_MODEL | _IN_MODEL:
        return spec(_guard(ctx, logical[0],
                           model if parent in _IN_MODEL else fsdp_axes), None)
    if leaf_name == "b" and parent in _OUT_MODEL | _IN_MODEL and lr == 2 \
            and parent not in ("",):
        return spec(None, _guard(ctx, logical[1],
                                 fsdp_axes if parent in _IN_MODEL else model))

    # ---- dense weights ------------------------------------------------------
    if leaf_name == "w" or (leaf_name == "q" and False):
        owner = parent
        if owner in _OUT_MODEL:
            return spec(_guard(ctx, logical[0], fsdp_axes),
                        _guard(ctx, logical[1], model))
        if owner in _IN_MODEL:
            return spec(_guard(ctx, logical[0], model),
                        _guard(ctx, logical[1], fsdp_axes))
        if owner in ("gates", "w"):  # xlstm gate proj / slstm w
            return spec(_guard(ctx, logical[0], fsdp_axes),
                        _guard(ctx, logical[1], model))
        return spec(*([None] * lr))

    # ---- biases -------------------------------------------------------------
    if leaf_name == "b":
        owner = parent
        if owner in _OUT_MODEL or owner in ("gates", "w"):
            return spec(_guard(ctx, logical[0], model))
        return spec(None)

    # ---- convs / per-head vectors -------------------------------------------
    if leaf_name == "conv_w":
        return spec(None, _guard(ctx, logical[1], model))
    if leaf_name == "conv_b":
        return spec(_guard(ctx, logical[0], model))
    if leaf_name in _HEAD_VECS:
        return spec(_guard(ctx, logical[0], model))
    if leaf_name == "skip":
        return spec(_guard(ctx, logical[0], model))

    # ---- int8 moment payloads ------------------------------------------------
    if leaf_name in ("q", "scale") and len(shape) >= 2 and parent not in (
            "attn", "ffn"):
        return P(*([None] * len(shape)))

    # default: replicate (norm scales etc.)
    return P(*([None] * len(shape)))


def param_specs(ctx: DistContext, params, sharding_plan, model_cfg=None):
    """PartitionSpec pytree for a param tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec_for_param(ctx, path, leaf, sharding_plan,
                                          model_cfg),
        params)


def opt_state_specs(ctx: DistContext, params, pspecs, train_plan):
    """Optimizer-state specs derived from the param specs.

    * AdamW fp32/bf16 moments: identical tree -> identical specs (ZeRO).
    * AdamW int8 moments: (nblocks, QBLOCK) payloads -> shard blocks over the
      fsdp axes when divisible, else replicate.
    * Adafactor: vr drops the last dim's spec entry, vc drops the
      second-to-last (factored stats follow their surviving dims).
    * Optimizer-owned induction scalars (``t``, bias corrections / decay)
      are replicated like the ``iv`` block — they're repaired via the
      opt-IV Eq. (1) path, not patched.
    """
    if train_plan.optimizer == "adafactor":
        def fact(p, s):
            dims = tuple(s) + (None,) * (p.ndim - len(tuple(s)))
            if p.ndim >= 2:
                return {"vr": P(*dims[:-1]),
                        "vc": P(*(dims[:-2] + dims[-1:]))}
            return {"v": P(*dims)}
        return {"stats": jax.tree_util.tree_map(fact, params, pspecs),
                "t": P(), "beta2": P()}

    adamw_iv = {"t": P(), "bc1": P(), "bc2": P()}
    if train_plan.moment_dtype == "int8":
        def q8spec(p, s):
            del s
            return {"q": P(None, None), "scale": P(None, None)}
        one = jax.tree_util.tree_map(q8spec, params, pspecs)
        return {"m": one, "v": one, **adamw_iv}

    return {"m": pspecs, "v": pspecs, **adamw_iv}


def batch_specs(ctx: DistContext, batch):
    """Batch arrays shard their leading (batch) dim over the batch axes."""
    def spec(leaf):
        if leaf.ndim == 0:
            return P()
        b = leaf.shape[0]
        ax = _guard(ctx, b, ctx.batch_axes)
        return P(*((ax,) + (None,) * (leaf.ndim - 1)))
    return jax.tree_util.tree_map(spec, batch)


def cache_specs(ctx: DistContext, cache):
    """Decode caches: shard batch dim over data axes when divisible; shard
    the sequence (capacity) dim over model (SP) — KV heads rarely divide a
    16-wide model axis, the sequence always does."""
    def spec(path, leaf):
        names = _path_names(path)
        if leaf.ndim == 0:
            return P()
        if names[-1] in ("k", "v", "mem_k", "mem_v") and leaf.ndim >= 4:
            # (count?, B, S, KV, D) or (L, B, S, KV, D)
            lead = leaf.ndim - 4
            B, S = leaf.shape[lead], leaf.shape[lead + 1]
            baxis = _guard(ctx, B, ctx.batch_axes)
            saxis = _guard(ctx, S, ctx.model_axis)
            if baxis is None and ctx.enabled:
                # B=1 long-context: shard S over data too
                saxis = _guard(ctx, S, ctx.batch_axes + (ctx.model_axis,))
            return P(*((None,) * lead + (baxis, saxis, None, None)))
        # ssm/conv/mlstm states: (count?, B, ...) -> batch over data;
        # dim0 is the scan-stack dim when dim1 divides the batch axes.
        if leaf.ndim >= 2:
            b0 = _guard(ctx, leaf.shape[0], ctx.batch_axes)
            b1 = _guard(ctx, leaf.shape[1], ctx.batch_axes)
            if b1 is not None:
                return P(*((None, b1) + (None,) * (leaf.ndim - 2)))
            if b0 is not None:
                return P(*((b0,) + (None,) * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))
    return jax.tree_util.tree_map_with_path(spec, cache)
