from repro.train.loop import (  # noqa: F401
    make_train_state,
    make_train_step,
    make_prefill_step,
    make_decode_step,
)
