"""Train/serve step builders and the TrainState.

TrainState = {
    'params': model params,
    'opt':    optimizer state (sharded like params),
    'iv':     induction-variable block — the IterPro-protected loop state,
}

The ``iv`` block is the heart of the paper adaptation: each counter is
updated *independently* (``x += s_x``) rather than derived from ``step`` —
the Independent Compute Promotion (ICP) pass of the paper, applied to the
training loop.  Because every counter is an affine function of the iteration
index with known (init, step) — registered in ``core/induction.py`` — any
single corrupted counter is recoverable from any healthy partner via the
paper's Eq. (1).

The optimizer state carries its OWN induction block to the same end: the
step counter ``opt/t`` advances by its own ``+1`` inside ``opt.update``
(never derived from ``iv/sched_pos``, so the two are independent Eq. (1)
partners), and the bias-correction/decay scalars stored next to it are pure
functions of ``t`` that the opt-IV rung recomputes from the consensus
iteration (``core/icp.promote`` exports both under full leaf paths).
"""

from __future__ import annotations

from functools import partial
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.registry import get_model
from repro.optim import make_optimizer


def iv_step_sizes(arch_cfg, global_batch: int) -> Dict[str, int]:
    """Per-IV (name -> step size); init values are all 0."""
    n_micro = max(arch_cfg.train.microbatch, 1)
    return {
        "step": 1,
        "data_offset": global_batch,   # sequences consumed
        "rng_counter": 1,
        "sched_pos": 1,
        "micro_count": n_micro,
    }


def init_iv(arch_cfg, global_batch: int) -> Dict[str, jnp.ndarray]:
    return {name: jnp.int32(0) for name in iv_step_sizes(arch_cfg,
                                                         global_batch)}


def advance_iv(iv, steps: Dict[str, int]):
    """ICP: each counter advances by its own literal increment — no counter
    is derived from another, so they are independent recovery partners."""
    return {name: iv[name] + jnp.int32(steps[name]) for name in steps}


def make_train_state(arch_cfg, key, global_batch: int = 0,
                     total_steps: int = 100_000):
    model = get_model(arch_cfg.model)
    opt = make_optimizer(arch_cfg.train, total_steps)
    params = model.init(arch_cfg.model, key)
    return {
        "params": params,
        "opt": opt.init(params),
        "iv": init_iv(arch_cfg, global_batch or 256),
    }


def make_train_step(arch_cfg, ctx=None, global_batch: int = 0,
                    total_steps: int = 100_000) -> Callable:
    """Returns step(state, batch) -> (state', metrics). jit/pjit-ready."""
    model = get_model(arch_cfg.model)
    mcfg = arch_cfg.model
    tp = arch_cfg.train
    opt = make_optimizer(tp, total_steps)
    remat = tp.remat != "none"
    steps = iv_step_sizes(arch_cfg, global_batch or 256)
    acc_dtype = jnp.dtype(tp.grad_reduce_dtype)

    def loss_fn(params, batch):
        loss, metrics = model.train_loss(params, mcfg, batch, ctx,
                                         remat=remat)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        n_micro = tp.microbatch

        if n_micro and n_micro > 1:
            def reshape(a):
                B = a.shape[0]
                assert B % n_micro == 0, (B, n_micro)
                return a.reshape((n_micro, B // n_micro) + a.shape[1:])

            mbatch = jax.tree_util.tree_map(reshape, batch)
            gacc0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, acc_dtype), params)

            def micro(carry, mbb):
                gacc, lsum = carry
                (loss, _), grads = grad_fn(params, mbb)
                gacc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(acc_dtype), gacc, grads)
                return (gacc, lsum + loss), None

            (grads, lsum), _ = jax.lax.scan(
                micro, (gacc0, jnp.float32(0.0)), mbatch)
            grads = jax.tree_util.tree_map(lambda g: g / n_micro, grads)
            loss = lsum / n_micro
            metrics = {}
        else:
            (loss, metrics), grads = grad_fn(params, batch)

        new_params, new_opt, stats = opt.update(
            grads, state["opt"], params, state["iv"]["sched_pos"])
        new_state = {
            "params": new_params,
            "opt": new_opt,
            "iv": advance_iv(state["iv"], steps),
        }
        out = {"loss": loss, **stats}
        if isinstance(metrics, dict):
            out.update({k: v for k, v in metrics.items()
                        if isinstance(v, jnp.ndarray) or jnp.isscalar(v)})
        return new_state, out

    return train_step


def pin_state_shardings(step_fn: Callable, shardings) -> Callable:
    """Wrap ``step_fn(state, *args) -> (new_state, aux)`` so the output
    state is sharding-constrained to ``shardings`` (the canonical
    ``launch/specs.state_shardings`` tree).

    Mesh loops need this pin: GSPMD is free to pick different output
    shardings than the inputs for some leaves (it does, e.g. for norm
    scales), which would reshard the state a little every step, defeat
    donation's in-place buffer reuse (donor and output layouts must
    match), and hand the shard-local canary a state whose layout drifts
    from the one its digest plan was built for.  With the pin the state's
    layout is a per-step invariant.

    The wrapper records its unpinned original (``fn.unpinned_step``) so
    the elastic remesh path can re-pin the SAME step against a degraded
    mesh's shardings instead of stacking a stale constraint under the
    fresh one (``launch/specs.bind_state`` unwraps before pinning)."""
    def fn(state, *args):
        new_state, aux = step_fn(state, *args)
        new_state = jax.tree_util.tree_map(
            jax.lax.with_sharding_constraint, new_state, shardings)
        return new_state, aux

    fn.unpinned_step = getattr(step_fn, "unpinned_step", step_fn)
    fn.pinned_shardings = shardings
    return fn


def make_prefill_step(arch_cfg, ctx=None, max_len: Optional[int] = None):
    model = get_model(arch_cfg.model)
    mcfg = arch_cfg.model

    def prefill_step(params, batch):
        return model.prefill(params, mcfg, batch, ctx, max_len=max_len)

    return prefill_step


def make_decode_step(arch_cfg, ctx=None):
    model = get_model(arch_cfg.model)
    mcfg = arch_cfg.model

    def decode_step(params, cache, token):
        return model.decode_step(params, mcfg, cache, token, ctx)

    return decode_step
