"""grok-1-314b — 8-expert top-2 MoE [hf:xai-org/grok-1; unverified].

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072, MoE 8e top-2.
8 experts < 16 model shards => MoE uses the TP path (per-expert ff sharded
over 'model' with ragged grouped matmul) rather than a2a EP.  Adafactor +
bf16 params + ZeRO-3 to fit 16 GB/chip.  Full attention => long_500k skipped.
"""

from repro.configs.base import ArchConfig, ModelConfig, ShardingPlan, TrainPlan

CONFIG = ArchConfig(
    arch_id="grok-1-314b",
    source="hf:xai-org/grok-1; unverified",
    model=ModelConfig(
        family="moe",
        n_layers=64,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=32768,               # dense-equivalent width; experts use moe_d_ff
        vocab_size=131072,
        head_dim=128,
        n_experts=8,
        top_k=2,
        moe_d_ff=32768,
        moe_impl="tp_ragged",
        attn_softcap=30.0,        # grok tanh logit capping
        logit_softcap=30.0,
    ),
    sharding=ShardingPlan(fsdp=True, tensor_parallel=True, expert_parallel=False),
    train=TrainPlan(optimizer="adafactor", microbatch=8, remat="layer",
                    moment_dtype="bfloat16"),
)
