"""Architecture registry: ``get_config(arch_id)`` / ``list_archs()``.

Every assigned architecture plus the paper-representative workload.  Smoke
variants are derived with ``get_config(id).smoke()``.
"""

from repro.configs.base import (
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPES_BY_NAME,
    TRAIN_4K,
    ArchConfig,
    ModelConfig,
    ShapeSpec,
    ShardingPlan,
    TrainPlan,
)

from repro.configs.xlstm_350m import CONFIG as _XLSTM_350M
from repro.configs.command_r_35b import CONFIG as _COMMAND_R_35B
from repro.configs.h2o_danube_1_8b import CONFIG as _H2O_DANUBE_18B
from repro.configs.gemma3_1b import CONFIG as _GEMMA3_1B
from repro.configs.gemma3_27b import CONFIG as _GEMMA3_27B
from repro.configs.seamless_m4t_large_v2 import CONFIG as _SEAMLESS_M4T
from repro.configs.qwen2_vl_7b import CONFIG as _QWEN2_VL_7B
from repro.configs.zamba2_7b import CONFIG as _ZAMBA2_7B
from repro.configs.grok_1_314b import CONFIG as _GROK_1_314B
from repro.configs.kimi_k2_1t_a32b import CONFIG as _KIMI_K2
from repro.configs.iterpro_100m import CONFIG as _ITERPRO_100M

_REGISTRY = {
    c.arch_id: c
    for c in (
        _XLSTM_350M,
        _COMMAND_R_35B,
        _H2O_DANUBE_18B,
        _GEMMA3_1B,
        _GEMMA3_27B,
        _SEAMLESS_M4T,
        _QWEN2_VL_7B,
        _ZAMBA2_7B,
        _GROK_1_314B,
        _KIMI_K2,
        _ITERPRO_100M,
    )
}

ASSIGNED_ARCHS = tuple(a for a in _REGISTRY if a != "iterpro-100m")


def list_archs(include_paper: bool = True):
    return tuple(_REGISTRY) if include_paper else ASSIGNED_ARCHS


def get_config(arch_id: str) -> ArchConfig:
    if arch_id.endswith("-smoke"):
        return _REGISTRY[arch_id[: -len("-smoke")]].smoke()
    return _REGISTRY[arch_id]


def get_shape(name: str) -> ShapeSpec:
    return SHAPES_BY_NAME[name]
