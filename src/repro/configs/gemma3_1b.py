"""gemma3-1b — 5:1 local:global attention, 128k context [hf:google/gemma-3-1b-pt].

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144.  Five sliding-window
(1024) layers per one global layer; RoPE theta 1M on global layers; qk-norm;
attention-logit softcap.  Treated as sub-quadratic => long_500k runs.
"""

from repro.configs.base import ArchConfig, ModelConfig, ShardingPlan, TrainPlan

CONFIG = ArchConfig(
    arch_id="gemma3-1b",
    source="hf:google/gemma-3-1b-pt; unverified",
    model=ModelConfig(
        family="dense",
        n_layers=26,
        d_model=1152,
        n_heads=4,
        n_kv_heads=1,
        d_ff=6912,
        vocab_size=262144,
        head_dim=256,
        rope_theta=1_000_000.0,
        local_window=1024,
        local_global_ratio=5,
        qk_norm=True,
        logit_softcap=30.0,
        tie_embeddings=True,
        max_position=131_072,
        sandwich_norm=True,
    ),
    sharding=ShardingPlan(fsdp=False, tensor_parallel=True),
    train=TrainPlan(optimizer="adamw", microbatch=0, remat="layer"),
)
