"""zamba2-7b — Mamba2 + shared attention blocks [arXiv:2411.15242; unverified].

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000, ssm_state=64.
Hybrid: Mamba2 (SSD) blocks with one *shared* full-attention block invoked
every 6th position (per-invocation LoRA deltas on the shared weights, the
Zamba2 trick).  SSM recurrent state => long_500k runs.
"""

from repro.configs.base import ArchConfig, ModelConfig, ShardingPlan, TrainPlan

CONFIG = ArchConfig(
    arch_id="zamba2-7b",
    source="arXiv:2411.15242; unverified",
    model=ModelConfig(
        family="hybrid",
        n_layers=81,
        d_model=3584,
        n_heads=32,
        n_kv_heads=32,
        d_ff=14336,
        vocab_size=32000,
        head_dim=112,
        ssm_state=64,
        ssm_expand=2,
        ssm_conv=4,
        ssm_heads=64,             # mamba2 heads: d_inner / 112
        hybrid_ratio=5,           # 5 mamba blocks per shared-attn invocation
        shared_attn=True,
        shared_attn_lora_rank=128,
    ),
    sharding=ShardingPlan(fsdp=True, tensor_parallel=True),
    train=TrainPlan(optimizer="adamw", microbatch=8, remat="layer"),
)
