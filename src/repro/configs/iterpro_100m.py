"""iterpro-100m — the paper-representative workload.

The IterPro paper evaluates on HPC mini-apps (GTC-P, HPCCG, CoMD, miniMD,
NPB); its *technique* protects long-running iterative loops.  In this
framework the protected loop is LM training, so the paper-representative
config is a ~100M-parameter dense decoder used for the end-to-end
fault-injection campaign (benchmarks reproducing Tables 3-6 / Figs 7-10) and
for the examples/fault_tolerant_training.py driver.
"""

from repro.configs.base import ArchConfig, ModelConfig, ShardingPlan, TrainPlan

CONFIG = ArchConfig(
    arch_id="iterpro-100m",
    source="paper-representative workload (this work)",
    model=ModelConfig(
        family="dense",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=4,
        d_ff=2048,
        vocab_size=32000,
        head_dim=64,
        tie_embeddings=True,
        param_dtype="float32",
        compute_dtype="float32",
    ),
    sharding=ShardingPlan(fsdp=False, tensor_parallel=True),
    train=TrainPlan(optimizer="adamw", learning_rate=6e-4, microbatch=0,
                    remat="none"),
)
