"""seamless-m4t-large-v2 — enc-dec, multimodal [arXiv:2308.11596; hf].

24L d_model=1024 16H (GQA kv=16) d_ff=8192 vocab=256206.  Encoder-decoder:
24 encoder + 24 decoder layers.  The audio frontend (conformer feature
extractor) is a STUB per the task spec: input_specs() provides precomputed
frame embeddings (B, T_src, frontend_dim).  Full attention => long_500k
skipped; decode shapes run against the decoder with cross-attention.
"""

from repro.configs.base import ArchConfig, ModelConfig, ShardingPlan, TrainPlan

CONFIG = ArchConfig(
    arch_id="seamless-m4t-large-v2",
    source="arXiv:2308.11596; hf",
    model=ModelConfig(
        family="encdec",
        n_layers=24,              # decoder depth
        n_enc_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=8192,
        vocab_size=256206,
        head_dim=64,
        frontend_dim=1024,        # stubbed audio frame-embedding width
        use_bias=True,
    ),
    sharding=ShardingPlan(fsdp=False, tensor_parallel=True),
    train=TrainPlan(optimizer="adamw", microbatch=0, remat="layer"),
)
