"""qwen2-vl-7b — M-RoPE, dynamic resolution VLM [arXiv:2409.12191; hf].

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.  Backbone only per
the task spec: the vision tower is a STUB — input_specs() provides
precomputed patch embeddings (B, n_patches, patch_dim) which are projected
and prepended to the token stream.  M-RoPE (temporal/height/width split
rotary) is implemented on the backbone.  Full attention => long_500k skipped.
"""

from repro.configs.base import ArchConfig, ModelConfig, ShardingPlan, TrainPlan

CONFIG = ArchConfig(
    arch_id="qwen2-vl-7b",
    source="arXiv:2409.12191; hf",
    model=ModelConfig(
        family="vlm",
        n_layers=28,
        d_model=3584,
        n_heads=28,
        n_kv_heads=4,
        d_ff=18944,
        vocab_size=152064,
        head_dim=128,
        rope_theta=1_000_000.0,
        m_rope=True,
        patch_dim=1280,           # stubbed vision-tower output width
        use_bias=True,            # qwen QKV bias
    ),
    sharding=ShardingPlan(fsdp=True, tensor_parallel=True),
    train=TrainPlan(optimizer="adamw", microbatch=8, remat="layer"),
)
