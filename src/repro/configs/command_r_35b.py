"""command-r-35b — GQA, no-bias dense LM [hf:CohereForAI/c4ai-command-r-v01].

40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000.  Pure full attention
=> long_500k is skipped (see DESIGN.md §Arch-applicability).
"""

from repro.configs.base import ArchConfig, ModelConfig, ShardingPlan, TrainPlan

CONFIG = ArchConfig(
    arch_id="command-r-35b",
    source="hf:CohereForAI/c4ai-command-r-v01; unverified",
    model=ModelConfig(
        family="dense",
        n_layers=40,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22528,
        vocab_size=256000,
        head_dim=128,
        rope_theta=8e6,
        use_bias=False,
        tie_embeddings=True,
        parallel_block=True,
    ),
    sharding=ShardingPlan(fsdp=True, tensor_parallel=True),
    train=TrainPlan(optimizer="adamw", microbatch=8, remat="layer",
                    moment_dtype="float32"),
)
