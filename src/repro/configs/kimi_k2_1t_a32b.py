"""kimi-k2-1t-a32b — trillion-param MoE, 384e top-8 [arXiv:2501.kimi2; unverified].

61L d_model=7168 64H (GQA kv=8) d_ff=2048 vocab=163840, MoE 384e top-8.
d_ff=2048 is the per-expert hidden dim (DeepSeek-V3-style fine-grained
experts) plus one shared expert; first layer dense.  384 experts = 24 per
model shard => a2a expert parallelism over 'model', ZeRO-3 over 'data',
Adafactor with bf16 factored moments — the only recipe that fits 16 GB/chip
at 1T params on a 256-chip pod.  Full attention => long_500k skipped.
"""

from repro.configs.base import ArchConfig, ModelConfig, ShardingPlan, TrainPlan

CONFIG = ArchConfig(
    arch_id="kimi-k2-1t-a32b",
    source="arXiv:2501.kimi2; unverified",
    model=ModelConfig(
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=64,
        n_kv_heads=8,
        d_ff=18432,               # dense layers / shared-expert path width
        vocab_size=163840,
        head_dim=112,
        n_experts=384,
        top_k=8,
        n_shared_experts=1,
        moe_d_ff=2048,
        moe_impl="ep_a2a",
        first_dense_layers=1,
    ),
    sharding=ShardingPlan(fsdp=True, tensor_parallel=True, expert_parallel=True),
    train=TrainPlan(optimizer="adafactor", microbatch=8, remat="layer",
                    moment_dtype="bfloat16"),
)
