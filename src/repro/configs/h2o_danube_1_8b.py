"""h2o-danube-1.8b — llama+mistral mix, SWA [arXiv:2401.16818; hf].

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000.  Sliding-window
attention (mistral-style, window 4096) on every layer => KV state is bounded
=> long_500k runs.
"""

from repro.configs.base import ArchConfig, ModelConfig, ShardingPlan, TrainPlan

CONFIG = ArchConfig(
    arch_id="h2o-danube-1.8b",
    source="arXiv:2401.16818; hf",
    model=ModelConfig(
        family="dense",
        n_layers=24,
        d_model=2560,
        n_heads=32,
        n_kv_heads=8,
        d_ff=6912,
        vocab_size=32000,
        head_dim=80,
        rope_theta=10_000.0,
        sliding_window=4096,
    ),
    sharding=ShardingPlan(fsdp=False, tensor_parallel=True),
    train=TrainPlan(optimizer="adamw", microbatch=8, remat="layer"),
)
