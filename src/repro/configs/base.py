"""Configuration system for the IterPro-JAX framework.

Every assigned architecture is described by an :class:`ArchConfig` — a frozen
dataclass bundling the model hyper-parameters, the sharding plan and the
training plan.  Configs are *data*, not code: the model zoo consumes them, the
launcher selects them with ``--arch <id>`` and the dry-run iterates the
registry.

Shape sets (assigned per the task spec) live here too: each architecture is
paired with the four LM shapes; applicability rules (``long_500k`` requires
sub-quadratic attention, encoder-only models have no decode) are encoded as
config predicates rather than ad-hoc launcher logic.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeSpec:
    """One (seq_len, global_batch) workload cell.

    ``kind`` selects which program is lowered:
      * ``train``   -> train_step   (fwd+bwd+optimizer update)
      * ``prefill`` -> prefill_step (fwd, build KV/state cache)
      * ``decode``  -> serve_step   (one new token against a seq_len cache)
    """

    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'

    def __post_init__(self):
        assert self.kind in ("train", "prefill", "decode"), self.kind


TRAIN_4K = ShapeSpec("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524_288, 1, "decode")

ALL_SHAPES: Tuple[ShapeSpec, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


# ---------------------------------------------------------------------------
# Model hyper-parameters
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters (family-discriminated)."""

    family: str  # 'dense' | 'moe' | 'ssm' | 'hybrid' | 'encdec' | 'vlm'
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads

    # --- attention options -------------------------------------------------
    rope_theta: float = 10_000.0
    sliding_window: int = 0        # 0 -> full attention on every layer
    local_window: int = 0          # window used by 'local' layers in a mix
    local_global_ratio: int = 0    # e.g. 5 -> 5 local layers per 1 global
    logit_softcap: float = 0.0     # gemma-style final-logit soft capping
    attn_softcap: float = 0.0      # gemma-style attention-logit soft capping
    qk_norm: bool = False
    use_bias: bool = False
    tie_embeddings: bool = False
    m_rope: bool = False           # Qwen2-VL multimodal RoPE
    max_position: int = 131_072
    sandwich_norm: bool = False    # gemma3 pre+post norms around attn/ffn
    parallel_block: bool = False   # command-r parallel attn+ffn blocks

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0      # kimi-style always-on shared expert
    moe_d_ff: int = 0              # per-expert hidden dim (0 -> d_ff)
    moe_impl: str = "tp_ragged"    # 'tp_ragged' | 'ep_a2a'
    moe_capacity: float = 1.25     # GShard capacity factor (dispatch slack)
    first_dense_layers: int = 0    # kimi: first layer(s) stay dense

    # --- SSM / recurrent ---------------------------------------------------
    ssm_state: int = 0             # mamba2 state dim
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_heads: int = 0
    mlstm_ratio: int = 0           # xLSTM: m mLSTM blocks per 1 sLSTM block
    hybrid_ratio: int = 0          # zamba: ssm blocks per 1 (shared) attn block
    shared_attn: bool = False      # zamba2 shared attention block + per-use LoRA
    shared_attn_lora_rank: int = 0

    # --- encoder-decoder ---------------------------------------------------
    n_enc_layers: int = 0          # >0 -> enc-dec; n_layers is the decoder depth
    frontend_dim: int = 0          # stubbed modality frontend embedding width

    # --- vlm ---------------------------------------------------------------
    patch_dim: int = 0             # stubbed patch-embedding width

    # --- numerics ----------------------------------------------------------
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    norm_eps: float = 1e-6

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_subquadratic(self) -> bool:
        """True when a 500k-token decode has bounded (non-full) attention
        state on every full-attention layer, or no attention at all."""
        if self.family in ("ssm", "hybrid"):
            return True
        if self.sliding_window > 0:
            return True  # SWA on every layer
        if self.local_global_ratio > 0:
            # local:global mixes are treated as sub-quadratic (gemma3): local
            # layers bound their KV; the rare global layers decode linearly
            # against an SP-sharded KV cache.
            return True
        return False

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs are decoders or enc-dec


# ---------------------------------------------------------------------------
# Sharding / training plans
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShardingPlan:
    """How this architecture maps onto the (pod, data, model) mesh."""

    fsdp: bool = False             # ZeRO-3 shard params+opt over 'data'
    tensor_parallel: bool = True   # TP over 'model'
    expert_parallel: bool = False  # EP (a2a) over 'model' for MoE
    sequence_parallel_kv: bool = True  # shard KV cache over 'model' at decode
    pipeline_stages: int = 1       # >1 -> PP over the 'pod' axis
    shard_vocab: bool = True


@dataclass(frozen=True)
class TrainPlan:
    optimizer: str = "adamw"       # 'adamw' | 'adafactor'
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    microbatch: int = 0            # 0 -> no gradient accumulation
    remat: str = "layer"           # 'none' | 'layer' | 'full'
    grad_reduce_dtype: str = "bfloat16"   # gradient-compression for the DP reduce
    moment_dtype: str = "float32"  # 'float32' | 'bfloat16' | 'int8'


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    source: str                    # provenance tag from the assignment table
    model: ModelConfig
    sharding: ShardingPlan = field(default_factory=ShardingPlan)
    train: TrainPlan = field(default_factory=TrainPlan)

    def shapes(self) -> Tuple[ShapeSpec, ...]:
        """The shape cells this architecture actually runs (skips encoded)."""
        out = []
        for s in ALL_SHAPES:
            if s.name == "long_500k" and not self.model.is_subquadratic:
                continue  # full-attention skip (recorded in DESIGN.md)
            out.append(s)
        return tuple(out)

    def skipped_shapes(self) -> Tuple[str, ...]:
        have = {s.name for s in self.shapes()}
        return tuple(s.name for s in ALL_SHAPES if s.name not in have)

    def with_overrides(self, **kw) -> "ArchConfig":
        return replace(self, **kw)

    # -- reduced config for CPU smoke tests ---------------------------------
    def smoke(self) -> "ArchConfig":
        m = self.model
        kv = min(m.n_kv_heads, 2) or 1
        heads = max(2, kv)
        updates = dict(
            n_layers=max(2, min(4, (m.local_global_ratio + 1) if m.local_global_ratio else 2)),
            d_model=64,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=32,
            d_ff=128 if m.d_ff else 0,
            vocab_size=256,
            max_position=512,
            param_dtype="float32",
            compute_dtype="float32",
        )
        if m.n_experts:
            updates.update(n_experts=min(m.n_experts, 4), top_k=min(m.top_k, 2),
                           moe_d_ff=64, first_dense_layers=min(m.first_dense_layers, 1))
        if m.ssm_state:
            updates.update(ssm_state=16, ssm_heads=4)
        if m.n_enc_layers:
            updates.update(n_enc_layers=2, frontend_dim=32)
        if m.patch_dim:
            updates.update(patch_dim=32)
        if m.sliding_window:
            updates.update(sliding_window=64)
        if m.local_window:
            updates.update(local_window=64)
        sm = replace(m, **updates)
        tp = replace(self.train, microbatch=0, remat="none")
        return ArchConfig(arch_id=self.arch_id + "-smoke", source=self.source,
                          model=sm, sharding=self.sharding, train=tp)


def asdict(cfg: ArchConfig) -> dict:
    return dataclasses.asdict(cfg)
