"""gemma3-27b — 5:1 local:global attention, 128k context [hf:google/gemma-3-1b-pt].

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144.
"""

from repro.configs.base import ArchConfig, ModelConfig, ShardingPlan, TrainPlan

CONFIG = ArchConfig(
    arch_id="gemma3-27b",
    source="hf:google/gemma-3-1b-pt; unverified",
    model=ModelConfig(
        family="dense",
        n_layers=62,
        d_model=5376,
        n_heads=32,
        n_kv_heads=16,
        d_ff=21504,
        vocab_size=262144,
        head_dim=128,
        rope_theta=1_000_000.0,
        local_window=1024,
        local_global_ratio=5,
        qk_norm=True,
        logit_softcap=30.0,
        tie_embeddings=True,
        max_position=131_072,
        sandwich_norm=True,
    ),
    sharding=ShardingPlan(fsdp=True, tensor_parallel=True),
    train=TrainPlan(optimizer="adamw", microbatch=8, remat="layer"),
)
