"""xlstm-350m — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

24L d_model=1024 4H (GQA kv=4) d_ff=0 vocab=50304.  d_ff=0 means the blocks
carry their own up/down projections (mLSTM projection factor 2, sLSTM 4/3
gated FFN) rather than a separate transformer FFN.  Block mix follows the
paper's xLSTM[7:1] recipe: 7 mLSTM blocks per 1 sLSTM block.
"""

from repro.configs.base import ArchConfig, ModelConfig, ShardingPlan, TrainPlan

CONFIG = ArchConfig(
    arch_id="xlstm-350m",
    source="arXiv:2405.04517; unverified",
    model=ModelConfig(
        family="ssm",
        n_layers=24,
        d_model=1024,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        head_dim=256,
        mlstm_ratio=7,          # xLSTM[7:1]
        ssm_expand=2,
        ssm_conv=4,
    ),
    sharding=ShardingPlan(fsdp=False, tensor_parallel=True),
    train=TrainPlan(optimizer="adamw", microbatch=0, remat="layer"),
)
