"""Disk checkpointing — the classic C/R baseline the paper measures against,
built properly so the comparison is fair:

* **async**: serialisation happens on a background thread off the step path
  (the step only pays one device->host copy);
* **double-buffered**: writes alternate between two slots and commit by
  atomic manifest rename — a crash mid-write never destroys the previous
  good checkpoint;
* **digest-verified**: every leaf's Fletcher digest is stored in the
  manifest and re-checked on load (a rotted checkpoint must not silently
  restore — the same exact-or-abort rule the recovery ladder uses).

Format: one ``.npz`` per slot (leaf-path keys) + ``manifest.json``
(step, slot, digests, dtypes).  bfloat16 leaves are stored as uint16 views
(npz has no bf16) and restored bit-exactly.
"""

from __future__ import annotations

import json
import os
import tempfile
import threading
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops

_MANIFEST = "manifest.json"


# ---------------------------------------------------------------------------
# (de)serialisation helpers
# ---------------------------------------------------------------------------

def _flatten(state) -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}

    def visit(path, leaf):
        out[kops.leaf_key(path)] = np.asarray(leaf)
        return leaf

    jax.tree_util.tree_map_with_path(visit, state)
    return out


def _store_view(arr: np.ndarray) -> Tuple[np.ndarray, str]:
    """npz-compatible view + the original dtype name."""
    dt = str(arr.dtype)
    if dt == "bfloat16":
        return arr.view(np.uint16), dt
    return arr, dt


def _restore_view(arr: np.ndarray, dtype: str) -> np.ndarray:
    if dtype == "bfloat16":
        return arr.view(jnp.bfloat16.dtype)
    return arr


def _unflatten(like_state, leaves: Dict[str, np.ndarray]):
    def visit(path, leaf):
        key = kops.leaf_key(path)
        arr = leaves[key]
        return arr.reshape(np.shape(leaf))

    return jax.tree_util.tree_map_with_path(visit, like_state)


# ---------------------------------------------------------------------------
# save / load
# ---------------------------------------------------------------------------

def save_checkpoint(directory: str, state, step: int, *, slot: int = 0) -> str:
    """Write ``state`` into ``directory/slot{slot}.npz`` and commit the
    manifest atomically.  Returns the manifest path."""
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(state)
    digests = {k: [int(x) for x in np.asarray(kops.checksum(v))]
               for k, v in flat.items()}
    views, dtypes = {}, {}
    for k, v in flat.items():
        view, dt = _store_view(v)
        views[k] = view
        dtypes[k] = dt

    payload = os.path.join(directory, f"slot{slot}.npz")
    tmp = payload + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **views)
    os.replace(tmp, payload)

    manifest = {
        "step": int(step),
        "slot": int(slot),
        "payload": os.path.basename(payload),
        "wall": time.time(),
        "digests": digests,
        "dtypes": dtypes,
    }
    mpath = os.path.join(directory, _MANIFEST)
    fd, tmpm = tempfile.mkstemp(dir=directory, suffix=".json.tmp")
    with os.fdopen(fd, "w") as f:
        json.dump(manifest, f)
    os.replace(tmpm, mpath)   # atomic commit: manifest names the good slot
    return mpath


def load_checkpoint(directory: str, like_state, *, verify: bool = True):
    """Load the committed checkpoint. Returns (state, step).

    Raises ``ValueError`` if digest verification fails (exact-or-abort).
    """
    mpath = os.path.join(directory, _MANIFEST)
    with open(mpath) as f:
        manifest = json.load(f)
    payload = os.path.join(directory, manifest["payload"])
    with np.load(payload) as z:
        leaves = {k: _restore_view(z[k], manifest["dtypes"][k])
                  for k in z.files}
    if verify:
        bad = [k for k, d in manifest["digests"].items()
               if not np.array_equal(
                   np.asarray(kops.checksum(leaves[k])), np.asarray(d))]
        if bad:
            raise ValueError(f"checkpoint digest mismatch: {bad[:4]}")
    state = _unflatten(like_state, leaves)
    return state, int(manifest["step"])


def load_latest(directory: str, like_state, *, verify: bool = True):
    return load_checkpoint(directory, like_state, verify=verify)


# ---------------------------------------------------------------------------
# manager
# ---------------------------------------------------------------------------

@dataclass
class _Pending:
    step: int
    host_state: object


class CheckpointManager:
    """Async double-buffered checkpointer.

    The step path pays only ``jax.device_get`` (one D2H copy); npz encoding
    and fsync happen on the writer thread.  Slots alternate 0/1 so the
    previous checkpoint survives until the new manifest commits.
    """

    def __init__(self, directory: str, interval: int = 100, *,
                 async_write: bool = True):
        self.directory = directory
        self.interval = max(1, interval)
        self.async_write = async_write
        self._slot = 0
        self._thread: Optional[threading.Thread] = None
        self._last_error: Optional[BaseException] = None
        self.saves = 0
        self.save_seconds_blocking = 0.0  # time the step path actually paid
        os.makedirs(directory, exist_ok=True)

    # -- step-path API ----------------------------------------------------

    def maybe_save(self, step: int, state) -> bool:
        if step % self.interval != 0:
            return False
        self.save(step, state)
        return True

    def save(self, step: int, state) -> None:
        from repro.core.microcheckpoint import host_copy

        t0 = time.perf_counter()
        # donation-safe D2H: a zero-copy host view would pin the live
        # buffers against donate_argnums for as long as the async writer
        # holds them — host_copy materialises real copies
        host = host_copy(state)
        self.wait()                                        # 1-deep pipeline
        if self.async_write:
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True)
            self._thread.start()
        else:
            self._write(step, host)
        self.save_seconds_blocking += time.perf_counter() - t0
        self.saves += 1

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._last_error is not None:
            err, self._last_error = self._last_error, None
            raise err

    # -- restore API --------------------------------------------------------

    def restore(self, like_state):
        self.wait()
        return load_latest(self.directory, like_state)

    def loader(self, like_state):
        """A zero-arg callable for RecoveryRuntime(checkpoint=...)."""
        return lambda: self.restore(like_state)

    # -- writer thread ------------------------------------------------------

    def _write(self, step: int, host_state) -> None:
        try:
            slot = self._slot
            self._slot ^= 1
            save_checkpoint(self.directory, host_state, step, slot=slot)
        except BaseException as e:  # surfaced on next wait()
            self._last_error = e
