from repro.checkpoint.store import (  # noqa: F401
    CheckpointManager,
    load_checkpoint,
    load_latest,
    save_checkpoint,
)
