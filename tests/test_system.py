"""End-to-end behaviour tests: the recovery-wrapped training loop survives
injected faults and converges; the serving loop survives cache corruption."""

import jax
import pytest

from repro.configs import get_config
from repro.launch.serve import serve
from repro.launch.train import train


@pytest.fixture(scope="module")
def cfg():
    return get_config("iterpro-100m").smoke()


def test_training_with_faults_recovers_and_learns(cfg, tmp_path):
    out = train(cfg, steps=20, global_batch=2, seq_len=32, seed=0,
                snapshot_interval=4, inject_every=6, canary_slices=1,
                checkpoint_dir=str(tmp_path), checkpoint_interval=10,
                verbose=False)
    assert out["steps"] == 20
    assert out["faults_injected"] >= 2
    # slices=1 => every persistent bit-flip is caught and recovered
    assert out["faults_detected"] == out["faults_injected"]
    assert out["faults_recovered"] == out["faults_detected"]
    assert out["recovery"]["recovery_rate"] == 1.0


def test_training_no_fault_no_recovery_activity(cfg):
    out = train(cfg, steps=8, global_batch=2, seq_len=32, seed=1,
                snapshot_interval=4, inject_every=0, verbose=False)
    assert out["faults_detected"] == 0
    assert out["recovery"]["events"] == 0


def test_serving_with_cache_corruption(cfg):
    out = serve(cfg, n_requests=2, prompt_len=16, gen_tokens=10, seed=0,
                inject_every=3, verbose=False)
    assert out["tokens_out"] == 2 * 10
    assert out["faults"]["injected"] >= 2
    # every DETECTED fault must be recovered (prefix replay always works)
    assert out["faults"]["recovered"] == out["faults"]["detected"]


def test_serving_canary_detects_and_replays_exactly(cfg):
    """Regression: the cache canary must detect cache corruption the free
    trap misses, and prefix replay must rebuild a BIT-IDENTICAL cache (an
    off-by-one token log once produced a plausible-but-wrong cache that
    only the canary caught)."""
    out = serve(cfg, n_requests=2, prompt_len=16, gen_tokens=10, seed=0,
                inject_every=3, verbose=False, canary_slices=1)
    assert out["tokens_out"] == 2 * 10           # all requests completed
    assert out["faults"]["injected"] >= 2
    # K=1 canary: every persistent cache flip is caught...
    assert out["faults"]["detected"] >= out["faults"]["injected"] - 1
    # ...and every detection recovers via prefix replay (never wedges)
    assert out["faults"]["recovered"] == out["faults"]["detected"]
    assert out["replay_tokens"] > 0
