"""Per-architecture smoke tests: a REDUCED config of the same family runs
one forward/train step and one prefill+decode on CPU, asserting output
shapes and finiteness (the task's required smoke matrix)."""

import math

import jax
import jax.numpy as jnp
import pytest

from repro.configs import list_archs, get_config
from repro.data.pipeline import TokenPipeline
from repro.models.registry import get_model
from repro.train.loop import make_train_state, make_train_step

B, S = 2, 32


def _batch(cfg, pipe, step=0):
    batch = pipe.batch_at(step)
    if cfg.model.n_enc_layers:
        batch = pipe.with_src_embeds(batch, 16, cfg.model.frontend_dim, step)
    if cfg.model.patch_dim:
        batch = pipe.with_patches(batch, 8, cfg.model.patch_dim, step)
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_train_step(arch):
    cfg = get_config(arch).smoke()
    pipe = TokenPipeline(cfg.model.vocab_size, S, B, seed=0)
    state = make_train_state(cfg, jax.random.PRNGKey(0), global_batch=B)
    step = jax.jit(make_train_step(cfg, global_batch=B))
    state2, metrics = step(state, _batch(cfg, pipe))
    loss = float(metrics["loss"])
    assert math.isfinite(loss) and loss > 0
    # IVs advanced independently (ICP)
    assert int(state2["iv"]["step"]) == 1
    assert int(state2["iv"]["data_offset"]) == B
    # optimizer state saw the gradients (params may not move at step 0:
    # warmup lr starts at 0) — take a second step and check params moved
    state3, _ = step(state2, _batch(cfg, pipe, 1))
    changed = any(
        not jnp.array_equal(a, b)
        for a, b in zip(jax.tree_util.tree_leaves(state["params"]),
                        jax.tree_util.tree_leaves(state3["params"])))
    assert changed


@pytest.mark.parametrize("arch", list_archs())
def test_prefill_decode(arch):
    cfg = get_config(arch).smoke()
    m = cfg.model
    model = get_model(m)
    pipe = TokenPipeline(m.vocab_size, S, B, seed=0)
    params = model.init(m, jax.random.PRNGKey(1))
    batch = _batch(cfg, pipe)

    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, m, b, None, max_len=S + 8))(params,
                                                                  batch)
    assert logits.shape == (B, m.vocab_size)
    assert bool(jnp.isfinite(logits).all())

    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    dec = jax.jit(lambda p, c, t: model.decode_step(p, m, c, t, None))
    for _ in range(3):
        logits, cache = dec(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert logits.shape == (B, m.vocab_size)
    assert bool(jnp.isfinite(logits).all())


def test_decode_matches_prefill_continuation():
    """Decoding token-by-token must agree with a longer prefill (cache
    correctness), for the dense family."""
    cfg = get_config("h2o-danube-1.8b").smoke()
    m = cfg.model
    model = get_model(m)
    pipe = TokenPipeline(m.vocab_size, S, B, seed=3)
    params = model.init(m, jax.random.PRNGKey(2))
    toks = pipe.batch_at(0)["tokens"]          # (B, S)

    # prefill on the first S-1 tokens, then decode the last token
    short = {"tokens": toks[:, : S - 1]}
    logits_s, cache = model.prefill(params, m, short, None, max_len=S + 4)
    logits_d, _ = model.decode_step(params, m, cache, toks[:, S - 1], None)

    full = {"tokens": toks}
    logits_f, _ = model.prefill(params, m, full, None, max_len=S + 4)

    assert jnp.allclose(logits_d, logits_f, atol=2e-4, rtol=2e-4), \
        float(jnp.max(jnp.abs(logits_d - logits_f)))
