"""In-step fused detection (core/fused_step.py + ChecksumCanary.fuse_into_step).

The PR-4 tentpole contract (DESIGN.md §4.2, "in-step fused" column):
  * the fused step's trajectory AND its digests are bit-identical to the
    PR-3 paths (non-donated ``check_and_arm`` and the donated
    ``arm_current``/``check`` pair) — fusing detection into the step must
    not change a single bit of either;
  * steady state is exactly 1 combined launch + 1 scalar sync per step,
    zero retraces (the K-executable cache holds, across factory
    instances too);
  * an injected flip is attributed to exactly the corrupted leaf via the
    DEFERRED resolver (the hot path fetched only the scalar flag);
  * donation really happens (pre-step buffers die) and the armed digests
    outlive them, bit-identical to the per-leaf oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.detect import ChecksumCanary, FaultReport
from repro.core.faults import flip_bit
from repro.kernels import digest as dg
from repro.kernels import ref

KEY = jax.random.PRNGKey(11)


def _tree():
    """Mixed dtypes/shapes: multi-tile, sub-tile, 16-bit, int, scalar."""
    ks = jax.random.split(KEY, 4)
    return {
        "params": {
            "w": jax.random.normal(ks[0], (257, 129)),          # 1+ tiles
            "b": jax.random.normal(ks[1], (33,)).astype(jnp.bfloat16),
        },
        "opt": {"m": jax.random.normal(ks[2], (40000,))},        # 2 tiles
        "iv": {"step": jnp.int32(12), "pos": jnp.int32(7)},
        "tok": jax.random.randint(ks[3], (17, 3), -5, 5, jnp.int32),
    }


def _raw_step(t, batch):
    """Structure/dtype-preserving step over ``_tree()`` states (+aux)."""
    def upd(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return (x * jnp.asarray(1.01, x.dtype)).astype(x.dtype)
        return x + jnp.ones((), x.dtype)
    return jax.tree_util.tree_map(upd, t), {"loss": batch.sum()}


BATCH = jnp.ones((8,), jnp.float32)


def _host(tree_or_leaf):
    """Host copy via a device temp: a zero-copy ``np.asarray`` view would
    pin the live buffer and silently veto the next donation (the PR-3
    footgun this suite must not trip)."""
    return jax.tree_util.tree_map(
        lambda x: np.asarray(jnp.array(x, copy=True)), tree_or_leaf)


def _same_tree(a, b) -> bool:
    return all(np.array_equal(x, y)
               for x, y in zip(jax.tree_util.tree_leaves(_host(a)),
                               jax.tree_util.tree_leaves(_host(b))))


# ---------------------------------------------------------------------------
# bit-exact conformance with the PR-3 paths
# ---------------------------------------------------------------------------

def test_fused_matches_check_and_arm_bitwise_nondonated():
    """Fused (donate=False) vs the non-donated ``check_and_arm`` path:
    identical protocol timing (check slice s of input, arm slice s+1 of
    output), so trajectories AND reference tables must match bit for
    bit at every step."""
    K = 3
    state_f = _tree()
    can_f = ChecksumCanary(state_f, n_slices=K)
    fac = can_f.fuse_into_step(_raw_step, donate=False)

    state_r = _tree()
    can_r = ChecksumCanary(state_r, n_slices=K)
    jstep = jax.jit(_raw_step)

    for s in range(2 * K):
        state_f, _, rep = fac.step(s, state_f, BATCH)
        assert rep is None
        new_r, _ = jstep(state_r, BATCH)
        assert can_r.check_and_arm(s, state_r, new_r) is None
        state_r = new_r
        assert _same_tree(state_f, state_r), f"trajectory diverged at {s}"
        assert np.array_equal(_host(can_f.reference),
                              _host(can_r.reference)), f"tables diverged at {s}"
        assert can_f.generation == can_r.generation


def test_fused_matches_donated_pair_bitwise():
    """Fused (donate=True) vs the PR-3 donated ``arm_current``/``check``
    pair: same trajectory bit for bit, and the digests each protocol
    verifies per step are digests of the same buffer versions — the pair
    arms slice s at step s, the fused step armed it at step s-1, so both
    must hold the per-leaf oracle digests of the same bytes."""
    K = 2
    state_f = _tree()
    can_f = ChecksumCanary(state_f, n_slices=K)
    fac = can_f.fuse_into_step(_raw_step, donate=True)

    state_r = _tree()
    can_r = ChecksumCanary(state_r, n_slices=K)
    dstep = jax.jit(_raw_step, donate_argnums=(0,))

    for s in range(2 * K):
        # oracle digests of the INPUT version both protocols will verify
        oracle = {k: np.asarray(ref.checksum_ref(jnp.array(v, copy=True)))
                  for k, v in zip(can_f._keys, can_f.plan.leaves(state_f))}

        old_f = jax.tree_util.tree_leaves(state_f)
        state_f, _, rep = fac.step(s, state_f, BATCH)
        assert rep is None
        assert all(l.is_deleted() for l in old_f), "fused donation vetoed"
        # the slice the fused step just checked was armed (at s-1, or at
        # init) with the oracle digests of the input version
        surviving = {k: t for k, t in zip(can_f._keys,
                                          _host(can_f._tables[(can_f._gen - 1) & 1]))}
        for i in can_f._slice_indices(s):
            key = can_f._keys[i]
            assert np.array_equal(surviving[key], oracle[key]), (s, key)

        can_r.arm_current(s, state_r)
        assert can_r.check(s, state_r) is None
        old_r = jax.tree_util.tree_leaves(state_r)
        state_r, _ = dstep(state_r, BATCH)
        assert all(l.is_deleted() for l in old_r), "pair donation vetoed"

        assert _same_tree(state_f, state_r), f"trajectory diverged at {s}"


# ---------------------------------------------------------------------------
# hot-path accounting + K-executable cache
# ---------------------------------------------------------------------------

def test_fused_steady_state_one_launch_one_sync_no_retrace():
    state = _tree()
    K = 4
    can = ChecksumCanary(state, n_slices=K)
    fac = can.fuse_into_step(_raw_step, donate=True)
    for s in range(K):                        # lazy warm: one full rotation
        state, _, rep = fac.step(s, state, BATCH)
        assert rep is None
    assert fac.n_compiles == K
    dg.STATS.reset()
    n = 2 * K
    for s in range(K, K + n):
        state, _, rep = fac.step(s, state, BATCH)
        assert rep is None
    launches, syncs, traces = dg.STATS.snapshot()
    assert launches == n     # ONE combined launch per step
    assert syncs == n        # ONE scalar device→host transfer per step
    assert traces == 0       # the K-executable cache holds
    assert fac.n_compiles == K                # nothing recompiled


def test_eager_warm_compiles_all_k_without_stepping():
    state = _tree()
    K = 3
    can = ChecksumCanary(state, n_slices=K)
    fac = can.fuse_into_step(_raw_step, donate=True, warm="eager")
    wall = fac.warm(state, BATCH)
    assert fac.n_compiles == K and wall > 0.0
    assert fac.compile_seconds > 0.0
    assert fac.warm(state, BATCH) == 0.0      # idempotent per signature
    g0 = can.generation                       # warm ran NO step: table and
    assert g0 == 0                            # generation untouched
    dg.STATS.reset()
    for s in range(2 * K):
        state, _, rep = fac.step(s, state, BATCH)
        assert rep is None
    assert dg.STATS.traces == 0               # warm really compiled all K
    assert fac.n_compiles == K


def test_executable_cache_shared_across_factories():
    """One factory per campaign trial must not recompile: the executable
    cache is keyed by (plan, K, step_fn, donate, rotation, args)."""
    K = 2
    state = _tree()
    can1 = ChecksumCanary(state, n_slices=K)
    fac1 = can1.fuse_into_step(_raw_step, donate=False)
    for s in range(K):
        state, _, _ = fac1.step(s, state, BATCH)
    state2 = _tree()
    can2 = ChecksumCanary(state2, n_slices=K)  # fresh canary, same plan
    fac2 = can2.fuse_into_step(_raw_step, donate=False)
    dg.STATS.reset()
    for s in range(K):
        state2, _, rep = fac2.step(s, state2, BATCH)
        assert rep is None
    assert dg.STATS.traces == 0
    assert fac2.n_compiles == 0               # global cache hit for all K


# ---------------------------------------------------------------------------
# fault path: deferred attribution
# ---------------------------------------------------------------------------

def test_fused_flip_attributed_to_exact_leaf_via_resolver():
    """A flip landing in the guarded window is detected by the in-step
    check at the slice's next rotation; the report carries only the
    scalar verdict until ``resolve()`` fetches the bad-mask vector and
    names exactly the corrupted leaf."""
    state = _tree()
    can = ChecksumCanary(state, n_slices=1)
    fac = can.fuse_into_step(_raw_step, donate=False)
    state, _, rep = fac.step(0, state, BATCH)
    assert rep is None
    bad = dict(state, opt={"m": flip_bit(state["opt"]["m"], 11, 4)})
    _, _, rep = fac.step(1, bad, BATCH)
    assert isinstance(rep, FaultReport) and rep.detector == "checksum"
    assert rep.leaves == []                   # hot path: flag only
    assert rep.resolve() == ["opt/m"]         # fault path: exact leaf
    assert rep.leaves == ["opt/m"]
    assert rep.resolve() == ["opt/m"]         # idempotent


def test_fused_donated_flip_detected_and_recovery_refresh_resumes():
    """Donated fused loop: a flip is detected in-step; after the (mock)
    recovery installs a clean state, ``refresh`` bumps the generation and
    the fused protocol resumes without spurious faults — and still
    catches the next real flip."""
    state = _tree()
    K = 2
    can = ChecksumCanary(state, n_slices=K)
    fac = can.fuse_into_step(_raw_step, donate=True)
    restore = jax.tree_util.tree_map(lambda x: jnp.array(x, copy=True),
                                     state)
    for s in range(2 * K):
        state, _, rep = fac.step(s, state, BATCH)
        assert rep is None

    def advance_to_rotation(state, s, idx):
        """Step the fused loop contiguously until the NEXT step's check
        slice covers plan leaf ``idx`` (skipping steps would leave stale
        armed slices and a false positive)."""
        while s % K != idx % K:
            state, _, rep = fac.step(s, state, BATCH)
            assert rep is None
            s += 1
        return state, s

    # adversary: flip a leaf of the live state just before the step whose
    # check slice covers it
    i = can.plan.index_of("opt/m")
    state, s = advance_to_rotation(state, 2 * K, i)
    bad = dict(state, opt={"m": flip_bit(state["opt"]["m"], 3, 7)})
    _, _, rep = fac.step(s, bad, BATCH)
    assert rep is not None and rep.resolve() == ["opt/m"]

    # recovery pivot (donated): discard the corrupt-derived output,
    # restore the snapshot, refresh the canary — the generation bump
    # makes the fresh digests the read generation
    g0 = can.generation
    state = restore
    can.refresh(state)
    assert can.generation > g0
    for s in range(2 * K):
        state, _, rep = fac.step(s, state, BATCH)
        assert rep is None                    # no spurious post-restore trap

    j = can.plan.index_of("tok")
    state, s = advance_to_rotation(state, 2 * K, j)
    bad = dict(state, tok=flip_bit(state["tok"], 1, 0))
    _, _, rep = fac.step(s, bad, BATCH)
    assert rep is not None and rep.resolve() == ["tok"]


def test_degenerate_rotations_more_slices_than_leaves():
    """K > n_leaves: empty rotations run the plain step (no digest, no
    generation bump) and the populated rotations still guard their
    leaf."""
    tree = {"a": jnp.arange(8, dtype=jnp.int32),
            "b": jnp.ones((5,), jnp.float32)}
    K = 4
    can = ChecksumCanary(tree, n_slices=K)
    fac = can.fuse_into_step(_raw_step, donate=False)
    state = tree
    for s in range(2 * K):
        state, _, rep = fac.step(s, state, BATCH)
        assert rep is None
    # leaf "a" (plan index 0) is checked at steps ≡ 0 (mod K)
    bad = dict(state, a=flip_bit(state["a"], 2, 1))
    _, _, rep = fac.step(2 * K, bad, BATCH)
    assert rep is not None and rep.resolve() == ["a"]


def test_fuse_into_step_rejects_bad_warm_knob():
    can = ChecksumCanary(_tree(), n_slices=2)
    with pytest.raises(ValueError):
        can.fuse_into_step(_raw_step, warm="sometimes")
