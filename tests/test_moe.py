"""MoE capacity dispatch: equivalence with per-token dense expert selection
when capacity is ample; EP path equivalence on a multi-device subprocess."""

import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from conftest import requires_axis_type
from repro.configs.base import ModelConfig
from repro.models import moe as M


def _cfg(E=4, k=2, d=16, ff=32, cap=1.25):
    return ModelConfig(family="moe", n_layers=1, d_model=d, n_heads=2,
                       n_kv_heads=2, d_ff=ff, vocab_size=64, n_experts=E,
                       top_k=k, moe_d_ff=ff, moe_capacity=cap,
                       param_dtype="float32", compute_dtype="float32")


def _dense_oracle(x, p, cfg):
    """Per-token dense computation of the selected experts (no capacity)."""
    w, ids, _ = M._route(x.astype(jnp.float32), p["router"]["w"], cfg.top_k)
    outs = []
    for t in range(x.shape[0]):
        acc = jnp.zeros((cfg.d_model,), jnp.float32)
        for j in range(cfg.top_k):
            e = int(ids[t, j])
            h = x[t] @ p["gate"][e], x[t] @ p["up"][e]
            hh = jax.nn.silu(h[0].astype(jnp.float32)) * h[1].astype(jnp.float32)
            acc = acc + w[t, j] * (hh.astype(x.dtype) @ p["down"][e]).astype(jnp.float32)
        outs.append(acc)
    return jnp.stack(outs).astype(x.dtype)


def test_capacity_dispatch_matches_dense_oracle():
    cfg = _cfg(cap=8.0)   # ample capacity: zero drops -> exact equivalence
    key = jax.random.PRNGKey(0)
    p = M.moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (24, cfg.d_model))
    y, aux = M._moe_local_math(x, p, cfg)
    y_ref = _dense_oracle(x, p, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-5, rtol=1e-5)
    assert float(aux["lb_loss"]) > 0


def test_capacity_drops_overflow_tokens():
    """With capacity 8 and all tokens routed to one expert, the overflow
    contributes zero (GShard semantics) rather than corrupting others."""
    cfg = _cfg(E=2, k=1)
    key = jax.random.PRNGKey(0)
    p = M.moe_init(key, cfg, jnp.float32)
    # bias the router so everything goes to expert 0 (positive inputs ×
    # positive column -> expert 0 wins for every token)
    p["router"]["w"] = jnp.zeros_like(p["router"]["w"]).at[:, 0].set(100.0)
    x = jnp.abs(jax.random.normal(key, (32, cfg.d_model))) + 0.1
    # cap = max(8, ceil(32*1*1.25/2) -> 24): 8 of 32 rows overflow
    y, _ = M._moe_local_math(x, p, cfg)
    y_ref = _dense_oracle(x, p, cfg)
    # the first `capacity` routed tokens match; some tail tokens are zero
    match = np.isclose(np.asarray(y), np.asarray(y_ref),
                       atol=1e-5).all(axis=1)
    zeros = (np.asarray(y) == 0).all(axis=1)
    assert (match | zeros).all()
    assert zeros.sum() > 0


EP_PROG = textwrap.dedent("""
    import os, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import ModelConfig
    from repro.distributed.context import DistContext
    from repro.models import moe as M

    out = {}
    for impl in ("ep_a2a", "ep_token_a2a"):
        cfg = ModelConfig(family="moe", n_layers=1, d_model=16, n_heads=2,
                          n_kv_heads=2, d_ff=32, vocab_size=64, n_experts=8,
                          top_k=2, moe_d_ff=32, moe_impl=impl,
                          moe_capacity=8.0,
                          param_dtype="float32", compute_dtype="float32")
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        ctx = DistContext.for_mesh(mesh, fsdp=True)
        key = jax.random.PRNGKey(0)
        p = M.moe_init(key, cfg, jnp.float32)
        x = jax.random.normal(jax.random.fold_in(key, 1),
                              (4, 8, cfg.d_model))
        y_local, _ = M.moe_apply(p, cfg, x, None)
        with mesh:
            y_dist, _ = jax.jit(
                lambda p, x: M.moe_apply(p, cfg, x, ctx))(p, x)
        out[impl] = {"err": float(jnp.max(jnp.abs(y_local - y_dist))),
                     "ep": M.use_ep(cfg, ctx)}
    print(json.dumps(out))
""")


@requires_axis_type
def test_ep_paths_match_local():
    """Both EP schedules (mask+psum baseline and token-routed a2a, §Perf B4)
    must agree with the single-device oracle."""
    out = subprocess.run([sys.executable, "-c", EP_PROG],
                         capture_output=True, text=True, cwd="/root/repo",
                         timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    data = json.loads(out.stdout.strip().splitlines()[-1])
    for impl, r in data.items():
        assert r["ep"] is True, (impl, r)
        assert r["err"] < 2e-4, (impl, r)
