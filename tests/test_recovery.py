"""Integration tests for the recovery ladder: detection -> diagnosis ->
repair -> exact-or-abort verification, on a real (tiny) training loop."""

import dataclasses
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ChecksumCanary,
    FaultReport,
    MicroCheckpointer,
    ParityStore,
    RecoveryFailed,
    RecoveryRuntime,
    RecoveryTable,
    inject,
    promote,
    sample_plan,
)
from repro.core.recovery_table import RUNG_EQ1, RUNG_REPLAY


def _runtime(tiny_setup, **kw):
    cfg, state0, step, bfn = tiny_setup
    micro = MicroCheckpointer(interval=4)
    rt = RecoveryRuntime(step_fn=step, batch_fn=bfn,
                         iv_registry=promote(cfg, 2), micro=micro, **kw)
    return rt, micro


def _advance(step, bfn, state, start, n, micro=None):
    for s in range(start, start + n):
        if micro is not None:
            micro.maybe_snapshot(s, state)
            micro.record_iv(s, state["iv"])
        state, _ = step(state, bfn(s))
    return state


def test_iv_corruption_recovers_via_eq1(tiny_setup):
    cfg, state0, step, bfn = tiny_setup
    rt, micro = _runtime(tiny_setup)
    state = _advance(step, bfn, state0, 0, 6, micro)

    bad_iv = dict(state["iv"])
    bad_iv["sched_pos"] = jnp.int32(12345)
    bad = dict(state, iv=bad_iv)

    fixed, ev = rt.recover(bad, FaultReport(6, "checksum",
                                            leaves=["iv/sched_pos"]), 6)
    assert ev.rung == RUNG_EQ1
    assert int(fixed["iv"]["sched_pos"]) == int(state["iv"]["sched_pos"])


def test_param_corruption_replays_bit_exact(tiny_setup):
    cfg, state0, step, bfn = tiny_setup
    rt, micro = _runtime(tiny_setup)
    state = _advance(step, bfn, state0, 0, 6, micro)

    plan = sample_plan(random.Random(0), state, max_step=1, target="params")
    plan = dataclasses.replace(plan, bit=30)
    bad = inject(state, plan)

    fixed, ev = rt.recover(bad, FaultReport(6, "checksum",
                                            leaves=["params/" + plan.leaf]),
                           6)
    assert ev.rung == RUNG_REPLAY
    for a, b in zip(jax.tree_util.tree_leaves(fixed["params"]),
                    jax.tree_util.tree_leaves(state["params"])):
        assert np.array_equal(np.asarray(a), np.asarray(b))  # BIT exact


def test_post_recovery_trajectory_is_fault_free(tiny_setup):
    """The strongest claim: after recovery the continued trajectory equals
    the never-faulted trajectory bit-for-bit."""
    cfg, state0, step, bfn = tiny_setup
    rt, micro = _runtime(tiny_setup)

    # fault-free reference
    ref_state = _advance(step, bfn, state0, 0, 10)

    state = _advance(step, bfn, state0, 0, 6, micro)
    plan = dataclasses.replace(
        sample_plan(random.Random(1), state, max_step=1, target="params"),
        bit=27)
    bad = inject(state, plan)
    fixed, _ = rt.recover(bad, FaultReport(6, "checksum",
                                           leaves=["params/" + plan.leaf]), 6)
    final = _advance(step, bfn, fixed, 6, 4)

    for a, b in zip(jax.tree_util.tree_leaves(final),
                    jax.tree_util.tree_leaves(ref_state)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_replica_vote_rung(tiny_setup):
    cfg, state0, step, bfn = tiny_setup
    state = _advance(step, bfn, state0, 0, 3)
    replicas = lambda s: [state, state]          # two healthy DP partners
    rt, micro = _runtime(tiny_setup, replicas=replicas)

    plan = dataclasses.replace(
        sample_plan(random.Random(2), state, max_step=1, target="params"),
        bit=30)
    bad = inject(state, plan)
    fixed, ev = rt.recover(bad, FaultReport(3, "checksum",
                                            leaves=["params/" + plan.leaf]),
                           3, ladder=["replica_vote"])
    assert ev.rung == "replica_vote"
    for a, b in zip(jax.tree_util.tree_leaves(fixed["params"]),
                    jax.tree_util.tree_leaves(state["params"])):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_parity_rung_reconstructs_lost_shard(tiny_setup):
    cfg, state0, step, bfn = tiny_setup
    state = _advance(step, bfn, state0, 0, 2)
    ps = ParityStore(state)                 # covers the FULL state tree
    ps.build(state, 2)
    rt, micro = _runtime(tiny_setup, parity=ps)

    # wipe EXACTLY parity block 1 of one leaf (a lost device's slice):
    # the plan's own block boundaries define what "one shard" means
    key = "params/embed/table"
    table = state["params"]["embed"]["table"]
    csum = np.cumsum((0,) + ps.plan.block_sizes[key])
    lo, hi = int(csum[1]), min(int(csum[2]), table.size)
    flat = np.asarray(table).ravel().copy()
    flat[lo:hi] = np.nan
    bad_table = jnp.asarray(flat.reshape(table.shape))
    bad = dict(state, params=dict(state["params"],
                                  embed={"table": bad_table}))

    fixed, ev = rt.recover(bad, FaultReport(2, "external",
                                            leaves=[key]),
                           2, ladder=["parity_xor"])
    assert ev.rung == "parity_xor"
    assert ev.steps_replayed == 0
    assert ev.bytes_moved > 0
    assert np.array_equal(np.asarray(fixed["params"]["embed"]["table"]),
                          np.asarray(table))


def test_exhausted_ladder_raises(tiny_setup):
    cfg, state0, step, bfn = tiny_setup
    rt, micro = _runtime(tiny_setup)      # no snapshots taken, no checkpoint
    state = _advance(step, bfn, state0, 0, 2)
    bad_iv = {k: jnp.int32(int(v) + 7 + i)       # break ALL counters
              for i, (k, v) in enumerate(state["iv"].items())}
    bad = dict(state, iv=bad_iv)
    with pytest.raises(RecoveryFailed):
        rt.recover(bad, FaultReport(2, "checksum",
                                    leaves=[f"iv/{k}" for k in bad_iv]), 2)


def test_canary_detects_and_names_leaf(tiny_setup):
    cfg, state0, step, bfn = tiny_setup
    canary = ChecksumCanary(state0, n_slices=1)   # check everything
    plan = dataclasses.replace(
        sample_plan(random.Random(3), state0, max_step=1, target="params"),
        bit=5)   # low mantissa bit: invisible to loss traps
    bad = inject(state0, plan)
    report = canary.check(0, bad)
    assert report is not None
    assert report.leaves == ["params/" + plan.leaf]


def test_recovery_table_roundtrip(tiny_setup):
    cfg, state0, step, bfn = tiny_setup
    table = RecoveryTable.build(state0, replicated=True, parity=True)
    assert len(table) == len(jax.tree_util.tree_leaves(state0))
    again = RecoveryTable.from_json(table.to_json())
    assert again.entries == table.entries
    e = again.lookup("iv/step")
    assert e is not None and e.ladder[0] == RUNG_EQ1


def test_every_emittable_rung_has_a_registered_handler(tiny_setup):
    """Dead-rung sweep: every rung name RecoveryTable.build can emit —
    under ANY combination of redundancy flags — must resolve to a handler
    in RecoveryRuntime._RUNGS, or recover() would skip it silently (the
    ladder driver ignores unknown rungs)."""
    cfg, state0, step, bfn = tiny_setup
    emittable = set()
    for replicated in (False, True):
        for parity in (False, True):
            for sharded in (False, True):
                table = RecoveryTable.build(state0, replicated=replicated,
                                            parity=parity, sharded=sharded)
                for entry in table.entries.values():
                    emittable.update(entry.ladder)
    missing = emittable - set(RecoveryRuntime._RUNGS)
    assert not missing, f"rungs with no registered handler: {missing}"
    # ...and no handler is dead weight: the flag space above reaches all
    assert emittable == set(RecoveryRuntime._RUNGS)


def test_replica_vote_routes_through_vote_kernel():
    """The TMR rung's repair math IS kernels/vote.py: kops.vote3 (the op
    _rung_replica calls) must produce vote3_tiles' bitwise majority."""
    from repro.kernels import ops as kops
    from repro.kernels import vote as kvote

    rng = np.random.default_rng(0)
    a = rng.standard_normal((300, 7)).astype(np.float32)
    b = a.copy()
    c = a.copy()
    bad = a.copy()
    bad[13, 2] = np.float32(1e30)          # any single-copy corruption
    fixed = np.asarray(kops.vote3(jnp.asarray(bad), jnp.asarray(b),
                                  jnp.asarray(c)))
    assert np.array_equal(fixed, a)
    # and the op is literally the Pallas kernel, not a reimplementation
    import inspect
    assert "vote3_tiles" in inspect.getsource(kops.vote3)
    assert kvote.vote3_tiles is not None
