"""Integration tests for the recovery ladder: detection -> diagnosis ->
repair -> exact-or-abort verification, on a real (tiny) training loop."""

import dataclasses
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ChecksumCanary,
    FaultReport,
    InjectionPlan,
    MicroCheckpointer,
    ParityStore,
    RecoveryFailed,
    RecoveryRuntime,
    RecoveryTable,
    inject,
    promote,
    sample_plan,
)
from repro.core.recovery_table import (
    RUNG_EQ1,
    RUNG_OPT_IV,
    RUNG_REPLAY,
    RUNG_TRIAGE,
)


def _runtime(tiny_setup, **kw):
    cfg, state0, step, bfn = tiny_setup
    micro = MicroCheckpointer(interval=4)
    rt = RecoveryRuntime(step_fn=step, batch_fn=bfn,
                         iv_registry=promote(cfg, 2), micro=micro, **kw)
    return rt, micro


def _advance(step, bfn, state, start, n, micro=None):
    for s in range(start, start + n):
        if micro is not None:
            micro.maybe_snapshot(s, state)
            micro.record_iv(s, state["iv"])
        state, _ = step(state, bfn(s))
    return state


def test_iv_corruption_recovers_via_eq1(tiny_setup):
    cfg, state0, step, bfn = tiny_setup
    rt, micro = _runtime(tiny_setup)
    state = _advance(step, bfn, state0, 0, 6, micro)

    bad_iv = dict(state["iv"])
    bad_iv["sched_pos"] = jnp.int32(12345)
    bad = dict(state, iv=bad_iv)

    fixed, ev = rt.recover(bad, FaultReport(6, "checksum",
                                            leaves=["iv/sched_pos"]), 6)
    assert ev.rung == RUNG_EQ1
    assert int(fixed["iv"]["sched_pos"]) == int(state["iv"]["sched_pos"])


def test_param_corruption_replays_bit_exact(tiny_setup):
    cfg, state0, step, bfn = tiny_setup
    rt, micro = _runtime(tiny_setup)
    state = _advance(step, bfn, state0, 0, 6, micro)

    plan = sample_plan(random.Random(0), state, max_step=1, target="params")
    plan = dataclasses.replace(plan, bit=30)
    bad = inject(state, plan)

    fixed, ev = rt.recover(bad, FaultReport(6, "checksum",
                                            leaves=["params/" + plan.leaf]),
                           6)
    assert ev.rung == RUNG_REPLAY
    for a, b in zip(jax.tree_util.tree_leaves(fixed["params"]),
                    jax.tree_util.tree_leaves(state["params"])):
        assert np.array_equal(np.asarray(a), np.asarray(b))  # BIT exact


def test_post_recovery_trajectory_is_fault_free(tiny_setup):
    """The strongest claim: after recovery the continued trajectory equals
    the never-faulted trajectory bit-for-bit."""
    cfg, state0, step, bfn = tiny_setup
    rt, micro = _runtime(tiny_setup)

    # fault-free reference
    ref_state = _advance(step, bfn, state0, 0, 10)

    state = _advance(step, bfn, state0, 0, 6, micro)
    plan = dataclasses.replace(
        sample_plan(random.Random(1), state, max_step=1, target="params"),
        bit=27)
    bad = inject(state, plan)
    fixed, _ = rt.recover(bad, FaultReport(6, "checksum",
                                           leaves=["params/" + plan.leaf]), 6)
    final = _advance(step, bfn, fixed, 6, 4)

    for a, b in zip(jax.tree_util.tree_leaves(final),
                    jax.tree_util.tree_leaves(ref_state)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_replica_vote_rung(tiny_setup):
    cfg, state0, step, bfn = tiny_setup
    state = _advance(step, bfn, state0, 0, 3)
    replicas = lambda s: [state, state]          # two healthy DP partners
    rt, micro = _runtime(tiny_setup, replicas=replicas)

    plan = dataclasses.replace(
        sample_plan(random.Random(2), state, max_step=1, target="params"),
        bit=30)
    bad = inject(state, plan)
    fixed, ev = rt.recover(bad, FaultReport(3, "checksum",
                                            leaves=["params/" + plan.leaf]),
                           3, ladder=["replica_vote"])
    assert ev.rung == "replica_vote"
    for a, b in zip(jax.tree_util.tree_leaves(fixed["params"]),
                    jax.tree_util.tree_leaves(state["params"])):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_parity_rung_reconstructs_lost_shard(tiny_setup):
    cfg, state0, step, bfn = tiny_setup
    state = _advance(step, bfn, state0, 0, 2)
    ps = ParityStore(state)                 # covers the FULL state tree
    ps.build(state, 2)
    rt, micro = _runtime(tiny_setup, parity=ps)

    # wipe EXACTLY parity block 1 of one leaf (a lost device's slice):
    # the plan's own block boundaries define what "one shard" means
    key = "params/embed/table"
    table = state["params"]["embed"]["table"]
    csum = np.cumsum((0,) + ps.plan.block_sizes[key])
    lo, hi = int(csum[1]), min(int(csum[2]), table.size)
    flat = np.asarray(table).ravel().copy()
    flat[lo:hi] = np.nan
    bad_table = jnp.asarray(flat.reshape(table.shape))
    bad = dict(state, params=dict(state["params"],
                                  embed={"table": bad_table}))

    fixed, ev = rt.recover(bad, FaultReport(2, "external",
                                            leaves=[key]),
                           2, ladder=["parity_xor"])
    assert ev.rung == "parity_xor"
    assert ev.steps_replayed == 0
    assert ev.bytes_moved > 0
    assert np.array_equal(np.asarray(fixed["params"]["embed"]["table"]),
                          np.asarray(table))


def test_exhausted_ladder_raises(tiny_setup):
    cfg, state0, step, bfn = tiny_setup
    rt, micro = _runtime(tiny_setup)      # no snapshots taken, no checkpoint
    state = _advance(step, bfn, state0, 0, 2)
    bad_iv = {k: jnp.int32(int(v) + 7 + i)       # break ALL counters
              for i, (k, v) in enumerate(state["iv"].items())}
    bad = dict(state, iv=bad_iv)
    with pytest.raises(RecoveryFailed):
        rt.recover(bad, FaultReport(2, "checksum",
                                    leaves=[f"iv/{k}" for k in bad_iv]), 2)


def test_canary_detects_and_names_leaf(tiny_setup):
    cfg, state0, step, bfn = tiny_setup
    canary = ChecksumCanary(state0, n_slices=1)   # check everything
    plan = dataclasses.replace(
        sample_plan(random.Random(3), state0, max_step=1, target="params"),
        bit=5)   # low mantissa bit: invisible to loss traps
    bad = inject(state0, plan)
    report = canary.check(0, bad)
    assert report is not None
    assert report.leaves == ["params/" + plan.leaf]


def test_recovery_table_roundtrip(tiny_setup):
    cfg, state0, step, bfn = tiny_setup
    table = RecoveryTable.build(state0, replicated=True, parity=True)
    assert len(table) == len(jax.tree_util.tree_leaves(state0))
    again = RecoveryTable.from_json(table.to_json())
    assert again.entries == table.entries
    e = again.lookup("iv/step")
    assert e is not None and e.ladder[0] == RUNG_EQ1


def test_every_emittable_rung_has_a_registered_handler(tiny_setup):
    """Dead-rung sweep: every rung name RecoveryTable.build can emit —
    under ANY combination of redundancy flags — must resolve to a handler
    in RecoveryRuntime._RUNGS, or recover() would skip it silently (the
    ladder driver ignores unknown rungs)."""
    cfg, state0, step, bfn = tiny_setup
    reg = promote(cfg, 2)
    opt_ivs = tuple(sorted(k for k in (set(reg.specs) | set(reg.derived))
                           if not k.startswith("iv/")))
    assert opt_ivs, "promote() must export optimizer-owned induction keys"
    emittable = set()
    for replicated in (False, True):
        for parity in (False, True):
            for sharded in (False, True):
                for triage in (False, True):
                    for elastic in (False, True):
                        table = RecoveryTable.build(
                            state0, replicated=replicated, parity=parity,
                            sharded=sharded, triage=triage,
                            elastic=elastic, opt_ivs=opt_ivs)
                        for entry in table.entries.values():
                            emittable.update(entry.ladder)
    missing = emittable - set(RecoveryRuntime._RUNGS)
    assert not missing, f"rungs with no registered handler: {missing}"
    # ...and no handler is dead weight: the flag space above reaches all
    # (triage and opt_iv included — a handler the table can never emit
    # would be untestable dead code)
    assert emittable == set(RecoveryRuntime._RUNGS)


def test_eq1_residue_abort_regression():
    """data_offset advances by the global batch (a non-unit step): a
    partner value off that lattice is itself corrupted, and Eq.(1) must
    refuse it instead of floor-dividing into a silently wrong repair."""
    from repro.core.induction import IVRegistry, RecoveryAbort

    reg = IVRegistry({"iv/step": (0, 1), "iv/data_offset": (0, 512)})
    assert reg.eq1("iv/step", "iv/data_offset", 512 * 7) == 7
    with pytest.raises(RecoveryAbort):
        reg.eq1("iv/step", "iv/data_offset", 512 * 7 + 3)


def test_opt_counter_flip_recovers_via_opt_iv(tiny_setup):
    """A bit flip in the optimizer's own step counter repairs through the
    opt_iv branch of the Eq.(1) consensus engine: zero snapshot bytes,
    zero replayed steps."""
    cfg, state0, step, bfn = tiny_setup
    rt, micro = _runtime(tiny_setup)
    state = _advance(step, bfn, state0, 0, 6, micro)

    bad = inject(state, InjectionPlan("t", 0, 3, 6, "opt"))
    assert int(bad["opt"]["t"]) != int(state["opt"]["t"])
    fixed, ev = rt.recover(bad, FaultReport(6, "checksum",
                                            leaves=["opt/t"]), 6)
    assert ev.rung == RUNG_OPT_IV
    assert ev.steps_replayed == 0
    assert ev.bytes_moved == 0
    assert int(fixed["opt"]["t"]) == int(state["opt"]["t"])


def test_derived_correction_flip_recomputed_bitwise(tiny_setup):
    """Bias-correction scalars are DERIVED induction entries: a flip in
    one is repaired by recomputing it from the consensus iteration, and
    the recomputation must be bit-identical to the never-faulted value."""
    cfg, state0, step, bfn = tiny_setup
    rt, micro = _runtime(tiny_setup)
    state = _advance(step, bfn, state0, 0, 6, micro)

    bad = inject(state, InjectionPlan("bc1", 0, 20, 6, "opt"))
    fixed, ev = rt.recover(bad, FaultReport(6, "checksum",
                                            leaves=["opt/bc1"]), 6)
    assert ev.rung == RUNG_OPT_IV
    assert ev.steps_replayed == 0
    assert (np.asarray(fixed["opt"]["bc1"]).tobytes()
            == np.asarray(state["opt"]["bc1"]).tobytes())   # BIT exact
    # the healthy twin was untouched by the repair
    assert (np.asarray(fixed["opt"]["bc2"]).tobytes()
            == np.asarray(state["opt"]["bc2"]).tobytes())


def test_triage_tolerates_sub_epsilon_moment_flip(tiny_setup):
    """Rung 0: a mantissa-tail flip in an EMA moment carries a certified
    below-epsilon perturbation — triage tolerates it in place (state
    untouched) and re-arms the digest row so the canary stays quiet."""
    cfg, state0, step, bfn = tiny_setup
    state = _advance(step, bfn, state0, 0, 6)
    canary = ChecksumCanary(state, n_slices=1)
    rt, micro = _runtime(tiny_setup, canary=canary, triage=True)

    plan = InjectionPlan("m/groups/0/0/ffn/up/w", 1000, 1, 6, "opt")
    bad = inject(state, plan)
    report = canary.check(6, bad)
    assert report is not None and report.detector == "checksum"
    assert report.leaves == ["opt/" + plan.leaf]

    fixed, ev = rt.recover(bad, report, 6)
    assert ev.rung == RUNG_TRIAGE
    assert ev.steps_replayed == 0
    assert ev.bytes_moved == 0
    # tolerate never alters state — the flipped bit is still there
    for a, b in zip(jax.tree_util.tree_leaves(fixed),
                    jax.tree_util.tree_leaves(bad)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # ...and the digest table was re-armed to the tolerated bits, so the
    # very next check does NOT re-fire on the value we chose to live with
    assert canary.check(7, fixed) is None


def test_triage_escalates_uncertifiable_flip(tiny_setup):
    """An exponent-scale flip in the same moment leaf fails the epsilon
    certificate: triage must abort into the rest of the ladder (replay
    here), preserving exact-or-abort."""
    cfg, state0, step, bfn = tiny_setup
    rt, micro = _runtime(tiny_setup, triage=True)
    state = _advance(step, bfn, state0, 0, 6, micro)
    canary = ChecksumCanary(state, n_slices=1)
    rt.canary = canary

    plan = InjectionPlan("m/groups/0/0/ffn/up/w", 1000, 30, 6, "opt")
    bad = inject(state, plan)
    report = canary.check(6, bad)
    assert report is not None

    fixed, ev = rt.recover(bad, report, 6)
    assert ev.rung == RUNG_REPLAY            # escalated past rung 0
    assert "escalate" in ev.report.detail
    for a, b in zip(jax.tree_util.tree_leaves(fixed),
                    jax.tree_util.tree_leaves(state)):
        assert np.array_equal(np.asarray(a), np.asarray(b))  # BIT exact


def test_triage_tolerates_int8_pad_tail_flip(tiny_setup):
    """Dead-region certificate: a flip in the int8-quantised moment pad
    tail (bytes _dq8 never reads, rewritten wholesale each update) is
    tolerated bitwise — no epsilon needed."""
    from repro.optim.optimizers import _q8

    p = jnp.arange(300, dtype=jnp.float32) / 7.0    # pads to 2x256 blocks
    state = {"params": {"w": p}, "opt": {"m": {"w": _q8(p)}},
             "iv": {"step": jnp.int32(4)}}
    canary = ChecksumCanary(state, n_slices=1)
    rt, micro = _runtime(tiny_setup, canary=canary, triage=True)

    bad = inject(state, InjectionPlan("m/w/q", 310, 6, 4, "opt"))
    report = canary.check(4, bad)
    assert report is not None and report.leaves == ["opt/m/w/q"]

    fixed, ev = rt.recover(bad, report, 4)
    assert ev.rung == RUNG_TRIAGE
    assert "dead-region" in ev.report.detail
    assert canary.check(5, fixed) is None    # re-armed


def test_triage_dead_element_boundary(tiny_setup):
    """The dead-element predicate draws the line exactly at the logical
    param size: pad-tail elements certify, live elements never do."""
    from repro.optim.optimizers import QBLOCK, _q8

    rt, micro = _runtime(tiny_setup)
    p = jnp.arange(300, dtype=jnp.float32)
    state = {"params": {"w": p}, "opt": {"m": {"w": _q8(p)}},
             "iv": {"step": jnp.int32(0)}}
    assert rt._dead_element(state, "opt/m/w/q", 300)       # first pad elt
    assert rt._dead_element(state, "opt/m/w/q", 511)       # last pad elt
    assert not rt._dead_element(state, "opt/m/w/q", 299)   # last live elt
    # both scale rows cover live elements (block 1 holds 256..299)
    assert not rt._dead_element(state, "opt/m/w/scale", 0)
    assert not rt._dead_element(state, "opt/m/w/scale", 1)
    assert rt._dead_element(state, "opt/m/w/scale", 2)     # all-pad block
    # never certifies outside the quantised-moment subtree
    assert not rt._dead_element(state, "params/w", 500)


def test_replica_vote_routes_through_vote_kernel():
    """The TMR rung's repair math IS kernels/vote.py: kops.vote3 (the op
    _rung_replica calls) must produce vote3_tiles' bitwise majority."""
    from repro.kernels import ops as kops
    from repro.kernels import vote as kvote

    rng = np.random.default_rng(0)
    a = rng.standard_normal((300, 7)).astype(np.float32)
    b = a.copy()
    c = a.copy()
    bad = a.copy()
    bad[13, 2] = np.float32(1e30)          # any single-copy corruption
    fixed = np.asarray(kops.vote3(jnp.asarray(bad), jnp.asarray(b),
                                  jnp.asarray(c)))
    assert np.array_equal(fixed, a)
    # and the op is literally the Pallas kernel, not a reimplementation
    import inspect
    assert "vote3_tiles" in inspect.getsource(kops.vote3)
    assert kvote.vote3_tiles is not None
