"""Mesh-sharded detection & recovery conformance (DESIGN.md §5).

Two tiers:

* **in-process mesh tests** — run when the process already has >= 8
  devices (the CI ``sharded`` job forces them with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8``; a plain
  1-device tier-1 run skips them):
    - shard digests bit-identical to the single-device uint32 oracle,
    - fault-flag all-reduce correctness + (leaf, shard) attribution,
    - partial-refresh contract on sharded generation tables,
    - shard-local recovery restores ONLY the injured shard,
    - donation + in-step-fused composition on the mesh,
    - campaign mesh regime reports the same outcomes as single-device.

* **a subprocess conformance smoke** — always runs (like the pipeline/MoE
  mesh tests): forces an 8-device CPU mesh in a child process and asserts
  the core contract (oracle bit-exactness, all-reduced flag, 1 launch +
  1 scalar sync per steady-state step), so the default tier-1 suite
  exercises the sharded path on every run.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp
import numpy as np

MESHABLE = len(jax.devices()) >= 8
mesh8 = pytest.mark.skipif(
    not MESHABLE,
    reason="needs >= 8 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _ctx():
    from repro.distributed.context import DistContext
    return DistContext.for_mesh(jax.make_mesh((4, 2), ("data", "model")))


def _toy_tree(ctx):
    """Small tree covering the spec zoo: dim-0/dim-1/two-axis sharding,
    flat all-axis sharding, bf16, replicated matrix, replicated scalar."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    def put(x, *spec):
        return jax.device_put(x, NamedSharding(ctx.mesh, P(*spec)))

    k = jax.random.PRNGKey
    return {
        "w_data": put(jax.random.normal(k(0), (16, 64)), "data", None),
        "w_model": put(jax.random.normal(k(1), (8, 32)), None, "model"),
        "w_both": put(jax.random.normal(k(2), (8, 16)), "data", "model"),
        "bf16": put(jax.random.normal(k(3), (64, 8)).astype(jnp.bfloat16),
                    ("data", "model"), None),
        "repl": put(jax.random.normal(k(4), (4, 4))),
        "counter": put(jnp.int32(3)),
    }


@mesh8
def test_shard_digests_bitexact_vs_single_device_oracle():
    from repro.kernels import digest as kd

    ctx = _ctx()
    tree = _toy_tree(ctx)
    plan = kd.sharded_plan_for(tree, ctx.mesh)
    assert plan.n_shards == 8
    table = np.asarray(plan.digest_table(tree))          # (8, L, 2)
    assert table.shape == (8, plan.n_leaves, 2)
    for i, key in enumerate(plan.keys):
        oracle = kd.host_shard_checksums(tree[key])
        assert np.array_equal(table[:, i], oracle), key
    # replicated leaves digest identically on every shard
    ri = plan.index_of("repl")
    assert all(np.array_equal(table[d, ri], table[0, ri]) for d in range(8))


@mesh8
def test_fault_flag_reduction_and_shard_attribution():
    from repro.core.detect import ChecksumCanary
    from repro.kernels import digest as kd

    ctx = _ctx()
    tree = _toy_tree(ctx)
    canary = ChecksumCanary(tree, n_slices=1, ctx=ctx)
    assert canary.check(0, tree) is None                 # clean: no fire

    # flip one element that lives on exactly one device's shard:
    # w_both (8, 16) P("data","model") -> local (2, 8); element [3, 9]
    # sits at data-row 1, model-col 1 => mesh position (1, 1) = shard 3
    bad = dict(tree)
    bad["w_both"] = tree["w_both"].at[3, 9].set(99.0)
    rep = canary.check(0, bad)
    assert rep is not None and rep.detector == "checksum"
    assert rep.leaves == ["w_both"]
    assert rep.shards == {"w_both": [3]}

    # a replicated leaf corrupts every shard's copy -> all shards named
    bad2 = dict(tree)
    bad2["repl"] = tree["repl"].at[1, 1].set(99.0)
    rep2 = canary.check(0, bad2)
    assert rep2 is not None and rep2.shards == {"repl": list(range(8))}

    # steady-state accounting: the check is 1 launch + 1 scalar sync
    kd.STATS.reset()
    assert canary.check(0, tree) is None
    assert kd.STATS.snapshot() == (1, 1, 0)


@mesh8
def test_partial_refresh_patches_without_generation_bump():
    """The refresh(keys=...) contract on SHARDED tables: named leaves'
    rows are patched in both generations (all shards), the generation is
    NOT bumped, and unrelated slices' references survive — the donated
    pair keeps passing mid-rotation."""
    from repro.core.detect import ChecksumCanary

    ctx = _ctx()
    tree = _toy_tree(ctx)
    canary = ChecksumCanary(tree, n_slices=3, ctx=ctx)

    state = tree
    for s in range(3):                                   # settle a rotation
        canary.arm_current(s, state)
        assert canary.check(s, state) is None

    gen = canary.generation
    # "repair" one leaf (new bytes) and partial-refresh just its rows
    state = dict(state)
    state["w_data"] = state["w_data"] * jnp.float32(1.5)
    canary.refresh(state, keys=["w_data"])
    assert canary.generation == gen, \
        "partial refresh must not bump the generation"

    # the repaired leaf certifies, and every UNRELATED slice's armed
    # reference is still valid through a full donated-pair rotation
    for s in range(3, 6):
        assert canary.check(s, state) is None, s
        canary.arm_current(s + 1, state)


@pytest.fixture(scope="module")
def mesh_train():
    """Shared sharded smoke train state + pinned step (compiled once)."""
    if not MESHABLE:
        pytest.skip("needs >= 8 devices")
    from repro.configs import get_config
    from repro.data.pipeline import TokenPipeline
    from repro.launch.specs import bind_state
    from repro.train.loop import make_train_state, make_train_step

    cfg = get_config("iterpro-100m").smoke()
    ctx = _ctx()
    B, S = 8, 32
    pipe = TokenPipeline(cfg.model.vocab_size, S, B, seed=0)
    state = make_train_state(cfg, jax.random.PRNGKey(0), global_batch=B)
    state, raw, bfn, sh = bind_state(
        ctx, cfg, state, make_train_step(cfg, global_batch=B),
        lambda s: pipe.batch_at(s))
    step = jax.jit(raw)
    st, m = step(state, bfn(0))
    jax.block_until_ready(m["loss"])
    return cfg, ctx, state, sh, raw, step, bfn


@mesh8
def test_shard_local_recovery_restores_only_injured_shard(mesh_train):
    from repro.core.detect import ChecksumCanary
    from repro.core.faults import InjectionPlan, inject
    from repro.core.icp import promote
    from repro.core.microcheckpoint import MicroCheckpointer
    from repro.core.recover import RecoveryRuntime
    from repro.core.recovery_table import RUNG_SHARD

    cfg, ctx, state0, sh, raw, step, bfn = mesh_train
    clone = lambda t: jax.tree_util.tree_map(
        lambda x: jnp.array(x, copy=True), t)

    micro = MicroCheckpointer(interval=2, ctx=ctx)
    canary = ChecksumCanary(state0, n_slices=1, ctx=ctx)
    runtime = RecoveryRuntime(step_fn=step, batch_fn=bfn,
                              iv_registry=promote(cfg, 8), micro=micro,
                              shardings=sh)
    state = clone(state0)
    for s in range(4):
        micro.maybe_snapshot(s, state)
        ns, m = step(state, bfn(s))
        assert canary.check_and_arm(s, state, ns) is None
        state = ns
    micro.maybe_snapshot(4, state)                   # version-matched snap
    truth = jax.tree_util.tree_map(np.asarray, state)

    bad = inject(state, InjectionPlan("groups/0/0/ffn/up/w", 1000, 30, 0,
                                      "params"))
    # shard ids in FaultReport.shards are MESH-FLAT indices
    # (kernels.digest.mesh_device_order), not jax device ids — key the
    # pointer probes the same way
    from repro.kernels.digest import mesh_device_order
    flat = {dev: d for d, dev in enumerate(mesh_device_order(ctx.mesh))}
    leaf = bad["params"]["groups"][0][0]["ffn"]["up"]["w"]
    ptrs = {flat[sl.device]: sl.data.unsafe_buffer_pointer()
            for sl in leaf.addressable_shards}
    shard_bytes = leaf.addressable_shards[0].data.nbytes

    ns, m = step(bad, bfn(4))
    rep = canary.check_and_arm(4, bad, ns)
    assert rep is not None and rep.shards, rep
    injured = rep.shards["params/groups/0/0/ffn/up/w"]

    fixed, ev = runtime.recover(bad, rep, 4)
    assert ev.rung == RUNG_SHARD, ev
    # ONLY the injured shards' bytes moved host->device
    assert ev.bytes_moved == shard_bytes * len(injured), ev.bytes_moved
    healed = fixed["params"]["groups"][0][0]["ffn"]["up"]["w"]
    for sl in healed.addressable_shards:
        d = flat[sl.device]
        if d in injured:
            assert sl.data.unsafe_buffer_pointer() != ptrs[d]
        else:                      # healthy shards keep their exact buffer
            assert sl.data.unsafe_buffer_pointer() == ptrs[d]
    # and the patch is bit-exact against the pre-injection truth
    for a, b in zip(jax.tree_util.tree_leaves(fixed),
                    jax.tree_util.tree_leaves(truth)):
        assert np.array_equal(np.asarray(a), b)

    # version mismatch => the rung must abort into replay, never mix
    # state versions: advance one step past the snapshot, re-inject
    state = fixed
    ns, m = step(state, bfn(5))
    canary.refresh(state)
    bad = inject(state, InjectionPlan("groups/0/0/ffn/up/w", 1000, 30, 0,
                                      "params"))
    ns, m = step(bad, bfn(5))
    rep = canary.check_and_arm(5, bad, ns)
    assert rep is not None
    fixed2, ev2 = runtime.recover(bad, rep, 5)
    assert ev2.rung == "replay", ev2
    assert "shard_patch" in ev2.attempted, ev2


@mesh8
def test_donation_and_fused_detect_compose_on_mesh(mesh_train):
    """donate + fused-detect on the mesh: bit-identical trajectory to the
    plain sharded step, 1 combined launch + 1 scalar sync per step."""
    from repro.core.detect import ChecksumCanary
    from repro.kernels import digest as kd

    cfg, ctx, state0, sh, raw, step, bfn = mesh_train
    clone = lambda t: jax.tree_util.tree_map(
        lambda x: jnp.array(x, copy=True), t)
    K = 2

    # truth: plain sharded steps
    truth = clone(state0)
    for s in range(2 * K):
        truth, _ = step(truth, bfn(s))
    truth = jax.tree_util.tree_map(np.asarray, truth)

    state = clone(state0)
    canary = ChecksumCanary(state, n_slices=K, ctx=ctx)
    factory = canary.fuse_into_step(raw, donate=True)
    for s in range(K):                                   # warm rotation
        state, m, rep = factory.step(s, state, bfn(s))
        assert rep is None
    kd.STATS.reset()
    for s in range(K, 2 * K):
        state, m, rep = factory.step(s, state, bfn(s))
        assert rep is None
    launches, syncs, traces = kd.STATS.snapshot()
    assert (launches, syncs, traces) == (K, K, 0)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(truth)):
        assert np.array_equal(np.asarray(a), b)


@mesh8
@pytest.mark.slow
def test_campaign_mesh_regime_outcome_conformance():
    """The seeded conformance campaign on the mesh must classify every
    constructed plan exactly like the single-device regimes (same
    outcome, same detector, recovered + exact), with recovery through
    either the shard_patch rung (version-matched snapshot: injections at
    even steps under interval=2) or replay."""
    import random

    from benchmarks._campaign import Campaign
    from repro.core import InjectionPlan
    from repro.core.recovery_table import RUNG_EQ1, RUNG_REPLAY, RUNG_SHARD

    campaign = Campaign(total_steps=8, snapshot_interval=2, seed=0,
                        ctx=_ctx())

    # expectations mirror tests/test_faults_campaign.py's single-device
    # CASES (same outcome + detector per regime); only the rung may
    # differ on the mesh: a version-matched snapshot (even-step
    # injection, interval 2, latency-0 checksum detection) upgrades the
    # full replay to the byte-minimal shard_patch.
    cases = [
        # (name, plan, canary (detector, rung), donated (detector, rung))
        ("norm-scale-b30",
         InjectionPlan("final_norm/scale", 3, 30, 2, "params"),
         ("nonfinite", RUNG_REPLAY),    # free trap fires before the canary
         ("checksum", RUNG_REPLAY)),    # pre-step check beats the traps
        ("ffn-b30-dormant",
         InjectionPlan("groups/0/0/ffn/up/w", 1000, 30, 3, "params"),
         ("checksum", RUNG_REPLAY),     # odd step: no version-matched snap
         ("checksum", RUNG_REPLAY)),
        ("wq-b27-benign",
         InjectionPlan("groups/0/0/attn/wq/w", 500, 27, 2, "params"),
         ("checksum", RUNG_SHARD),      # snapshot @2 == detection step 2
         ("checksum", RUNG_REPLAY)),
        ("iv-step-b12",
         InjectionPlan("step", 0, 12, 2, "iv"),
         ("checksum", RUNG_EQ1),        # IV block: Eq.(1) partner repair
         ("checksum", RUNG_REPLAY)),
    ]
    for name, plan, (det, rung), (ddet, drung) in cases:
        trial = campaign.run_trial(random.Random(0), plan=plan,
                                   use_canary=True, canary_slices=1)
        assert trial.outcome == "crash", (name, trial)
        assert trial.detector == det, (name, trial)
        assert trial.recovered and trial.exact, (name, trial)
        assert trial.rung == rung, (name, trial)
        assert 0 <= trial.latency_steps <= 1, (name, trial)

        donated = campaign.run_trial(random.Random(0), plan=plan,
                                     use_canary=True, canary_slices=1,
                                     donate=True)
        assert donated.outcome == "crash", (name, donated)
        assert donated.detector == ddet, (name, donated)
        assert donated.recovered and donated.exact, (name, donated)
        # donation kills the live buffers: unconditional replay pivot
        assert donated.rung == drung, (name, donated)


def test_single_axis_mesh_specs_degrade_to_pure_dp():
    """Regression: a pure data-parallel mesh ("--mesh 4" -> ("data",))
    has no "model" axis; every tensor-parallel spec rule must degrade to
    replication instead of raising KeyError.  Spec generation is
    allocation-free (ShapeDtypeStructs), so this runs on any device
    count."""
    from jax.sharding import Mesh
    from repro.configs import get_config
    from repro.distributed.context import DistContext
    from repro.launch.specs import state_shardings, state_struct

    cfg = get_config("iterpro-100m").smoke()
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("data",))
    ctx = DistContext.for_mesh(mesh)
    assert ctx.tp_size == 1
    sh, specs = state_shardings(ctx, cfg, state_struct(cfg, 4))
    # no spec may name the absent axis
    for spec in jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: hasattr(x, "index")):
        for entry in spec:
            names = (entry,) if isinstance(entry, str) else (entry or ())
            assert "model" not in names, spec


def test_recovery_table_sharded_ladders():
    """RecoveryTable.build(sharded=True) leads every non-IV ladder with
    the shard_patch rung; IV ladders keep Eq.(1) first (device-count
    independent — the table is pure metadata)."""
    from repro.core.recovery_table import (
        RUNG_EQ1,
        RUNG_SHARD,
        RecoveryTable,
    )

    state = {"params": {"w": np.zeros((4, 4), np.float32)},
             "iv": {"step": np.int32(0), "pos": np.int32(0)}}
    table = RecoveryTable.build(state, sharded=True)
    assert table.lookup("params/w").ladder[0] == RUNG_SHARD
    iv_entry = table.lookup("iv/step")
    assert RUNG_SHARD not in iv_entry.ladder
    assert iv_entry.ladder[0] == RUNG_EQ1
    # default build stays shard-free (single-device loops)
    assert RUNG_SHARD not in RecoveryTable.build(state).lookup(
        "params/w").ladder


# ---------------------------------------------------------------------------
# always-run subprocess smoke (the default tier-1 session has 1 device)
# ---------------------------------------------------------------------------

SHARDED_PROG = textwrap.dedent("""
    import os, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distributed.context import DistContext
    from repro.core.detect import ChecksumCanary
    from repro.kernels import digest as kd

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    ctx = DistContext.for_mesh(mesh)
    put = lambda x, *s: jax.device_put(x, NamedSharding(mesh, P(*s)))
    k = jax.random.PRNGKey
    tree = {
        "a": put(jax.random.normal(k(0), (16, 64)), "data", None),
        "b": put(jax.random.normal(k(1), (8, 32)), None, "model"),
        "c": put(jax.random.normal(k(2), (64,)).astype(jnp.bfloat16),
                 ("data", "model")),
        "s": put(jnp.int32(7)),
    }
    plan = kd.sharded_plan_for(tree, mesh)
    table = np.asarray(plan.digest_table(tree))
    oracle = all(np.array_equal(table[:, i],
                                kd.host_shard_checksums(tree[key]))
                 for i, key in enumerate(plan.keys))

    canary = ChecksumCanary(tree, n_slices=1, ctx=ctx)
    clean = canary.check(0, tree) is None
    kd.STATS.reset()
    canary.check(1, tree)
    acct = kd.STATS.snapshot()

    bad = dict(tree)
    bad["b"] = tree["b"].at[0, 20].set(99.0)   # model col 1 -> shards 1,3,5,7
    rep = canary.check(2, bad)
    print(json.dumps({
        "oracle": bool(oracle), "clean": bool(clean),
        "launches": acct[0], "syncs": acct[1], "traces": acct[2],
        "leaves": rep.leaves if rep else None,
        "shards": rep.shards if rep else None,
    }))
""")


def test_sharded_conformance_subprocess():
    """Core mesh contract on a forced 8-device child process: per-shard
    oracle bit-exactness, all-reduced flag, 1 launch + 1 scalar sync."""
    out = subprocess.run([sys.executable, "-c", SHARDED_PROG],
                         capture_output=True, text=True,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))),
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    data = json.loads(out.stdout.strip().splitlines()[-1])
    assert data["oracle"] is True
    assert data["clean"] is True
    assert (data["launches"], data["syncs"], data["traces"]) == (1, 1, 0)
    assert data["leaves"] == ["b"]
    assert data["shards"] == {"b": [1, 3, 5, 7]}
