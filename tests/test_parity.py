"""Unit tests for the device-resident XOR parity layer (core/parity.py
+ the ``parity_xor`` recovery rung).

Covers the PR's satellite checklist:

* incremental parity maintained through the canary's launches is
  bit-exact to a from-scratch rebuild of the same state version;
* a FINITE bit flip is localised (trial reconstruction against the
  canary's reference digest — the non-finite-only scan the seed used is
  blind to it) and repaired bit-exactly;
* a wholly LOST shard (zero-wiped, external attribution — nothing for a
  non-finite scan to see) reconstructs bit-exactly with 0 replayed
  steps and 0 host-snapshot bytes;
* two injured shards of one leaf escalate (single parity reconstructs
  exactly one);
* an uncovered-leaf-only report aborts up front;
* on a mesh: the parity slice map derives from each leaf's actual
  NamedSharding slices — a TP-sharded/DP-replicated leaf dedupes its
  replicas to unique logical blocks (XOR over an even replica count
  self-cancels), and a wiped TP slice reconstructs on every replica.
"""

import dataclasses
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ChecksumCanary,
    FaultReport,
    MicroCheckpointer,
    ParityStore,
    RecoveryFailed,
    RecoveryRuntime,
    inject,
    promote,
    sample_plan,
)
from repro.core.recovery_table import RUNG_PARITY


def _runtime(tiny_setup, **kw):
    cfg, state0, step, bfn = tiny_setup
    micro = MicroCheckpointer(interval=4)
    return RecoveryRuntime(step_fn=step, batch_fn=bfn,
                           iv_registry=promote(cfg, 2), micro=micro, **kw)


def _leaf(state, key):
    from repro.kernels.ops import leaf_key
    flat = jax.tree_util.tree_flatten_with_path(state)[0]
    return {leaf_key(p): v for p, v in flat}[key]


def _wipe_block(state, ps, key, blk, value=0.0):
    """Zero exactly parity block ``blk`` of ``key`` — the plan's own
    boundaries define what "one shard" means off-mesh."""
    leaf = _leaf(state, key)
    csum = np.cumsum((0,) + ps.plan.block_sizes[key])
    lo, hi = int(csum[blk]), min(int(csum[blk + 1]), leaf.size)
    flat = np.asarray(leaf).ravel().copy()
    flat[lo:hi] = value
    bad_leaf = jnp.asarray(flat.reshape(leaf.shape))

    def swap(path, x):
        from repro.kernels.ops import leaf_key
        return bad_leaf if leaf_key(path) == key else x

    return jax.tree_util.tree_map_with_path(swap, state)


def test_incremental_update_equals_rebuild(tiny_setup):
    """Parity maintained incrementally inside check_and_arm's launch over
    several steps == a from-scratch rebuild of the final state."""
    cfg, state0, step, bfn = tiny_setup
    canary = ChecksumCanary(state0, n_slices=2)
    ps = ParityStore(state0)
    ps.build(state0, 0)
    canary.attach_parity(ps)
    st = state0
    for s in range(4):
        ns, _ = step(st, bfn(s))
        assert canary.check_and_arm(s, st, ns) is None
        st = ns
    fresh = ParityStore(st)
    fresh.build(st, 4)
    assert np.array_equal(np.asarray(ps.parity), np.asarray(fresh.parity))
    assert ps.version == 4


def test_finite_flip_localized_and_repaired(tiny_setup):
    """A low-mantissa bit flip is invisible to non-finite scans; the rung
    must localise it by trial reconstruction against the canary's
    reference digest and repair bit-exactly (no snapshot, no replay)."""
    cfg, state0, step, bfn = tiny_setup
    canary = ChecksumCanary(state0, n_slices=1)
    ps = ParityStore(state0)
    ps.build(state0, 0)
    plan = dataclasses.replace(
        sample_plan(random.Random(7), state0, max_step=1, target="params"),
        bit=3)                       # finite everywhere, loss-invisible
    bad = inject(state0, plan)
    report = canary.check(0, bad)
    assert report is not None and report.leaves == ["params/" + plan.leaf]

    rt = _runtime(tiny_setup, parity=ps, canary=canary)
    fixed, ev = rt.recover(bad, report, 0, ladder=[RUNG_PARITY])
    assert ev.rung == RUNG_PARITY
    assert ev.steps_replayed == 0
    for a, b in zip(jax.tree_util.tree_leaves(fixed),
                    jax.tree_util.tree_leaves(state0)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_lost_whole_shard_reconstructs(tiny_setup):
    """A zero-wiped shard with explicit external attribution (a lost
    device's slice: nothing non-finite to scan for) reconstructs
    bit-exactly from survivors + parity."""
    cfg, state0, step, bfn = tiny_setup
    ps = ParityStore(state0)
    ps.build(state0, 0)
    key = "params/final_norm/scale"
    assert ps.covers(key)
    bad = _wipe_block(state0, ps, key, 0)
    report = FaultReport(0, "external", leaves=[key], shards={key: [0]})

    rt = _runtime(tiny_setup, parity=ps)
    fixed, ev = rt.recover(bad, report, 0, ladder=[RUNG_PARITY])
    assert ev.rung == RUNG_PARITY
    assert ev.steps_replayed == 0
    assert ev.bytes_moved > 0
    assert np.array_equal(np.asarray(_leaf(fixed, key)),
                          np.asarray(_leaf(state0, key)))


def test_two_injured_shards_escalate(tiny_setup):
    """Single parity reconstructs exactly one shard per leaf — two
    injured shards must abort the rung (exact-or-abort), not guess."""
    cfg, state0, step, bfn = tiny_setup
    ps = ParityStore(state0)
    ps.build(state0, 0)
    key = "params/embed/table"
    bad = _wipe_block(_wipe_block(state0, ps, key, 0), ps, key, 2)
    report = FaultReport(0, "external", leaves=[key], shards={key: [0, 2]})
    rt = _runtime(tiny_setup, parity=ps)
    with pytest.raises(RecoveryFailed):
        rt.recover(bad, report, 0, ladder=[RUNG_PARITY])


def test_uncovered_leaf_aborts_up_front(tiny_setup):
    """An injury attributed only to uncovered leaves (the IV block) must
    abort before any reconstruction work."""
    cfg, state0, step, bfn = tiny_setup
    ps = ParityStore(state0)
    ps.build(state0, 0)
    report = FaultReport(0, "external", leaves=["iv/step"])
    rt = _runtime(tiny_setup, parity=ps)
    with pytest.raises(RecoveryFailed):
        rt.recover(state0, report, 0, ladder=[RUNG_PARITY])


@pytest.mark.skipif(len(jax.devices()) < 4,
                    reason="needs a multi-device mesh")
def test_tp_sharded_slice_map_regression():
    """The parity slice map must derive from each leaf's ACTUAL
    NamedSharding slices, not a first-divisible-dim guess: a TP-sharded
    (axis 1) / DP-replicated leaf has n_model unique blocks, its
    replicas collapse onto them in the device->block map, and a wiped TP
    slice reconstructs bit-exactly on EVERY replica."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.distributed.context import DistContext

    n = len(jax.devices())
    mesh = jax.make_mesh((n // 2, 2), ("data", "model"))
    ctx = DistContext.for_mesh(mesh)
    leaf = jnp.arange(16 * 256, dtype=jnp.float32).reshape(16, 256)
    sh = NamedSharding(mesh, P(None, "model"))       # TP, DP-replicated
    tree = {"w": jax.device_put(leaf, sh)}
    ps = ParityStore(tree, ctx=ctx)
    ps.build(tree, 0)
    plan = ps.plan

    # dedup: 2 unique logical blocks (the model-axis halves), every data
    # replica mapped onto them
    assert plan.n_blocks["w"] == 2
    uniq, _ = plan.slices["w"]
    assert len(uniq) == 2
    dmap = plan.device_block["w"]
    assert len(dmap) == mesh.size and set(dmap) == {0, 1}
    assert len(plan.block_devices("w", 1)) == n // 2   # all replicas

    # wipe TP slice 1 (columns 128:) — materialises on every replica,
    # exactly as a logical write does
    wiped = np.asarray(leaf).copy()
    wiped[:, 128:] = 0.0
    bad = {"w": jax.device_put(jnp.asarray(wiped), sh)}
    rec = np.asarray(ps.reconstruct_shard(bad["w"], "w", 1))
    assert np.array_equal(rec, np.asarray(leaf)[:, 128:])

    # fully-replicated leaf: ONE unique block, reconstructable from the
    # parity stream alone (survivor set is empty)
    rleaf = jnp.arange(512, dtype=jnp.float32)
    rtree = {"w": jax.device_put(rleaf, NamedSharding(mesh, P(None)))}
    rps = ParityStore(rtree, ctx=ctx)
    rps.build(rtree, 0)
    assert rps.plan.n_blocks["w"] == 1
    rec = np.asarray(rps.reconstruct_shard(
        jax.device_put(jnp.zeros_like(rleaf),
                       NamedSharding(mesh, P(None))), "w", 0))
    assert np.array_equal(rec.ravel(), np.asarray(rleaf))
