"""Every assigned architecture must expose the EXACT config from the
assignment table, plus the shape-applicability rules."""

import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, list_archs

# (arch, L, d_model, H, KV, d_ff, vocab)
TABLE = {
    "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
    "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
    "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
    "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
    "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
    "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
    "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
    "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
    "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
    "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
}


@pytest.mark.parametrize("arch", sorted(TABLE))
def test_assigned_hyperparams(arch):
    m = get_config(arch).model
    L, d, H, KV, ff, V = TABLE[arch]
    assert m.n_layers == L
    assert m.d_model == d
    assert m.n_heads == H
    assert m.n_kv_heads == KV
    assert m.vocab_size == V
    if arch == "kimi-k2-1t-a32b":
        assert m.moe_d_ff == ff           # per-expert ff in the table
        assert (m.n_experts, m.top_k) == (384, 8)
    elif arch == "grok-1-314b":
        assert m.d_ff == ff
        assert (m.n_experts, m.top_k) == (8, 2)
    elif arch == "xlstm-350m":
        assert m.d_ff == ff               # 0: no separate FFN
    else:
        assert m.d_ff == ff


def test_all_ten_assigned():
    assert set(TABLE) == set(ASSIGNED_ARCHS)


def test_special_flags():
    assert get_config("zamba2-7b").model.ssm_state == 64
    assert get_config("gemma3-1b").model.local_global_ratio == 5
    assert get_config("gemma3-27b").model.local_global_ratio == 5
    assert get_config("h2o-danube-1.8b").model.sliding_window > 0
    assert get_config("qwen2-vl-7b").model.m_rope
    assert get_config("seamless-m4t-large-v2").model.n_enc_layers == 24


@pytest.mark.parametrize("arch", sorted(TABLE))
def test_long500k_rule(arch):
    """long_500k only for sub-quadratic archs (DESIGN.md §8)."""
    cfg = get_config(arch)
    runs = {s.name for s in cfg.shapes()}
    subq = arch in ("xlstm-350m", "h2o-danube-1.8b", "gemma3-1b",
                    "gemma3-27b", "zamba2-7b")
    assert ("long_500k" in runs) == subq


def test_smoke_configs_are_small():
    for arch in list_archs():
        sm = get_config(arch).smoke().model
        assert sm.d_model <= 64 and sm.vocab_size <= 256
