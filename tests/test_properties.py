"""Hypothesis property tests over tensors: parity reconstruction, bit-flip
detection, data-pipeline determinism and work-stealing invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")   # optional dep: skip, don't break collection
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.faults import flip_bit
from repro.data.pipeline import TokenPipeline, shard_assignment
from repro.kernels import ops, ref


@given(n_shards=st.integers(2, 6), lost=st.integers(0, 5),
       rows=st.integers(1, 40), cols=st.integers(1, 9),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_parity_reconstruct_property(n_shards, lost, rows, cols, seed):
    lost = lost % n_shards
    key = jax.random.PRNGKey(seed)
    shards = [jax.random.normal(jax.random.fold_in(key, i), (rows, cols))
              for i in range(n_shards)]
    parity = ref.xor_fold_ref(shards)
    rec = ref.xor_reconstruct_ref(parity,
                                  shards[:lost] + shards[lost + 1:])
    assert np.array_equal(np.asarray(rec), np.asarray(shards[lost]))


@given(element=st.integers(0, 999), bit=st.integers(0, 31),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=60, deadline=None)
def test_flip_bit_involution_and_detection(element, bit, seed):
    """flip∘flip = identity, and every flip changes the checksum."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (1000,))
    y = flip_bit(x, element, bit)
    z = flip_bit(y, element, bit)
    assert np.array_equal(np.asarray(x), np.asarray(z))
    assert not np.array_equal(np.asarray(x), np.asarray(y))
    assert not np.array_equal(np.asarray(ref.checksum_ref(x)),
                              np.asarray(ref.checksum_ref(y)))


@given(step=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_pipeline_index_addressable(step):
    """batch(step) is a pure function of (seed, step): recomputable at any
    time — the property the replay rung depends on."""
    p = TokenPipeline(vocab_size=128, seq_len=16, global_batch=4, seed=9)
    a = p.batch_at(step)
    b = p.batch_at(step)
    assert np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    # shards tile the global batch exactly
    full = np.asarray(a["tokens"])
    parts = [np.asarray(p.shard_at(step, i, 4)["tokens"]) for i in range(4)]
    assert np.array_equal(np.concatenate(parts, axis=0), full)


@given(step=st.integers(0, 1000),
       n=st.integers(2, 12),
       dead=st.sets(st.integers(0, 11), max_size=6))
@settings(max_examples=100, deadline=None)
def test_shard_assignment_partition(step, n, dead):
    """Deterministic work-stealing: every input slice is owned by exactly
    one healthy host, dead hosts own nothing."""
    dead = {d for d in dead if d < n}
    if len(dead) >= n:
        dead = set(list(dead)[: n - 1])
    assign = shard_assignment(step, n, tuple(dead))
    owned = [s for slices in assign.values() for s in slices]
    assert sorted(owned) == list(range(n))          # exact partition
    assert set(assign).isdisjoint(dead)             # dead own nothing
    # deterministic: same inputs -> same assignment
    assert assign == shard_assignment(step, n, tuple(dead))


@given(step=st.integers(0, 500),
       n=st.integers(2, 6),
       dead=st.sets(st.integers(0, 5), max_size=4),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_stolen_batch_rows_exactly_once(step, n, dead, seed):
    """ROW-level elastic identity (DESIGN §7): after a hard loss, the
    survivors' stolen loads contain every global-batch row exactly once,
    reassemble ``batch_at(step)`` bit-identically, and two hosts that
    compute the assignment independently agree — no coordinator round."""
    dead = {d for d in dead if d < n}
    if len(dead) >= n:
        dead = set(list(dead)[: n - 1])
    B = 2 * n                                     # per-slice rows = 2
    pipe = TokenPipeline(vocab_size=64, seq_len=8, global_batch=B,
                         seed=seed)
    ref_batch = pipe.batch_at(step)
    assign = shard_assignment(step, n, tuple(dead))

    # every global row loaded exactly once across surviving owners
    rows_seen = []
    parts = {}
    for owner, slices in assign.items():
        for sl in slices:
            parts[sl] = pipe.shard_at(step, sl, n)
            per = B // n
            rows_seen.extend(range(sl * per, (sl + 1) * per))
    assert sorted(rows_seen) == list(range(B))

    # canonical-order concatenation is THE global batch, bit-identical
    for k in ref_batch:
        stolen = np.concatenate(
            [np.asarray(parts[i][k]) for i in range(n)], axis=0)
        assert np.array_equal(stolen, np.asarray(ref_batch[k]))

    # independent hosts agree (pure function of (step, n, dead))
    assert assign == shard_assignment(step, n, tuple(sorted(dead)))

    # the dead slices' rows rotate among survivors: within one full
    # rotation period every dead slice is served by >1 distinct owner
    healthy = n - len(dead)
    if dead and healthy > 1:
        owners = {sl: set() for sl in dead}
        for s in range(step, step + healthy):
            for owner, slices in shard_assignment(s, n,
                                                  tuple(dead)).items():
                for sl in slices:
                    if sl in dead:
                        owners[sl].add(owner)
        assert all(len(o) > 1 for o in owners.values())
