"""Fused digest engine (kernels/digest.py + the reworked ChecksumCanary).

The detection-cost contract (DESIGN.md §4.2):
  * the fused whole-state digest is bit-identical to per-leaf ``checksum``;
  * a flipped bit in ANY leaf is attributed to exactly that leaf path;
  * the plan cache prevents retracing (trace counters stay flat);
  * one canary ``check_and_arm`` = exactly 1 fused launch + 1 host sync.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.detect import ChecksumCanary
from repro.core.faults import flip_bit
from repro.core.microcheckpoint import MicroCheckpointer
from repro.kernels import digest as dg
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def _tree():
    """Mixed dtypes/shapes: multi-tile, sub-tile, 16-bit, int, scalar."""
    ks = jax.random.split(KEY, 4)
    return {
        "params": {
            "w": jax.random.normal(ks[0], (257, 129)),          # 1+ tiles
            "b": jax.random.normal(ks[1], (33,)).astype(jnp.bfloat16),
        },
        "opt": {"m": jax.random.normal(ks[2], (40000,))},        # 2 tiles
        "iv": {"step": jnp.int32(12), "pos": jnp.int32(7)},
        "tok": jax.random.randint(ks[3], (17, 3), -5, 5, jnp.int32),
    }


def _leaves_by_key(tree):
    out = {}

    def visit(path, leaf):
        out[ops.leaf_key(path)] = leaf
        return leaf

    jax.tree_util.tree_map_with_path(visit, tree)
    return out


# ---------------------------------------------------------------------------
# bit-exactness
# ---------------------------------------------------------------------------

def test_fused_digest_matches_per_leaf_checksum():
    tree = _tree()
    plan = dg.plan_for(tree)
    table = np.asarray(plan.digest_table(tree))
    leaves = _leaves_by_key(tree)
    assert set(plan.keys) == set(leaves)
    for i, k in enumerate(plan.keys):
        per_leaf = np.asarray(ops.checksum(leaves[k]))
        oracle = np.asarray(ref.checksum_ref(leaves[k]))
        assert np.array_equal(table[i], per_leaf), k
        assert np.array_equal(table[i], oracle), k


def test_tree_checksums_is_fused_and_bit_exact():
    tree = _tree()
    digests = ops.tree_checksums(tree)
    for k, leaf in _leaves_by_key(tree).items():
        assert np.array_equal(digests[k], np.asarray(ops.checksum(leaf))), k


def test_subtree_checksums_subset():
    tree = _tree()
    full = ops.tree_checksums(tree)
    sub = ops.subtree_checksums(tree, ["opt/m", "iv/step"])
    assert set(sub) == {"opt/m", "iv/step"}
    for k, v in sub.items():
        assert np.array_equal(v, full[k])


# ---------------------------------------------------------------------------
# attribution
# ---------------------------------------------------------------------------

def test_flip_in_any_leaf_attributed_to_exactly_that_leaf():
    tree = _tree()
    reference = ops.tree_checksums(tree)
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    for j, (path, leaf) in enumerate(flat):
        key = ops.leaf_key(path)
        bit = 3 if np.asarray(leaf).dtype.itemsize * 8 > 3 else 0
        corrupted = jax.tree_util.tree_unflatten(
            treedef,
            [flip_bit(x, 0, bit) if i == j else x
             for i, (_, x) in enumerate(flat)])
        assert ops.verify_tree(corrupted, reference) == [key]


def test_canary_names_dormant_flip_in_armed_window():
    """Corruption landing in a slice between its arm and its check — the
    window the rotating canary guards — is caught at that slice's next
    check and attributed to exactly the corrupted leaf."""
    tree = _tree()
    K = 3
    canary = ChecksumCanary(tree, n_slices=K)
    target_slice = list(canary._keys).index("opt/m") % K
    bad = dict(tree, opt={"m": flip_bit(tree["opt"]["m"], 11, 4)})
    reports = []
    for s in range(K, 2 * K):
        # the flip manifests while slice `target_slice` is armed: present
        # the corrupted state at that slice's check step
        seen = bad if s % K == target_slice else tree
        reports.append(canary.check_and_arm(s, seen))
    hits = [r for r in reports if r is not None]
    assert len(hits) == 1
    assert hits[0].leaves == ["opt/m"]


# ---------------------------------------------------------------------------
# hot-path accounting: launches / syncs / retraces
# ---------------------------------------------------------------------------

def test_check_and_arm_is_one_launch_one_sync_no_retrace():
    tree = _tree()
    assert len(jax.tree_util.tree_leaves(tree)) > 4   # multi-leaf state
    canary = ChecksumCanary(tree, n_slices=4)
    for s in range(8):                                # warm every rotation
        canary.check_and_arm(s, tree)
    dg.STATS.reset()
    for s in range(8, 16):
        assert canary.check_and_arm(s, tree) is None
    launches, syncs, traces = dg.STATS.snapshot()
    assert launches == 8     # exactly ONE fused launch per step
    assert syncs == 8        # exactly ONE device→host transfer per step
    assert traces == 0       # plan/jit caches prevent any retracing


def test_tree_checksums_one_launch_one_sync():
    tree = _tree()
    ops.tree_checksums(tree)                          # warm/compile
    dg.STATS.reset()
    ops.tree_checksums(tree)
    launches, syncs, traces = dg.STATS.snapshot()
    assert (launches, syncs, traces) == (1, 1, 0)


def test_plan_cache_reuses_plan_and_compiled_fns():
    tree = _tree()
    plan = dg.plan_for(tree)
    same_structure = jax.tree_util.tree_map(lambda x: x + 0, tree)
    assert dg.plan_for(same_structure) is plan
    plan.digest_table(tree)                           # warm
    dg.STATS.reset()
    plan.digest_table(same_structure)                 # same structure ->
    assert dg.STATS.traces == 0                       # no retrace
    # a different structure gets its own plan
    other = {"x": jnp.ones((5,))}
    assert dg.plan_for(other) is not plan


def test_canary_instances_share_compiled_step_fns():
    """One canary per campaign trial must not recompile the fused step."""
    tree = _tree()
    c1 = ChecksumCanary(tree, n_slices=2)
    for s in range(4):
        c1.check_and_arm(s, tree)
    dg.STATS.reset()
    c2 = ChecksumCanary(tree, n_slices=2)             # fresh instance
    for s in range(4):
        c2.check_and_arm(s, tree)
    assert dg.STATS.traces == 0


# ---------------------------------------------------------------------------
# consumers
# ---------------------------------------------------------------------------

def test_micro_snapshot_single_pass_digests_and_cached_memory():
    tree = _tree()
    micro = MicroCheckpointer(interval=1, keep=2)
    micro.snapshot(0, tree)
    snap = micro.snapshots[-1]
    # digests certify the stored bytes and match the live state's digests
    assert micro.verify(snap) == []
    live = ops.tree_checksums(tree)
    assert all(np.array_equal(snap.digests[k], live[k]) for k in live)
    # memory accounting cached at snapshot time, no re-materialisation
    want = sum(np.asarray(x).nbytes for x in jax.tree_util.tree_leaves(tree))
    assert snap.nbytes == want
    micro.snapshot(1, tree)
    assert micro.memory_bytes == 2 * want


def test_refresh_subset_updates_reference_rows():
    tree = _tree()
    canary = ChecksumCanary(tree, n_slices=1)
    bad = dict(tree, opt={"m": flip_bit(tree["opt"]["m"], 2, 8)})
    assert canary.check(0, bad) is not None
    canary.refresh(bad, keys=["opt/m"])
    assert canary.check(0, bad) is None
    # and the rest of the table still guards the untouched leaves
    worse = dict(bad, tok=flip_bit(bad["tok"], 1, 0))
    report = canary.check(0, worse)
    assert report is not None and report.leaves == ["tok"]


def test_partial_refresh_keeps_generation_and_unrelated_slices():
    """Regression for the partial-refresh contract (see
    ``ChecksumCanary.refresh``): an explicit ``keys=`` refresh must NOT
    bump the generation and must not invalidate any other slice's armed
    reference.  A generation bump here would swap the read/write roles of
    the double-buffered pair mid-rotation, so the donated pair's next
    ``check`` would verify a slice against rows armed two generations ago
    (an older state version) and fire a spurious fault."""
    tree = _tree()
    canary = ChecksumCanary(tree, n_slices=3)
    step = _toy_step()

    # donated-style pair over a MUTATING state: every check verifies the
    # same version the matching arm digested
    state = jax.tree_util.tree_map(lambda x: jnp.array(x, copy=True), tree)
    for s in range(3):
        canary.arm_current(s, state)
        assert canary.check(s, state) is None
        state = step(state)

    gen = canary.generation
    canary.arm_current(3, state)
    # mid-generation targeted repair of ONE leaf (its row is patched in
    # both tables; nothing else may change)
    canary.refresh(state, keys=["opt/m"])
    assert canary.generation == gen + 1  # only arm_current's own bump
    # the pending slice's armed reference must still verify, and the
    # following full rotation must stay trap-free
    assert canary.check(3, state) is None
    state = step(state)
    for s in range(4, 7):
        canary.arm_current(s, state)
        assert canary.check(s, state) is None, s
        state = step(state)


# ---------------------------------------------------------------------------
# donation contract: the resilient hot path survives donate_argnums
# ---------------------------------------------------------------------------

def _toy_step():
    """Structure/dtype-preserving donated step over ``_tree()`` states."""
    def upd(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return (x * jnp.asarray(1.01, x.dtype)).astype(x.dtype)
        return x + jnp.ones((), x.dtype)
    return jax.jit(lambda t: jax.tree_util.tree_map(upd, t),
                   donate_argnums=(0,))


def _host_leaves(tree):
    # copy via a device temp: converting the live array to numpy can
    # cache a host view on it and silently veto the donation this test
    # asserts (see microcheckpoint._host_copy)
    return {k: np.asarray(jnp.array(v, copy=True))
            for k, v in _leaves_by_key(tree).items()}


def test_donated_step_deletes_prestep_and_digests_survive():
    """The core donation contract: after the donated step consumes the
    pre-step buffers, (a) they are really gone (``is_deleted``), and
    (b) their digests — armed at the buffer's last readable moment —
    survive in the read-generation table, bit-identical to the per-leaf
    oracle of the (now unreachable) pre-step bytes."""
    state = _tree()
    dstep = _toy_step()
    K = 2
    canary = ChecksumCanary(state, n_slices=K)
    for s in range(2 * K):
        # donated pair: arm slice s%K, verify the same slice/version
        canary.arm_current(s, state)
        host = _host_leaves(state)          # oracle copy, survives donation
        assert canary.check(s, state) is None
        old_leaves = jax.tree_util.tree_leaves(state)
        state = dstep(state)
        # (a) the pre-step buffer is deleted — donation really happened
        assert all(l.is_deleted() for l in old_leaves)
        # (b) the armed digests outlive it, bit-identical to the oracle
        surviving = canary.reference_digests()
        for i in canary._slice_indices(s):
            key = canary._keys[i]
            assert np.array_equal(surviving[key],
                                  np.asarray(ref.checksum_ref(host[key]))), key


def test_donated_pair_hot_path_accounting():
    """Steady-state donated step: arm = 1 launch + 0 syncs, check =
    1 launch + 1 scalar sync (the per-call 1-launch/1-sync contract), no
    retraces, and the packing buffers are pointer-stable (zero new
    steady-state allocations on the digest path)."""
    state = _tree()
    dstep = _toy_step()
    K = 4
    canary = ChecksumCanary(state, n_slices=K)
    for s in range(K):                       # warm every rotation
        canary.arm_current(s, state)
        canary.check(s, state)
        state = dstep(state)
    ptrs = {idx: canary.plan.buffer_pointer(idx)
            for idx in list(canary.plan._pack_bufs)}
    state = dstep(state)                     # flush pointer-probe residue
    dg.STATS.reset()
    n = 2 * K
    for s in range(K, K + n):
        canary.arm_current(s, state)
        assert canary.check(s, state) is None
        state = dstep(state)
    launches, syncs, traces = dg.STATS.snapshot()
    assert launches == 2 * n     # arm + check, each ONE fused launch
    assert syncs == n            # ONLY the check syncs, one scalar
    assert traces == 0           # plan/jit caches prevent any retracing
    for idx, p in ptrs.items():  # same HBM ranges rewritten in place
        assert canary.plan.buffer_pointer(idx) == p, idx


def test_donated_flip_between_arm_and_check_is_attributed():
    """Corruption landing after the arm and before the step consumes the
    buffer — the donated protocol's guarded window — is caught by the
    check at the buffer's last readable moment and attributed to exactly
    the corrupted leaf, before the step can consume the rot."""
    state = _tree()
    dstep = _toy_step()
    canary = ChecksumCanary(state, n_slices=1)
    reports = []
    for s in range(4):
        canary.arm_current(s, state)
        seen = state
        if s == 2:                            # the adversary window
            seen = dict(state, opt={"m": flip_bit(state["opt"]["m"], 11, 4)})
        reports.append(canary.check(s, seen))
        state = dstep(seen)
    hits = [r for r in reports if r is not None]
    assert len(hits) == 1
    assert hits[0].leaves == ["opt/m"]


def test_full_refresh_bumps_generation_and_survives_restore():
    """Regression (donation + restore): a full ``refresh`` must BUMP the
    table generation so the fresh digests become the read generation —
    without the bump the first post-restore check under donation verifies
    the restored state against the stale pre-restore generation and fires
    a spurious checksum fault."""
    state = _tree()
    dstep = _toy_step()
    K = 2
    canary = ChecksumCanary(state, n_slices=K)
    restore_point = jax.tree_util.tree_map(lambda x: jnp.array(x, copy=True),
                                           state)
    for s in range(2 * K):                    # advance the donated loop
        canary.arm_current(s, state)
        assert canary.check(s, state) is None
        state = dstep(state)

    # cold restore to the step-0 state: the tables hold digests of a
    # far-future generation until refresh installs the restored digests
    state = restore_point
    g0 = canary.generation
    canary.refresh(state)
    assert canary.generation > g0             # the load-bearing bump
    # first post-restore check must NOT fire spuriously...
    assert canary.check(0, state) is None
    # ...the donated pair protocol resumes cleanly...
    for s in range(K):
        canary.arm_current(s, state)
        assert canary.check(s, state) is None
        state = dstep(state)
    # ...and a real flip is still caught and attributed ("tok" is an
    # odd-index plan leaf, so an odd step's slice covers it)
    bad = dict(state, tok=flip_bit(state["tok"], 1, 0))
    s = K + 1
    assert canary.plan.index_of("tok") % K == s % K
    canary.arm_current(s, state)
    report = canary.check(s, bad)
    assert report is not None and report.leaves == ["tok"]


# ---------------------------------------------------------------------------
# host digest path: snapshot certification without device re-upload
# ---------------------------------------------------------------------------

def test_host_checksum_matches_oracle_all_dtypes():
    key = jax.random.PRNGKey(3)
    arrays = [
        jax.random.normal(key, (129, 7)),                     # f32, odd
        jax.random.normal(key, (33,)).astype(jnp.bfloat16),   # bf16
        jax.random.normal(key, (5, 5)).astype(jnp.float16),   # f16
        jnp.arange(-7, 9, dtype=jnp.int32),                   # i32
        jnp.arange(-4, 5, dtype=jnp.int8),                    # i8
        jnp.int32(42),                                        # scalar
    ]
    for a in arrays:
        host = np.asarray(a)
        assert np.array_equal(dg.host_checksum(host),
                              np.asarray(ref.checksum_ref(a))), a.dtype


def test_snapshot_digests_are_host_side_and_bit_exact():
    """Snapshot certification must never touch the device: zero digest
    launches/syncs counted, yet the stored digests are bit-identical to
    the device engine's over the same bytes."""
    tree = _tree()
    live = ops.tree_checksums(tree)           # device digests (warm)
    micro = MicroCheckpointer(interval=1)
    dg.STATS.reset()
    micro.snapshot(0, tree)
    snap = micro.snapshots[-1]
    assert micro.verify(snap) == []
    launches, syncs, traces = dg.STATS.snapshot()
    assert launches == 0 and syncs == 0       # pure host DMA path
    assert all(np.array_equal(snap.digests[k], live[k]) for k in live)
