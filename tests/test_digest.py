"""Fused digest engine (kernels/digest.py + the reworked ChecksumCanary).

The detection-cost contract (DESIGN.md §4.2):
  * the fused whole-state digest is bit-identical to per-leaf ``checksum``;
  * a flipped bit in ANY leaf is attributed to exactly that leaf path;
  * the plan cache prevents retracing (trace counters stay flat);
  * one canary ``check_and_arm`` = exactly 1 fused launch + 1 host sync.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.detect import ChecksumCanary
from repro.core.faults import flip_bit
from repro.core.microcheckpoint import MicroCheckpointer
from repro.kernels import digest as dg
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def _tree():
    """Mixed dtypes/shapes: multi-tile, sub-tile, 16-bit, int, scalar."""
    ks = jax.random.split(KEY, 4)
    return {
        "params": {
            "w": jax.random.normal(ks[0], (257, 129)),          # 1+ tiles
            "b": jax.random.normal(ks[1], (33,)).astype(jnp.bfloat16),
        },
        "opt": {"m": jax.random.normal(ks[2], (40000,))},        # 2 tiles
        "iv": {"step": jnp.int32(12), "pos": jnp.int32(7)},
        "tok": jax.random.randint(ks[3], (17, 3), -5, 5, jnp.int32),
    }


def _leaves_by_key(tree):
    out = {}

    def visit(path, leaf):
        out[ops.leaf_key(path)] = leaf
        return leaf

    jax.tree_util.tree_map_with_path(visit, tree)
    return out


# ---------------------------------------------------------------------------
# bit-exactness
# ---------------------------------------------------------------------------

def test_fused_digest_matches_per_leaf_checksum():
    tree = _tree()
    plan = dg.plan_for(tree)
    table = np.asarray(plan.digest_table(tree))
    leaves = _leaves_by_key(tree)
    assert set(plan.keys) == set(leaves)
    for i, k in enumerate(plan.keys):
        per_leaf = np.asarray(ops.checksum(leaves[k]))
        oracle = np.asarray(ref.checksum_ref(leaves[k]))
        assert np.array_equal(table[i], per_leaf), k
        assert np.array_equal(table[i], oracle), k


def test_tree_checksums_is_fused_and_bit_exact():
    tree = _tree()
    digests = ops.tree_checksums(tree)
    for k, leaf in _leaves_by_key(tree).items():
        assert np.array_equal(digests[k], np.asarray(ops.checksum(leaf))), k


def test_subtree_checksums_subset():
    tree = _tree()
    full = ops.tree_checksums(tree)
    sub = ops.subtree_checksums(tree, ["opt/m", "iv/step"])
    assert set(sub) == {"opt/m", "iv/step"}
    for k, v in sub.items():
        assert np.array_equal(v, full[k])


# ---------------------------------------------------------------------------
# attribution
# ---------------------------------------------------------------------------

def test_flip_in_any_leaf_attributed_to_exactly_that_leaf():
    tree = _tree()
    reference = ops.tree_checksums(tree)
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    for j, (path, leaf) in enumerate(flat):
        key = ops.leaf_key(path)
        bit = 3 if np.asarray(leaf).dtype.itemsize * 8 > 3 else 0
        corrupted = jax.tree_util.tree_unflatten(
            treedef,
            [flip_bit(x, 0, bit) if i == j else x
             for i, (_, x) in enumerate(flat)])
        assert ops.verify_tree(corrupted, reference) == [key]


def test_canary_names_dormant_flip_in_armed_window():
    """Corruption landing in a slice between its arm and its check — the
    window the rotating canary guards — is caught at that slice's next
    check and attributed to exactly the corrupted leaf."""
    tree = _tree()
    K = 3
    canary = ChecksumCanary(tree, n_slices=K)
    target_slice = list(canary._keys).index("opt/m") % K
    bad = dict(tree, opt={"m": flip_bit(tree["opt"]["m"], 11, 4)})
    reports = []
    for s in range(K, 2 * K):
        # the flip manifests while slice `target_slice` is armed: present
        # the corrupted state at that slice's check step
        seen = bad if s % K == target_slice else tree
        reports.append(canary.check_and_arm(s, seen))
    hits = [r for r in reports if r is not None]
    assert len(hits) == 1
    assert hits[0].leaves == ["opt/m"]


# ---------------------------------------------------------------------------
# hot-path accounting: launches / syncs / retraces
# ---------------------------------------------------------------------------

def test_check_and_arm_is_one_launch_one_sync_no_retrace():
    tree = _tree()
    assert len(jax.tree_util.tree_leaves(tree)) > 4   # multi-leaf state
    canary = ChecksumCanary(tree, n_slices=4)
    for s in range(8):                                # warm every rotation
        canary.check_and_arm(s, tree)
    dg.STATS.reset()
    for s in range(8, 16):
        assert canary.check_and_arm(s, tree) is None
    launches, syncs, traces = dg.STATS.snapshot()
    assert launches == 8     # exactly ONE fused launch per step
    assert syncs == 8        # exactly ONE device→host transfer per step
    assert traces == 0       # plan/jit caches prevent any retracing


def test_tree_checksums_one_launch_one_sync():
    tree = _tree()
    ops.tree_checksums(tree)                          # warm/compile
    dg.STATS.reset()
    ops.tree_checksums(tree)
    launches, syncs, traces = dg.STATS.snapshot()
    assert (launches, syncs, traces) == (1, 1, 0)


def test_plan_cache_reuses_plan_and_compiled_fns():
    tree = _tree()
    plan = dg.plan_for(tree)
    same_structure = jax.tree_util.tree_map(lambda x: x + 0, tree)
    assert dg.plan_for(same_structure) is plan
    plan.digest_table(tree)                           # warm
    dg.STATS.reset()
    plan.digest_table(same_structure)                 # same structure ->
    assert dg.STATS.traces == 0                       # no retrace
    # a different structure gets its own plan
    other = {"x": jnp.ones((5,))}
    assert dg.plan_for(other) is not plan


def test_canary_instances_share_compiled_step_fns():
    """One canary per campaign trial must not recompile the fused step."""
    tree = _tree()
    c1 = ChecksumCanary(tree, n_slices=2)
    for s in range(4):
        c1.check_and_arm(s, tree)
    dg.STATS.reset()
    c2 = ChecksumCanary(tree, n_slices=2)             # fresh instance
    for s in range(4):
        c2.check_and_arm(s, tree)
    assert dg.STATS.traces == 0


# ---------------------------------------------------------------------------
# consumers
# ---------------------------------------------------------------------------

def test_micro_snapshot_single_pass_digests_and_cached_memory():
    tree = _tree()
    micro = MicroCheckpointer(interval=1, keep=2)
    micro.snapshot(0, tree)
    snap = micro.snapshots[-1]
    # digests certify the stored bytes and match the live state's digests
    assert micro.verify(snap) == []
    live = ops.tree_checksums(tree)
    assert all(np.array_equal(snap.digests[k], live[k]) for k in live)
    # memory accounting cached at snapshot time, no re-materialisation
    want = sum(np.asarray(x).nbytes for x in jax.tree_util.tree_leaves(tree))
    assert snap.nbytes == want
    micro.snapshot(1, tree)
    assert micro.memory_bytes == 2 * want


def test_refresh_subset_updates_reference_rows():
    tree = _tree()
    canary = ChecksumCanary(tree, n_slices=1)
    bad = dict(tree, opt={"m": flip_bit(tree["opt"]["m"], 2, 8)})
    assert canary.check(0, bad) is not None
    canary.refresh(bad, keys=["opt/m"])
    assert canary.check(0, bad) is None
    # and the rest of the table still guards the untouched leaves
    worse = dict(bad, tok=flip_bit(bad["tok"], 1, 0))
    report = canary.check(0, worse)
    assert report is not None and report.leaves == ["tok"]
