"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracles.

checksum / vote / parity are bitwise algorithms -> exact equality.
flash attention is floating point -> assert_allclose with dtype tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(42)

SHAPES = [(7,), (128,), (4096,), (33333,), (17, 9), (128, 128), (3, 5, 7)]
DTYPES = ["float32", "bfloat16", "float16", "int32", "int8"]


def _rand(shape, dtype, key=KEY):
    if dtype in ("float32", "bfloat16", "float16"):
        return jax.random.normal(key, shape).astype(dtype)
    return jax.random.randint(key, shape, -120, 120).astype(dtype)


# ---------------------------------------------------------------------------
# checksum
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_checksum_matches_ref(shape, dtype):
    x = _rand(shape, dtype)
    assert np.array_equal(np.asarray(ops.checksum(x)),
                          np.asarray(ref.checksum_ref(x)))


def test_checksum_detects_single_bit():
    from repro.core.faults import flip_bit
    x = _rand((4096,), "float32")
    for bit in (0, 7, 23, 31):
        y = flip_bit(x, 123, bit)
        assert not np.array_equal(np.asarray(ops.checksum(x)),
                                  np.asarray(ops.checksum(y)))


def test_checksum_detects_swap():
    """Position weighting: swapping two unequal elements changes s2."""
    x = jnp.arange(100, dtype=jnp.int32)
    y = x.at[3].set(x[50]).at[50].set(x[3])
    assert not np.array_equal(np.asarray(ops.checksum(x)),
                              np.asarray(ops.checksum(y)))


# ---------------------------------------------------------------------------
# vote / parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(100,), (257, 3), (128, 128)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int32"])
def test_vote3_heals_any_single_corruption(shape, dtype):
    x = _rand(shape, dtype)
    bad = jnp.asarray(x).reshape(-1).at[7].set(0).reshape(shape)
    healed = ops.vote3(bad, x, x)
    assert np.array_equal(np.asarray(healed), np.asarray(x))
    assert np.array_equal(np.asarray(ops.vote3(x, bad, x)), np.asarray(x))
    assert np.array_equal(np.asarray(ops.vote3(x, x, bad)), np.asarray(x))


@pytest.mark.parametrize("n_shards", [2, 4, 7])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int32"])
def test_xor_reconstruct_bit_exact(n_shards, dtype):
    shards = [_rand((65, 9), dtype, jax.random.fold_in(KEY, i))
              for i in range(n_shards)]
    parity = ops.xor_fold(shards)
    for lost in range(n_shards):
        others = shards[:lost] + shards[lost + 1:]
        rec = ops.xor_reconstruct(parity, others)
        assert np.array_equal(np.asarray(rec), np.asarray(shards[lost])), \
            f"shard {lost} not reconstructed"


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # B, Sq, Sk, H, KV, D, causal, window, softcap, dtype
    (2, 128, 128, 4, 2, 32, True, 0, 0.0, "float32"),
    (1, 256, 256, 8, 8, 64, True, 64, 0.0, "float32"),
    (2, 64, 64, 4, 1, 16, True, 0, 30.0, "float32"),
    (1, 96, 96, 2, 2, 48, True, 0, 0.0, "float32"),   # non-multiple pads
    (1, 128, 128, 2, 2, 128, False, 0, 0.0, "bfloat16"),
    (1, 64, 64, 4, 4, 160, True, 0, 0.0, "float32"),  # D pads to 256
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_matches_ref(case):
    B, Sq, Sk, H, KV, D, causal, window, cap, dt = case
    ks = jax.random.split(jax.random.fold_in(KEY, hash(case) % 2**31), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D)).astype(dt)
    k = jax.random.normal(ks[1], (B, Sk, KV, D)).astype(dt)
    v = jax.random.normal(ks[2], (B, Sk, KV, D)).astype(dt)

    o = ops.flash_attention(q, k, v, causal=causal, window=window,
                            softcap=cap, block_q=32, block_k=32)

    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, Sk, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, Sk, D)
    r = ref.flash_attention_ref(qf, kf, vf, causal=causal, window=window,
                                softcap=cap)
    r = r.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)

    tol = 3e-2 if dt == "bfloat16" else 2e-5
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=tol, rtol=tol)


def test_flash_matches_model_attention():
    """The kernel agrees with the model's direct-attention path (the
    training semantics) on contiguous positions."""
    from repro.models import layers as L
    B, S, H, KV, D = 2, 64, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KV, D))
    v = jax.random.normal(ks[2], (B, S, KV, D))
    pos = L.make_positions(B, S)
    direct = L.attention_direct(q, k, v, pos, pos, window=8)
    flash = ops.flash_attention(q, k, v, causal=True, window=8,
                                block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(flash), np.asarray(direct),
                               atol=2e-5, rtol=2e-5)
