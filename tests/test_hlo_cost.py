"""The trip-count-aware HLO cost analyzer vs analytic ground truth.

Multi-device cases run in a subprocess (XLA device count is locked at
first jax init; the test session must keep seeing 1 CPU device).
"""

import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp

from conftest import requires_axis_type
from repro.launch import hlo_cost as HC


def test_single_device_matmul_flops():
    M, K, N = 64, 32, 48
    c = jax.jit(lambda a, b: a @ b).lower(
        jax.ShapeDtypeStruct((M, K), jnp.float32),
        jax.ShapeDtypeStruct((K, N), jnp.float32)).compile()
    cost = HC.analyze(c.as_text())
    assert cost.flops == 2 * M * K * N


def test_scan_trip_count_multiplies():
    M, K, T = 32, 16, 9

    def g(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=T)
        return y

    c = jax.jit(g).lower(
        jax.ShapeDtypeStruct((M, K), jnp.float32),
        jax.ShapeDtypeStruct((K, K), jnp.float32)).compile()
    cost = HC.analyze(c.as_text())
    assert cost.flops == 2 * M * K * K * T
    assert T in cost.while_trips.values()


SUBPROCESS_PROG = textwrap.dedent("""
    import os, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch import hlo_cost as HC

    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    M, K, N = 512, 256, 1024
    a = jax.ShapeDtypeStruct((M, K), jnp.float32)
    b = jax.ShapeDtypeStruct((K, N), jnp.float32)
    with mesh:
        c = jax.jit(lambda a, b: a @ b, in_shardings=(
            NamedSharding(mesh, P("data", None)),
            NamedSharding(mesh, P(None, "model")))).lower(a, b).compile()
    cost = HC.analyze(c.as_text())

    def h(x):
        y = (x @ x.T).sum(0)
        return jax.lax.with_sharding_constraint(
            y, NamedSharding(mesh, P(None)))
    with mesh:
        c2 = jax.jit(h, in_shardings=(NamedSharding(mesh, P("data", "model")),)
                     ).lower(jax.ShapeDtypeStruct((M, M), jnp.float32)).compile()
    cost2 = HC.analyze(c2.as_text())
    print(json.dumps({
        "flops_per_dev": cost.flops,
        "expected": 2 * M * K * N / 8,
        "coll_kinds": sorted(cost2.coll_bytes_by_kind),
        "coll_total": cost2.coll_bytes,
    }))
""")


@requires_axis_type
def test_spmd_per_device_flops_and_collectives():
    out = subprocess.run([sys.executable, "-c", SUBPROCESS_PROG],
                         capture_output=True, text=True, cwd="/root/repo",
                         timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    data = json.loads(out.stdout.strip().splitlines()[-1])
    assert data["flops_per_dev"] == data["expected"]
    assert "all-reduce" in data["coll_kinds"]
    assert data["coll_total"] > 0


def test_collective_seconds_algo_factors():
    t = HC.collective_seconds({"all-reduce": 100e9, "all-gather": 50e9},
                              link_bw=50e9)
    assert abs(t - (2 * 100e9 + 50e9) / 50e9 / 1) < 1e-9
