"""Launch-layer tests: input specs, model-FLOPs accounting, elastic
manager, and the dry-run driver on a (subprocess) multi-device mesh."""

import json
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.configs import get_config, get_shape, list_archs
from repro.launch import hlo_analysis as H


# ---------------------------------------------------------------------------
# analytic accounting
# ---------------------------------------------------------------------------

def test_param_counts_sane():
    # dense 1.8B: total within 20% of nameplate
    total, active = H.param_counts(get_config("h2o-danube-1.8b"))
    assert 1.4e9 < total < 2.2e9
    assert active == total
    # kimi: ~1T total, ~32B active
    total, active = H.param_counts(get_config("kimi-k2-1t-a32b"))
    assert 0.75e12 < total < 1.3e12
    assert 20e9 < active < 45e9
    # grok: ~314B total
    total, _ = H.param_counts(get_config("grok-1-314b"))
    assert 2.4e11 < total < 3.9e11
    # zamba2: stored ~7B, compute-active < stored (shared attention)
    total, active = H.param_counts(get_config("zamba2-7b"))
    assert 4e9 < total < 10e9


def test_model_flops_kinds():
    cfg = get_config("h2o-danube-1.8b")
    tr = H.model_flops_for_cell(cfg, get_shape("train_4k"))
    pf = H.model_flops_for_cell(cfg, get_shape("prefill_32k"))
    dc = H.model_flops_for_cell(cfg, get_shape("decode_32k"))
    assert tr > pf > dc > 0
    # train is ~3x a forward at the same token count
    fwd_like = tr / 3
    assert 0.5 < fwd_like / (2 * H.param_counts(cfg)[1] * 256 * 4096) < 2.5


def test_encdec_prefill_is_source_side():
    """seamless prefill encodes SRC_FRAMES frames + one BOS decode — its
    useful flops must NOT scale with the 32k target length."""
    cfg = get_config("seamless-m4t-large-v2")
    pf32 = H.model_flops_for_cell(cfg, get_shape("prefill_32k"))
    tr = H.model_flops_for_cell(cfg, get_shape("train_4k"))
    assert pf32 < tr / 10


def test_skips_are_exactly_the_full_attention_archs():
    skip = {a for a in list_archs()
            if "long_500k" in get_config(a).skipped_shapes()}
    assert skip == {"command-r-35b", "seamless-m4t-large-v2", "qwen2-vl-7b",
                    "grok-1-314b", "kimi-k2-1t-a32b", "iterpro-100m"}


# ---------------------------------------------------------------------------
# elastic manager
# ---------------------------------------------------------------------------

def test_elastic_assignment_rotates():
    from repro.launch.elastic import ElasticManager
    mgr = ElasticManager(n_slices=8)
    mgr.mark_dead(3)
    owners = {step: [h for h, v in mgr.assignment(step).items()
                     if 3 in v][0] for step in range(6)}
    assert 3 not in set(owners.values())
    assert len(set(owners.values())) > 1        # burden rotates
    with pytest.raises(RuntimeError):
        for s in range(8):
            mgr.mark_dead(s)


# ---------------------------------------------------------------------------
# dry-run driver (one small cell, 8 fake devices, subprocess)
# ---------------------------------------------------------------------------

DRYRUN_PROG = textwrap.dedent("""
    import os, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    from repro.launch.dryrun import run_cell
    rec = run_cell("xlstm-350m", "decode_32k", "single",
                   variant={"mesh_shape": [2, 4]})
    out = {k: rec.get(k) for k in ("status", "chips")}
    out["has_roofline"] = "roofline" in rec
    out["bottleneck"] = rec.get("roofline", {}).get("bottleneck")
    print(json.dumps(out))
""")


def test_dryrun_cell_subprocess():
    out = subprocess.run([sys.executable, "-c", DRYRUN_PROG],
                         capture_output=True, text=True, cwd="/root/repo",
                         timeout=560)
    assert out.returncode == 0, out.stderr[-2000:]
    data = json.loads(out.stdout.strip().splitlines()[-1])
    assert data["status"] == "ok", data
    assert data["chips"] == 8
    assert data["has_roofline"]
    assert data["bottleneck"] in ("compute", "memory", "collective")


def test_input_specs_cover_all_kinds_locally():
    """input_specs builds structs for every (arch x shape) without device
    allocation — even off-mesh (ctx local)."""
    from repro.distributed.context import DistContext
    from repro.launch.specs import batch_struct, cache_struct, state_struct
    for arch in ("gemma3-1b", "zamba2-7b", "seamless-m4t-large-v2",
                 "qwen2-vl-7b", "kimi-k2-1t-a32b"):
        cfg = get_config(arch)
        st = state_struct(cfg, 256)
        assert "params" in st and "opt" in st and "iv" in st
        b = batch_struct(cfg, 8, 128)
        assert b["tokens"].shape == (8, 128)
        c = cache_struct(cfg, 2, 64)
        assert isinstance(c, dict)
        for leaf in jax.tree_util.tree_leaves(st):
            assert isinstance(leaf, jax.ShapeDtypeStruct)
