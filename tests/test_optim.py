"""Optimizer correctness: AdamW against a hand-rolled reference, Adafactor
state shapes/factoring, int8 moment quantisation bounds, schedules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainPlan
from repro.optim import make_optimizer
from repro.optim.schedules import warmup_cosine


def _params():
    k = jax.random.PRNGKey(0)
    return {"w": jax.random.normal(k, (8, 4)),
            "b": jnp.zeros((4,))}


def test_adamw_matches_reference():
    plan = TrainPlan(optimizer="adamw", learning_rate=1e-2, warmup_steps=0,
                     weight_decay=0.0, grad_clip=0.0)
    opt = make_optimizer(plan, total_steps=100)
    params = _params()
    state = opt.init(params)
    grads = jax.tree_util.tree_map(jnp.ones_like, params)

    new_params, new_state, _ = opt.update(grads, state, params, jnp.int32(0))

    # reference: first Adam step with bias correction -> update = lr * 1
    b1, b2, eps = 0.9, 0.999, 1e-8
    m = 0.1
    v = 0.001
    mh, vh = m / (1 - b1), v / (1 - b2)
    lr = warmup_cosine(plan.learning_rate, 0, 100)(jnp.int32(0))
    expect = np.asarray(params["w"]) - float(lr) * mh / (np.sqrt(vh) + eps)
    np.testing.assert_allclose(np.asarray(new_params["w"]), expect,
                               atol=1e-5, rtol=1e-5)


def test_weight_decay_is_decoupled():
    plan = TrainPlan(optimizer="adamw", learning_rate=1e-2, warmup_steps=0,
                     weight_decay=0.1, grad_clip=0.0)
    opt = make_optimizer(plan, total_steps=100)
    params = _params()
    state = opt.init(params)
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    new_params, _, _ = opt.update(zeros, state, params, jnp.int32(0))
    lr = float(warmup_cosine(plan.learning_rate, 0, 100)(jnp.int32(0)))
    np.testing.assert_allclose(np.asarray(new_params["w"]),
                               np.asarray(params["w"]) * (1 - lr * 0.1),
                               atol=1e-6, rtol=1e-6)


def test_adafactor_factored_shapes():
    plan = TrainPlan(optimizer="adafactor")
    opt = make_optimizer(plan, total_steps=100)
    params = {"w": jnp.zeros((8, 4))}
    state = opt.init(params)
    stats = state["stats"]["w"]
    assert stats["vr"].shape == (8,)
    assert stats["vc"].shape == (4,)
    grads = {"w": jnp.ones((8, 4))}
    new_params, new_state, _ = opt.update(grads, state, params, jnp.int32(0))
    assert new_params["w"].shape == (8, 4)
    assert bool(jnp.isfinite(new_params["w"]).all())


def test_int8_moments_bounded_error():
    plan = TrainPlan(optimizer="adamw", moment_dtype="int8",
                     learning_rate=1e-3, grad_clip=0.0)
    opt = make_optimizer(plan, total_steps=100)
    params = _params()
    state = opt.init(params)
    grads = jax.tree_util.tree_map(
        lambda p: jax.random.normal(jax.random.PRNGKey(1), p.shape), params)
    p1, s1, _ = opt.update(grads, state, params, jnp.int32(0))
    # fp32 baseline
    plan32 = TrainPlan(optimizer="adamw", moment_dtype="float32",
                       learning_rate=1e-3, grad_clip=0.0)
    opt32 = make_optimizer(plan32, total_steps=100)
    p2, _, _ = opt32.update(grads, opt32.init(params), params, jnp.int32(0))
    err = float(jnp.max(jnp.abs(p1["w"] - p2["w"])))
    assert err < 5e-4, err   # one step of int8-moment noise stays tiny


def test_schedule_warmup_and_decay():
    sched = warmup_cosine(1.0, 10, 100)
    lr0 = float(sched(jnp.int32(0)))
    lr_mid = float(sched(jnp.int32(10)))
    lr_end = float(sched(jnp.int32(99)))
    assert lr0 < 0.2
    assert abs(lr_mid - 1.0) < 1e-6
    assert lr_end < 0.15
