"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see 1 CPU device;
only the dry-run process forces 512 placeholder devices (see launch/dryrun).
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.data.pipeline import TokenPipeline
from repro.train.loop import make_train_state, make_train_step

#: shared version guard: the multi-device subprocess programs
#: (test_hlo_cost / test_moe / test_pipeline) build their meshes with
#: ``jax.sharding.AxisType`` (newer jax); on older jax the subprocess
#: would die with AttributeError — skip with a reasoned marker instead
#: of red noise, importorskip-style.
requires_axis_type = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="jax.sharding.AxisType not available in this jax version")


@pytest.fixture(scope="session")
def tiny_cfg():
    """A very small config for fast loop-level tests."""
    cfg = get_config("iterpro-100m").smoke()
    return cfg


@pytest.fixture(scope="session")
def tiny_setup(tiny_cfg):
    """(cfg, state0, jitted step_fn, batch_fn) shared across tests."""
    B, S = 2, 32
    pipe = TokenPipeline(tiny_cfg.model.vocab_size, S, B, seed=0)
    state = make_train_state(tiny_cfg, jax.random.PRNGKey(0), global_batch=B)
    step = jax.jit(make_train_step(tiny_cfg, global_batch=B))
    bfn = lambda s: pipe.batch_at(s)
    # warm the jit cache once for the whole session
    st, m = step(state, bfn(0))
    jax.block_until_ready(m["loss"])
    return tiny_cfg, state, step, bfn
