"""Continuous-batching serving engine: slot isolation, slot-scoped
recovery, and the 1-launch/1-sync/0-retrace hot-path contract.

The load-bearing regressions (ISSUE 6 acceptance):

* a fault injected into ONE slot's decode state leaves every healthy
  slot's subsequent tokens BIT-IDENTICAL to a fault-free run — only the
  injured request pays prefix replay;
* admission/eviction at steady state causes 0 retraces (slot turnover is
  slice writes through pre-compiled executables, never a recompile);
* a steady-state engine step is exactly 1 logical launch + 1 scalar
  fault sync.

ISSUE 7 additions (paged KV pool + chunked prefill + bugfix batch):

* paged decode is BIT-IDENTICAL to the dense engine on heterogeneous
  prompt lengths, and chunked prefill to monolithic;
* the canary attributes pool faults at (leaf, block) granularity and the
  owner translation keeps ``injured_slots`` working; a flip on an
  UNOWNED block evicts nobody;
* over-budget requests are rejected at admission with a typed error
  (the old engine silently overflowed past ``max_len``);
* idle waits honor an injected virtual clock instead of busy-spinning
  wall time.
"""

import random
import time

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.detect import (FaultReport, block_leaf_prefix,
                               block_of_leaf, block_view, slot_leaf_prefix,
                               slot_of_leaf, slot_view)
from repro.core.recover import plan_serving_recovery
from repro.kernels import digest as kdigest
from repro.serving import (AdmissionError, PoolSaturated, Request,
                           RequestQueue, ServingEngine, VirtualClock)

S, MAX_LEN, K = 3, 48, 4   # one engine shape for most tests — the
# module-level executable caches make every extra engine over it free


@pytest.fixture(scope="module")
def cfg():
    return get_config("iterpro-100m").smoke()


def mk_requests(cfg, n, gen=8, plen=6, seed=0, arrivals=None):
    nprng = np.random.default_rng(seed)
    return [Request(
        rid=i,
        prompt=nprng.integers(0, cfg.model.vocab_size,
                              size=plen).astype(np.int32),
        max_new_tokens=gen,
        arrival_s=float(arrivals[i]) if arrivals is not None else 0.0)
        for i in range(n)]


def mk_engine(cfg, **kw):
    kw.setdefault("n_slots", S)
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("canary_slices", K)
    kw.setdefault("donate", True)
    return ServingEngine(cfg, **kw)


# -- request / queue front end ------------------------------------------


def test_request_log_and_retract():
    rq = Request(rid=0, prompt=np.zeros(4, np.int32), max_new_tokens=5)
    rq.log = [7, 1, 2, 3]
    assert rq.n_out == 3 and not rq.done
    assert rq.retract(2) == 2
    assert rq.log == [7, 1] and rq.retracted == 2
    assert rq.retract(9) == 1          # never touches log[0]
    assert rq.log == [7]
    assert rq.retract(1) == 0


def test_queue_order_and_front_requeue():
    reqs = [Request(rid=i, prompt=np.zeros(2, np.int32), max_new_tokens=1,
                    arrival_s=t) for i, t in enumerate([0.3, 0.1, 0.2])]
    q = RequestQueue(reqs)
    assert q.pop_ready(0.0) is None            # nothing has arrived yet
    evicted = q.pop_ready(1.0)
    assert evicted.rid == 1
    q.requeue_front(evicted)                   # jumps ahead of rid=2
    assert q.pop_ready(1.0).rid == 1
    assert q.pop_ready(1.0).rid == 2
    assert q.pop_ready(1.0).rid == 0
    assert q.next_arrival() is None


# -- slot-view canary mapping (core/detect.py) --------------------------


def test_slot_view_mapping_roundtrip():
    tree = {"k": np.arange(12.0).reshape(3, 4), "pos": np.arange(3)}
    view = slot_view(tree, 3)
    assert sorted(view) == [slot_leaf_prefix(u) for u in range(3)]
    assert np.array_equal(view[slot_leaf_prefix(1)]["k"], tree["k"][1])
    assert slot_of_leaf("slot002/groups/0/0/k") == 2
    assert slot_of_leaf("params/w") is None


def test_fault_report_injured_slots():
    rep = FaultReport(0, "checksum",
                      leaves=["slot001/k", "slot001/pos", "slot000/k", "x"])
    assert rep.injured_slots() == [0, 1]


# -- recovery policy (core/recover.py) ----------------------------------


def test_plan_serving_recovery_checksum_zero_retract():
    rep = FaultReport(3, "checksum", leaves=["slot002/k"])
    plan = plan_serving_recovery(rep, n_slices=4)
    assert plan.scope == "slots" and plan.slots == [2]
    # one-step detection latency: no ACCEPTED token is suspect
    assert plan.retract == 0


def test_plan_serving_recovery_nonfinite_window():
    plan = plan_serving_recovery(None, n_slices=4, nonfinite_slots=[1])
    assert plan.scope == "slots" and plan.slots == [1]
    assert plan.retract == 3           # K-1 at-rest window
    plan0 = plan_serving_recovery(None, n_slices=0, nonfinite_slots=[1])
    assert plan0.retract is None       # no canary: no bound, full replay


def test_plan_serving_recovery_no_attribution_evicts_engine():
    rep = FaultReport(3, "external")
    plan = plan_serving_recovery(rep, n_slices=4)
    assert plan.scope == "engine" and plan.retract is None


# -- engine: continuous batching ----------------------------------------


def test_continuous_batching_all_complete(cfg):
    eng = mk_engine(cfg)
    # 2x oversubscribed with staggered arrivals: freed slots must be
    # re-filled mid-flight (iteration-level scheduling)
    n = 2 * S
    reqs = mk_requests(cfg, n, gen=6, arrivals=np.linspace(0, 0.05, n))
    rep = eng.run(reqs)
    assert rep.completed == n and rep.dropped == 0
    assert rep.tokens_out == n * 6
    assert rep.admissions == n
    for r in rep.per_request.values():
        assert len(r["tokens"]) == 6 and not r["dropped"]


def test_lane_outputs_independent_of_slot_and_batchmates(cfg):
    """The same request produces the same tokens whatever slot it lands
    in and whoever shares the batch — the determinism slot-isolated
    recovery is built on."""
    reqs_a = mk_requests(cfg, 4, gen=6)
    solo = {}
    for rq in mk_requests(cfg, 4, gen=6):
        eng = mk_engine(cfg)
        out = eng.run([rq])
        solo[rq.rid] = out.per_request[rq.rid]["tokens"]
    eng = mk_engine(cfg)
    rep = eng.run(reqs_a)
    for rid, toks in solo.items():
        assert rep.per_request[rid]["tokens"] == toks


# -- engine: fault storm, slot isolation, recovery ----------------------


def run_pair(cfg, n=6, gen=8, inject_every=5, seed=0, **kw):
    base = mk_engine(cfg, **kw)
    base_rep = base.run(mk_requests(cfg, n, gen=gen))
    storm = mk_engine(cfg, **kw)
    storm_rep = storm.run(mk_requests(cfg, n, gen=gen),
                          inject_every=inject_every,
                          inject_rng=random.Random(seed))
    return base_rep, storm_rep


def test_fault_storm_detects_recovers_and_isolates(cfg):
    base_rep, storm_rep = run_pair(cfg)
    f = storm_rep.summary()["faults"]
    assert f["injected"] >= 2
    # armed-window storm: every flip lands in the protected slice
    assert f["detected"] == f["injected"]
    assert f["recovered"] == f["detected"]
    assert storm_rep.dropped == 0
    assert storm_rep.replay_tokens > 0
    assert storm_rep.injured_rids
    # THE isolation regression: healthy requests bit-identical...
    for rid, rec in base_rep.per_request.items():
        if rid not in storm_rep.injured_rids:
            assert storm_rep.per_request[rid]["tokens"] == rec["tokens"]
            assert storm_rep.per_request[rid]["replays"] == 0
    # ...and only injured requests paid prefix replay
    replayed = {rid for rid, r in storm_rep.per_request.items()
                if r["replays"]}
    assert replayed <= storm_rep.injured_rids
    # replay determinism: injured requests are ALSO bit-identical
    for rid in storm_rep.injured_rids:
        assert (storm_rep.per_request[rid]["tokens"]
                == base_rep.per_request[rid]["tokens"])


def test_targeted_fault_names_its_slot(cfg):
    eng = mk_engine(cfg)
    reqs = mk_requests(cfg, S, gen=32)
    for u, rq in enumerate(reqs):
        eng.admit(rq, u)
    for _ in range(K):
        eng.engine_step()
    victim = 1
    u, key, _ = eng.corrupt_slot(random.Random(0), slot=victim,
                                 armed_only=True)
    assert u == victim
    _, finite, report = eng.engine_step()
    assert report is not None
    assert report.injured_slots() == [victim]
    q = RequestQueue()
    evicted = eng.handle_fault(report, finite, 0.0, q)
    assert evicted == [victim]
    assert eng.slot_rid[victim] is None          # victim evicted...
    assert len(q) == 1 and q.pop_ready(0.0).rid == reqs[victim].rid
    others = [eng.slot_rid[i] for i in range(S) if i != victim]
    assert all(r is not None for r in others)    # ...healthy slots live
    # healthy lanes keep decoding the very next engine step, no refire
    _, _, rep2 = eng.engine_step()
    assert rep2 is None


def test_k1_canary_catches_every_flip(cfg):
    _, storm_rep = run_pair(cfg, inject_every=4, canary_slices=1)
    f = storm_rep.summary()["faults"]
    assert f["injected"] >= 2
    assert f["detected"] == f["injected"]
    assert f["recovered"] == f["detected"]


# -- engine: hot-path contract ------------------------------------------


def test_steady_state_one_launch_one_sync_zero_retraces(cfg):
    eng = mk_engine(cfg)
    eng.warm()
    for u, rq in enumerate(mk_requests(cfg, S, gen=40)):
        eng.admit(rq, u)
    for _ in range(K):                 # settle one full rotation
        assert eng.engine_step()[2] is None
    kdigest.STATS.reset()
    W = 8
    for _ in range(W):
        assert eng.engine_step()[2] is None
    launches, syncs, traces = kdigest.STATS.snapshot()
    assert (launches, syncs, traces) == (W, W, 0), (
        "steady-state engine step must be 1 logical launch + 1 scalar "
        f"fault sync + 0 retraces, got {launches}/{syncs}/{traces} over "
        f"{W} steps")


def test_admission_and_eviction_zero_retraces(cfg):
    eng = mk_engine(cfg)
    eng.warm()
    reqs = mk_requests(cfg, 2 * S, gen=40, seed=3)
    for u in range(S):
        eng.admit(reqs[u], u)
    for _ in range(K):
        eng.engine_step()
    kdigest.STATS.reset()
    # churn every slot once: evict + admit + step — all slice writes
    for u in range(S):
        eng._free(u)
        eng.admit(reqs[S + u], u)
        eng.engine_step()
    assert kdigest.STATS.traces == 0, (
        f"slot churn retraced {kdigest.STATS.traces} digest fns")


def test_storm_run_zero_retraces_after_preflight(cfg):
    # a full run (admissions, faults, evictions, replays) after one
    # preflight run must not retrace anything
    pre = mk_engine(cfg)
    pre.warm()
    pre.run(mk_requests(cfg, 2 * S, gen=4), inject_every=2,
            inject_rng=random.Random(1))
    kdigest.STATS.reset()
    eng = mk_engine(cfg)
    rep = eng.run(mk_requests(cfg, 2 * S, gen=6), inject_every=4,
                  inject_rng=random.Random(0))
    assert rep.completed == 2 * S
    assert kdigest.STATS.traces == 0


# -- serve() CLI wrapper ------------------------------------------------


def test_serve_summary_has_percentiles_and_is_seeded(cfg):
    from repro.launch.serve import serve
    out = serve(cfg, n_requests=2, prompt_len=8, gen_tokens=4, seed=7,
                inject_every=3, verbose=False)
    for k in ("p50_decode_ms", "p99_decode_ms", "p50_recovery_ms",
              "p99_recovery_ms", "mean_decode_ms", "mean_recovery_ms"):
        assert k in out
    assert out["tokens_out"] == 2 * 4
    # full-stack reproducibility: same seed => same counters
    out2 = serve(cfg, n_requests=2, prompt_len=8, gen_tokens=4, seed=7,
                 inject_every=3, verbose=False)
    for k in ("tokens_out", "faults", "replay_tokens",
              "retracted_tokens", "engine_steps", "admissions"):
        assert out[k] == out2[k], k


# -- paged KV pool (ISSUE 7) --------------------------------------------


HET_PLENS = (4, 11, 23, 6, 17)


def mk_het_requests(cfg, n, gen=6, seed=0):
    nprng = np.random.default_rng(seed)
    return [Request(
        rid=i,
        prompt=nprng.integers(0, cfg.model.vocab_size,
                              size=HET_PLENS[i % len(HET_PLENS)]
                              ).astype(np.int32),
        max_new_tokens=gen) for i in range(n)]


def tokens_of(rep):
    return {rid: r["tokens"] for rid, r in rep.per_request.items()}


def test_block_view_and_injured_blocks():
    pool = {"groups": [np.arange(24.0).reshape(4, 2, 3)]}
    view = block_view(pool, 4)
    assert sorted(view) == [block_leaf_prefix(b) for b in range(4)]
    assert np.array_equal(view[block_leaf_prefix(2)]["groups"][0],
                          pool["groups"][0][2])
    assert block_of_leaf("block0007/groups/0/0/k") == 7
    assert block_of_leaf("slot001/block0007/groups/0/0/k") == 7
    assert block_of_leaf("slot001/pos") is None
    rep = FaultReport(0, "checksum",
                      leaves=["block0003/g/k", "slot001/block0001/g/v",
                              "slot001/pos"])
    assert rep.injured_blocks() == [1, 3]


def test_plan_serving_recovery_unowned_block_evicts_nobody():
    rep = FaultReport(5, "checksum", leaves=["block0009/groups/0/0/k"])
    plan = plan_serving_recovery(rep, n_slices=4)
    assert plan.scope == "slots" and plan.slots == []
    assert plan.retract == 0


def test_paged_bit_identical_to_dense_heterogeneous(cfg):
    reqs = lambda: mk_het_requests(cfg, 5, gen=6)
    dense = mk_engine(cfg, paged=False).run(reqs())
    paged = mk_engine(cfg, paged=True).run(reqs())
    assert paged.completed == 5 and paged.dropped == 0
    assert tokens_of(paged) == tokens_of(dense)


def test_chunked_prefill_matches_monolithic(cfg):
    reqs = lambda: mk_het_requests(cfg, 5, gen=6, seed=2)
    mono = mk_engine(cfg, paged=True, prefill_chunk=0).run(reqs())
    chunk = mk_engine(cfg, paged=True, prefill_chunk=5).run(reqs())
    assert chunk.completed == 5 and chunk.dropped == 0
    assert tokens_of(chunk) == tokens_of(mono)


def test_admission_overflow_rejected_typed(cfg):
    # direct: both layouts raise the typed error before touching state
    for paged in (True, False):
        eng = mk_engine(cfg, paged=paged)
        big = Request(rid=0, prompt=np.zeros(MAX_LEN, np.int32),
                      max_new_tokens=8)
        with pytest.raises(AdmissionError):
            eng.admit(big, 0)
        assert eng.slot_rid[0] is None
        assert eng.report.admissions == 0
    # run(): the oversized request is rejected and accounted; everyone
    # else completes untouched
    eng = mk_engine(cfg, paged=True)
    reqs = mk_het_requests(cfg, 4, gen=6)
    reqs.append(Request(rid=99, prompt=np.zeros(MAX_LEN, np.int32),
                        max_new_tokens=8))
    rep = eng.run(reqs)
    assert rep.admission_rejected == 1
    assert rep.summary()["admission_rejected"] == 1
    assert rep.per_request[99]["dropped"]
    assert rep.completed == 4 and rep.dropped == 1


def test_paged_block_churn_zero_retraces(cfg):
    eng = mk_engine(cfg, paged=True)
    eng.warm()
    for u, rq in enumerate(mk_het_requests(cfg, S, gen=20)):
        eng.admit(rq, u)
    for _ in range(K):
        eng.engine_step()
    kdigest.STATS.reset()
    # churn with a DIFFERENT block count per admission (heterogeneous
    # prompts): alloc/free must stay fixed-shape slice writes
    churn = mk_het_requests(cfg, S, gen=20, seed=5)
    for u in range(S):
        eng._free(u)
        eng.admit(churn[u], u)
        eng.engine_step()
    assert kdigest.STATS.traces == 0, (
        f"block churn retraced {kdigest.STATS.traces} digest fns")


def test_targeted_paged_fault_blocks_attribute_to_owner(cfg):
    eng = mk_engine(cfg, paged=True)
    reqs = mk_het_requests(cfg, S, gen=20)
    for u, rq in enumerate(reqs):
        eng.admit(rq, u)
    for _ in range(K):
        eng.engine_step()
    victim = 1
    owned_before = set(eng.alloc.owned(victim))
    free_before = eng.alloc.free_count
    u, key, _ = eng.corrupt_slot(random.Random(0), slot=victim,
                                 armed_only=True)
    assert u == victim
    _, finite, report = eng.engine_step()
    assert report is not None
    assert report.injured_slots() == [victim]
    # block-granular attribution maps into the victim's owned set
    assert set(report.injured_blocks()) <= owned_before
    q = RequestQueue()
    evicted = eng.handle_fault(report, finite, 0.0, q)
    assert evicted == [victim]
    # the victim's blocks went back to the pool
    assert eng.alloc.owned(victim) == []
    assert eng.alloc.free_count == free_before + len(owned_before)
    assert len(q) == 1 and q.pop_ready(0.0).rid == reqs[victim].rid
    # healthy slots live on; no refire next step
    assert all(eng.slot_rid[i] is not None for i in range(S)
               if i != victim)
    _, _, rep2 = eng.engine_step()
    assert rep2 is None


def test_unowned_block_fault_evicts_nobody(cfg):
    eng = mk_engine(cfg, paged=True)
    for u, rq in enumerate(mk_het_requests(cfg, S, gen=20)):
        eng.admit(rq, u)
    for _ in range(K):
        eng.engine_step()
    # pick a free (unowned, non-scratch) block whose unit is armed for
    # the NEXT step's check
    cls = eng.step_count % K
    key = next(k for b in range(1, eng.n_blocks)
               if b not in eng.alloc.owner
               for k in eng._block_keys[b]
               if eng.plan.index_of(k) % K == cls)
    u, _, _ = eng.corrupt_slot(random.Random(0), key=key)
    assert u == -1                       # nobody owns it
    _, finite, report = eng.engine_step()
    assert report is not None
    assert report.injured_slots() == []  # no owner -> no victim
    q = RequestQueue()
    evicted = eng.handle_fault(report, finite, 0.0, q)
    assert evicted == [] and len(q) == 0
    assert all(eng.slot_rid[i] is not None for i in range(S))
    assert eng.report.faults_on_free_slots == 1
    _, _, rep2 = eng.engine_step()       # re-certified: no refire
    assert rep2 is None


def test_pool_saturation_defers_admission(cfg):
    # pool sized for ~one in-flight request: plen=6 + 1 + gen=8 -> 15
    # positions -> 2 blocks of 8; capacity 3 admits one request plus a
    # block of slack, so concurrent admissions must serialize
    eng = mk_engine(cfg, paged=True, pool_blocks=4)
    reqs = mk_requests(cfg, 3, gen=8)
    rep = eng.run(reqs)
    assert rep.completed == 3 and rep.dropped == 0
    assert rep.admission_rejected == 0
    # direct API surface: a second allocation while saturated raises
    eng2 = mk_engine(cfg, paged=True, pool_blocks=4)
    eng2.admit(mk_requests(cfg, 1, gen=8)[0], 0)
    with pytest.raises(PoolSaturated):
        eng2.admit(mk_requests(cfg, 2, gen=8)[1], 1)


# -- engine clock (bugfix: idle waits honor the injected clock) ---------


def test_virtual_clock_idle_wait_never_touches_wall_sleep(cfg, monkeypatch):
    calls = []
    monkeypatch.setattr(time, "sleep",
                        lambda dt: calls.append(dt))
    clock = VirtualClock()
    eng = mk_engine(cfg, paged=True)
    # a gap in arrivals forces the idle-wait path between requests
    reqs = mk_requests(cfg, 2, gen=4, arrivals=[0.0, 25.0])
    rep = eng.run(reqs, clock=clock)
    assert rep.completed == 2
    assert calls == [], ("idle wait busy-spun wall time despite the "
                         "injected virtual clock")
    assert clock.t >= 25.0               # the wait advanced VIRTUAL time


def test_wall_clock_idle_wait_sleeps_once_not_in_1ms_slices(cfg,
                                                            monkeypatch):
    real_sleep = time.sleep
    calls = []

    def counting_sleep(dt):
        calls.append(dt)
        real_sleep(min(dt, 0.2))         # keep the test fast
    monkeypatch.setattr(time, "sleep", counting_sleep)
    eng = mk_engine(cfg, paged=True)
    reqs = mk_requests(cfg, 2, gen=4, arrivals=[0.0, 0.15])
    rep = eng.run(reqs)
    assert rep.completed == 2
    # the old code slept in min(1e-3, ...) slices: ~150 calls for this
    # gap.  The fix sleeps the full remaining wait in one call.
    assert len(calls) <= 3, f"{len(calls)} sleep calls (busy-spin)"
