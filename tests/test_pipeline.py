"""Pipeline parallelism: the GPipe schedule must equal the sequential
composition of stages, for any (stages, microbatches) combination.
Runs on a subprocess mesh (the test session keeps 1 device)."""

import json
import subprocess
import sys
import textwrap

from conftest import requires_axis_type

PIPE_PROG = textwrap.dedent("""
    import os, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    from repro.distributed.pipeline import pipeline_apply

    S, M, B, d = 4, 6, 2, 8
    mesh = jax.make_mesh((S,), ("stage",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, S)
    params = {"w": jnp.stack([
        jax.random.normal(k, (d, d)) * 0.3 for k in ks]),
        "b": jnp.stack([jax.random.normal(k, (d,)) * 0.1 for k in ks])}
    xs = jax.random.normal(jax.random.fold_in(key, 9), (M, B, d))

    def stage_fn(p, x):
        return jnp.tanh(x @ p["w"] + p["b"])

    # sequential truth
    y_ref = xs
    for i in range(S):
        y_ref = jax.vmap(lambda x: stage_fn(
            {"w": params["w"][i], "b": params["b"][i]}, x))(y_ref)

    with mesh:
        y = pipeline_apply(stage_fn, params, xs, mesh, axis="stage")
    err = float(jnp.max(jnp.abs(y - y_ref)))
    print(json.dumps({"err": err}))
""")


@requires_axis_type
def test_gpipe_matches_sequential():
    out = subprocess.run([sys.executable, "-c", PIPE_PROG],
                         capture_output=True, text=True, cwd="/root/repo",
                         timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    data = json.loads(out.stdout.strip().splitlines()[-1])
    assert data["err"] < 1e-5, data
