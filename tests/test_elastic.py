"""Elastic hard-loss recovery — the chaos-drill suite (DESIGN.md §7).

Two tiers, mirroring test_sharded_resilience.py:

* **in-process mesh tests** (need >= 8 devices; the CI ``elastic`` job
  forces them): row-safe parity reconstruction into a DEGRADED target
  sharding with bit-identity against the pre-loss oracle (including the
  replica-dedup edge), the legacy-placement refusal, and the
  two-drills-in-one-process cache-eviction regression.

* **subprocess chaos drills** (always run): an 8-device child process
  trains, "loses" a device row mid-run (external ``FaultReport`` with
  ``lost_rows`` — the dead devices are never read again), recovers via
  the ``remesh`` rung with ZERO disk restores, and proves

    - the reconstructed state is bit-identical to the pre-loss oracle and
      digest-certified against the canary's surviving reference rows,
    - the post-resume loss trajectory is bit-identical to a clean
      degraded-mesh continuation from the oracle state (same global
      batch at reduced DP width),
    - the survivors' stolen loads reassemble the exact global batch,
    - the steady state after remesh keeps the 1-launch/1-sync/0-retrace
      contract (no hidden retraces against the dead mesh).
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp
import numpy as np

MESHABLE = len(jax.devices()) >= 8
mesh8 = pytest.mark.skipif(
    not MESHABLE,
    reason="needs >= 8 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _ctx():
    from repro.distributed.context import DistContext
    return DistContext.for_mesh(jax.make_mesh((4, 2), ("data", "model")))


def _toy_tree(ctx):
    """FSDP-flavoured spec zoo: data-dim-0, data-middle-dim (the layout
    that exposed the XLA SPMD concat miscompile), bf16 over (model, data),
    a data-sharded leaf REPLICATED over model (the dedup edge), and a
    fully replicated leaf (the re-gather path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    def put(x, *spec):
        return jax.device_put(x, NamedSharding(ctx.mesh, P(*spec)))

    # data dims are divisible by 4 AND 3 so the same PartitionSpec
    # re-shards onto the degraded (3, 2) mesh
    k = jax.random.PRNGKey
    return {
        "w0": put(jax.random.normal(k(0), (12, 8)), "data", "model"),
        "w3d": put(jax.random.normal(k(1), (1, 60, 64)),
                   None, "data", "model"),
        "wbf": put(jax.random.normal(k(2), (4, 12)).astype(jnp.bfloat16),
                   "model", "data"),
        "wdup": put(jax.random.normal(k(3), (12, 6)), "data", None),
        "wrep": put(jax.random.normal(k(4), (8,))),
    }


def _host_oracle(tree):
    return {k: np.asarray(v) for k, v in tree.items()}


@mesh8
class TestRowSafeReconstruction:
    def test_every_single_row_loss_reconstructs_bit_identical(self):
        """For EACH data row r: kill it, reconstruct every covered leaf
        from survivors + parity, re-gather the rest — bit-identical to
        the pre-loss oracle, reading nothing from the dead devices."""
        from repro.core.parity import ParityStore
        from repro.launch.elastic import _host_regather

        ctx = _ctx()
        tree = _toy_tree(ctx)
        oracle = _host_oracle(tree)
        ps = ParityStore(tree, ctx=ctx, row_safe=True)
        ps.build(tree)
        plan = ps.plan
        assert set(plan.keys) >= {"w0", "w3d", "wbf", "wdup"}
        assert "wrep" not in plan.key_set          # replicated: re-gather

        for row in range(4):
            dead = set(ctx.row_devices(row))
            pflat = plan.host_parity_flat(ps.parity, dead)
            for key, leaf in tree.items():
                if key in plan.key_set:
                    full, missing = plan.host_assemble_leaf(key, leaf, dead)
                    blocks = plan.host_surviving_blocks(key, leaf, dead)
                    uniq, _ = plan.slices[key]
                    for b in missing:
                        blk = plan.host_reconstruct_block(
                            key, b, pflat, blocks)
                        full[tuple(slice(a, e) for a, e in uniq[b])] = blk
                else:
                    full = _host_regather(leaf, dead)
                    assert full is not None
                got = np.atleast_1d(np.asarray(full))
                want = np.atleast_1d(oracle[key])
                assert got.dtype == want.dtype
                assert np.array_equal(got.view(np.uint8),
                                      want.view(np.uint8)), \
                    f"row {row}, leaf {key}: reconstruction not bit-exact"

    def test_reconstruct_into_degraded_target_sharding(self):
        """The reconstructed hosts re-shard onto the DEGRADED mesh's
        NamedShardings (the actual resume layout): values stay
        bit-identical and every committed shard lives on a survivor."""
        from jax.sharding import NamedSharding
        from repro.core.parity import ParityStore
        from repro.launch.elastic import _host_regather

        ctx = _ctx()
        tree = _toy_tree(ctx)
        oracle = _host_oracle(tree)
        ps = ParityStore(tree, ctx=ctx, row_safe=True)
        ps.build(tree)
        plan = ps.plan

        row = 3
        dead = set(ctx.row_devices(row))
        new_ctx = ctx.degrade((row,))
        assert new_ctx.mesh.shape["data"] == 3
        assert not (set(np.ravel(new_ctx.mesh.devices)) & dead)

        pflat = plan.host_parity_flat(ps.parity, dead)
        for key, leaf in tree.items():
            if key in plan.key_set:
                full, missing = plan.host_assemble_leaf(key, leaf, dead)
                blocks = plan.host_surviving_blocks(key, leaf, dead)
                uniq, _ = plan.slices[key]
                for b in missing:
                    full[tuple(slice(a, e) for a, e in uniq[b])] = \
                        plan.host_reconstruct_block(key, b, pflat, blocks)
            else:
                full = _host_regather(leaf, dead)
            # same PartitionSpec, shrunken mesh — the degraded layout
            spec = leaf.sharding.spec
            placed = jax.device_put(
                jnp.asarray(full),
                NamedSharding(new_ctx.mesh, spec))
            got = np.atleast_1d(np.asarray(placed))
            want = np.atleast_1d(oracle[key])
            assert np.array_equal(got.view(np.uint8), want.view(np.uint8))
            assert not ({sh.device for sh in placed.addressable_shards}
                        & dead)

    def test_replica_dedup_edge(self):
        """A data-sharded leaf replicated over 'model' holds TWO device
        copies per block: survivor reads must dedup (XOR-folding a block
        twice would self-cancel) and a row loss must still be a single
        erasure per fold group."""
        from repro.core.parity import ParityStore

        ctx = _ctx()
        tree = _toy_tree(ctx)
        ps = ParityStore(tree, ctx=ctx, row_safe=True)
        ps.build(tree)
        plan = ps.plan
        leaf = tree["wdup"]
        # 8 device shards but only 4 unique blocks
        uniq, dmap = plan.slices["wdup"]
        assert len(uniq) == 4 and len(dmap) == 8

        dead = set(ctx.row_devices(2))
        blocks = plan.host_surviving_blocks("wdup", leaf, dead)
        assert sorted(blocks) == [0, 1, 3]        # block 2 fully dead
        full, missing = plan.host_assemble_leaf("wdup", leaf, dead)
        assert missing == [2]
        pflat = plan.host_parity_flat(ps.parity, dead)
        blk = plan.host_reconstruct_block("wdup", 2, pflat, blocks)
        want = np.asarray(tree["wdup"])[uniq[2][0][0]:uniq[2][0][1]]
        assert np.array_equal(blk.view(np.uint8), want.view(np.uint8))

    def test_legacy_placement_refused_and_row_safe_required(self):
        """Default (legacy) parity placement puts parity row d on device
        d — a data-row loss takes parity down with the data.  The host
        read must refuse rather than hand back zeros, and on_loss must
        refuse to run on a legacy store."""
        from repro.core.parity import ParityStore
        from repro.launch.elastic import ElasticManager

        ctx = _ctx()
        tree = _toy_tree(ctx)
        legacy = ParityStore(tree, ctx=ctx)       # row_safe=False
        legacy.build(tree)
        dead = set(ctx.row_devices(1))
        with pytest.raises(RuntimeError, match="row_safe"):
            legacy.plan.host_parity_flat(legacy.parity, dead)

        emgr = ElasticManager(ctx)
        with pytest.raises(RuntimeError, match="row_safe"):
            emgr.on_loss(step=0, dead_rows=(1,), state=tree,
                         raw_step=lambda s, b: (s, {}), cfg=None,
                         batch_fn=lambda s: None, pstore=legacy)


@mesh8
def test_two_drills_in_one_process_evict_stale_mesh_caches():
    """(4,2) -> (3,2) -> (2,2): a second hard loss in the same process
    must run against the FIRST degraded mesh's executables/plans, so the
    drill asserts every global cache drops its old-mesh keys after each
    remesh, slice bookkeeping keeps ORIGINAL ids, and the final step
    still trains."""
    import dataclasses

    from repro.configs import get_config
    from repro.core import parity as core_parity
    from repro.core.detect import ChecksumCanary
    from repro.core.parity import ParityStore
    from repro.data.pipeline import TokenPipeline
    from repro.kernels import digest as kdigest
    from repro.launch.elastic import ElasticManager
    from repro.launch.specs import bind_state
    from repro.train.loop import make_train_state, make_train_step

    def stale_keys(mesh):
        mk = kdigest._mesh_key(mesh)
        n = sum(1 for k in kdigest._SHARDED_PLAN_CACHE if k[0] == mk)
        n += sum(1 for k in core_parity._PARITY_PLAN_CACHE if k[0] == mk)
        return n

    cfg = get_config("iterpro-100m").smoke()
    cfg = dataclasses.replace(
        cfg, sharding=dataclasses.replace(cfg.sharding, fsdp=True))
    B, S = 12, 16
    ctx = _ctx()
    mesh0 = ctx.mesh
    pipe = TokenPipeline(cfg.model.vocab_size, S, B, seed=0)
    state = make_train_state(cfg, jax.random.PRNGKey(0), global_batch=B)
    raw_bfn = lambda s: pipe.batch_at(s)
    state, raw, bfn, sh = bind_state(
        ctx, cfg, state, make_train_step(cfg, global_batch=B), raw_bfn)
    step = jax.jit(raw)
    canary = ChecksumCanary(state, n_slices=1, ctx=ctx)
    pstore = ParityStore(state, ctx=ctx, row_safe=True)
    pstore.build(state)
    canary.attach_parity(pstore)
    assert stale_keys(mesh0) > 0                  # plans exist pre-drill

    new_state, m = step(state, bfn(0))
    assert canary.check_and_arm(0, state, new_state) is None
    state = new_state

    emgr = ElasticManager(ctx)
    r1 = emgr.on_loss(step=1, dead_rows=(3,), state=state, raw_step=raw,
                      cfg=cfg, batch_fn=raw_bfn, canary=canary,
                      pstore=pstore)
    assert r1.ctx.mesh.shape["data"] == 3
    assert r1.event.lost_slices == (3,)
    assert r1.event.uncertified_blocks == 0
    assert stale_keys(mesh0) == 0                 # old-mesh plans gone
    mesh1 = r1.ctx.mesh
    st1, m = r1.step(r1.state, r1.bfn(1))
    assert np.isfinite(float(m["loss"]))
    assert r1.canary.check_and_arm(1, r1.state, st1) is None

    # second drill: current row 2 is ORIGINAL slice 2
    r2 = emgr.on_loss(step=2, dead_rows=(2,), state=st1,
                      raw_step=r1.raw_step, cfg=cfg, batch_fn=raw_bfn,
                      canary=r1.canary, pstore=r1.pstore)
    assert r2.ctx.mesh.shape["data"] == 2
    assert r2.event.lost_slices == (2,)
    assert r2.event.uncertified_blocks == 0
    assert emgr.dead == {2, 3}
    assert emgr.slice_ids == [0, 1]
    assert stale_keys(mesh1) == 0
    st2, m = r2.step(r2.state, r2.bfn(2))
    assert np.isfinite(float(m["loss"]))
    # losing every surviving row is unrecoverable — must refuse loudly
    with pytest.raises(RuntimeError):
        emgr.on_loss(step=3, dead_rows=(0, 1), state=st2,
                     raw_step=r2.raw_step, cfg=cfg, batch_fn=raw_bfn,
                     canary=r2.canary, pstore=r2.pstore)


def test_bind_state_offmesh_passthrough(tiny_setup):
    """Off-mesh, bind_state is the identity recipe: no device_put, no
    pin, iterable unpack, pin() == identity."""
    from repro.launch.specs import bind_state

    cfg, state0, _, bfn = tiny_setup
    raw = lambda s, b: (s, {})
    bound = bind_state(None, cfg, state0, raw, bfn)
    st, step, bf, sh = bound
    assert st is state0 and step is raw and bf is bfn and sh is None
    assert bound.pin(raw) is raw


def test_kill_row_requires_elastic(tiny_cfg):
    from repro.launch.train import train

    with pytest.raises(ValueError, match="kill_row_at requires elastic"):
        train(tiny_cfg, steps=1, global_batch=2, seq_len=16,
              kill_row_at=0, verbose=False)


# ---------------------------------------------------------------------------
# subprocess chaos drills (always run: the child forces 8 CPU devices)
# ---------------------------------------------------------------------------

_DRILL = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json
    import numpy as np
    import jax, jax.numpy as jnp

    from repro.configs import get_config
    from repro.core.detect import ChecksumCanary, FaultReport
    from repro.core.icp import promote
    from repro.core.microcheckpoint import MicroCheckpointer
    from repro.core.parity import ParityStore
    from repro.core.recover import RecoveryRuntime
    from repro.data.pipeline import TokenPipeline
    from repro.distributed.context import DistContext
    from repro.kernels import digest as kdigest
    from repro.launch.elastic import ElasticManager, stolen_batch
    from repro.launch.specs import bind_state
    from repro.train.loop import make_train_state, make_train_step

    out = {}
    cfg = get_config("iterpro-100m").smoke()
    cfg = dataclasses.replace(
        cfg, sharding=dataclasses.replace(cfg.sharding, fsdp=True))
    B, S, KILL, STEPS = 12, 16, 3, 7
    ctx = DistContext.for_mesh(jax.make_mesh((4, 2), ("data", "model")))
    pipe = TokenPipeline(cfg.model.vocab_size, S, B, seed=0)
    state = make_train_state(cfg, jax.random.PRNGKey(0), global_batch=B)
    raw_bfn = lambda s: pipe.batch_at(s)
    state, raw, bfn, sh = bind_state(
        ctx, cfg, state, make_train_step(cfg, global_batch=B), raw_bfn)
    step = jax.jit(raw)
    canary = ChecksumCanary(state, n_slices=1, ctx=ctx)
    pstore = ParityStore(state, ctx=ctx, row_safe=True)
    pstore.build(state)
    canary.attach_parity(pstore)
    out["parity_covers"] = len(pstore.plan.keys)
    emgr = ElasticManager(ctx)
    runtime = RecoveryRuntime(
        step_fn=step, batch_fn=bfn, iv_registry=promote(cfg, B),
        micro=MicroCheckpointer(interval=2, ctx=ctx), parity=pstore,
        shardings=sh, canary=canary,
        elastic=emgr.hook(raw_step=raw, cfg=cfg, batch_fn=raw_bfn,
                          canary=canary, pstore=pstore))

    losses = []
    for s in range(KILL):
        ns, m = step(state, bfn(s))
        assert canary.check_and_arm(s, state, ns) is None
        losses.append(float(m["loss"]))
        state = ns

    # pre-loss oracle (ground truth for the equivalence assertions; the
    # recovery path itself never reads the dead devices)
    oracle = jax.tree_util.tree_map(np.asarray, state)

    report = FaultReport(KILL, "external", lost_rows=(3,),
                         detail="chaos drill: row 3 lost")
    state, ev = runtime.recover(state, report, KILL)
    resume = runtime.pending_remesh
    out["rung"] = ev.rung
    out["attempted"] = list(ev.attempted)
    out["has_resume"] = resume is not None
    e = resume.event
    out["event"] = e.to_dict()
    out["new_dp"] = resume.ctx.mesh.shape["data"]

    # bit-identity of the reconstructed state vs the pre-loss oracle
    got = jax.tree_util.tree_map(np.asarray, resume.state)
    flat_g, _ = jax.tree_util.tree_flatten(got)
    flat_o, _ = jax.tree_util.tree_flatten(oracle)
    out["state_bit_identical"] = all(
        np.array_equal(np.atleast_1d(a).view(np.uint8),
                       np.atleast_1d(b).view(np.uint8))
        for a, b in zip(flat_g, flat_o))

    # no dead device holds any shard of the resumed state
    dead = set(ctx.row_devices(3))
    out["dead_unreferenced"] = not any(
        sh_.device in dead
        for leaf in jax.tree_util.tree_leaves(resume.state)
        for sh_ in leaf.addressable_shards)

    # survivors' stolen loads reassemble the exact global batch
    sb = stolen_batch(pipe, KILL, 4, (3,))
    ref = pipe.batch_at(KILL)
    out["stolen_batch_identity"] = all(
        np.array_equal(np.asarray(sb[k]), np.asarray(ref[k])) for k in ref)

    # drill continuation on the AOT-compiled resume step
    st = resume.state
    drill_losses = []
    for s in range(KILL, STEPS):
        ns, m = resume.step(st, resume.bfn(s))
        assert resume.canary.check_and_arm(s, st, ns) is None
        drill_losses.append(float(m["loss"]))
        st = ns

    # steady-state contract after remesh: 1 launch + 1 sync + 0 retraces
    kdigest.STATS.reset()
    extra = []
    for s in range(STEPS, STEPS + 2):
        ns, m = resume.step(st, resume.bfn(s))
        assert resume.canary.check_and_arm(s, st, ns) is None
        extra.append(float(m["loss"]))
        st = ns
    jax.block_until_ready(jax.tree_util.tree_leaves(st)[0])
    out["stats"] = kdigest.STATS.snapshot()

    # oracle continuation: a NEVER-FAILED run on the degraded mesh from
    # the pre-loss oracle state, same global batches — must match the
    # drill losses bit-exactly (deterministic CPU XLA)
    ob = bind_state(resume.ctx, cfg, oracle, raw, raw_bfn)
    ostep = jax.jit(ob.step)
    ost = ob.state
    oracle_losses = []
    for s in range(KILL, STEPS + 2):
        ost, m = ostep(ost, ob.bfn(s))
        oracle_losses.append(float(m["loss"]))
    out["losses_match_oracle"] = drill_losses + extra == oracle_losses
    out["drill_losses"] = drill_losses
    out["oracle_losses"] = oracle_losses
    print(json.dumps(out))
""")


def _run_child(prog, timeout=1200):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "src"),
         env.get("PYTHONPATH", "")])
    res = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert res.returncode == 0, f"child failed:\n{res.stdout}\n{res.stderr}"
    return json.loads(res.stdout.strip().splitlines()[-1])


def test_chaos_drill_row_loss_resume():
    """THE drill: 8-device child, row 3 dies between steps, remesh rung
    recovers with zero disk restores, digest-certified bit-identical
    state, bit-identical degraded-trajectory losses, steady-state
    1/1/0 after resume."""
    out = _run_child(_DRILL)
    assert out["rung"] == "remesh"
    assert out["attempted"] == ["remesh"]         # no other rung touched
    assert out["has_resume"]
    assert out["new_dp"] == 3
    ev = out["event"]
    assert ev["disk_restores"] == 0               # zero disk-checkpoint
    assert ev["lost_slices"] == [3]
    assert ev["blocks_reconstructed"] > 0         # FSDP shards via parity
    assert ev["certified_blocks"] > 0             # vs surviving digests
    assert ev["uncertified_blocks"] == 0          # K=1: fully certified
    assert out["parity_covers"] > 0
    assert out["state_bit_identical"]
    assert out["dead_unreferenced"]
    assert out["stolen_batch_identity"]
    assert out["losses_match_oracle"], (
        out["drill_losses"], out["oracle_losses"])
    launches, syncs, traces = out["stats"]
    assert launches == 2 and syncs == 2 and traces == 0


def test_train_cli_elastic_kill_row_smoke():
    """The driver-level drill: --elastic --kill-row-at through the real
    train CLI, asserting the remesh event lands in the JSON report and
    the loop finishes every step at reduced DP width."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count=8"
        import json
        from repro.configs import get_config
        from repro.launch.train import train

        cfg = get_config("iterpro-100m").smoke()
        out = train(cfg, steps=6, global_batch=8, seq_len=16,
                    canary_slices=1, mesh="4,2", parity=True,
                    elastic=True, kill_row_at=3, verbose=False)
        print(json.dumps(out))
    """)
    out = _run_child(prog)
    assert out["steps"] == 6
    assert out["faults_detected"] == 1 and out["faults_recovered"] == 1
    assert out["recovery"]["by_rung"] == {"remesh": 1}
    [ev] = out["elastic_events"]
    assert ev["lost_rows"] == [3] and ev["disk_restores"] == 0
    assert out["mesh"]["shape"] == {"data": 3, "model": 2}
