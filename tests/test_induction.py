"""Hypothesis property tests for the paper's Eq. (1) and the IV registry —
the system invariants behind induction-variable recovery."""

import pytest

pytest.importorskip("hypothesis")   # optional dep: skip, don't break collection
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.induction import IVRegistry, IVSpec, RecoveryAbort

steps = st.integers(min_value=-1000, max_value=1000).filter(lambda s: s != 0)
inits = st.integers(min_value=-10**6, max_value=10**6)
iters = st.integers(min_value=0, max_value=10**6)


@given(i0=inits, si=steps, k0=inits, sk=steps, n=iters)
@settings(max_examples=200, deadline=None)
def test_eq1_roundtrip(i0, si, k0, sk, n):
    """Eq. (1): recovering i from a healthy partner k at any iteration n
    returns exactly i's true value — for any affine family, including
    negative and non-unit steps."""
    reg = IVRegistry({"i": (i0, si), "k": (k0, sk)})
    k_val = k0 + n * sk
    assert reg.eq1("i", "k", k_val) == i0 + n * si


@given(i0=inits, si=steps, n=iters)
@settings(max_examples=100, deadline=None)
def test_iteration_of_inverse(i0, si, n):
    spec = IVSpec("x", i0, si)
    assert spec.iteration_of(spec.value_at(n)) == n


@given(n=iters, bad_idx=st.integers(0, 4),
       corrupt=st.integers(-10**9, 10**9))
@settings(max_examples=200, deadline=None)
def test_majority_diagnosis_repairs_single_corruption(n, bad_idx, corrupt):
    """With >=3 IVs, one corrupted counter is identified and repaired from
    the consensus iteration — the framework's extension of pairwise Eq. (1)."""
    specs = {f"v{j}": (j * 3, j + 1) for j in range(5)}
    reg = IVRegistry(specs)
    values = {name: spec[0] + n * spec[1] for name, spec in specs.items()}
    name = f"v{bad_idx}"
    truth = values[name]
    values[name] = corrupt
    fixed, bad = reg.recover(values)
    assert fixed[name] == truth
    assert all(fixed[k] == specs[k][0] + n * specs[k][1] for k in specs)
    if corrupt != truth:
        assert bad == [name]


@given(n=iters)
@settings(max_examples=50, deadline=None)
def test_no_consensus_aborts(n):
    """Exact-or-abort: when no majority agrees, recovery must raise rather
    than risk an SDC (the paper's §5.3.1 rule)."""
    reg = IVRegistry({"a": (0, 1), "b": (0, 2), "c": (0, 3)})
    # corrupt two of three -> no strict majority
    values = {"a": n, "b": 2 * n + 7, "c": 3 * n + 11}
    with pytest.raises(RecoveryAbort):
        reg.recover(values)


def test_icp_counts():
    """Table-6 analogue: ICP creates recoverable IVs where none existed."""
    from repro.configs import get_config
    from repro.core.icp import recoverable_iv_count
    cfg = get_config("iterpro-100m")
    assert recoverable_iv_count(cfg, 256, icp_enabled=False) == 0
    assert recoverable_iv_count(cfg, 256, icp_enabled=True) >= 5
