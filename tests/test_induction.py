"""Hypothesis property tests for the paper's Eq. (1) and the IV registry —
the system invariants behind induction-variable recovery."""

import pytest

pytest.importorskip("hypothesis")   # optional dep: skip, don't break collection
from hypothesis import assume, given, settings, strategies as st  # noqa: E402

from repro.core.induction import IVRegistry, IVSpec, RecoveryAbort

steps = st.integers(min_value=-1000, max_value=1000).filter(lambda s: s != 0)
inits = st.integers(min_value=-10**6, max_value=10**6)
iters = st.integers(min_value=0, max_value=10**6)


@given(i0=inits, si=steps, k0=inits, sk=steps, n=iters)
@settings(max_examples=200, deadline=None)
def test_eq1_roundtrip(i0, si, k0, sk, n):
    """Eq. (1): recovering i from a healthy partner k at any iteration n
    returns exactly i's true value — for any affine family, including
    negative and non-unit steps."""
    reg = IVRegistry({"i": (i0, si), "k": (k0, sk)})
    k_val = k0 + n * sk
    assert reg.eq1("i", "k", k_val) == i0 + n * si


@given(i0=inits, si=steps, n=iters)
@settings(max_examples=100, deadline=None)
def test_iteration_of_inverse(i0, si, n):
    spec = IVSpec("x", i0, si)
    assert spec.iteration_of(spec.value_at(n)) == n


@given(n=iters, bad_idx=st.integers(0, 4),
       corrupt=st.integers(-10**9, 10**9))
@settings(max_examples=200, deadline=None)
def test_majority_diagnosis_repairs_single_corruption(n, bad_idx, corrupt):
    """With >=3 IVs, one corrupted counter is identified and repaired from
    the consensus iteration — the framework's extension of pairwise Eq. (1)."""
    specs = {f"v{j}": (j * 3, j + 1) for j in range(5)}
    reg = IVRegistry(specs)
    values = {name: spec[0] + n * spec[1] for name, spec in specs.items()}
    name = f"v{bad_idx}"
    truth = values[name]
    values[name] = corrupt
    fixed, bad = reg.recover(values)
    assert fixed[name] == truth
    assert all(fixed[k] == specs[k][0] + n * specs[k][1] for k in specs)
    if corrupt != truth:
        assert bad == [name]


@given(n=iters)
@settings(max_examples=50, deadline=None)
def test_no_consensus_aborts(n):
    """Exact-or-abort: when no majority agrees, recovery must raise rather
    than risk an SDC (the paper's §5.3.1 rule)."""
    reg = IVRegistry({"a": (0, 1), "b": (0, 2), "c": (0, 3)})
    # corrupt two of three -> no strict majority
    values = {"a": n, "b": 2 * n + 7, "c": 3 * n + 11}
    with pytest.raises(RecoveryAbort):
        reg.recover(values)


@given(i0=inits, si=steps, k0=inits, sk=steps, n=iters,
       r=st.integers(min_value=1, max_value=999))
@settings(max_examples=200, deadline=None)
def test_eq1_rejects_off_family_partner(i0, si, k0, sk, n, r):
    """Regression, generalised: a partner value with a non-zero residue
    mod its step is NOT on its affine family (it is itself corrupted) —
    Eq. (1) must abort rather than silently floor-divide and manufacture
    a wrong repair."""
    resid = r % abs(sk)
    assume(resid != 0)
    reg = IVRegistry({"i": (i0, si), "k": (k0, sk)})
    with pytest.raises(RecoveryAbort):
        reg.eq1("i", "k", k0 + n * sk + resid)


@given(i0=inits, si=steps, k0=inits, sk=steps, n=iters)
@settings(max_examples=200, deadline=None)
def test_eq1_agrees_with_diagnose(i0, si, k0, sk, n):
    """Pairwise Eq. (1) and the majority engine are one theory: with both
    partners healthy, diagnose's consensus iteration is n with nothing
    flagged, and eq1 in either direction reproduces the true values."""
    reg = IVRegistry({"i": (i0, si), "k": (k0, sk)})
    vals = {"i": i0 + n * si, "k": k0 + n * sk}
    n_star, bad = reg.diagnose(vals)
    assert n_star == n and bad == []
    assert reg.eq1("i", "k", vals["k"]) == vals["i"]
    assert reg.eq1("k", "i", vals["i"]) == vals["k"]


@given(n=iters, m=iters)
@settings(max_examples=100, deadline=None)
def test_strict_majority_repairs_minority(n, m):
    """3-of-5 agreement is a strict majority: the consensus wins and
    exactly the two outliers are flagged and rewritten."""
    assume(n != m)
    reg = IVRegistry({f"v{j}": (j, 1) for j in range(5)})
    vals = {f"v{j}": j + (n if j < 3 else m) for j in range(5)}
    n_star, bad = reg.diagnose(vals)
    assert n_star == n
    assert bad == ["v3", "v4"]
    fixed, repaired = reg.recover(vals)
    assert repaired == ["v3", "v4"]
    assert all(fixed[f"v{j}"] == j + n for j in range(5))


@given(n=iters, m=iters)
@settings(max_examples=100, deadline=None)
def test_tie_is_not_a_majority(n, m):
    """2-vs-2 split: strict majority means a tie aborts — picking either
    side would be a coin-flip SDC."""
    assume(n != m)
    reg = IVRegistry({f"v{j}": (j, 1) for j in range(4)})
    vals = {f"v{j}": j + (n if j < 2 else m) for j in range(4)}
    n_star, _ = reg.diagnose(vals)
    assert n_star is None
    with pytest.raises(RecoveryAbort):
        reg.recover(vals)


def test_icp_counts():
    """Table-6 analogue: ICP creates recoverable IVs where none existed."""
    from repro.configs import get_config
    from repro.core.icp import recoverable_iv_count
    cfg = get_config("iterpro-100m")
    assert recoverable_iv_count(cfg, 256, icp_enabled=False) == 0
    assert recoverable_iv_count(cfg, 256, icp_enabled=True) >= 5
