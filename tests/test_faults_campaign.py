"""Seeded end-to-end injection-conformance suite (paper §5.1 methodology).

FlipTracker-style validation: instead of sampling random flips and
trusting the classifier, every plan below is CONSTRUCTED so its physical
outcome is forced, and the Benign/Crash/SDC/Hang classifier plus the
recovery ladder are asserted against that independently-known ground
truth — under the stock loop, the canary loop, and the donated
(``donate_argnums``) production loop.

Ground-truth reasoning per plan (tiny iterpro-100m smoke config, seed 0):

* ``norm-scale-b30`` — flips exponent bit 30 of ``final_norm/scale[3]``
  (a value ~1e-7 → ~3e31): the output norm scales logits past float32
  softmax range, so the loss goes non-finite within the injected step.
  The FREE trap must catch it (the paper's SIGSEGV analogue).
* ``ffn-b30-dormant`` — bit 30 of one ``ffn/up/w`` weight (~0.02 →
  ~1e37): RMSNorm structurally renormalises the exploded channel, the
  loss stays finite and close — free traps are blind, the trajectory
  silently diverges => SDC.  The canary converts it into an immediately
  detected, exactly recovered crash.
* ``wq-b27-benign`` — bit 27 of one attention weight (~1e-2 relative
  nudge of a single scalar): horizon loss within 1e-5 relative of truth
  => benign under free traps.  Still a persistent flip, so the canary
  reports it (crash + exact recovery) — detection coverage exceeds the
  paper's.
* ``iv-step-b12`` — bit 12 of the ``iv/step`` counter: invisible to the
  loss at this horizon (benign under free traps); the canary localises
  it to the IV block, where the NON-donated ladder repairs via the
  Eq. (1) partner rung — and the DONATED ladder must pivot to the
  in-HBM snapshot + replay rung unconditionally (the pre-step state was
  consumed by the step).
* ``opt-t-b3`` — bit 3 of the optimizer's own step counter ``opt/t``
  (2 → 10): the shifted bias corrections are loss-invisible at this
  horizon (benign under free traps), but the counter is an affine member
  of the induction registry — the canary localises the flip to
  ``opt/t`` and the opt_iv branch of the Eq. (1) consensus engine
  repairs it in place: rung ≤ 1, ZERO snapshot bytes, ZERO replayed
  steps.  Donation pivots to replay exactly like the iv case.

All crashes must recover to a BIT-EXACT trajectory (trial.exact): the
continued run equals the never-faulted run bit for bit.
"""

import os
import sys

# the campaign engine lives in benchmarks/ (shared with the paper-table
# benchmarks); make the repo root importable under pytest
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import random

import pytest

from benchmarks._campaign import Campaign, summarize
from repro.core import InjectionPlan
from repro.core.recovery_table import (
    RUNG_EQ1,
    RUNG_OPT_IV,
    RUNG_PARITY,
    RUNG_REPLAY,
    RUNG_TRIAGE,
)

pytestmark = pytest.mark.slow

TOTAL_STEPS = 8


@pytest.fixture(scope="module")
def campaign():
    """Tiny config + fault-free ground-truth trajectory (8 steps)."""
    return Campaign(total_steps=TOTAL_STEPS, snapshot_interval=2, seed=0)


# (name, plan, expected outcome per detection regime)
#   traps    = free traps only (paper §5.2 setup)
#   canary   = + rotating checksum canary, K=1, donate=False
#   donated  = + canary, donate=True (production compilation)
# expected := (outcome, detector, recovered, exact, rung)
CASES = [
    ("norm-scale-b30",
     InjectionPlan("final_norm/scale", 3, 30, 2, "params"),
     {"traps":   ("crash", "nonfinite", True, True, RUNG_REPLAY),
      "canary":  ("crash", "nonfinite", True, True, RUNG_REPLAY),
      "donated": ("crash", "checksum", True, True, RUNG_REPLAY),
      # in-step detection checks the INPUT slice before the traps ever
      # see the step's (non-finite) loss — detector is the checksum,
      # exactly as in the donated pre-step check; outcome/rung identical
      "fused":   ("crash", "checksum", True, True, RUNG_REPLAY)}),
    ("ffn-b30-dormant",
     InjectionPlan("groups/0/0/ffn/up/w", 1000, 30, 3, "params"),
     {"traps":   ("sdc", "", False, False, ""),
      "canary":  ("crash", "checksum", True, True, RUNG_REPLAY),
      "donated": ("crash", "checksum", True, True, RUNG_REPLAY)}),
    ("wq-b27-benign",
     InjectionPlan("groups/0/0/attn/wq/w", 500, 27, 2, "params"),
     {"traps":   ("benign", "", False, False, ""),
      "canary":  ("crash", "checksum", True, True, RUNG_REPLAY),
      "donated": ("crash", "checksum", True, True, RUNG_REPLAY)}),
    ("iv-step-b12",
     InjectionPlan("step", 0, 12, 2, "iv"),
     {"traps":   ("benign", "", False, False, ""),
      "canary":  ("crash", "checksum", True, True, RUNG_EQ1),
      "donated": ("crash", "checksum", True, True, RUNG_REPLAY)}),
    ("opt-t-b3",
     InjectionPlan("t", 0, 3, 2, "opt"),
     {"traps":   ("benign", "", False, False, ""),
      "canary":  ("crash", "checksum", True, True, RUNG_OPT_IV),
      "donated": ("crash", "checksum", True, True, RUNG_REPLAY)}),
]

REGIMES = {"traps": dict(use_canary=False, donate=False),
           "canary": dict(use_canary=True, donate=False),
           "donated": dict(use_canary=True, donate=True),
           # in-step fused detection must CONFORM to the unfused paths:
           # same outcomes, same detectors, same rungs, same exactness
           # (fused non-donated ≡ canary regime — incl. the Eq.(1) rung
           # chosen from the RESOLVED deferred attribution; fused donated
           # ≡ donated regime's unconditional replay pivot)
           "fused": dict(use_canary=True, donate=False, fused=True),
           "fused-donated": dict(use_canary=True, donate=True, fused=True)}

#: which CASES expectation column a regime is asserted against when the
#: case has no explicit column for it
EXPECT_AS = {"fused": "canary", "fused-donated": "donated"}


@pytest.mark.parametrize("name,plan,expected",
                         CASES, ids=[c[0] for c in CASES])
@pytest.mark.parametrize("regime", list(REGIMES))
def test_outcome_conformance(campaign, name, plan, expected, regime):
    """Classifier + ladder conformance against constructed ground truth."""
    want_outcome, want_detector, want_rec, want_exact, want_rung = \
        expected.get(regime) or expected[EXPECT_AS.get(regime, regime)]
    trial = campaign.run_trial(random.Random(0), plan=plan,
                               canary_slices=1, **REGIMES[regime])
    assert trial.outcome == want_outcome, (name, regime, trial)
    assert trial.detector == want_detector, (name, regime, trial)
    assert trial.recovered == want_rec, (name, regime, trial)
    if want_rec:
        # detected crashes recover to a BIT-EXACT trajectory
        assert trial.exact == want_exact, (name, regime, trial)
        assert trial.rung == want_rung, (name, regime, trial)
        # detection is near-immediate (paper: ≤50 instructions; here:
        # within one step of the injection)
        assert 0 <= trial.latency_steps <= 1, (name, regime, trial)


def test_classifier_aggregate_matches_ground_truth(campaign):
    """The summarize() table over the fixed plan list must reproduce the
    per-plan ground truth exactly (no hangs, canary converts every
    silent corruption into a recovered crash)."""
    rng = random.Random(0)
    traps = summarize([campaign.run_trial(rng, plan=p, use_canary=False)
                       for _, p, _ in CASES])
    assert traps["outcomes"] == {"crash": 1, "sdc": 1, "benign": 3}
    assert traps["outcomes"].get("hang", 0) == 0
    assert traps["crash_symptoms"] == {"nonfinite": 1}

    canary = summarize([campaign.run_trial(rng, plan=p, use_canary=True,
                                           canary_slices=1)
                        for _, p, _ in CASES])
    assert canary["outcomes"] == {"crash": 5}
    assert canary["recovered"] == 5
    assert canary["exact"] == 5 and canary["exact_rate"] == 1.0

    donated = summarize([campaign.run_trial(rng, plan=p, use_canary=True,
                                            canary_slices=1, donate=True)
                         for _, p, _ in CASES])
    assert donated["outcomes"] == {"crash": 5}
    assert donated["recovered"] == 5 and donated["exact"] == 5
    # the donated ladder NEVER uses an in-place rung — unconditional
    # pivot to the in-HBM snapshot + replay
    assert set(donated["by_rung"]) == {RUNG_REPLAY}


def test_donated_sweep_recovers_via_replay_only(campaign):
    """Sampled (size-weighted) donated sweep: every detected crash must
    recover bit-exactly through the snapshot+replay pivot — an in-place
    rung firing under donation would mean the runtime touched a donated
    buffer."""
    trials = campaign.run(6, seed=11, use_canary=True, canary_slices=1,
                          donate=True)
    crashes = [t for t in trials if t.outcome == "crash"]
    assert crashes, "sweep produced no detected crash"
    for t in crashes:
        assert t.recovered and t.exact, t
        assert t.rung == RUNG_REPLAY, t


def test_donated_and_stock_loops_agree_bitwise(campaign):
    """donate_argnums must not change the math: the donated fault-free
    trajectory equals the stock trajectory bit for bit."""
    state = campaign.clone(campaign.states[0])
    dstep = campaign.donated_step()
    for s in range(TOTAL_STEPS):
        state, _ = dstep(state, campaign.bfn(s))
    assert campaign._digest(state) == campaign.final_digest


def test_parity_regime_repairs_low_bit_flip(campaign):
    """Donated pair + XOR parity (the acceptance path): a low-mantissa
    flip — finite, loss-invisible, localisable without digest-collision
    ambiguity — must repair via the snapshot-free parity rung: 0 steps
    replayed, O(bytes/D) moved, bit-exact continuation."""
    plan = InjectionPlan("groups/0/0/ffn/up/w", 1000, 5, 3, "params")
    trial = campaign.run_trial(random.Random(0), plan=plan, canary_slices=1,
                               parity=True, donate=True)
    assert trial.outcome == "crash" and trial.detector == "checksum", trial
    assert trial.recovered and trial.exact, trial
    assert trial.rung == RUNG_PARITY, trial
    assert trial.replayed == 0, trial
    assert trial.bytes_moved > 0, trial
    assert trial.latency_steps == 0, trial


def test_parity_sweep_exact_with_snapshot_free_repairs(campaign):
    """Sampled donated sweep with parity: every detected crash recovers
    bit-exactly; the rung is parity_xor wherever the injury certifies
    uniquely, and escalates to replay otherwise (a high-bit flip can
    Fletcher-collide with its XOR-mirrored repair — exact-or-abort)."""
    trials = campaign.run(6, target="params", seed=3, parity=True,
                          donate=True)
    crashes = [t for t in trials if t.outcome == "crash"]
    assert crashes, "sweep produced no detected crash"
    for t in crashes:
        assert t.recovered and t.exact, t
        assert t.rung in (RUNG_PARITY, RUNG_REPLAY), t
        if t.rung == RUNG_PARITY:
            assert t.replayed == 0 and t.bytes_moved > 0, t
    assert any(t.rung == RUNG_PARITY for t in crashes), crashes


def test_parity_fused_regimes(campaign):
    """In-step fused detection + parity: the NON-donated fused loop keeps
    live survivors, so parity repairs in place; the fused DONATED loop's
    report says consumed=True (the detecting launch ate the faulting
    buffers) and must pivot to snapshot+replay unconditionally."""
    plan = InjectionPlan("groups/0/0/ffn/up/w", 1000, 5, 3, "params")
    live = campaign.run_trial(random.Random(0), plan=plan, canary_slices=1,
                              parity=True, fused=True)
    assert live.outcome == "crash" and live.recovered and live.exact, live
    assert live.rung == RUNG_PARITY, live

    dead = campaign.run_trial(random.Random(0), plan=plan, canary_slices=1,
                              parity=True, donate=True, fused=True)
    assert dead.outcome == "crash" and dead.recovered and dead.exact, dead
    assert dead.rung == RUNG_REPLAY, dead


def test_care_mode_rejects_donation(campaign):
    """CARE diagnoses the live IV block — undefined once the step has
    consumed it; the campaign must refuse the combination loudly."""
    with pytest.raises(ValueError):
        campaign.run_trial(random.Random(0), mode="care", donate=True)


def test_opt_state_flip_stays_on_rung_one(campaign):
    """The acceptance criterion, asserted end to end: an optimizer-state
    counter flip is recovered at rung <= 1 (eq1/opt_iv) — zero snapshot
    bytes read, zero replayed steps — and the continued trajectory is
    bit-exact."""
    plan = InjectionPlan("t", 0, 3, 2, "opt")
    trial = campaign.run_trial(random.Random(0), plan=plan, use_canary=True,
                               canary_slices=1)
    assert trial.outcome == "crash" and trial.detector == "checksum", trial
    assert trial.recovered and trial.exact, trial
    assert trial.rung in (RUNG_EQ1, RUNG_OPT_IV), trial
    assert trial.replayed == 0, trial
    assert trial.bytes_moved == 0, trial
    # ...and the ladder never even attempted a snapshot rung: the repair
    # is pure scalar arithmetic over the induction registry
    assert trial.latency_steps == 0, trial


def test_triage_tolerates_certified_flip(campaign):
    """Rung 0 in the live loop: a mantissa-tail flip in a first-moment
    EMA certifies below-epsilon — triage tolerates it (no repair, zero
    bytes, zero replay) and the loop runs on without the canary
    re-firing.  The trajectory is NOT bit-exact (the flip stays), which
    is the point: tolerated, not repaired."""
    plan = InjectionPlan("m/groups/0/0/ffn/up/w", 1000, 1, 3, "opt")
    trial = campaign.run_trial(random.Random(0), plan=plan, canary_slices=1,
                               triage=True)
    assert trial.outcome == "crash" and trial.detector == "checksum", trial
    assert trial.recovered, trial
    assert trial.rung == RUNG_TRIAGE, trial
    assert trial.replayed == 0, trial
    assert trial.bytes_moved == 0, trial
    assert trial.latency_steps == 0, trial


def test_triage_escalates_to_exact_repair(campaign):
    """The same moment leaf, exponent bit 30: the epsilon certificate
    fails, triage aborts, and the ladder escalates to an EXACT repair —
    exact-or-abort survives rung 0."""
    plan = InjectionPlan("m/groups/0/0/ffn/up/w", 1000, 30, 3, "opt")
    trial = campaign.run_trial(random.Random(0), plan=plan, canary_slices=1,
                               triage=True)
    assert trial.outcome == "crash" and trial.detector == "checksum", trial
    assert trial.recovered and trial.exact, trial
    assert trial.rung != RUNG_TRIAGE, trial


def test_triage_preserves_param_fault_behaviour(campaign):
    """triage=True must not change how UNCERTIFIABLE faults recover: a
    param exponent flip still replays bit-exactly, exactly as in the
    canary regime without triage."""
    plan = InjectionPlan("groups/0/0/ffn/up/w", 1000, 30, 3, "params")
    trial = campaign.run_trial(random.Random(0), plan=plan, canary_slices=1,
                               triage=True)
    assert trial.outcome == "crash" and trial.recovered and trial.exact, trial
    assert trial.rung == RUNG_REPLAY, trial
