"""Checkpoint store: bit-exact roundtrip, atomic commit, digest verify,
async writer error propagation."""

import glob
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_latest, save_checkpoint


def _state():
    k = jax.random.PRNGKey(0)
    return {
        "params": {"w": jax.random.normal(k, (33, 9)),
                   "emb": jax.random.normal(k, (50, 8)).astype(jnp.bfloat16)},
        "opt": {"m": jnp.zeros((33, 9)), "t": jnp.int32(7)},
        "iv": {"step": jnp.int32(7)},
    }


def test_roundtrip_bit_exact(tmp_path):
    state = _state()
    save_checkpoint(str(tmp_path), state, step=7)
    restored, step = load_latest(str(tmp_path), state)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        assert np.asarray(a).dtype == np.asarray(b).dtype
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_corruption_aborts_restore(tmp_path):
    """Exact-or-abort extends to disk: a rotted checkpoint must not load."""
    state = _state()
    save_checkpoint(str(tmp_path), state, step=3)
    payload = glob.glob(str(tmp_path / "slot*.npz"))[0]
    raw = bytearray(open(payload, "rb").read())
    raw[len(raw) // 2] ^= 0xFF
    open(payload, "wb").write(bytes(raw))
    with pytest.raises(Exception):
        load_latest(str(tmp_path), state)


def test_double_buffering_survives_partial_write(tmp_path):
    state = _state()
    mgr = CheckpointManager(str(tmp_path), interval=1, async_write=False)
    mgr.save(1, state)
    mgr.save(2, state)
    # simulate a crash mid-write of slot0 (the NEXT slot): trash it WITHOUT
    # committing a manifest — the committed manifest still points at slot1
    with open(tmp_path / "slot0.npz", "wb") as f:
        f.write(b"garbage")
    restored, step = mgr.restore(state)
    assert step == 2


def test_async_writer(tmp_path):
    state = _state()
    mgr = CheckpointManager(str(tmp_path), interval=2)
    assert mgr.maybe_save(0, state)
    assert not mgr.maybe_save(1, state)
    assert mgr.maybe_save(4, state)
    mgr.wait()
    _, step = mgr.restore(state)
    assert step == 4
    manifest = json.load(open(tmp_path / "manifest.json"))
    assert manifest["step"] == 4
