"""Quickstart: build a model, train a few steps, survive a fault.

    PYTHONPATH=src python examples/quickstart.py

Walks the public API end to end in under a minute on CPU:
  1. pick an architecture config (any of the 10 assigned + iterpro-100m);
  2. one jitted train step on synthetic data;
  3. flip one bit in the state (simulated transient error);
  4. detect it with the checksum canary and repair it with the recovery
     ladder — then verify the repair is bit-exact.
"""

import dataclasses
import random

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.core import (ChecksumCanary, MicroCheckpointer, RecoveryRuntime,
                        inject, promote, sample_plan)
from repro.data.pipeline import TokenPipeline
from repro.train.loop import make_train_state, make_train_step


def main():
    print("assigned architectures:", ", ".join(list_archs()))
    cfg = get_config("iterpro-100m").smoke()   # CPU-sized reduced config
    B, S = 4, 64

    # --- substrate: data, state, step -----------------------------------
    pipe = TokenPipeline(cfg.model.vocab_size, S, B, seed=0)
    state = make_train_state(cfg, jax.random.PRNGKey(0), global_batch=B)
    step = jax.jit(make_train_step(cfg, global_batch=B))

    # --- resilience: snapshots + canary + runtime ------------------------
    micro = MicroCheckpointer(interval=2)
    runtime = RecoveryRuntime(step_fn=step, batch_fn=pipe.batch_at,
                              iv_registry=promote(cfg, B), micro=micro)

    for s in range(6):
        micro.maybe_snapshot(s, state)
        micro.record_iv(s, state["iv"])
        state, metrics = step(state, pipe.batch_at(s))
        print(f"step {s}: loss {float(metrics['loss']):.4f}")

    canary = ChecksumCanary(state, n_slices=1)

    # --- a transient error strikes --------------------------------------
    plan = dataclasses.replace(
        sample_plan(random.Random(1), state, 1, target="params"), bit=30)
    corrupted = inject(state, plan)
    print(f"\ninjected bit-flip: params/{plan.leaf} bit {plan.bit}")

    report = canary.check(6, corrupted)
    print(f"canary: {report}")

    repaired, event = runtime.recover(corrupted, report, 6)
    print(f"recovered via '{event.rung}' in {event.wall_seconds*1e3:.1f} ms "
          f"({event.steps_replayed} steps replayed)")

    exact = all(np.array_equal(np.asarray(a), np.asarray(b))
                for a, b in zip(jax.tree_util.tree_leaves(state),
                                jax.tree_util.tree_leaves(repaired)))
    print("repair bit-exact:", exact)
    assert exact


if __name__ == "__main__":
    main()
