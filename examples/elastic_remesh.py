"""Elastic hard-loss recovery, live: lose a data row, keep training.

    PYTHONPATH=src python examples/elastic_remesh.py

Runs the real degraded-mesh resume path (DESIGN.md §7) on a forced
8-device CPU mesh:

1. train on a 4x2 ("data", "model") mesh with the row-safe XOR parity
   and a K=1 canary;
2. at step 4 a whole data row "dies" (a `FaultReport` with `lost_rows` —
   the recovery path never reads the dead devices again);
3. the `remesh` rung reconstructs the dead row's FSDP shards from
   parity + survivors, digest-certifies every surviving block against
   the canary's surviving reference rows, evicts everything compiled
   against the dead mesh, re-binds + re-lowers ONCE on the degraded
   (3, 2) mesh, and training resumes at dp=3 with the SAME global batch
   (survivors deterministically steal the dead slice's rows);
4. zero disk-checkpoint restore, zero replayed steps — asserted.

`--dry-run` keeps the original production-shape proof: lower + compile
the step for a 256-chip config on a simulated degraded (15, 16) mesh,
no state, no hardware.
"""

import os

# must be set before jax initialises its backends
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import dataclasses


def live():
    import jax
    from repro.configs import get_config
    from repro.launch.train import train

    assert len(jax.devices()) >= 8, (
        "run with XLA_FLAGS=--xla_force_host_platform_device_count=8")
    cfg = get_config("iterpro-100m").smoke()
    # force FSDP so the dead row's shards exist nowhere else and MUST be
    # reconstructed from parity (pure DP would just re-gather replicas)
    cfg = dataclasses.replace(
        cfg, sharding=dataclasses.replace(cfg.sharding, fsdp=True))

    out = train(cfg, steps=8, global_batch=12, seq_len=32,
                canary_slices=1, mesh="4,2", parity=True,
                elastic=True, kill_row_at=4, verbose=True)

    [ev] = out["elastic_events"]
    print(f"\nhard loss at step {ev['step']}: rows {ev['lost_rows']} -> "
          f"dp {ev['old_dp']} -> {ev['new_dp']}")
    print(f"  reconstructed {ev['blocks_reconstructed']} blocks / "
          f"{ev['bytes_reconstructed']} B from XOR parity; re-gathered "
          f"{ev['leaves_regathered']} replicated leaves")
    print(f"  certified {ev['certified_blocks']} surviving blocks against "
          f"surviving canary rows ({ev['uncertified_blocks']} failures)")
    print(f"  downtime {ev['downtime_seconds']:.2f} s = reconstruct "
          f"{ev['reconstruct_seconds']:.2f} s + re-lower "
          f"{ev['relower_seconds']:.2f} s")
    print(f"  disk restores: {ev['disk_restores']}")
    print(f"final mesh: {out['mesh']['shape']}, recovery by rung: "
          f"{out['recovery']['by_rung']}")
    assert ev["disk_restores"] == 0 and ev["uncertified_blocks"] == 0
    assert out["recovery"]["by_rung"] == {"remesh": 1}
    assert out["steps"] == 8
    print("\nelastic path proven LIVE: same step function, same global "
          "batch, reduced DP width, zero checkpoint bytes.")


def dry_run(arch: str, shape: str):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    from repro.configs import get_config, get_shape
    from repro.launch.elastic import ElasticManager, relower_degraded

    cfg = get_config(arch)
    mgr = ElasticManager(n_slices=16)
    print("healthy assignment step 0:", dict(list(
        mgr.assignment(0).items())[:4]), "...")
    print("\n!! data row 5 lost (16 chips)")
    mgr.mark_dead(5)
    print("step 1 work-stealing:", {h: v for h, v in
                                    mgr.assignment(1).items()
                                    if len(v) > 1})
    print("step 2 work-stealing:", {h: v for h, v in
                                    mgr.assignment(2).items()
                                    if len(v) > 1}, "(rotates)")
    print(f"\nre-lowering {arch} x {shape} on the degraded (15, 16) "
          f"mesh ...")
    compiled, mesh, secs = relower_degraded(cfg, get_shape(shape),
                                            lost_slices=1)
    mem = compiled.memory_analysis()
    print(f"compiled in {secs:.1f}s on mesh {dict(mesh.shape)} (240 chips)")
    print(f"per-device args: {mem.argument_size_in_bytes/1e9:.2f} GB, "
          f"temp: {mem.temp_size_in_bytes/1e9:.2f} GB")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="production-shape lower/compile proof on a "
                         "simulated 240-chip degraded mesh (no state)")
    ap.add_argument("--arch", default="gemma3-1b",
                    help="dry-run arch")
    ap.add_argument("--shape", default="train_4k",
                    help="dry-run shape")
    args = ap.parse_args()
    if args.dry_run:
        dry_run(args.arch, args.shape)
    else:
        live()


if __name__ == "__main__":
    main()
