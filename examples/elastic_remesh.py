import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Elastic re-mesh demo: lose a 16-chip data row, keep training.

    PYTHONPATH=src python examples/elastic_remesh.py [--arch gemma3-1b]

Shows the three pieces of the elastic story (DESIGN.md §5):
  1. deterministic work-stealing of the dead slices' data (no coordinator);
  2. re-lowering the SAME step function on the degraded (15, 16) mesh;
  3. the recovery ladder repairing the state that lived on the dead row
     (parity rung / replica copies), so no checkpoint restore is needed.
(This is the dry-run form: lower+compile, no real hardware.)
"""

import argparse
import time

from repro.configs import get_config, get_shape
from repro.launch.elastic import ElasticManager, relower_degraded


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--shape", default="train_4k")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    shape = get_shape(args.shape)

    mgr = ElasticManager(n_slices=16)
    print("healthy assignment step 0:", dict(list(
        mgr.assignment(0).items())[:4]), "...")

    print("\n!! data row 5 lost (16 chips)")
    mgr.mark_dead(5)
    a1 = mgr.assignment(1)
    stealers = {h: v for h, v in a1.items() if len(v) > 1}
    print("step 1 work-stealing:", stealers)
    a2 = mgr.assignment(2)
    print("step 2 work-stealing:", {h: v for h, v in a2.items()
                                    if len(v) > 1}, "(rotates)")

    print(f"\nre-lowering {args.arch} x {args.shape} on the degraded "
          f"(15, 16) mesh ...")
    compiled, mesh, secs = relower_degraded(cfg, shape, lost_slices=1)
    mem = compiled.memory_analysis()
    print(f"compiled in {secs:.1f}s on mesh {dict(mesh.shape)} "
          f"({240} chips)")
    print(f"per-device args: {mem.argument_size_in_bytes/1e9:.2f} GB, "
          f"temp: {mem.temp_size_in_bytes/1e9:.2f} GB")
    print("\nelastic path proven: same step function, reduced DP width, "
          "zero code changes.")


if __name__ == "__main__":
    main()
