"""Mesh-sharded resilience end to end (DESIGN.md §5), on a forced
8-device CPU mesh:

    PYTHONPATH=src python examples/sharded_resilience.py

1. shard a smoke train state over a 4x2 ("data", "model") mesh,
2. run the shard-local rotating canary (one logical launch + ONE
   all-reduced scalar per step — the only cross-device traffic),
3. flip one bit in one device's shard of one weight,
4. detect it and attribute it to the exact (leaf, shard) pair,
5. restore ONLY the injured shard's bytes from a version-matched,
   digest-certified micro-snapshot — healthy shards keep their buffers —
   and prove the repaired state is bit-identical to the truth.
"""

import os

# must be set before jax initialises its backends
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.detect import ChecksumCanary
from repro.core.faults import InjectionPlan, inject
from repro.core.icp import promote
from repro.core.microcheckpoint import MicroCheckpointer
from repro.core.recover import RecoveryRuntime
from repro.data.pipeline import TokenPipeline
from repro.distributed.context import DistContext
from repro.kernels import digest as kdigest
from repro.launch.specs import bind_state
from repro.train.loop import (
    make_train_state,
    make_train_step,
)


def main():
    assert len(jax.devices()) >= 8, (
        "run with XLA_FLAGS=--xla_force_host_platform_device_count=8")
    cfg = get_config("iterpro-100m").smoke()
    B, S = 8, 32
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    ctx = DistContext.for_mesh(mesh)
    print(f"mesh: {dict(mesh.shape)} -> {ctx.n_devices} shards")

    pipe = TokenPipeline(cfg.model.vocab_size, S, B, seed=0)
    state = make_train_state(cfg, jax.random.PRNGKey(0), global_batch=B)
    state, pinned, bfn, shardings = bind_state(
        ctx, cfg, state, make_train_step(cfg, global_batch=B),
        lambda s: pipe.batch_at(s))
    step = jax.jit(pinned)

    micro = MicroCheckpointer(interval=2, ctx=ctx)
    canary = ChecksumCanary(state, n_slices=1, ctx=ctx)
    runtime = RecoveryRuntime(step_fn=step, batch_fn=bfn,
                              iv_registry=promote(cfg, B), micro=micro,
                              shardings=shardings)

    print("training 4 clean steps (canary: 1 launch + 1 all-reduced "
          "scalar sync/step)...")
    for s in range(4):
        micro.maybe_snapshot(s, state)
        kdigest.STATS.reset()
        new_state, m = step(state, bfn(s))
        assert canary.check_and_arm(s, state, new_state) is None
        l, sy, tr = kdigest.STATS.snapshot()
        print(f"  step {s}: loss {float(m['loss']):.4f}  "
              f"canary launches={l} syncs={sy} retraces={tr}")
        state = new_state
    micro.maybe_snapshot(4, state)                 # version-matched anchor
    truth = jax.tree_util.tree_map(np.asarray, state)

    leaf_key = "groups/0/0/ffn/up/w"
    print(f"\nflipping bit 30 of params/{leaf_key}[1000] "
          f"(lands in the model-axis-1 shards)...")
    bad = inject(state, InjectionPlan(leaf_key, 1000, 30, 0, "params"))

    new_state, m = step(bad, bfn(4))
    report = canary.check_and_arm(4, bad, new_state)
    assert report is not None
    print(f"detected: {report}")
    print(f"(leaf, shard) attribution: {report.shards}")

    state_bytes = sum(x.nbytes for x in jax.tree_util.tree_leaves(truth))
    fixed, ev = runtime.recover(bad, report, 4)
    print(f"\nrecovered via rung '{ev.rung}' in {ev.wall_seconds*1e3:.1f} "
          f"ms — moved {ev.bytes_moved} B of a {state_bytes} B state "
          f"({100 * ev.bytes_moved / state_bytes:.2f}%)")
    ok = all(np.array_equal(np.asarray(a), b)
             for a, b in zip(jax.tree_util.tree_leaves(fixed),
                             jax.tree_util.tree_leaves(truth)))
    print(f"repaired state bit-identical to pre-fault truth: {ok}")
    assert ok and ev.rung == "shard_patch"


if __name__ == "__main__":
    main()
