"""Continuous-batching serving under transient faults: a bit flip lands
in ONE slot's decode state mid-generation; the per-slot canary attributes
it, that slot alone is evicted to prefix replay (the serving analogue of
the paper's RSI replay), and every other slot keeps decoding the very
next engine step — no request is dropped.

    PYTHONPATH=src python examples/serve_with_recovery.py
"""

import argparse
import json

from repro.configs import get_config
from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="iterpro-100m")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--slots", type=int, default=0,
                    help="batch slots (0: min(4, requests))")
    ap.add_argument("--inject", type=int, default=6,
                    help="flip one bit in a slot's decode state every N "
                         "accepted tokens")
    ap.add_argument("--donate", action="store_true",
                    help="donate the slot-major cache into the fused step "
                         "(in-place KV update)")
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    out = serve(cfg, n_requests=args.requests, prompt_len=args.prompt_len,
                gen_tokens=args.gen, inject_every=args.inject,
                n_slots=args.slots, donate=args.donate, verbose=False)
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
