"""Batched serving under transient faults: the KV cache is corrupted
mid-generation; the runtime detects it and rebuilds the cache by prefix
replay (the serving analogue of the paper's RSI replay) instead of
dropping the requests.

    PYTHONPATH=src python examples/serve_with_recovery.py
"""

import argparse
import json

from repro.configs import get_config
from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="iterpro-100m")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--inject", type=int, default=6,
                    help="corrupt the cache every N generated tokens")
    ap.add_argument("--donate", action="store_true",
                    help="donate the decode cache into the step (in-place "
                         "KV update); the canary checks pre-decode")
    ap.add_argument("--fused-detect", action="store_true",
                    help="run the cache canary INSIDE the jitted decode "
                         "(1 combined launch + 1 scalar sync per token)")
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    out = serve(cfg, n_requests=args.requests, prompt_len=args.prompt_len,
                gen_tokens=args.gen, inject_every=args.inject, verbose=True,
                donate=args.donate, fused_detect=args.fused_detect)
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
