"""End-to-end driver: train a ~100M-param LM for a few hundred steps while
an adversary injects transient faults — the loss keeps improving because
every fault is recovered with near-zero downtime.

CPU demo (reduced model, ~2 min):
    PYTHONPATH=src python examples/train_resilient.py

Full 100M config (the real target; slow on CPU, native on TPU):
    PYTHONPATH=src python examples/train_resilient.py --full --steps 300

Production compilation (in-place state update + in-step fused detection —
1 combined launch + 1 scalar sync per step):
    PYTHONPATH=src python examples/train_resilient.py --donate --fused-detect

Any assigned architecture works: --arch zamba2-7b (reduced automatically
unless --full).
"""

import argparse
import json

from repro.configs import get_config
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="iterpro-100m")
    ap.add_argument("--full", action="store_true",
                    help="use the full (unreduced) config")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--inject", type=int, default=25,
                    help="inject one bit-flip every N steps")
    ap.add_argument("--ckpt-dir", default="/tmp/iterpro_ckpt")
    ap.add_argument("--donate", action="store_true",
                    help="production compilation: donate_argnums=(0,) "
                         "(in-place state update; recovery pivots to "
                         "snapshot+replay)")
    ap.add_argument("--fused-detect", action="store_true",
                    help="run the canary INSIDE the jitted step — 1 "
                         "combined launch + 1 scalar sync per step "
                         "(DESIGN.md §4.2 in-step fused)")
    ap.add_argument("--mesh", default=None,
                    help="shard the whole resilient loop over a device "
                         "mesh, e.g. '4,2' (CPU repro: XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8; "
                         "DESIGN.md §5)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.smoke()

    out = train(cfg,
                steps=args.steps,
                global_batch=args.batch,
                seq_len=args.seq,
                snapshot_interval=8,
                checkpoint_dir=args.ckpt_dir,
                checkpoint_interval=50,
                inject_every=args.inject,
                canary_slices=4,
                donate=args.donate,
                fused_detect=args.fused_detect,
                mesh=args.mesh,
                verbose=True)

    print("\n=== run report ===")
    print(json.dumps(out, indent=1))
    losses = out.get("final_loss")
    print(f"\ntrained {out['steps']} steps; "
          f"{out['faults_injected']} faults injected, "
          f"{out['faults_recovered']} recovered; final loss {losses}")


if __name__ == "__main__":
    main()
