"""Fig 9: no-fault runtime overhead of the resilience subsystem.

Paper claim: ~0% runtime overhead + 27 MB fixed memory, because detection
is free (SIGSEGV) and the runtime is off the hot path.

Here: free traps read scalars the step already computed (literally free);
the only paid component is the optional rotating canary (1/K of state
digested per step).  We measure steps/s for: no detectors / traps only /
traps + canary at K in {8, 4, 1}, plus the micro-checkpoint memory cost."""

from __future__ import annotations

import time
from typing import Dict

import jax
import numpy as np

from benchmarks._campaign import Campaign
from repro.core import ChecksumCanary, MicroCheckpointer, trap_loss_spike, trap_nonfinite


def _loop(campaign: Campaign, steps: int, *, traps: bool, canary_k: int,
          snapshots: bool) -> float:
    """Returns steps/sec over `steps` warm steps."""
    state = campaign.states[0]
    canary = ChecksumCanary(state, n_slices=canary_k) if canary_k else None
    micro = MicroCheckpointer(interval=2) if snapshots else None
    history = []
    # warm
    st, m = campaign.step(state, campaign.bfn(0))
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    for s in range(steps):
        if micro is not None:
            micro.maybe_snapshot(s, state)
            micro.record_iv(s, state["iv"])
        if canary is not None:
            canary.check(s, state)
        state, metrics = campaign.step(state, campaign.bfn(s))
        if traps:
            trap_nonfinite(s, metrics) or \
                trap_loss_spike(s, metrics, history)
            history.append(float(metrics["loss"]))
        if canary is not None:
            canary.arm(s, state)
    jax.block_until_ready(jax.tree_util.tree_leaves(state)[0])
    return steps / (time.perf_counter() - t0)


def run(campaign: Campaign, steps: int = 30) -> Dict:
    base = _loop(campaign, steps, traps=False, canary_k=0, snapshots=False)
    traps = _loop(campaign, steps, traps=True, canary_k=0, snapshots=False)
    snaps = _loop(campaign, steps, traps=True, canary_k=0, snapshots=True)
    k8 = _loop(campaign, steps, traps=True, canary_k=8, snapshots=True)
    k1 = _loop(campaign, steps, traps=True, canary_k=1, snapshots=True)

    micro = MicroCheckpointer(interval=2)
    micro.snapshot(0, campaign.states[0])
    micro.snapshot(2, campaign.states[0])
    return {
        "steps_per_s": {"no_detectors": base, "traps_only": traps,
                        "traps+snapshots": snaps,
                        "traps+snapshots+canary_k8": k8,
                        "traps+snapshots+canary_k1": k1},
        "overhead_pct": {
            "traps_only": 100 * (base / traps - 1),
            "traps+snapshots": 100 * (base / snaps - 1),
            "traps+snapshots+canary_k8": 100 * (base / k8 - 1),
            "traps+snapshots+canary_k1": 100 * (base / k1 - 1),
        },
        "snapshot_memory_bytes": micro.memory_bytes,
        "note": ("canary digests run as Pallas interpret on CPU here — on "
                 "TPU the compiled kernel streams at HBM bandwidth and the "
                 "K=8 rotating slice costs <1% of step time (see DESIGN.md "
                 "§4.2); traps_only is the paper-faithful free-detection "
                 "configuration."),
    }


def render(out: Dict) -> str:
    lines = ["## No-fault overhead (paper Fig 9 analogue)", ""]
    lines.append("| configuration | steps/s | overhead vs bare |")
    lines.append("|---|---|---|")
    sps = out["steps_per_s"]
    lines.append(f"| no detectors | {sps['no_detectors']:.2f} | — |")
    for k in ("traps_only", "traps+snapshots", "traps+snapshots+canary_k8",
              "traps+snapshots+canary_k1"):
        lines.append(f"| {k} | {sps[k]:.2f} "
                     f"| {out['overhead_pct'][k]:+.1f}% |")
    lines.append("")
    lines.append(f"- double-buffered in-HBM snapshot memory: "
                 f"{out['snapshot_memory_bytes']/1e6:.1f} MB "
                 f"(paper: 27 MB fixed)")
    lines.append(f"- {out['note']}")
    return "\n".join(lines)
