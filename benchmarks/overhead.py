"""Fig 9: no-fault runtime overhead of the resilience subsystem.

Paper claim: ~0% runtime overhead + 27 MB fixed memory, because detection
is free (SIGSEGV) and the runtime is off the hot path.

Here: free traps read scalars the step already computed (literally free);
the only paid component is the optional rotating canary — one fused digest
launch + one scalar device→host sync per step regardless of leaf count
(DESIGN.md §4.2).  We measure steps/s for: no detectors / traps only /
traps + canary at K in {8, 4, 1}, plus the micro-checkpoint memory cost,
plus a detection-throughput microbenchmark (GB/s digested, launches/step,
syncs/step) comparing the fused engine against the seed's per-leaf path.
In a multi-device process the sharded section additionally HARD-ASSERTS
the DESIGN.md §5 mesh cost model: 1 launch + 1 all-reduced scalar sync
per step, per-shard oracle bit-exactness, and the /D per-device byte
split."""

from __future__ import annotations

import time
from collections import deque
from typing import Dict, Optional

import jax
import numpy as np

from benchmarks._campaign import Campaign
from repro.core import ChecksumCanary, MicroCheckpointer, trap_loss_spike, trap_nonfinite
from repro.core.detect import LOSS_WINDOW
from repro.kernels import digest as kdigest
from repro.kernels import ops as kops


def _loop(campaign: Campaign, steps: int, *, traps: bool, canary_k: int,
          snapshots: bool, donate: bool = False,
          fused: bool = False) -> float:
    """Returns steps/sec over `steps` warm steps."""
    state = campaign.states[0]
    if donate:
        # a donated loop consumes its input buffers — run on a private
        # deep copy so the campaign's ground-truth states survive
        state = campaign.clone(state)
        step_fn = campaign.donated_step()
    else:
        step_fn = campaign.step
    canary = ChecksumCanary(state, n_slices=canary_k) if canary_k else None
    factory = canary.fuse_into_step(campaign.raw_step(), donate=donate) \
        if fused and canary is not None else None
    micro = MicroCheckpointer(interval=2) if snapshots else None
    history = deque(maxlen=LOSS_WINDOW)   # bounded: the trap only ever
    # reads the last LOSS_WINDOW values
    # warm the step and one full canary rotation (compiles the K fused
    # step functions once; steady-state per-step cost is what we measure)
    s0 = 0
    if factory is not None:
        # AOT-compile all K rotation executables, then settle one full
        # rotation THROUGH the factory so every executable has run once
        # before the timer starts (matching the execution-warmed unfused
        # rows); stepping via the factory keeps the canary table and the
        # state version in lockstep, so the timed loop resumes at s=K
        factory.warm(state, campaign.bfn(0))
        for s in range(canary.n_slices):
            state, m, _ = factory.step(s, state, campaign.bfn(s))
        jax.block_until_ready(m["loss"])
        s0 = canary.n_slices
    elif donate:
        state, m = step_fn(state, campaign.bfn(0))
        jax.block_until_ready(m["loss"])
    else:
        st, m = step_fn(state, campaign.bfn(0))
        jax.block_until_ready(m["loss"])
    if canary is not None and factory is None:
        for s in range(canary.n_slices):
            if donate:
                canary.arm_current(s, state)
                canary.check(s, state)
            else:
                canary.check_and_arm(s, state)
    t0 = time.perf_counter()
    for s in range(s0, s0 + steps):
        if canary is not None and donate and factory is None:
            # donated pair, arm half: digest slice s%K of the buffer the
            # previous step produced (one launch, no sync)
            canary.arm_current(s, state)
        if micro is not None:
            micro.maybe_snapshot(s, state)
            micro.record_iv(s, state["iv"])
        if canary is not None and donate and factory is None:
            # check half: verify the same slice of the same version at the
            # buffer's last readable moment (one launch + one scalar sync)
            canary.check(s, state)
        if factory is not None:
            # in-step fused: detection rides the step's own launch — ONE
            # combined launch + ONE scalar sync per step
            new_state, metrics, _ = factory.step(s, state, campaign.bfn(s))
        else:
            new_state, metrics = step_fn(state, campaign.bfn(s))
        if traps:
            trap_nonfinite(s, metrics) or \
                trap_loss_spike(s, metrics, history)
            history.append(float(metrics["loss"]))
        if canary is not None and not donate and factory is None:
            # one fused launch + one scalar sync: check slice s%K of the
            # pre-step state, arm slice (s+1)%K of the fresh output
            canary.check_and_arm(s, state, new_state)
        state = new_state
    jax.block_until_ready(jax.tree_util.tree_leaves(state)[0])
    return steps / (time.perf_counter() - t0)


def _per_leaf_checksums(tree) -> Dict[str, np.ndarray]:
    """The SEED detection path, kept as the benchmark baseline: one jit'd
    ``checksum`` dispatch + one blocking device→host transfer per leaf."""
    out = {}

    def visit(path, leaf):
        out[kops.leaf_key(path)] = np.asarray(kops.checksum(leaf))
        return leaf

    jax.tree_util.tree_map_with_path(visit, tree)
    return out


def digest_throughput(campaign: Campaign, reps: int = 10) -> Dict:
    """Detection-cost microbenchmark: whole-state digest via the fused
    single-launch engine vs the seed per-leaf path, on the same state."""
    state = campaign.states[0]
    plan = kdigest.plan_for(state)
    state_bytes = sum(np.dtype(jax.numpy.result_type(x)).itemsize *
                      int(np.prod(jax.numpy.shape(x)) or 1)
                      for x in jax.tree_util.tree_leaves(state))

    # fused (one launch, digest table stays on device, zero syncs) vs the
    # seed path (O(leaves) launches + blocking transfers) — interleaved
    # and median-reduced so a noisy-neighbour scheduler can't flip the
    # comparison
    jax.block_until_ready(plan.digest_table(state))          # warm/compile
    _per_leaf_checksums(state)                               # warm/compile
    fused_t, per_leaf_t = [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(plan.digest_table(state))
        fused_t.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        _per_leaf_checksums(state)
        per_leaf_t.append(time.perf_counter() - t0)
    fused_s = float(np.median(fused_t))
    per_leaf_s = float(np.median(per_leaf_t))

    # hot-path accounting for one steady-state canary check+arm: warm a
    # FULL rotation first (each of the K rotations compiles its own fused
    # step function exactly once)
    canary = ChecksumCanary(state, n_slices=8)
    for s in range(canary.n_slices):                         # warm/compile
        canary.check_and_arm(s, state)
    kdigest.STATS.reset()
    canary.check_and_arm(canary.n_slices, state)
    launches, syncs, traces = kdigest.STATS.snapshot()

    return {
        "n_leaves": plan.n_leaves,
        "state_mb": state_bytes / 1e6,
        "digested_mb_per_pass": plan.bytes_per_pass / 1e6,
        "fused_ms": 1e3 * fused_s,
        "per_leaf_ms": 1e3 * per_leaf_s,
        "fused_gbps": plan.bytes_per_pass / fused_s / 1e9,
        "per_leaf_gbps": plan.bytes_per_pass / per_leaf_s / 1e9,
        "speedup": per_leaf_s / fused_s,
        "canary_launches_per_step": launches,
        "canary_syncs_per_step": syncs,
        "canary_retraces_per_step": traces,
    }


def donation_steady_state(campaign: Campaign, steps: int = 16) -> Dict:
    """Donation-mode hot-path accounting (the PR-3 tentpole contract):

    * the digest path makes ZERO new device allocations per steady-state
      step — the persistent packing buffer is donated through every
      launch (``input_output_aliases`` on the pack kernel) and the
      write-generation reference table is scatter-armed in place;
    * the packing buffers are POINTER-STABLE: the same HBM ranges are
      rewritten every step;
    * per donated step the canary pair costs 2 launches (arm: no sync,
      check: ONE scalar sync), 0 retraces — same 2/K bytes as the fused
      non-donated call.
    """
    import gc

    state = campaign.clone(campaign.states[0])
    step_fn = campaign.donated_step()
    canary = ChecksumCanary(state, n_slices=8)
    state, m = step_fn(state, campaign.bfn(0))
    jax.block_until_ready(m["loss"])
    # warm every rotation's arm/check pair (compiles once per rotation)
    for s in range(canary.n_slices):
        canary.arm_current(s, state)
        canary.check(s, state)
    # record the packing-buffer addresses, then settle one full rotation:
    # probing unsafe_buffer_pointer leaves per-buffer residue that the
    # next donation of each subset flushes, and the live-array window
    # below must contain only steady-state work
    subsets = list(canary.plan._pack_bufs.keys())
    union_ptrs = {idx: canary.plan.buffer_pointer(idx) for idx in subsets}
    for s in range(canary.n_slices):
        canary.arm_current(s, state)
        canary.check(s, state)
        new_state, metrics = step_fn(state, campaign.bfn(s))
        state = new_state
    jax.block_until_ready(jax.tree_util.tree_leaves(state)[0])

    gc.collect()
    live0 = len(jax.live_arrays())
    kdigest.STATS.reset()
    for s in range(steps):
        canary.arm_current(s, state)
        canary.check(s, state)
        new_state, metrics = step_fn(state, campaign.bfn(s))
        state = new_state
    jax.block_until_ready(jax.tree_util.tree_leaves(state)[0])
    gc.collect()
    live1 = len(jax.live_arrays())
    launches, syncs, traces = kdigest.STATS.snapshot()
    ptr_stable = all(canary.plan.buffer_pointer(idx) == p
                     for idx, p in union_ptrs.items())

    # donation-effectiveness probe: a digest must CONSUME the buffer it
    # was handed (the donated object dies) and hand back the same HBM
    # range.  A silently vetoed donation (e.g. a stray host view pinning
    # the buffer) would leave the old object alive and/or move the
    # address — the live-array delta alone cannot see that, since a
    # fresh-alloc-and-free per step also nets zero.  Probe rotation 0's
    # registered (hot-path-persistent) slice buffer with one more pair.
    plan = canary.plan
    idx_probe = tuple(canary._slice_indices(0))
    probe_buf = plan._pack_bufs[idx_probe]
    probe_ptr = plan.buffer_pointer(idx_probe)
    canary.arm_current(0, state)
    canary.check(0, state)
    donation_effective = bool(probe_buf.is_deleted()
                              and plan.buffer_pointer(idx_probe) == probe_ptr)

    # digest-only throughput of the donated pair (no step compute in the
    # timed window): bytes = 2 rotating slices of the packed state per step
    t0 = time.perf_counter()
    for s in range(steps):
        canary.arm_current(s + 1, state)
        canary.check(s + 1, state)
    digest_wall = time.perf_counter() - t0
    digested_bytes = 2 * canary.plan.bytes_per_pass / canary.n_slices
    return {
        "steps": steps,
        # net live-array growth (leak detector); 0 allocs/step is proven
        # by donation_effective + pack_buffer_ptr_stable, not this alone
        "net_new_live_arrays_per_step": (live1 - live0) / steps,
        "pack_buffer_ptr_stable": ptr_stable,
        "donation_effective": donation_effective,
        "canary_launches_per_step": launches / steps,
        "canary_syncs_per_step": syncs / steps,
        "canary_retraces_per_step": traces / steps,
        "digested_mb_per_step": digested_bytes / 1e6,
        "digest_gbps": digested_bytes * steps / digest_wall / 1e9,
    }


def fused_steady_state(campaign: Campaign, steps: int = 16,
                       n_slices: int = 8) -> Dict:
    """In-step fused detection accounting (the PR-4 tentpole contract;
    DESIGN.md §4.2 "in-step fused" column):

    * steady state (after the K-executable warmup) is EXACTLY 1 combined
      launch + 1 scalar device→host sync per step — detection adds zero
      dispatches to the donated step;
    * warmup = K rotation-specialised AOT compilations (wall time and
      count reported: the price of fusing detection into the step);
    * zero retraces in steady state (the executable cache holds);
    * digests bit-exact to the per-leaf oracle: the slice armed by a
      steady-state fused step matches ``ref.checksum_ref`` of the same
      output bytes (probed via a device-temp host copy so the probe
      cannot veto donation).
    """
    from repro.kernels import ref as kref

    state = campaign.clone(campaign.states[0])
    canary = ChecksumCanary(state, n_slices=n_slices)
    factory = canary.fuse_into_step(campaign.raw_step(), donate=True)
    warm_s = factory.warm(state, campaign.bfn(0))

    # settle one full rotation so every executable has run once
    for s in range(n_slices):
        state, m, rep = factory.step(s, state, campaign.bfn(s))
        assert rep is None
    jax.block_until_ready(jax.tree_util.tree_leaves(state)[0])

    kdigest.STATS.reset()
    t0 = time.perf_counter()
    for s in range(n_slices, n_slices + steps):
        state, m, rep = factory.step(s, state, campaign.bfn(s))
        assert rep is None
    jax.block_until_ready(jax.tree_util.tree_leaves(state)[0])
    wall = time.perf_counter() - t0
    launches, syncs, traces = kdigest.STATS.snapshot()

    # oracle probe: one more fused step; the freshly armed rows (read
    # generation after the commit) must equal the per-leaf oracle digests
    # of the output state's arm slice
    s = n_slices + steps
    new_state, m, rep = factory.step(s, state, campaign.bfn(s))
    arm_idx = canary._slice_indices(s + 1)
    out_leaves = canary.plan.leaves(new_state)
    table = np.asarray(jax.numpy.array(canary.reference, copy=True))
    oracle_exact = all(
        np.array_equal(table[i],
                       np.asarray(kref.checksum_ref(
                           jax.numpy.array(out_leaves[i], copy=True))))
        for i in arm_idx)

    digested_bytes = 2 * canary.plan.bytes_per_pass / n_slices
    return {
        "steps": steps,
        "n_slices": n_slices,
        "warmup_compiles": factory.n_compiles,
        "warmup_compile_s": factory.compile_seconds,
        "warmup_wall_s": warm_s,
        "launches_per_step": launches / steps,
        "syncs_per_step": syncs / steps,
        "retraces_per_step": traces / steps,
        "digested_mb_per_step": digested_bytes / 1e6,
        "steps_per_s": steps / wall,
        "oracle_exact": bool(oracle_exact),
    }


def sharded_steady_state(campaign: Campaign, steps: int = 10,
                         n_slices: int = 8) -> Optional[Dict]:
    """Mesh-sharded detection accounting (the DESIGN.md §5 cost model;
    requires >1 device — on CPU force them with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``):

    * sharded steady-state detection is EXACTLY 1 combined launch + 1
      scalar host sync per step — in fused ``check_and_arm`` form AND in
      in-step fused (donated) form — with 0 retraces: the mesh adds no
      dispatches and no extra host traffic; the one fetched scalar is the
      all-reduced fault flag, the only cross-device communication on the
      no-fault path (all asserted, not just reported);
    * the donated pair keeps its 2-launch/1-sync contract;
    * shard digests are bit-identical to the single-device uint32 oracle
      (``host_shard_checksums`` of every leaf's shard bytes — asserted);
    * byte accounting matches the model: the global pass digests the
      whole packed state (bytes_per_pass == n_shards × local pass), each
      step streams ~2B/K of it, and every device streams exactly 1/D of
      that.
    """
    n_dev = len(jax.devices())
    if n_dev < 2:
        return None
    from repro.distributed.context import DistContext

    if campaign.ctx is not None:
        # mesh-regime campaign: its step is already pinned to its own
        # mesh/shardings — reuse them (pinning again onto a second mesh
        # would reshard every leaf every step and corrupt the very
        # accounting this section asserts)
        ctx = campaign.ctx
        mesh = ctx.mesh
        state = campaign.clone(campaign.states[0])
        bfn = campaign.bfn
        raw = campaign.raw_step()
    else:
        if n_dev >= 4 and n_dev % 2 == 0:
            mesh = jax.make_mesh((n_dev // 2, 2), ("data", "model"))
        else:
            mesh = jax.make_mesh((n_dev,), ("data",))
        ctx = DistContext.for_mesh(mesh)
        from repro.launch.specs import bind_state
        state, raw, bfn, _ = bind_state(
            ctx, campaign.cfg, campaign.clone(campaign.states[0]),
            campaign.raw_step(), campaign.bfn)
    step_fn = jax.jit(raw)

    canary = ChecksumCanary(state, n_slices=n_slices, ctx=ctx)
    plan = canary.plan
    state_bytes = sum(x.nbytes for x in jax.tree_util.tree_leaves(state))

    # oracle: every (leaf, shard) digest must equal the single-device
    # uint32 oracle of exactly that shard's bytes
    leaves = plan.leaves(state)
    table = np.asarray(jax.numpy.array(plan.digest_table(state), copy=True))
    oracle_exact = all(
        np.array_equal(table[:, i], kdigest.host_shard_checksums(leaves[i]))
        for i in range(plan.n_leaves))
    assert oracle_exact, "sharded digests diverge from the per-shard oracle"

    # --- fused check_and_arm: 1 launch + 1 scalar sync per step ---------
    st = state
    for s in range(n_slices):                                # warm/compile
        ns, m = step_fn(st, bfn(s))
        assert canary.check_and_arm(s, st, ns) is None
        st = ns
    jax.block_until_ready(jax.tree_util.tree_leaves(st)[0])
    kdigest.STATS.reset()
    t0 = time.perf_counter()
    for s in range(n_slices, n_slices + steps):
        ns, m = step_fn(st, bfn(s))
        assert canary.check_and_arm(s, st, ns) is None
        st = ns
    jax.block_until_ready(jax.tree_util.tree_leaves(st)[0])
    wall = time.perf_counter() - t0
    launches, syncs, traces = kdigest.STATS.snapshot()
    assert launches == steps and syncs == steps and traces == 0, (
        "sharded check_and_arm steady state must be 1 launch + 1 scalar "
        f"sync + 0 retraces per step, got {launches}/{syncs}/{traces} "
        f"over {steps} steps")

    # --- donated pair: 2 launches + 1 scalar sync per step --------------
    dstate = campaign.clone(state)
    dstep = jax.jit(raw, donate_argnums=(0,))
    dcanary = ChecksumCanary(dstate, n_slices=n_slices, ctx=ctx)
    for s in range(n_slices):                                # warm/compile
        dcanary.arm_current(s, dstate)
        assert dcanary.check(s, dstate) is None
        dstate, m = dstep(dstate, bfn(s))
    jax.block_until_ready(jax.tree_util.tree_leaves(dstate)[0])
    kdigest.STATS.reset()
    for s in range(steps):
        dcanary.arm_current(s, dstate)
        assert dcanary.check(s, dstate) is None
        dstate, m = dstep(dstate, bfn(s))
    jax.block_until_ready(jax.tree_util.tree_leaves(dstate)[0])
    dl, ds, dt = kdigest.STATS.snapshot()
    assert dl == 2 * steps and ds == steps and dt == 0, (dl, ds, dt)

    # --- in-step fused under donation: 1 COMBINED launch + 1 sync -------
    fstate = campaign.clone(state)
    fcanary = ChecksumCanary(fstate, n_slices=n_slices, ctx=ctx)
    factory = fcanary.fuse_into_step(raw, donate=True)
    warm_s = factory.warm(fstate, bfn(0))
    for s in range(n_slices):                                # settle
        fstate, m, rep = factory.step(s, fstate, bfn(s))
        assert rep is None
    jax.block_until_ready(jax.tree_util.tree_leaves(fstate)[0])
    kdigest.STATS.reset()
    for s in range(n_slices, n_slices + steps):
        fstate, m, rep = factory.step(s, fstate, bfn(s))
        assert rep is None
    jax.block_until_ready(jax.tree_util.tree_leaves(fstate)[0])
    fl, fs, ft = kdigest.STATS.snapshot()
    assert fl == steps and fs == steps and ft == 0, (
        "sharded in-step fused steady state must be 1 combined launch + "
        f"1 scalar sync + 0 retraces per step, got {fl}/{fs}/{ft} over "
        f"{steps} steps")

    # --- byte accounting vs the cost model ------------------------------
    # the DESIGN §5 model: every device packs its LOCAL shard of each
    # leaf row-aligned (512 B rows, 128 KiB tile granularity per pass),
    # and the global pass is exactly n_shards local passes.  Recompute
    # the prediction independently from the shard shapes and require
    # exact agreement with the plan's accounting.
    LANES, TILE_ROWS = 128, 256
    local_rows = sum(
        max(1, -(-int(np.prod(x.sharding.shard_shape(jax.numpy.shape(x)),
                               dtype=np.int64) or 1) // LANES))
        for x in jax.tree_util.tree_leaves(state))
    expected_local = -(-local_rows // TILE_ROWS) * TILE_ROWS * LANES * 4
    assert plan.local_bytes_per_pass == expected_local, (
        plan.local_bytes_per_pass, expected_local)
    assert plan.bytes_per_pass == plan.local_bytes_per_pass * plan.n_shards
    digested_per_step = 2 * plan.bytes_per_pass / n_slices
    # alignment overhead (≤512 B/leaf/shard + tile tail) — reported; it
    # is a fixed byte count, so it amortises to ~1x on production states
    # and only looks large on this CPU smoke state split D ways
    pack_ratio = plan.bytes_per_pass / state_bytes

    return {
        "mesh_shape": dict(mesh.shape),
        "n_shards": plan.n_shards,
        "n_slices": n_slices,
        "steps": steps,
        "oracle_exact": bool(oracle_exact),
        "check_and_arm": {"launches_per_step": launches / steps,
                          "syncs_per_step": syncs / steps,
                          "retraces_per_step": traces / steps,
                          "steps_per_s": steps / wall},
        "donated_pair": {"launches_per_step": dl / steps,
                         "syncs_per_step": ds / steps,
                         "retraces_per_step": dt / steps},
        "fused": {"launches_per_step": fl / steps,
                  "syncs_per_step": fs / steps,
                  "retraces_per_step": ft / steps,
                  "warmup_compiles": factory.n_compiles,
                  "warmup_wall_s": warm_s},
        "state_mb": state_bytes / 1e6,
        "packed_mb_per_pass": plan.bytes_per_pass / 1e6,
        "digested_mb_per_step": digested_per_step / 1e6,
        "per_device_mb_per_step": digested_per_step / plan.n_shards / 1e6,
        "pack_ratio": pack_ratio,
    }


def parity_steady_state(campaign: Campaign, steps: int = 16,
                        n_slices: int = 8) -> Dict:
    """XOR-parity maintenance accounting (the parity-rung contract).

    The parity shard is updated INSIDE the canary's existing launches
    (gated incremental ``old ^ new ^ parity`` in check_and_arm and the
    in-step fused step; rebuild-of-armed-version riding the donated
    pair's arm), so attaching a ParityStore must not change the
    steady-state dispatch/sync/retrace counts of ANY protocol.  All
    hard-asserted, not just reported:

      * fused ``check_and_arm`` + parity: 1 launch + 1 scalar sync;
      * donated arm/check pair + parity: 2 launches + 1 scalar sync;
      * in-step fused under donation + parity: 1 COMBINED launch + 1
        scalar sync;
      * 0 retraces everywhere (the executable caches key on the plan
        object, which is process-cached per tree structure);
      * the incrementally-maintained parity is bit-exact to a
        from-scratch rebuild of the final state;
      * memory cost = parity buffer bytes ~= covered bytes / D.
    """
    from repro.core import ParityStore

    # --- fused check_and_arm with parity riding the launch --------------
    st = campaign.states[0]
    canary = ChecksumCanary(st, n_slices=n_slices)
    pstore = ParityStore(st)
    pstore.build(st, 0)
    canary.attach_parity(pstore)
    for s in range(n_slices):                                # warm/compile
        ns, m = campaign.step(st, campaign.bfn(s))
        assert canary.check_and_arm(s, st, ns) is None
        st = ns
    jax.block_until_ready(jax.tree_util.tree_leaves(st)[0])
    kdigest.STATS.reset()
    for s in range(n_slices, n_slices + steps):
        ns, m = campaign.step(st, campaign.bfn(s))
        assert canary.check_and_arm(s, st, ns) is None
        st = ns
    jax.block_until_ready(jax.tree_util.tree_leaves(st)[0])
    cl, cs, ct = kdigest.STATS.snapshot()
    assert cl == steps and cs == steps and ct == 0, (
        "check_and_arm with parity attached must stay 1 launch + 1 "
        f"scalar sync + 0 retraces per step, got {cl}/{cs}/{ct} over "
        f"{steps} steps")
    # incremental parity of the final version == from-scratch rebuild
    fresh = ParityStore(st)
    fresh.build(st, 0)
    inc_exact = bool(np.array_equal(np.asarray(pstore.parity),
                                    np.asarray(fresh.parity)))
    assert inc_exact, "incremental parity diverged from rebuild"

    # --- donated pair with parity ---------------------------------------
    dstate = campaign.clone(campaign.states[0])
    dstep = campaign.donated_step()
    dcanary = ChecksumCanary(dstate, n_slices=n_slices)
    dps = ParityStore(dstate)
    dps.build(dstate, 0)
    dcanary.attach_parity(dps)
    for s in range(n_slices):                                # warm/compile
        dcanary.arm_current(s, dstate)
        assert dcanary.check(s, dstate) is None
        dstate, m = dstep(dstate, campaign.bfn(s))
    jax.block_until_ready(jax.tree_util.tree_leaves(dstate)[0])
    kdigest.STATS.reset()
    for s in range(steps):
        dcanary.arm_current(s, dstate)
        assert dcanary.check(s, dstate) is None
        dstate, m = dstep(dstate, campaign.bfn(s))
    jax.block_until_ready(jax.tree_util.tree_leaves(dstate)[0])
    dl, ds, dt = kdigest.STATS.snapshot()
    assert dl == 2 * steps and ds == steps and dt == 0, (
        "donated pair with parity attached must stay 2 launches + 1 "
        f"scalar sync + 0 retraces per step, got {dl}/{ds}/{dt} over "
        f"{steps} steps")

    # --- in-step fused under donation with parity -----------------------
    fstate = campaign.clone(campaign.states[0])
    fcanary = ChecksumCanary(fstate, n_slices=n_slices)
    fps = ParityStore(fstate)
    fps.build(fstate, 0)
    fcanary.attach_parity(fps)
    factory = fcanary.fuse_into_step(campaign.raw_step(), donate=True)
    factory.warm(fstate, campaign.bfn(0))
    for s in range(n_slices):                                # settle
        fstate, m, rep = factory.step(s, fstate, campaign.bfn(s))
        assert rep is None
    jax.block_until_ready(jax.tree_util.tree_leaves(fstate)[0])
    kdigest.STATS.reset()
    for s in range(n_slices, n_slices + steps):
        fstate, m, rep = factory.step(s, fstate, campaign.bfn(s))
        assert rep is None
    jax.block_until_ready(jax.tree_util.tree_leaves(fstate)[0])
    fl, fs_, ft = kdigest.STATS.snapshot()
    assert fl == steps and fs_ == steps and ft == 0, (
        "in-step fused with parity attached must stay 1 combined launch "
        f"+ 1 scalar sync + 0 retraces per step, got {fl}/{fs_}/{ft} "
        f"over {steps} steps")

    covered = sum(
        int(np.prod(pstore.plan.shapes[k]) or 1)
        * np.dtype(pstore.plan.dtypes[k]).itemsize
        for k in pstore.plan.keys)
    state_bytes = sum(x.nbytes
                      for x in jax.tree_util.tree_leaves(campaign.states[0]))
    return {
        "steps": steps,
        "n_shards": pstore.plan.n_shards,
        "incremental_equals_rebuild": inc_exact,
        "check_and_arm": {"launches_per_step": cl / steps,
                          "syncs_per_step": cs / steps,
                          "retraces_per_step": ct / steps},
        "donated_pair": {"launches_per_step": dl / steps,
                         "syncs_per_step": ds / steps,
                         "retraces_per_step": dt / steps},
        "fused": {"launches_per_step": fl / steps,
                  "syncs_per_step": fs_ / steps,
                  "retraces_per_step": ft / steps},
        "parity_memory_bytes": pstore.memory_bytes,
        "state_bytes": state_bytes,
        "memory_overhead": pstore.memory_bytes / state_bytes,
        "covered_bytes": covered,
    }


def run(campaign: Campaign, steps: int = 30) -> Dict:
    base = _loop(campaign, steps, traps=False, canary_k=0, snapshots=False)
    traps = _loop(campaign, steps, traps=True, canary_k=0, snapshots=False)
    snaps = _loop(campaign, steps, traps=True, canary_k=0, snapshots=True)
    k8 = _loop(campaign, steps, traps=True, canary_k=8, snapshots=True)
    k1 = _loop(campaign, steps, traps=True, canary_k=1, snapshots=True)
    # donation mode: the production compilation setting (in-place state
    # update) with the arm/check canary pair
    dbase = _loop(campaign, steps, traps=True, canary_k=0, snapshots=True,
                  donate=True)
    dk8 = _loop(campaign, steps, traps=True, canary_k=8, snapshots=True,
                donate=True)
    # in-step fused detection: the canary rides the donated step's own
    # launch (1 launch + 1 scalar sync per step after K-executable
    # warmup).  The accounting section runs FIRST — it shares the global
    # executable cache with the steps/s loop below, and only the first
    # builder pays (and can report) the real K-compile warmup cost.
    fused = fused_steady_state(campaign)
    dfk8 = _loop(campaign, steps, traps=True, canary_k=8, snapshots=True,
                 donate=True, fused=True)

    # XOR-parity maintenance: hard-asserts that attaching a ParityStore
    # leaves every protocol's launch/sync/retrace counts unchanged
    parity = parity_steady_state(campaign)

    micro = MicroCheckpointer(interval=2)
    micro.snapshot(0, campaign.states[0])
    micro.snapshot(2, campaign.states[0])
    # mesh-sharded section — runs (and hard-asserts its cost contract)
    # only when the process has >1 device, e.g. under
    # XLA_FLAGS=--xla_force_host_platform_device_count=8
    sharded = sharded_steady_state(campaign)
    return {
        "steps_per_s": {"no_detectors": base, "traps_only": traps,
                        "traps+snapshots": snaps,
                        "traps+snapshots+canary_k8": k8,
                        "traps+snapshots+canary_k1": k1,
                        "donated+traps+snapshots": dbase,
                        "donated+traps+snapshots+canary_k8": dk8,
                        "donated+fused+traps+snapshots+canary_k8": dfk8},
        "sharded": sharded,
        "overhead_pct": {
            "traps_only": 100 * (base / traps - 1),
            "traps+snapshots": 100 * (base / snaps - 1),
            "traps+snapshots+canary_k8": 100 * (base / k8 - 1),
            "traps+snapshots+canary_k1": 100 * (base / k1 - 1),
            "donated_canary_k8_vs_donated": 100 * (dbase / dk8 - 1),
            "donated_fused_k8_vs_donated": 100 * (dbase / dfk8 - 1),
        },
        "snapshot_memory_bytes": micro.memory_bytes,
        "digest": digest_throughput(campaign),
        "donation": donation_steady_state(campaign),
        "fused": fused,
        "parity": parity,
        "note": ("canary digests run as Pallas interpret on CPU here — on "
                 "TPU the compiled kernel streams at HBM bandwidth and the "
                 "K=8 rotating canary (one fused launch + one scalar sync "
                 "per step) costs <1% of step time (see DESIGN.md §4.2); "
                 "traps_only is the paper-faithful free-detection "
                 "configuration."),
    }


def render(out: Dict) -> str:
    lines = ["## No-fault overhead (paper Fig 9 analogue)", ""]
    lines.append("| configuration | steps/s | overhead vs bare |")
    lines.append("|---|---|---|")
    sps = out["steps_per_s"]
    lines.append(f"| no detectors | {sps['no_detectors']:.2f} | — |")
    for k in ("traps_only", "traps+snapshots", "traps+snapshots+canary_k8",
              "traps+snapshots+canary_k1"):
        lines.append(f"| {k} | {sps[k]:.2f} "
                     f"| {out['overhead_pct'][k]:+.1f}% |")
    lines.append("")
    d = out["digest"]
    lines.append("### Detection throughput (fused digest engine vs seed "
                 "per-leaf path)")
    lines.append("")
    lines.append("| path | ms/pass | GB/s | launches | syncs |")
    lines.append("|---|---|---|---|---|")
    lines.append(f"| fused single-launch | {d['fused_ms']:.2f} "
                 f"| {d['fused_gbps']:.2f} | 1 | 0-1 |")
    lines.append(f"| seed per-leaf | {d['per_leaf_ms']:.2f} "
                 f"| {d['per_leaf_gbps']:.2f} | {d['n_leaves']} "
                 f"| {d['n_leaves']} |")
    lines.append("")
    lines.append(f"- fused speedup over per-leaf: {d['speedup']:.1f}× on "
                 f"{d['n_leaves']} leaves "
                 f"({d['digested_mb_per_pass']:.1f} MB digested/pass)")
    lines.append(f"- canary check+arm hot path: "
                 f"{d['canary_launches_per_step']} launch, "
                 f"{d['canary_syncs_per_step']} host sync, "
                 f"{d['canary_retraces_per_step']} retraces per step")
    dn = out["donation"]
    lines.append("")
    lines.append("### Donation mode (donate_argnums=(0,): in-place state "
                 "update)")
    lines.append("")
    zero_allocs = (dn["donation_effective"]
                   and dn["pack_buffer_ptr_stable"]
                   and dn["net_new_live_arrays_per_step"] <= 0)
    lines.append(f"- steady-state device allocations/step on the digest "
                 f"path: **{0 if zero_allocs else 'NONZERO'}** "
                 f"(donation consumed the handed-in buffer: "
                 f"{dn['donation_effective']}; packing buffers "
                 f"pointer-stable: {dn['pack_buffer_ptr_stable']}; net "
                 f"live-array growth/step: "
                 f"{dn['net_new_live_arrays_per_step']:g})")
    lines.append(f"- canary pair per step: "
                 f"{dn['canary_launches_per_step']:g} launches "
                 f"(arm: 0 syncs; check: 1 scalar sync → "
                 f"{dn['canary_syncs_per_step']:g} syncs/step), "
                 f"{dn['canary_retraces_per_step']:g} retraces; "
                 f"{dn['digested_mb_per_step']:.1f} MB digested/step "
                 f"at {dn['digest_gbps']:.2f} GB/s")
    k_d = "donated+traps+snapshots"
    k_dk8 = "donated+traps+snapshots+canary_k8"
    d_cost = out["overhead_pct"]["donated_canary_k8_vs_donated"]
    lines.append(f"- donated loop: {sps[k_d]:.2f} steps/s bare vs "
                 f"{sps[k_dk8]:.2f} with canary K=8 "
                 f"({d_cost:+.1f}% canary cost under donation)")
    fu = out["fused"]
    lines.append("")
    lines.append("### In-step fused detection (canary inside the donated "
                 "step; DESIGN.md §4.2)")
    lines.append("")
    lines.append(f"- steady-state hot path: "
                 f"**{fu['launches_per_step']:g} launch/step** (the step's "
                 f"own dispatch carries the check+arm digest), "
                 f"{fu['syncs_per_step']:g} scalar sync/step, "
                 f"{fu['retraces_per_step']:g} retraces/step; digests "
                 f"bit-exact to the per-leaf oracle: {fu['oracle_exact']}")
    lines.append(f"- K-executable warmup: {fu['warmup_compiles']} "
                 f"rotation-specialised compiles in "
                 f"{fu['warmup_wall_s']:.2f} s wall "
                 f"({fu['warmup_compile_s']:.2f} s compiling) for "
                 f"K={fu['n_slices']} — the one-time price of fusing "
                 f"detection into the step")
    k_dfk8 = "donated+fused+traps+snapshots+canary_k8"
    f_cost = out["overhead_pct"]["donated_fused_k8_vs_donated"]
    lines.append(f"- donated loop: {sps[k_dfk8]:.2f} steps/s fused vs "
                 f"{sps[k_dk8]:.2f} with the arm/check pair "
                 f"({f_cost:+.1f}% fused canary cost vs donated bare; "
                 f"{fu['digested_mb_per_step']:.1f} MB digested/step — "
                 f"same bytes as the pair, half its dispatches)")
    lines.append(f"- double-buffered in-HBM snapshot memory: "
                 f"{out['snapshot_memory_bytes']/1e6:.1f} MB "
                 f"(paper: 27 MB fixed)")
    pa = out.get("parity")
    if pa:
        lines.append("")
        lines.append("### XOR parity maintenance (device-resident rung; "
                     "rides the canary's launches)")
        lines.append("")
        ca, dp, pf = pa["check_and_arm"], pa["donated_pair"], pa["fused"]
        lines.append(
            f"- steady state with parity ATTACHED (asserted): "
            f"check_and_arm **{ca['launches_per_step']:g} launch + "
            f"{ca['syncs_per_step']:g} scalar sync**/step; donated pair "
            f"{dp['launches_per_step']:g}/{dp['syncs_per_step']:g}; "
            f"in-step fused **{pf['launches_per_step']:g} combined launch "
            f"+ {pf['syncs_per_step']:g} scalar sync**/step; 0 retraces "
            f"everywhere — parity maintenance adds ZERO dispatches")
        lines.append(
            f"- incremental update bit-exact to a from-scratch rebuild "
            f"after {pa['steps']} steps: "
            f"{pa['incremental_equals_rebuild']}")
        lines.append(
            f"- memory: {pa['parity_memory_bytes']/1e6:.1f} MB parity for "
            f"{pa['state_bytes']/1e6:.1f} MB state "
            f"({100 * pa['memory_overhead']:.1f}% ~= 1/D, D="
            f"{pa['n_shards']}) — the price of reconstructing any single "
            f"lost shard with no snapshot and no replay")
    shd = out.get("sharded")
    lines.append("")
    lines.append("### Mesh-sharded detection (shard-local digests, "
                 "all-reduced fault flag; DESIGN.md §5)")
    lines.append("")
    if shd is None:
        lines.append("- skipped: single-device process (force a CPU mesh "
                     "with XLA_FLAGS=--xla_force_host_platform_device_"
                     "count=8)")
    else:
        ca, fu = shd["check_and_arm"], shd["fused"]
        lines.append(f"- mesh {shd['mesh_shape']} ({shd['n_shards']} "
                     f"shards), K={shd['n_slices']}: per-shard digests "
                     f"bit-identical to the single-device oracle: "
                     f"{shd['oracle_exact']}")
        lines.append(f"- steady state (asserted): check_and_arm "
                     f"**{ca['launches_per_step']:g} launch + "
                     f"{ca['syncs_per_step']:g} scalar sync**/step; "
                     f"donated pair "
                     f"{shd['donated_pair']['launches_per_step']:g}/"
                     f"{shd['donated_pair']['syncs_per_step']:g}; "
                     f"in-step fused (donated) "
                     f"**{fu['launches_per_step']:g} combined launch + "
                     f"{fu['syncs_per_step']:g} scalar sync**/step "
                     f"(warmup {fu['warmup_compiles']} compiles, "
                     f"{fu['warmup_wall_s']:.1f} s); 0 retraces everywhere")
        lines.append(f"- bytes: {shd['state_mb']:.1f} MB state packs to "
                     f"{shd['packed_mb_per_pass']:.1f} MB "
                     f"({shd['pack_ratio']:.2f}x); "
                     f"{shd['digested_mb_per_step']:.2f} MB digested/step "
                     f"total = {shd['per_device_mb_per_step']:.3f} MB/"
                     f"device — each device streams only its addressable "
                     f"1/{shd['n_shards']}; the all-reduced fault flag is "
                     f"the only cross-device traffic on the no-fault path")
    lines.append(f"- {out['note']}")
    return "\n".join(lines)
