"""Fig 9: no-fault runtime overhead of the resilience subsystem.

Paper claim: ~0% runtime overhead + 27 MB fixed memory, because detection
is free (SIGSEGV) and the runtime is off the hot path.

Here: free traps read scalars the step already computed (literally free);
the only paid component is the optional rotating canary — one fused digest
launch + one scalar device→host sync per step regardless of leaf count
(DESIGN.md §4.2).  We measure steps/s for: no detectors / traps only /
traps + canary at K in {8, 4, 1}, plus the micro-checkpoint memory cost,
plus a detection-throughput microbenchmark (GB/s digested, launches/step,
syncs/step) comparing the fused engine against the seed's per-leaf path."""

from __future__ import annotations

import time
from collections import deque
from typing import Dict

import jax
import numpy as np

from benchmarks._campaign import Campaign
from repro.core import ChecksumCanary, MicroCheckpointer, trap_loss_spike, trap_nonfinite
from repro.core.detect import LOSS_WINDOW
from repro.kernels import digest as kdigest
from repro.kernels import ops as kops


def _loop(campaign: Campaign, steps: int, *, traps: bool, canary_k: int,
          snapshots: bool) -> float:
    """Returns steps/sec over `steps` warm steps."""
    state = campaign.states[0]
    canary = ChecksumCanary(state, n_slices=canary_k) if canary_k else None
    micro = MicroCheckpointer(interval=2) if snapshots else None
    history = deque(maxlen=LOSS_WINDOW)   # bounded: the trap only ever
    # reads the last LOSS_WINDOW values
    # warm the step and one full canary rotation (compiles the K fused
    # step functions once; steady-state per-step cost is what we measure)
    st, m = campaign.step(state, campaign.bfn(0))
    jax.block_until_ready(m["loss"])
    if canary is not None:
        for s in range(canary.n_slices):
            canary.check_and_arm(s, state)
    t0 = time.perf_counter()
    for s in range(steps):
        if micro is not None:
            micro.maybe_snapshot(s, state)
            micro.record_iv(s, state["iv"])
        new_state, metrics = campaign.step(state, campaign.bfn(s))
        if traps:
            trap_nonfinite(s, metrics) or \
                trap_loss_spike(s, metrics, history)
            history.append(float(metrics["loss"]))
        if canary is not None:
            # one fused launch + one scalar sync: check slice s%K of the
            # pre-step state, arm slice (s+1)%K of the fresh output
            canary.check_and_arm(s, state, new_state)
        state = new_state
    jax.block_until_ready(jax.tree_util.tree_leaves(state)[0])
    return steps / (time.perf_counter() - t0)


def _per_leaf_checksums(tree) -> Dict[str, np.ndarray]:
    """The SEED detection path, kept as the benchmark baseline: one jit'd
    ``checksum`` dispatch + one blocking device→host transfer per leaf."""
    out = {}

    def visit(path, leaf):
        out[kops.leaf_key(path)] = np.asarray(kops.checksum(leaf))
        return leaf

    jax.tree_util.tree_map_with_path(visit, tree)
    return out


def digest_throughput(campaign: Campaign, reps: int = 10) -> Dict:
    """Detection-cost microbenchmark: whole-state digest via the fused
    single-launch engine vs the seed per-leaf path, on the same state."""
    state = campaign.states[0]
    plan = kdigest.plan_for(state)
    state_bytes = sum(np.dtype(jax.numpy.result_type(x)).itemsize *
                      int(np.prod(jax.numpy.shape(x)) or 1)
                      for x in jax.tree_util.tree_leaves(state))

    # fused (one launch, digest table stays on device, zero syncs) vs the
    # seed path (O(leaves) launches + blocking transfers) — interleaved
    # and median-reduced so a noisy-neighbour scheduler can't flip the
    # comparison
    jax.block_until_ready(plan.digest_table(state))          # warm/compile
    _per_leaf_checksums(state)                               # warm/compile
    fused_t, per_leaf_t = [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(plan.digest_table(state))
        fused_t.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        _per_leaf_checksums(state)
        per_leaf_t.append(time.perf_counter() - t0)
    fused_s = float(np.median(fused_t))
    per_leaf_s = float(np.median(per_leaf_t))

    # hot-path accounting for one steady-state canary check+arm: warm a
    # FULL rotation first (each of the K rotations compiles its own fused
    # step function exactly once)
    canary = ChecksumCanary(state, n_slices=8)
    for s in range(canary.n_slices):                         # warm/compile
        canary.check_and_arm(s, state)
    kdigest.STATS.reset()
    canary.check_and_arm(canary.n_slices, state)
    launches, syncs, traces = kdigest.STATS.snapshot()

    return {
        "n_leaves": plan.n_leaves,
        "state_mb": state_bytes / 1e6,
        "digested_mb_per_pass": plan.bytes_per_pass / 1e6,
        "fused_ms": 1e3 * fused_s,
        "per_leaf_ms": 1e3 * per_leaf_s,
        "fused_gbps": plan.bytes_per_pass / fused_s / 1e9,
        "per_leaf_gbps": plan.bytes_per_pass / per_leaf_s / 1e9,
        "speedup": per_leaf_s / fused_s,
        "canary_launches_per_step": launches,
        "canary_syncs_per_step": syncs,
        "canary_retraces_per_step": traces,
    }


def run(campaign: Campaign, steps: int = 30) -> Dict:
    base = _loop(campaign, steps, traps=False, canary_k=0, snapshots=False)
    traps = _loop(campaign, steps, traps=True, canary_k=0, snapshots=False)
    snaps = _loop(campaign, steps, traps=True, canary_k=0, snapshots=True)
    k8 = _loop(campaign, steps, traps=True, canary_k=8, snapshots=True)
    k1 = _loop(campaign, steps, traps=True, canary_k=1, snapshots=True)

    micro = MicroCheckpointer(interval=2)
    micro.snapshot(0, campaign.states[0])
    micro.snapshot(2, campaign.states[0])
    return {
        "steps_per_s": {"no_detectors": base, "traps_only": traps,
                        "traps+snapshots": snaps,
                        "traps+snapshots+canary_k8": k8,
                        "traps+snapshots+canary_k1": k1},
        "overhead_pct": {
            "traps_only": 100 * (base / traps - 1),
            "traps+snapshots": 100 * (base / snaps - 1),
            "traps+snapshots+canary_k8": 100 * (base / k8 - 1),
            "traps+snapshots+canary_k1": 100 * (base / k1 - 1),
        },
        "snapshot_memory_bytes": micro.memory_bytes,
        "digest": digest_throughput(campaign),
        "note": ("canary digests run as Pallas interpret on CPU here — on "
                 "TPU the compiled kernel streams at HBM bandwidth and the "
                 "K=8 rotating canary (one fused launch + one scalar sync "
                 "per step) costs <1% of step time (see DESIGN.md §4.2); "
                 "traps_only is the paper-faithful free-detection "
                 "configuration."),
    }


def render(out: Dict) -> str:
    lines = ["## No-fault overhead (paper Fig 9 analogue)", ""]
    lines.append("| configuration | steps/s | overhead vs bare |")
    lines.append("|---|---|---|")
    sps = out["steps_per_s"]
    lines.append(f"| no detectors | {sps['no_detectors']:.2f} | — |")
    for k in ("traps_only", "traps+snapshots", "traps+snapshots+canary_k8",
              "traps+snapshots+canary_k1"):
        lines.append(f"| {k} | {sps[k]:.2f} "
                     f"| {out['overhead_pct'][k]:+.1f}% |")
    lines.append("")
    d = out["digest"]
    lines.append("### Detection throughput (fused digest engine vs seed "
                 "per-leaf path)")
    lines.append("")
    lines.append("| path | ms/pass | GB/s | launches | syncs |")
    lines.append("|---|---|---|---|---|")
    lines.append(f"| fused single-launch | {d['fused_ms']:.2f} "
                 f"| {d['fused_gbps']:.2f} | 1 | 0-1 |")
    lines.append(f"| seed per-leaf | {d['per_leaf_ms']:.2f} "
                 f"| {d['per_leaf_gbps']:.2f} | {d['n_leaves']} "
                 f"| {d['n_leaves']} |")
    lines.append("")
    lines.append(f"- fused speedup over per-leaf: {d['speedup']:.1f}× on "
                 f"{d['n_leaves']} leaves "
                 f"({d['digested_mb_per_pass']:.1f} MB digested/pass)")
    lines.append(f"- canary check+arm hot path: "
                 f"{d['canary_launches_per_step']} launch, "
                 f"{d['canary_syncs_per_step']} host sync, "
                 f"{d['canary_retraces_per_step']} retraces per step")
    lines.append(f"- double-buffered in-HBM snapshot memory: "
                 f"{out['snapshot_memory_bytes']/1e6:.1f} MB "
                 f"(paper: 27 MB fixed)")
    lines.append(f"- {out['note']}")
    return "\n".join(lines)
