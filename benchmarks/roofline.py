"""§Roofline: render the per-(arch x shape x mesh) roofline table from the
dry-run sweep results (dryrun_results.json)."""

from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

RESULTS = os.path.join(os.path.dirname(__file__), "..",
                       "dryrun_results.json")


def load(path: Optional[str] = None) -> List[Dict]:
    with open(path or RESULTS) as f:
        return json.load(f)


def _fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    return f"{1e3 * x:.1f}ms"


def improvement_hint(rec: Dict) -> str:
    """One sentence: what would move the dominant term down."""
    r = rec["roofline"]
    b = r["bottleneck"]
    arch = rec["arch"]
    kind = rec["kind"]
    if b == "collective":
        kinds = rec["hlo_cost"]["coll_bytes_by_kind"]
        top = max(kinds, key=kinds.get) if kinds else "all-reduce"
        if top == "all-gather":
            return ("dominated by per-microbatch ZeRO-3 weight gathers — "
                    "gather once per step or switch to token-routed EP")
        return (f"dominated by {top} — overlap with compute "
                f"(async collectives) or reduce in bf16")
    if b == "memory":
        if kind == "train":
            return ("HBM traffic from unfused f32 intermediates + remat "
                    "re-reads — flash-attention kernel removes the "
                    "materialised score tensors; cast residuals to bf16")
        if kind == "decode":
            return "KV-cache reads dominate — quantise cache to int8 / SP-shard"
        return "score materialisation — flash attention removes it"
    return ("compute-bound (good); closer to roofline via MXU-aligned "
            "tiles and fewer recomputed FLOPs (remat policy)")


def run(path: Optional[str] = None) -> Dict:
    recs = [r for r in load(path) if r["status"] == "ok"]
    skips = [r for r in load(path) if r["status"] == "skipped"]
    return {"cells": recs, "skipped": skips}


def render(out: Dict, mesh: str = "single") -> str:
    lines = [
        f"## Roofline — {mesh}-pod mesh "
        f"({'256' if mesh == 'single' else '512'} chips)",
        "",
        "| arch | shape | t_compute | t_memory | t_coll | bound | "
        "useful/HLO flops | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in out["cells"]:
        if r["mesh"] != mesh:
            continue
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(rf['t_compute_s'])} "
            f"| {_fmt_s(rf['t_memory_s'])} | {_fmt_s(rf['t_collective_s'])} "
            f"| {rf['bottleneck']} | {rf['useful_flops_fraction']:.3f} "
            f"| {rf['roofline_fraction']:.4f} |")
    lines.append("")
    skips = [r for r in out["skipped"] if r["mesh"] == mesh]
    if skips:
        lines.append(f"Skipped ({len(skips)}): " + ", ".join(
            f"{r['arch']}x{r['shape']}" for r in skips) +
            " — full-attention archs at 500k decode (DESIGN.md §8).")
    return "\n".join(lines)
