"""Figs 7, 8 and 10: recovery rate, recovery time and the CARE-vs-IterPro
ablation (the value of induction-variable recovery), plus the beyond-paper
canary ablation."""

from __future__ import annotations

from typing import Dict

import numpy as np

from benchmarks._campaign import Campaign, summarize


def run(campaign: Campaign, n_trials: int = 100, seed: int = 23) -> Dict:
    # Detection held constant (canary) so the RECOVERY POLICIES compare on
    # the same detected-fault population; same seed -> identical injections.
    care = summarize(campaign.run(n_trials, mode="care", seed=seed,
                                  use_canary=True, canary_slices=4))
    iterpro = summarize(campaign.run(n_trials, mode="iterpro", seed=seed,
                                     use_canary=True, canary_slices=4))
    # paper-faithful traps-only row (the free-detection regime)
    traps = summarize(campaign.run(n_trials, mode="iterpro", seed=seed))
    # IV-targeted campaign: the paper's Fig-10 gap lives in loop state.
    care_iv = summarize(campaign.run(max(20, n_trials // 3), mode="care",
                                     target="iv", seed=seed + 1,
                                     use_canary=True, canary_slices=1))
    iterpro_iv = summarize(campaign.run(max(20, n_trials // 3),
                                        mode="iterpro", target="iv",
                                        seed=seed + 1,
                                        use_canary=True, canary_slices=1))
    return {"care": care, "iterpro": iterpro, "traps_only": traps,
            "care_iv": care_iv, "iterpro_iv": iterpro_iv,
            "n_trials": n_trials}


def _pct(x) -> str:
    return "n/a" if x is None else f"{100 * x:.1f}%"


def _ms(x) -> str:
    return "n/a" if x is None else f"{x:.1f}"


def render(out: Dict) -> str:
    lines = ["## Recovery (paper Figs 7, 8, 10 analogue)", ""]
    lines.append("| system | crashes | recovered | in-HBM rate | incl. C/R "
                 "| exact | p50 ms | mean steps replayed |")
    lines.append("|---|---|---|---|---|---|---|---|")
    for name, s in (("traps-only detection (paper regime)",
                     out["traps_only"]),
                    ("CARE policy (SC'19: no IV recovery)", out["care"]),
                    ("IterPro policy (full ladder)", out["iterpro"])):
        lines.append(
            f"| {name} | {s['crashes']} | {s['recovered']} "
            f"| {_pct(s['iterpro_rate'])} | {_pct(s['recovery_rate'])} "
            f"| {_pct(s['exact_rate'])} | {_ms(s['p50_recovery_ms'])} "
            f"| {s['mean_steps_replayed'] if s['mean_steps_replayed'] is not None else 'n/a'} |")
    lines.append("")
    lines.append("Paper: IterPro 83.55% avg recovery of SIGSEGV faults vs "
                 "CARE 57.64%; dozens of ms per recovery.")
    lines.append("")
    lines.append("### Induction-variable faults only (Fig 10's gap)")
    lines.append("| system | crashes | recovered | rate |")
    lines.append("|---|---|---|---|")
    for name, s in (("CARE", out["care_iv"]),
                    ("IterPro", out["iterpro_iv"])):
        lines.append(f"| {name} | {s['crashes']} | {s['recovered']} "
                     f"| {_pct(s['recovery_rate'])} |")
    lines.append("")
    lines.append("### Recovery-time breakdown (Fig 8)")
    rec = out["iterpro"]
    lines.append(f"- p50 recovery: {_ms(rec['p50_recovery_ms'])} ms; "
                 f"mean: {_ms(rec['mean_recovery_ms'])} ms")
    lines.append(f"- by rung: {rec['by_rung']}")
    lines.append("- (paper: >98% of recovery time is diagnosis/load, not "
                 "the kernel itself — here the analogous split is "
                 "snapshot-verify + device-put vs the replayed steps)")
    return "\n".join(lines)
