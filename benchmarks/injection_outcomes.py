"""Tables 3 & 4 + Table 5: injection outcome classes, detection-symptom
breakdown and detection-latency distribution (the paper's manifestation
study, §5.2), on the training-state failure domain.

Two detection regimes are reported:
* free traps only — the direct analogue of the paper's setup (detection
  costs nothing).  KEY DOMAIN FINDING: the trap rate here is FAR below the
  paper's 89.8%-SIGSEGV rate, because (a) a pure-dataflow program has no
  invalid-address hardware trap to piggyback on, and (b) RMSNorm
  *structurally masks* magnitude faults — a weight flipped to 3.7e37 barely
  moves the loss (the norm renormalises the exploded channel).  Faults that
  would crash an HPC stencil become silent here.
* + rotating canary — IterPro-JAX's answer, following the paper's own
  philosophy (manufacture cheap detection where hardware gives none): the
  Pallas checksum canary converts those silent corruptions into precisely
  localised, near-immediately detected faults at ~1-2% step cost (K=8).
"""

from __future__ import annotations

from typing import Dict

from benchmarks._campaign import Campaign, summarize


def run(campaign: Campaign, n_trials: int = 100, seed: int = 11) -> Dict:
    traps = summarize(campaign.run(n_trials, mode="iterpro", seed=seed))
    canary = summarize(campaign.run(n_trials, mode="iterpro", seed=seed,
                                    use_canary=True, canary_slices=4))
    return {"traps_only": traps, "with_canary": canary,
            "n_trials": n_trials}


def render(out: Dict) -> str:
    n = out["n_trials"]
    t, c = out["traps_only"], out["with_canary"]
    lines = ["## Injection outcomes (paper Tables 3-5 analogue)", ""]
    lines.append("| outcome | traps only | +canary (K=4) | paper (avg) |")
    lines.append("|---|---|---|---|")
    paper = {"benign": "~44%", "crash": "~29%", "sdc": "~28%",
             "hang": "~0%"}
    for k in ("benign", "crash", "sdc", "hang"):
        vt = t["outcomes"].get(k, 0)
        vc = c["outcomes"].get(k, 0)
        lines.append(f"| {k} | {vt} ({100*vt/n:.0f}%) "
                     f"| {vc} ({100*vc/n:.0f}%) | {paper[k]} |")
    lines.append("")
    lines.append("Domain finding: free traps detect almost nothing here — "
                 "RMSNorm structurally masks magnitude faults and pure "
                 "dataflow has no invalid-address trap; the canary restores "
                 "(and exceeds) the paper's detection coverage, converting "
                 "would-be SDCs into recoverable 'crashes'.")
    lines.append("")
    lines.append("| detection symptom | traps only | +canary | paper "
                 "analogue |")
    lines.append("|---|---|---|---|")
    mapping = {"nonfinite": "SIGSEGV/SIGFPE-class (free trap)",
               "loss_spike": "SIGABRT-class (anomaly)",
               "checksum": "manufactured trap (no paper analogue)"}
    for k in ("nonfinite", "loss_spike", "checksum"):
        lines.append(f"| {k} | {t['crash_symptoms'].get(k, 0)} "
                     f"| {c['crash_symptoms'].get(k, 0)} "
                     f"| {mapping[k]} |")
    lines.append("")
    lines.append("| detection latency (steps) | traps only | +canary | "
                 "paper: instrs |")
    lines.append("|---|---|---|---|")
    paper_lat = {"0": "<=10 instr (53-99%)", "1": "11-50",
                 "2-4": "51-400", ">4": ">400"}
    for k in ("0", "1", "2-4", ">4"):
        lines.append(f"| {k} | {t['latency_steps_hist'].get(k, 0)} "
                     f"| {c['latency_steps_hist'].get(k, 0)} "
                     f"| {paper_lat[k]} |")
    return "\n".join(lines)
