"""Elastic hard-loss drill — downtime-to-resume vs checkpoint restart.

    PYTHONPATH=src python -m benchmarks.elastic_drill --smoke

Kills a data row of an 8-device (4, 2) mesh mid-run and measures what the
remesh rung (DESIGN.md §7) actually costs:

* **downtime to resume** — last healthy step to first post-loss step:
  survivor-honest gather + XOR parity reconstruction of the dead rows'
  FSDP shards + ONE re-lower on the degraded (3, 2) mesh,
* **bytes moved** — reconstructed (parity) vs re-gathered (replicated)
  bytes, against the full state size a disk restore would move,
* **the strawman** — a from-checkpoint restart on the SAME degraded mesh:
  device_put of the full host checkpoint + re-lower + replay of the steps
  since the last snapshot (the paper's cold-restart cost floor; real
  restarts add scheduler/requeue time on top).

Two contracts are HARD-ASSERTED, not just reported (overhead.py-style):

* ``disk_restores == 0`` and ``uncertified_blocks == 0`` on the remesh
  event — recovery read parity + survivors only, and every surviving
  block was digest-certified against the canary's surviving rows;
* post-remesh steady state is EXACTLY 1 logical canary launch + 1 scalar
  sync + 0 digest retraces per step — the resumed loop kept the fused
  observability contract, and the AOT resume step cannot retrace.

``--out`` writes machine-readable ``BENCH_elastic.json`` so the elastic
downtime trajectory is tracked across PRs.
"""

from __future__ import annotations

import os

# must be set before jax initialises its backends
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import dataclasses
import json
import time
from typing import Dict, Optional

import jax
import numpy as np

from repro.configs import get_config
from repro.core.detect import ChecksumCanary, FaultReport
from repro.core.icp import promote
from repro.core.microcheckpoint import MicroCheckpointer
from repro.core.parity import ParityStore
from repro.core.recover import RecoveryRuntime
from repro.data.pipeline import TokenPipeline
from repro.distributed.context import DistContext
from repro.kernels import digest as kdigest
from repro.launch.elastic import ElasticManager
from repro.launch.specs import bind_state
from repro.train.loop import make_train_state, make_train_step

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), os.pardir,
                           "BENCH_elastic.json")


def _state_bytes(state) -> int:
    return sum(x.nbytes for x in jax.tree_util.tree_leaves(state))


def run(*, arch: str = "iterpro-100m", smoke: bool = True,
        steps: int = 10, kill_at: int = 5, ckpt_every: int = 4,
        global_batch: int = 12, seq_len: int = 32, kill_row: int = 3,
        pure_dp: bool = False, seed: int = 0,
        steady_steps: int = 4) -> Dict:
    assert len(jax.devices()) >= 8, (
        "run with XLA_FLAGS=--xla_force_host_platform_device_count=8")
    assert 0 < kill_at < steps
    cfg = get_config(arch)
    if smoke:
        cfg = cfg.smoke()
    if not pure_dp:
        # force FSDP so the dead row's shards exercise the parity
        # reconstruction path (pure DP degenerates to re-gather)
        cfg = dataclasses.replace(
            cfg, sharding=dataclasses.replace(cfg.sharding, fsdp=True))
    B, S = global_batch, seq_len

    ctx = DistContext.for_mesh(jax.make_mesh((4, 2), ("data", "model")))
    pipe = TokenPipeline(cfg.model.vocab_size, S, B, seed=seed)
    state = make_train_state(cfg, jax.random.PRNGKey(seed), global_batch=B)
    raw_bfn = lambda s: pipe.batch_at(s)
    state, raw, bfn, sh = bind_state(
        ctx, cfg, state, make_train_step(cfg, global_batch=B), raw_bfn)
    step = jax.jit(raw)
    canary = ChecksumCanary(state, n_slices=1, ctx=ctx)
    pstore = ParityStore(state, ctx=ctx, row_safe=True)
    pstore.build(state)
    canary.attach_parity(pstore)
    emgr = ElasticManager(ctx)
    runtime = RecoveryRuntime(
        step_fn=step, batch_fn=bfn, iv_registry=promote(cfg, B),
        micro=MicroCheckpointer(interval=ckpt_every, ctx=ctx),
        parity=pstore, shardings=sh, canary=canary,
        elastic=emgr.hook(raw_step=raw, cfg=cfg, batch_fn=raw_bfn,
                          canary=canary, pstore=pstore))

    # ---- healthy phase, snapshotting the restart strawman's checkpoint
    ckpt_step, ckpt_host = 0, jax.tree_util.tree_map(np.asarray, state)
    step_walls = []
    for s in range(kill_at):
        if s and s % ckpt_every == 0:
            ckpt_step = s
            ckpt_host = jax.tree_util.tree_map(np.asarray, state)
        t0 = time.perf_counter()
        ns, m = step(state, bfn(s))
        assert canary.check_and_arm(s, state, ns) is None
        jax.block_until_ready(ns["step"] if "step" in ns else
                              jax.tree_util.tree_leaves(ns)[0])
        step_walls.append(time.perf_counter() - t0)
        state = ns
    total_bytes = _state_bytes(state)

    # ---- the hard loss -------------------------------------------------
    report = FaultReport(kill_at, "external", lost_rows=(kill_row,),
                         detail=f"drill: data row {kill_row} lost")
    t_loss = time.perf_counter()
    state, rev = runtime.recover(state, report, kill_at)
    resume = runtime.pending_remesh
    assert resume is not None and rev.rung == "remesh"
    ev = resume.event
    assert ev.disk_restores == 0, "remesh path touched a disk checkpoint"
    assert ev.uncertified_blocks == 0, (
        f"{ev.uncertified_blocks} surviving blocks failed digest "
        f"certification")

    # first post-loss step closes the downtime window
    st = resume.state
    ns, m = resume.step(st, resume.bfn(kill_at))
    assert resume.canary.check_and_arm(kill_at, st, ns) is None
    jax.block_until_ready(jax.tree_util.tree_leaves(ns)[0])
    downtime_to_resume = time.perf_counter() - t_loss
    st = ns

    # ---- run out the schedule on the degraded mesh ---------------------
    for s in range(kill_at + 1, steps):
        ns, m = resume.step(st, resume.bfn(s))
        assert resume.canary.check_and_arm(s, st, ns) is None
        st = ns
    final_loss = float(m["loss"])

    # ---- hard-assert the post-remesh steady state: 1/1/0 ---------------
    jax.block_until_ready(jax.tree_util.tree_leaves(st)[0])
    kdigest.STATS.reset()
    for s in range(steps, steps + steady_steps):
        ns, m = resume.step(st, resume.bfn(s))
        assert resume.canary.check_and_arm(s, st, ns) is None
        st = ns
    jax.block_until_ready(jax.tree_util.tree_leaves(st)[0])
    launches, syncs, traces = kdigest.STATS.snapshot()
    assert launches == steady_steps and syncs == steady_steps \
        and traces == 0, (
            "post-remesh steady state must be 1 logical launch + 1 "
            f"scalar sync + 0 retraces per step, got {launches}/{syncs}/"
            f"{traces} over {steady_steps} steps")

    # ---- the strawman: from-checkpoint restart on the degraded mesh ----
    # full-state device_put + re-lower + replay of the steps lost since
    # the last snapshot.  The remesh path's re-lower already warmed XLA's
    # autotuning for this (mesh, program), so this strawman is a LOWER
    # bound on a cold restart — which only strengthens the comparison.
    t0 = time.perf_counter()
    rb = bind_state(resume.ctx, cfg, ckpt_host, raw, raw_bfn)
    rstep = jax.jit(rb.step)
    compiled = rstep.lower(rb.state, rb.bfn(ckpt_step)).compile()
    t_bind = time.perf_counter() - t0
    rst = rb.state
    for s in range(ckpt_step, kill_at):
        rst, _ = compiled(rst, rb.bfn(s))
    jax.block_until_ready(jax.tree_util.tree_leaves(rst)[0])
    restart_wall = time.perf_counter() - t0

    return {
        "config": {"arch": arch, "smoke": smoke, "steps": steps,
                   "kill_at": kill_at, "kill_row": kill_row,
                   "ckpt_every": ckpt_every, "global_batch": B,
                   "seq_len": S, "pure_dp": pure_dp, "seed": seed,
                   "mesh": {"data": 4, "model": 2},
                   "degraded_mesh": dict(resume.ctx.mesh.shape)},
        "event": ev.to_dict(),
        "downtime_to_resume_s": downtime_to_resume,
        "reconstruct_s": ev.reconstruct_seconds,
        "relower_s": ev.relower_seconds,
        "bytes_reconstructed": ev.bytes_reconstructed,
        "bytes_regathered": ev.bytes_regathered,
        "state_bytes": total_bytes,
        "reconstructed_fraction":
            ev.bytes_reconstructed / total_bytes if total_bytes else 0.0,
        "restart_baseline": {
            "ckpt_step": ckpt_step,
            "replay_steps": kill_at - ckpt_step,
            "bind_and_compile_s": t_bind,
            "wall_s": restart_wall,
            "bytes_moved": total_bytes,
        },
        "speedup_vs_restart":
            restart_wall / downtime_to_resume if downtime_to_resume else 0.0,
        "healthy_step_ms": 1e3 * float(np.mean(step_walls))
        if step_walls else 0.0,
        "steady_state": {"launches_per_step": launches / steady_steps,
                         "syncs_per_step": syncs / steady_steps,
                         "retraces": traces},
        "final_loss": final_loss,
        "disk_restores": 0,                        # asserted above
    }


def bench_record(out: Dict) -> Dict:
    """The compact cross-PR trajectory record (BENCH_elastic.json)."""
    ev = out["event"]
    return {
        "downtime_to_resume_s": out["downtime_to_resume_s"],
        "reconstruct_s": out["reconstruct_s"],
        "relower_s": out["relower_s"],
        "bytes_reconstructed": out["bytes_reconstructed"],
        "bytes_regathered": out["bytes_regathered"],
        "state_bytes": out["state_bytes"],
        "blocks_reconstructed": ev["blocks_reconstructed"],
        "certified_blocks": ev["certified_blocks"],
        "uncertified_blocks": ev["uncertified_blocks"],
        "restart_baseline_s": out["restart_baseline"]["wall_s"],
        "speedup_vs_restart": out["speedup_vs_restart"],
        "steady_state_launches_per_step":
            out["steady_state"]["launches_per_step"],
        "steady_state_retraces": out["steady_state"]["retraces"],
        "disk_restores": out["disk_restores"],
        "old_dp": ev["old_dp"],
        "new_dp": ev["new_dp"],
    }


def write_bench(out: Dict, path: str = DEFAULT_OUT) -> str:
    path = os.path.abspath(path)
    with open(path, "w") as f:
        json.dump(bench_record(out), f, indent=1)
        f.write("\n")
    return path


def render(out: Dict) -> str:
    c, ev, rb = out["config"], out["event"], out["restart_baseline"]
    lines = ["## Elastic hard-loss drill (remesh rung vs restart)", ""]
    lines.append(
        f"{c['arch']}{' smoke' if c['smoke'] else ''}, mesh "
        f"{c['mesh']['data']}x{c['mesh']['model']} -> "
        f"{out['config']['degraded_mesh']}, row {c['kill_row']} killed at "
        f"step {c['kill_at']}/{c['steps']}, global batch {c['global_batch']}"
        f" kept")
    lines.append("")
    lines.append("| path | wall (s) | bytes moved |")
    lines.append("|---|---|---|")
    lines.append(
        f"| remesh rung (resume) | {out['downtime_to_resume_s']:.2f} | "
        f"{out['bytes_reconstructed'] + out['bytes_regathered']} |")
    lines.append(
        f"| checkpoint restart + replay {rb['replay_steps']} steps | "
        f"{rb['wall_s']:.2f} | {rb['bytes_moved']} |")
    lines.append("")
    lines.append(
        f"- downtime to resume {out['downtime_to_resume_s']:.2f} s = "
        f"reconstruct {out['reconstruct_s']:.2f} s + re-lower "
        f"{out['relower_s']:.2f} s + first degraded step")
    lines.append(
        f"- reconstructed {ev['blocks_reconstructed']} blocks / "
        f"{out['bytes_reconstructed']} B from XOR parity "
        f"({100 * out['reconstructed_fraction']:.2f}% of the "
        f"{out['state_bytes']} B state); re-gathered "
        f"{ev['leaves_regathered']} replicated leaves / "
        f"{out['bytes_regathered']} B")
    lines.append(
        f"- certification: {ev['certified_blocks']} surviving blocks "
        f"digest-certified, {ev['uncertified_blocks']} failures "
        f"(asserted 0); disk restores: {out['disk_restores']} "
        f"(asserted 0)")
    ss = out["steady_state"]
    lines.append(
        f"- post-remesh steady state (asserted): "
        f"{ss['launches_per_step']:g} launch + {ss['syncs_per_step']:g} "
        f"sync + {ss['retraces']} retraces per step at dp={ev['new_dp']}")
    moved = out["bytes_reconstructed"] + out["bytes_regathered"]
    lines.append(
        f"- speedup vs checkpoint restart: "
        f"{out['speedup_vs_restart']:.1f}x wall (restart here is a warm "
        f"lower bound: same-process XLA, zero requeue time; at CPU-smoke "
        f"scale both windows are compile-dominated — the scale-relevant "
        f"ratio is bytes moved, {moved} vs {rb['bytes_moved']} = "
        f"{rb['bytes_moved'] / moved:.1f}x less traffic)")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="iterpro-100m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--kill-at", type=int, default=5)
    ap.add_argument("--kill-row", type=int, default=3)
    ap.add_argument("--ckpt-every", type=int, default=4,
                    help="restart strawman's snapshot interval")
    ap.add_argument("--batch", type=int, default=12)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--pure-dp", action="store_true",
                    help="keep the arch's fsdp=False: exercises the "
                         "re-gather path instead of parity reconstruction")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="path for BENCH_elastic.json ('' to skip)")
    args = ap.parse_args()

    out = run(arch=args.arch, smoke=args.smoke, steps=args.steps,
              kill_at=args.kill_at, kill_row=args.kill_row,
              ckpt_every=args.ckpt_every, global_batch=args.batch,
              seq_len=args.seq, pure_dp=args.pure_dp, seed=args.seed)
    print(render(out))
    if args.out:
        path = write_bench(out, args.out)
        print(f"\nwrote {path}")


if __name__ == "__main__":
    main()
