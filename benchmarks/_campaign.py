"""Shared fault-injection campaign — the engine behind the paper-table
benchmarks (Tables 3-5, Figs 7-8, 10).

Methodology (paper §5.1, adapted to the training-state failure domain):

* fault model: single bit flip in one element of one state leaf, leaf chosen
  size-weighted (the execution-weighted analogue), element/bit/step uniform;
  one injection per trial.
* detectors: by default only the FREE traps (non-finite loss, loss spike) —
  the analogue of the paper's hardware SIGSEGV (§5.2 studies stock
  applications with no paid detection).  ``use_canary=True`` adds the
  rotating checksum canary (IterPro-JAX's paid detector; an ablation the
  paper doesn't have).
* outcomes:
    Benign — no detector fires AND the final state is bitwise identical to
             the fault-free trajectory (flip masked / overwritten);
    Crash  — a detector fires (the hardware-trap analogue);
    SDC    — no detector fires but the final state diverges;
    Hang   — loss plateaus at a pathological level (proxy).
* detection latency = steps from injection to the firing detector.
* recovery realism: snapshots follow the LIVE schedule — a snapshot taken
  after the injection captures the corrupted lineage, exactly as on a real
  cluster.  We therefore report both
    recovered — the job continued (the ladder produced a verified-finite
                state), and
    exact     — the continued trajectory is bitwise identical to the
                fault-free truth (the paper's no-SDC guarantee).

Modes:
  'iterpro' — full ladder (Eq.(1) IV repair -> replay -> ...);
  'care'    — the SC'19 baseline: no induction-variable recovery; a trial
              whose IV block is corrupted cannot replay (the RSI's loop
              state is gone) and counts unrecovered.

Mesh regime (``Campaign(ctx=DistContext)``; DESIGN.md §5): the whole
campaign — ground-truth trajectory, injection, detection, recovery and
the horizon continuation — runs on the device mesh.  The ground truth is
recomputed ON the mesh because reduction reordering under GSPMD is not
bit-identical to single-device execution; outcome CLASSIFICATION is what
must conform across regimes (asserted by tests/test_sharded_resilience.py).
The canary goes shard-local, snapshots carry per-(leaf, shard) digests,
and non-donated recoveries may use the shard_patch rung (restore only the
injured shard) when a version-matched snapshot exists.
"""

from __future__ import annotations

import dataclasses
import random
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import (
    ChecksumCanary,
    FaultReport,
    InjectionPlan,
    MicroCheckpointer,
    RecoveryFailed,
    RecoveryRuntime,
    inject,
    promote,
    sample_plan,
    trap_loss_spike,
    trap_nonfinite,
)
from repro.core.detect import LOSS_WINDOW
from repro.data.pipeline import TokenPipeline
from repro.train.loop import make_train_state, make_train_step


@dataclass
class Trial:
    target: str
    leaf: str
    bit: int
    inject_step: int
    outcome: str = ""              # benign | crash | sdc | hang
    detector: str = ""             # nonfinite | loss_spike | checksum
    latency_steps: int = -1
    recovered: bool = False
    exact: bool = False            # post-recovery trajectory == truth
    rung: str = ""
    recovery_ms: float = 0.0
    phase_ms: Dict[str, float] = field(default_factory=dict)
    replayed: int = 0
    bytes_moved: int = 0           # repair bytes (parity / shard_patch)


class Campaign:
    def __init__(self, cfg_name: str = "iterpro-100m", B: int = 2,
                 S: int = 32, total_steps: int = 10,
                 snapshot_interval: int = 2, seed: int = 0, ctx=None):
        self.B, self.S = B, S
        self.total_steps = total_steps
        self.snapshot_interval = snapshot_interval
        self.seed = seed
        self.ctx = ctx if (ctx is not None and ctx.enabled) else None
        self.cfg = get_config(cfg_name).smoke()
        self.pipe = TokenPipeline(self.cfg.model.vocab_size, S, B, seed=seed)
        self.shardings = None
        self._donated_step = None    # built lazily: donate_argnums=(0,)
        self._raw_step = None        # built lazily: unjitted (fused detect)

        state = make_train_state(self.cfg, jax.random.PRNGKey(seed),
                                 global_batch=B)
        # mesh regime: the bind recipe (shard the state, pin its layout
        # through the step, shard batches) — the ground truth below then
        # IS the mesh trajectory (GSPMD reduction order is not
        # bit-identical to single-device, so truth must be computed where
        # trials run); off-mesh everything passes through untouched
        from repro.launch.specs import bind_state
        bound = bind_state(self.ctx, self.cfg, state,
                           make_train_step(self.cfg, global_batch=B),
                           lambda s: self.pipe.batch_at(s))
        state, pinned, self.bfn, self.shardings = bound
        self._pin = bound.pin
        self.step = jax.jit(pinned)

        # fault-free reference trajectory (ground truth for benign/SDC/exact)
        self.states = [state]
        self.losses = []
        for s in range(total_steps):
            state, m = self.step(state, self.bfn(s))
            self.losses.append(float(m["loss"]))
            self.states.append(state)
        self.final_digest = self._digest(self.states[-1])

    @staticmethod
    def _digest(state):
        return [np.asarray(x).tobytes()
                for x in jax.tree_util.tree_leaves(state)]

    @staticmethod
    def clone(tree):
        """Deep device copy — a donated loop must not delete buffers the
        injected tree shares with the ground-truth trajectory."""
        return jax.tree_util.tree_map(lambda x: jnp.array(x, copy=True),
                                      tree)

    def donated_step(self):
        """The production-compilation step: ``donate_argnums=(0,)`` (XLA
        updates the state in place; the pre-step buffers die)."""
        if self._donated_step is None:
            self._donated_step = jax.jit(
                self._pin(make_train_step(self.cfg, global_batch=self.B)),
                donate_argnums=(0,))
        return self._donated_step

    def raw_step(self):
        """The UNJITTED step function, for in-step fused detection: the
        ``FusedStepFactory`` jits it together with the canary check/arm.
        One function object for the campaign's lifetime, so the factory's
        global executable cache never recompiles across trials.  In the
        mesh regime the output layout is pinned to the canonical
        shardings, exactly like the jitted steps."""
        if self._raw_step is None:
            self._raw_step = self._pin(
                make_train_step(self.cfg, global_batch=self.B))
        return self._raw_step

    # ------------------------------------------------------------------

    def run_trial(self, rng: random.Random, mode: str = "iterpro",
                  target: Optional[str] = None,
                  use_canary: bool = False,
                  canary_slices: int = 4,
                  plan: Optional[InjectionPlan] = None,
                  donate: bool = False,
                  fused: bool = False,
                  parity: bool = False,
                  triage: bool = False) -> Trial:
        """One injection trial.

        ``plan``   : fixed InjectionPlan (its ``step`` is the injection
                     step) — the seeded-conformance entry point; None
                     samples the paper's size-weighted model.
        ``donate`` : run the faulty loop with the donated step — the
                     canary switches to the arm-before/check-after pair
                     around the adversary window, and recovery pivots to
                     snapshot + replay (RecoveryRuntime(donated=True)).
        ``fused``  : in-step fused detection (implies ``use_canary``): the
                     canary check/arm ride the step's own launch
                     (``ChecksumCanary.fuse_into_step``); detection step
                     indices, attribution and recovery semantics must
                     conform to the pair/check_and_arm paths.
        ``parity`` : maintain the device-resident XOR parity shard
                     (implies ``use_canary`` — maintenance rides the
                     canary's launches) and give recovery the parity_xor
                     rung: snapshot-free O(bytes/D) shard reconstruction
                     for checksum-attributed faults.  Under fused+donated
                     detection the faulting version is consumed by the
                     detecting launch, so those trials still replay.
        ``triage`` : enable recovery rung 0 (implies ``use_canary``):
                     checksum faults are classified against the canary's
                     reference digest pair and certified-harmless flips
                     are tolerated in place (rung ``triage``, zero bytes,
                     zero replay); uncertifiable faults escalate down the
                     unchanged ladder.
        """
        if mode == "care" and donate:
            raise ValueError("care mode diagnoses the live IV block and is "
                             "not defined for a donated loop")
        if fused or parity or triage:
            use_canary = True
        if plan is None:
            tgt = target or rng.choices(["params", "opt", "iv"],
                                        weights=[0.55, 0.40, 0.05])[0]
            t0 = rng.randrange(1, self.total_steps - 1)
            plan = sample_plan(rng, self.states[t0], max_step=1, target=tgt)
            plan = dataclasses.replace(plan, step=t0)
        tgt = plan.target
        t0 = plan.step
        assert 1 <= t0 < self.total_steps
        trial = Trial(target=tgt, leaf=f"{tgt}/{plan.leaf}", bit=plan.bit,
                      inject_step=t0)

        # live-schedule snapshots: clean prefix up to t0, then the faulty
        # run snapshots its own (possibly corrupted) lineage — realism.
        micro = MicroCheckpointer(interval=self.snapshot_interval, keep=2,
                                  ctx=self.ctx)
        for s in range(0, t0 + 1):
            micro.maybe_snapshot(s, self.states[s])
            micro.record_iv(s, self.states[s]["iv"])

        step_fn = self.donated_step() if donate else self.step
        state = inject(self.states[t0], plan)
        if donate:
            state = self.clone(state)
        canary = ChecksumCanary(self.states[t0], n_slices=canary_slices,
                                ctx=self.ctx) \
            if use_canary else None
        pstore = None
        if parity:
            # built over the HEALTHY pre-injection version, exactly like
            # the canary's initial digest table (the plan is globally
            # cached, so trials share layout + compiled parity math)
            from repro.core import ParityStore
            pstore = ParityStore(self.states[t0], ctx=self.ctx)
            pstore.build(self.states[t0], t0)
            canary.attach_parity(pstore)
        factory = canary.fuse_into_step(self.raw_step(), donate=donate) \
            if fused else None
        # bounded: the spike trap reads only the last LOSS_WINDOW losses
        history = deque(self.losses[:t0], maxlen=LOSS_WINDOW)

        report = None
        s = t0
        while s < self.total_steps:
            if s > t0:
                micro.maybe_snapshot(s, state)
                micro.record_iv(s, state["iv"])
            if donate and canary is not None and factory is None:
                # donated protocol: slice s%K was armed when this buffer
                # was the previous step's fresh output (for s == t0: at
                # canary construction); verify it at its last readable
                # moment, one launch + one scalar sync
                report = canary.check(s, state)
                if report is not None:
                    break
            if factory is not None:
                # in-step fused: check slice s%K of the input + arm slice
                # (s+1)%K of the output inside the step's own launch; on a
                # report the output is corrupt-derived and discarded
                new_state, metrics, report = factory.step(
                    s, state, self.bfn(s))
                if report is not None:
                    break
            else:
                new_state, metrics = step_fn(state, self.bfn(s))
            if donate and canary is not None and factory is None:
                # arm half: digest slice (s+1)%K of the fresh output (one
                # launch, no sync) — next iteration's check verifies it
                canary.arm_current(s + 1, new_state)
            report = trap_nonfinite(s, metrics) or \
                trap_loss_spike(s, metrics, history)
            if report is None and not donate and canary is not None \
                    and factory is None:
                # fused rotating canary: ONE launch + ONE scalar sync —
                # verify slice s%K of the (pre-step) state the step just
                # consumed, arm slice (s+1)%K of its output
                report = canary.check_and_arm(s, state, new_state)
            if report is not None:
                break
            history.append(float(metrics["loss"]))
            state = new_state
            s += 1

        if report is None:
            # benign vs SDC: bitwise identity is too strict for a persistent
            # single-bit flip (a low mantissa bit changes the trajectory
            # forever at numerically negligible magnitude), so we classify
            # on the horizon loss: within 1e-5 relative of truth => benign
            # (no impact on the application), else SDC.
            same_bits = self._digest(state) == self.final_digest
            final_loss = history[-1] if history else float("inf")
            truth_loss = self.losses[-1]
            benign = same_bits or (
                abs(final_loss - truth_loss) <= 1e-5 * abs(truth_loss))
            trial.outcome = "benign" if benign else "sdc"
            if not benign and history and history[-1] > 50.0:
                trial.outcome = "hang"     # pathological plateau proxy
            return trial

        trial.outcome = "crash"
        trial.detector = report.detector
        trial.latency_steps = s - t0

        # ---------------- recovery ------------------------------------
        # checkpoint rung: the clean "disk checkpoint" at step 0 (the
        # paper's baseline C/R — expensive because it replays everything).
        runtime = RecoveryRuntime(step_fn=self.step, batch_fn=self.bfn,
                                  iv_registry=promote(self.cfg, self.B),
                                  micro=micro, parity=pstore,
                                  checkpoint=lambda: (self.states[0], 0),
                                  donated=donate, shardings=self.shardings,
                                  canary=canary, triage=triage)
        ladder = None
        if mode == "care":
            # CARE cannot repair loop state: if any IV is corrupted the RSI
            # has no intact loop state to replay over -> unrecoverable.
            # (registry keys are full leaf paths — prefix the live values)
            iv_vals = {f"iv/{k}": int(v) for k, v in state["iv"].items()}
            _, bad = promote(self.cfg, self.B).diagnose(iv_vals)
            if bad:
                trial.recovered = False
                return trial
            ladder = ["replay", "checkpoint"]

        t1 = time.perf_counter()
        try:
            fixed, ev = runtime.recover(state, report, s, ladder=ladder)
        except RecoveryFailed:
            trial.recovered = False
            return trial
        trial.recovered = True
        trial.rung = ev.rung
        trial.recovery_ms = 1e3 * (time.perf_counter() - t1)
        trial.phase_ms = {k: 1e3 * v for k, v in ev.phase_seconds.items()}
        trial.replayed = ev.steps_replayed
        trial.bytes_moved = ev.bytes_moved

        # exactness: continue to the horizon and compare bitwise with truth
        cont = fixed
        for s2 in range(s, self.total_steps):
            cont, _ = self.step(cont, self.bfn(s2))
        trial.exact = self._digest(cont) == self.final_digest
        return trial

    def run(self, n_trials: int, mode: str = "iterpro",
            target: Optional[str] = None, seed: int = 1,
            use_canary: bool = False, canary_slices: int = 4,
            donate: bool = False, fused: bool = False,
            parity: bool = False, triage: bool = False) -> List[Trial]:
        rng = random.Random(seed)
        return [self.run_trial(rng, mode=mode, target=target,
                               use_canary=use_canary,
                               canary_slices=canary_slices, donate=donate,
                               fused=fused, parity=parity, triage=triage)
                for _ in range(n_trials)]


def summarize(trials: List[Trial]) -> Dict:
    n = len(trials)
    by_outcome: Dict[str, int] = {}
    for t in trials:
        by_outcome[t.outcome] = by_outcome.get(t.outcome, 0) + 1
    crashes = [t for t in trials if t.outcome == "crash"]
    by_detector: Dict[str, int] = {}
    for t in crashes:
        by_detector[t.detector] = by_detector.get(t.detector, 0) + 1
    lat = [t.latency_steps for t in crashes]
    lat_hist = {"0": sum(1 for v in lat if v == 0),
                "1": sum(1 for v in lat if v == 1),
                "2-4": sum(1 for v in lat if 2 <= v <= 4),
                ">4": sum(1 for v in lat if v > 4)}
    rec = [t for t in crashes if t.recovered]
    exact = [t for t in rec if t.exact]
    by_rung: Dict[str, int] = {}
    for t in rec:
        by_rung[t.rung] = by_rung.get(t.rung, 0) + 1
    # paper-comparable: recovered by IterPro's in-HBM rungs, NOT classic C/R
    iterpro_rec = [t for t in rec if t.rung != "checkpoint"]
    return {
        "trials": n,
        "outcomes": by_outcome,
        "crash_symptoms": by_detector,
        "latency_steps_hist": lat_hist,
        "crashes": len(crashes),
        "recovered": len(rec),
        "recovery_rate": (len(rec) / len(crashes)) if crashes else None,
        "iterpro_recovered": len(iterpro_rec),
        "iterpro_rate": (len(iterpro_rec) / len(crashes)) if crashes
        else None,
        "exact": len(exact),
        "exact_rate": (len(exact) / len(rec)) if rec else None,
        "by_rung": by_rung,
        "mean_recovery_ms": float(np.mean([t.recovery_ms for t in rec]))
        if rec else None,
        "p50_recovery_ms": float(np.median([t.recovery_ms for t in rec]))
        if rec else None,
        "mean_steps_replayed": float(np.mean([t.replayed for t in rec]))
        if rec else None,
    }
