"""The title claim, quantified: downtime per fault, IterPro vs classic
checkpoint/restart.

    downtime_IterPro = detect latency + ladder wall time + replayed steps
    downtime_C/R     = restore wall time + E[lost steps] = interval/2

Measured on the smoke model (step time, recovery wall, restore wall), then
projected to pod scale with the roofline step times and a disk-restore model
(state_bytes / aggregate read bandwidth) — the paper's Fig-8 'dozens of ms
vs minutes' argument at 1T-parameter scale.
"""

from __future__ import annotations

import tempfile
import time
from typing import Dict

import jax
import numpy as np

from benchmarks._campaign import Campaign
from repro.checkpoint import CheckpointManager

# at-scale projection constants
DISK_BW_PER_HOST = 1e9          # 1 GB/s restore bandwidth per host
HOSTS = 64                      # 256 chips / 4 chips per host
KIMI_STATE_BYTES = 2.06e12      # measured (EXPERIMENTS §Perf canary table)
KIMI_STEP_S = 67.0              # kimi B4 roofline-bound step (memory term)
SNAPSHOT_K = 8                  # in-HBM snapshot interval


def run(campaign: Campaign, ckpt_interval: int = 200) -> Dict:
    # measured small-scale quantities
    state = campaign.states[0]
    t0 = time.perf_counter()
    st, m = campaign.step(state, campaign.bfn(0))
    jax.block_until_ready(m["loss"])
    step_s = time.perf_counter() - t0

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, interval=1, async_write=False)
        mgr.save(0, state)
        t0 = time.perf_counter()
        mgr.restore(state)
        restore_s = time.perf_counter() - t0

    # IterPro: canary detects within <=1 step; ladder p50 ~28 ms (bench);
    # replay <= snapshot interval steps.
    iterpro_small = 0.028 + (SNAPSHOT_K / 2) * step_s
    cr_small = restore_s + (ckpt_interval / 2) * step_s

    # at-scale projection (kimi-k2, 256 chips)
    restore_scale = KIMI_STATE_BYTES / (DISK_BW_PER_HOST * HOSTS)
    iterpro_scale = 0.028 + (SNAPSHOT_K / 2) * KIMI_STEP_S
    cr_scale = restore_scale + (ckpt_interval / 2) * KIMI_STEP_S

    return {
        "measured_smoke": {
            "step_s": step_s,
            "restore_s": restore_s,
            "iterpro_downtime_s": iterpro_small,
            "cr_downtime_s": cr_small,
            "speedup": cr_small / iterpro_small,
        },
        "projected_kimi_256chips": {
            "step_s": KIMI_STEP_S,
            "restore_s": restore_scale,
            "iterpro_downtime_s": iterpro_scale,
            "cr_downtime_s": cr_scale,
            "speedup": cr_scale / iterpro_scale,
        },
        "ckpt_interval": ckpt_interval,
    }


def render(out: Dict) -> str:
    lines = ["## Downtime per fault (the title claim)", ""]
    lines.append(f"(checkpoint interval = {out['ckpt_interval']} steps; "
                 f"IterPro = detect + ladder + <=K/2 replayed steps, K=8)")
    lines.append("")
    lines.append("| scale | step | C/R restore | C/R downtime | IterPro "
                 "downtime | speedup |")
    lines.append("|---|---|---|---|---|---|")
    for name, s in (("smoke (measured)", out["measured_smoke"]),
                    ("kimi-k2 256 chips (projected)",
                     out["projected_kimi_256chips"])):
        lines.append(
            f"| {name} | {s['step_s']:.2f}s | {s['restore_s']:.1f}s "
            f"| {s['cr_downtime_s']:.1f}s | {s['iterpro_downtime_s']:.1f}s "
            f"| **{s['speedup']:.0f}x** |")
    lines.append("")
    lines.append("The gap GROWS with scale: C/R downtime is dominated by "
                 "interval/2 lost steps + a restore that reads the full "
                 "state from disk; IterPro's is bounded by K/2 in-HBM "
                 "replayed steps regardless of model size.")
    return "\n".join(lines)
