"""The title claim, quantified: downtime per fault, IterPro vs classic
checkpoint/restart.

    downtime_IterPro = detect latency + ladder wall time + replayed steps
    downtime_C/R     = restore wall time + E[lost steps] = interval/2

Measured on the smoke model (step time, recovery wall, restore wall), then
projected to pod scale with the roofline step times and a disk-restore model
(state_bytes / aggregate read bandwidth) — the paper's Fig-8 'dozens of ms
vs minutes' argument at 1T-parameter scale.

Two refinements over the headline number:

* **per-rung breakdown** — a small measured campaign splits downtime by
  the rung that actually recovered each fault (eq1 repair vs shard patch
  vs replay vs C/R), since "downtime per fault" is really a distribution
  over which ladder rung fires;
* **serving row** — for live traffic the right unit is not lost steps but
  what a CLIENT pays per fault: per-fault recovery wall (slot eviction ->
  victim re-admitted) and added end-to-end latency, taken from the
  serving SLO benchmark (``benchmarks.serving_slo``) when its output is
  passed in.
"""

from __future__ import annotations

import random
import tempfile
import time
from typing import Dict, List, Optional

import jax
import numpy as np

from benchmarks._campaign import Campaign, Trial
from repro.checkpoint import CheckpointManager
from repro.core import InjectionPlan

# at-scale projection constants
DISK_BW_PER_HOST = 1e9          # 1 GB/s restore bandwidth per host
HOSTS = 64                      # 256 chips / 4 chips per host
KIMI_STATE_BYTES = 2.06e12      # measured (EXPERIMENTS §Perf canary table)
KIMI_STEP_S = 67.0              # kimi B4 roofline-bound step (memory term)
SNAPSHOT_K = 8                  # in-HBM snapshot interval


def by_rung(trials: List[Trial], step_s: float) -> Dict:
    """Per-rung downtime table: of the trials each rung recovered, its
    share, recovery wall time, replayed steps, and total downtime per
    fault (detect latency + ladder wall + replayed steps)."""
    rec = [t for t in trials if t.outcome == "crash" and t.recovered]
    out: Dict[str, Dict] = {}
    for rung in sorted({t.rung for t in rec}):
        rs = [t for t in rec if t.rung == rung]
        wall = [t.recovery_ms for t in rs]
        replayed = [t.replayed for t in rs]
        latency = [max(0, t.latency_steps) for t in rs]
        out[rung] = {
            "n": len(rs),
            "fraction_of_recovered": len(rs) / len(rec),
            "mean_recovery_ms": float(np.mean(wall)),
            "p50_recovery_ms": float(np.median(wall)),
            "mean_steps_replayed": float(np.mean(replayed)),
            # downtime = detection latency + ladder wall + replay
            "mean_downtime_s": float(np.mean(
                [(lat + rep) * step_s + w / 1e3
                 for lat, rep, w in zip(latency, replayed, wall)])),
        }
    return out


def run(campaign: Campaign, ckpt_interval: int = 200, n_trials: int = 24,
        serving: Optional[Dict] = None) -> Dict:
    # measured small-scale quantities
    state = campaign.states[0]
    t0 = time.perf_counter()
    st, m = campaign.step(state, campaign.bfn(0))
    jax.block_until_ready(m["loss"])
    step_s = time.perf_counter() - t0

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, interval=1, async_write=False)
        mgr.save(0, state)
        t0 = time.perf_counter()
        mgr.restore(state)
        restore_s = time.perf_counter() - t0

    # IterPro: canary detects within <=1 step; ladder p50 ~28 ms (bench);
    # replay <= snapshot interval steps.
    iterpro_small = 0.028 + (SNAPSHOT_K / 2) * step_s
    cr_small = restore_s + (ckpt_interval / 2) * step_s

    # at-scale projection (kimi-k2, 256 chips)
    restore_scale = KIMI_STATE_BYTES / (DISK_BW_PER_HOST * HOSTS)
    iterpro_scale = 0.028 + (SNAPSHOT_K / 2) * KIMI_STEP_S
    cr_scale = restore_scale + (ckpt_interval / 2) * KIMI_STEP_S

    # measured per-rung split: canary-detected campaign so every rung of
    # the ladder is reachable (traps-only rarely exercises eq1/patch).
    # triage=True arms rung 0, so certified-harmless flips land in the
    # "triage" row instead of paying replay.
    trials = campaign.run(n_trials, mode="iterpro", seed=31,
                          use_canary=True, canary_slices=4, triage=True)
    # seeded probes: random sampling rarely lands on the two new in-place
    # rungs, so pin one fault each — a bit flip in the optimizer's own
    # step counter (opt_iv: Eq.(1) consensus over the induction registry)
    # and a below-epsilon mantissa flip in a first-moment EMA (triage:
    # certified tolerable, zero repair)
    # canary_slices=1 -> the whole state is digest-checked every step, so
    # detection is checksum-attributed at the injection step (a rotating
    # canary can re-arm a scalar's slice from the corrupt-derived state
    # before its check comes up, demoting the fault to a trap)
    probe_rng = random.Random(41)
    probes = [
        campaign.run_trial(probe_rng, mode="iterpro",
                           plan=InjectionPlan("t", 0, 3, 3, "opt"),
                           use_canary=True, canary_slices=1, triage=True),
        campaign.run_trial(probe_rng, mode="iterpro",
                           plan=InjectionPlan("m/groups/0/0/ffn/up/w",
                                              1000, 1, 3, "opt"),
                           use_canary=True, canary_slices=1, triage=True),
    ]
    trials = trials + probes
    rung_table = by_rung(trials, step_s)

    # parity regime: donated pair + device-resident XOR parity — the
    # snapshot-free rung.  Measured per fault: how often parity_xor wins
    # the ladder, its repair wall, bytes reconstructed (O(bytes/D)), and
    # the fixed memory price (1/D of the covered state)
    ptrials = campaign.run(max(8, n_trials // 2), mode="iterpro", seed=37,
                           parity=True, donate=True, canary_slices=4)
    prec = [t for t in ptrials if t.outcome == "crash" and t.recovered]
    pxor = [t for t in prec if t.rung == "parity_xor"]
    from repro.core import ParityStore
    pst = ParityStore(state)
    pst.build(state)
    state_bytes = sum(x.nbytes for x in jax.tree_util.tree_leaves(state))
    parity_row = {
        "trials": len(ptrials),
        "crashes_recovered": len(prec),
        "parity_xor_share": len(pxor) / max(1, len(prec)),
        "mean_repair_ms": float(np.mean([t.recovery_ms for t in pxor])
                                ) if pxor else 0.0,
        "mean_bytes_moved": float(np.mean([t.bytes_moved for t in pxor])
                                  ) if pxor else 0.0,
        "mean_steps_replayed": float(np.mean([t.replayed for t in pxor])
                                     ) if pxor else 0.0,
        "all_exact": bool(all(t.exact for t in prec)) if prec else True,
        "memory_bytes": pst.memory_bytes,
        "memory_overhead": pst.memory_bytes / state_bytes,
        "n_shards": pst.plan.n_shards,
    }

    # serving: per-fault client cost from the SLO benchmark, if it ran
    serving_row = None
    if serving is not None:
        al, rc = serving["added_latency_ms"], serving["recovery_ms"]
        serving_row = {
            "faults": serving["faults"]["injected"],
            "recovered_fraction":
                serving["faults"]["recovered"]
                / max(1, serving["faults"]["injected"]),
            "mean_recovery_ms": rc["mean"],
            "p99_recovery_ms": rc["p99"],
            "injured_added_latency_ms": al["injured"],
            "healthy_added_latency_ms": al["healthy"],
            "dropped_healthy": serving["dropped_healthy"],
        }

    return {
        "measured_smoke": {
            "step_s": step_s,
            "restore_s": restore_s,
            "iterpro_downtime_s": iterpro_small,
            "cr_downtime_s": cr_small,
            "speedup": cr_small / iterpro_small,
        },
        "projected_kimi_256chips": {
            "step_s": KIMI_STEP_S,
            "restore_s": restore_scale,
            "iterpro_downtime_s": iterpro_scale,
            "cr_downtime_s": cr_scale,
            "speedup": cr_scale / iterpro_scale,
        },
        "ckpt_interval": ckpt_interval,
        "by_rung": rung_table,
        "rung_trials": n_trials + len(probes),
        "parity": parity_row,
        "serving": serving_row,
    }


def render(out: Dict) -> str:
    lines = ["## Downtime per fault (the title claim)", ""]
    lines.append(f"(checkpoint interval = {out['ckpt_interval']} steps; "
                 f"IterPro = detect + ladder + <=K/2 replayed steps, K=8)")
    lines.append("")
    lines.append("| scale | step | C/R restore | C/R downtime | IterPro "
                 "downtime | speedup |")
    lines.append("|---|---|---|---|---|---|")
    for name, s in (("smoke (measured)", out["measured_smoke"]),
                    ("kimi-k2 256 chips (projected)",
                     out["projected_kimi_256chips"])):
        lines.append(
            f"| {name} | {s['step_s']:.2f}s | {s['restore_s']:.1f}s "
            f"| {s['cr_downtime_s']:.1f}s | {s['iterpro_downtime_s']:.1f}s "
            f"| **{s['speedup']:.0f}x** |")
    lines.append("")
    lines.append("The gap GROWS with scale: C/R downtime is dominated by "
                 "interval/2 lost steps + a restore that reads the full "
                 "state from disk; IterPro's is bounded by K/2 in-HBM "
                 "replayed steps regardless of model size.")
    if out.get("by_rung"):
        lines.append("")
        lines.append(f"### Downtime by recovery rung (measured, "
                     f"{out['rung_trials']} canary-detected trials)")
        lines.append("| rung | share of recovered | mean wall (ms) "
                     "| p50 wall (ms) | mean steps replayed "
                     "| mean downtime (s) |")
        lines.append("|---|---|---|---|---|---|")
        for rung, r in out["by_rung"].items():
            lines.append(
                f"| {rung} | {100 * r['fraction_of_recovered']:.0f}% "
                f"({r['n']}) | {r['mean_recovery_ms']:.1f} "
                f"| {r['p50_recovery_ms']:.1f} "
                f"| {r['mean_steps_replayed']:.1f} "
                f"| {r['mean_downtime_s']:.2f} |")
        lines.append("")
        lines.append("Downtime per fault is a distribution over WHICH rung "
                     "fires: rung 0 (triage) tolerates certified-harmless "
                     "flips for the cost of re-arming a digest row; "
                     "in-place repairs (eq1, opt_iv, shard_patch) cost "
                     "milliseconds and replay nothing; replay pays <=K "
                     "steps; only the checkpoint rung pays C/R prices. "
                     "opt_iv extends Eq.(1) to the optimizer's own "
                     "induction block — a flipped step counter or "
                     "bias-correction scalar repairs from the consensus "
                     "iteration with zero snapshot bytes.")
    if out.get("parity"):
        p = out["parity"]
        lines.append("")
        lines.append("### Parity rung (snapshot-free reconstruction, "
                     "donated pair + XOR parity)")
        lines.append("| recovered share | mean repair wall (ms) "
                     "| mean bytes moved | steps replayed "
                     "| memory overhead |")
        lines.append("|---|---|---|---|---|")
        lines.append(
            f"| {100 * p['parity_xor_share']:.0f}% of "
            f"{p['crashes_recovered']} recovered crashes "
            f"| {p['mean_repair_ms']:.1f} "
            f"| {p['mean_bytes_moved']:.0f} B "
            f"| {p['mean_steps_replayed']:.1f} "
            f"| {100 * p['memory_overhead']:.1f}% = 1/D, D="
            f"{p['n_shards']} |")
        lines.append("")
        lines.append(f"Reconstruction reads O(bytes/D) from live "
                     f"survivors + the device-resident parity shard — 0 "
                     f"host-snapshot bytes, 0 replayed steps; every "
                     f"recovered trial bit-exact: {p['all_exact']}. "
                     f"Faults the rung cannot certify (digest-collision "
                     f"ambiguity, multi-shard injury) escalate to replay "
                     f"— exact-or-abort, never a guess.")
    if out.get("serving"):
        s = out["serving"]
        inj, hl = s["injured_added_latency_ms"], s["healthy_added_latency_ms"]
        lines.append("")
        lines.append("### Serving: what a client pays per fault")
        lines.append(
            f"- {s['faults']} faults, "
            f"{100 * s['recovered_fraction']:.0f}% recovered by slot "
            f"eviction + prefix replay; {s['dropped_healthy']} healthy "
            f"requests dropped")
        lines.append(
            f"- recovery wall per fault: mean {s['mean_recovery_ms']:.1f} "
            f"ms, p99 {s['p99_recovery_ms']:.1f} ms (eviction -> victim "
            f"re-admitted)")
        lines.append(
            f"- added e2e latency: injured p50 {inj['p50']:.1f} / "
            f"p99 {inj['p99']:.1f} ms; healthy p50 {hl['p50']:.1f} / "
            f"p99 {hl['p99']:.1f} ms — the training benchmarks' 'lost "
            f"steps' become a per-request latency tax, paid almost "
            f"entirely by the injured request")
    return "\n".join(lines)


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny campaign: render the per-rung table and "
                         "assert the triage/opt_iv rows are present")
    ap.add_argument("--trials", type=int, default=24)
    ap.add_argument("--ckpt-interval", type=int, default=200)
    args = ap.parse_args(argv)

    n_trials = 6 if args.smoke else args.trials
    campaign = Campaign(total_steps=8, snapshot_interval=2)
    out = run(campaign, ckpt_interval=args.ckpt_interval, n_trials=n_trials)
    text = render(out)
    print(text)
    if args.smoke:
        # the seeded probes guarantee both new rungs appear in the table
        for rung in ("triage", "opt_iv"):
            assert rung in out["by_rung"], (
                f"per-rung table is missing the '{rung}' row: "
                f"{sorted(out['by_rung'])}")
            assert f"| {rung} |" in text, f"render lacks the {rung} row"
        print("\nsmoke OK: per-rung table renders with triage + opt_iv rows")
    return out


if __name__ == "__main__":
    main()
