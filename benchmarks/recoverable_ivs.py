"""Table 6: number of recoverable induction variables, original (no ICP)
vs IterPro-transformed, across the assigned architectures' training loops."""

from __future__ import annotations

from typing import Dict

from repro.configs import get_config, list_archs
from repro.core.icp import recoverable_iv_count


def run() -> Dict:
    rows = {}
    for arch in list_archs():
        cfg = get_config(arch)
        orig = recoverable_iv_count(cfg, 256, icp_enabled=False)
        ours = recoverable_iv_count(cfg, 256, icp_enabled=True)
        rows[arch] = {"original": orig, "iterpro": ours}
    return rows


def render(out: Dict) -> str:
    lines = ["## Recoverable induction variables (paper Table 6 analogue)",
             "",
             "| arch (training loop) | original | IterPro (ICP) | gain |",
             "|---|---|---|---|"]
    for arch, r in out.items():
        gain = "BIG" if r["original"] == 0 else \
            f"{100 * (r['iterpro'] / r['original'] - 1):.0f}%"
        lines.append(f"| {arch} | {r['original']} | {r['iterpro']} "
                     f"| {gain} |")
    lines.append("")
    lines.append("Without ICP the loop carries ONE counter (`step`) and "
                 "derives the rest — corruption has no partner to recover "
                 "from (0 recoverable, the paper's EP/IS 'BIG' rows). ICP "
                 "promotes every derived counter to independent state.")
    return "\n".join(lines)
